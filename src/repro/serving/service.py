"""The serving driver: bundle restore, AOT pools, coalescing loops
(DESIGN.md §9/§11).

This is the engine ``launch/serve.py`` is now a thin argparse CLI over.
Three drain loops share the restore/bucket/mesh scaffolding:

- :func:`_batch_loop` — FIFO coalescing over whole-trajectory samplers
  (the PR 4 prototype, kept as the baseline and the latent-sde path);
- :func:`_adaptive_terminal_loop` — terminal sampling with **SLO-aware
  tolerance routing**: requests are bucketed by deadline class and each
  batch runs at the loosest rtol its tightest deadline allows
  (:func:`repro.serving.route_rtol` — replacing PR 5's tightest-ask
  rule); per-row convergence rides back on :class:`ServeResult`;
- :func:`_stream_loop` — chunked long-horizon streaming;

plus :func:`_scheduler_loop`, which drives the continuous-batching
:class:`~repro.serving.Scheduler` over the same synthetic request stream.
"""

from __future__ import annotations

import contextlib
import tempfile
import time
from typing import Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt
from ..distributed.compat import set_mesh
from ..distributed.sharding import data_parallel_mesh
from .registry import (LoadedModel, ModelRegistry, _init_params,
                       restore_for_serving)
from .scheduler import Scheduler, latency_summary, serve_buckets
from .types import (DEADLINE_CLASSES, PAD_SEED, deadline_class_for,
                    percentile, route_rtol, synthetic_requests)

#: Stable private names (the PR 7 API promise): these helpers moved here
#: from launch/serve.py and downstream code may rely on them.
_percentile = percentile


def _fresh_cfg(workload: str, args):
    """Smoke-mode config from the CLI flags (no checkpoint to read one from)."""
    from ..core.sde import LatentSDEConfig, NeuralSDEConfig

    num_steps = 16 if args.sde_steps is None else args.sde_steps
    exact = args.solver == "reversible_heun"
    if workload == "sde-gan":
        return NeuralSDEConfig(
            data_dim=1, hidden_dim=16, noise_dim=4, width=32,
            num_steps=num_steps, solver=args.solver, exact_adjoint=exact,
            use_pallas_kernels=args.pallas)
    return LatentSDEConfig(
        data_dim=2, hidden_dim=16, context_dim=16, width=32,
        num_steps=num_steps, solver=args.solver, exact_adjoint=exact,
        use_pallas_kernels=args.pallas)


def _request_keys(requests, pad_to: int):
    """Key array for a coalesced batch: per-request seeds fanned out per
    row, padded to the bucket size with throwaway keys."""
    parts = [
        jax.vmap(lambda j, s=r.seed: jax.random.fold_in(
            jax.random.PRNGKey(s), j))(jnp.arange(r.size))
        for r in requests
    ]
    used = sum(r.size for r in requests)
    if pad_to > used:
        parts.append(jax.vmap(lambda j: jax.random.fold_in(
            jax.random.PRNGKey(PAD_SEED), j))(jnp.arange(pad_to - used)))
    return jnp.concatenate(parts, axis=0)


def _compile_pool(sampler, params, buckets, *example_args, tag: str = ""):
    """AOT-compile the sampler once per bucket shape.

    ``example_args``: extra example operands after ``(params, keys)`` —
    e.g. the adaptive loop's traced-rtol scalar (shape, not value, is what
    the compile caches on).
    """
    jitted = jax.jit(sampler)
    pool = {}
    for b in buckets:
        keys = jax.random.split(jax.random.PRNGKey(0), b)
        t0 = time.perf_counter()
        pool[b] = jitted.lower(params, keys, *example_args).compile()
        print(f"[serve] compiled {tag}bucket {b} in "
              f"{time.perf_counter() - t0:.2f}s", flush=True)
    return pool


def _coalesce(pending, cap: int):
    """Pop pending requests FIFO until the next one would overflow ``cap``."""
    batch, rows = [], 0
    while pending and rows + pending[0].size <= cap:
        r = pending.popleft()
        batch.append(r)
        rows += r.size
    return batch, rows


def _report(tag: str, stats: dict, total_rows: int, n_batches: int,
            latencies, wall: float) -> None:
    tps = total_rows / max(wall, 1e-9)
    p50, p99 = _percentile(latencies, 0.50), _percentile(latencies, 0.99)
    stats.update(trajectories=total_rows, batches=n_batches,
                 traj_per_s=tps, p50_s=p50, p99_s=p99)
    print(f"[serve] {tag}: {total_rows} trajectories in {n_batches} "
          f"batches @ {tps:.1f} traj/s", flush=True)
    print(f"[serve] latency p50 {p50 * 1e3:.1f}ms p99 {p99 * 1e3:.1f}ms "
          f"(n={len(latencies)} requests, closed-loop)", flush=True)


# -----------------------------------------------------------------------------
# the service entry point
# -----------------------------------------------------------------------------


def serve_sde(workload: str, ckpt_dir: Optional[str], smoke: bool,
              max_batch: int, requests: int, request_max: int,
              latent_mode: str = "prior", obs_len: int = 9,
              stream_chunks: int = 0, adaptive: bool = False,
              atol: float = 1e-6, seed: int = 0,
              scheduler: Optional[str] = None, preempt: bool = False,
              pool_budget_mb: Optional[float] = None,
              async_front: bool = False, args=None) -> dict:
    """Run the trajectory-sampling service; returns the stats dict it prints.

    With ``--smoke`` and no ``--ckpt-dir``, a fresh-initialised model is
    saved to (and restored from) a throwaway serving bundle — the same
    restore path a trained checkpoint takes, exercised end to end.
    ``scheduler`` selects the continuous-batching path (``"continuous"``
    or its ``"fifo"`` baseline) instead of the drain loops; ``preempt``
    (cross-lane preemption), ``pool_budget_mb`` (LRU compile-pool cap)
    and ``async_front`` (drive the drain through
    :class:`~repro.serving.AsyncFrontend` instead of a direct step loop)
    ride on it and require it.
    """
    from ..launch.steps import SERVE_WORKLOADS

    if workload not in SERVE_WORKLOADS:
        raise ValueError(f"serve_sde serves {SERVE_WORKLOADS}, got {workload!r}")
    if adaptive and workload != "sde-gan":
        raise ValueError(
            "--adaptive serves terminal samples from the SDE-GAN generator; "
            "the latent-sde decoders serve whole trajectories, which have no "
            "fixed output grid under adaptive stepping")
    if adaptive and stream_chunks > 1:
        raise ValueError(
            "--adaptive and --stream-chunks are mutually exclusive: "
            "streaming emits a fixed per-chunk grid, adaptive solving "
            "chooses its own")
    if scheduler is not None and workload != "sde-gan":
        raise ValueError(
            "--scheduler drives the continuous-batching chunked rollout, "
            "which is the SDE-GAN generator's carry machinery; latent-sde "
            "serves through the coalescing loop")
    if scheduler is None and (preempt or pool_budget_mb is not None
                              or async_front):
        opts = [n for n, on in (("--preempt", preempt),
                                ("--pool-budget-mb", pool_budget_mb
                                 is not None),
                                ("--async-front", async_front)) if on]
        raise ValueError(
            f"{', '.join(opts)} require the continuous-batching path — "
            f"pass --scheduler continuous (or fifo)")
    if pool_budget_mb is not None and pool_budget_mb <= 0:
        raise ValueError(f"--pool-budget-mb must be positive, got "
                         f"{pool_budget_mb}")
    if requests < 1 or request_max < 1:
        raise ValueError(
            f"--requests ({requests}) and --request-max ({request_max}) "
            f"must both be >= 1 — an empty queue has no latency to report")
    if ckpt_dir is None:
        if not smoke:
            raise ValueError("--ckpt-dir is required without --smoke (a "
                             "production service has a trained model)")
        ckpt_dir = tempfile.mkdtemp(prefix="repro-serve-smoke-")
        cfg = _fresh_cfg(workload, args)
        ckpt.save_serving_bundle(ckpt_dir, 0, _init_params(workload, cfg, seed),
                                 workload, cfg)
        print(f"[serve] --smoke: fresh {workload} bundle at {ckpt_dir}",
              flush=True)
    params, cfg, step = restore_for_serving(workload, ckpt_dir)
    print(f"[serve] restored {workload} serving bundle (train step {step}, "
          f"solver={cfg.solver}, num_steps={cfg.num_steps})", flush=True)

    n_dev = len(jax.devices())
    mesh = data_parallel_mesh()
    if mesh is not None and max_batch < n_dev:
        # a bucket must hold >= one row per device to shard; a tiny
        # --max-batch on a big host serves unsharded instead of dying
        print(f"[serve] --max-batch {max_batch} < {n_dev} devices — "
              f"serving unsharded", flush=True)
        mesh = None
    buckets = serve_buckets(max_batch, n_dev if mesh is not None else 1)
    request_max = min(request_max, buckets[-1])
    mesh_ctx = set_mesh(mesh) if mesh is not None else contextlib.nullcontext()

    stats: dict = {"workload": workload, "restored_step": step,
                   "buckets": buckets, "devices": n_dev}
    with mesh_ctx:
        if mesh is not None:
            print(f"[serve] data-parallel over {n_dev} devices", flush=True)
        if scheduler is not None:
            _scheduler_loop(cfg, params, buckets, requests, request_max,
                            scheduler, seed, stats,
                            shard_base=n_dev if mesh is not None else 1,
                            preempt=preempt, pool_budget_mb=pool_budget_mb,
                            async_front=async_front)
        elif adaptive:
            _adaptive_terminal_loop(cfg, params, buckets, requests,
                                    request_max, atol, seed, stats)
        elif stream_chunks > 1:
            _stream_loop(workload, cfg, params, buckets, requests,
                         request_max, stream_chunks, seed, stats)
        else:
            _batch_loop(workload, cfg, params, buckets, requests, request_max,
                        latent_mode, obs_len, seed, stats)
    return stats


# -----------------------------------------------------------------------------
# drain loops
# -----------------------------------------------------------------------------


def _batch_loop(workload, cfg, params, buckets, requests, request_max,
                latent_mode, obs_len, seed, stats):
    from ..launch.steps import make_sample_step

    sampler = make_sample_step(workload, cfg, latent_mode=latent_mode,
                               obs_len=obs_len)
    pool = _compile_pool(sampler, params, buckets)

    pending = synthetic_requests(requests, request_max, seed)
    latencies, total_rows, n_batches = [], 0, 0
    t_start = time.perf_counter()
    while pending:
        batch, rows = _coalesce(pending, buckets[-1])
        bucket = next(b for b in buckets if b >= rows)
        keys = _request_keys(batch, bucket)
        ys = pool[bucket](params, keys)
        jax.block_until_ready(ys)
        t_now = time.perf_counter()
        latencies += [t_now - t_start] * len(batch)  # closed-loop: all at t0
        total_rows += rows
        n_batches += 1
    wall = time.perf_counter() - t_start
    _report(f"{workload}" + (f"/{latent_mode}" if workload == "latent-sde"
                             else ""),
            stats, total_rows, n_batches, latencies, wall)


def _adaptive_terminal_loop(cfg, params, buckets, requests, request_max,
                            atol, seed, stats):
    """Per-deadline-class terminal sampling (DESIGN.md §10/§11).

    One compiled program per bucket serves EVERY tolerance — ``rtol`` is a
    traced scalar argument of the sampler, so tolerance never enters the
    AOT cache key.  Requests are coalesced *within a deadline class* and
    each batch runs at the loosest rtol its tightest deadline allows
    (:func:`route_rtol` — the SLO routing rule that replaced PR 5's
    tightest-ask minimum).  Budget-exhausted rows come back on
    ``ServeResult.converged`` per request, not only as a log line.
    """
    import collections

    import numpy as np

    from ..launch.steps import make_adaptive_terminal_step

    pool = _compile_pool(make_adaptive_terminal_step(cfg, atol=atol), params,
                         buckets, jnp.asarray(1e-3, cfg.dtype),
                         tag="adaptive ")

    all_pending = synthetic_requests(requests, request_max, seed,
                                     adaptive=True)
    # bucket by deadline class FIRST (tightest first), FIFO within a class
    by_class = collections.OrderedDict(
        (c.name, collections.deque()) for c in DEADLINE_CLASSES)
    for r in all_pending:
        by_class[deadline_class_for(r.deadline_ms).name].append(r)

    results, latencies, total_rows, n_batches, non_converged = [], [], 0, 0, 0
    rtols_served = set()
    t_start = time.perf_counter()
    for cls_name, pending in by_class.items():
        while pending:
            batch, rows = _coalesce(pending, buckets[-1])
            bucket = next(b for b in buckets if b >= rows)
            keys = _request_keys(batch, bucket)
            batch_rtol = route_rtol(batch)  # loosest the deadlines allow
            rtols_served.add(batch_rtol)
            ys, conv = pool[bucket](params, keys,
                                    jnp.asarray(batch_rtol, cfg.dtype))
            jax.block_until_ready(ys)
            t_now = time.perf_counter()
            conv = np.asarray(conv)
            i = 0
            for r in batch:
                results.append(_terminal_result(r, conv[i:i + r.size],
                                                t_now - t_start, batch_rtol))
                i += r.size
            # padding rows don't count; a real non-converged row is a sample
            # at t_final < t1, not Y_T — carried per request on ServeResult
            non_converged += int((~conv[:rows]).sum())
            latencies += [t_now - t_start] * len(batch)
            total_rows += rows
            n_batches += 1
    wall = time.perf_counter() - t_start
    _report("sde-gan/adaptive", stats, total_rows, n_batches, latencies, wall)
    stats["rtols_served"] = sorted(rtols_served)
    stats["classes_served"] = [c for c, q in by_class.items() if not q]
    stats["compiled_programs"] = len(pool)
    stats["non_converged"] = non_converged
    stats["results"] = results
    print(f"[serve] adaptive: {len(rtols_served)} distinct tolerances "
          f"(deadline-routed across {len(by_class)} classes) served by "
          f"{len(pool)} compiled program(s) "
          f"(rtol is traced — no recompiles)", flush=True)
    if non_converged:
        print(f"[serve] WARNING: {non_converged}/{total_rows} rows exhausted "
              f"the adaptive step budget before t1 (served state is at "
              f"t_final < t1) — marked converged=False on their "
              f"ServeResult; raise max_steps or loosen the tolerance",
              flush=True)


def _terminal_result(request, conv, latency_s, rtol):
    from .types import ServeResult

    return ServeResult(rid=request.rid, model_id=request.model_id,
                       size=request.size, converged=conv,
                       latency_s=latency_s, deadline_ms=request.deadline_ms,
                       rtol=rtol)


def _stream_loop(workload, cfg, params, buckets, requests, request_max,
                 stream_chunks, seed, stats):
    """Long-horizon streaming: emit the trajectory in time chunks."""
    from ..core.sde import generator_initial_state
    from ..launch.steps import make_stream_chunk_step

    if workload != "sde-gan":
        raise ValueError("--stream-chunks streams the SDE-GAN generator "
                         "rollout; the latent decoder serves whole "
                         "trajectories")
    if cfg.num_steps % stream_chunks != 0:
        raise ValueError(
            f"--stream-chunks ({stream_chunks}) must divide the solver "
            f"horizon num_steps ({cfg.num_steps}) so chunks share a grid")
    span = cfg.t1 / stream_chunks
    steps_per_chunk = cfg.num_steps // stream_chunks
    jit_chunk = jax.jit(make_stream_chunk_step(cfg, span, steps_per_chunk))
    jit_init = jax.jit(lambda p, keys: generator_initial_state(p, cfg, keys))
    # AOT-compile both programs per bucket BEFORE the clock starts — the
    # t_start scalar is traced, so one chunk program covers every chunk
    init_pool, chunk_pool = {}, {}
    for b in buckets:
        keys = jax.random.split(jax.random.PRNGKey(0), b)
        t0 = time.perf_counter()
        init_pool[b] = jit_init.lower(params, keys).compile()
        x0 = init_pool[b](params, keys)
        chunk_pool[b] = jit_chunk.lower(
            params, keys, x0, jnp.asarray(0.0, cfg.dtype)).compile()
        print(f"[serve] compiled stream bucket {b} in "
              f"{time.perf_counter() - t0:.2f}s", flush=True)

    pending = synthetic_requests(requests, request_max, seed)
    latencies, first_chunk_ms, total_rows, n_batches = [], [], 0, 0
    t_start = time.perf_counter()
    while pending:
        batch, rows = _coalesce(pending, buckets[-1])
        bucket = next(b for b in buckets if b >= rows)
        keys = _request_keys(batch, bucket)
        x = init_pool[bucket](params, keys)
        t_batch0 = time.perf_counter()
        for c in range(stream_chunks):
            ckeys = jax.vmap(
                lambda k, c=c: jax.random.fold_in(k, 1000 + c))(keys)
            ys_c, x = chunk_pool[bucket](params, ckeys, x,
                                         jnp.asarray(c * span, cfg.dtype))
            jax.block_until_ready(ys_c)  # "emitted" to the client here
            if c == 0:
                first_chunk_ms.append((time.perf_counter() - t_batch0) * 1e3)
        t_now = time.perf_counter()
        latencies += [t_now - t_start] * len(batch)
        total_rows += rows
        n_batches += 1
    wall = time.perf_counter() - t_start
    _report(f"sde-gan/stream×{stream_chunks}", stats, total_rows, n_batches,
            latencies, wall)
    stats["first_chunk_ms"] = sum(first_chunk_ms) / len(first_chunk_ms)
    print(f"[serve] stream: mean first-chunk latency "
          f"{stats['first_chunk_ms']:.1f}ms "
          f"({steps_per_chunk}/{cfg.num_steps} steps per chunk)", flush=True)


def _scheduler_loop(cfg, params, buckets, requests, request_max, mode, seed,
                    stats, shard_base: int = 1, preempt: bool = False,
                    pool_budget_mb: Optional[float] = None,
                    async_front: bool = False):
    """Drive the continuous-batching :class:`Scheduler` over the synthetic
    stream (closed-loop: everything arrives at t0; the open-loop Poisson
    driver lives in benchmarks/serving.py).  With ``async_front`` the same
    stream is pushed through :class:`~repro.serving.AsyncFrontend` — N
    concurrent ``submit`` coroutines over the asyncio ingestion path —
    instead of calling ``step`` directly."""
    budget = (None if pool_budget_mb is None
              else int(pool_budget_mb * 2 ** 20))
    registry = ModelRegistry(pool_budget_bytes=budget)
    registry.register(LoadedModel("default", "sde-gan", cfg, params))
    chunks = 4 if cfg.num_steps % 4 == 0 else 1
    sched = Scheduler(registry, max_batch=buckets[-1], chunks=chunks,
                      mode=mode, shard_base=shard_base, preempt=preempt)
    sched.warm("default")
    pending = synthetic_requests(requests, request_max, seed)
    t_start = time.perf_counter()
    if async_front:
        results, n_iter = _drain_async(sched, pending)
    else:
        for r in pending:
            sched.submit(r, arrival_s=0.0)
        results, n_iter = [], 0
        while sched.busy:
            results += sched.step()
            n_iter += 1
    wall = time.perf_counter() - t_start
    _report(f"sde-gan/scheduler-{mode}×{chunks}chunks", stats,
            sum(r.size for r in results), n_iter,
            [r.latency_s for r in results], wall)
    stats.update(latency_summary(results), scheduler=mode, chunks=chunks)
    stats.update(preempt=preempt, frontend="asyncio" if async_front
                 else "direct")
    if budget is not None:
        stats.update(pool_budget_bytes=budget,
                     pool_bytes=registry.pool_bytes(),
                     pool_evictions=registry.evictions)
        print(f"[serve] pool budget {pool_budget_mb:g} MB: "
              f"{registry.pool_bytes()} B resident, "
              f"{registry.evictions} evictions", flush=True)
    print(f"[serve] scheduler: mode={mode}, {len(results)} requests, "
          f"pools={len(registry.pool_keys('default'))} compiled programs "
          f"(chunk t_start per-row traced — admission at chunk boundaries)",
          flush=True)


def _drain_async(sched, pending):
    """Closed-loop drain over the asyncio frontend: one ``submit``
    coroutine per request (all arrivals stamped t=0), gathered to
    completion.  Returns ``(results, engine iterations)``."""
    import asyncio

    from .frontend import AsyncFrontend

    async def drive():
        front = AsyncFrontend(sched)
        await front.start()
        try:
            results = await asyncio.gather(
                *(front.submit(r, arrival_s=0.0) for r in pending))
        finally:
            await front.close()
        return list(results), front.steps

    return asyncio.run(drive())
