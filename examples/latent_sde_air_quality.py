"""Latent SDE on the air-quality-like dataset (paper Table 1 / F.4).

ELBO training (reconstruction + KL path penalty) through the shared launch
step (:func:`repro.launch.steps.make_latent_sde_step`): one ``jax.vjp``
forward per step, Adam per the paper, and a choice of adjoint —

* ``--exact-adjoint`` (default): reversible Heun + the exact O(1)-memory
  adjoint; add ``--pallas`` to run the diagonal-noise hot loop through the
  fused kernels (compiled on TPU, the jnp oracle elsewhere);
* ``--backsolve``: the Li et al. continuous-adjoint baseline (midpoint,
  O(√h) gradient error) the paper improves on;
* ``--gradient-mode checkpoint``: recursive binomial checkpointing —
  gradients exact to floating point at O(log n) memory, for any solver
  (DESIGN.md §12).  ``--gradient-mode`` also accepts ``exact``/
  ``backsolve`` as spellings of the flags above.

``--precision bf16_compute`` evaluates the drift/diffusion fields in
bfloat16 while state and gradient accumulation stay float32.

``--sde-steps`` is validated against the data grid up front: the dataset
has 24 hourly observations (T = 23 intervals), so any positive multiple of
23 is accepted and anything else raises a named ``ValueError`` instead of
a broadcast crash from inside the solve.

Prints ELBO during training and signature-MMD of prior samples vs held-out
data at the end.

Run:  PYTHONPATH=src python examples/latent_sde_air_quality.py --steps 400
"""

import argparse
import time

import jax

from repro.core import losses
from repro.core.sde import LatentSDEConfig, latent_sde_init, latent_sde_sample
from repro.data.synthetic import air_quality_like
from repro.launch.steps import make_latent_sde_optimizer, make_latent_sde_step

SEQ_LEN = 24  # hourly observations (paper F.4) => data grid T = 23


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--sde-steps", type=int, default=SEQ_LEN - 1,
                    help=f"solver steps per solve; must be a positive "
                         f"multiple of the data grid T = {SEQ_LEN - 1}")
    adj = ap.add_mutually_exclusive_group()
    adj.add_argument("--exact-adjoint", dest="adjoint", action="store_const",
                     const="exact", default="exact",
                     help="reversible Heun + exact O(1)-memory adjoint "
                          "(the paper's recipe; default)")
    adj.add_argument("--backsolve", dest="adjoint", action="store_const",
                     const="backsolve",
                     help="continuous-adjoint baseline (midpoint, O(√h) "
                          "gradient error)")
    adj.add_argument("--gradient-mode", dest="adjoint",
                     choices=("exact", "backsolve", "checkpoint"),
                     help="gradient derivation by name; 'checkpoint' = "
                          "recursive binomial checkpointing (exact "
                          "gradients, O(log n) memory, any solver)")
    ap.add_argument("--pallas", action="store_true",
                    help="fuse the diagonal-noise reversible-Heun hot loop "
                         "(requires the exact adjoint)")
    ap.add_argument("--precision", choices=("highest", "bf16_compute"),
                    default="highest",
                    help="field-eval compute policy for every solve "
                         "(bf16_compute keeps accumulation in float32)")
    args = ap.parse_args(argv)

    solver = "midpoint" if args.adjoint == "backsolve" else "reversible_heun"
    cfg = LatentSDEConfig(data_dim=2, hidden_dim=16, context_dim=16, width=32,
                          num_steps=args.sde_steps, solver=solver,
                          exact_adjoint=args.adjoint == "exact",
                          kl_weight=0.1, use_pallas_kernels=args.pallas,
                          precision=args.precision)
    key = jax.random.PRNGKey(0)
    params = latent_sde_init(key, cfg)
    oi, ou = make_latent_sde_optimizer(lr=1e-3)
    state = oi(params)
    # validates --sde-steps against the T = 23 data grid (and the solver ×
    # adjoint × --pallas combination) eagerly, before any jit
    step_fn = jax.jit(make_latent_sde_step(cfg, ou, args.batch, SEQ_LEN,
                                           adjoint=args.adjoint))

    t0 = time.time()
    for step in range(args.steps):
        params, state, m = step_fn(params, state,
                                   jax.random.fold_in(key, 10 + step))
        if step % 50 == 0:
            print(f"step {step:4d}  -ELBO {float(m['loss']):8.4f}  "
                  f"recon {float(m['recon']):.4f}  "
                  f"kl_path {float(m['kl_path']):.4f}  "
                  f"({time.time()-t0:.0f}s)", flush=True)

    ys, _ = air_quality_like(jax.random.fold_in(key, 999), 512, SEQ_LEN)
    samples = latent_sde_sample(params, cfg, jax.random.fold_in(key, 1000), 512)
    stride = cfg.num_steps // (SEQ_LEN - 1)  # align samples to the data grid
    mmd = float(losses.signature_mmd(ys, samples[::stride]))
    print(f"final ({args.adjoint}, {solver}): "
          f"sig-MMD(prior samples, held-out) {mmd:.4f}, "
          f"total {time.time()-t0:.0f}s")
    return mmd


if __name__ == "__main__":
    main()
