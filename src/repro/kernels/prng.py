"""Counter-based PRNG primitives, bit-exact vs ``jax.random`` (Threefry-2x32).

The Brownian kernels (:mod:`repro.kernels.brownian`) generate increments
*inside* the Pallas grid, so the solver's time loop no longer round-trips
to a host-side ``jax.random`` call per step.  For that to be legal the
in-kernel draws must be **bitwise identical** to what
:class:`repro.core.brownian.BrownianPath` produces via ``jax.random`` —
the forward/backward replay contract (DESIGN.md §10) is bitwise, so even
1-ulp drift in the noise would break gradient exactness.

This module is therefore a transcription of the exact op sequence of
JAX's Threefry path (``jax._src.prng``, with the default
``threefry_partitionable=False``), written only with primitives that are
legal inside a Pallas kernel body (elementwise ``lax`` ops, ``iota``,
bitcasts — no ``jax.random``, no key pytrees):

* :func:`threefry2x32` — the 20-round hash (5 × 4 rounds, rotation
  schedule ``(13,15,26,6)/(17,29,16,24)``, key schedule
  ``k0, k1, k0^k1^0x1BD11BDA`` with round-index injections);
* :func:`fold_in` — ``threefry2x32(key, seed_pair(n))``, matching
  ``jax.random.fold_in``'s counter scheme;
* :func:`random_bits` — 32/64-bit streams over an ``iota`` counter with
  JAX's odd-size padding and split-halves layout;
* :func:`uniform` / :func:`normal` — the mantissa-shift bitcast and
  ``sqrt(2)·erf_inv`` transform, op for op.

tests/test_kernel_parity.py pins every function here bitwise against its
``jax.random`` counterpart across dtypes and shapes.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x, d: int):
    d = np.uint32(d)
    return lax.shift_left(x, d) | lax.shift_right_logical(x, np.uint32(32 - d))


def _round4(x0, x1, rots):
    for r in rots:
        x0 = x0 + x1
        x1 = _rotl(x1, r)
        x1 = x0 ^ x1
    return x0, x1


def threefry2x32(k1, k2, x1, x2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The Threefry-2x32 hash; all args uint32, broadcastable.

    Bitwise identical to ``jax._src.prng.threefry2x32_p`` (both the rolled
    and unrolled XLA lowerings compute this same sequence).
    """
    k1 = jnp.asarray(k1, jnp.uint32)
    k2 = jnp.asarray(k2, jnp.uint32)
    x1 = jnp.asarray(x1, jnp.uint32)
    x2 = jnp.asarray(x2, jnp.uint32)
    ks = (k1, k2, k1 ^ k2 ^ _PARITY)
    x1 = x1 + ks[0]
    x2 = x2 + ks[1]
    # 5 groups of 4 rounds; after group i (1-based) inject (ks[i], ks[i+1] + i)
    schedule = ((_ROT_A, 1, 2), (_ROT_B, 2, 0), (_ROT_A, 0, 1),
                (_ROT_B, 1, 2), (_ROT_A, 2, 0))
    for i, (rots, ka, kb) in enumerate(schedule):
        x1, x2 = _round4(x1, x2, rots)
        x1 = x1 + ks[ka]
        x2 = x2 + ks[kb] + np.uint32(i + 1)
    return x1, x2


def seed_pair(data) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``(hi, lo)`` uint32 pair for an integer counter — ``threefry_seed``."""
    data = jnp.asarray(data)
    if data.dtype.itemsize <= 4:
        hi = jnp.zeros((), jnp.uint32)
        lo = lax.convert_element_type(data, jnp.uint32)
    else:
        hi = lax.convert_element_type(
            lax.shift_right_logical(data, np.int64(32)), jnp.uint32)
        lo = lax.convert_element_type(
            jnp.bitwise_and(data, np.uint32(0xFFFFFFFF)), jnp.uint32)
    return hi, lo


def fold_in(k1, k2, data) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """New raw key pair — bitwise ``jax.random.fold_in(key, data)``."""
    hi, lo = seed_pair(data)
    return threefry2x32(k1, k2, hi, lo)


def random_bits(k1, k2, bit_width: int, size: int) -> jnp.ndarray:
    """Flat uint{32,64} stream of ``size`` draws — ``_threefry_random_bits``.

    The counter layout mirrors JAX exactly: ``max_count =
    ceil(bit_width·size/32)`` counters ``iota(uint32, max_count)``,
    zero-padded to even length, split in half for the two hash lanes; for
    64-bit output the two halves recombine as ``hi << 32 | lo``.
    """
    if bit_width not in (32, 64):
        raise ValueError(f"bit_width must be 32 or 64, got {bit_width}")
    max_count = -(-bit_width * size // 32)
    odd = max_count % 2
    half = (max_count + odd) // 2
    counts = lax.iota(jnp.uint32, half)
    x1 = counts
    x2 = counts + np.uint32(half)
    if odd:
        # JAX pads the counter stream with one zero before splitting it in
        # half, hashes, then drops the pad — lane 2's last counter is 0.
        x2 = jnp.where(counts == np.uint32(half - 1), np.uint32(0), x2)
    y1, y2 = threefry2x32(k1, k2, x1, x2)
    bits = lax.concatenate([y1, y2[:half - odd]], 0)
    if bit_width == 64:
        hi = lax.convert_element_type(bits[:size], jnp.uint64)
        lo = lax.convert_element_type(bits[size:], jnp.uint64)
        bits = lax.shift_left(hi, np.uint64(32)) | lo
    return bits


def uniform(k1, k2, size: int, dtype) -> jnp.ndarray:
    """Flat uniforms on the *unit* transform of ``jax.random.uniform``
    with ``minval=lo, maxval=hi`` applied by :func:`normal` — here the
    raw ``bitcast(mantissa | 1.0) − 1`` stream in [0, 1)."""
    dtype = jnp.dtype(dtype)
    finfo = jnp.finfo(dtype)
    nbits, nmant = finfo.bits, finfo.nmant
    uint_dtype = jnp.uint32 if nbits == 32 else jnp.uint64
    bits = random_bits(k1, k2, nbits, size)
    float_bits = lax.bitwise_or(
        lax.shift_right_logical(bits, np.array(nbits - nmant, uint_dtype)),
        np.array(1.0, dtype).view(uint_dtype))
    return lax.bitcast_convert_type(float_bits, dtype) - np.array(1.0, dtype)


def uniform_range(k1, k2, size: int, dtype, minval, maxval) -> jnp.ndarray:
    """``jax.random.uniform(key, (size,), dtype, minval, maxval)`` bitwise."""
    dtype = jnp.dtype(dtype)
    minval = np.array(minval, dtype)
    maxval = np.array(maxval, dtype)
    floats = uniform(k1, k2, size, dtype)
    return lax.max(jnp.broadcast_to(minval, (size,)),
                   floats * (maxval - minval) + minval)


def normal(k1, k2, size: int, dtype) -> jnp.ndarray:
    """Flat standard normals — bitwise ``jax.random.normal(key, (size,))``."""
    dtype = jnp.dtype(dtype)
    lo = np.nextafter(np.array(-1.0, dtype), np.array(0.0, dtype), dtype=dtype)
    hi = np.array(1.0, dtype)
    u = uniform_range(k1, k2, size, dtype, lo, hi)
    return lax.mul(np.array(np.sqrt(2), dtype), lax.erf_inv(u))


def normal_like(k1, k2, shape: Tuple[int, ...], dtype) -> jnp.ndarray:
    """Shaped standard normals — bitwise ``jax.random.normal(key, shape)``."""
    size = math.prod(shape)
    return normal(k1, k2, size, dtype).reshape(shape)


def key_data_pair(key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split a JAX PRNG key (typed or raw ``(2,) uint32``) into scalars."""
    import jax

    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    key = jnp.asarray(key)
    return key[..., 0], key[..., 1]
