"""Fused softmax-cross-entropy Pallas kernel — the LM-loss hot spot.

At production vocab sizes the logits tensor (B·S, V) is the single largest
activation: XLA materialises it, reads it for max, again for exp-sum, again
for the label gather.  This kernel streams vocab tiles through VMEM with a
running (max, sumexp, label-logit) triple — one HBM read of the logits, no
(B·S, V) f32 temporary.

Layout: grid over (row-block, vocab-block) with the vocab axis innermost
(sequential on TPU) so the running statistics stay in VMEM scratch.  Row
blocks are MXU/VPU-aligned multiples of 8; vocab blocks default to 2048
(f32 tile (8, 128) × 16 lanes deep).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bv, logits_ref, labels_ref, loss_ref, m_ref, l_ref, ll_ref):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    x = logits_ref[...].astype(jnp.float32)          # (br, bv)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, -1, keepdims=True))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(x - m_new), -1, keepdims=True)
    m_ref[...] = m_new
    # label logit: the label falls in this vocab block iff in [iv*bv, iv*bv+bv)
    lab = labels_ref[...]                             # (br, 1) int32
    local = lab - iv * bv
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    hit = (cols == local)                             # one-hot within block
    ll_ref[...] = ll_ref[...] + jnp.sum(jnp.where(hit, x, 0.0), -1, keepdims=True)

    @pl.when(iv == pl.num_programs(1) - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        loss_ref[...] = (lse - ll_ref[...]).astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_vocab", "interpret"))
def fused_xent(logits: jax.Array, labels: jax.Array, block_rows: int = 256,
               block_vocab: int = 2048, interpret: bool = True) -> jax.Array:
    """Per-token cross entropy.  logits: (..., V); labels: (...) int32.
    Returns (...) f32 losses (mean-reduce outside)."""
    V = logits.shape[-1]
    flat = logits.reshape(-1, V)
    lab = labels.reshape(-1, 1).astype(jnp.int32)
    R = flat.shape[0]
    br = min(block_rows, R)
    while R % br:
        br //= 2
    bv = min(block_vocab, V)
    while V % bv:
        bv //= 2
    out = pl.pallas_call(
        functools.partial(_kernel, bv),
        grid=(R // br, V // bv),
        in_specs=[
            pl.BlockSpec((br, bv), lambda i, j: (i, j)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((br, 1), jnp.float32),
            pltpu.VMEM((br, 1), jnp.float32),
            pltpu.VMEM((br, 1), jnp.float32),
        ],
        interpret=interpret,
    )(flat, lab)
    return out.reshape(labels.shape)
