"""Brownian sampling tests: exactness, determinism, bridge statistics.

Property-based (hypothesis) tests assert the system invariants:
additivity W(s,u) = W(s,t) + W(t,u), bit-identical replay, and the Lévy
bridge conditional statistics of eq. (8).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st

from repro.core.brownian import BrownianPath, VirtualBrownianTree
from repro.core.brownian_interval import BrownianInterval, HostVirtualBrownianTree


# -----------------------------------------------------------------------------
# host-side Brownian Interval (paper §4, Algorithms 3/4)
# -----------------------------------------------------------------------------


@given(st.lists(st.tuples(st.floats(0.0, 0.99), st.floats(0.01, 1.0)),
                min_size=1, max_size=20),
       st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_interval_additivity(queries, seed):
    """W(s,u) == W(s,m) + W(m,u) for any midpoint, any query history."""
    bi = BrownianInterval(0.0, 1.0, (3,), seed=seed)
    for a, b in queries:
        s, t = min(a, b), max(a, b)
        if t - s < 1e-6:
            continue
        m = 0.5 * (s + t)
        w_st = bi(s, t)
        w_sm = bi(s, m)
        w_mt = bi(m, t)
        np.testing.assert_allclose(w_st, w_sm + w_mt, rtol=1e-9, atol=1e-9)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_interval_deterministic_replay(seed):
    """(a) Re-querying the SAME tree in any order returns identical values —
    the backward-pass requirement (§4).  (b) A fresh tree with the same seed
    and the same query history reproduces the path exactly."""
    qs = [(0.1, 0.3), (0.5, 0.9), (0.0, 0.05), (0.3, 0.5)]
    b1 = BrownianInterval(0.0, 1.0, (4,), seed=seed)
    fwd = [b1(s, t) for s, t in qs]
    bwd = [b1(s, t) for s, t in reversed(qs)][::-1]     # same tree, reversed
    for a, b in zip(fwd, bwd):
        np.testing.assert_allclose(a, b, rtol=1e-12)
    b2 = BrownianInterval(0.0, 1.0, (4,), seed=seed)    # fresh, same history
    again = [b2(s, t) for s, t in qs]
    for a, b in zip(fwd, again):
        np.testing.assert_allclose(a, b, rtol=1e-12)


def test_interval_bridge_statistics():
    """Conditional mean/var of W(0, s) | W(0, 1) matches eq. (8)."""
    n = 4000
    s = 0.3
    samples = np.zeros((n, 2))
    for i in range(n):
        bi = BrownianInterval(0.0, 1.0, (1,), seed=i)
        w01 = bi(0.0, 1.0)[0]
        w0s = bi(0.0, s)[0]
        samples[i] = (w01, w0s)
    w01, w0s = samples[:, 0], samples[:, 1]
    # regress: E[W_{0,s} | W_{0,1}] = s·W_{0,1}; Var = s(1-s)
    slope = np.polyfit(w01, w0s, 1)[0]
    resid_var = np.var(w0s - s * w01)
    assert abs(slope - s) < 0.05, slope
    assert abs(resid_var - s * (1 - s)) < 0.05, resid_var


def test_interval_exact_vs_vbtree_approximate():
    """The Interval aligns with query points (exact); the VBT discretises."""
    bi = BrownianInterval(0.0, 1.0, (1,), seed=7)
    q = (0.123456789, 0.123456789 + 1e-4)
    w1 = bi(*q)
    w2 = bi(*q)
    np.testing.assert_array_equal(w1, w2)  # exact & reproducible
    vb = HostVirtualBrownianTree(0.0, 1.0, (1,), seed=7, eps=1e-2)
    # VBT at coarse eps cannot resolve the tiny interval exactly
    v1 = vb(*q)
    assert v1.shape == (1,)


def test_interval_cache_hits():
    """Forward + backward sweep: with a cache sized to the query count the
    backward pass is all hits (the paper's amortised-O(1) claim); a small
    cache degrades gracefully (evictions -> recompute, still correct)."""
    bi = BrownianInterval(0.0, 1.0, (2,), seed=0, cache_size=1024,
                          preplant_dt=0.01)
    ts = np.linspace(0, 1, 101)
    fwd = [bi(s, t) for s, t in zip(ts[:-1], ts[1:])]
    h_fwd, m_fwd = bi.cache_stats
    bwd = [bi(s, t) for s, t in zip(ts[:-1][::-1], ts[1:][::-1])][::-1]
    for a, b in zip(fwd, bwd):
        np.testing.assert_array_equal(a, b)
    h_all, m_all = bi.cache_stats
    assert m_all == m_fwd, "backward sweep must be pure cache hits"
    assert h_all - h_fwd == 100  # one hit per backward query: amortised O(1)
    # small cache: same values, worse hit rate, no error
    small = BrownianInterval(0.0, 1.0, (2,), seed=0, cache_size=8)
    fwd_small = [small(s, t) for s, t in zip(ts[:-1], ts[1:])]
    for a, b in zip(fwd, fwd_small):
        np.testing.assert_array_equal(a, b)


def test_interval_rejects_bad_query():
    bi = BrownianInterval(0.0, 1.0, (1,))
    with pytest.raises(ValueError):
        bi(0.5, 0.2)
    with pytest.raises(ValueError):
        bi(-0.1, 0.5)


# -----------------------------------------------------------------------------
# in-graph BrownianPath (TPU-native adaptation)
# -----------------------------------------------------------------------------


def test_path_increments_deterministic(key):
    bm = BrownianPath(key, 0.0, 1.0, (8,))
    a = bm.increment(jnp.int32(3), 10)
    b = bm.increment(jnp.int32(3), 10)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_path_increment_statistics(key):
    bm = BrownianPath(key, 0.0, 1.0, (20_000,))
    ws = bm.increments(16)  # (16, 20000)
    var = np.var(np.asarray(ws), axis=1)
    np.testing.assert_allclose(var, 1.0 / 16, rtol=0.1)
    total = np.asarray(jnp.sum(ws, 0))
    assert abs(np.var(total) - 1.0) < 0.05


def test_path_evaluate_additivity(key):
    bm = BrownianPath(key, 0.0, 1.0, (4,), jnp.float64)
    w1 = bm.evaluate(0.25, 0.5)
    w2 = bm.evaluate(0.5, 0.75)
    w3 = bm.evaluate(0.25, 0.75)
    np.testing.assert_allclose(np.asarray(w1 + w2), np.asarray(w3), atol=1e-6)


def test_path_value_evaluate_contract(key):
    """``evaluate(s, t) == value(t) - value(s)`` bitwise, and
    ``value(t0) == 0`` — the contract the adaptive driver's left-endpoint
    carry relies on (DESIGN.md §10) to keep the exact adjoint's backward
    replay bit-identical to the forward.  Pinned at float64 (the adjoint
    replay's precision) — without x64 the requested dtype silently
    truncates to float32."""
    jax.config.update("jax_enable_x64", True)
    try:
        bm = BrownianPath(key, 0.0, 1.0, (4,), jnp.float64)
        assert bm.value(0.0).dtype == jnp.float64
        np.testing.assert_array_equal(np.asarray(bm.value(0.0)),
                                      np.zeros(4))
        for s, t in ((0.0, 0.3), (0.21, 0.77), (0.5, 1.0), (0.137, 0.1371)):
            np.testing.assert_array_equal(
                np.asarray(bm.evaluate(s, t)),
                np.asarray(bm.value(t) - bm.value(s)))
    finally:
        jax.config.update("jax_enable_x64", False)


def test_virtual_brownian_tree_consistency(key):
    vb = VirtualBrownianTree(key, 0.0, 1.0, (4,), tol=1e-4)
    a = vb.evaluate(0.2, 0.7)
    b = vb.evaluate(0.2, 0.7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_path_fwd_bwd_same_noise(seed):
    """The solver requirement (§4): forward and backward passes see
    bit-identical increments with zero storage."""
    bm = BrownianPath(jax.random.PRNGKey(seed), 0.0, 1.0, (4,))
    fwd = [np.asarray(bm.increment(jnp.int32(i), 8)) for i in range(8)]
    bwd = [np.asarray(bm.increment(jnp.int32(i), 8)) for i in range(7, -1, -1)]
    for a, b in zip(fwd, bwd[::-1]):
        np.testing.assert_array_equal(a, b)


@given(st.floats(0.02, 0.98), st.floats(0.02, 0.98), st.floats(0.0, 1.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_path_evaluate_additive_over_adjacent_intervals(a, b, frac, seed):
    """System invariant: ``evaluate`` is additive over adjacent intervals —
    W(s,u) == W(s,t) + W(t,u) for ANY interior split point t, because every
    query is the difference of deterministic W(·) samples.  Property-based
    over (interval, split, seed)."""
    s, u = min(a, b), max(a, b)
    if u - s < 1e-3:
        u = s + 1e-3
    t = s + frac * (u - s)
    bm = BrownianPath(jax.random.PRNGKey(seed), 0.0, 1.0, (3,))
    w_su = np.asarray(bm.evaluate(s, u))
    w_st = np.asarray(bm.evaluate(s, t))
    w_tu = np.asarray(bm.evaluate(t, u))
    np.testing.assert_allclose(w_st + w_tu, w_su, atol=1e-5, rtol=1e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_vbtree_grid_increments_sum_to_full_interval(seed, n):
    """System invariant: VirtualBrownianTree increments over an ``n``-step
    grid telescope to ``evaluate(t0, t1)`` — each increment is a difference
    of deterministic W(·) samples, so the interior points cancel exactly."""
    vb = VirtualBrownianTree(jax.random.PRNGKey(seed), 0.0, 1.0, (3,),
                             tol=1e-4)
    total = sum(np.asarray(vb.increment(jnp.int32(i), n)) for i in range(n))
    full = np.asarray(vb.evaluate(0.0, 1.0))
    np.testing.assert_allclose(total, full, atol=1e-5, rtol=1e-5)


def test_dense_path_pathwise_consistent_refinement(key):
    """DenseBrownianPath: coarse increments are sums of fine ones — the
    property strong-convergence measurement needs.  Pinned at float64 (the
    1e-12 tolerance is an f64 claim) — without x64 the requested dtype
    silently truncates to float32."""
    from repro.core.brownian import DenseBrownianPath

    jax.config.update("jax_enable_x64", True)
    try:
        bm = DenseBrownianPath.sample(key, 0.0, 1.0, 64, (5,), jnp.float64)
        for n_coarse in (8, 16, 32):
            r = 64 // n_coarse
            for n in range(0, n_coarse, 3):
                coarse = bm.increment(jnp.int32(n), n_coarse)
                fine = sum(bm.increment(jnp.int32(n * r + i), 64)
                           for i in range(r))
                np.testing.assert_allclose(np.asarray(coarse),
                                           np.asarray(fine),
                                           rtol=1e-12, atol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", False)
