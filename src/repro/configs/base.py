"""Architecture config schema + input-shape sets for the assigned pool."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None   # default d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"
    ffn: str = "swiglu"              # swiglu | gelu
    rope_theta: float = 10_000.0
    dtype: object = jnp.bfloat16

    # --- attention flavour
    attention: str = "gqa"           # gqa | mla
    # MLA (MiniCPM3 / DeepSeek-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25

    # --- hybrid / SSM
    ssm: bool = False                # pure-SSM stack (mamba2)
    attn_every: int = 0              # hybrid: attention layer every k-th (jamba: 8)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4

    # --- encoder-decoder
    encoder_layers: int = 0          # >0 => enc-dec; num_layers is decoder depth

    # --- multimodal stub frontend
    frontend: Optional[str] = None   # "patch" (vlm) | "frame" (audio)
    frontend_len: int = 0            # prefix length supplied as embeddings

    # --- execution knobs (perf levers; see EXPERIMENTS.md §Perf)
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "full"         # "full" | "collectives" (save post-AR
    #   activations so the backward never re-runs TP all-reduces; §Perf C2)
    reversible_residual: bool = False  # beyond-paper: reversible-Heun layer stack
    sequence_parallel: bool = False    # shard residual-stream seq dim over 'model'
    attn_mha_tp: bool = True           # repeat K/V to Hq when Hkv % tp != 0
    #   (clean head-sharding; found in §Perf iteration 1 — see EXPERIMENTS.md)
    attn_impl: str = "scan"            # "scan" (O(1) HLO) | "unrolled" (exact cost_analysis)
    attn_block_q: int = 1024
    attn_block_k: int = 1024
    adam_dtype: str = "float32"        # "bfloat16" halves optimizer-state HBM

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (total; for MoE also see active_param_count)."""
        from ..models.counting import param_count

        return param_count(self)

    def active_param_count(self) -> int:
        from ..models.counting import param_count

        return param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence mixing; decode with a full KV cache
# is linear per token but the brief assigns it only to SSM/hybrid archs.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_is_runnable(arch: "ArchConfig", shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch.family not in LONG_CONTEXT_FAMILIES:
        return False, "long_500k skipped: full-attention arch (see DESIGN.md §6)"
    return True, ""
