"""SDE-GAN Lipschitz control without gradient penalty (paper §5).

The discriminator CDE's vector fields must have Lipschitz constant ≤ 1 —
the recurrent structure amplifies any λ > 1 to O(λ^T).  The paper's recipe:

* **hard clipping**: each linear map's entries are clipped into
  ``[-1/fan_in, 1/fan_in]`` after every optimiser update, enforcing
  ``‖Ax‖∞ ≤ ‖x‖∞``;
* **LipSwish** activations (Lipschitz 1, C²-smooth — required for solver
  convergence, Appendix D).

Applied as a *functional transform* on the parameter pytree (JAX has no
in-place ``clamp_``), keyed on the MLP parameter naming of
:mod:`repro.nn.core`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_linear(params: dict) -> dict:
    """Clip one Linear's weight entries to [-1/fan_in, 1/fan_in]; bias passes
    through (adding a bias has Lipschitz constant one, paper §5)."""
    w = params["w"]
    bound = 1.0 / w.shape[0]
    out = dict(params)
    out["w"] = jnp.clip(w, -bound, bound)
    return out


def clip_mlp(params: dict) -> dict:
    return {"layers": [clip_linear(p) for p in params["layers"]]}


def clip_lipschitz(tree, mlp_names=("f", "g", "xi")):
    """Clip the named discriminator MLPs inside a parameter tree."""
    out = dict(tree)
    for name in mlp_names:
        if name in out:
            out[name] = clip_mlp(out[name])
    return out


def lipschitz_bound_mlp(params: dict) -> float:
    """Upper bound on the MLP's ∞-norm Lipschitz constant (∏ max row-ℓ1)."""
    bound = 1.0
    for p in params["layers"]:
        bound = bound * jnp.max(jnp.sum(jnp.abs(p["w"]), axis=0))
    return bound
