"""Careful clipping: SDE-GAN Lipschitz control without gradient penalty (§5).

The discriminator CDE's vector fields must have Lipschitz constant ≤ 1 —
the recurrent structure amplifies any λ > 1 to O(λ^T).  The paper's recipe
(DESIGN.md §4):

* **hard clipping**: each linear map's entries are clipped into
  ``[-1/fan_in, 1/fan_in]`` after every optimiser update, enforcing
  ``‖Ax‖∞ ≤ ‖x‖∞`` (column ℓ1 sums ≤ 1);
* **LipSwish** activations (Lipschitz 1, C²-smooth — required for solver
  convergence, Appendix D).

Clipping is a *projection onto the constraint set applied after the
optimiser update* — not gradient clipping, and not a loss penalty.  That
ordering is the whole point: a penalty (WGAN-GP) needs a second backward
pass through the CDE solve, which doubles the cost and is incompatible with
the O(1)-memory reversible adjoint (no double-backward rule); a projection
touches only the parameter pytree and costs one elementwise pass.

Three layers of API, most-general first:

* :func:`clip_pytree` — walk any parameter pytree and project every MLP
  (``{"layers": [...]}`` subtree) it contains; bare Linears (readouts like
  the discriminator's ``m``) pass through untouched.
* :func:`clip_lipschitz` — the historical name-keyed entry point (clips the
  ``f``/``g``/``xi`` MLPs of a discriminator tree); kept as the stable API.
* :func:`lipschitz_projection` in :mod:`repro.optim` wraps either as an
  optax-style ``(init, update)`` transform so the projection composes with
  any optimiser chain.

Everything is a functional transform on the pytree (JAX has no in-place
``clamp_``), keyed on the MLP parameter naming of :mod:`repro.nn.core`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_linear(params: dict) -> dict:
    """Clip one Linear's weight entries to [-1/fan_in, 1/fan_in]; bias passes
    through (adding a bias has Lipschitz constant one, paper §5)."""
    w = params["w"]
    bound = 1.0 / w.shape[0]
    out = dict(params)
    out["w"] = jnp.clip(w, -bound, bound)
    return out


def clip_mlp(params: dict) -> dict:
    return {"layers": [clip_linear(p) for p in params["layers"]]}


def _is_mlp(node) -> bool:
    return isinstance(node, dict) and set(node) == {"layers"}


def clip_pytree(tree):
    """Project every MLP inside an arbitrary parameter pytree.

    Structural, not name-keyed: any ``{"layers": [...]}`` subtree (the MLP
    convention of :mod:`repro.nn.core`) is clipped per-layer; everything
    else — bare Linears, norms, readouts — is returned unchanged.  This is
    what makes the projection composable with optimisers that see only an
    opaque pytree: no registry of which names are vector fields.
    """
    if _is_mlp(tree):
        return clip_mlp(tree)
    if isinstance(tree, dict):
        return {k: clip_pytree(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(clip_pytree(v) for v in tree)
    return tree


def clip_lipschitz(tree, mlp_names=("f", "g", "xi")):
    """Clip the named discriminator MLPs inside a parameter tree.

    The discriminator convention: vector fields ``f``/``g`` and the initial
    network ``xi`` are constrained; the readout ``m`` is not (it is applied
    once, not recurrently).  For trees following the nn.core MLP structure
    this agrees with :func:`clip_pytree` restricted to those names.
    """
    out = dict(tree)
    for name in mlp_names:
        if name in out:
            out[name] = clip_mlp(out[name])
    return out


# -----------------------------------------------------------------------------
# diagnostics — used by tests and benchmarks/clipping.py
# -----------------------------------------------------------------------------


def lipschitz_bound_mlp(params: dict) -> jax.Array:
    """Upper bound on the MLP's ∞-norm Lipschitz constant (∏ max col-ℓ1)."""
    bound = 1.0
    for p in params["layers"]:
        bound = bound * jnp.max(jnp.sum(jnp.abs(p["w"]), axis=0))
    return bound


def per_layer_violation(params: dict) -> jax.Array:
    """Max over layers of ``fan_in · max|w|`` — ≤ 1 iff every entry is inside
    its clipping box.  The per-layer bound the careful-clipping tests pin."""
    v = jnp.asarray(0.0)
    for p in params["layers"]:
        v = jnp.maximum(v, p["w"].shape[0] * jnp.max(jnp.abs(p["w"])))
    return v


def max_lipschitz_bound(tree, mlp_names=("f", "g", "xi")) -> jax.Array:
    """Worst ∞-norm Lipschitz bound across the named MLPs of a tree."""
    b = jnp.asarray(0.0)
    for name in mlp_names:
        if name in tree:
            b = jnp.maximum(b, lipschitz_bound_mlp(tree[name]))
    return b
