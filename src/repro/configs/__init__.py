"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses

from . import (
    dbrx_132b,
    grok_1_314b,
    jamba_v0_1_52b,
    mamba2_1_3b,
    minicpm3_4b,
    pixtral_12b,
    qwen2_5_14b,
    seamless_m4t_medium,
    starcoder2_3b,
    tinyllama_1_1b,
)
from .base import LONG_CONTEXT_FAMILIES, SHAPES, ArchConfig, ShapeConfig, cell_is_runnable

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        pixtral_12b, qwen2_5_14b, minicpm3_4b, starcoder2_3b, tinyllama_1_1b,
        dbrx_132b, grok_1_314b, jamba_v0_1_52b, seamless_m4t_medium, mamba2_1_3b,
    )
}

ARCH_NAMES = sorted(REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return REGISTRY[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny vocab — exercises the identical code path."""
    import jax.numpy as jnp

    cfg = get_config(name)
    updates = dict(
        num_layers=max(2, cfg.attn_every or 2) if cfg.family == "hybrid" else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.ssm and cfg.family == "ssm" else 128,
        vocab=256,
        dtype=jnp.float32,
        frontend_len=8 if cfg.frontend else 0,
        scan_layers=False,
        remat=False,
    )
    if cfg.attention == "mla":
        updates.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                       qk_rope_head_dim=8, v_head_dim=8, head_dim=16)
    if cfg.moe:
        updates.update(num_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.ssm or cfg.family == "hybrid":
        updates.update(ssm_state=16, ssm_headdim=16)
    if cfg.encoder_layers:
        updates.update(encoder_layers=2)
    return dataclasses.replace(cfg, **updates)


__all__ = [
    "REGISTRY", "ARCH_NAMES", "get_config", "smoke_config",
    "ArchConfig", "ShapeConfig", "SHAPES", "cell_is_runnable", "LONG_CONTEXT_FAMILIES",
]
