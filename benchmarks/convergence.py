"""Paper Appendix D.4 (Figs. 5/6): strong/weak convergence order, plus the
adaptive cost-vs-accuracy frontier (DESIGN.md §10).

Two experiments:

1. **Order fits** — anharmonic oscillator ``dy = sin(y) dt + dW`` (additive
   noise), y0 = 1, T = 1.  Reversible Heun should show strong order ~1.0
   and weak order ~2.0 in the additive-noise setting (Theorems D.13-D.17),
   matching standard Heun.

2. **Frontier** (EXPERIMENTS.md §Frontier) — a time-localised stiffness
   burst ``dy = θ(t)(m − y) dt + σ dW`` with ``θ(t) = a + A·exp(−((t−c)/w)²)``:
   the dynamics are flat outside a narrow window, so an adaptive controller
   concentrates its steps there.  Gates (asserted at run time):

   * adaptive reversible Heun reaches its achieved strong error with
     **fewer vector-field evaluations** than the fixed uniform grid that
     error level requires (log-log interpolation of the fixed-grid error
     curve), on a *shared* ``DenseBrownianPath`` per path;
   * the accepted-step sequence replays **bitwise**: a plain scan over the
     stored ``(ts, dts)`` reproduces the adaptive terminal state exactly;
   * the exact adjoint's backward reconstruction over the accepted grid
     matches the forward states to float64 round-off, and its parameter
     gradient matches plain AD through the frozen-grid replay likewise.

3. **SRK order + crossing** (DESIGN.md §13; EXPERIMENTS.md §Frontier) —
   geometric Brownian motion with its pathwise-exact terminal value as
   reference, on a shared ``DenseBrownianPath`` whose W leaves are
   bitwise-identical between the space-time mode (SRK consumes (W, H))
   and the plain mode (reversible Heun consumes W).  Gates: the SRK
   log-log strong-error slope sits in [1.4, 1.6], and the error-vs-NFE
   curves cross — reversible Heun (1 NFE/step, order 1.0) is more
   accurate per evaluation at coarse budgets, SRK (5 NFE/step, order
   1.5) past the crossover.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from . import report
except ImportError:  # run as a loose script
    import report


def run(solver: str, num_steps: int, bm, y0):
    from repro.core.solvers import sde_solve

    drift = lambda p, t, y: jnp.sin(y)
    diffusion = lambda p, t, y: jnp.ones_like(y)
    coarse = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, num_steps,
                       solver=solver, save_trajectory=False)
    # fine reference on the SAME Brownian path (paper's protocol: "obtained
    # using the same Brownian sample paths", 10x finer)
    fine = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, bm.fine_steps,
                     solver="heun", save_trajectory=False)
    return np.asarray(coarse[..., 0]), np.asarray(fine[..., 0])


def empirical_orders(solver: str, n_paths: int = 20_000):
    from repro.core.brownian import DenseBrownianPath

    key = jax.random.PRNGKey(42)
    y0 = jnp.ones((n_paths, 1), jnp.float64)
    bm = DenseBrownianPath.sample(key, 0.0, 1.0, 640, (n_paths, 1), jnp.float64)
    hs, strong, weak1 = [], [], []
    for num_steps in (8, 16, 32, 64):
        c, f = run(solver, num_steps, bm, y0)
        hs.append(1.0 / num_steps)
        strong.append(np.mean(np.abs(c - f)))
        weak1.append(abs(np.mean(c) - np.mean(f)))
    fit = lambda errs: np.polyfit(np.log(hs), np.log(np.maximum(errs, 1e-16)), 1)[0]
    return fit(strong), fit(weak1)


PRESET_PATHS = {"tiny": 2_000, "quick": 5_000, "full": 50_000}


# -----------------------------------------------------------------------------
# Adaptive cost-vs-accuracy frontier (DESIGN.md §10; EXPERIMENTS.md §Frontier)
# -----------------------------------------------------------------------------

#: Burst problem: θ(t) = BURST_A + BURST_AMP·exp(−((t−BURST_C)/BURST_W)²).
#: Outside the window the dynamics are near-flat (big steps are fine);
#: inside, explicit stability needs θ·dt ≲ 2 → dt ≲ 0.06.
BURST_A, BURST_AMP, BURST_C, BURST_W = 0.5, 30.0, 0.5, 0.05
BURST_SIGMA = 0.05
FRONTIER_FINE = 4096
FRONTIER_FIXED_GRIDS = (16, 32, 64, 128, 256, 512)
PRESET_FRONTIER_PATHS = {"tiny": 64, "quick": 128, "full": 512}


def _burst_fields():
    def drift(p, t, y):
        theta = BURST_A + BURST_AMP * jnp.exp(-(((t - BURST_C) / BURST_W) ** 2))
        return theta * (1.0 - y)

    def diffusion(p, t, y):
        return BURST_SIGMA * jnp.ones_like(y)

    return drift, diffusion


def frontier(preset: str):
    """NFE to reach a target strong error: adaptive vs best fixed grid."""
    from repro.core.brownian import DenseBrownianPath
    from repro.core.solve import solve_adaptive
    from repro.core.solvers import sde_solve

    drift, diffusion = _burst_fields()
    n_paths = PRESET_FRONTIER_PATHS[preset]
    key = jax.random.PRNGKey(7)
    y0 = jnp.zeros((n_paths, 1), jnp.float64)
    bm = DenseBrownianPath.sample(key, 0.0, 1.0, FRONTIER_FINE,
                                  (n_paths, 1), jnp.float64)
    ref = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, FRONTIER_FINE,
                    solver="heun", save_trajectory=False)
    ref = np.asarray(ref[..., 0])

    # fixed uniform grids, all paths in one batched solve on the SAME path
    fixed_err = []
    for n in FRONTIER_FIXED_GRIDS:
        zT = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, n,
                       solver="reversible_heun", save_trajectory=False)
        fixed_err.append(np.mean(np.abs(np.asarray(zT[..., 0]) - ref)))

    # adaptive, one controller per path (vmapped), same dense sample paths
    def one(wi, y0i):
        bmi = DenseBrownianPath(wi, 0.0, 1.0)
        z, st = solve_adaptive(drift, diffusion, None, y0i, bmi, 0.0, 1.0,
                               solver="reversible_heun", rtol=2e-3, atol=1e-5,
                               max_steps=2048, dt0=1.0 / 16)
        return z, st.nfe, st.converged

    zT_a, nfe, conv = jax.vmap(one)(jnp.moveaxis(bm.w, 1, 0), y0)
    assert bool(jnp.all(conv)), "adaptive solves must converge within budget"
    adaptive_err = float(np.mean(np.abs(np.asarray(zT_a[..., 0]) - ref)))
    adaptive_nfe = float(np.mean(np.asarray(nfe)))

    # fixed-grid NFE needed for the adaptive error level: log-log interp of
    # the (error -> NFE) curve (NFE = num_steps + 1 at 1 eval/step).
    # np.interp needs increasing xp; at finite path counts adjacent grids
    # can invert by sampling noise, so force the coarsening-direction curve
    # monotone (running max of error as grids coarsen) before interpolating
    log_err = np.log(np.maximum.accumulate(np.asarray(fixed_err)[::-1]))
    log_nfe = np.log(np.asarray(FRONTIER_FIXED_GRIDS, float)[::-1] + 1.0)
    fixed_nfe_at_err = float(np.exp(np.interp(np.log(adaptive_err),
                                              log_err, log_nfe)))
    savings = fixed_nfe_at_err / adaptive_nfe
    print(f"convergence_frontier,adaptive: err={adaptive_err:.2e} "
          f"nfe={adaptive_nfe:.0f}; fixed grid needs "
          f"~{fixed_nfe_at_err:.0f} nfe for that error "
          f"({savings:.2f}x savings)", flush=True)
    for n, e in zip(FRONTIER_FIXED_GRIDS, fixed_err):
        print(f"convergence_frontier,fixed,n={n},err={e:.2e}", flush=True)
    # THE gate: adaptive must beat the best fixed grid on evaluations
    assert adaptive_nfe < fixed_nfe_at_err, (
        f"adaptive stepping must reach its error with fewer NFEs than a "
        f"uniform grid: adaptive {adaptive_nfe:.0f} vs fixed "
        f"{fixed_nfe_at_err:.0f}")
    return [
        ("convergence_frontier", "adaptive_strong_error", adaptive_err),
        ("convergence_frontier", "adaptive_nfe", adaptive_nfe),
        ("convergence_frontier", "fixed_nfe_matching_error", fixed_nfe_at_err),
        ("convergence_frontier", "nfe_savings_ratio", savings),
    ]


# -----------------------------------------------------------------------------
# SRK strong-order gate + error-vs-NFE crossing (DESIGN.md §13)
# -----------------------------------------------------------------------------

#: GBM test problem dz = μz dt + σz dW (Itô); multiplicative noise makes
#: the *stochastic* discretisation error dominate, which is where the
#: order-1.5 scheme separates from order-1.0 ones.  (On the additive-noise
#: burst above both solvers are deterministic-error-dominated at order ~2
#: and the 5×-NFE SRK step never pays for itself.)
SRK_MU, SRK_SIGMA = 0.7, 0.5
SRK_FINE = 4096
SRK_GRIDS = (8, 16, 32, 64, 128)                  # SRK: 5 NFE/step
SRK_HEUN_GRIDS = (32, 64, 128, 256, 512, 1024)    # reversible Heun: 1 NFE/step
PRESET_SRK_PATHS = {"tiny": 512, "quick": 1000, "full": 2000}


def srk_frontier(preset: str):
    """Order-1.5 slope gate + the SRK / reversible-Heun NFE crossing.

    Both solvers integrate the SAME Itô SDE: SRK natively, reversible
    Heun through the Stratonovich form (drift μ − σ²/2).  The reference
    is the pathwise-exact terminal value ``exp((μ−σ²/2)T + σW_T)`` — no
    fine solve, so the measured slopes are pure scheme error.  The W
    sample paths are shared bitwise across modes: the plain-mode
    ``DenseBrownianPath`` is built from the space-time path's own ``w``
    leaf.
    """
    from repro.core.brownian import DenseBrownianPath
    from repro.core.solve import solve
    from repro.core.solvers import sde_solve

    n_paths = PRESET_SRK_PATHS[preset]
    key = jax.random.PRNGKey(11)
    y0 = jnp.ones((n_paths, 1), jnp.float64)
    bm_st = DenseBrownianPath.sample(key, 0.0, 1.0, SRK_FINE, (n_paths, 1),
                                     jnp.float64, levy_area="space-time")
    bm = DenseBrownianPath(bm_st.w, 0.0, 1.0)  # same W bitwise, no H
    wT, _ = bm_st.value(1.0)
    exact = np.asarray(jnp.exp((SRK_MU - 0.5 * SRK_SIGMA ** 2)
                               + SRK_SIGMA * wT)[..., 0])

    ito_drift = lambda p, t, z: SRK_MU * z
    strat_drift = lambda p, t, z: (SRK_MU - 0.5 * SRK_SIGMA ** 2) * z
    diffusion = lambda p, t, z: SRK_SIGMA * z

    def err(zT):
        return float(np.mean(np.abs(np.asarray(zT[..., 0]) - exact)))

    srk_err = [err(solve(ito_drift, diffusion, None, y0, bm_st, 0.0, 1.0, n,
                         solver="srk", save_trajectory=False))
               for n in SRK_GRIDS]
    heun_err = [err(sde_solve(strat_drift, diffusion, None, y0, bm, 0.0, 1.0,
                              n, solver="reversible_heun",
                              save_trajectory=False))
                for n in SRK_HEUN_GRIDS]

    slope = float(-np.polyfit(np.log(np.asarray(SRK_GRIDS, float)),
                              np.log(srk_err), 1)[0])
    srk_nfe = [5 * n for n in SRK_GRIDS]
    heun_nfe = list(SRK_HEUN_GRIDS)  # 1 NFE/step
    rows = [("convergence_srk", "srk_strong_order", slope)]
    for nfe, e in zip(srk_nfe, srk_err):
        rows.append(("convergence_srk", f"srk_err_at_nfe_{nfe}", e))
        print(f"convergence_srk,srk,nfe={nfe},err={e:.3e}", flush=True)
    for nfe, e in zip(heun_nfe, heun_err):
        rows.append(("convergence_srk", f"revheun_err_at_nfe_{nfe}", e))
        print(f"convergence_srk,revheun,nfe={nfe},err={e:.3e}", flush=True)

    # log-log interpolation of both error-vs-NFE curves over the common
    # NFE range; the crossover is where the difference changes sign
    lo, hi = max(srk_nfe[0], heun_nfe[0]), min(srk_nfe[-1], heun_nfe[-1])
    srk_at = lambda lnfe: np.interp(lnfe, np.log(srk_nfe), np.log(srk_err))
    heun_at = lambda lnfe: np.interp(lnfe, np.log(heun_nfe), np.log(heun_err))
    grid = np.linspace(np.log(lo), np.log(hi), 256)
    diff = srk_at(grid) - heun_at(grid)
    crossover = float(np.exp(grid[int(np.argmax(diff < 0))]))
    print(f"convergence_srk,srk_strong_order={slope:.2f} "
          f"(gate [1.4, 1.6]); crossover_nfe~{crossover:.0f} "
          f"(revheun better below, srk better above)", flush=True)

    assert 1.4 <= slope <= 1.6, (
        f"SRK strong order {slope:.3f} outside the order-1.5 gate "
        f"[1.4, 1.6] — the (W, H) pair or the tableau is wrong")
    assert diff[0] > 0, (
        f"reversible Heun must be more accurate per NFE at the coarse end "
        f"(nfe={lo}): srk {np.exp(srk_at(grid[0])):.2e} vs "
        f"revheun {np.exp(heun_at(grid[0])):.2e}")
    assert diff[-1] < 0, (
        f"SRK must be more accurate per NFE at the fine end (nfe={hi}): "
        f"srk {np.exp(srk_at(grid[-1])):.2e} vs "
        f"revheun {np.exp(heun_at(grid[-1])):.2e}")
    rows.append(("convergence_srk", "crossover_nfe", crossover))
    return rows


def replay_gates():
    """Accepted-grid replay contract (float64): bitwise forward replay,
    round-off-level backward reconstruction, exact-adjoint gradient ==
    frozen-grid AD.

    Every program here evaluates the IDENTICAL parametrised drift — the
    accepted grid is a sequence of fp-boundary accept decisions, and two
    XLA programs with *different* op graphs (e.g. one with a ``+θ`` the
    other without) may round an ulp apart and flip a decision; identical
    graphs compile to bit-identical loop bodies (the property the gate
    pins).
    """
    from jax import lax

    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve, solve_adaptive
    from repro.core.solvers import (RevHeunState, apply_diffusion,
                                    reversible_heun_step)

    base_drift, diffusion = _burst_fields()
    drift = lambda p, t, y: base_drift(None, t, y) + p["shift"]
    p0 = {"shift": jnp.float64(0.0)}
    key = jax.random.PRNGKey(3)
    z0 = jnp.zeros((4,), jnp.float64)
    bm = BrownianPath(key, 0.0, 1.0, (4,), jnp.float64)
    rtol, atol, max_steps, dt0 = 1e-4, 1e-7, 2048, 1.0 / 16

    zT, st = solve_adaptive(drift, diffusion, p0, z0, bm, 0.0, 1.0,
                            solver="reversible_heun", rtol=rtol, atol=atol,
                            max_steps=max_steps, dt0=dt0)
    n = int(st.num_accepted)
    ts, dts = st.ts, st.dts

    def replay(p, z0_):
        s0 = RevHeunState(z0_, z0_, drift(p, 0.0, z0_), diffusion(p, 0.0, z0_))

        def body(s, i):
            dw = bm.evaluate(ts[i], ts[i] + dts[i]).astype(z0_.dtype)
            new = reversible_heun_step(s, ts[i], dts[i], dw, drift, diffusion,
                                       p, "diagonal")
            return new, s.z

        fin, z_hist = lax.scan(body, s0, jnp.arange(n))
        return fin, z_hist

    fin, z_hist = replay(p0, z0)
    bitwise_mismatch = float(jnp.sum(fin.z != zT))

    def reverse(s, i):
        dt, tl = dts[i], ts[i]
        dw = bm.evaluate(tl, tl + dt).astype(z0.dtype)
        z1, zh1, mu1, s1 = s
        zh = 2.0 * z1 - zh1 - mu1 * dt - apply_diffusion(s1, dw, "diagonal")
        mu = drift(p0, tl, zh)
        sg = diffusion(p0, tl, zh)
        z = z1 - 0.5 * (mu + mu1) * dt - apply_diffusion(0.5 * (sg + s1), dw,
                                                         "diagonal")
        return RevHeunState(z, zh, mu, sg), z

    _, z_rec = lax.scan(reverse, fin, jnp.arange(n - 1, -1, -1))
    recon_err = float(jnp.max(jnp.abs(z_rec[::-1] - z_hist)))

    g_adj = jax.grad(lambda p: jnp.sum(solve(
        drift, diffusion, p, z0, bm, 0.0, 1.0, 16,
        solver="reversible_heun", gradient_mode="reversible_adjoint",
        save_trajectory=False, adaptive=True, rtol=rtol, atol=atol,
        max_steps=max_steps, dt0=dt0) ** 2))(p0)

    def replay_p(p):
        s0 = RevHeunState(z0, z0, drift(p, 0.0, z0), diffusion(p, 0.0, z0))

        def body(s, i):
            dw = bm.evaluate(ts[i], ts[i] + dts[i]).astype(z0.dtype)
            return reversible_heun_step(s, ts[i], dts[i], dw, drift,
                                        diffusion, p, "diagonal"), None

        fin_, _ = lax.scan(body, s0, jnp.arange(n))
        return jnp.sum(fin_.z ** 2)

    g_rep = jax.grad(replay_p)(p0)
    grad_err = float(jnp.max(jnp.abs(g_adj["shift"] - g_rep["shift"])))

    print(f"convergence_frontier,replay: accepted={n} "
          f"bitwise_mismatch={bitwise_mismatch:.0f} "
          f"reconstruction_err={recon_err:.2e} grad_err={grad_err:.2e}",
          flush=True)
    assert bitwise_mismatch == 0.0, \
        "forward replay over the stored accepted grid must be bitwise"
    assert recon_err < 1e-12, \
        f"backward reconstruction must be at float64 round-off: {recon_err}"
    assert grad_err < 1e-10, \
        f"exact adjoint must match frozen-grid AD: {grad_err}"
    return [
        ("convergence_frontier", "replay_bitwise_mismatch", bitwise_mismatch),
        ("convergence_frontier", "reconstruction_max_err", recon_err),
        ("convergence_frontier", "adjoint_vs_replay_grad_err", grad_err),
    ]


def main(preset: str = "full"):
    jax.config.update("jax_enable_x64", True)
    n_paths = PRESET_PATHS[preset]
    rows = []
    for solver in ("heun", "reversible_heun"):
        s_ord, w_ord = empirical_orders(solver, n_paths)
        rows.append(("convergence", f"{solver}_strong_order", s_ord))
        rows.append(("convergence", f"{solver}_weak_order", w_ord))
        print(f"convergence,{solver},strong_order={s_ord:.2f},"
              f"weak_order={w_ord:.2f}", flush=True)
    rows += frontier(preset)
    rows += srk_frontier(preset)
    rows += replay_gates()
    jax.config.update("jax_enable_x64", False)
    return rows


if __name__ == "__main__":
    report.standalone("convergence", main)
