"""Elastic mesh re-planning after device loss.

At 1000+-node scale a failed host removes a block of devices.  The runtime
policy: keep the model-parallel degree (it matches the arch's divisibility
choices and the ICI domain), shrink the data axis to the largest value that
fits the surviving device count, and re-balance the global batch across the
new data degree.  Deterministic data (batch = f(key, step)) means the
restarted run replays identical samples regardless of the new topology.
"""

from __future__ import annotations

from typing import Tuple


def plan_mesh(num_devices: int, model_parallel: int = 16) -> Tuple[int, int]:
    """Largest (data, model) grid with data*model <= num_devices.

    Keeps ``model`` fixed while any multiple fits; degrades model-parallel
    only when fewer than ``model_parallel`` devices survive.
    """
    if num_devices < 1:
        raise ValueError("no surviving devices")
    model = min(model_parallel, num_devices)
    while model > 1 and num_devices // model == 0:
        model //= 2
    data = max(1, num_devices // model)
    return data, model


def rebatch(global_batch: int, data_degree: int) -> int:
    """Per-data-shard batch after an elastic resize (keeps global batch by
    raising per-shard batch; exact when divisible, padded otherwise)."""
    return -(-global_batch // data_degree)


def surviving_devices(total: int, failed_hosts: int, devices_per_host: int = 8) -> int:
    return total - failed_hosts * devices_per_host
