"""Paper Table 2 / Tables 7-10: Brownian Interval vs Virtual Brownian Tree.

Access-pattern benchmarks over subdivided [0, 1]: sequential (an SDE solve),
doubly sequential (solve + adjoint), and random access; several batch sizes.
Reports the fastest of ``reps`` runs (the paper's protocol: "errors in speed
benchmarks are one-sided").
"""

from __future__ import annotations

import time

import numpy as np

try:
    from . import report
except ImportError:  # run as a loose script: python benchmarks/brownian.py
    import report


def _intervals(n: int):
    ts = np.linspace(0.0, 1.0, n + 1)
    return list(zip(ts[:-1], ts[1:]))


def bench_access(maker, pattern: str, n_intervals: int, reps: int = 5):
    best = float("inf")
    for _ in range(reps):
        bi = maker()
        iv = _intervals(n_intervals)
        if pattern == "sequential":
            order = iv
        elif pattern == "doubly":
            order = iv + iv[::-1]
        else:  # random
            rng = np.random.default_rng(0)
            order = [iv[i] for i in rng.permutation(len(iv))]
        t0 = time.perf_counter()
        for s, t in order:
            bi(s, t)
        best = min(best, time.perf_counter() - t0)
    return best


def sde_solve_host(bi, n_steps: int, size: int):
    """Euler–Maruyama driven by a host Brownian source + backward sweep."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((size,)) * 0.1
    y = np.zeros(size)
    dt = 1.0 / n_steps
    for n in range(n_steps):
        dw = bi(n * dt, (n + 1) * dt)
        y = y + np.tanh(a * y) * dt + dw.reshape(-1)[:size] * 0.1
    for n in range(n_steps - 1, -1, -1):  # adjoint pass reuses the same noise
        dw = bi(n * dt, (n + 1) * dt)
        y = y - np.tanh(a * y) * dt - dw.reshape(-1)[:size] * 0.1
    return y


PRESET_SHAPES = {
    #          sizes, access reps, solve reps
    "tiny":  ([1, 2560], 2, 2),
    "quick": ([1, 2560], 3, 3),
    "full":  ([1, 2560, 32768], 5, 3),
}


def main(preset: str = "full"):
    from repro.core.brownian_interval import BrownianInterval, HostVirtualBrownianTree

    sizes, access_reps, solve_reps = PRESET_SHAPES[preset]
    n_intervals = 100
    rows = []
    for size in sizes:
        shape = (size,)
        for pattern in ("sequential", "doubly", "random"):
            t_bi = bench_access(
                lambda: BrownianInterval(0.0, 1.0, shape, seed=1,
                                         preplant_dt=1.0 / n_intervals),
                pattern, n_intervals, reps=access_reps)
            t_vbt = bench_access(
                lambda: HostVirtualBrownianTree(0.0, 1.0, shape, seed=1, eps=1e-5),
                pattern, n_intervals, reps=access_reps)
            rows.append(("brownian", f"{pattern},size={size}", t_vbt / t_bi))
            print(f"brownian,{pattern},size={size},interval={t_bi*1e3:.2f}ms,"
                  f"vbtree={t_vbt*1e3:.2f}ms,speedup={t_vbt/t_bi:.2f}x", flush=True)

    # SDE-solve benchmark (paper Table 10): Euler-Maruyama forward + adjoint
    # backward sweep driven by each Brownian source.
    for size in sizes:
        t_bi = float("inf")
        t_vbt = float("inf")
        for _ in range(solve_reps):
            bi = BrownianInterval(0.0, 1.0, (size,), seed=2,
                                  preplant_dt=1.0 / n_intervals)
            t0 = time.perf_counter()
            sde_solve_host(bi, n_intervals, size)
            t_bi = min(t_bi, time.perf_counter() - t0)
            vb = HostVirtualBrownianTree(0.0, 1.0, (size,), seed=2, eps=1e-5)
            t0 = time.perf_counter()
            sde_solve_host(vb, n_intervals, size)
            t_vbt = min(t_vbt, time.perf_counter() - t0)
        rows.append(("brownian", f"sde_solve,size={size}", t_vbt / t_bi))
        print(f"brownian,sde_solve,size={size},interval={t_bi*1e3:.2f}ms,"
              f"vbtree={t_vbt*1e3:.2f}ms,speedup={t_vbt/t_bi:.2f}x", flush=True)

    # cache effectiveness (the paper's O(1) amortised claim)
    bi = BrownianInterval(0.0, 1.0, (16,), seed=3, preplant_dt=0.01)
    for s, t in _intervals(100):
        bi(s, t)
    hits, misses = bi.cache_stats
    rate = hits / max(hits + misses, 1)
    rows.append(("brownian", "lru_hit_rate", rate))
    print(f"brownian,lru_hit_rate,{rate:.3f}", flush=True)
    return rows


if __name__ == "__main__":
    report.standalone("brownian", main)
