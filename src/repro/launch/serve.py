"""Neural-SDE trajectory-sampling service (DESIGN.md §9).

The inference driver for the paper's actual product: batched trajectory
sampling from a trained SDE-GAN generator or Latent-SDE decoder.  The loop

1. **restores a serving bundle** — the params-only checkpoint + workload/
   config handshake that launch/train.py writes under ``<ckpt>/serving/``
   (``repro.checkpoint.load_serving_meta``); a missing or mismatched bundle
   dies with a named error, never a pytree shape mismatch;
2. **AOT-compiles one sampler per batch bucket** (powers of two × device
   count up to ``--max-batch``, via ``launch.steps.make_sample_step``) —
   an off-size coalesced batch pads its key array up to the nearest bucket
   instead of recompiling, and padding cannot change real rows because
   every row is a pure function of its own PRNG key;
3. **shards each batch over the data-parallel mesh**
   (``distributed.sharding.data_parallel_mesh`` + the time-major layout;
   ``--host-devices N`` simulates N CPU devices);
4. **drives a request-coalescing queue**: pending requests are packed into
   full batches FIFO, each request's trajectories are keyed off its seed,
   and the loop reports trajectories/sec and p50/p99 request latency.

Sampling routes through ``repro.solve()`` — every registered solver ×
noise type is servable (``--solver``, ``--pallas``).  ``--stream-chunks K``
(SDE-GAN) solves the horizon in K time chunks through one compiled chunk
program (traced start time) and emits each chunk as it completes — long
horizons get first-chunk latency, not full-horizon.

The leftover transformer-LM decode loop from the seed scaffold lives
behind ``--workload lm`` and imports ``repro.models``/``repro.configs``
only there — SDE serving never touches the transformer stack.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --workload sde-gan \
        --host-devices 2 --smoke
    PYTHONPATH=src python -m repro.launch.serve --workload latent-sde \
        --ckpt-dir /tmp/ckpt --requests 64 --max-batch 32
"""

from __future__ import annotations

import argparse
import collections
import contextlib
import dataclasses
import tempfile
import time
from typing import Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt
from ..distributed.compat import set_mesh
from ..distributed.sharding import data_parallel_mesh
from .steps import SERVE_WORKLOADS, make_sample_step, make_stream_chunk_step

_PAD_SEED = 0x5EED_0DD  # keys for bucket-padding rows (rows are discarded)


# -----------------------------------------------------------------------------
# checkpoint handshake
# -----------------------------------------------------------------------------


def _build_cfg(workload: str, config: dict):
    """Rebuild the model config dataclass from the bundle's JSON dict."""
    from ..core.sde import LatentSDEConfig, NeuralSDEConfig

    cls = NeuralSDEConfig if workload == "sde-gan" else LatentSDEConfig
    d = dict(config)
    d["dtype"] = jnp.dtype(d.get("dtype", "float32"))
    try:
        return cls(**d)
    except TypeError as e:
        raise ValueError(
            f"serving bundle config does not match {cls.__name__} — written "
            f"by an incompatible code version ({e})") from e


def _init_params(workload: str, cfg, seed: int):
    """Parameter template (and fresh-init values) for a workload's bundle."""
    from ..core.sde import generator_init, latent_sde_init

    key = jax.random.PRNGKey(seed)
    if workload == "sde-gan":
        return generator_init(key, cfg)  # serving needs the generator only
    return latent_sde_init(key, cfg)


def _fresh_cfg(workload: str, args):
    """Smoke-mode config from the CLI flags (no checkpoint to read one from)."""
    from ..core.sde import LatentSDEConfig, NeuralSDEConfig

    num_steps = 16 if args.sde_steps is None else args.sde_steps
    exact = args.solver == "reversible_heun"
    if workload == "sde-gan":
        return NeuralSDEConfig(
            data_dim=1, hidden_dim=16, noise_dim=4, width=32,
            num_steps=num_steps, solver=args.solver, exact_adjoint=exact,
            use_pallas_kernels=args.pallas)
    return LatentSDEConfig(
        data_dim=2, hidden_dim=16, context_dim=16, width=32,
        num_steps=num_steps, solver=args.solver, exact_adjoint=exact,
        use_pallas_kernels=args.pallas)


def restore_for_serving(workload: str, ckpt_dir: str):
    """Handshake + restore: ``(params, cfg, step)`` from a serving bundle."""
    meta, step = ckpt.load_serving_meta(ckpt_dir)
    if meta.get("workload") != workload:
        raise ValueError(
            f"serving bundle under {ckpt_dir} was trained for workload "
            f"{meta.get('workload')!r}, not {workload!r} — point --ckpt-dir "
            f"at a matching run or change --workload")
    cfg = _build_cfg(workload, meta.get("config", {}))
    params, step = ckpt.restore_serving_bundle(
        ckpt_dir, _init_params(workload, cfg, 0))
    return params, cfg, step


# -----------------------------------------------------------------------------
# request queue
# -----------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One client ask: ``size`` trajectories keyed off ``seed``.

    ``rtol`` is only consumed by the adaptive terminal-sampling mode
    (``--adaptive``): the accuracy the client requests for its samples.
    """

    rid: int
    size: int
    seed: int
    rtol: float = 1e-3


#: Tolerances the synthetic adaptive request stream cycles through — all
#: served by the SAME compiled program per bucket (rtol is traced).
_SYNTH_RTOLS = (1e-2, 3e-3, 1e-3, 3e-4)


def synthetic_requests(n: int, max_size: int, seed: int,
                       adaptive: bool = False):
    """Deterministic request stream (sizes cycle 1..max_size, seeds unique;
    with ``adaptive`` the per-request tolerance cycles :data:`_SYNTH_RTOLS`)."""
    return collections.deque(
        Request(rid=i, size=1 + (i * 7 + seed) % max_size,
                seed=seed * 100_003 + i,
                rtol=_SYNTH_RTOLS[i % len(_SYNTH_RTOLS)] if adaptive else 1e-3)
        for i in range(n))


def serve_buckets(max_batch: int, shard_base: int):
    """Bucket sizes: shard_base × powers of two, up to ``max_batch``.

    ``shard_base`` is the device count when a mesh is active (every bucket
    must divide exactly for the data-parallel in_sharding), else 1.  The
    largest bucket caps how many rows one coalesced batch may hold.
    """
    sizes = []
    b = max(shard_base, 1)
    while b <= max_batch:
        sizes.append(b)
        b *= 2
    if not sizes:
        raise ValueError(
            f"--max-batch {max_batch} is below the shard base {shard_base}; "
            f"the smallest servable bucket is one row per device")
    return sizes


def _request_keys(requests, pad_to: int):
    """Key array for a coalesced batch: per-request seeds fanned out per
    row, padded to the bucket size with throwaway keys."""
    parts = [
        jax.vmap(lambda j, s=r.seed: jax.random.fold_in(
            jax.random.PRNGKey(s), j))(jnp.arange(r.size))
        for r in requests
    ]
    used = sum(r.size for r in requests)
    if pad_to > used:
        parts.append(jax.vmap(lambda j: jax.random.fold_in(
            jax.random.PRNGKey(_PAD_SEED), j))(jnp.arange(pad_to - used)))
    return jnp.concatenate(parts, axis=0)


def _percentile(xs, q: float) -> float:
    xs = sorted(xs)
    idx = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
    return xs[idx]


# -----------------------------------------------------------------------------
# the service loop
# -----------------------------------------------------------------------------


def serve_sde(workload: str, ckpt_dir: Optional[str], smoke: bool,
              max_batch: int, requests: int, request_max: int,
              latent_mode: str = "prior", obs_len: int = 9,
              stream_chunks: int = 0, adaptive: bool = False,
              atol: float = 1e-6, seed: int = 0, args=None) -> dict:
    """Run the trajectory-sampling service; returns the stats dict it prints.

    With ``--smoke`` and no ``--ckpt-dir``, a fresh-initialised model is
    saved to (and restored from) a throwaway serving bundle — the same
    restore path a trained checkpoint takes, exercised end to end.
    """
    if workload not in SERVE_WORKLOADS:
        raise ValueError(f"serve_sde serves {SERVE_WORKLOADS}, got {workload!r}")
    if adaptive and workload != "sde-gan":
        raise ValueError(
            "--adaptive serves terminal samples from the SDE-GAN generator; "
            "the latent-sde decoders serve whole trajectories, which have no "
            "fixed output grid under adaptive stepping")
    if adaptive and stream_chunks > 1:
        raise ValueError(
            "--adaptive and --stream-chunks are mutually exclusive: "
            "streaming emits a fixed per-chunk grid, adaptive solving "
            "chooses its own")
    if requests < 1 or request_max < 1:
        raise ValueError(
            f"--requests ({requests}) and --request-max ({request_max}) "
            f"must both be >= 1 — an empty queue has no latency to report")
    if ckpt_dir is None:
        if not smoke:
            raise ValueError("--ckpt-dir is required without --smoke (a "
                             "production service has a trained model)")
        ckpt_dir = tempfile.mkdtemp(prefix="repro-serve-smoke-")
        cfg = _fresh_cfg(workload, args)
        ckpt.save_serving_bundle(ckpt_dir, 0, _init_params(workload, cfg, seed),
                                 workload, cfg)
        print(f"[serve] --smoke: fresh {workload} bundle at {ckpt_dir}",
              flush=True)
    params, cfg, step = restore_for_serving(workload, ckpt_dir)
    print(f"[serve] restored {workload} serving bundle (train step {step}, "
          f"solver={cfg.solver}, num_steps={cfg.num_steps})", flush=True)

    n_dev = len(jax.devices())
    mesh = data_parallel_mesh()
    if mesh is not None and max_batch < n_dev:
        # a bucket must hold >= one row per device to shard; a tiny
        # --max-batch on a big host serves unsharded instead of dying
        print(f"[serve] --max-batch {max_batch} < {n_dev} devices — "
              f"serving unsharded", flush=True)
        mesh = None
    buckets = serve_buckets(max_batch, n_dev if mesh is not None else 1)
    request_max = min(request_max, buckets[-1])
    mesh_ctx = set_mesh(mesh) if mesh is not None else contextlib.nullcontext()

    stats: dict = {"workload": workload, "restored_step": step,
                   "buckets": buckets, "devices": n_dev}
    with mesh_ctx:
        if mesh is not None:
            print(f"[serve] data-parallel over {n_dev} devices", flush=True)
        if adaptive:
            _adaptive_terminal_loop(cfg, params, buckets, requests,
                                    request_max, atol, seed, stats)
        elif stream_chunks > 1:
            _stream_loop(workload, cfg, params, buckets, requests,
                         request_max, stream_chunks, seed, stats)
        else:
            _batch_loop(workload, cfg, params, buckets, requests, request_max,
                        latent_mode, obs_len, seed, stats)
    return stats


def _compile_pool(sampler, params, buckets, *example_args, tag: str = ""):
    """AOT-compile the sampler once per bucket shape.

    ``example_args``: extra example operands after ``(params, keys)`` —
    e.g. the adaptive loop's traced-rtol scalar (shape, not value, is what
    the compile caches on).
    """
    jitted = jax.jit(sampler)
    pool = {}
    for b in buckets:
        keys = jax.random.split(jax.random.PRNGKey(0), b)
        t0 = time.perf_counter()
        pool[b] = jitted.lower(params, keys, *example_args).compile()
        print(f"[serve] compiled {tag}bucket {b} in "
              f"{time.perf_counter() - t0:.2f}s", flush=True)
    return pool


def _coalesce(pending, cap: int):
    """Pop pending requests FIFO until the next one would overflow ``cap``."""
    batch, rows = [], 0
    while pending and rows + pending[0].size <= cap:
        r = pending.popleft()
        batch.append(r)
        rows += r.size
    return batch, rows


def _report(tag: str, stats: dict, total_rows: int, n_batches: int,
            latencies, wall: float) -> None:
    tps = total_rows / max(wall, 1e-9)
    p50, p99 = _percentile(latencies, 0.50), _percentile(latencies, 0.99)
    stats.update(trajectories=total_rows, batches=n_batches,
                 traj_per_s=tps, p50_s=p50, p99_s=p99)
    print(f"[serve] {tag}: {total_rows} trajectories in {n_batches} "
          f"batches @ {tps:.1f} traj/s", flush=True)
    print(f"[serve] latency p50 {p50 * 1e3:.1f}ms p99 {p99 * 1e3:.1f}ms "
          f"(n={len(latencies)} requests, closed-loop)", flush=True)


def _batch_loop(workload, cfg, params, buckets, requests, request_max,
                latent_mode, obs_len, seed, stats):
    sampler = make_sample_step(workload, cfg, latent_mode=latent_mode,
                               obs_len=obs_len)
    pool = _compile_pool(sampler, params, buckets)

    pending = synthetic_requests(requests, request_max, seed)
    latencies, total_rows, n_batches = [], 0, 0
    t_start = time.perf_counter()
    while pending:
        batch, rows = _coalesce(pending, buckets[-1])
        bucket = next(b for b in buckets if b >= rows)
        keys = _request_keys(batch, bucket)
        ys = pool[bucket](params, keys)
        jax.block_until_ready(ys)
        t_now = time.perf_counter()
        latencies += [t_now - t_start] * len(batch)  # closed-loop: all at t0
        total_rows += rows
        n_batches += 1
    wall = time.perf_counter() - t_start
    _report(f"{workload}" + (f"/{latent_mode}" if workload == "latent-sde"
                             else ""),
            stats, total_rows, n_batches, latencies, wall)


def _adaptive_terminal_loop(cfg, params, buckets, requests, request_max,
                            atol, seed, stats):
    """Per-request-tolerance terminal sampling (DESIGN.md §10).

    One compiled program per bucket serves EVERY tolerance — ``rtol`` is a
    traced scalar argument of the sampler, so tolerance never enters the
    AOT cache key.  A coalesced batch runs at the tightest tolerance of its
    requests (over-delivering for the looser ones, never the reverse).
    """
    from .steps import make_adaptive_terminal_step

    pool = _compile_pool(make_adaptive_terminal_step(cfg, atol=atol), params,
                         buckets, jnp.asarray(1e-3, cfg.dtype),
                         tag="adaptive ")

    pending = synthetic_requests(requests, request_max, seed, adaptive=True)
    latencies, total_rows, n_batches, non_converged = [], 0, 0, 0
    rtols_served = set()
    t_start = time.perf_counter()
    while pending:
        batch, rows = _coalesce(pending, buckets[-1])
        bucket = next(b for b in buckets if b >= rows)
        keys = _request_keys(batch, bucket)
        batch_rtol = min(r.rtol for r in batch)  # tightest ask wins
        rtols_served.update(r.rtol for r in batch)
        ys, conv = pool[bucket](params, keys,
                                jnp.asarray(batch_rtol, cfg.dtype))
        jax.block_until_ready(ys)
        # padding rows don't count; a real non-converged row is a sample at
        # t_final < t1, not Y_T — report it, never ship it silently
        non_converged += int(jnp.sum(~conv[:rows]))
        t_now = time.perf_counter()
        latencies += [t_now - t_start] * len(batch)
        total_rows += rows
        n_batches += 1
    wall = time.perf_counter() - t_start
    _report("sde-gan/adaptive", stats, total_rows, n_batches, latencies, wall)
    stats["rtols_served"] = sorted(rtols_served)
    stats["compiled_programs"] = len(pool)
    stats["non_converged"] = non_converged
    print(f"[serve] adaptive: {len(rtols_served)} distinct tolerances "
          f"served by {len(pool)} compiled program(s) "
          f"(rtol is traced — no recompiles)", flush=True)
    if non_converged:
        print(f"[serve] WARNING: {non_converged}/{total_rows} rows exhausted "
              f"the adaptive step budget before t1 (served state is at "
              f"t_final < t1) — raise max_steps or loosen the tolerance",
              flush=True)


def _stream_loop(workload, cfg, params, buckets, requests, request_max,
                 stream_chunks, seed, stats):
    """Long-horizon streaming: emit the trajectory in time chunks."""
    from ..core.sde import generator_initial_state

    if workload != "sde-gan":
        raise ValueError("--stream-chunks streams the SDE-GAN generator "
                         "rollout; the latent decoder serves whole "
                         "trajectories")
    if cfg.num_steps % stream_chunks != 0:
        raise ValueError(
            f"--stream-chunks ({stream_chunks}) must divide the solver "
            f"horizon num_steps ({cfg.num_steps}) so chunks share a grid")
    span = cfg.t1 / stream_chunks
    steps_per_chunk = cfg.num_steps // stream_chunks
    jit_chunk = jax.jit(make_stream_chunk_step(cfg, span, steps_per_chunk))
    jit_init = jax.jit(lambda p, keys: generator_initial_state(p, cfg, keys))
    # AOT-compile both programs per bucket BEFORE the clock starts — the
    # t_start scalar is traced, so one chunk program covers every chunk
    init_pool, chunk_pool = {}, {}
    for b in buckets:
        keys = jax.random.split(jax.random.PRNGKey(0), b)
        t0 = time.perf_counter()
        init_pool[b] = jit_init.lower(params, keys).compile()
        x0 = init_pool[b](params, keys)
        chunk_pool[b] = jit_chunk.lower(
            params, keys, x0, jnp.asarray(0.0, cfg.dtype)).compile()
        print(f"[serve] compiled stream bucket {b} in "
              f"{time.perf_counter() - t0:.2f}s", flush=True)

    pending = synthetic_requests(requests, request_max, seed)
    latencies, first_chunk_ms, total_rows, n_batches = [], [], 0, 0
    t_start = time.perf_counter()
    while pending:
        batch, rows = _coalesce(pending, buckets[-1])
        bucket = next(b for b in buckets if b >= rows)
        keys = _request_keys(batch, bucket)
        x = init_pool[bucket](params, keys)
        t_batch0 = time.perf_counter()
        for c in range(stream_chunks):
            ckeys = jax.vmap(
                lambda k, c=c: jax.random.fold_in(k, 1000 + c))(keys)
            ys_c, x = chunk_pool[bucket](params, ckeys, x,
                                         jnp.asarray(c * span, cfg.dtype))
            jax.block_until_ready(ys_c)  # "emitted" to the client here
            if c == 0:
                first_chunk_ms.append((time.perf_counter() - t_batch0) * 1e3)
        t_now = time.perf_counter()
        latencies += [t_now - t_start] * len(batch)
        total_rows += rows
        n_batches += 1
    wall = time.perf_counter() - t_start
    _report(f"sde-gan/stream×{stream_chunks}", stats, total_rows, n_batches,
            latencies, wall)
    stats["first_chunk_ms"] = sum(first_chunk_ms) / len(first_chunk_ms)
    print(f"[serve] stream: mean first-chunk latency "
          f"{stats['first_chunk_ms']:.1f}ms "
          f"({steps_per_chunk}/{cfg.num_steps} steps per chunk)", flush=True)


# -----------------------------------------------------------------------------
# the quarantined transformer-LM decode loop (seed scaffold)
# -----------------------------------------------------------------------------


def serve_lm(arch: str, batch: int, prompt_len: int, gen: int,
             smoke: bool = True, seed: int = 0):
    """Prefill + greedy-decode smoke loop for the transformer zoo.

    Kept behind ``--workload lm``: this is the only place serve.py touches
    ``repro.models``/``repro.configs`` — the SDE workloads never import the
    transformer stack.
    """
    from ..configs import get_config, smoke_config
    from ..models import transformer as T
    from .steps import greedy_sample, make_prefill_step, make_serve_step

    cfg = smoke_config(arch) if smoke else get_config(arch)
    if cfg.family == "encdec":
        raise SystemExit("use --arch with a decoder-only config for serve.py")

    key = jax.random.PRNGKey(seed)
    params = T.init_lm(key, cfg)
    max_len = prompt_len + gen + (cfg.frontend_len if cfg.frontend else 0)

    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab)
    batch_in = {"tokens": prompts}
    pos0 = prompt_len
    if cfg.frontend:
        batch_in["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.frontend_len, cfg.d_model), cfg.dtype)
        pos0 += cfg.frontend_len

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, caches = prefill(params, batch_in)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    token = greedy_sample(logits)
    out_tokens = [token]
    t0 = time.time()
    for i in range(gen - 1):
        logits, caches = decode(params, caches, token,
                                jnp.asarray(pos0 + i, jnp.int32))
        token = greedy_sample(logits)
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0

    gen_tokens = jnp.concatenate(out_tokens, axis=1)
    tps = batch * (gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] {arch}: batch={batch} prefill({prompt_len} tok) "
          f"{t_prefill * 1e3:.1f}ms; decode {gen - 1} steps @ {tps:.1f} tok/s")
    print(f"[serve] sample generation (row 0): {gen_tokens[0].tolist()}")
    return gen_tokens


# -----------------------------------------------------------------------------
# CLI
# -----------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=SERVE_WORKLOADS + ("lm",),
                    default="sde-gan")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir written by launch/train.py (the "
                         "serving bundle lives under <ckpt-dir>/serving/); "
                         "omit with --smoke for a fresh-init service")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="simulate N CPU devices (must be processed before "
                         "the XLA backend initialises)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="largest serving bucket (rows per compiled batch)")
    ap.add_argument("--requests", type=int, default=12,
                    help="synthetic requests to drain through the queue")
    ap.add_argument("--request-max", type=int, default=4,
                    help="largest per-request trajectory count")
    ap.add_argument("--latent-mode", choices=("prior", "posterior"),
                    default="prior",
                    help="latent-sde: decode from the prior, or encode "
                         "observations and decode the posterior")
    ap.add_argument("--obs-len", type=int, default=9,
                    help="latent-sde posterior: observation points per "
                         "request (num_steps must be a multiple of "
                         "obs_len - 1)")
    ap.add_argument("--stream-chunks", type=int, default=0,
                    help="sde-gan: stream the horizon in K time chunks "
                         "(0/1 = whole trajectories)")
    ap.add_argument("--adaptive", action="store_true",
                    help="sde-gan: serve adaptive terminal samples at each "
                         "request's tolerance (rtol is traced — one "
                         "compiled program per bucket serves every rtol)")
    ap.add_argument("--atol", type=float, default=1e-6,
                    help="adaptive serving: absolute tolerance floor")
    ap.add_argument("--solver", default="reversible_heun",
                    help="fresh-init (--smoke) solver; restored bundles "
                         "carry their own")
    ap.add_argument("--pallas", action="store_true",
                    help="fresh-init: request the fused hot loop (diagonal-"
                         "noise latent decode fuses; sde-gan warns + runs "
                         "unfused)")
    ap.add_argument("--sde-steps", type=int, default=None,
                    help="fresh-init solver steps (default 16)")
    ap.add_argument("--seed", type=int, default=0)
    # --workload lm (quarantined transformer decode loop)
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    if args.host_devices is not None:
        from ..distributed.compat import force_host_device_count

        force_host_device_count(args.host_devices)
    if args.workload == "lm":
        return serve_lm(args.arch, args.batch, args.prompt_len, args.gen,
                        args.smoke, args.seed)
    return serve_sde(args.workload, args.ckpt_dir, args.smoke,
                     args.max_batch, args.requests, args.request_max,
                     latent_mode=args.latent_mode, obs_len=args.obs_len,
                     stream_chunks=args.stream_chunks,
                     adaptive=args.adaptive, atol=args.atol,
                     seed=args.seed, args=args)


if __name__ == "__main__":
    main()
