"""Deterministic, seeded synthetic datasets mirroring the paper's benchmarks.

The paper's three datasets (Appendix F) are: SGD weight trajectories, Beijing
air-quality (PM2.5 + O₃, 24 hourly steps, 12 location labels), and a
time-dependent Ornstein–Uhlenbeck process.  The container is offline, so we
generate distribution-matched stand-ins with the *same* shapes, lengths,
normalisation, and qualitative structure (F.7's OU process is exactly
reproducible since it is itself synthetic).

All generators are pure functions of a PRNG key → suitable for deterministic
resume (fault-tolerance requirement) and per-host sharding by folding in the
host id.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ou_process(key, batch: int, length: int = 32, rho: float = 0.02, kappa: float = 0.1,
               chi: float = 0.4, dtype=jnp.float32):
    """Paper F.7: dY = (ρt − κY) dt + χ dW on t ∈ [0, length-1]. Returns
    (length, batch, 1), normalised per the paper (initial value stats)."""
    dt = 1.0
    ts = jnp.arange(length, dtype=dtype)

    def body(y, inp):
        t, eps = inp
        y1 = y + (rho * t - kappa * y) * dt + chi * jnp.sqrt(dt) * eps
        return y1, y1

    k0, key = jax.random.split(key)
    y0 = jax.random.normal(k0, (batch, 1), dtype)  # stationary-ish start
    eps = jax.random.normal(key, (length - 1, batch, 1), dtype)
    _, ys = jax.lax.scan(body, y0, (ts[:-1], eps))
    out = jnp.concatenate([y0[None], ys], 0)
    return _normalise_initial(out)


def sgd_weights_like(key, batch: int, length: int = 50, dtype=jnp.float32):
    """Weight-trajectory stand-in: exponential decay toward a random optimum
    with heteroscedastic SGD noise (univariate, length 50 as in F.3)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w_star = jax.random.normal(k1, (batch, 1), dtype)
    w0 = w_star + jax.random.normal(k2, (batch, 1), dtype) * 2.0
    rate = jax.random.uniform(k3, (batch, 1), dtype, 0.05, 0.2)
    eps = jax.random.normal(k4, (length - 1, batch, 1), dtype)

    def body(w, e):
        w1 = w + rate * (w_star - w) + 0.05 * e * jnp.abs(w - w_star)
        return w1, w1

    _, ws = jax.lax.scan(body, w0, eps)
    return _normalise_initial(jnp.concatenate([w0[None], ws], 0))


def air_quality_like(key, batch: int, length: int = 24, num_labels: int = 12,
                     dtype=jnp.float32):
    """Bivariate (PM2.5-like, O₃-like) daily profiles with a class label.
    O₃ channel has the paper's "peak in the latter half" non-autonomy.
    Returns (ys (length, batch, 2), labels (batch,))."""
    kl, kp, ko, kn = jax.random.split(key, 4)
    labels = jax.random.randint(kl, (batch,), 0, num_labels)
    ts = jnp.linspace(0.0, 1.0, length, dtype=dtype)[:, None, None]
    base = (labels.astype(dtype) / num_labels)[None, :, None]
    pm = base + 0.3 * jnp.sin(2 * jnp.pi * (ts + 0.2 * base)) \
        + 0.15 * jax.random.normal(kp, (length, batch, 1), dtype)
    peak_t = 0.55 + 0.25 * base
    o3 = 0.8 * jnp.exp(-((ts - peak_t) ** 2) / 0.02) + base * 0.2 \
        + 0.1 * jax.random.normal(ko, (length, batch, 1), dtype)
    ys = jnp.concatenate([pm, o3], -1)
    return _normalise_initial(ys), labels


def token_batches(key, step: jax.Array, batch: int, seq_len: int, vocab: int):
    """Deterministic LM token pipeline: batch for global step ``step`` is a
    pure function of (key, step) — restart/elastic replays identical data.
    Structured (Zipf-ish + local repetition) so the loss is learnable."""
    k = jax.random.fold_in(key, step)
    k1, k2 = jax.random.split(k)
    # Zipf-like marginal via exponential transform of uniforms
    u = jax.random.uniform(k1, (batch, seq_len + 1), minval=1e-6)
    ranks = jnp.floor(jnp.float32(vocab) ** u)
    toks = jnp.clip(ranks.astype(jnp.int32) - 1, 0, vocab - 1)
    # local repetition: with p=0.3 copy the previous token
    rep = jax.random.bernoulli(k2, 0.3, (batch, seq_len + 1))
    toks = jnp.where(rep, jnp.roll(toks, 1, axis=1), toks)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def _normalise_initial(ys):
    """Paper Appendix F normalisation: zero-mean/unit-variance *initial value*."""
    m = jnp.mean(ys[0])
    s = jnp.std(ys[0]) + 1e-6
    return (ys - m) / s
