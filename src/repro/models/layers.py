"""Sequence-mixing and FFN layers for the architecture zoo.

Each layer is ``(init(key, cfg) -> params, apply(params, cfg, x, ...) -> y)``
plus a decode form operating on an explicit cache pytree.  Naming follows
the sharding rules in :mod:`repro.distributed.sharding`.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .. import nn
from ..configs.base import ArchConfig
from ..distributed.sharding import hint, tp_size
from ..kernels import ops

# =============================================================================
# RoPE
# =============================================================================


def rope_freqs(head_dim: int, theta: float, positions: jax.Array, dtype=jnp.float32):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, hd/2)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (S, hd/2) or broadcastable."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


# =============================================================================
# Blockwise (flash-style) attention in pure jnp — the XLA/dry-run path.
#
# Two variants with identical math:
#   * "scan"     — lax.map over q blocks, lax.scan over kv blocks.  O(1) HLO
#                  size; used for the full-config compile (memory proof).
#   * "unrolled" — python loops; every block matmul appears in the HLO, so
#                  ``cost_analysis()`` reports exact attention FLOPs.  Used by
#                  the roofline costing lowers (1-/2-layer extrapolation).
# On TPU backends ``ops.flash_attention`` (the Pallas kernel) is selected
# instead.  All paths avoid the O(S²) score materialisation.
# =============================================================================


def _online_update(m, l, acc, s, vblk):
    """One online-softmax accumulation step.
    s: (B, Hkv, G, bq, bk) f32; vblk: (B, Hkv, bk, D)."""
    m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m - m_new)
    l_new = alpha * l + jnp.sum(p, -1, keepdims=True)
    acc_new = alpha * acc + jnp.einsum(
        "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32))
    return m_new, l_new, acc_new


def blockwise_attention(q, k, v, causal: bool = True, scale: Optional[float] = None,
                        bq: int = 1024, bk: int = 1024, impl: str = "scan"):
    """GQA attention without materialising (S, S).  q: (B, Hq, S, D);
    k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0."""
    B, Hq, S, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq = min(bq, S)
    bk = min(bk, Sk)
    while S % bq:
        bq //= 2
    while Sk % bk:
        bk //= 2
    nq, nk = S // bq, Sk // bk
    qg = q.reshape(B, Hkv, G, nq, bq, D)
    kb = k.reshape(B, Hkv, nk, bk, D)
    vb = v.reshape(B, Hkv, nk, bk, D)

    def scores(qblk, kblk, iq, ik):
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, -1e30)
        return s

    def init_carry():
        return (jnp.full((B, Hkv, G, bq, 1), -1e30, jnp.float32),
                jnp.zeros((B, Hkv, G, bq, 1), jnp.float32),
                jnp.zeros((B, Hkv, G, bq, D), jnp.float32))

    if impl == "unrolled":
        outs = []
        for iq in range(nq):
            m, l, acc = init_carry()
            for ik in range(nk):
                if causal and ik * bk > iq * bq + bq - 1:
                    continue  # fully masked block — skip its compute
                s = scores(qg[:, :, :, iq], kb[:, :, ik], iq, ik)
                m, l, acc = _online_update(m, l, acc, s, vb[:, :, ik])
            outs.append(acc / jnp.maximum(l, 1e-30))
        out = jnp.stack(outs, axis=3)  # (B, Hkv, G, nq, bq, D)
    else:
        kb_t = jnp.moveaxis(kb, 2, 0)  # (nk, B, Hkv, bk, D)
        vb_t = jnp.moveaxis(vb, 2, 0)

        def per_q(args):
            iq, qblk = args

            def inner(carry, inp):
                ik, kblk, vblk = inp
                s = scores(qblk, kblk, iq, ik)
                return _online_update(*carry, s, vblk), None

            (m, l, acc), _ = jax.lax.scan(
                inner, init_carry(), (jnp.arange(nk), kb_t, vb_t))
            return acc / jnp.maximum(l, 1e-30)

        qb_t = jnp.moveaxis(qg, 3, 0)  # (nq, B, Hkv, G, bq, D)
        out = jax.lax.map(per_q, (jnp.arange(nq), qb_t))
        out = jnp.moveaxis(out, 0, 3)  # (B, Hkv, G, nq, bq, D)

    return out.reshape(B, Hkv, G, S, D).reshape(B, Hq, S, D).astype(q.dtype)


def _attend_dispatch(cfg: ArchConfig, q, k, v, causal: bool):
    """Pick the attention implementation: Pallas kernel on TPU, blockwise
    jnp (scan or unrolled per cfg.attn_impl) elsewhere."""
    if jax.default_backend() == "tpu":
        return ops.flash_attention(q, k, v, causal=causal)
    return blockwise_attention(q, k, v, causal=causal,
                               bq=cfg.attn_block_q, bk=cfg.attn_block_k,
                               impl=cfg.attn_impl)


# =============================================================================
# GQA attention
# =============================================================================


def gqa_init(key, cfg: ArchConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd), cfg.dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), cfg.dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), cfg.dtype) * s,
        "wo": jax.random.normal(ks[3], (hq * hd, d), cfg.dtype) * s / math.sqrt(2 * cfg.num_layers),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.dtype)
    return p


def _project_qkv(p, cfg, x, positions, kv_source=None, use_rope: bool = True):
    B, S, _ = x.shape
    src = x if kv_source is None else kv_source
    Sk = src.shape[1]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = src @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = src @ p["wv"] + (p["bv"] if "bv" in p else 0)
    q = q.reshape(B, S, hq, hd)
    k = k.reshape(B, Sk, hkv, hd)
    v = v.reshape(B, Sk, hkv, hd)
    if not use_rope:
        return q, k, v
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions, x.dtype)
    if kv_source is None:
        return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v
    kcos, ksin = rope_freqs(hd, cfg.rope_theta, jnp.arange(Sk), x.dtype)
    return apply_rope(q, cos, sin), apply_rope(k, kcos, ksin), v


def gqa_attend(p, cfg: ArchConfig, x, causal: bool = True, kv_source=None):
    """Full-sequence attention (train/prefill).  x: (B, S, D).

    ``kv_source`` (B, Sk, D) switches to cross-attention (enc-dec decoder).
    Returns (out, (k, v)) — the kv pair feeds prefill cache construction.
    """
    B, S, _ = x.shape
    src = x if kv_source is None else kv_source
    q, k, v = _project_qkv(p, cfg, x, jnp.arange(S), kv_source=src,
                           use_rope=kv_source is None)
    q = jnp.swapaxes(q, 1, 2)  # (B, H, S, hd)
    k = jnp.swapaxes(k, 1, 2)
    v = jnp.swapaxes(v, 1, 2)
    kv_ret = (jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))
    # TP head-sharding: when the kv-head count doesn't divide the model axis
    # (GQA with few kv heads), GSPMD falls into mixed factorizations that
    # all-gather score tensors (§Perf iteration 1, EXPERIMENTS.md).  Repeat
    # K/V to the full query heads first — a small gather — so all three
    # tensors shard cleanly over heads.
    t = tp_size()
    if (cfg.attn_mha_tp and t > 1 and cfg.num_kv_heads % t != 0
            and cfg.num_heads % cfg.num_kv_heads == 0):
        g = cfg.num_heads // cfg.num_kv_heads
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    q = hint(q, "dp", "tp", None, None)
    k = hint(k, "dp", "tp", None, None)
    v = hint(v, "dp", "tp", None, None)
    o = _attend_dispatch(cfg, q, k, v, causal)
    o = jnp.swapaxes(o, 1, 2).reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = hint(o @ p["wo"], "dp", None, None)
    # name the post-all-reduce activation so remat_policy="collectives" can
    # pin it (backward then skips re-running the TP all-reduce; §Perf C2)
    out = checkpoint_name(out, "post_ar")
    return out, kv_ret


def gqa_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((batch, max_len, hkv, hd), dtype),
    }


def gqa_decode(p, cfg: ArchConfig, x, cache, pos):
    """Single-token decode.  x: (B, 1, D); cache k/v: (B, Smax, Hkv, hd);
    ``pos``: scalar current position (same for the whole batch)."""
    B = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k_new, v_new = _project_qkv(p, cfg, x, pos[None] if jnp.ndim(pos) == 0 else pos)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    S = k.shape[1]
    group = hq // hkv
    qg = q.reshape(B, hkv, group, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    mask = (jnp.arange(S) <= pos)[None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    o = o.reshape(B, 1, hq * hd).astype(x.dtype)
    return o @ p["wo"], {"k": k, "v": v}


def gqa_cross_decode(p, cfg: ArchConfig, x, k, v):
    """Cross-attention decode: q from one new token, (k, v) precomputed from
    the encoder output (no rope, no causal mask).  x: (B, 1, D)."""
    B = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, _, _ = _project_qkv(p, cfg, x, jnp.zeros((1,), jnp.int32),
                           kv_source=x, use_rope=False)
    group = hq // hkv
    qg = q.reshape(B, hkv, group, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    o = o.reshape(B, 1, hq * hd).astype(x.dtype)
    return o @ p["wo"]


# =============================================================================
# MLA (multi-head latent attention, MiniCPM3/DeepSeek style)
# =============================================================================


def mla_init(key, cfg: ArchConfig):
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "wq_a": jax.random.normal(ks[0], (d, rq), cfg.dtype) * s,
        "q_ln": nn.rmsnorm_init(rq, cfg.dtype),
        "wq_b": jax.random.normal(ks[1], (rq, h * (dn + dr)), cfg.dtype) / math.sqrt(rq),
        "wkv_a": jax.random.normal(ks[2], (d, rkv + dr), cfg.dtype) * s,
        "kv_ln": nn.rmsnorm_init(rkv, cfg.dtype),
        "wkv_b": jax.random.normal(ks[3], (rkv, h * (dn + dv)), cfg.dtype) / math.sqrt(rkv),
        "wo": jax.random.normal(ks[4], (h * dv, d), cfg.dtype) * s / math.sqrt(2 * cfg.num_layers),
    }


def _mla_qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q = nn.rmsnorm(p["q_ln"], x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(B, S, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    kv = x @ p["wkv_a"]
    ckv, k_pe = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    ckv = nn.rmsnorm(p["kv_ln"], ckv)
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions, x.dtype)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe[..., None, :], cos, sin)[..., 0, :]  # shared across heads
    return q_nope, q_pe, ckv, k_pe


def mla_attend(p, cfg: ArchConfig, x, causal: bool = True):
    """MLA train/prefill.  Folds the (nope ‖ rope) score split into a single
    concatenated head dim so the blockwise kernel applies unchanged."""
    B, S, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_pe, ckv, k_pe = _mla_qkv(p, cfg, x, jnp.arange(S))
    kv = (ckv @ p["wkv_b"]).reshape(B, S, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q_cat = jnp.concatenate([q_nope, q_pe], -1)                     # (B,S,h,dn+dr)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, S, h, dr))], -1)
    # pad v to the q head dim so shapes line up, slice after
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, (dn + dr) - dv)))
    q_cat = hint(jnp.swapaxes(q_cat, 1, 2), "dp", "tp", None, None)
    k_cat = hint(jnp.swapaxes(k_cat, 1, 2), "dp", "tp", None, None)
    v_pad = hint(jnp.swapaxes(v_pad, 1, 2), "dp", "tp", None, None)
    o = blockwise_attention(q_cat, k_cat, v_pad, causal=causal,
                            scale=1.0 / math.sqrt(dn + dr),
                            bq=cfg.attn_block_q, bk=cfg.attn_block_k,
                            impl=cfg.attn_impl)
    o = jnp.swapaxes(o, 1, 2)[..., :dv]                              # (B,S,h,dv)
    out = hint(o.reshape(B, S, h * dv) @ p["wo"], "dp", None, None)
    return out, (ckv, k_pe)                                          # latent cache


def mla_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(p, cfg: ArchConfig, x, cache, pos):
    """Latent-cache decode (the MLA memory win): scores via the absorbed
    q·W_kvbᵀ form so only (ckv, k_pe) are cached."""
    B = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_pe, ckv_new, kpe_new = _mla_qkv(p, cfg, x, pos[None] if jnp.ndim(pos) == 0 else pos)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    kpe = jax.lax.dynamic_update_slice(cache["kpe"], kpe_new.astype(cache["kpe"].dtype), (0, pos, 0))
    wkv_b = p["wkv_b"].reshape(r, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]          # (r, h, dn), (r, h, dv)
    # absorb: q̃ = q_nope · W_ukᵀ  -> (B, 1, h, r)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv.astype(jnp.float32))
        + jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32), kpe.astype(jnp.float32))
    ) / math.sqrt(dn + dr)
    S = ckv.shape[1]
    mask = (jnp.arange(S) <= pos)[None, None, None, :]
    w = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", w, ckv.astype(jnp.float32))      # (B,1,h,r)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
    return o.reshape(B, 1, h * dv) @ p["wo"], {"ckv": ckv, "kpe": kpe}


# =============================================================================
# FFN: SwiGLU / GELU + MoE
# =============================================================================


def ffn_init(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    p = {"up": jax.random.normal(ks[0], (d, f), cfg.dtype) * s,
         "down": jax.random.normal(ks[1], (f, d), cfg.dtype) / math.sqrt(f) / math.sqrt(2 * cfg.num_layers)}
    if cfg.ffn == "swiglu":
        p["gate"] = jax.random.normal(ks[2], (d, f), cfg.dtype) * s
    return p


def ffn_apply(p, cfg: ArchConfig, x):
    if cfg.ffn == "swiglu":
        h = nn.silu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"])
    h = hint(h, "dp", None, "tp")
    out = hint(h @ p["down"], "dp", None, None)
    return checkpoint_name(out, "post_ar")


def moe_init(key, cfg: ArchConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s,
        "e_up": jax.random.normal(ks[1], (E, d, f), cfg.dtype) * s,
        "e_down": jax.random.normal(ks[2], (E, f, d), cfg.dtype) / math.sqrt(f) / math.sqrt(2 * cfg.num_layers),
    }
    if cfg.ffn == "swiglu":
        p["e_gate"] = jax.random.normal(ks[3], (E, d, f), cfg.dtype) * s
    return p


def moe_apply(p, cfg: ArchConfig, x):
    """Top-k token-choice MoE with per-row capacity, gather/scatter dispatch.

    x: (B, S, D).  Routing is per batch row (a proxy for per-device groups):
    capacity C = S·k/E·cf.  Dispatch/combine are index gathers + scatter-adds
    — no one-hot einsum, so HLO FLOPs stay close to the active-expert math
    (important for the MODEL_FLOPS/HLO_FLOPs roofline ratio).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * S * K / E))

    logits = (x.astype(jnp.float32) @ p["router"])            # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, K)                          # (B, S, K)
    w = w / jnp.clip(jnp.sum(w, -1, keepdims=True), 1e-9)
    # combine in the compute dtype: keeping w in f32 drags f32 cotangents
    # through the dispatch gather/scatter collectives (§Perf iteration A4')
    w = w.astype(x.dtype)

    def route_one(xb, wb, ib):
        # xb: (S, D); wb/ib: (S, K)
        flat_e = ib.reshape(-1)                               # (S*K,)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (S*K, E)
        pos = jnp.cumsum(oh, axis=0) - oh
        pos = jnp.sum(pos * oh, axis=-1)                      # position within expert
        keep = pos < C
        tok = jnp.repeat(jnp.arange(S), K)
        slot = jnp.where(keep, flat_e * C + pos, E * C)       # E*C = dropped sentinel
        buf = jnp.full((E * C + 1,), S, jnp.int32).at[slot].set(tok, mode="drop")
        buf = buf[: E * C]
        x_pad = jnp.concatenate([xb, jnp.zeros((1, D), xb.dtype)], 0)
        xe = x_pad[buf].reshape(E, C, D)
        wslot = jnp.zeros((E * C + 1,), wb.dtype).at[slot].set(wb.reshape(-1), mode="drop")[: E * C]
        return xe, buf, wslot

    xe, buf, wslot = jax.vmap(route_one)(x, w, idx)           # (B,E,C,D), (B,E*C), (B,E*C)
    xe = hint(xe, "dp", "tp", None, None)
    if cfg.ffn == "swiglu":
        h = nn.silu(jnp.einsum("becd,edf->becf", xe, p["e_gate"])) * \
            jnp.einsum("becd,edf->becf", xe, p["e_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xe, p["e_up"]))
    h = hint(h, "dp", "tp", None, None)
    ye = jnp.einsum("becf,efd->becd", h, p["e_down"])         # (B, E, C, D)
    ye = hint(ye, "dp", "tp", None, None)

    def combine_one(yeb, bufb, wslotb):
        flat = yeb.reshape(E * C, D) * wslotb[:, None].astype(yeb.dtype)
        out = jnp.zeros((S + 1, D), yeb.dtype).at[bufb].add(flat, mode="drop")
        return out[:S]

    y = jax.vmap(combine_one)(ye, buf, wslot)
    return hint(y, "dp", None, None), logits


def moe_aux_loss(logits, idx_weights=None):
    """Load-balancing auxiliary loss (Switch-style)."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac_prob = jnp.mean(probs, axis=(0, 1))
    top1 = jnp.argmax(probs, axis=-1)
    frac_tok = jnp.mean(jax.nn.one_hot(top1, probs.shape[-1]), axis=(0, 1))
    return probs.shape[-1] * jnp.sum(frac_prob * frac_tok)


# =============================================================================
# Mamba2 mixer (SSD)
# =============================================================================


def mamba2_init(key, cfg: ArchConfig):
    d = cfg.d_model
    di, N, H, P_, k = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_conv
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * N + H), cfg.dtype) * s,
        "conv_w": jax.random.normal(ks[1], (k, conv_dim), cfg.dtype) / math.sqrt(k),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),  # softplus⁻¹
        "Dskip": jnp.ones((H,), jnp.float32),
        "norm_g": jnp.ones((di,), cfg.dtype),
        "out_proj": jax.random.normal(ks[2], (di, d), cfg.dtype) / math.sqrt(di) / math.sqrt(2 * cfg.num_layers),
    }


def _causal_conv(xbc, w, b):
    """xbc: (B, S, Cdim); depthwise causal conv, kernel (k, Cdim)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(k))
    return out + b


def mamba2_apply(p, cfg: ArchConfig, x):
    """Train/prefill path (chunked SSD).  x: (B, S, D).

    Returns (out, cache) — cache is the terminal (conv window, SSM state),
    so a prefill directly seeds the recurrent decode path.
    """
    B, S, D = x.shape
    di, N, H, P_ = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    k = cfg.ssm_conv
    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    conv_in = jnp.concatenate([xin, Bc, Cc], -1)                         # (B,S,conv_dim)
    xbc = nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xin, Bc, Cc = jnp.split(xbc, [di, di + N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])          # (B,S,H)
    a = (-jnp.exp(p["A_log"]) * dt)                                      # (B,S,H) log-decay
    xh = xin.reshape(B, S, H, P_)
    xs = (xh * dt[..., None].astype(xh.dtype)).transpose(0, 2, 1, 3)     # (B,H,S,P)
    bmat = jnp.broadcast_to(Bc[:, None], (B, H, S, N))
    cmat = jnp.broadcast_to(Cc[:, None], (B, H, S, N))
    y, h_final = ssd_chunked_dense(xs, a.transpose(0, 2, 1), bmat, cmat)  # (B,H,S,P)
    y = y + p["Dskip"][None, :, None, None].astype(y.dtype) * xh.transpose(0, 2, 1, 3)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, di)
    y = y * nn.silu(z)
    y = nn.rmsnorm({"g": p["norm_g"]}, y)
    cache = {"conv": conv_in[:, S - (k - 1):, :], "ssm": h_final}
    return hint(y @ p["out_proj"], "dp", None, None), cache


def ssd_chunked_dense(x, a, b, c, chunk: int = 128):
    """Pure-jnp chunked SSD (matmul form + associative scan over chunks).

    Same math as kernels/ssd_chunk.py but fully parallel over chunks — this
    is the XLA path used on CPU and for the dry-run (no sequential S-loop,
    so cost_analysis sees the real matmul FLOPs).
    x: (B,H,S,P)  a: (B,H,S)  b,c: (B,H,S,N)  ->  (B,H,S,P)
    """
    B, H, S, P_ = x.shape
    N = b.shape[-1]
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L
    xc = x.reshape(B, H, nc, L, P_).astype(jnp.float32)
    ac = a.reshape(B, H, nc, L).astype(jnp.float32)
    bc = b.reshape(B, H, nc, L, N).astype(jnp.float32)
    cc = c.reshape(B, H, nc, L, N).astype(jnp.float32)
    cum = jnp.cumsum(ac, -1)                                   # (B,H,nc,L)
    # intra-chunk
    smat = jnp.einsum("bhctn,bhcsn->bhcts", cc, bc)
    decay = jnp.exp(cum[..., :, None] - cum[..., None, :])
    tri = jnp.tril(jnp.ones((L, L), bool))
    smat = jnp.where(tri, smat * decay, 0.0)
    y = jnp.einsum("bhcts,bhcsp->bhctp", smat, xc)
    # chunk-final states:  S_c = Σ_s e^{cumL - cum_s} b_s x_sᵀ ;  decay_c = e^{cumL}
    bscaled = bc * jnp.exp(cum[..., -1:, None] - cum[..., :, None])
    Sc = jnp.einsum("bhcsn,bhcsp->bhcnp", bscaled, xc)         # (B,H,nc,N,P)
    dc = jnp.exp(cum[..., -1])                                 # (B,H,nc)
    # inter-chunk initial states via associative linear-recurrence scan:
    #   h_c = d_c · h_{c-1} + S_c   (h_0 init 0); we need h before each chunk.
    def op(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2

    dfull, sfull = jax.lax.associative_scan(op, (dc, Sc), axis=2)
    # state *before* chunk c is the scan result of chunk c-1 (shift right)
    h_prev = jnp.concatenate([jnp.zeros_like(Sc[:, :, :1]), sfull[:, :, :-1]], axis=2)
    y = y + jnp.einsum("bhctn,bhcnp->bhctp", cc * jnp.exp(cum)[..., None], h_prev)
    return y.reshape(B, H, S, P_).astype(x.dtype), sfull[:, :, -1]


def mamba2_init_cache(cfg: ArchConfig, batch: int, dtype):
    di, N, H, P_, k = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, k - 1, di + 2 * N), dtype),
        "ssm": jnp.zeros((batch, H, N, P_), jnp.float32),
    }


def mamba2_decode(p, cfg: ArchConfig, x, cache, pos):
    """Single-token recurrent step.  x: (B, 1, D)."""
    B = x.shape[0]
    di, N, H, P_ = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = x[:, 0] @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    xbc_new = jnp.concatenate([xin, Bc, Cc], -1)               # (B, conv_dim)
    conv_win = jnp.concatenate([cache["conv"], xbc_new[:, None]], 1)  # (B, k, conv)
    w = p["conv_w"]
    out = jnp.sum(conv_win * w[None], axis=1) + p["conv_b"]
    xbc = nn.silu(out)
    xin, Bc, Cc = jnp.split(xbc, [di, di + N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                             # (B,H) decay
    xh = xin.reshape(B, H, P_).astype(jnp.float32) * dt[..., None]
    h = cache["ssm"] * a[..., None, None] + Bc[:, None, :, None].astype(jnp.float32) * xh[:, :, None, :]
    y = jnp.einsum("bn,bhnp->bhp", Cc.astype(jnp.float32), h)
    y = y + p["Dskip"][None, :, None] * xin.reshape(B, H, P_).astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype) * nn.silu(z)
    y = nn.rmsnorm({"g": p["norm_g"]}, y)
    new_cache = {"conv": conv_win[:, 1:], "ssm": h}
    return (y @ p["out_proj"])[:, None], new_cache
