"""Fused reversible-Heun state updates (Algorithm 1/2) as Pallas TPU kernels.

The solver's per-step arithmetic is pure elementwise VPU work: without
fusion, XLA materialises each intermediate (2z, −ẑ, μΔt, σΔW, …) through
HBM.  One VMEM-resident kernel per phase turns ~6 HBM round-trips into one
read + one write per operand — the solver loop is memory-bound, so this is
the hot spot the paper's 1-NFE-per-step advantage exposes.

Phase 1 computes ẑ_{n+1} (before the vector-field evaluation); phase 2
computes z_{n+1} (after).  Both take a static ``sign``: ``+1.0`` is the
forward step (Algorithm 1) and ``-1.0`` the algebraic inverse (Algorithm 2,
used by the O(1)-memory backward reconstruction in
:mod:`repro.core.adjoint`), which negates the Δt and ΔW terms in-kernel so
no extra negated operand ever touches HBM.

Kernel contract
===============

* **Noise layout**: diagonal noise only — ``z, ẑ, μ, σ, ΔW`` all share the
  state shape.  General (matrix) noise needs an ``einsum`` per step and is
  served by the unfused path in :mod:`repro.core.solvers`.
* **Shapes/tiling**: operands are flattened to ``(rows, cols)`` with
  ``cols = shape[-1]`` (1-D states become ``(1, n)``).  Block sizes are the
  largest divisor of each dim from the preference ladder
  ``(256|512, 256, 128, 64, …, 1)``, so *any* shape is legal, but
  performance wants ``cols`` a multiple of the 128-lane VPU width and
  ``rows`` a multiple of 8 (f32) / 16 (bf16) sublanes.
* **dt is static**: ``dt`` (a Python float) is baked into the kernel at
  trace time — fixed-step solvers re-use one compiled kernel for the whole
  scan.  Traced step sizes must use the unfused path.
* **Interpret mode**: ``interpret=True`` runs the kernel body under the
  Pallas interpreter — required on CPU, and how CI validates the kernels
  without a TPU (see tests/test_kernels.py and tests/test_solve.py).  The
  solver hot loop does NOT pay this off-TPU: ``repro.core.solvers``
  dispatches per the kernels/ops.py policy (compiled kernel on TPU, the
  fused jnp oracle in :mod:`repro.kernels.ref` elsewhere) and only forces
  the interpreter when a caller passes ``interpret=True`` explicitly.
* **Differentiability**: ``pallas_call`` has no VJP rule — these kernels
  must only appear where AD never traces through them: the custom-VJP
  forward scan and the closed-form backward reconstruction.  The local
  per-step VJPs in :mod:`repro.core.adjoint` deliberately use the unfused
  stepper.  ``jax.vmap`` (batched multi-trajectory solving) IS supported.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl


def _phase1_kernel(dt, sign, z_ref, zh_ref, mu_ref, sig_ref, dw_ref, o_ref):
    o_ref[...] = (
        2.0 * z_ref[...]
        - zh_ref[...]
        + mu_ref[...] * (sign * dt)
        + (sign * sig_ref[...]) * dw_ref[...]
    )


def _phase2_kernel(dt, sign, z_ref, mu_ref, mu1_ref, sig_ref, sig1_ref, dw_ref, o_ref):
    o_ref[...] = (
        z_ref[...]
        + (sign * 0.5 * dt) * (mu_ref[...] + mu1_ref[...])
        + (sign * 0.5) * (sig_ref[...] + sig1_ref[...]) * dw_ref[...]
    )


def _tile(n: int, pref: int) -> int:
    for t in (pref, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if t <= n and n % t == 0:
            return t
    return 1


def _call_elementwise(kernel, args, interpret: bool):
    x = args[0]
    orig_shape = x.shape
    flat = [a.reshape(-1, orig_shape[-1]) if a.ndim > 1 else a.reshape(1, -1) for a in args]
    rows, cols = flat[0].shape
    br, bc = _tile(rows, 256), _tile(cols, 512)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    out = pl.pallas_call(
        kernel,
        grid=(rows // br, cols // bc),
        in_specs=[spec] * len(flat),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(*flat)
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("dt", "sign", "interpret"))
def rev_heun_phase1(z, zh, mu, sigma, dw, dt: float, sign: float = 1.0,
                    interpret: bool = True):
    """ẑ_{n+1} = 2z − ẑ + sign·(μΔt + σΔW) — fused, one HBM pass."""
    return _call_elementwise(
        functools.partial(_phase1_kernel, dt, sign), (z, zh, mu, sigma, dw), interpret)


@functools.partial(jax.jit, static_argnames=("dt", "sign", "interpret"))
def rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt: float, sign: float = 1.0,
                    interpret: bool = True):
    """z_{n+1} = z + sign·(½(μ+μ′)Δt + ½(σ+σ′)ΔW) — fused, one HBM pass."""
    return _call_elementwise(
        functools.partial(_phase2_kernel, dt, sign), (z, mu, mu1, sigma, sigma1, dw), interpret)
