"""The Brownian Interval — faithful host-side implementation (paper §4, App. E).

A lazily grown binary tree of ``(interval, seed)`` nodes.  Queries return the
exact increment ``W_{s,t}``; the tree aligns itself with query points, so no
discretisation error is ever introduced (unlike the Virtual Brownian Tree).
Three of the paper's engineering points are reproduced:

* **splittable PRNG** — each child's seed is derived deterministically from
  its parent's (Salmon et al. [34] / Claessen & Pałka [35]); we use numpy's
  Philox counter-based generator keyed by the node seed.
* **LRU cache on computed increments** — queries adjacent to recent queries
  (the SDE-solver access pattern) hit the cache and cost amortised O(1).
* **search hints** — ``traverse`` starts from the most recent node, not the
  root (App. E "Search hints"), and an optional **pre-planted dyadic tree**
  (App. E "Backward pass") bounds recomputation on right-to-left sweeps.

This module is intentionally host-side Python: it is the *reference /
benchmark* implementation used to reproduce Table 2.  The in-graph TPU path
(:class:`repro.core.brownian.BrownianPath`) achieves the same
exactness-without-storage via JAX's own counter-based splittable PRNG; see
DESIGN.md §2 for why the LRU cache dissolves on TPU.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["BrownianInterval", "HostVirtualBrownianTree"]


class _Node:
    __slots__ = ("a", "b", "seed", "parent", "left", "right")

    def __init__(self, a: float, b: float, seed: int, parent: Optional["_Node"]):
        self.a = a
        self.b = b
        self.seed = seed
        self.parent = parent
        self.left: Optional[_Node] = None
        self.right: Optional[_Node] = None

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Node([{self.a}, {self.b}])"


def _split_seed(seed: int) -> Tuple[int, int]:
    """Deterministic splittable seed derivation (counter-based hash)."""
    rng = np.random.Philox(key=seed & ((1 << 64) - 1))
    child = np.random.Generator(rng).integers(0, 2**63 - 1, size=2)
    return int(child[0]), int(child[1])


class _LRU:
    """Fixed-size LRU cache: node-id -> increment array."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: OrderedDict[int, np.ndarray] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, k: int):
        v = self._d.get(k)
        if v is not None:
            self.hits += 1
            self._d.move_to_end(k)
        else:
            self.misses += 1
        return v

    def put(self, k: int, v: np.ndarray):
        self._d[k] = v
        self._d.move_to_end(k)
        if len(self._d) > self.maxsize:
            self._d.popitem(last=False)


class BrownianInterval:
    """Exact sampling/reconstruction of Brownian increments ``W_{s,t}``.

    Parameters
    ----------
    t0, t1 : global interval.
    shape  : shape of each increment (e.g. ``(batch, w_dim)``).
    seed   : global seed (root of the splittable-PRNG tree).
    cache_size : LRU cache entries (the paper's "fixed and constant" GPU cost).
    preplant_dt : if given, pre-plant a dyadic tree whose leaves are no larger
        than ``4/5 * preplant_dt * cache_size`` (App. E backward-pass remedy),
        making right-to-left sweeps O(n log n) instead of O(n^2).
    """

    def __init__(
        self,
        t0: float,
        t1: float,
        shape: Tuple[int, ...],
        seed: int = 0,
        cache_size: int = 128,
        preplant_dt: Optional[float] = None,
        dtype=np.float64,
    ):
        assert t1 > t0
        self.t0, self.t1 = float(t0), float(t1)
        self.shape = tuple(shape)
        self.dtype = dtype
        self._root = _Node(self.t0, self.t1, seed, None)
        self._cache = _LRU(cache_size)
        self._hint: _Node = self._root
        if preplant_dt is not None:
            leaf = max(preplant_dt * cache_size * 0.8, 1e-12)
            self._preplant(self._root, leaf)

    # -- public API ----------------------------------------------------------
    def __call__(self, s: float, t: float) -> np.ndarray:
        """Return the exact increment ``W_t - W_s``."""
        if not (self.t0 <= s < t <= self.t1):
            raise ValueError(f"query [{s}, {t}] outside [{self.t0}, {self.t1}]")
        nodes = self._traverse(self._hint, s, t)
        self._hint = nodes[-1]
        out = np.zeros(self.shape, self.dtype)
        for n in nodes:
            out += self._sample(n)
        return out

    @property
    def cache_stats(self) -> Tuple[int, int]:
        return self._cache.hits, self._cache.misses

    # -- Algorithm 3: sample -------------------------------------------------
    def _base_normal(self, seed: int, scale: float) -> np.ndarray:
        g = np.random.Generator(np.random.Philox(key=seed & ((1 << 64) - 1)))
        return g.normal(0.0, scale, size=self.shape).astype(self.dtype, copy=False)

    def _bridge(self, a: float, b: float, x: float, w_parent: np.ndarray, seed: int) -> np.ndarray:
        """Lévy bridge (paper eq. (8)): sample W_{a,x} | W_{a,b} = w_parent."""
        mean = (x - a) / (b - a) * w_parent
        std = np.sqrt((b - x) * (x - a) / (b - a))
        g = np.random.Generator(np.random.Philox(key=seed & ((1 << 64) - 1)))
        return mean + std * g.standard_normal(self.shape).astype(self.dtype, copy=False)

    def _sample(self, node: _Node) -> np.ndarray:
        cached = self._cache.get(id(node))
        if cached is not None:
            return cached
        if node is self._root:
            out = self._base_normal(node.seed, np.sqrt(self.t1 - self.t0))
        else:
            parent = node.parent
            w_parent = self._sample(parent)
            if node is parent.right:
                # W_{mid, b} = W_{a, b} - W_{a, mid}
                left = parent.left
                w_left = self._bridge(parent.a, parent.b, left.b, w_parent, left.seed)
                out = w_parent - w_left
            else:
                out = self._bridge(parent.a, parent.b, node.b, w_parent, node.seed)
        self._cache.put(id(node), out)
        return out

    # -- Algorithm 4: traverse -------------------------------------------------
    def _bisect(self, node: _Node, x: float) -> None:
        s_left, s_right = _split_seed(node.seed)
        node.left = _Node(node.a, x, s_left, node)
        node.right = _Node(x, node.b, s_right, node)

    def _traverse(self, start: _Node, c: float, d: float) -> List[_Node]:
        nodes: List[_Node] = []
        # Iterative (trampolined) version of Algorithm 4 — the paper notes
        # recursion depth errors otherwise ("Recursion errors", App. E).
        stack: List[Tuple[_Node, float, float]] = [(start, c, d)]
        while stack:
            node, lo, hi = stack.pop()
            # outside our jurisdiction — pass to parent
            while lo < node.a or hi > node.b:
                node = node.parent
            if lo == node.a and hi == node.b:
                nodes.append(node)
                continue
            if node.left is None:  # leaf
                if node.a == lo:
                    self._bisect(node, hi)
                    nodes.append(node.left)
                else:
                    self._bisect(node, lo)
                    stack.append((node.right, lo, hi))
                continue
            m = node.left.b
            if hi <= m:
                stack.append((node.left, lo, hi))
            elif lo >= m:
                stack.append((node.right, lo, hi))
            else:
                # split across both children; keep left-to-right output order
                stack.append((node.right, m, hi))
                stack.append((node.left, lo, m))
        return nodes

    def _preplant(self, node: _Node, leaf_size: float) -> None:
        stack = [node]
        while stack:
            n = stack.pop()
            if (n.b - n.a) <= leaf_size:
                continue
            self._bisect(n, 0.5 * (n.a + n.b))
            stack.extend((n.left, n.right))


class HostVirtualBrownianTree:
    """Host-side Virtual Brownian Tree baseline (Li et al. [15]).

    Every query runs the full ``O(log(1/eps))`` dyadic descent from the root —
    no cache, no tree growth, approximate at resolution ``eps``.
    """

    def __init__(self, t0: float, t1: float, shape, seed: int = 0, eps: float = 1e-5, dtype=np.float64):
        self.t0, self.t1 = float(t0), float(t1)
        self.shape = tuple(shape)
        self.eps = eps
        self.seed = seed
        self.dtype = dtype
        import math

        self._depth = max(1, int(math.ceil(math.log2((t1 - t0) / eps))))

    def _w(self, t: float) -> np.ndarray:
        g = np.random.Generator(np.random.Philox(key=self.seed))
        w_a = np.zeros(self.shape, self.dtype)
        w_b = g.standard_normal(self.shape).astype(self.dtype) * np.sqrt(self.t1 - self.t0)
        a, b = self.t0, self.t1
        seed = self.seed
        for _ in range(self._depth):
            m = 0.5 * (a + b)
            s_left, s_right = _split_seed(seed)
            gm = np.random.Generator(np.random.Philox(key=s_left))
            std = np.sqrt((b - m) * (m - a) / (b - a))
            w_m = 0.5 * (w_a + w_b) + std * gm.standard_normal(self.shape).astype(self.dtype)
            if t <= m:
                b, w_b, seed = m, w_m, s_left
            else:
                a, w_a, seed = m, w_m, s_right
            if (b - a) <= self.eps:
                break
        return w_a

    def __call__(self, s: float, t: float) -> np.ndarray:
        return self._w(t) - self._w(s)
