"""Unit tests for the measurement layer: HLO collective/traffic parsers.

The roofline numbers are only as good as these parsers — pin their
behaviour on synthetic post-SPMD HLO snippets."""

from repro.launch.dryrun import collective_bytes, macro_bytes

HLO = """
HloModule jit_step, entry_computation_layout={...}

%fused (p: bf16[128,256]) -> bf16[128,256] {
  %ag = bf16[128,256]{1,0} all-gather(bf16[8,256]{1,0} %p), dimensions={0}
  ROOT %r = bf16[128,256]{1,0} add(%ag, %ag)
}

ENTRY %main {
  %x = bf16[64,512]{1,0} parameter(0)
  %w = bf16[512,256]{1,0} parameter(1)
  %d = bf16[64,256]{1,0} dot(bf16[64,512]{1,0} %x, bf16[512,256]{1,0} %w), lhs_contracting_dims={1}
  %ar = f32[64,256]{1,0} all-reduce(f32[64,256]{1,0} %c), replica_groups={}
  %rs = f32[4,256]{1,0} reduce-scatter(f32[64,256]{1,0} %c2), dimensions={0}
  %a2a = (f32[8,16]{1,0}, f32[8,16]{1,0}) all-to-all(f32[8,16]{1,0} %e, f32[8,16]{1,0} %f)
  %cp = bf16[32]{0} collective-permute(bf16[32]{0} %g), source_target_pairs={{0,1}}
  %g1 = bf16[64,32]{1,0} gather(bf16[1000,32]{1,0} %table, s32[64,1]{1,0} %idx), offset_dims={1}
  %dus = bf16[64,4096,8]{2,1,0} dynamic-update-slice(bf16[64,4096,8]{2,1,0} %cache, bf16[64,1,8]{2,1,0} %upd, %i, %j, %k)
}
"""


def test_collective_bytes_by_type():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 128 * 256 * 2
    assert out["all-reduce"] == 64 * 256 * 4
    assert out["reduce-scatter"] == 4 * 256 * 4
    assert out["all-to-all"] == 2 * 8 * 16 * 4          # tuple: both members
    assert out["collective-permute"] == 32 * 2
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_macro_bytes_rules():
    out = macro_bytes(HLO)
    dot = (64 * 512 + 512 * 256 + 64 * 256) * 2          # A + B + C, bf16
    gather = 2 * 64 * 32 * 2                             # 2 x result
    dus = 2 * 64 * 1 * 8 * 2                             # 2 x update slice
    assert out == dot + gather + dus


def test_parsers_ignore_metadata_shapes():
    line = ('%ar = f32[16]{0} all-reduce(f32[16]{0} %x), '
            'metadata={op_name="foo" source_file="f32[9999999]"}\n')
    assert collective_bytes(line)["all-reduce"] == 16 * 4
