"""Paper Fig. 2 / Table 6: relative gradient error of continuous adjoints.

Fixes the paper's test problem (differentiate a small Neural SDE) and
compares optimise-then-discretise gradients against discretise-then-optimise
per solver and step size.  The reversible Heun method must be exact to
floating-point error; midpoint/Heun carry O(h^p) truncation error.

Also gates the two new gradient backends (DESIGN.md §12):

* ``checkpoint`` — recursive binomial checkpointing must match discretise
  gradients to <= 1e-10 for EVERY solver (they are the same discrete
  gradients, rematerialised), while the compiled backward's temp buffers
  follow the O(log n) schedule model (``checkpoint_schedule``) instead of
  discretise's O(n) — asserted against XLA's ``memory_analysis()``.
* ``bf16_compute`` — the low-precision field-eval policy must move
  gradients by a pinned *nonzero but bounded* amount: zero would mean the
  cast never happened, large would mean accumulation degraded too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:
    from . import report
except ImportError:  # run as a loose script
    import report


def build_problem(key, batch=32, x_dim=32, w_dim=16, width=8,
                  dtype=jnp.float64, noise="general", levy_area=None):
    """The Fig.-2 Neural SDE; ``noise="diagonal"`` shrinks the diffusion
    head to a state-shaped output and sizes the Brownian path to match
    (``levy_area="space-time"`` for solvers that consume (W, H) pairs)."""
    from repro import nn
    from repro.core.brownian import BrownianPath

    kp1, kp2, kz, kw = jax.random.split(key, 4)
    g_out = x_dim if noise == "diagonal" else x_dim * w_dim
    params = {
        "f": nn.mlp_init(kp1, [x_dim, width, x_dim], dtype=dtype),
        "g": nn.mlp_init(kp2, [x_dim, width, g_out], dtype=dtype),
    }

    def drift(p, t, x):
        return jax.nn.sigmoid(nn.mlp(p["f"], x, nn.lipswish))

    def diffusion(p, t, x):
        out = jax.nn.sigmoid(nn.mlp(p["g"], x, nn.lipswish))
        if noise == "diagonal":
            return out * 0.2
        return out.reshape(x.shape[:-1] + (x_dim, w_dim)) * 0.2

    z0 = jax.random.normal(kz, (batch, x_dim), dtype)
    bm_shape = (batch, x_dim if noise == "diagonal" else w_dim)
    bm = BrownianPath(kw, 0.0, 1.0, bm_shape, dtype, levy_area=levy_area)
    return params, drift, diffusion, z0, bm


def relative_l1(g1, g2):
    l1, l2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    num = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(l1, l2))
    den = max(sum(float(jnp.sum(jnp.abs(a))) for a in l1),
              sum(float(jnp.sum(jnp.abs(b))) for b in l2), 1e-300)
    return num / den


def gradient_error(solver: str, num_steps: int, key=None, dtype=jnp.float64):
    """Relative L1 error of adjoint-computed vs autodiff gradients.

    Both paths dispatch through :func:`repro.solve`: the reference is
    ``gradient_mode="discretise"`` (AD through the scan), the adjoint under
    test is the registry's native adjoint for the solver —
    ``"reversible_adjoint"`` (exact) for reversible Heun,
    ``"continuous_adjoint"`` (eq. (6), O(√h) error) for midpoint/Heun.
    """
    from repro.core.solve import get_solver, solve

    key = jax.random.PRNGKey(0) if key is None else key
    params, drift, diffusion, z0, bm = build_problem(key, dtype=dtype)

    def loss_dto(p, z):
        traj = solve(drift, diffusion, p, z, bm, 0.0, 1.0, num_steps,
                     solver=solver, gradient_mode="discretise", noise="general")
        return jnp.sum(traj[-1] ** 2)

    g_dto = jax.grad(loss_dto, argnums=(0, 1))(params, z0)

    adjoint_mode = ("reversible_adjoint"
                    if "reversible_adjoint" in get_solver(solver).gradient_modes
                    else "continuous_adjoint")

    def loss_otd(p, z):
        zT = solve(drift, diffusion, p, z, bm, 0.0, 1.0, num_steps,
                   solver=solver, gradient_mode=adjoint_mode, noise="general",
                   save_trajectory=False)
        return jnp.sum(zT ** 2)

    g_otd = jax.grad(loss_otd, argnums=(0, 1))(params, z0)
    return relative_l1(g_otd, g_dto)


def checkpoint_error(solver: str, num_steps: int, key=None,
                     dtype=jnp.float64):
    """Relative L1 error of checkpoint-mode vs discretise-mode gradients.

    Both are discretise-then-optimise derivations of the SAME discrete
    trajectory — checkpointing only changes what is stored vs recomputed —
    so the error must sit at floating-point noise for every solver.  The
    problem follows the solver's capability rows: solvers without general
    noise (srk) run the diagonal layout, on a space-time Lévy-area path
    when the spec demands (W, H) pairs.
    """
    from repro.core.solve import get_solver, solve

    spec = get_solver(solver)
    noise = "general" if "general" in spec.noise_types else "diagonal"
    key = jax.random.PRNGKey(0) if key is None else key
    params, drift, diffusion, z0, bm = build_problem(
        key, dtype=dtype, noise=noise,
        levy_area="space-time" if spec.needs_levy_area else None)

    def loss(mode, save_traj):
        def f(p, z):
            out = solve(drift, diffusion, p, z, bm, 0.0, 1.0, num_steps,
                        solver=solver, gradient_mode=mode, noise=noise,
                        save_trajectory=save_traj)
            return jnp.sum((out[-1] if save_traj else out) ** 2)
        return f

    g_dto = jax.grad(loss("discretise", True), argnums=(0, 1))(params, z0)
    g_ckpt = jax.grad(loss("checkpoint", False), argnums=(0, 1))(params, z0)
    return relative_l1(g_ckpt, g_dto)


def backward_temp_bytes(mode: str, num_steps: int, key=None,
                        dtype=jnp.float64):
    """XLA temp-buffer bytes of the compiled gradient program, or ``None``
    when the backend's ``memory_analysis`` does not report them."""
    from repro.core.solve import solve

    key = jax.random.PRNGKey(0) if key is None else key
    params, drift, diffusion, z0, bm = build_problem(key, dtype=dtype)

    def loss(p):
        zT = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                   solver="heun", gradient_mode=mode, noise="general",
                   save_trajectory=False)
        return jnp.sum(zT ** 2)

    compiled = jax.jit(jax.grad(loss)).lower(params).compile()
    try:
        temp = compiled.memory_analysis().temp_size_in_bytes
    except (AttributeError, NotImplementedError):
        return None
    return int(temp)


def bf16_gradient_shift(solver: str = "heun", num_steps: int = 16,
                        key=None):
    """Relative L1 shift of ``precision="bf16_compute"`` gradients vs
    ``"highest"`` — the pinned-tolerance gate for the precision policy."""
    from repro.core.solve import solve

    key = jax.random.PRNGKey(0) if key is None else key
    params, drift, diffusion, z0, bm = build_problem(key, dtype=jnp.float64)

    def loss(precision):
        def f(p):
            zT = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                       solver=solver, gradient_mode="checkpoint",
                       noise="general", save_trajectory=False,
                       precision=precision)
            return jnp.sum(zT ** 2)
        return f

    g_hi = jax.grad(loss("highest"))(params)
    g_lo = jax.grad(loss("bf16_compute"))(params)
    return relative_l1(g_lo, g_hi)


PRESET_STEPS = {
    "tiny": [1, 4, 16],
    "quick": [1, 4, 16, 64],
    "full": [1, 4, 16, 64, 256, 1024],
}

CHECKPOINT_ERR_GATE = 1e-10
# bf16 mantissa is 8 bits: per-step field error ~2^-8; accumulated relative
# gradient shift on this problem sits ~1e-3.  Gate generously above that
# but far below "accumulation degraded" (which would be O(1)), and strictly
# above zero (zero ⇒ the cast silently never happened).
BF16_SHIFT_BOUNDS = (1e-6, 0.2)
# measured-vs-model slack for the temp-byte gate (constant-factor headroom
# for XLA scratch that is not a solver carry)
MEM_MODEL_SLACK = 2.0


def main(preset: str = "full"):
    from repro.core.gradients import checkpoint_schedule
    from repro.core.solve import SOLVERS

    jax.config.update("jax_enable_x64", True)
    steps_list = PRESET_STEPS[preset]
    rows = []
    for solver in ("midpoint", "heun", "reversible_heun"):
        for n in steps_list:
            err = gradient_error(solver, n)
            rows.append(("gradient_error", f"{solver},steps={n}", err))
            print(f"gradient_error,{solver},steps={n},{err:.3e}", flush=True)

    # -- checkpoint backend: exact for every registered solver ---------------
    for solver in sorted(SOLVERS):
        for n in steps_list:
            err = checkpoint_error(solver, n)
            rows.append(("gradient_error",
                         f"{solver},checkpoint,steps={n}", err))
            print(f"gradient_error,{solver},checkpoint,steps={n},"
                  f"{err:.3e}", flush=True)
            assert err <= CHECKPOINT_ERR_GATE, (
                f"checkpoint gradients for {solver} at steps={n} drifted "
                f"{err:.3e} from discretise (gate {CHECKPOINT_ERR_GATE:g}) "
                f"— the rematerialised backward no longer replays the same "
                f"discrete steps")

    # -- checkpoint memory: measured temp bytes follow the O(log n) model ----
    temps = {}
    for n in steps_list:
        sched = checkpoint_schedule(n)
        rows.append(("gradient_error",
                     f"checkpoint,peak_live_states,steps={n}",
                     sched["peak_live_states"]))
        for mode in ("discretise", "checkpoint"):
            t = backward_temp_bytes(mode, n)
            if t is not None:
                temps[(mode, n)] = t
                rows.append(("gradient_error",
                             f"{mode},temp_bytes,steps={n}", t))
                print(f"gradient_error,{mode},temp_bytes,steps={n},{t}",
                      flush=True)
    n_lo, n_hi = steps_list[1], steps_list[-1]
    if ("checkpoint", n_hi) in temps and ("checkpoint", n_lo) in temps:
        grow = temps[("checkpoint", n_hi)] / max(temps[("checkpoint", n_lo)], 1)
        model = (checkpoint_schedule(n_hi)["peak_live_states"]
                 / checkpoint_schedule(n_lo)["peak_live_states"])
        assert grow <= model * MEM_MODEL_SLACK, (
            f"checkpoint backward temp bytes grew {grow:.2f}x from "
            f"steps={n_lo} to steps={n_hi}; the O(log n) schedule model "
            f"allows {model:.2f}x (x{MEM_MODEL_SLACK:g} slack) — residuals "
            f"are being stored per-step again")
        if n_hi >= 16:
            assert temps[("checkpoint", n_hi)] < temps[("discretise", n_hi)], (
                f"checkpoint backward stores {temps[('checkpoint', n_hi)]} "
                f"temp bytes at steps={n_hi}, not less than discretise's "
                f"{temps[('discretise', n_hi)]} — checkpointing saves "
                f"nothing")

    # -- bf16 precision policy: nonzero but bounded gradient shift -----------
    shift = bf16_gradient_shift()
    rows.append(("gradient_error", "bf16_compute,heun,steps=16", shift))
    print(f"gradient_error,bf16_compute,heun,steps=16,{shift:.3e}",
          flush=True)
    lo, hi = BF16_SHIFT_BOUNDS
    assert lo < shift < hi, (
        f"bf16_compute gradient shift {shift:.3e} outside ({lo:g}, {hi:g}) "
        f"— below means the compute-dtype cast silently stopped happening, "
        f"above means gradient accumulation degraded to bf16 too")

    jax.config.update("jax_enable_x64", False)
    return rows


if __name__ == "__main__":
    report.standalone("gradient_error", main)
