"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh).

Method
------
XLA's ``cost_analysis`` counts a ``while``-loop (scan) body ONCE, so the
full-config scanned compile (the §Dry-run memory/shardability proof) cannot
give total FLOPs.  Instead we lower the SAME step with the layer stack
**unrolled** at 1 and 2 units and extrapolate affinely::

    cost(U units) = cost(1) + (U - 1) * (cost(2) - cost(1))

This is exact for every per-unit-affine quantity (matmul FLOPs, HBM bytes,
collective bytes, optimizer/grad FLOPs) and attributes embedding/head/loss
costs to the base term.  Attention inside the costing lowers uses the
``unrolled`` blockwise implementation, so its FLOPs are fully visible too.

Hardware constants (TPU v5e): 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip,
~50 GB/s/link ICI.  cost_analysis of an SPMD executable is per-device, so

    compute    = flops / peak_flops
    memory     = bytes_accessed / hbm_bw
    collective = collective_bytes / link_bw          (all per-chip, seconds)

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (inference);
the ratio MODEL_FLOPS/HLO_FLOPs measures how much compiled compute is
"useful" (catches remat/redundancy waste).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link (ICI)

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "roofline"


def _costing_cfg(cfg, k: int):
    """Config with k units, unrolled stack, exact-cost attention."""
    from repro.models.transformer import unit_pattern

    unit = len(unit_pattern(cfg))
    upd: Dict[str, Any] = dict(num_layers=unit * k, scan_layers=False,
                               attn_impl="unrolled")
    if cfg.encoder_layers:
        upd["encoder_layers"] = k
    return dataclasses.replace(cfg, **upd)


def _cost_of(cfg, shape, mesh) -> Dict[str, float]:
    from repro.launch.dryrun import analyze, lower_cell

    lowered, _ = lower_cell(cfg, shape, mesh)
    a = analyze(lowered)
    # memory term uses the TPU-fusion-adjusted traffic model (macro ops);
    # the raw XLA-CPU "bytes accessed" (every unfused op at full size) is
    # kept for reference — see dryrun.macro_bytes docstring.
    return {"flops": a["flops"], "bytes": a["macro_bytes"],
            "raw_bytes": a["bytes_accessed"],
            "coll": float(a["collective_bytes"]["total"]),
            "compile_seconds": a["compile_seconds"]}


def extrapolated_cost(cfg, shape, mesh) -> Dict[str, float]:
    """Total per-device cost via the 1-unit/2-unit affine extrapolation."""
    from repro.models.transformer import num_units

    u = num_units(cfg)
    c1 = _cost_of(_costing_cfg(cfg, 1), shape, mesh)
    if u == 1:
        return {**c1, "per_unit_flops": c1["flops"], "units": 1}
    c2 = _cost_of(_costing_cfg(cfg, 2), shape, mesh)
    out = {}
    for k in ("flops", "bytes", "raw_bytes", "coll"):
        d = c2[k] - c1[k]
        out[k] = c1[k] + (u - 1) * d
        out[f"per_unit_{k}"] = d
    out["units"] = u
    out["compile_seconds"] = c1["compile_seconds"] + c2["compile_seconds"]
    return out


def model_flops_for_cell(cfg, shape) -> float:
    """Global useful FLOPs for one step of this cell."""
    from repro.models.counting import param_count

    n_active = param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def ideal_bytes_per_dev(cfg, shape, devices: int) -> float:
    """Decode ideal: the unavoidable HBM reads — every (active) parameter
    once + the whole KV/state cache once, spread over the mesh."""
    from repro.models.counting import param_count

    param_bytes = param_count(cfg, active_only=True) * 2  # bf16
    cache_bytes = 0.0
    if shape.kind == "decode":
        from repro.configs.base import SHAPES  # noqa: F401 (doc pointer)
        from repro.launch.specs import input_specs

        specs = input_specs(cfg, shape)
        for leaf in __import__("jax").tree.leaves(specs["caches"]):
            cache_bytes += leaf.size * leaf.dtype.itemsize
    return (param_bytes + cache_bytes) / devices


def roofline_terms(cost: Dict[str, float], devices: int, cfg, shape) -> Dict[str, Any]:
    compute_s = cost["flops"] / PEAK_FLOPS
    memory_s = cost["bytes"] / HBM_BW
    coll_s = cost["coll"] / LINK_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    mf = model_flops_for_cell(cfg, shape) / devices
    total = max(compute_s, memory_s, coll_s)
    # roofline fraction = (hardware-limited ideal step time) / (bound implied
    # by the compiled artifact).  Train/prefill are compute-ideal (MODEL_FLOPS
    # at peak MXU); decode is memory-ideal (params+cache through HBM once).
    if shape.kind == "decode":
        ideal = ideal_bytes_per_dev(cfg, shape, devices) / HBM_BW
    else:
        ideal = mf / PEAK_FLOPS
    return {
        "devices": devices,
        "kind": shape.kind,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "ideal_s": ideal,
        "useful_flops_ratio": (mf / cost["flops"]) if cost["flops"] else 0.0,
        "roofline_fraction": (ideal / total) if total else 0.0,
    }


def recompute_terms():
    """Rewrite the derived terms in every stored JSON from its raw cost dict
    (post-hoc metric changes without recompiling)."""
    from repro.configs import SHAPES, get_config

    for p in sorted(RESULTS_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok" or "cost" not in r:
            continue
        cfg = get_config(r["arch"])
        if r.get("overrides"):
            cfg = dataclasses.replace(cfg, **r["overrides"])
        shape = SHAPES[r["shape"]]
        r.update(roofline_terms(r["cost"], r.get("devices", 256), cfg, shape))
        p.write_text(json.dumps(r, indent=2))


def run_cell(arch: str, shape_name: str, variant: str = "baseline",
             overrides: Optional[Dict[str, Any]] = None,
             force: bool = False) -> Dict[str, Any]:
    """Roofline for one cell on the single-pod mesh.  ``variant`` names a
    hillclimb configuration; ``overrides`` are ArchConfig field updates."""
    from repro.configs import SHAPES, cell_is_runnable, get_config
    from repro.launch.mesh import make_production_mesh

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{variant}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    record: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "variant": variant, "overrides": overrides or {}}
    runnable, why = cell_is_runnable(cfg, shape_name)
    if not runnable:
        record.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(record, indent=2))
        return record

    mesh = make_production_mesh(multi_pod=False)
    record["devices"] = mesh.size
    try:
        cost = extrapolated_cost(cfg, shape, mesh)
        record["cost"] = cost
        record.update(roofline_terms(cost, mesh.size, cfg, shape))
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        import traceback

        record.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-3000:])
    out_path.write_text(json.dumps(record, indent=2))
    return record


def summarize() -> str:
    rows = []
    for p in sorted(RESULTS_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        rows.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['variant']:18s} "
            f"comp {r['compute_s']*1e3:9.2f}ms  mem {r['memory_s']*1e3:9.2f}ms  "
            f"coll {r['collective_s']*1e3:9.2f}ms  dom={r['dominant']:10s} "
            f"useful={r['useful_flops_ratio']:.3f} roofline={r['roofline_fraction']:.3f}")
    return "\n".join(rows)


def main(argv=None):
    import argparse

    from repro.configs import ARCH_NAMES, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override key=value (hillclimb lever)")
    ap.add_argument("--summary", action="store_true")
    ap.add_argument("--recompute", action="store_true",
                    help="rewrite derived terms from stored costs (no compiles)")
    args = ap.parse_args(argv)

    if args.recompute:
        recompute_terms()
        print(summarize())
        return
    if args.summary:
        print(summarize())
        return

    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v)
        if isinstance(overrides[k], str):
            try:
                overrides[k] = int(v)
            except ValueError:
                pass

    archs = ARCH_NAMES if args.all or not args.arch else [args.arch]
    shapes = sorted(SHAPES) if args.all or not args.shape else [args.shape]
    for arch in archs:
        for shape in shapes:
            r = run_cell(arch, shape, args.variant, overrides or None,
                         force=args.force)
            if r["status"] == "ok":
                print(f"[ok]   {arch} × {shape} × {args.variant}: "
                      f"dom={r['dominant']} comp={r['compute_s']*1e3:.1f}ms "
                      f"mem={r['memory_s']*1e3:.1f}ms coll={r['collective_s']*1e3:.1f}ms "
                      f"roofline={r['roofline_fraction']:.3f}", flush=True)
            elif r["status"] == "skipped":
                print(f"[skip] {arch} × {shape}: {r['reason']}", flush=True)
            else:
                print(f"[FAIL] {arch} × {shape}: {r['error']}", flush=True)


if __name__ == "__main__":
    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    main()
