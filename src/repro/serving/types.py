"""Request/result types and SLO-aware tolerance routing (DESIGN.md §11).

The deadline→tolerance contract: a request carries ``deadline_ms`` — the
latency SLO its client bought — and the service maps that deadline onto
the loosest solver tolerance the deadline's class admits.  Because
``rtol`` is a *traced* scalar in every adaptive sampler (DESIGN.md §10),
the whole deadline spectrum is served by ONE compiled program per bucket;
routing is pure Python over the class table, never a recompile.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Optional

#: Seed for bucket-padding rows (padding output is discarded; the rows are
#: provably invisible to real rows — tests/test_serving.py).
PAD_SEED = 0x5EED_0DD


@dataclasses.dataclass
class Request:
    """One client ask: ``size`` trajectories (or terminal samples) keyed
    off ``seed``.

    ``deadline_ms``: the latency SLO — it picks the request's deadline
    class, which drives the served tolerance for adaptive terminal
    sampling (:func:`route_rtol`) and, under ``Scheduler(preempt=True)``,
    whether the request counts as realtime pressure (tightest class) or
    yields under it (loosest class).  Admission itself stays arrival-
    order — deliberately not earliest-deadline-first, which starves the
    relaxed class.  ``math.inf`` means "no SLO" (batch class).

    ``model_id``: which registry entry serves this request (multi-model
    serving; ``"default"`` matches a single-entry bundle and every
    upgraded v1 bundle).

    ``rtol``: optional *explicit* accuracy ask for adaptive terminal
    sampling.  ``None`` (the default) lets the deadline class choose; an
    explicit value acts as an accuracy **floor** — the batch never runs
    looser than the tightest explicit ask it contains.

    ``kind``: ``"rollout"`` (chunked trajectory, the continuous-batching
    path) or ``"terminal"`` (adaptive terminal sample at a routed
    tolerance).
    """

    rid: int
    size: int
    seed: int
    rtol: Optional[float] = None
    deadline_ms: float = math.inf
    model_id: str = "default"
    kind: str = "rollout"

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"request {self.rid}: size must be >= 1, "
                             f"got {self.size}")
        if self.kind not in ("rollout", "terminal"):
            raise ValueError(f"request {self.rid}: kind must be 'rollout' "
                             f"or 'terminal', got {self.kind!r}")
        if self.rtol is not None and self.rtol <= 0:
            raise ValueError(f"request {self.rid}: rtol must be positive, "
                             f"got {self.rtol}")


@dataclasses.dataclass
class ServeResult:
    """What the service hands back for one :class:`Request`.

    ``converged`` is a per-row bool array (length ``size``): for adaptive
    terminal sampling, ``False`` marks rows whose controller exhausted its
    step budget before ``t1`` — the sample is the state at ``t_final <
    t1``, and callers can now distinguish those rows structurally instead
    of parsing the serve loop's warning log.  Fixed-grid rollouts are
    always fully converged.

    ``rtol`` is the tolerance the batch actually ran at (the routed one —
    possibly looser than a fixed-tolerance service would have picked,
    never looser than the request's explicit ask).  ``samples`` carries
    the payload when the caller asked the scheduler to collect it
    (``(num_steps+1, size, data_dim)`` trajectories for rollouts,
    ``(size, data_dim)`` for terminal samples), else ``None`` —
    load-generator runs skip the host round-trip.
    """

    rid: int
    model_id: str
    size: int
    converged: Any
    latency_s: float
    deadline_ms: float = math.inf
    rtol: Optional[float] = None
    samples: Any = None

    @property
    def deadline_met(self) -> bool:
        """True when the observed latency landed inside the request's
        ``deadline_ms`` SLO (always True for the no-SLO batch class)."""
        return self.latency_s * 1e3 <= self.deadline_ms

    @property
    def num_converged(self) -> int:
        """How many of the result's rows converged (== ``size`` for
        fixed-grid rollouts; adaptive terminal rows may fall short when
        the controller exhausts its step budget)."""
        import numpy as np

        return int(np.sum(np.asarray(self.converged)))


@dataclasses.dataclass(frozen=True)
class DeadlineClass:
    """One SLO tier: requests with ``deadline_ms <= max_deadline_ms``
    (and above the previous tier's bound) belong to it, and ``rtol`` is
    the loosest tolerance the tier's accuracy SLO admits."""

    name: str
    max_deadline_ms: float
    rtol: float


#: The default SLO ladder, tightest deadline first.  A tighter deadline
#: admits a LOOSER tolerance (the client traded accuracy for latency);
#: an unbounded deadline gets the service's most accurate tier.  The
#: table is ordered and contiguous: class i covers
#: (classes[i-1].max_deadline_ms, classes[i].max_deadline_ms].
DEADLINE_CLASSES = (
    DeadlineClass("realtime", 50.0, 1e-2),
    DeadlineClass("interactive", 250.0, 3e-3),
    DeadlineClass("standard", 1000.0, 1e-3),
    DeadlineClass("relaxed", math.inf, 3e-4),
)


def deadline_class_for(deadline_ms: float,
                       classes=DEADLINE_CLASSES) -> DeadlineClass:
    """Map a deadline onto its SLO tier (the first class that covers it)."""
    for c in classes:
        if deadline_ms <= c.max_deadline_ms:
            return c
    return classes[-1]


def route_rtol(batch, classes=DEADLINE_CLASSES) -> float:
    """The tolerance one coalesced batch runs at (DESIGN.md §11).

    The rule: **the loosest rtol the batch's tightest deadline allows** —
    the tightest deadline picks the SLO tier, and the tier's rtol is
    served.  This replaces the PR 5 tightest-ask rule (min over per-
    request rtols), which made one accuracy-hungry request slow every
    deadline-bound request sharing its batch.  Explicit per-request
    ``rtol`` asks survive as accuracy floors: the batch never runs looser
    than the tightest explicit ask.  Because the scheduler coalesces
    within a deadline class, mixing is already minimal — this function is
    the single place the mapping lives.
    """
    if not batch:
        raise ValueError("route_rtol needs a non-empty batch")
    rtol = deadline_class_for(min(r.deadline_ms for r in batch), classes).rtol
    explicit = [r.rtol for r in batch if r.rtol is not None]
    if explicit:
        rtol = min(rtol, *explicit)
    return rtol


def synthetic_requests(n: int, max_size: int, seed: int,
                       adaptive: bool = False, model_id: str = "default"):
    """Deterministic request stream (sizes cycle ``1..max_size``, seeds
    unique).  With ``adaptive`` the stream becomes terminal-sampling
    requests cycling through every deadline class (so one drain exercises
    the whole routing table); otherwise rollout requests with unbounded
    deadlines (the PR 4-compatible stream)."""
    reqs = collections.deque()
    for i in range(n):
        kw = {}
        if adaptive:
            cls = DEADLINE_CLASSES[i % len(DEADLINE_CLASSES)]
            dl = cls.max_deadline_ms if math.isfinite(cls.max_deadline_ms) \
                else 10 * DEADLINE_CLASSES[-2].max_deadline_ms
            kw = dict(kind="terminal", deadline_ms=dl)
        reqs.append(Request(rid=i, size=1 + (i * 7 + seed) % max_size,
                            seed=seed * 100_003 + i, model_id=model_id, **kw))
    return reqs


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile of a non-empty sample (the repo's serving
    latency convention since PR 4)."""
    xs = sorted(xs)
    idx = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
    return xs[idx]
