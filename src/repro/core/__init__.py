"""The paper's primary contributions as composable JAX modules."""

from .adjoint import continuous_adjoint_solve, reversible_heun_solve  # noqa: F401
from .brownian import (  # noqa: F401
    BrownianPath,
    DenseBrownianPath,
    VirtualBrownianTree,
    brownian_increments,
    davie_levy_area,
    space_time_levy_area,
    stlevy_difference,
)
from .brownian_interval import BrownianInterval, HostVirtualBrownianTree  # noqa: F401
from .clipping import clip_lipschitz, clip_linear, clip_mlp, lipschitz_bound_mlp  # noqa: F401
from .losses import signature, signature_mmd, time_augment, wasserstein_losses  # noqa: F401
from .paths import LinearPathControl  # noqa: F401
from .gradients import (  # noqa: F401
    GRADIENT_BACKENDS,
    GradientBackend,
    PrecisionPolicy,
    checkpoint_schedule,
    register_backend,
    resolve_precision,
)
from .solve import (  # noqa: F401
    GRADIENT_MODES,
    SOLVERS,
    SolverSpec,
    available_solvers,
    get_solver,
    gradient_capabilities,
    register_solver,
    solve,
    solve_batched,
)
from .solvers import (  # noqa: F401
    NFE_PER_STEP,
    RevHeunState,
    ode_solve,
    reversible_heun_reverse_step,
    reversible_heun_step,
    sde_solve,
)
