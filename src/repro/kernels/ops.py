"""Dispatching wrappers: Pallas kernel on TPU, jnp reference elsewhere.

Policy: on a TPU backend the compiled kernels run natively; on CPU/GPU the
pure-jnp oracle runs (fast + lets XLA fuse).  ``use_kernel=True`` forces the
Pallas path with ``interpret=True`` off-TPU — this is what the kernel tests
exercise.  Setting ``REPRO_FORCE_PALLAS_INTERPRET=1`` in the environment
flips the default (``use_kernel=None``) to the forced path too — CI's
kernel-parity job uses it to sweep the whole differential suite through the
Pallas interpreter without touching call sites.  The dry-run/roofline path
uses the reference implementations so `cost_analysis()` reflects the XLA
graph (see DESIGN.md §5).
"""

from __future__ import annotations

import os
from typing import Optional

import jax

from . import brownian as _bk
from . import flash_attention as _fa
from . import fused_mlp as _fm
from . import prng
from . import ref
from . import reversible_heun_step as _rh
from . import ssd_chunk as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _decide(use_kernel: Optional[bool]):
    """-> (run_kernel, interpret)."""
    if use_kernel is None:
        use_kernel = (_on_tpu()
                      or bool(os.environ.get("REPRO_FORCE_PALLAS_INTERPRET")))
    return use_kernel, not _on_tpu()


def flash_attention(q, k, v, causal=True, scale=None, block_q=128, block_k=128,
                    use_kernel: Optional[bool] = None):
    run, interp = _decide(use_kernel)
    if run:
        return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                                   block_q=block_q, block_k=block_k, interpret=interp)
    return ref.flash_attention(q, k, v, causal=causal, scale=scale)


def fused_mlp(x, w1, b1, w2, b2, use_kernel: Optional[bool] = None):
    run, interp = _decide(use_kernel)
    if run:
        return _fm.fused_mlp(x, w1, b1, w2, b2, interpret=interp)
    return ref.fused_mlp(x, w1, b1, w2, b2)


def ssd_chunk(x, a, b, c, chunk=64, use_kernel: Optional[bool] = None):
    run, interp = _decide(use_kernel)
    if run:
        return _ssd.ssd_chunk(x, a, b, c, chunk=chunk, interpret=interp)
    return ref.ssd_scan(x, a, b, c)


def rev_heun_phase1(z, zh, mu, sigma, dw, dt, sign: float = 1.0,
                    use_kernel: Optional[bool] = None):
    run, interp = _decide(use_kernel)
    if run:
        return _rh.rev_heun_phase1(z, zh, mu, sigma, dw, dt, sign=sign,
                                   interpret=interp)
    return ref.rev_heun_phase1(z, zh, mu, sigma, dw, dt, sign)


def rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt, sign: float = 1.0,
                    use_kernel: Optional[bool] = None):
    run, interp = _decide(use_kernel)
    if run:
        return _rh.rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt,
                                   sign=sign, interpret=interp)
    return ref.rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt, sign)


def rev_heun_bwd_phase1(g_z1, g_mu1, g_sig1, dw, dt,
                        use_kernel: Optional[bool] = None):
    """Backward pre-field cotangents ``(c_mu1, c_sig1)`` — fused adjoint."""
    run, interp = _decide(use_kernel)
    if run:
        return _rh.rev_heun_bwd_phase1(g_z1, g_mu1, g_sig1, dw, dt,
                                       interpret=interp)
    return ref.rev_heun_bwd_phase1(g_z1, g_mu1, g_sig1, dw, dt)


def rev_heun_bwd_phase2(g_z1, ghat, dw, dt, use_kernel: Optional[bool] = None):
    """Backward post-field cotangents ``(d_z, d_zh, d_mu, d_sigma)``."""
    run, interp = _decide(use_kernel)
    if run:
        return _rh.rev_heun_bwd_phase2(g_z1, ghat, dw, dt, interpret=interp)
    return ref.rev_heun_bwd_phase2(g_z1, ghat, dw, dt)


def rev_heun_phase1_gen(z, zh, mu, sigma, key, n, dt_grid, dt, sign=1.0,
                        use_kernel: Optional[bool] = None):
    """Phase 1 with in-kernel ΔW generation — ``(ẑ_{n+1}, ΔW_n)``."""
    run, interp = _decide(use_kernel)
    k1, k2 = prng.key_data_pair(key)
    if run:
        return _bk.rev_heun_phase1_gen(z, zh, mu, sigma, k1, k2, n, dt_grid,
                                       dt, sign=sign, interpret=interp)
    dw = ref.brownian_increment(k1, k2, n, z.shape, z.dtype, dt_grid)
    return ref.rev_heun_phase1(z, zh, mu, sigma, dw, dt, sign), dw


def brownian_increment(key, n, shape, dtype, dt,
                       use_kernel: Optional[bool] = None):
    """Step-``n`` uniform-grid increment, counter-keyed on ``n``."""
    run, interp = _decide(use_kernel)
    k1, k2 = prng.key_data_pair(key)
    if run:
        return _bk.brownian_increment(k1, k2, n, tuple(shape), dtype, dt,
                                      interpret=interp)
    return ref.brownian_increment(k1, k2, n, tuple(shape), dtype, dt)


def brownian_value(key, t, t0, t1, shape, dtype, depth: int = 24,
                   use_kernel: Optional[bool] = None):
    """``W(t) − W(t0)`` via single-kernel Lévy-bridge descent."""
    run, interp = _decide(use_kernel)
    k1, k2 = prng.key_data_pair(key)
    if run:
        return _bk.brownian_value(k1, k2, t, float(t0), float(t1),
                                  tuple(shape), dtype, depth=depth,
                                  interpret=interp)
    return ref.brownian_value(k1, k2, t, t0, t1, tuple(shape), dtype,
                              depth=depth)


def fused_xent(logits, labels, use_kernel: Optional[bool] = None):
    from . import xent as _xent

    run, interp = _decide(use_kernel)
    if run:
        return _xent.fused_xent(logits, labels, interpret=interp)
    return ref.fused_xent(logits, labels)


# =============================================================================
# Precision-policy casts (the solve stack's bf16-compute / f32-state policy)
# =============================================================================
#
# These live in the dispatch layer because the compute dtype is a dispatch
# decision of the same kind as kernel-vs-oracle: the canonical cast the
# whole solve stack shares (repro.core.gradients.resolve_precision builds
# on it), so a future low-precision kernel path changes one place.


def cast_to_compute(tree, compute_dtype):
    """Cast every inexact-float leaf of ``tree`` to ``compute_dtype``.

    Integer leaves (PRNG keys, counters) pass through untouched.
    """
    import jax.numpy as jnp

    def cast(x):
        x = jnp.asarray(x)
        if jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(compute_dtype)
        return x

    return jax.tree.map(cast, tree)


def wrap_vector_field(field, compute_dtype):
    """``(params, t, z) -> f`` evaluated in ``compute_dtype``, output cast
    back to the state dtype.

    The casts are linear, so under AD the parameter/state cotangents are
    up-cast on the way out — gradient *accumulation* (adjoint sums, scan
    carries, optimiser updates) stays in the state dtype; only the field
    arithmetic itself runs low-precision.  ``t`` is left in its own dtype:
    time resolution must not degrade with the compute policy.
    """
    import jax.numpy as jnp

    def wrapped(params, t, z):
        z = jnp.asarray(z)
        out = field(cast_to_compute(params, compute_dtype), t,
                    z.astype(compute_dtype))
        return jnp.asarray(out).astype(z.dtype)

    return wrapped
