"""minicpm3-4b [dense] — Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B; hf]

62L d_model=2560 40H (kv=40 — MLA shares a compressed latent across heads)
d_ff=6400 vocab=73448.  MLA ranks follow the HF config: q_lora 768,
kv_lora 256, qk_nope 64, qk_rope 32, v_head 64.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    head_dim=96,  # nope + rope
    ffn="swiglu",
    norm="rmsnorm",
)
