"""Discretise-then-optimise: JAX AD straight through the solver scan.

The reference gradient path (§2.3): residuals are the scan's O(n)
activations and the backward rule is whatever ``jax.vjp`` derives.  Every
registered stepper serves it — the spec's stepper is dispatched into
``sde_solve``'s scan.  Adaptive solves run forward-only under this mode
(``lax.while_loop`` has no reverse-mode rule; use ``reversible_adjoint``
or ``checkpoint`` for adaptive gradients).
"""

from __future__ import annotations

from ..solvers import sde_solve
from .base import GradientBackend, register_backend


def _validate(spec, *, noise, save_trajectory, use_pallas, adaptive):
    if use_pallas:
        raise ValueError(
            "use_pallas_kernels is incompatible with gradient_mode="
            "'discretise': the fused kernels' derivative is the "
            "hand-derived backward kernel pair registered through the "
            "reversible-adjoint custom_vjp, not a pallas_call VJP rule "
            "plain AD could trace.  Use gradient_mode="
            "'reversible_adjoint' instead — its forward pass is the "
            "identical fused scan (so this also covers pure forward "
            "simulation), and differentiating it runs the fused exact "
            "adjoint")


def _solve(spec, drift, diffusion, params, z0, bm, t0, t1, num_steps, *,
           noise, save_trajectory, use_pallas):
    return sde_solve(
        drift, diffusion, params, z0, bm, t0, t1, num_steps,
        solver=spec.name, noise=noise, save_trajectory=save_trajectory,
        use_pallas_kernels=use_pallas,
        # registry-registered steppers (z-carried) dispatch through here;
        # "reversible_heun" keeps sde_solve's carried-state fast path.
        step_fn=None if spec.name == "reversible_heun" else spec.stepper)


def _solve_adaptive(spec, drift, diffusion, params, z0, bm, rtol, atol,
                    t0, t1, max_steps, dt0, *, noise, use_pallas,
                    bridge_depth):
    # late import: the adaptive driver lives in the front-end module, which
    # imports this package at load time; by call time it is loaded
    from ..solve import _adaptive_loop
    from ..solvers import reversible_heun_step

    carry, stats = _adaptive_loop(
        spec, drift, diffusion, params, z0, bm, t0, t1, rtol, atol,
        max_steps, dt0, noise, use_pallas=use_pallas,
        bridge_depth=bridge_depth)
    z = carry.z if spec.stepper is reversible_heun_step else carry
    return z, stats.converged


register_backend(GradientBackend(
    name="discretise",
    summary="AD through the scan, O(n) activation memory",
    terminal_only=False,
    supports_adaptive=True,
    solve=_solve,
    solve_adaptive=_solve_adaptive,
    validate=_validate,
))
