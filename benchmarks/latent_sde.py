"""Latent-SDE (VAE) benchmark suite: the fused diagonal-noise training step
and exact-adjoint vs backsolve gradient error on the ELBO.

Two axes:

1. **Fused vs unfused ELBO step** — one full training step of
   ``repro.launch.steps.make_latent_sde_step`` (encoder GRU + posterior
   solve + exact-adjoint backward + Adam update) with and without
   ``use_pallas_kernels``.  This is the workload the fused reversible-Heun
   kernels were built for: diagonal noise under the exact adjoint, so the
   forward scan *and* the backward's closed-form reconstruction run fused.
   Wall-clock rows are reported for existence; the **gated** comparison
   (``fused_speedup``) is the XLA cost-model bytes-accessed ratio, which is
   deterministic where wall clock on shared CI runners is not (DESIGN.md
   §7: magnitude gates must reflect strictly-less work).  Fusion never
   *adds* memory traffic: on TPU the kernels collapse the per-step HBM
   round-trips (ratio > 1); on CPU/GPU the fused path dispatches to the
   identical jnp oracle (DESIGN.md §5), so the ratio is exactly 1.0 —
   ≥ 1× everywhere, by construction rather than by timing luck.

2. **Exact adjoint vs backsolve** (paper Fig. 2, on the ELBO): relative L1
   gradient error of each adjoint against its own discretise-then-optimise
   reference (same solver, same Brownian sample, float64) on the
   terminal-form ELBO (``latent_sde_loss_terminal`` — the only form the
   backsolve baseline can differentiate at all; see DESIGN.md §8).  The
   reversible-Heun exact adjoint must match to floating-point error; the
   Li et al. continuous adjoint carries O(√h) truncation error.  Gate:
   ``exact < 1e-8`` and ``exact < backsolve`` at every step count.

Run:  PYTHONPATH=src python benchmarks/latent_sde.py --preset tiny
Emits BENCH_latent_sde.json (schema in benchmarks/report.py).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

try:
    from . import report
    from .gradient_error import relative_l1
except ImportError:  # run as a loose script: python benchmarks/latent_sde.py
    import report
    from gradient_error import relative_l1

# step-timing shapes: seq_len (=> T = seq_len-1), solver steps (a multiple
# of T), batch, hidden/context width, timing reps
PRESET_SHAPES = {
    "tiny":  dict(seq_len=9, num_steps=16, batch=16, hidden=8, width=16, reps=6),
    "quick": dict(seq_len=24, num_steps=46, batch=32, hidden=16, width=32, reps=8),
    "full":  dict(seq_len=24, num_steps=92, batch=128, hidden=16, width=32, reps=15),
}

# gradient-error solver steps (all multiples of T = 8)
PRESET_GRAD_STEPS = {
    "tiny": [8, 32],
    "quick": [8, 32, 128],
    "full": [8, 32, 128, 512],
}


def _build_step(fused: bool, seq_len: int, num_steps: int, batch: int,
                hidden: int, width: int):
    from repro.core.sde import LatentSDEConfig, latent_sde_init
    from repro.launch.steps import make_latent_sde_optimizer, make_latent_sde_step

    cfg = LatentSDEConfig(data_dim=2, hidden_dim=hidden, context_dim=hidden,
                          width=width, num_steps=num_steps, kl_weight=0.1,
                          use_pallas_kernels=fused)
    key = jax.random.PRNGKey(0)
    params = latent_sde_init(key, cfg)
    oi, ou = make_latent_sde_optimizer()
    step = jax.jit(make_latent_sde_step(cfg, ou, batch, seq_len))
    return step, params, oi(params), jax.random.fold_in(key, 1)


def _bytes_accessed(jitted_step, *args) -> float:
    """XLA cost-model bytes for one compiled step (the deterministic axis
    of the fused-vs-unfused comparison).  ``cost_analysis`` returns a dict
    or a one-element list of dicts depending on the jax version."""
    cost = jitted_step.lower(*args).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    b = float((cost or {}).get("bytes accessed", 0.0))
    if b <= 0.0:
        raise RuntimeError(
            "XLA cost_analysis reported no bytes-accessed figure on this "
            "backend; the fused-vs-unfused gate needs the cost model")
    return b


def bench_fused_vs_unfused(seq_len: int, num_steps: int, batch: int,
                           hidden: int, width: int, reps: int):
    """Interleaved best-of-``reps`` wall clock + cost-model bytes for the
    fused and unfused ELBO steps.  Interleaving keeps both programs under
    the same machine conditions; the min is robust to scheduler noise."""
    steps = {}
    for fused in (False, True):
        steps[fused] = _build_step(fused, seq_len, num_steps, batch, hidden,
                                   width)
    # warm both (compile + one run) before any timing
    for fused, (step, params, state, k) in steps.items():
        jax.block_until_ready(step(params, state, k))
        jax.block_until_ready(step(params, state, k))
    best = {False: float("inf"), True: float("inf")}
    for _ in range(reps):
        for fused, (step, params, state, k) in steps.items():
            t0 = time.perf_counter()
            jax.block_until_ready(step(params, state, k))
            best[fused] = min(best[fused], time.perf_counter() - t0)
    bytes_ = {fused: _bytes_accessed(step, params, state, k)
              for fused, (step, params, state, k) in steps.items()}
    return best, bytes_


def grad_error_rows(preset: str):
    """Exact-adjoint and backsolve gradient error on the terminal ELBO,
    each against its own same-solver discretise reference (float64)."""
    from repro.core.sde import (LatentSDEConfig, latent_sde_init,
                                latent_sde_loss_terminal)
    from repro.data.synthetic import air_quality_like

    key = jax.random.PRNGKey(7)
    seq_len, batch = 9, 8
    ys, _ = air_quality_like(jax.random.fold_in(key, 1), batch, seq_len,
                             dtype=jnp.float64)
    rows = []
    for num_steps in PRESET_GRAD_STEPS[preset]:
        errs = {}
        for label, solver, adjoint_mode in (
                ("exact_adjoint", "reversible_heun", "reversible_adjoint"),
                ("backsolve", "midpoint", "continuous_adjoint")):
            cfg = LatentSDEConfig(data_dim=2, hidden_dim=8, context_dim=8,
                                  width=16, num_steps=num_steps, solver=solver,
                                  kl_weight=0.1, dtype=jnp.float64)
            params = latent_sde_init(jax.random.fold_in(key, 2), cfg)

            def loss(p, mode, cfg=cfg):
                out, _ = latent_sde_loss_terminal(
                    p, cfg, jax.random.fold_in(key, 3), ys,
                    gradient_mode=mode)
                return out

            g_ref = jax.grad(lambda p: loss(p, "discretise"))(params)
            g_adj = jax.grad(lambda p: loss(p, adjoint_mode))(params)
            err = relative_l1(g_adj, g_ref)
            errs[label] = err
            rows.append(("latent_sde_grad",
                         f"{label},steps={num_steps}", err))
            print(f"latent_sde_grad,{label},steps={num_steps},{err:.3e}",
                  flush=True)
        # the paper's claim: the exact adjoint is FP-exact where the
        # backsolve baseline carries O(√h) truncation error
        assert errs["exact_adjoint"] < 1e-8, errs
        assert errs["exact_adjoint"] < errs["backsolve"], errs
    return rows


def main(preset: str = "full"):
    shape = dict(PRESET_SHAPES[preset])
    reps = shape.pop("reps")
    rows = []

    best, bytes_ = bench_fused_vs_unfused(reps=reps, **shape)
    for fused in (False, True):
        label = "fused" if fused else "unfused"
        rows.append(("latent_sde", f"{label}_step_ms", best[fused] * 1e3))
        rows.append(("latent_sde", f"{label}_bytes_accessed", bytes_[fused]))
        print(f"latent_sde,{label},{best[fused]*1e3:.2f}ms,"
              f"bytes={bytes_[fused]:.3e}", flush=True)
    wallclock = best[False] / best[True]
    speedup = bytes_[False] / bytes_[True]
    rows.append(("latent_sde", "fused_wallclock_speedup", wallclock))
    rows.append(("latent_sde", "fused_speedup", speedup))
    backend = jax.default_backend()
    print(f"latent_sde,fused_speedup,{speedup:.3f}x (cost-model bytes; "
          f"wallclock {wallclock:.2f}x"
          f"{', oracle-dispatch parity on ' + backend if backend != 'tpu' else ''})",
          flush=True)
    # the gate: fusion never adds traffic — ratio 1.0 on non-TPU backends
    # (fused path IS the jnp oracle there), > 1.0 where the kernels compile
    assert speedup >= 1.0 - 1e-9, (
        f"fused step accessed MORE bytes than unfused "
        f"({bytes_[True]:.3e} vs {bytes_[False]:.3e})")

    jax.config.update("jax_enable_x64", True)
    try:
        rows.extend(grad_error_rows(preset))
    finally:
        jax.config.update("jax_enable_x64", False)
    return rows


if __name__ == "__main__":
    report.standalone("latent_sde", main)
