"""End-to-end LM training driver on a ~20M-param tinyllama-family config.

Exercises the full production stack on CPU: config system -> model zoo ->
train_step (AdamW + cosine + grad clip) -> deterministic data pipeline ->
atomic checkpointing -> auto-resume.  The same code path scales to the
256/512-chip meshes via launch/dryrun.py (AOT-verified) and launch/train.py.

Run:  PYTHONPATH=src python examples/lm_train.py --steps 200
"""

import argparse
import dataclasses
import time

import jax

from repro.launch.train import train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args(argv)

    # a mid-size member of the tinyllama family (~20M params): real vocab,
    # reduced width/depth — the same ArchConfig schema as the full 1.1B.
    t0 = time.time()
    import repro.configs.tinyllama_1_1b as tl

    cfg = dataclasses.replace(
        tl.CONFIG, num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        head_dim=32, d_ff=704, dtype=jax.numpy.float32,
        scan_layers=False, remat=False)

    # train() resolves configs by name; monkey-patch a local registry entry
    from repro import configs as cfgmod

    cfgmod.REGISTRY["tinyllama-mid"] = cfg
    params, losses = train(
        arch="tinyllama-mid", steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, smoke=False, seed=0,
        peak_lr=1e-3)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[lm_train] {n/1e6:.1f}M params; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f} in {args.steps} steps ({time.time()-t0:.0f}s)")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
