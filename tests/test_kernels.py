"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Pallas kernels run in interpret mode on CPU (the kernel body executes in
Python) — correctness validation for the TPU target.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_mlp import fused_mlp
from repro.kernels.reversible_heun_step import rev_heun_phase1, rev_heun_phase2
from repro.kernels.ssd_chunk import ssd_chunk

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       # bf16 has ~8 mantissa bits; kernel vs oracle accumulation order
       # differs, so per-element deviations up to a few % are expected.
       jnp.bfloat16: dict(rtol=6e-2, atol=6e-2)}


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA 4:1
    (1, 4, 1, 128, 128),     # MQA
    (2, 4, 4, 64, 32),       # small S < block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(key, B, Hq, Hkv, S, D, causal):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(kk, (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(kv, (B, Hkv, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL[jnp.float32])


def test_flash_attention_bf16(key):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(kk, (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(kv, (1, 2, 128, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[jnp.bfloat16])


def test_blockwise_attention_matches_oracle(key):
    """The XLA (dry-run) attention path: scan and unrolled variants."""
    from repro.models.layers import blockwise_attention

    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 8, 256, 32), jnp.float32)
    k = jax.random.normal(kk, (2, 2, 256, 32), jnp.float32)
    v = jax.random.normal(kv, (2, 2, 256, 32), jnp.float32)
    want = ref.flash_attention(q, k, v, causal=True)
    for impl in ("scan", "unrolled"):
        out = blockwise_attention(q, k, v, causal=True, bq=64, bk=64, impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", [(64, 32), (128, 67), (4, 8, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mlp_matches_oracle(key, shape, dtype):
    din, h, dout = shape[-1], 48, 24
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], shape, dtype)
    w1 = jax.random.normal(ks[1], (din, h), dtype) * 0.3
    b1 = jax.random.normal(ks[2], (h,), dtype) * 0.1
    w2 = jax.random.normal(ks[3], (h, dout), dtype) * 0.3
    b2 = jax.random.normal(ks[4], (dout,), dtype) * 0.1
    out = fused_mlp(x, w1, b1, w2, b2, interpret=True)
    want = ref.fused_mlp(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("B,H,S,P,N,chunk", [
    (1, 2, 128, 64, 32, 64),
    (2, 4, 256, 32, 16, 128),
    (1, 1, 64, 64, 64, 64),
])
def test_ssd_chunk_matches_sequential_oracle(key, B, H, S, P, N, chunk):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, H, S, P), jnp.float32)
    a = -jnp.abs(jax.random.normal(ks[1], (B, H, S), jnp.float32)) * 0.1
    b = jax.random.normal(ks[2], (B, H, S, N), jnp.float32) * 0.5
    c = jax.random.normal(ks[3], (B, H, S, N), jnp.float32) * 0.5
    out = ssd_chunk(x, a, b, c, chunk=chunk, interpret=True)
    want = ref.ssd_scan(x, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_dense_matches_oracle(key):
    """The XLA associative-scan SSD path (models/layers.py) + final state."""
    from repro.models.layers import ssd_chunked_dense

    B, H, S, P, N = 2, 2, 128, 32, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, H, S, P), jnp.float32)
    a = -jnp.abs(jax.random.normal(ks[1], (B, H, S), jnp.float32)) * 0.1
    b = jax.random.normal(ks[2], (B, H, S, N), jnp.float32) * 0.5
    c = jax.random.normal(ks[3], (B, H, S, N), jnp.float32) * 0.5
    out, h_final = ssd_chunked_dense(x, a, b, c, chunk=32)
    want = ref.ssd_scan(x, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)
    # final state must match a sequential recurrence's terminal state
    def seq_final(xh, ah, bh, ch):
        h = jnp.zeros((N, P))
        for t in range(S):
            h = jnp.exp(ah[t]) * h + bh[t][:, None] * xh[t][None, :]
        return h
    want_h = seq_final(x[0, 0], a[0, 0], b[0, 0], c[0, 0])
    np.testing.assert_allclose(np.asarray(h_final[0, 0]), np.asarray(want_h),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(32, 64), (8, 16, 32), (128,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rev_heun_kernels_match_oracle(key, shape, dtype):
    ks = jax.random.split(key, 6)
    args = [jax.random.normal(k, shape, dtype) for k in ks]
    dt = 0.125
    out1 = rev_heun_phase1(*args[:5], dt, interpret=True)
    want1 = ref.rev_heun_phase1(*args[:5], dt)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(want1, np.float32), **TOL[dtype])
    out2 = rev_heun_phase2(*args, dt, interpret=True)
    want2 = ref.rev_heun_phase2(*args, dt)
    np.testing.assert_allclose(np.asarray(out2, np.float32),
                               np.asarray(want2, np.float32), **TOL[dtype])


def test_ops_dispatch_cpu(key):
    """ops.py picks the jnp reference on CPU and the kernel when forced."""
    from repro.kernels import ops

    x = jax.random.normal(key, (16, 8))
    w1 = jnp.eye(8, 12)
    b1 = jnp.zeros(12)
    w2 = jnp.eye(12, 8)
    b2 = jnp.zeros(8)
    a = ops.fused_mlp(x, w1, b1, w2, b2)                 # ref path
    b = ops.fused_mlp(x, w1, b1, w2, b2, use_kernel=True)  # pallas interpret
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("R,V,br,bv", [
    (64, 1024, 32, 256),
    (128, 512, 256, 2048),   # blocks larger than dims -> clamped
    (32, 1000, 8, 125),      # non-power-of-two vocab
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_xent_matches_oracle(key, R, V, br, bv, dtype):
    from repro.kernels.xent import fused_xent

    kl, kj = jax.random.split(key)
    logits = jax.random.normal(kl, (R, V), dtype) * 3.0
    labels = jax.random.randint(kj, (R,), 0, V)
    out = fused_xent(logits, labels, block_rows=br, block_vocab=bv, interpret=True)
    want = ref.fused_xent(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5 if dtype == jnp.float32 else 3e-2,
                               atol=1e-5 if dtype == jnp.float32 else 3e-2)


def test_fused_xent_equals_model_loss(key):
    """The kernel's mean equals models.transformer.softmax_xent."""
    from repro.kernels.xent import fused_xent
    from repro.models.transformer import softmax_xent

    logits = jax.random.normal(key, (4, 16, 256), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (4, 16), 0, 256)
    a = float(jnp.mean(fused_xent(logits, labels, interpret=True)))
    b = float(softmax_xent(logits, labels))
    np.testing.assert_allclose(a, b, rtol=1e-6)
