"""repro — 'Efficient and Accurate Gradients for Neural SDEs' as a
production-grade multi-pod JAX framework.

Paper contributions (repro.core):
  * reversible Heun solver + O(1)-memory exact adjoint
  * Brownian Interval (host reference) / BrownianPath (TPU-native)
  * SDE-GAN training via Lipschitz clipping + LipSwish

Framework substrates: repro.nn, repro.models (10-arch zoo), repro.optim,
repro.data, repro.distributed, repro.checkpoint, repro.kernels (Pallas),
repro.launch (mesh / dryrun / train / serve).
"""

__version__ = "1.0.0"
