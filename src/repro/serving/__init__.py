"""repro.serving — the public Neural-SDE serving API (DESIGN.md §9/§11).

The production serving surface the ``launch/serve.py`` CLI is a thin
wrapper over:

- :class:`Request` / :class:`ServeResult` — the wire types.  A request
  carries ``deadline_ms`` (its latency SLO), ``model_id`` (which registry
  entry serves it) and an optional explicit ``rtol`` accuracy floor; a
  result carries per-row ``converged`` so budget-exhausted adaptive rows
  are distinguishable structurally, never only via the warning log.
- :class:`ModelRegistry` / :class:`LoadedModel` / :func:`load_model` —
  N named checkpoints hot-loaded in one process from ``repro-serving/v2``
  bundles (v1 bundles upgrade transparently), with AOT compile pools
  keyed ``(model_id, kind, bucket)``.
- :class:`Scheduler` — the continuous-batching scheduler: chunked
  rollouts advance through one compiled chunk program per bucket
  (per-row traced ``t_start``), new requests join in-flight batches at
  chunk boundaries (arrival order), and adaptive terminal
  batches run at the deadline-routed tolerance (:func:`route_rtol`).
  PR 10 adds per-model admission quotas and cross-lane preemption
  (``preempt=True`` — relaxed rows yield at chunk boundaries under
  realtime pressure, bitwise-invisibly; DESIGN.md §14).
- :class:`AsyncFrontend` — asyncio ingestion in front of one scheduler:
  ``await submit(request)`` queues, the engine drains between scheduler
  iterations (= chunk boundaries), ``serve_tcp`` adds a JSON-lines TCP
  loopback; the compiled hot loop runs on a single executor thread so
  the event loop never blocks on device work.
- :func:`serve_sde` — the batteries-included service driver (restore,
  mesh, buckets, drain loops) behind the CLI.

Quickstart::

    import repro.serving as serving

    registry = serving.ModelRegistry()
    registry.load("/path/to/ckpt")          # every bundle entry, by name
    sched = serving.Scheduler(registry, max_batch=16, chunks=4)
    sched.submit(serving.Request(rid=0, size=4, seed=123,
                                 deadline_ms=250.0))
    results = sched.run()                    # -> [ServeResult]

The private helpers PR 4/5 grew inside launch/serve.py — ``_coalesce``,
``_compile_pool``, ``_batch_loop``, ``_percentile`` — live behind this
package now with stable names (imported below).
"""

from .frontend import (  # noqa: F401
    AsyncFrontend,
    request_from_wire,
    result_summary,
)
from .registry import (  # noqa: F401
    LoadedModel,
    ModelRegistry,
    load_model,
    restore_for_serving,
)
from .scheduler import (  # noqa: F401
    Scheduler,
    class_latency_summary,
    latency_summary,
    run_open_loop,
    serve_buckets,
)
from .service import (  # noqa: F401
    _adaptive_terminal_loop,
    _batch_loop,
    _coalesce,
    _compile_pool,
    _percentile,
    _request_keys,
    _stream_loop,
    serve_sde,
)
from .types import (  # noqa: F401
    DEADLINE_CLASSES,
    DeadlineClass,
    Request,
    ServeResult,
    deadline_class_for,
    percentile,
    route_rtol,
    synthetic_requests,
)

__all__ = [
    "AsyncFrontend",
    "DEADLINE_CLASSES",
    "DeadlineClass",
    "LoadedModel",
    "ModelRegistry",
    "Request",
    "Scheduler",
    "ServeResult",
    "class_latency_summary",
    "deadline_class_for",
    "latency_summary",
    "load_model",
    "percentile",
    "request_from_wire",
    "restore_for_serving",
    "result_summary",
    "route_rtol",
    "run_open_loop",
    "serve_buckets",
    "serve_sde",
    "synthetic_requests",
]
