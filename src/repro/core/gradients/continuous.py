"""Continuous adjoint (optimise-then-discretise) baseline — eq. (6).

The backsolve of Li et al. 2020: the backward pass re-integrates the state
backwards in time alongside the adjoint SDE.  The recomputed ``z`` differs
from the forward pass by the solver truncation error, so gradients carry
O(√h) error — the failure mode the paper eliminates, kept here as the
measured baseline (benchmarks/gradient_error.py charts it).

Moved verbatim from ``repro.core.adjoint`` when the gradient layer became
backend-structured; only the registry glue at the bottom is new.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..brownian import BrownianPath
from ..solvers import apply_diffusion
from .base import GradientBackend, register_backend

#: Solvers the continuous-adjoint backward integrator actually implements
#: a time-reversed stepper for.  A registered solver outside this set
#: would silently fall back to backward Euler — reject instead.
_CONTINUOUS_ADJOINT_BACKWARDS = ("euler_maruyama", "midpoint", "heun")


def continuous_adjoint_solve(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    t0: float,
    t1: float,
    num_steps: int,
    solver: str = "midpoint",
    noise: str = "diagonal",
):
    """Terminal value ``z_T`` whose VJP solves the adjoint SDE (6) backwards.

    The backward pass re-integrates ``z`` *backwards in time with the same
    solver and the same Brownian sample* while integrating the adjoint
    ``a_t = dL/dz_t`` and parameter adjoint.  The recomputed ``z`` differs
    from the forward pass by the truncation error — the gradient error the
    paper measures in Fig. 2 / Table 6.
    """

    @jax.custom_vjp
    def solve(params, z0):
        from ..solvers import sde_solve

        return sde_solve(
            drift, diffusion, params, z0, bm, t0, t1, num_steps,
            solver=solver, noise=noise, save_trajectory=False,
        )

    def fwd(params, z0):
        zT = solve(params, z0)
        return zT, (params, zT)

    def bwd(residuals, g_zT):
        params, zT = residuals
        dt = (t1 - t0) / num_steps
        dtype = zT.dtype
        g_params0 = jax.tree.map(jnp.zeros_like, params)

        # Augmented backward dynamics.  State: (z, a, g_params).
        #   dz      =  μ dt + σ∘dW                     (re-integrated, backwards)
        #   da      = -aᵀ ∂μ/∂z dt - aᵀ ∂σ/∂z ∘ dW     (eq. (6))
        #   dθ_adj  = -aᵀ ∂μ/∂θ dt - aᵀ ∂σ/∂θ ∘ dW
        # Implemented as drift/"diffusion·dW" of the augmented system so that
        # any two-evaluation Stratonovich solver below can integrate it.
        def aug_drift(t, aug):
            z, a, _ = aug
            mu, vjp = jax.vjp(lambda p, z_: drift(p, t, z_), params, z)
            d_theta, d_z = vjp(a)
            return (mu, jax.tree.map(jnp.negative, d_z), jax.tree.map(jnp.negative, d_theta))

        def aug_diff_dw(t, aug, dw):
            z, a, _ = aug
            sdw, vjp = jax.vjp(
                lambda p, z_: apply_diffusion(diffusion(p, t, z_), dw, noise), params, z
            )
            d_theta, d_z = vjp(a)
            return (sdw, jax.tree.map(jnp.negative, d_z), jax.tree.map(jnp.negative, d_theta))

        def add(u, v, scale=1.0):
            return jax.tree.map(lambda x, y: x + scale * y, u, v)

        def step_back(aug, n):
            # integrate from t_{n+1} down to t_n: effective dt is -dt, dW is
            # -dW_n (time reversal of the Stratonovich integral).
            t_hi = t0 + (n + 1) * dt
            dw = bm.increment(n, num_steps).astype(dtype)
            ndt, ndw = -dt, -dw
            if solver == "midpoint":
                k1 = add(add(aug, aug_drift(t_hi, aug), 0.5 * ndt),
                         aug_diff_dw(t_hi, aug, 0.5 * ndw))
                tm = t_hi + 0.5 * ndt
                new = add(add(aug, aug_drift(tm, k1), ndt), aug_diff_dw(tm, k1, ndw))
            elif solver == "heun":
                f0 = aug_drift(t_hi, aug)
                s0 = aug_diff_dw(t_hi, aug, ndw)
                pred = add(add(aug, f0, ndt), s0)
                t_lo = t_hi + ndt
                f1 = aug_drift(t_lo, pred)
                s1 = aug_diff_dw(t_lo, pred, ndw)
                new = add(add(add(add(aug, f0, 0.5 * ndt), f1, 0.5 * ndt),
                              s0, 0.5), s1, 0.5)
            else:  # euler_maruyama backwards (for completeness)
                new = add(add(aug, aug_drift(t_hi, aug), ndt), aug_diff_dw(t_hi, aug, ndw))
            return new, None

        aug0 = (zT, g_zT, g_params0)
        (z_rec, a0, g_params), _ = lax.scan(step_back, aug0, jnp.arange(num_steps - 1, -1, -1))
        del z_rec  # reconstructed z0 — differs from true z0 by truncation error
        return (g_params, a0)

    solve.defvjp(fwd, bwd)
    return solve(params, z0)


# =============================================================================
# Backend registration
# =============================================================================


def _validate(spec, *, noise, save_trajectory, use_pallas, adaptive):
    if spec.name not in _CONTINUOUS_ADJOINT_BACKWARDS:
        raise ValueError(
            f"solver {spec.name!r} declares continuous_adjoint but the "
            f"continuous-adjoint backward integrator only implements "
            f"{_CONTINUOUS_ADJOINT_BACKWARDS} (repro.core.gradients."
            f"continuous); extend continuous_adjoint_solve before "
            f"registering this combination")
    if save_trajectory:
        raise ValueError(
            "continuous_adjoint backpropagates a terminal-value cotangent "
            "only — call solve(..., save_trajectory=False)")
    if adaptive:
        raise ValueError(
            "adaptive=True is incompatible with gradient_mode="
            "'continuous_adjoint': the eq.-(6) backward integrator "
            "re-integrates on the forward's fixed uniform grid; use "
            "'reversible_adjoint' (exact adjoint replaying the accepted "
            "grid), 'checkpoint' (recursive rematerialisation of the "
            "accepted grid), or 'discretise' (forward simulation only)")


def _solve(spec, drift, diffusion, params, z0, bm, t0, t1, num_steps, *,
           noise, save_trajectory, use_pallas):
    return continuous_adjoint_solve(
        drift, diffusion, params, z0, bm, t0, t1, num_steps,
        solver=spec.name, noise=noise)


register_backend(GradientBackend(
    name="continuous_adjoint",
    summary="optimise-then-discretise backsolve (eq. 6), O(√h) gradient error",
    terminal_only=True,
    supports_adaptive=False,
    solve=_solve,
    validate=_validate,
))
