"""SDE solvers (paper §3) as `lax.scan` steppers.

All Stratonovich solvers share the calling convention::

    drift(params, t, z)      -> dz/dt                    (shape of z)
    diffusion(params, t, z)  -> sigma                    (diagonal: shape of z;
                                                          general: (*z.shape, w))

and consume a :class:`repro.core.brownian.BrownianPath` so that the forward
and backward passes see bit-identical noise without storing it.

Solver inventory (paper §3 "Computational efficiency"):

=================  ============  =====================  ====================
solver             SDE type      drift+diffusion evals  notes
=================  ============  =====================  ====================
euler_maruyama     Itô           1 / step               order 0.5 baseline
midpoint           Stratonovich  2 / step               paper's main baseline
heun               Stratonovich  2 / step               trapezoidal
reversible_heun    Stratonovich  **1 / step**           algebraically
                                                        reversible (paper §3)
srk                Itô           5 / step               strong order **1.5**;
                                                        consumes (ΔW, ΔH)
                                                        space–time Lévy pairs
=================  ============  =====================  ====================

`reversible_heun` here is the *plain scan* version: differentiating through
it with standard JAX AD gives discretise-then-optimise gradients (and O(N)
activation memory).  The O(1)-memory exact adjoint lives in
:mod:`repro.core.adjoint`.

The reversible-Heun hot loop optionally runs through the fused Pallas
kernels (:mod:`repro.kernels.reversible_heun_step`) via
``use_pallas=True`` — see the kernel module docstring for the contract
(diagonal noise; ``dt`` may be traced, so this includes the adaptive
driver; plain AD must not trace through the fused ops — gradients use the
hand-derived backward kernels via :mod:`repro.core.adjoint`).  Callers
should normally go through the :func:`repro.core.solve.solve` front-end,
which validates the flag against the solver registry.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .brownian import BrownianPath

Drift = Callable  # (params, t, z) -> z-shaped
Diffusion = Callable  # (params, t, z) -> z-shaped (diagonal) or (*z, w) (general)

#: drift+diffusion evaluations per step, per solver (paper's NFE accounting).
NFE_PER_STEP = {
    "euler_maruyama": 1,
    "midpoint": 2,
    "heun": 2,
    "reversible_heun": 1,
    "srk": 5,
}


def _tree_cast(x, dtype):
    """``astype`` over a pytree — identical to ``x.astype`` for plain arrays.

    The Brownian layer returns a bare ``ΔW`` array in ``levy_area=None`` mode
    and a ``(ΔW, ΔH)`` pair in ``levy_area="space-time"`` mode; every dw
    consumer casts through this so both shapes flow.
    """
    return jax.tree.map(lambda a: a.astype(dtype), x)


def apply_diffusion(sigma: jax.Array, dw: jax.Array, noise: str) -> jax.Array:
    """``sigma · dW`` for diagonal or general (matrix) noise."""
    if noise == "diagonal":
        return sigma * dw
    if noise == "general":
        return jnp.einsum("...ij,...j->...i", sigma, dw)
    raise ValueError(f"unknown noise type: {noise}")


def dw_shape(z_shape, w_dim: Optional[int], noise: str):
    if noise == "diagonal":
        return tuple(z_shape)
    return tuple(z_shape[:-1]) + (w_dim,)


def _pallas_dispatch(interpret: Optional[bool]) -> tuple:
    """Resolve the fused-step implementation -> ``(run_kernel, interpret)``.

    The kernels/ops.py policy (DESIGN.md §5), applied to the solver hot
    loop: on TPU the compiled Pallas kernels run natively; on CPU/GPU the
    fused pure-jnp oracle (:mod:`repro.kernels.ref`) runs instead — same
    math, and XLA fuses it, so ``use_pallas_kernels=True`` never *slows* a
    non-TPU backend down the way always-interpret mode did.  Passing
    ``interpret=True`` explicitly forces the Pallas interpreter off-TPU —
    that is the kernel-equivalence code path the tests pin.
    """
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        return on_tpu, False
    return True, interpret and not on_tpu


class RevHeunState(NamedTuple):
    """Carried state of the reversible Heun method (Algorithm 1)."""

    z: jax.Array
    zh: jax.Array  # ẑ — the auxiliary (midpoint-propagated) track
    mu: jax.Array
    sigma: jax.Array


def reversible_heun_step(state: RevHeunState, t, dt, dw, drift, diffusion, params, noise,
                         use_pallas: bool = False, interpret: Optional[bool] = None,
                         gen=None):
    """One step of Algorithm 1.  Exactly one drift+diffusion evaluation.

    With ``use_pallas=True`` (diagonal noise) the two elementwise state
    updates run as fused Pallas kernels; ``dt`` may be a traced scalar (the
    kernels take it as a scalar operand), so the adaptive driver's
    controller-chosen step sizes work fused too.  AD must not trace through
    this path — gradients go through the hand-derived backward kernels via
    :mod:`repro.core.adjoint`.

    ``gen=(key, n, dt_grid)`` generates this step's ``ΔW`` *inside* the
    phase-1 kernel (counter-based Threefry keyed on ``n``, bitwise
    ``BrownianPath.increment(n)`` with grid spacing ``dt_grid``) instead of
    consuming ``dw`` — the fixed-grid time loop then never leaves the fused
    path between noise generation and state update.  ``dw`` is ignored
    when ``gen`` is given.
    """
    z, zh, mu, sigma = state
    if use_pallas and noise == "diagonal":
        run_kernel, interp = _pallas_dispatch(interpret)
        from ..kernels import ops

        use_kernel = True if run_kernel and interp else (run_kernel or None)
        if gen is not None:
            key, n, dt_grid = gen
            zh1, dw = ops.rev_heun_phase1_gen(z, zh, mu, sigma, key, n,
                                              dt_grid, dt,
                                              use_kernel=use_kernel)
        else:
            zh1 = ops.rev_heun_phase1(z, zh, mu, sigma, dw, dt,
                                      use_kernel=use_kernel)
        mu1 = drift(params, t + dt, zh1)
        sigma1 = diffusion(params, t + dt, zh1)
        z1 = ops.rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt,
                                 use_kernel=use_kernel)
        return RevHeunState(z1, zh1, mu1, sigma1)
    zh1 = 2.0 * z - zh + mu * dt + apply_diffusion(sigma, dw, noise)
    mu1 = drift(params, t + dt, zh1)
    sigma1 = diffusion(params, t + dt, zh1)
    z1 = z + 0.5 * (mu + mu1) * dt + apply_diffusion(0.5 * (sigma + sigma1), dw, noise)
    return RevHeunState(z1, zh1, mu1, sigma1)


def reversible_heun_reverse_step(state: RevHeunState, t1, dt, dw, drift, diffusion, params, noise,
                                 use_pallas: bool = False, interpret: Optional[bool] = None):
    """Algebraic inverse of :func:`reversible_heun_step` (Algorithm 2, reverse).

    Reconstructs ``(z_n, ẑ_n, μ_n, σ_n)`` from ``(z_{n+1}, ẑ_{n+1}, μ_{n+1},
    σ_{n+1})`` in closed form — the paper's key property.  ``use_pallas``
    runs the same fused kernels with ``sign=-1`` (backward reconstruction).
    """
    z1, zh1, mu1, sigma1 = state
    if use_pallas and noise == "diagonal":
        run_kernel, interp = _pallas_dispatch(interpret)
        from ..kernels import ops

        use_kernel = True if run_kernel and interp else (run_kernel or None)
        zh = ops.rev_heun_phase1(z1, zh1, mu1, sigma1, dw, dt, sign=-1.0,
                                 use_kernel=use_kernel)
        mu = drift(params, t1 - dt, zh)
        sigma = diffusion(params, t1 - dt, zh)
        z = ops.rev_heun_phase2(z1, mu, mu1, sigma, sigma1, dw, dt, sign=-1.0,
                                use_kernel=use_kernel)
        return RevHeunState(z, zh, mu, sigma)
    zh = 2.0 * z1 - zh1 - mu1 * dt - apply_diffusion(sigma1, dw, noise)
    mu = drift(params, t1 - dt, zh)
    sigma = diffusion(params, t1 - dt, zh)
    z = z1 - 0.5 * (mu + mu1) * dt - apply_diffusion(0.5 * (sigma + sigma1), dw, noise)
    return RevHeunState(z, zh, mu, sigma)


# -----------------------------------------------------------------------------
# Embedded error estimates (adaptive stepping; DESIGN.md §10)
# -----------------------------------------------------------------------------
#
# Uniform interface: ``(carry, t, dt, dw, drift, diffusion, params, noise)
# -> (carry_new, err)`` where ``err`` is an elementwise local-error estimate
# with the shape of ``z``.  None of these cost extra vector-field
# evaluations over the plain stepper:
#
# * reversible Heun: the gap ``z − ẑ`` between the two carried tracks is
#   *free* — but it alternates sign and persists across steps
#   (δ_{n+1} = −δ_n + ½Δμ·dt + ½Δσ·dW), so the raw gap measures the
#   accumulated track distance, not this step's error.  The *increment*
#   of the gap, ``δ_{n+1} + δ_n = ½(μ(ẑ₁)−μ(ẑ₀))dt + ½(σ(ẑ₁)−σ(ẑ₀))dW``,
#   is the genuine local quantity (→ 0 as dt → 0) and costs nothing;
# * heun: the Euler predictor ``z + μ₀dt + σ₀dW`` is the embedded
#   lower-order solution; the corrector − predictor gap estimates the
#   error;
# * midpoint: same Euler pair, reusing the two evaluations the step
#   already makes.
#
# euler_maruyama has no second solution to compare against — it carries no
# embedded pair and the front-end rejects ``adaptive=True`` for it eagerly.


def reversible_heun_embedded_step(state: RevHeunState, t, dt, dw, drift, diffusion,
                                  params, noise, use_pallas: bool = False,
                                  interpret: Optional[bool] = None):
    new = reversible_heun_step(state, t, dt, dw, drift, diffusion, params, noise,
                               use_pallas=use_pallas, interpret=interpret)
    return new, (new.z - new.zh) + (state.z - state.zh)


def _heun_embedded_step(z, t, dt, dw, drift, diffusion, params, noise):
    mu0 = drift(params, t, z)
    s0 = diffusion(params, t, z)
    zp = z + mu0 * dt + apply_diffusion(s0, dw, noise)  # Euler (embedded)
    mu1 = drift(params, t + dt, zp)
    s1 = diffusion(params, t + dt, zp)
    z1 = z + 0.5 * (mu0 + mu1) * dt + apply_diffusion(0.5 * (s0 + s1), dw, noise)
    return z1, z1 - zp


def _midpoint_embedded_step(z, t, dt, dw, drift, diffusion, params, noise):
    mu0 = drift(params, t, z)
    s0 = diffusion(params, t, z)
    euler = mu0 * dt + apply_diffusion(s0, dw, noise)
    half = z + 0.5 * euler
    tm = t + 0.5 * dt
    z1 = z + drift(params, tm, half) * dt + apply_diffusion(
        diffusion(params, tm, half), dw, noise)
    return z1, z1 - (z + euler)


def _srk_embedded_step(z, t, dt, dw, drift, diffusion, params, noise):
    """Strong-order-1.5 explicit SRK step (Kloeden–Platen, Itô, diagonal noise).

    ``dw`` must be the ``(ΔW, ΔH)`` pair from a ``levy_area="space-time"``
    Brownian path: the I_{(1,0)} = ∫∫ dW ds iterated integral that separates
    order 1.5 from order 1.0 is ``dt·(H + ΔW/2)`` and cannot be recovered
    from ``ΔW`` alone.  The scheme is the explicit strong order-1.5 method of
    Kloeden & Platen (1992, §11.2) specialised to diagonal noise, with every
    supporting value evaluated at ``t+dt`` so non-autonomous fields pick up
    the L⁰-operator time derivatives:

        Υ± = z + a·dt ± b·√dt          Φ± = Υ₊ ± b(Υ₊)·√dt

        z₁ = z + ¼(a(Υ₊) + 2a + a(Υ₋))dt + b·ΔW
               + (b(Υ₊) − b(Υ₋))/(2√dt) · I₍₁,₁₎
               + (a(Υ₊) − a(Υ₋))/(2√dt) · I₍₁,₀₎
               + (b(Υ₊) − 2b + b(Υ₋))/(2dt) · I₍₀,₁₎
               + (b(Φ₊) − b(Φ₋) − b(Υ₊) + b(Υ₋))/(2dt) · I₍₁,₁,₁₎

    with I₍₁,₁₎ = (ΔW²−dt)/2, I₍₁,₀₎ = dt(H + ΔW/2), I₍₀,₁₎ = ΔW·dt −
    I₍₁,₀₎, I₍₁,₁,₁₎ = (ΔW³ − 3dt·ΔW)/6.  Strong order 1.5 requires the
    diffusion to be strictly diagonal (∂bᵢ/∂zⱼ = 0 for i≠j) — same
    restriction as torchsde's ``srk``; for additive noise the scheme keeps
    order 1.5 with the I₍₁,₀₎ drift-area term doing the work.

    The embedded estimate is the Euler–Maruyama step from the stage-1
    evaluations — zero extra NFE, the same pattern as heun/midpoint.

    ``dt == 0`` (the adaptive checkpoint replay's padding slots) is guarded
    with a ``where``-substituted divisor so no inf·0 NaN enters the forward
    values or their VJP.
    """
    if not isinstance(dw, (tuple, list)):
        raise TypeError(
            "solver 'srk' needs (dW, dH) pairs — construct the Brownian path "
            "with levy_area='space-time'")
    if noise != "diagonal":
        raise ValueError(
            "solver 'srk' supports diagonal noise only (general noise needs "
            "full Lévy areas, which space-time H does not provide)")
    w, h = dw
    dt_safe = jnp.where(dt == 0, jnp.ones_like(dt), dt)
    sq = jnp.sqrt(dt_safe)

    a0 = drift(params, t, z)
    b0 = diffusion(params, t, z)
    up = z + a0 * dt + b0 * sq
    um = z + a0 * dt - b0 * sq
    t1 = t + dt
    ap = drift(params, t1, up)
    am = drift(params, t1, um)
    bp = diffusion(params, t1, up)
    bm_ = diffusion(params, t1, um)
    pp = up + bp * sq
    pm = up - bp * sq
    bpp = diffusion(params, t1, pp)
    bpm = diffusion(params, t1, pm)

    i10 = dt * (h + 0.5 * w)           # I_{(1,0)} = ∫ (W_s − W_t) ds
    i01 = w * dt - i10                 # I_{(0,1)} = ∫ s dW
    i11 = 0.5 * (w * w - dt)           # I_{(1,1)}
    i111 = (w * w * w - 3.0 * dt * w) / 6.0

    z1 = (z
          + 0.25 * (ap + 2.0 * a0 + am) * dt
          + b0 * w
          + (bp - bm_) * (0.5 / sq) * i11
          + (ap - am) * (0.5 / sq) * i10
          + (bp - 2.0 * b0 + bm_) * (0.5 / dt_safe) * i01
          + (bpp - bpm - bp + bm_) * (0.5 / dt_safe) * i111)
    return z1, z1 - (z + a0 * dt + b0 * w)


def _srk_step(z, t, dt, dw, drift, diffusion, params, noise):
    return _srk_embedded_step(z, t, dt, dw, drift, diffusion, params, noise)[0]


def _euler_maruyama_step(z, t, dt, dw, drift, diffusion, params, noise):
    return z + drift(params, t, z) * dt + apply_diffusion(diffusion(params, t, z), dw, noise)


def _midpoint_step(z, t, dt, dw, drift, diffusion, params, noise):
    # the fixed-grid stepper IS the embedded pair minus the error output
    # (XLA dead-code-eliminates the unused estimate) — one scheme, not two
    return _midpoint_embedded_step(z, t, dt, dw, drift, diffusion, params, noise)[0]


def _heun_step(z, t, dt, dw, drift, diffusion, params, noise):
    return _heun_embedded_step(z, t, dt, dw, drift, diffusion, params, noise)[0]


def sde_solve(
    drift: Drift,
    diffusion: Diffusion,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    t0: float,
    t1: float,
    num_steps: int,
    solver: str = "reversible_heun",
    noise: str = "diagonal",
    save_trajectory: bool = True,
    use_pallas_kernels: bool = False,
    step_fn: Optional[Callable] = None,
):
    """Solve ``dZ = μ dt + σ ∘ dW`` from ``t0`` to ``t1`` in ``num_steps`` steps.

    Returns the trajectory ``(num_steps+1, *z0.shape)`` if ``save_trajectory``
    else the terminal value.  Differentiating through this function gives
    discretise-then-optimise gradients (O(N) memory).  For the paper's O(1)
    exact adjoint use :func:`repro.core.adjoint.reversible_heun_solve`.

    ``use_pallas_kernels`` fuses the reversible-Heun state updates
    (diagonal noise only).  The fused ops have no VJP rule, so this flag is
    for forward simulation; for fused *training* use the exact adjoint via
    :func:`repro.core.solve.solve` with ``gradient_mode="reversible_adjoint"``.
    """
    dt = (t1 - t0) / num_steps
    dtype = z0.dtype

    if solver == "reversible_heun":
        state0 = RevHeunState(z0, z0, drift(params, t0, z0), diffusion(params, t0, z0))

        def body(state, n):
            t = t0 + n * dt
            dw = _tree_cast(bm.increment(n, num_steps), dtype)
            new = reversible_heun_step(state, t, dt, dw, drift, diffusion, params, noise,
                                       use_pallas=use_pallas_kernels)
            return new, (new.z if save_trajectory else None)

        final, traj = lax.scan(body, state0, jnp.arange(num_steps))
        if save_trajectory:
            return jnp.concatenate([z0[None], traj], axis=0)
        return final.z

    # ``step_fn`` lets the registry (repro.core.solve) dispatch solvers this
    # module doesn't know about: any ``(z, t, dt, dw, drift, diffusion,
    # params, noise) -> z`` stepper that carries the state itself.
    step = step_fn or {
        "euler_maruyama": _euler_maruyama_step,
        "midpoint": _midpoint_step,
        "heun": _heun_step,
    }.get(solver)
    if step is None:
        raise ValueError(
            f"solver {solver!r} has no builtin stepper; pass step_fn= "
            f"(repro.core.solve does this from the registry)")

    def body(z, n):
        t = t0 + n * dt
        dw = _tree_cast(bm.increment(n, num_steps), dtype)
        z1 = step(z, t, dt, dw, drift, diffusion, params, noise)
        return z1, (z1 if save_trajectory else None)

    final, traj = lax.scan(body, z0, jnp.arange(num_steps))
    if save_trajectory:
        return jnp.concatenate([z0[None], traj], axis=0)
    return final


def ode_solve(f, params, z0, t0, t1, num_steps, solver="reversible_heun"):
    """Deterministic limit (σ=0) — used for the stability tests (App. D.5)."""
    zero_diff = lambda p, t, z: jnp.zeros_like(z)
    key = jax.random.PRNGKey(0)
    bm = BrownianPath(key, t0, t1, z0.shape, z0.dtype)
    return sde_solve(f, zero_diff, params, z0, bm, t0, t1, num_steps, solver=solver, noise="diagonal")
