"""Sharded, atomic, step-granular checkpointing.

Layout::

    <dir>/step_<N>/
        shard_<host>.npz     # one file per host process (host 0 here)
        MANIFEST.json        # written LAST -> commit marker

A checkpoint is valid iff its MANIFEST exists; a crash mid-write leaves no
manifest and the directory is ignored (and garbage-collected on the next
save).  ``restore_checkpoint`` finds the newest valid step — the auto-resume
path of launch/train.py.  Leaves are addressed by their pytree key-path so a
restore is robust to dict-ordering changes.

**Serving bundles** (DESIGN.md §9): training additionally persists a
params-only checkpoint under ``<dir>/serving/`` whose manifest carries the
``repro-serving/v1`` handshake — workload name + the model config needed to
rebuild the parameter template.  launch/serve.py restores *only* from a
bundle, so a training checkpoint saved under different flags or an older
code version dies with a named error instead of a silent shape mismatch.
"""

from __future__ import annotations

import dataclasses
import json
import shutil
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

SERVING_SCHEMA = "repro-serving/v1"
_SERVING_SUBDIR = "serving"


def _leaf_names(tree) -> Tuple[list, Any]:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(path) for path, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return names, (leaves, treedef)


def save_checkpoint(ckpt_dir, step: int, tree, host_id: int = 0,
                    keep: int = 3, meta: Optional[dict] = None) -> Path:
    """Atomically persist ``tree`` at ``step``; prunes to ``keep`` newest.

    ``meta``: optional JSON-safe dict stored in the manifest (the serving
    handshake rides here)."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:012d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:012d}"
    if tmp_dir.exists():
        shutil.rmtree(tmp_dir)
    tmp_dir.mkdir(parents=True)

    names, (leaves, _) = _leaf_names(tree)
    arrays = {n: np.asarray(l) for n, l in zip(names, leaves)}
    np.savez(tmp_dir / f"shard_{host_id}.npz", **arrays)
    manifest = {
        "step": step,
        "num_hosts": 1,
        "leaves": {n: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for n, a in arrays.items()},
    }
    if meta is not None:
        manifest["meta"] = meta
    (tmp_dir / "MANIFEST.json").write_text(json.dumps(manifest, indent=2))
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp_dir.rename(step_dir)  # atomic commit

    # prune: keep the newest `keep` valid checkpoints + drop stale tmp dirs
    valid = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "MANIFEST.json").exists())
    for d in valid[:-keep]:
        shutil.rmtree(d)
    for d in ckpt_dir.glob(".tmp_step_*"):
        shutil.rmtree(d)
    return step_dir


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    valid = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "MANIFEST.json").exists())
    if not valid:
        return None
    return int(valid[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir, like_tree, step: Optional[int] = None,
                       host_id: int = 0):
    """Restore into the structure (and dtypes) of ``like_tree``.

    Returns (tree, step).  Raises FileNotFoundError when nothing valid exists.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:012d}"
    manifest = json.loads((step_dir / "MANIFEST.json").read_text())
    data = np.load(step_dir / f"shard_{host_id}.npz")

    names, (leaves, treedef) = _leaf_names(like_tree)
    restored = []
    for n, like in zip(names, leaves):
        arr = data[n]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"checkpoint leaf {n}: shape {arr.shape} != {like.shape}")
        restored.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["step"]


# -----------------------------------------------------------------------------
# serving bundles (the train -> serve checkpoint handshake; DESIGN.md §9)
# -----------------------------------------------------------------------------


def _json_safe(v):
    """JSON-encode dataclass config values; dtype-likes become their name."""
    try:
        json.dumps(v)
        return v
    except TypeError:
        return np.dtype(v).name  # jnp.float32 & friends


def config_to_meta(cfg) -> dict:
    """Dataclass model config -> the JSON-safe dict stored in the bundle."""
    d = dataclasses.asdict(cfg) if dataclasses.is_dataclass(cfg) else dict(cfg)
    return {k: _json_safe(v) for k, v in d.items()}


def save_serving_bundle(ckpt_dir, step: int, params, workload: str,
                        cfg) -> Path:
    """Persist a params-only serving checkpoint under ``<ckpt_dir>/serving``.

    The manifest carries the handshake: schema tag, workload name, and the
    model config (so launch/serve.py can rebuild the parameter template and
    the sampler without the training flags)."""
    meta = {"schema": SERVING_SCHEMA, "workload": workload,
            "config": config_to_meta(cfg)}
    return save_checkpoint(Path(ckpt_dir) / _SERVING_SUBDIR, step, params,
                           meta=meta)


def load_serving_meta(ckpt_dir) -> Tuple[dict, int]:
    """Read the newest serving bundle's handshake -> ``(meta, step)``.

    Named errors for every way the handshake can be absent or stale —
    launch/serve.py surfaces these verbatim instead of a pytree-leaf
    mismatch deep inside restore."""
    sdir = Path(ckpt_dir) / _SERVING_SUBDIR
    step = latest_step(sdir)
    if step is None:
        raise FileNotFoundError(
            f"no serving bundle under {ckpt_dir} — launch/train.py writes "
            f"<ckpt-dir>/{_SERVING_SUBDIR}/ alongside training checkpoints "
            f"(this checkpoint predates the serving subsystem, or the path "
            f"is wrong); re-run training, or use launch/serve.py --smoke "
            f"for a fresh-init service")
    manifest = json.loads(
        (sdir / f"step_{step:012d}" / "MANIFEST.json").read_text())
    meta = manifest.get("meta") or {}
    if meta.get("schema") != SERVING_SCHEMA:
        raise ValueError(
            f"serving bundle under {ckpt_dir} has schema "
            f"{meta.get('schema')!r}, expected {SERVING_SCHEMA!r} — written "
            f"by an incompatible code version; re-run training")
    return meta, step


def restore_serving_bundle(ckpt_dir, like_tree, step: Optional[int] = None):
    """Restore the params-only serving tree into ``like_tree``'s structure."""
    return restore_checkpoint(Path(ckpt_dir) / _SERVING_SUBDIR, like_tree,
                              step=step)
