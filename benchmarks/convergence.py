"""Paper Appendix D.4 (Figs. 5/6): strong/weak convergence order.

Anharmonic oscillator  dy = sin(y) dt + dW  (additive noise), y0 = 1, T = 1.
Reversible Heun should show strong order ~1.0 and weak order ~2.0 in the
additive-noise setting (Theorems D.13-D.17), matching standard Heun.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from . import report
except ImportError:  # run as a loose script
    import report


def run(solver: str, num_steps: int, bm, y0):
    from repro.core.solvers import sde_solve

    drift = lambda p, t, y: jnp.sin(y)
    diffusion = lambda p, t, y: jnp.ones_like(y)
    coarse = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, num_steps,
                       solver=solver, save_trajectory=False)
    # fine reference on the SAME Brownian path (paper's protocol: "obtained
    # using the same Brownian sample paths", 10x finer)
    fine = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, bm.fine_steps,
                     solver="heun", save_trajectory=False)
    return np.asarray(coarse[..., 0]), np.asarray(fine[..., 0])


def empirical_orders(solver: str, n_paths: int = 20_000):
    from repro.core.brownian import DenseBrownianPath

    key = jax.random.PRNGKey(42)
    y0 = jnp.ones((n_paths, 1), jnp.float64)
    bm = DenseBrownianPath.sample(key, 0.0, 1.0, 640, (n_paths, 1), jnp.float64)
    hs, strong, weak1 = [], [], []
    for num_steps in (8, 16, 32, 64):
        c, f = run(solver, num_steps, bm, y0)
        hs.append(1.0 / num_steps)
        strong.append(np.mean(np.abs(c - f)))
        weak1.append(abs(np.mean(c) - np.mean(f)))
    fit = lambda errs: np.polyfit(np.log(hs), np.log(np.maximum(errs, 1e-16)), 1)[0]
    return fit(strong), fit(weak1)


PRESET_PATHS = {"tiny": 2_000, "quick": 5_000, "full": 50_000}


def main(preset: str = "full"):
    jax.config.update("jax_enable_x64", True)
    n_paths = PRESET_PATHS[preset]
    rows = []
    for solver in ("heun", "reversible_heun"):
        s_ord, w_ord = empirical_orders(solver, n_paths)
        rows.append(("convergence", f"{solver}_strong_order", s_ord))
        rows.append(("convergence", f"{solver}_weak_order", w_ord))
        print(f"convergence,{solver},strong_order={s_ord:.2f},"
              f"weak_order={w_ord:.2f}", flush=True)
    jax.config.update("jax_enable_x64", False)
    return rows


if __name__ == "__main__":
    report.standalone("convergence", main)
