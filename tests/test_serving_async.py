"""Async ingestion, preemption, quota, and elastic-pool tests
(DESIGN.md §14).

The PR 10 contracts: requests submitted over the asyncio frontend (queue
or TCP loopback) produce bitwise the trajectories a solo direct-step
scheduler produces; cross-lane preemption pauses relaxed-class rows at
chunk boundaries and resumes them bitwise-invisibly; per-model admission
quotas bound in-flight rows without ever dropping a request; LRU pool
eviction under a byte budget recompiles transparently and bitwise; and
bundle ``serving`` hints thread through ``LoadedModel.hints`` into the
scheduler's quota default.
"""

import asyncio
import json
import math

import jax
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.sde import NeuralSDEConfig, generator_init
from repro.serving import (AsyncFrontend, LoadedModel, ModelRegistry,
                           Request, Scheduler, class_latency_summary,
                           load_model, request_from_wire)

GAN_CFG = dict(data_dim=1, hidden_dim=8, noise_dim=4, width=16, num_steps=8)


def _registry(key, model_ids=("default",), **reg_kw):
    reg = ModelRegistry(**reg_kw)
    cfg = NeuralSDEConfig(**GAN_CFG)
    for i, mid in enumerate(model_ids):
        params = generator_init(jax.random.fold_in(key, i), cfg)
        reg.register(LoadedModel(mid, "sde-gan", cfg, params))
    return reg


def _solo_samples(reg, req, **sched_kw):
    """Oracle: the request's trajectories from a fresh direct-step
    scheduler serving nothing else."""
    sched = Scheduler(reg, max_batch=8, chunks=4, collect=True, **sched_kw)
    sched.submit(req)
    (res,) = sched.run()
    return res.samples


# -----------------------------------------------------------------------------
# asyncio frontend: queue ingestion, bitwise oracle, TCP loopback
# -----------------------------------------------------------------------------


def test_async_frontend_bitwise_equals_solo(key):
    """Concurrent submissions over the asyncio queue complete with bitwise
    the trajectories each request gets from a solo scheduler — the engine
    drains the queue only between steps, so async arrival IS chunk-
    boundary admission."""
    reg = _registry(key)
    reqs = [Request(rid=i, size=1 + i % 3, seed=100 + i) for i in range(5)]

    async def drive():
        front = AsyncFrontend(
            Scheduler(reg, max_batch=8, chunks=4, collect=True))
        await front.start()
        try:
            return await asyncio.gather(*(front.submit(r) for r in reqs))
        finally:
            await front.close()

    results = asyncio.run(drive())
    by_rid = {r.rid: r for r in results}
    assert sorted(by_rid) == [r.rid for r in reqs]
    for req in reqs:
        np.testing.assert_array_equal(
            by_rid[req.rid].samples,
            _solo_samples(reg, Request(rid=99, size=req.size,
                                       seed=req.seed)))


def test_async_frontend_named_errors(key):
    reg = _registry(key)

    async def unstarted():
        await AsyncFrontend(Scheduler(reg)).submit(
            Request(rid=0, size=1, seed=0))

    with pytest.raises(RuntimeError, match="start"):
        asyncio.run(unstarted())

    async def duplicate_rid():
        front = AsyncFrontend(Scheduler(reg, max_batch=4, chunks=4))
        await front.start()
        try:
            task = asyncio.ensure_future(
                front.submit(Request(rid=7, size=1, seed=0)))
            await asyncio.sleep(0)  # let the first submit register its rid
            with pytest.raises(ValueError, match="rid 7"):
                await front.submit(Request(rid=7, size=1, seed=1))
            await task
        finally:
            await front.close()

    asyncio.run(duplicate_rid())

    async def oversized():
        front = AsyncFrontend(Scheduler(reg, max_batch=2, chunks=4))
        await front.start()
        try:
            # scheduler-side rejection travels back through the future
            with pytest.raises(ValueError, match="exceeds the largest"):
                await front.submit(Request(rid=0, size=64, seed=0))
        finally:
            await front.close()

    asyncio.run(oversized())


def test_tcp_loopback_roundtrip(key):
    """The JSON-lines TCP surface serves real requests: summaries come
    back (no payloads on the wire), bad requests come back as error
    objects, and the socket closes cleanly."""
    reg = _registry(key)

    async def drive():
        front = AsyncFrontend(Scheduler(reg, max_batch=4, chunks=4))
        host, port = await front.serve_tcp()
        reader, writer = await asyncio.open_connection(host, port)
        lines = [
            {"rid": 0, "size": 2, "seed": 11, "deadline_ms": None},
            {"rid": 1, "size": 1, "seed": 12, "kind": "terminal",
             "deadline_ms": 250.0},
            {"rid": 2, "size": 1, "seed": 13, "bogus_field": 1},
        ]
        for obj in lines:
            writer.write(json.dumps(obj).encode() + b"\n")
        await writer.drain()
        replies = [json.loads(await reader.readline()) for _ in lines]
        writer.close()
        await writer.wait_closed()
        await front.close()
        return replies

    replies = asyncio.run(drive())
    by_rid = {r["rid"]: r for r in replies}
    assert by_rid[0]["size"] == 2 and by_rid[0]["deadline_met"] is True
    assert by_rid[0]["num_converged"] == 2
    assert "samples" not in by_rid[0]
    assert by_rid[1]["rtol"] is not None  # deadline-routed terminal batch
    assert "bogus_field" in by_rid[2]["error"]


def test_request_from_wire_contract():
    req = request_from_wire({"rid": 3, "size": 2, "seed": 5,
                             "deadline_ms": None})
    assert req.deadline_ms == math.inf
    with pytest.raises(ValueError, match="unknown request fields"):
        request_from_wire({"rid": 0, "size": 1, "seed": 0, "sizee": 1})
    with pytest.raises(ValueError, match="JSON object"):
        request_from_wire([1, 2, 3])


# -----------------------------------------------------------------------------
# cross-lane preemption: engages under realtime pressure, bitwise-invisible
# -----------------------------------------------------------------------------


def test_preemption_pauses_and_resumes_bitwise(key):
    """Under realtime pressure on lane "rt", lane "bulk"'s relaxed rows
    pause at a chunk boundary and later resume — and the preempted
    trajectories are bitwise the solo-scheduler ones."""
    reg = _registry(key, ("bulk", "rt"))
    sched = Scheduler(reg, max_batch=8, chunks=4, collect=True, preempt=True)
    bulk = Request(rid=0, size=3, seed=21, model_id="bulk")  # relaxed class
    sched.submit(bulk)
    assert sched.step() == []  # bulk in flight, one chunk deep

    # realtime terminal work lands on the OTHER lane -> bulk must yield
    sched.submit(Request(rid=1, size=1, seed=22, model_id="rt",
                         kind="terminal", deadline_ms=40.0))
    results = sched.step()
    assert [r.rid for r in results] == [1]  # realtime served this iteration
    assert sched.counters["preempted_rows"] == 3
    lane = sched._lanes["bulk"]
    assert len(lane.paused) == 3 and not lane.active

    results += sched.run()  # pressure gone -> bulk resumes and finishes
    assert sched.counters["resumed_rows"] == 3
    by_rid = {r.rid: r for r in results}
    np.testing.assert_array_equal(
        by_rid[0].samples,
        _solo_samples(reg, Request(rid=9, size=3, seed=21,
                                   model_id="bulk")))


def test_preemption_defers_relaxed_terminal_batches(key):
    """A non-urgent lane's relaxed-class terminal batch defers under
    pressure; deadline-bound classes on the same lane still serve."""
    reg = _registry(key, ("bulk", "rt"))
    sched = Scheduler(reg, max_batch=4, chunks=4, preempt=True)
    sched.submit(Request(rid=0, size=1, seed=1, model_id="bulk",
                         kind="terminal"))  # relaxed (deadline inf)
    sched.submit(Request(rid=1, size=1, seed=2, model_id="rt",
                         kind="terminal", deadline_ms=40.0))
    results = sched.step()
    # the rt batch ran; bulk's relaxed terminal deferred this iteration
    assert [r.rid for r in results] == [1]
    assert sched._lanes["bulk"].pending_term
    results += sched.run()
    assert sorted(r.rid for r in results) == [0, 1]


def test_no_preemption_without_flag(key):
    """preempt=False (the default): realtime work elsewhere never pauses
    another lane's rows — PR 7 behaviour is untouched."""
    reg = _registry(key, ("bulk", "rt"))
    sched = Scheduler(reg, max_batch=8, chunks=4)
    sched.submit(Request(rid=0, size=2, seed=5, model_id="bulk"))
    sched.step()
    sched.submit(Request(rid=1, size=1, seed=6, model_id="rt",
                         kind="terminal", deadline_ms=40.0))
    sched.run()
    assert sched.counters["preempted_rows"] == 0
    assert sched.counters["resumed_rows"] == 0


# -----------------------------------------------------------------------------
# per-model admission quotas
# -----------------------------------------------------------------------------


def test_quota_bounds_in_flight_rows(key):
    """A quota of 2 never lets the lane hold more than 2 in-flight rows,
    yet every request eventually serves (waits, never drops)."""
    reg = _registry(key)
    sched = Scheduler(reg, max_batch=8, chunks=4, quota=2)
    for i in range(4):
        sched.submit(Request(rid=i, size=1, seed=30 + i))
    seen_rids, max_in_flight = set(), 0
    while sched.busy:
        results = sched.step()
        lane = sched._lanes["default"]
        max_in_flight = max(max_in_flight,
                            len(lane.active) + len(lane.paused))
        seen_rids |= {r.rid for r in results}
    assert max_in_flight == 2
    assert seen_rids == {0, 1, 2, 3}


def test_quota_dict_is_per_model(key):
    reg = _registry(key, ("a", "b"))
    sched = Scheduler(reg, max_batch=8, chunks=4, quota={"a": 1})
    for i in range(2):
        sched.submit(Request(rid=i, size=1, seed=i, model_id="a"))
        sched.submit(Request(rid=10 + i, size=1, seed=i, model_id="b"))
    sched.step()
    assert len(sched._lanes["a"].active) == 1   # capped
    assert len(sched._lanes["b"].active) == 2   # unlimited
    sched.run()


def test_quota_named_errors(key):
    reg = _registry(key)
    with pytest.raises(TypeError, match="quota"):
        Scheduler(reg, quota="lots")
    with pytest.raises(ValueError, match="quota"):
        Scheduler(reg, max_batch=4, chunks=4, quota=0).submit(
            Request(rid=0, size=1, seed=0))


def test_bundle_serving_hints_thread_to_scheduler_quota(key, tmp_path):
    """A bundle's serving hints ({"quota": 1}) surface on
    LoadedModel.hints and become the lane's quota default; an explicit
    Scheduler(quota=...) wins over the hint."""
    cfg = NeuralSDEConfig(**GAN_CFG)
    params = generator_init(key, cfg)
    ckpt.save_serving_registry(tmp_path, 3,
                               {"default": (params, "sde-gan", cfg)},
                               serving_hints={"default": {"quota": 1}})
    model = load_model(tmp_path)
    assert model.hints == {"quota": 1}

    reg = ModelRegistry()
    reg.load(tmp_path)
    sched = Scheduler(reg, max_batch=8, chunks=4)
    sched.submit(Request(rid=0, size=1, seed=0))
    sched.submit(Request(rid=1, size=1, seed=1))
    sched.step()
    assert len(sched._lanes["default"].active) == 1  # hint quota engaged
    sched.run()

    override = Scheduler(reg, max_batch=8, chunks=4, quota=2)
    override.submit(Request(rid=0, size=1, seed=0))
    override.submit(Request(rid=1, size=1, seed=1))
    override.step()
    assert len(override._lanes["default"].active) == 2
    override.run()

    with pytest.raises(ValueError, match="serving_hints"):
        ckpt.save_serving_registry(tmp_path, 4,
                                   {"default": (params, "sde-gan", cfg)},
                                   serving_hints={"ghost": {"quota": 1}})


# -----------------------------------------------------------------------------
# elastic pools: LRU eviction under a byte budget, bitwise recompile
# -----------------------------------------------------------------------------


def test_pool_eviction_lru_and_bitwise_recompile(key):
    """With a budget sized so only ~one program fits, compiling a second
    evicts the coldest; re-serving through the evicted key recompiles and
    the result is bitwise the unbounded registry's."""
    free = _registry(key)
    req = Request(rid=0, size=1, seed=77)
    expect = _solo_samples(free, req)
    if free.pool_bytes() == 0:
        pytest.skip("backend reports no memory_analysis sizes — "
                    "budget can never trip (documented fail-open)")

    cfg = free.get("default").cfg
    # a budget below the init+chunk working set forces the pair to cycle
    # (a single program over the budget still serves — it is protected)
    reg = ModelRegistry(pool_budget_bytes=max(1,
                                              int(free.pool_bytes() * 0.75)))
    reg.register(LoadedModel("default", "sde-gan", cfg,
                             free.get("default").params))
    sched = Scheduler(reg, max_batch=2, chunks=4, collect=True)
    sched.submit(Request(rid=0, size=1, seed=77))
    sched.run()
    compiles_before = reg.compiles
    assert reg.evictions >= 1  # init/chunk programs cycled under budget
    assert reg.pool_bytes() <= reg.pool_budget_bytes or \
        len(reg.pool_keys()) == 1

    # the evicted program recompiles transparently and bitwise
    sched2 = Scheduler(reg, max_batch=2, chunks=4, collect=True)
    sched2.submit(Request(rid=1, size=1, seed=77))
    (res,) = sched2.run()
    assert reg.compiles > compiles_before  # a recompile actually happened
    np.testing.assert_array_equal(res.samples, expect)


def test_pool_budget_validation_and_accounting(key):
    with pytest.raises(ValueError, match="pool_budget_bytes"):
        ModelRegistry(pool_budget_bytes=0)
    reg = _registry(key)
    sched = Scheduler(reg, max_batch=2, chunks=4)
    sched.submit(Request(rid=0, size=1, seed=0))
    sched.run()
    assert reg.compiles == len(reg.pool_keys()) > 0
    assert reg.evictions == 0  # unbounded pool never evicts
    assert reg.pool_bytes() == reg.pool_bytes("default")
    reg.unload("default")
    assert reg.pool_bytes() == 0 and reg.pool_keys() == ()


# -----------------------------------------------------------------------------
# per-class latency summaries (the preemption gate's read surface)
# -----------------------------------------------------------------------------


def test_class_latency_summary_groups_by_class(key):
    sched = Scheduler(_registry(key), max_batch=4, chunks=4)
    sched.submit(Request(rid=0, size=1, seed=1, kind="terminal",
                         deadline_ms=40.0))
    sched.submit(Request(rid=1, size=1, seed=2))  # relaxed rollout
    summary = class_latency_summary(sched.run())
    assert set(summary) == {"realtime", "relaxed"}
    assert summary["realtime"]["requests"] == 1
    assert summary["relaxed"]["rows"] == 1
    for s in summary.values():
        assert {"p50_s", "p99_s", "deadline_misses"} <= set(s)
