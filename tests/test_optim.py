"""Optimizer + schedule property tests (hypothesis where it pays)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st

from repro import optim
from repro.core.clipping import clip_mlp


def test_adam_bias_correction_first_step(key):
    """After one step from zero state, Adam's update is -lr·sign-ish of g
    (bias correction makes m̂ = g exactly)."""
    oi, ou = optim.adam(lr=1e-2, eps=0.0)
    p = {"w": jax.random.normal(key, (16,))}
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (16,))}
    upd, _ = ou(g, oi(p), p)
    want = -1e-2 * np.sign(np.asarray(g["w"]))
    np.testing.assert_allclose(np.asarray(upd["w"]), want, rtol=1e-5)


def test_adam_moment_dtype_override(key):
    oi, _ = optim.adam(1e-3, moment_dtype="bfloat16")
    p = {"w": jnp.zeros((8,), jnp.bfloat16)}
    st_ = oi(p)
    assert st_.m["w"].dtype == jnp.bfloat16
    assert st_.v["w"].dtype == jnp.bfloat16


@given(st.floats(0.1, 10.0), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm_bound(max_norm, size):
    g = {"a": jnp.ones((size,)) * 3.0, "b": jnp.full((2,), -4.0)}
    clipped, gnorm = optim.clip_by_global_norm(g, max_norm)
    new_norm = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped))))
    assert new_norm <= max_norm * (1 + 1e-4) or new_norm <= float(gnorm) + 1e-4


def test_cosine_schedule_shape():
    lr = optim.cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 0.11      # end of warmup
    assert float(lr(jnp.int32(100))) >= 0.1 - 1e-6          # floor
    assert float(lr(jnp.int32(50))) < float(lr(jnp.int32(12)))  # decays


def test_swa_is_running_mean(key):
    ps = [{"w": jnp.full((3,), float(i))} for i in range(5)]
    avg = ps[0]
    for n, p in enumerate(ps[1:], start=1):
        avg = optim.swa_update(avg, p, n)
    np.testing.assert_allclose(np.asarray(avg["w"]), np.full(3, 2.0), rtol=1e-6)


@given(st.floats(0.5, 100.0))
@settings(max_examples=20, deadline=None)
def test_clipping_idempotent(scale):
    """clip(clip(W)) == clip(W) — projection property (paper §5)."""
    key = jax.random.PRNGKey(0)
    p = {"layers": [{"w": jax.random.normal(key, (8, 4)) * scale,
                     "b": jnp.ones((4,))}]}
    c1 = clip_mlp(p)
    c2 = clip_mlp(c1)
    np.testing.assert_array_equal(np.asarray(c1["layers"][0]["w"]),
                                  np.asarray(c2["layers"][0]["w"]))
    bound = 1.0 / 8
    assert float(jnp.max(jnp.abs(c1["layers"][0]["w"]))) <= bound + 1e-9


def test_adadelta_updates_move_params(key):
    oi, ou = optim.adadelta(lr=1.0)
    p = {"w": jax.random.normal(key, (8,))}
    g = {"w": jnp.ones((8,))}
    state = oi(p)
    upd, state = ou(g, state, p)
    assert float(jnp.max(jnp.abs(upd["w"]))) > 0.0
    assert np.all(np.asarray(upd["w"]) < 0)   # descent direction
