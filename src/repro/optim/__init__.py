from .optimizers import (  # noqa: F401
    adadelta,
    adam,
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    cosine_schedule,
    lipschitz_projection,
    swa_update,
)
from .compression import compress_int8, decompress_int8, ef_compress_update  # noqa: F401
