import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: AOT lower + compile of every (arch × shape × mesh) cell.
#
# Proves — without hardware — that the distribution config is coherent:
# sharding propagates, the collectives are supported, and the per-device
# memory fits.  The compiled artifact also feeds the roofline analysis
# (benchmarks/roofline.py) via ``cost_analysis`` + the collective-bytes parse.
#
# The XLA_FLAGS assignment is the VERY FIRST statement — before ANY other
# import — because jax locks the device count at first init.  Nothing else in
# the repo sets it (smoke tests and benches see the real single device).
#
# Usage::
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells x 2 meshes
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single

import argparse
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config
from ..configs.base import ArchConfig, ShapeConfig
from ..distributed.compat import set_mesh
from ..distributed.sharding import param_pspecs
from ..models.counting import model_flops_per_token, param_count
from ..optim.optimizers import OptState
from .mesh import make_production_mesh
from .specs import abstract_params, batch_pspecs, input_specs
from .steps import make_optimizer, make_prefill_step, make_serve_step, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"=\s*(?:\([^)]*\)|(\w+)\[([0-9,]*)\])\s*(\S+)\(")
_TUPLE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def macro_bytes(hlo_text: str) -> int:
    """TPU-fusion-adjusted HBM-traffic estimate from post-SPMD HLO.

    XLA-CPU's ``bytes accessed`` counts every elementwise/copy/reshape op at
    full size; on TPU those fuse into neighbouring matmuls and never touch
    HBM.  This proxy counts only the ops whose traffic survives fusion:

      * dot / convolution (and oneDNN matmul custom-calls): A + B + C bytes
      * gather / dynamic-slice: 2 x result (read the slice, write it)
      * scatter / dynamic-update-slice: 2 x update (in-place on TPU)

    It remains an upper bound for attention (the shipped Pallas flash kernel
    keeps the score matrix in VMEM; this counts it) — noted in EXPERIMENTS.md.
    """
    total = 0
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if "=" not in line or "(" not in line:
            continue
        shapes = _TUPLE_RE.findall(line.split("metadata=")[0])
        if not shapes:
            continue
        if (" dot(" in line or " convolution(" in line
                or ("custom-call" in line and "matmul" in line)):
            total += sum(_bytes_of(dt, dims) for dt, dims in shapes)
        elif " gather(" in line or " dynamic-slice(" in line:
            total += 2 * _bytes_of(*shapes[0])
        elif " scatter(" in line or " dynamic-update-slice(" in line:
            upd = shapes[2] if len(shapes) > 2 else shapes[0]
            total += 2 * _bytes_of(*upd)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    Per-op result shapes are a proxy for link traffic (exact up to the
    ring-algorithm factor 2(n-1)/n, noted in EXPERIMENTS.md §Roofline).
    Collectives inside while-loop bodies appear once — the roofline harness
    extrapolates per-layer costs from unrolled lowers (see
    benchmarks/roofline.py) so scan bodies never hide traffic.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip().lstrip("%")
        for coll in _COLLECTIVES:
            # match ops like: %ar = f32[128]{0} all-reduce(...), or tuple-shaped
            if f" {coll}(" in stripped or f"= {coll}(" in stripped.replace("  ", " "):
                head = stripped.split(f" {coll}(")[0]
                total = sum(_bytes_of(dt, dims) for dt, dims in _TUPLE_RE.findall(head))
                out[coll] += total
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _named(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_pspecs(params_pspecs):
    return OptState(P(), params_pspecs, params_pspecs)


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               include_optimizer: bool = True):
    """Lower the step for one cell under ``mesh``.  Returns (lowered, kind)."""
    with set_mesh(mesh):
        specs = input_specs(cfg, shape)
        bspecs = batch_pspecs(specs, mesh)
        params_sds = abstract_params(cfg)
        ppspecs = param_pspecs(params_sds, cfg.num_experts)
        pns = _named(mesh, ppspecs)
        bns = _named(mesh, bspecs)

        if shape.kind == "train":
            opt_init, opt_update = make_optimizer(cfg)
            opt_sds = jax.eval_shape(opt_init, params_sds)
            ons = _named(mesh, opt_pspecs(ppspecs))
            step = make_train_step(cfg, opt_update)
            jitted = jax.jit(step, in_shardings=(pns, ons, bns),
                             out_shardings=(pns, ons, None),
                             donate_argnums=(0, 1))
            return jitted.lower(params_sds, opt_sds, specs), "train_step"

        if shape.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(pns, bns))
            return jitted.lower(params_sds, specs), "prefill_step"

        # decode — pure-TP weights when params/TP fit a ~8 GiB HBM budget
        # (otherwise keep the FSDP factor; §Perf iteration D1)
        from ..models.counting import param_count

        tp_n = dict(mesh.shape).get("model", 1)
        pure_tp = (param_count(cfg) * 2 / tp_n) <= 8 * 2**30
        if pure_tp:
            pns = _named(mesh, param_pspecs(params_sds, cfg.num_experts,
                                            serve_pure_tp=True))
        step = make_serve_step(cfg)
        cns = bns.pop("caches")
        token_ns, pos_ns = bns["token"], bns["pos"]
        jitted = jax.jit(step, in_shardings=(pns, cns, token_ns, pos_ns),
                         donate_argnums=(1,))
        return jitted.lower(params_sds, specs["caches"], specs["token"],
                            specs["pos"]), "serve_step"


def analyze(lowered) -> Dict[str, Any]:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = collective_bytes(text)
    return {
        "compile_seconds": round(compile_s, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "macro_bytes": macro_bytes(text),
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, overrides: Optional[Dict[str, Any]] = None,
             variant: str = "") -> Dict[str, Any]:
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if variant:
        mesh_name = f"{mesh_name}__{variant}"
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "params": param_count(cfg), "active_params": param_count(cfg, True),
        "model_flops_per_token": model_flops_per_token(cfg),
    }
    runnable, why = cell_is_runnable(cfg, shape_name)
    if not runnable:
        record["status"] = "skipped"
        record["reason"] = why
        out_path.write_text(json.dumps(record, indent=2))
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    record["devices"] = mesh.size
    try:
        lowered, kind = lower_cell(cfg, shape, mesh)
        record["step_kind"] = kind
        record.update(analyze(lowered))
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(record, indent=2))
    return record


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override key=value (hillclimb lever)")
    ap.add_argument("--variant", default="", help="label for override runs")
    args = ap.parse_args(argv)

    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v)
        if isinstance(overrides[k], str):
            try:
                overrides[k] = int(v)
            except ValueError:
                pass

    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                r = run_cell(arch, shape_name, multi, force=args.force,
                             overrides=overrides or None, variant=args.variant)
                tag = f"{arch} × {shape_name} × {r['mesh']}"
                if r["status"] == "ok":
                    gb = r["memory"]["peak_bytes"] / 2**30
                    print(f"[ok]      {tag}: peak {gb:.2f} GiB/dev, "
                          f"flops {r['flops']:.3e}, "
                          f"coll {r['collective_bytes']['total']:.3e} B, "
                          f"compile {r['compile_seconds']}s", flush=True)
                elif r["status"] == "skipped":
                    print(f"[skip]    {tag}: {r['reason']}", flush=True)
                else:
                    failures += 1
                    print(f"[FAILED]  {tag}: {r['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
