"""Compatibility shim — the gradient layer lives in :mod:`repro.core.gradients`.

The three bespoke adjoint implementations that used to be this module were
ported bitwise-unchanged onto the :class:`~repro.core.gradients.base.
GradientBackend` registry (``gradients/reversible.py`` and
``gradients/continuous.py``); this module keeps the historical import path
(``repro.core.adjoint``) working for external callers.  New code should
import from :mod:`repro.core.gradients` or go through ``repro.solve()``.
"""

from .gradients.continuous import continuous_adjoint_solve
from .gradients.reversible import (
    reversible_heun_solve,
    reversible_heun_solve_adaptive,
    reversible_heun_solve_final,
)

__all__ = [
    "continuous_adjoint_solve",
    "reversible_heun_solve",
    "reversible_heun_solve_adaptive",
    "reversible_heun_solve_final",
]
