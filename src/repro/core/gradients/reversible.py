"""The paper's exact adjoint (§2.4, §3, Appendix C): ``reversible_adjoint``.

A ``jax.custom_vjp`` whose backward pass *algebraically reverses* the
solver (Algorithm 2): it reconstructs ``(z_n, ẑ_n, μ_n, σ_n)`` in closed
form from the step-``n+1`` state, replays the local forward, and
accumulates local VJPs.  Activation memory is **O(1) in the number of
steps** (only the terminal state is saved) and the resulting gradients
match discretise-then-optimise **to floating-point error** (paper Fig. 2).

Moved verbatim from ``repro.core.adjoint`` when the gradient layer became
backend-structured — the solver code here (including the fused-kernel
local VJP) is bitwise the pre-refactor implementation; only the module
path and the thin registry glue at the bottom are new.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..brownian import BrownianPath
from ..solvers import (
    RevHeunState,
    apply_diffusion,
    reversible_heun_reverse_step,
    reversible_heun_step,
)
from .base import GradientBackend, register_backend


def _float0_zeros(tree):
    """Cotangents for non-differentiable (integer) leaves, e.g. PRNG keys."""

    def z(x):
        x = jnp.asarray(x)
        if jnp.issubdtype(x.dtype, jnp.inexact):
            return jnp.zeros_like(x)
        return np.zeros(x.shape, jax.dtypes.float0)

    return jax.tree.map(z, tree)


def _gen_spec(bm, z0, noise, use_pallas):
    """``(key, dt_grid_fn)`` for in-kernel ΔW generation, or ``None``.

    The fused forward scan may draw each step's increment *inside* the
    phase-1 kernel (counter-based Threefry keyed on the step index) instead
    of calling ``bm.increment`` — but only when the in-kernel draw is
    bitwise what ``bm.increment(n, num_steps).astype(z.dtype)`` produces:
    the path must be the counter-keyed :class:`BrownianPath` (not a dense
    or tree sampler), already in the solve dtype (no conversion to mimic),
    and shaped like the state (diagonal noise).
    """
    if not (use_pallas and noise == "diagonal"
            and type(bm) is BrownianPath):
        return None
    if jnp.dtype(bm.dtype) != jnp.dtype(z0.dtype):
        return None
    if tuple(bm.shape) != tuple(z0.shape):
        return None
    return bm.key, lambda num_steps: (bm.t1 - bm.t0) / num_steps


def _fused_local_vjp(drift, diffusion, params, state0, cts, t_left, dt, dw):
    """Hand-derived VJP of one Algorithm-1 step (the fused exact adjoint).

    Bitwise identical to ``jax.vjp`` of the unfused stepper (the grouping
    every term is accumulated in is the transpose's own — DESIGN.md §3
    derives it), with the elementwise cotangent phases running through the
    kernels/ops.py policy: backward Pallas kernels on TPU, the jnp oracle
    elsewhere.  One vector-field VJP per step, exactly like the unfused
    path — only the elementwise algebra around it is fused.

    ``state0`` is the step's *left* state (already reconstructed);
    ``cts = (g_z, g_zh, g_mu, g_sigma)`` the step-``n+1`` cotangents.
    Returns ``(dparams, (d_z, d_zh, d_mu, d_sigma))``.
    """
    from ...kernels import ops

    g_z, g_zh, g_mu, g_sigma = cts
    # ẑ_{n+1} recomputed from the left state — the same bits the unfused
    # local forward produces internally (state1.zh has drifted bits after
    # the round-trip through reconstruction).
    zh1 = ops.rev_heun_phase1(state0.z, state0.zh, state0.mu, state0.sigma,
                              dw, dt)
    c_mu1, c_sig1 = ops.rev_heun_bwd_phase1(g_z, g_mu, g_sigma, dw, dt)
    t_right = t_left + dt
    # Returning ``x`` first makes the g_zh seed enter the ẑ₁-cotangent sum
    # before the field contributions — the same accumulation order as the
    # unfused transpose, keeping the identity bitwise.
    _, vjp_fields = jax.vjp(
        lambda p, x: (x, drift(p, t_right, x), diffusion(p, t_right, x)),
        params, zh1)
    dparams, ghat = vjp_fields((g_zh, c_mu1, c_sig1))
    d_z, d_zh, d_mu, d_sigma = ops.rev_heun_bwd_phase2(g_z, ghat, dw, dt)
    return dparams, (d_z, d_zh, d_mu, d_sigma)


# =============================================================================
# Reversible Heun with exact O(1)-memory adjoint
# =============================================================================


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 5, 6, 7, 8, 9))
def reversible_heun_solve(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    t0: float,
    t1: float,
    num_steps: int,
    noise: str = "diagonal",
    use_pallas: bool = False,
):
    """Solve the Stratonovich SDE with Algorithm 1; exact-gradient backward.

    Returns the trajectory ``(num_steps+1, *z0.shape)`` (index 0 is ``z0``).
    Losses may consume any subset of the trajectory; the backward pass
    injects each step's cotangent as it sweeps right-to-left.

    ``use_pallas`` runs the *whole* per-step pipeline fused (diagonal noise
    only): the forward scan (with ΔW generated inside the phase-1 kernel
    when the path allows it — see :func:`_gen_spec`), the backward's
    closed-form state reconstruction, and the hand-derived per-step
    cotangent phases (:func:`_fused_local_vjp`, bitwise the unfused
    ``jax.vjp``).  AD never traces through a Pallas op — the backward
    kernels ARE the derivative, registered through this ``custom_vjp``.
    """
    traj, _final = _forward(drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
                            use_pallas)
    return traj


def _forward(drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
             use_pallas=False):
    dt = (t1 - t0) / num_steps
    dtype = z0.dtype
    state0 = RevHeunState(z0, z0, drift(params, t0, z0), diffusion(params, t0, z0))
    gen = _gen_spec(bm, z0, noise, use_pallas)

    def body(state, n):
        t = t0 + n * dt
        if gen is not None:
            # ΔW generated inside the fused phase-1 kernel (bitwise
            # bm.increment(n, num_steps)); no host-side draw per step.
            key, dt_grid_fn = gen
            new = reversible_heun_step(state, t, dt, None, drift, diffusion,
                                       params, noise, use_pallas=use_pallas,
                                       gen=(key, n, dt_grid_fn(num_steps)))
        else:
            dw = bm.increment(n, num_steps).astype(dtype)
            new = reversible_heun_step(state, t, dt, dw, drift, diffusion, params, noise,
                                       use_pallas=use_pallas)
        return new, new.z

    final, zs = lax.scan(body, state0, jnp.arange(num_steps))
    traj = jnp.concatenate([z0[None], zs], axis=0)
    return traj, final


def _fwd_rule(drift, diffusion, params, z0, bm, t0, t1, num_steps, noise, use_pallas):
    traj, final = _forward(drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
                           use_pallas)
    # O(1)-in-depth residuals: terminal solver state only (+ params, bm key).
    return traj, (params, final, bm)


def _bwd_rule(drift, diffusion, t0, t1, num_steps, noise, use_pallas, residuals, g_traj):
    params, final, bm = residuals
    dt = (t1 - t0) / num_steps
    dtype = final.z.dtype

    def local_forward(params_, z, zh, mu, sigma, t, dw):
        """Algorithm 1 as a pure function of the carried state (1 NFE)."""
        return tuple(
            reversible_heun_step(
                RevHeunState(z, zh, mu, sigma), t, dt, dw, drift, diffusion, params_, noise
            )
        )

    g_params0 = jax.tree.map(jnp.zeros_like, params)
    zeros = jnp.zeros_like(final.z)
    zeros_sig = jnp.zeros_like(final.sigma)
    # cotangents: (g_z, g_zh, g_mu, g_sigma); seed g_z with the terminal
    # trajectory cotangent.
    carry0 = (final, (g_traj[num_steps], zeros, zeros, zeros_sig), g_params0)

    fused = use_pallas and noise == "diagonal"

    def body(carry, n):
        state1, (g_z, g_zh, g_mu, g_sigma), g_params = carry
        t1_local = t0 + (n + 1) * dt
        dw = bm.increment(n, num_steps).astype(dtype)
        # ---- reverse step: closed-form state reconstruction (Algorithm 2)
        state0 = reversible_heun_reverse_step(
            state1, t1_local, dt, dw, drift, diffusion, params, noise,
            use_pallas=use_pallas,
        )
        # ---- local forward + local backward
        if fused:
            # hand-derived transpose through the backward kernels — one
            # field VJP, elementwise cotangent phases fused (bitwise the
            # unfused jax.vjp below)
            dparams, (d_z, d_zh, d_mu, d_sigma) = _fused_local_vjp(
                drift, diffusion, params, state0,
                (g_z, g_zh, g_mu, g_sigma), t1_local - dt, dt, dw)
        else:
            _, vjp = jax.vjp(
                lambda p, z, zh, mu, sigma: local_forward(p, z, zh, mu, sigma, t1_local - dt, dw),
                params,
                state0.z,
                state0.zh,
                state0.mu,
                state0.sigma,
            )
            dparams, d_z, d_zh, d_mu, d_sigma = vjp((g_z, g_zh, g_mu, g_sigma))
        g_params = jax.tree.map(jnp.add, g_params, dparams)
        # inject this step's trajectory cotangent into g_z
        d_z = d_z + g_traj[n]
        return (state0, (d_z, d_zh, d_mu, d_sigma), g_params), None

    (state0, (g_z, g_zh, g_mu, g_sigma), g_params), _ = lax.scan(
        body, carry0, jnp.arange(num_steps - 1, -1, -1)
    )

    # ---- initial condition: zh_0 = z_0, mu_0 = drift(params, t0, z0), ...
    def init_fn(params_, z0_):
        return z0_, z0_, drift(params_, t0, z0_), diffusion(params_, t0, z0_)

    _, vjp0 = jax.vjp(init_fn, params, state0.z)
    dparams0, g_z0 = vjp0((g_z, g_zh, g_mu, g_sigma))
    g_params = jax.tree.map(jnp.add, g_params, dparams0)
    return (g_params, g_z0, _float0_zeros(bm))


reversible_heun_solve.defvjp(_fwd_rule, _bwd_rule)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 5, 6, 7, 8, 9))
def reversible_heun_solve_final(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    t0: float,
    t1: float,
    num_steps: int,
    noise: str = "diagonal",
    use_pallas: bool = False,
):
    """Terminal-value-only variant of :func:`reversible_heun_solve`.

    Same exact O(1)-memory backward, but the primal output is just ``z_N`` —
    so nothing O(num_steps) is ever materialised.  This is the form the
    reversible *residual-stack* wrapper (models/reversible.py) uses: there
    ``num_steps`` is the network depth and the saving is activation memory.
    """
    _traj, final = _forward(drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
                            use_pallas)
    return final.z


def _fwd_rule_final(drift, diffusion, params, z0, bm, t0, t1, num_steps, noise, use_pallas):
    dt = (t1 - t0) / num_steps
    dtype = z0.dtype
    state0 = RevHeunState(z0, z0, drift(params, t0, z0), diffusion(params, t0, z0))
    gen = _gen_spec(bm, z0, noise, use_pallas)

    def body(state, n):
        t = t0 + n * dt
        if gen is not None:
            key, dt_grid_fn = gen
            return reversible_heun_step(state, t, dt, None, drift, diffusion,
                                        params, noise, use_pallas=use_pallas,
                                        gen=(key, n, dt_grid_fn(num_steps))), None
        dw = bm.increment(n, num_steps).astype(dtype)
        return reversible_heun_step(state, t, dt, dw, drift, diffusion, params, noise,
                                    use_pallas=use_pallas), None

    final, _ = lax.scan(body, state0, jnp.arange(num_steps))
    return final.z, (params, final, bm)


def _bwd_rule_final(drift, diffusion, t0, t1, num_steps, noise, use_pallas, residuals, g_zT):
    params, final, bm = residuals
    dt = (t1 - t0) / num_steps
    dtype = final.z.dtype

    def local_forward(params_, z, zh, mu, sigma, t, dw):
        return tuple(reversible_heun_step(
            RevHeunState(z, zh, mu, sigma), t, dt, dw, drift, diffusion, params_, noise))

    g_params0 = jax.tree.map(jnp.zeros_like, params)
    zeros = jnp.zeros_like(final.z)
    carry0 = (final, (g_zT, zeros, zeros, jnp.zeros_like(final.sigma)), g_params0)

    fused = use_pallas and noise == "diagonal"

    def body(carry, n):
        state1, cts, g_params = carry
        t1_local = t0 + (n + 1) * dt
        dw = bm.increment(n, num_steps).astype(dtype)
        state0 = reversible_heun_reverse_step(
            state1, t1_local, dt, dw, drift, diffusion, params, noise,
            use_pallas=use_pallas)
        if fused:
            dparams, (d_z, d_zh, d_mu, d_sigma) = _fused_local_vjp(
                drift, diffusion, params, state0, cts, t1_local - dt, dt, dw)
        else:
            _, vjp = jax.vjp(
                lambda p, z, zh, mu, sigma: local_forward(p, z, zh, mu, sigma, t1_local - dt, dw),
                params, state0.z, state0.zh, state0.mu, state0.sigma)
            dparams, d_z, d_zh, d_mu, d_sigma = vjp(cts)
        g_params = jax.tree.map(jnp.add, g_params, dparams)
        return (state0, (d_z, d_zh, d_mu, d_sigma), g_params), None

    (state0, (g_z, g_zh, g_mu, g_sigma), g_params), _ = lax.scan(
        body, carry0, jnp.arange(num_steps - 1, -1, -1))

    def init_fn(params_, z0_):
        return z0_, z0_, drift(params_, t0, z0_), diffusion(params_, t0, z0_)

    _, vjp0 = jax.vjp(init_fn, params, state0.z)
    dparams0, g_z0 = vjp0((g_z, g_zh, g_mu, g_sigma))
    g_params = jax.tree.map(jnp.add, g_params, dparams0)
    return (g_params, g_z0, _float0_zeros(bm))


reversible_heun_solve_final.defvjp(_fwd_rule_final, _bwd_rule_final)


# =============================================================================
# Adaptive reversible Heun with exact adjoint over the accepted grid
# =============================================================================
#
# The adaptive forward (repro.core.solve._adaptive_loop) accepts steps on a
# controller-chosen non-uniform grid.  The replay contract (DESIGN.md §10):
# the forward stores ONLY the accepted-step scalars ``(ts, dts)`` —
# O(max_steps) scalar memory, no trajectory storage — and the backward
# re-derives each step's Brownian increment as ``bm.evaluate(ts[i],
# ts[i] + dts[i])``, the bit-identical expression the forward evaluated,
# then algebraically reverses the step (Algorithm 2).  Rejected attempts
# never enter the buffers: gradients see exactly the accepted sequence.


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 7, 8, 9, 10, 11, 12, 13))
def reversible_heun_solve_adaptive(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    rtol,
    atol,
    t0: float,
    t1: float,
    max_steps: int,
    dt0: float,
    noise: str = "diagonal",
    use_pallas: bool = False,
    bridge_depth: Optional[int] = None,
):
    """``(z_T, converged)`` of the adaptive reversible-Heun solve; exact
    adjoint on ``z_T``.

    ``converged`` rides along so the caller can refuse to treat a
    budget-exhausted state at ``t_final < t1`` as ``z_T`` (solve()
    NaN-poisons it); its cotangent is ignored.  ``rtol``/``atol`` sit in
    differentiable positions so they may be traced scalars (per-request
    tolerance in serving) — their cotangents are zero.  ``use_pallas``
    fuses the embedded stepper's state updates and the backward replay's
    reconstruction + cotangent phases — the kernels take the controller's
    traced ``dt`` as a scalar operand, so adaptivity and fusion compose.
    ``bridge_depth`` caps the dyadic descent of Brownian queries (see
    ``repro.solve``); the backward replay descends to the SAME depth, so
    replay stays bit-identical at any setting.  Callers go through
    ``repro.solve(..., adaptive=True, gradient_mode="reversible_adjoint")``.
    """
    final, stats = _adaptive_forward(drift, diffusion, params, z0, bm,
                                     rtol, atol, t0, t1, max_steps, dt0,
                                     noise, use_pallas, bridge_depth)
    return final.z, stats.converged


def _adaptive_forward(drift, diffusion, params, z0, bm, rtol, atol,
                      t0, t1, max_steps, dt0, noise, use_pallas=False,
                      bridge_depth=None):
    # late import: solve.py imports this package at load time (the driver
    # lives there per the front-end layering; by call time it is loaded)
    from ..solve import _adaptive_loop, get_solver

    return _adaptive_loop(get_solver("reversible_heun"), drift, diffusion,
                          params, z0, bm, t0, t1, rtol, atol, max_steps,
                          dt0, noise, use_pallas=use_pallas,
                          bridge_depth=bridge_depth)


def _fwd_rule_adaptive(drift, diffusion, params, z0, bm, rtol, atol,
                       t0, t1, max_steps, dt0, noise, use_pallas,
                       bridge_depth):
    final, stats = _adaptive_forward(drift, diffusion, params, z0, bm,
                                     rtol, atol, t0, t1, max_steps, dt0,
                                     noise, use_pallas, bridge_depth)
    # O(max_steps)-scalar residuals: terminal solver state + the accepted
    # (t, dt) sequence (+ params, bm key).  rtol/atol ride along only to
    # shape their zero cotangents.
    return (final.z, stats.converged), (
        params, final, bm, stats.dts, stats.ts,
        stats.num_accepted, jnp.asarray(rtol), jnp.asarray(atol))


def _bwd_rule_adaptive(drift, diffusion, t0, t1, max_steps, dt0, noise,
                       use_pallas, bridge_depth, residuals, g_out):
    g_zT, _g_converged = g_out  # bool output: float0 cotangent, discarded
    params, final, bm, dts, ts, n_acc, rtol, atol = residuals
    dtype = final.z.dtype
    fused = use_pallas and noise == "diagonal"
    dkw = {} if bridge_depth is None else {"depth": bridge_depth}

    def local_forward(params_, z, zh, mu, sigma, t, dt, dw):
        return tuple(reversible_heun_step(
            RevHeunState(z, zh, mu, sigma), t, dt, dw, drift, diffusion,
            params_, noise))

    g_params0 = jax.tree.map(jnp.zeros_like, params)
    zeros = jnp.zeros_like(final.z)
    carry0 = (final, (g_zT, zeros, zeros, jnp.zeros_like(final.sigma)),
              g_params0)

    def body(loop_carry):
        i, carry = loop_carry

        def replay(carry):
            state1, cts, g_params = carry
            # ``i`` can sit below 0 on vmap lanes that finished early (the
            # batched while_loop keeps stepping them; lax.cond lowers to
            # select there) — clamp so the discarded computation stays
            # in-bounds and finite
            j = jnp.maximum(i, 0)
            dt = dts[j]
            t_left = ts[j]
            # same value-difference (astype order AND bridge depth) as the
            # forward driver, so dw is bit-identical to what the accepted
            # step saw
            if hasattr(bm, "value"):
                dw = (bm.value(t_left + dt, **dkw).astype(dtype)
                      - bm.value(t_left, **dkw).astype(dtype))
            else:
                dw = bm.evaluate(t_left, t_left + dt, **dkw).astype(dtype)
            # Algorithm 2 inline, anchored on the STORED left endpoint so
            # the vector fields are evaluated at bit-identical times (the
            # helper's ``t1 - dt`` would reintroduce fp drift).
            z1, zh1, mu1, sigma1 = state1
            if fused:
                from ...kernels import ops
                zh = ops.rev_heun_phase1(z1, zh1, mu1, sigma1, dw, dt,
                                         sign=-1.0)
                mu = drift(params, t_left, zh)
                sigma = diffusion(params, t_left, zh)
                z = ops.rev_heun_phase2(z1, mu, mu1, sigma, sigma1, dw, dt,
                                        sign=-1.0)
                state0 = RevHeunState(z, zh, mu, sigma)
                dparams, (d_z, d_zh, d_mu, d_sigma) = _fused_local_vjp(
                    drift, diffusion, params, state0, cts, t_left, dt, dw)
            else:
                zh = (2.0 * z1 - zh1 - mu1 * dt
                      - apply_diffusion(sigma1, dw, noise))
                mu = drift(params, t_left, zh)
                sigma = diffusion(params, t_left, zh)
                z = z1 - 0.5 * (mu + mu1) * dt - apply_diffusion(
                    0.5 * (sigma + sigma1), dw, noise)
                state0 = RevHeunState(z, zh, mu, sigma)
                _, vjp = jax.vjp(
                    lambda p, z_, zh_, mu_, sigma_: local_forward(
                        p, z_, zh_, mu_, sigma_, t_left, dt, dw),
                    params, state0.z, state0.zh, state0.mu, state0.sigma)
                dparams, d_z, d_zh, d_mu, d_sigma = vjp(cts)
            g_params = jax.tree.map(jnp.add, g_params, dparams)
            return (state0, (d_z, d_zh, d_mu, d_sigma), g_params)

        return (i - 1, lax.cond(i >= 0, replay, lambda c: c, carry))

    # walk i = n_acc-1 .. 0: the trip count is the ACCEPTED count, not
    # max_steps — under vmap the batched loop runs max(n_acc) iterations
    # instead of paying the full padded buffer per trajectory (cond lowers
    # to select there, so padded slots would otherwise do real work)
    _, (state0, cts, g_params) = lax.while_loop(
        lambda c: c[0] >= 0, body, (n_acc - 1, carry0))

    def init_fn(params_, z0_):
        return z0_, z0_, drift(params_, t0, z0_), diffusion(params_, t0, z0_)

    _, vjp0 = jax.vjp(init_fn, params, state0.z)
    dparams0, g_z0 = vjp0(cts)
    g_params = jax.tree.map(jnp.add, g_params, dparams0)
    return (g_params, g_z0, _float0_zeros(bm),
            jnp.zeros_like(rtol), jnp.zeros_like(atol))


reversible_heun_solve_adaptive.defvjp(_fwd_rule_adaptive, _bwd_rule_adaptive)


# =============================================================================
# Backend registration
# =============================================================================


def _validate(spec, *, noise, save_trajectory, use_pallas, adaptive):
    if (spec.stepper is not reversible_heun_step
            or spec.reverse_stepper is not reversible_heun_reverse_step):
        raise ValueError(
            f"solver {spec.name!r} declares reversible_adjoint but the exact "
            f"adjoint is implemented for the reversible-Heun stepper pair "
            f"(repro.core.gradients.reversible); a custom reversible solver "
            f"needs its own custom_vjp there")


def _solve(spec, drift, diffusion, params, z0, bm, t0, t1, num_steps, *,
           noise, save_trajectory, use_pallas):
    if save_trajectory:
        return reversible_heun_solve(
            drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
            use_pallas)
    return reversible_heun_solve_final(
        drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
        use_pallas)


def _solve_adaptive(spec, drift, diffusion, params, z0, bm, rtol, atol,
                    t0, t1, max_steps, dt0, *, noise, use_pallas,
                    bridge_depth):
    return reversible_heun_solve_adaptive(
        drift, diffusion, params, z0, bm, rtol, atol, t0, t1, max_steps,
        dt0, noise, use_pallas, bridge_depth)


register_backend(GradientBackend(
    name="reversible_adjoint",
    summary="paper's exact adjoint: algebraic reversal, O(1) memory",
    terminal_only=False,
    supports_adaptive=True,
    solve=_solve,
    solve_adaptive=_solve_adaptive,
    validate=_validate,
))
