from .sharding import (  # noqa: F401
    active_mesh_axes,
    batch_pspec,
    dp_axes,
    hint,
    param_pspecs,
    tp_axis,
)
