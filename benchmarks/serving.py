"""Serving benchmark suite: batched trajectory-sampling throughput.

Two axes (DESIGN.md §9; the serving architecture under test is
``repro.launch.steps.make_sample_step`` — the exact program
launch/serve.py AOT-compiles per bucket):

1. **Throughput vs batch size** (SDE-GAN generator rollout): best-of-reps
   wall clock and trajectories/sec per bucket size.  Larger buckets must
   amortise per-dispatch overhead — the whole point of request coalescing —
   so the gate asserts trajectories/sec is strictly higher at the largest
   bucket than at batch 1.

2. **Fused vs unfused latent prior decode** — the diagonal-noise sampler
   with and without ``use_pallas_kernels``.  As in benchmarks/latent_sde.py,
   wall-clock rows are reported for existence and the **gated** comparison
   is the XLA cost-model bytes-accessed ratio (deterministic where shared
   CI runners are not): fusion never *adds* traffic, so the ratio is ≥ 1
   by construction (exactly 1.0 off-TPU, where the fused path dispatches
   to the identical jnp oracle — DESIGN.md §5).

The ``*_ms`` rows feed CI's bench-regression gate
(``benchmarks/report.py --compare``): a >2× best-of-reps wall-clock
regression against the committed BENCH_serving.json fails bench-smoke.

Run:  PYTHONPATH=src python benchmarks/serving.py --preset tiny
Emits BENCH_serving.json (schema in benchmarks/report.py).
"""

from __future__ import annotations

import time

import jax

try:
    from . import report
    from .latent_sde import _bytes_accessed
except ImportError:  # run as a loose script: python benchmarks/serving.py
    import report
    from latent_sde import _bytes_accessed

# num_steps: solver horizon; batches: bucket sizes (throughput axis);
# fused_batch: bucket for the fused-vs-unfused comparison; reps: timing reps
PRESET_SHAPES = {
    "tiny":  dict(num_steps=16, batches=(1, 4, 16), fused_batch=16,
                  hidden=8, width=16, reps=5),
    "quick": dict(num_steps=32, batches=(1, 8, 32, 128), fused_batch=64,
                  hidden=16, width=32, reps=8),
    "full":  dict(num_steps=64, batches=(1, 16, 128, 1024), fused_batch=256,
                  hidden=16, width=32, reps=15),
}


def _best_of(reps: int, compiled, *args) -> float:
    jax.block_until_ready(compiled(*args))  # warm (AOT: compile already done)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_throughput(num_steps: int, batches, hidden: int, width: int,
                     reps: int):
    """trajectories/sec per bucket size for the SDE-GAN sampler."""
    from repro.core.sde import NeuralSDEConfig, generator_init
    from repro.launch.steps import make_sample_step

    cfg = NeuralSDEConfig(data_dim=1, hidden_dim=hidden, noise_dim=4,
                          width=width, num_steps=num_steps)
    key = jax.random.PRNGKey(0)
    params = generator_init(key, cfg)
    jitted = jax.jit(make_sample_step("sde-gan", cfg))

    rows, tps = [], {}
    for b in batches:
        keys = jax.random.split(jax.random.fold_in(key, b), b)
        compiled = jitted.lower(params, keys).compile()
        best = _best_of(reps, compiled, params, keys)
        tps[b] = b / best
        rows.append(("serving", f"sde_gan_batch{b}_ms", best * 1e3))
        rows.append(("serving", f"sde_gan_traj_per_s,batch={b}", tps[b]))
        print(f"serving,sde_gan,batch={b},{best*1e3:.2f}ms,"
              f"{tps[b]:.1f}traj/s", flush=True)
    big, small = max(batches), min(batches)
    # coalescing must pay: the big bucket amortises dispatch overhead
    assert tps[big] > tps[small], (
        f"batching did not improve throughput: batch={big} served "
        f"{tps[big]:.1f} traj/s vs {tps[small]:.1f} at batch={small}")
    return rows


def bench_fused_prior(num_steps: int, fused_batch: int, hidden: int,
                      width: int, reps: int):
    """Fused vs unfused latent prior decode: interleaved best-of-reps wall
    clock + the deterministic cost-model bytes gate."""
    from repro.core.sde import LatentSDEConfig, latent_sde_init
    from repro.launch.steps import make_sample_step

    key = jax.random.PRNGKey(1)
    keys = jax.random.split(key, fused_batch)
    built = {}
    for fused in (False, True):
        cfg = LatentSDEConfig(data_dim=2, hidden_dim=hidden,
                              context_dim=hidden, width=width,
                              num_steps=num_steps, use_pallas_kernels=fused)
        params = latent_sde_init(key, cfg)
        jitted = jax.jit(make_sample_step("latent-sde", cfg))
        built[fused] = (jitted.lower(params, keys).compile(), jitted, params)
        jax.block_until_ready(built[fused][0](params, keys))  # warm

    best = {False: float("inf"), True: float("inf")}
    for _ in range(reps):  # interleave: same machine conditions for both
        for fused, (compiled, _, params) in built.items():
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(params, keys))
            best[fused] = min(best[fused], time.perf_counter() - t0)
    bytes_ = {fused: _bytes_accessed(jitted, params, keys)
              for fused, (_, jitted, params) in built.items()}

    rows = []
    for fused in (False, True):
        label = "fused" if fused else "unfused"
        rows.append(("serving", f"latent_prior_{label}_ms", best[fused] * 1e3))
        rows.append(("serving", f"latent_prior_{label}_bytes_accessed",
                     bytes_[fused]))
        print(f"serving,latent_prior_{label},{best[fused]*1e3:.2f}ms,"
              f"bytes={bytes_[fused]:.3e}", flush=True)
    speedup = bytes_[False] / bytes_[True]
    rows.append(("serving", "latent_prior_fused_speedup", speedup))
    print(f"serving,latent_prior_fused_speedup,{speedup:.3f}x "
          f"(cost-model bytes)", flush=True)
    assert speedup >= 1.0 - 1e-9, (
        f"fused prior decode accessed MORE bytes than unfused "
        f"({bytes_[True]:.3e} vs {bytes_[False]:.3e})")
    return rows


def main(preset: str = "full"):
    shape = PRESET_SHAPES[preset]
    rows = bench_throughput(shape["num_steps"], shape["batches"],
                            shape["hidden"], shape["width"], shape["reps"])
    rows += bench_fused_prior(shape["num_steps"], shape["fused_batch"],
                              shape["hidden"], shape["width"], shape["reps"])
    return rows


if __name__ == "__main__":
    report.standalone("serving", main)
