"""Continuous-batching scheduler tests (DESIGN.md §11).

The PR 7 contracts: the ``repro-serving/v1`` → ``v2`` bundle upgrade is
bitwise; SLO routing serves the loosest rtol the tightest deadline
allows (explicit asks only ever tighten); a request admitted into a
half-full in-flight batch produces bitwise the trajectories it produces
solo (and bitwise the PR 4 stream loop's); two registry models never
share params or compile pools; budget-exhausted adaptive rows come back
``converged=False`` on their :class:`ServeResult`.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.sde import (LatentSDEConfig, NeuralSDEConfig, generator_init,
                            generator_initial_state, latent_sde_init)
from repro.serving import (DEADLINE_CLASSES, LoadedModel, ModelRegistry,
                           Request, Scheduler, deadline_class_for, load_model,
                           route_rtol)

GAN_CFG = dict(data_dim=1, hidden_dim=8, noise_dim=4, width=16, num_steps=8)


def _registry(key, model_ids=("default",)):
    """Fresh registry (and so fresh compile pools) per test — the pool key
    is (model_id, kind, bucket), deliberately NOT the controller limits."""
    reg = ModelRegistry()
    cfg = NeuralSDEConfig(**GAN_CFG)
    for i, mid in enumerate(model_ids):
        params = generator_init(jax.random.fold_in(key, i), cfg)
        reg.register(LoadedModel(mid, "sde-gan", cfg, params))
    return reg


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -----------------------------------------------------------------------------
# bundle handshake: v1 -> v2 upgrade, v2 registry round trip, unknown schema
# -----------------------------------------------------------------------------


def test_v1_bundle_upgrades_to_v2_bitwise(key, tmp_path):
    """A PR 4-era v1 bundle reads back as a single-entry v2 registry under
    model_id="default", params bitwise-identical to what was saved."""
    cfg = NeuralSDEConfig(**GAN_CFG)
    params = generator_init(key, cfg)
    ckpt.save_serving_bundle_v1(tmp_path, 5, params, "sde-gan", cfg)

    meta, step = ckpt.load_serving_manifest(tmp_path)
    assert step == 5
    assert meta["schema"] == ckpt.SERVING_SCHEMA_V2
    assert meta["upgraded_from"] == ckpt.SERVING_SCHEMA_V1
    assert [m["model_id"] for m in meta["models"]] == [ckpt.DEFAULT_MODEL_ID]

    model = load_model(tmp_path)
    assert model.model_id == ckpt.DEFAULT_MODEL_ID
    assert model.workload == "sde-gan" and model.step == 5
    assert model.cfg.num_steps == cfg.num_steps
    _assert_trees_equal(model.params, params)

    # and the registry loader takes the same upgrade path
    reg = ModelRegistry()
    assert reg.load(tmp_path) == (ckpt.DEFAULT_MODEL_ID,)
    _assert_trees_equal(reg.get(ckpt.DEFAULT_MODEL_ID).params, params)


def test_v2_multi_model_bundle_roundtrip_bitwise(key, tmp_path):
    cfg = NeuralSDEConfig(**GAN_CFG)
    params = {mid: generator_init(jax.random.fold_in(key, i), cfg)
              for i, mid in enumerate(("a", "b"))}
    ckpt.save_serving_registry(
        tmp_path, 7, {mid: (p, "sde-gan", cfg) for mid, p in params.items()})

    reg = ModelRegistry()
    assert reg.load(tmp_path) == ("a", "b")
    for mid, p in params.items():
        _assert_trees_equal(reg.get(mid).params, p)
    # the single-model loader must refuse to guess among two entries
    with pytest.raises(ValueError, match="model_id"):
        load_model(tmp_path)


def test_unknown_bundle_schema_raises_named_error(key, tmp_path):
    cfg = NeuralSDEConfig(**GAN_CFG)
    ckpt.save_checkpoint(tmp_path / "serving", 1, generator_init(key, cfg),
                         meta={"schema": "repro-serving/v99"})
    with pytest.raises(ckpt.UnknownServingSchemaError, match="v99"):
        ckpt.load_serving_manifest(tmp_path)


# -----------------------------------------------------------------------------
# SLO routing: deadline class table and the loosest-admissible rule
# -----------------------------------------------------------------------------


def test_deadline_class_boundaries():
    """The table is contiguous and upper-bound inclusive."""
    assert deadline_class_for(1.0).name == "realtime"
    assert deadline_class_for(50.0).name == "realtime"
    assert deadline_class_for(50.1).name == "interactive"
    assert deadline_class_for(250.0).name == "interactive"
    assert deadline_class_for(1000.0).name == "standard"
    assert deadline_class_for(math.inf).name == "relaxed"


def test_route_rtol_serves_loosest_admissible():
    realtime = Request(rid=0, size=1, seed=0, deadline_ms=40.0)
    relaxed = Request(rid=1, size=1, seed=1)  # deadline inf
    # a lone unbounded request gets the most accurate tier
    assert route_rtol([relaxed]) == DEADLINE_CLASSES[-1].rtol
    # the tightest deadline in the batch picks the (loosest) tier rtol
    assert route_rtol([relaxed, realtime]) == DEADLINE_CLASSES[0].rtol
    # an explicit ask is an accuracy FLOOR: it tightens ...
    asked = Request(rid=2, size=1, seed=2, deadline_ms=40.0, rtol=1e-4)
    assert route_rtol([realtime, asked]) == 1e-4
    # ... but never loosens past the class rtol
    loose_ask = Request(rid=3, size=1, seed=3, rtol=1e-1)
    assert route_rtol([loose_ask]) == DEADLINE_CLASSES[-1].rtol
    with pytest.raises(ValueError, match="non-empty"):
        route_rtol([])


def test_scheduler_routes_terminal_batches_by_deadline_class(key):
    """End to end: one terminal request per deadline class drains as one
    batch per class, each at its class rtol (requests carry no explicit
    ask, so the deadline alone picks the served tolerance)."""
    sched = Scheduler(_registry(key), max_batch=4, chunks=4)
    for i, cls in enumerate(DEADLINE_CLASSES):
        dl = cls.max_deadline_ms  # upper bound is inclusive
        sched.submit(Request(rid=i, size=1, seed=10 + i, kind="terminal",
                             deadline_ms=dl))
    results = sched.run()
    assert len(results) == len(DEADLINE_CLASSES)
    for r in results:
        assert r.rtol == DEADLINE_CLASSES[r.rid].rtol
        assert r.num_converged == r.size  # default budget is ample here


# -----------------------------------------------------------------------------
# continuous batching: mid-flight admission is bitwise-invisible
# -----------------------------------------------------------------------------


def test_mid_flight_admission_bitwise_equals_solo(key):
    """A request admitted into a half-drained in-flight batch produces
    bitwise the trajectories it produces alone — every row is a pure
    function of (params, request seed, row index, chunk index)."""
    reg = _registry(key)
    first = Request(rid=0, size=3, seed=7)
    late = Request(rid=1, size=2, seed=123)

    def solo(req):
        sched = Scheduler(reg, max_batch=8, chunks=4, collect=True)
        sched.submit(req)
        (res,) = sched.run()
        return res.samples

    sched = Scheduler(reg, max_batch=8, chunks=4, collect=True)
    sched.submit(first)
    results = sched.step()  # `first` is now in flight, one chunk deep
    assert results == [] and sched.busy
    sched.submit(late)      # joins at the next chunk boundary
    results += sched.run()

    cfg = reg.get("default").cfg
    by_rid = {r.rid: r for r in results}
    assert by_rid[1].samples.shape == (cfg.num_steps + 1, 2, cfg.data_dim)
    np.testing.assert_array_equal(by_rid[0].samples, solo(first))
    np.testing.assert_array_equal(by_rid[1].samples, solo(late))


def test_scheduler_rollout_bitwise_matches_stream_loop(key):
    """Independent oracle: the scheduler's chunked rollout reproduces the
    PR 4 stream loop bit for bit — same base key fold_in(PRNGKey(seed), j),
    same chunk key fold_in(base, 1000 + c), same chunk stitching."""
    from repro.launch.steps import make_stream_chunk_step

    reg = _registry(key)
    model = reg.get("default")
    cfg, size, seed = model.cfg, 2, 42

    sched = Scheduler(reg, max_batch=2, chunks=4, collect=True)
    sched.submit(Request(rid=0, size=size, seed=seed))
    (res,) = sched.run()

    chunks, steps_per = 4, cfg.num_steps // 4
    span = cfg.t1 / chunks
    chunk_fn = jax.jit(make_stream_chunk_step(cfg, span, steps_per))
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(seed), j)
                      for j in range(size)])
    x = generator_initial_state(model.params, cfg, keys)
    expect = []
    for c in range(chunks):
        ckeys = jax.vmap(lambda k, c=c: jax.random.fold_in(k, 1000 + c))(keys)
        ys, x = chunk_fn(model.params, ckeys, x,
                         jnp.asarray(c * span, cfg.dtype))
        expect.append(np.asarray(ys if c == 0 else ys[1:]))
    np.testing.assert_array_equal(res.samples, np.concatenate(expect))


# -----------------------------------------------------------------------------
# multi-model isolation
# -----------------------------------------------------------------------------


def test_two_model_registry_isolation(key):
    """Two models serve side by side from one scheduler: same-seed requests
    get different (per-model) trajectories, each bitwise what a single-model
    scheduler produces, and the compile pools never mix ids — unloading one
    model leaves the other's programs untouched."""
    reg = _registry(key, ("a", "b"))
    sched = Scheduler(reg, max_batch=4, chunks=4, collect=True)
    sched.submit(Request(rid=0, size=2, seed=9, model_id="a"))
    sched.submit(Request(rid=1, size=2, seed=9, model_id="b"))
    by_rid = {r.rid: r for r in sched.run()}
    assert by_rid[0].model_id == "a" and by_rid[1].model_id == "b"
    assert not np.array_equal(by_rid[0].samples, by_rid[1].samples)

    solo = Scheduler(reg, max_batch=4, chunks=4, collect=True)
    solo.submit(Request(rid=2, size=2, seed=9, model_id="a"))
    (res_a,) = solo.run()
    np.testing.assert_array_equal(by_rid[0].samples, res_a.samples)

    keys_a, keys_b = reg.pool_keys("a"), reg.pool_keys("b")
    assert keys_a and keys_b
    assert all(k[0] == "a" for k in keys_a)
    assert set(reg.pool_keys()) == set(keys_a) | set(keys_b)
    reg.unload("a")
    assert "a" not in reg
    assert reg.pool_keys("a") == ()
    assert reg.pool_keys("b") == keys_b


# -----------------------------------------------------------------------------
# per-row convergence + named scheduler errors
# -----------------------------------------------------------------------------


def test_serve_result_reports_budget_exhausted_rows(key):
    """A starved adaptive controller (max_steps=2 at the relaxed tier's
    tight rtol) marks every row converged=False on the ServeResult —
    structural, not a log line."""
    sched = Scheduler(_registry(key), max_batch=2, chunks=4, max_steps=2)
    sched.submit(Request(rid=0, size=2, seed=3, kind="terminal"))
    (res,) = sched.run()
    assert res.rtol == DEADLINE_CLASSES[-1].rtol
    assert res.converged.shape == (2,)
    assert res.num_converged == 0
    assert res.deadline_met  # deadline inf: slow but never missed


def test_scheduler_named_errors(key):
    reg = _registry(key)
    with pytest.raises(ValueError, match="mode"):
        Scheduler(reg, mode="bogus")
    with pytest.raises(ValueError, match="chunks"):
        Scheduler(reg, max_batch=4, chunks=3).submit(
            Request(rid=0, size=1, seed=0))  # 3 doesn't divide num_steps=8
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        Scheduler(reg, max_batch=4).submit(Request(rid=1, size=16, seed=0))
    lcfg = LatentSDEConfig(data_dim=2, hidden_dim=8, context_dim=8, width=16,
                           num_steps=16)
    reg.register(LoadedModel("lat", "latent-sde", lcfg,
                             latent_sde_init(key, lcfg)))
    with pytest.raises(ValueError, match="latent-sde"):
        Scheduler(reg).submit(Request(rid=2, size=1, seed=0, model_id="lat"))
    with pytest.raises(ValueError, match="size"):
        Request(rid=3, size=0, seed=0)
    with pytest.raises(ValueError, match="kind"):
        Request(rid=4, size=1, seed=0, kind="magic")
