"""Sharding-rule unit tests (1-device safe; full meshes live in dryrun)."""

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.distributed.compat import abstract_mesh, make_mesh, set_mesh
from repro.distributed.sharding import hint, param_pspecs
from repro.launch.specs import abstract_params, batch_pspecs, input_specs
from repro.configs.base import SHAPES


def test_hint_noop_without_mesh(key):
    x = jax.random.normal(key, (4, 4))
    y = hint(x, "dp", "tp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_param_pspecs_structure_matches():
    cfg = smoke_config("qwen2.5-14b")
    params = abstract_params(cfg)
    specs = param_pspecs(params, cfg.num_experts)
    jax.tree.map(lambda a, b: None, params, specs,
                 is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


def test_param_pspecs_under_mesh():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = smoke_config("dbrx-132b")
    params = abstract_params(cfg)
    with set_mesh(mesh):
        specs = param_pspecs(params, cfg.num_experts)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)


def test_input_specs_cover_all_cells():
    """Every (arch × shape) cell defines a complete, consistent spec set."""
    from repro.configs import ARCH_NAMES, cell_is_runnable

    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, _ = cell_is_runnable(cfg, sname)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            if shape.kind == "train":
                assert "tokens" in specs and "labels" in specs
                assert specs["tokens"].shape[0] == shape.global_batch
            elif shape.kind == "prefill":
                assert "tokens" in specs and "labels" not in specs
            else:
                assert {"token", "caches", "pos"} <= set(specs)
                assert specs["token"].shape == (shape.global_batch, 1)


def test_batch_pspecs_divisibility():
    """No pspec may demand a finer split than the dim allows (the
    production-mesh sizes, via AbstractMesh — no devices needed)."""
    mesh = abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("jamba-v0.1-52b")
    specs = input_specs(cfg, SHAPES["long_500k"])
    ps = batch_pspecs(specs, mesh)
    assert ps["token"] == P(None, None)  # batch 1 < dp 16: dp dropped
    cfg2 = get_config("qwen2.5-14b")
    specs2 = input_specs(cfg2, SHAPES["decode_32k"])
    ps2 = batch_pspecs(specs2, mesh)
    assert ps2["token"] == P(("data",), None) or ps2["token"] == P("data", None)
    # GQA kv heads (8) don't divide model (16): cache falls to seq sharding
    kspec = jax.tree.leaves(ps2["caches"],
                            is_leaf=lambda x: isinstance(x, P))[0]
    assert "model" in str(kspec)
