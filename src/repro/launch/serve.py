"""Neural-SDE serving CLI (DESIGN.md §9/§11).

A thin argparse front-end over the public :mod:`repro.serving` API —
restore/bucket/mesh/scheduling all live in the package; this module only
parses flags, plus hosts the quarantined transformer-LM decode loop from
the seed scaffold (``--workload lm`` — the only place serve.py touches
``repro.models``/``repro.configs``).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --workload sde-gan \
        --host-devices 2 --smoke
    PYTHONPATH=src python -m repro.launch.serve --workload sde-gan \
        --scheduler continuous --requests 24
    PYTHONPATH=src python -m repro.launch.serve --workload latent-sde \
        --ckpt-dir /tmp/ckpt --requests 64 --max-batch 32

Back-compat: the names PR 4-6 exposed here (``Request``,
``synthetic_requests``, ``serve_buckets``, ``restore_for_serving``,
``serve_sde``, ``_coalesce``, ``_compile_pool``, ``_batch_loop``,
``_percentile``) are re-exported from :mod:`repro.serving`.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..serving import (  # noqa: F401  (re-exports: the PR 4-6 surface)
    Request,
    _adaptive_terminal_loop,
    _batch_loop,
    _coalesce,
    _compile_pool,
    _percentile,
    _request_keys,
    _stream_loop,
    restore_for_serving,
    serve_buckets,
    serve_sde,
    synthetic_requests,
)
from .steps import SERVE_WORKLOADS

# -----------------------------------------------------------------------------
# the quarantined transformer-LM decode loop (seed scaffold)
# -----------------------------------------------------------------------------


def serve_lm(arch: str, batch: int, prompt_len: int, gen: int,
             smoke: bool = True, seed: int = 0):
    """Prefill + greedy-decode smoke loop for the transformer zoo.

    Kept behind ``--workload lm``: this is the only place serve.py touches
    ``repro.models``/``repro.configs`` — the SDE workloads never import the
    transformer stack.
    """
    from ..configs import get_config, smoke_config
    from ..models import transformer as T
    from .steps import greedy_sample, make_prefill_step, make_serve_step

    cfg = smoke_config(arch) if smoke else get_config(arch)
    if cfg.family == "encdec":
        raise SystemExit("use --arch with a decoder-only config for serve.py")

    key = jax.random.PRNGKey(seed)
    params = T.init_lm(key, cfg)
    max_len = prompt_len + gen + (cfg.frontend_len if cfg.frontend else 0)

    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab)
    batch_in = {"tokens": prompts}
    pos0 = prompt_len
    if cfg.frontend:
        batch_in["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.frontend_len, cfg.d_model), cfg.dtype)
        pos0 += cfg.frontend_len

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, caches = prefill(params, batch_in)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    token = greedy_sample(logits)
    out_tokens = [token]
    t0 = time.time()
    for i in range(gen - 1):
        logits, caches = decode(params, caches, token,
                                jnp.asarray(pos0 + i, jnp.int32))
        token = greedy_sample(logits)
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0

    gen_tokens = jnp.concatenate(out_tokens, axis=1)
    tps = batch * (gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] {arch}: batch={batch} prefill({prompt_len} tok) "
          f"{t_prefill * 1e3:.1f}ms; decode {gen - 1} steps @ {tps:.1f} tok/s")
    print(f"[serve] sample generation (row 0): {gen_tokens[0].tolist()}")
    return gen_tokens


# -----------------------------------------------------------------------------
# CLI
# -----------------------------------------------------------------------------


_EPILOG = """\
tolerance routing (DESIGN.md §11):
  Adaptive terminal batches are coalesced per deadline class and run at
  the LOOSEST rtol the batch's tightest deadline allows (route_rtol).
  This replaced the PR 5 tightest-ask rule — one accuracy-hungry request
  no longer slows every deadline-bound request sharing its batch.
  Explicit per-request rtol asks survive as accuracy floors only.
  SLO ladder: realtime <=50ms -> 1e-2, interactive <=250ms -> 3e-3,
  standard <=1000ms -> 1e-3, relaxed (no SLO) -> 3e-4.

scheduler extras (DESIGN.md §14, all require --scheduler):
  --preempt          cross-lane preemption: under realtime-class pressure
                     on any lane, other lanes' relaxed rollouts yield at
                     chunk boundaries (bitwise-invisible to them).
  --pool-budget-mb   LRU cap on the AOT compile pools: cold
                     (model, kind, bucket) programs are evicted and
                     transparently recompiled on next use.
  --async-front      drive the drain through the asyncio ingestion
                     front-end (repro.serving.AsyncFrontend).
"""


def main(argv=None):
    ap = argparse.ArgumentParser(
        epilog=_EPILOG, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--workload", choices=SERVE_WORKLOADS + ("lm",),
                    default="sde-gan")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir written by launch/train.py (the "
                         "serving bundle lives under <ckpt-dir>/serving/); "
                         "omit with --smoke for a fresh-init service")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="simulate N CPU devices (must be processed before "
                         "the XLA backend initialises)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="largest serving bucket (rows per compiled batch)")
    ap.add_argument("--requests", type=int, default=12,
                    help="synthetic requests to drain through the queue")
    ap.add_argument("--request-max", type=int, default=4,
                    help="largest per-request trajectory count")
    ap.add_argument("--latent-mode", choices=("prior", "posterior"),
                    default="prior",
                    help="latent-sde: decode from the prior, or encode "
                         "observations and decode the posterior")
    ap.add_argument("--obs-len", type=int, default=9,
                    help="latent-sde posterior: observation points per "
                         "request (num_steps must be a multiple of "
                         "obs_len - 1)")
    ap.add_argument("--stream-chunks", type=int, default=0,
                    help="sde-gan: stream the horizon in K time chunks "
                         "(0/1 = whole trajectories)")
    ap.add_argument("--adaptive", action="store_true",
                    help="sde-gan: serve adaptive terminal samples at the "
                         "deadline-routed tolerance (rtol is traced — one "
                         "compiled program per bucket serves every rtol)")
    ap.add_argument("--atol", type=float, default=1e-6,
                    help="adaptive serving: absolute tolerance floor")
    ap.add_argument("--scheduler", choices=("continuous", "fifo"),
                    default=None,
                    help="sde-gan: drive the continuous-batching scheduler "
                         "(repro.serving.Scheduler) — 'fifo' runs the same "
                         "chunked programs under the PR 4 drain-then-"
                         "coalesce baseline for comparison")
    ap.add_argument("--preempt", action="store_true",
                    help="scheduler: yield relaxed-class rollouts at chunk "
                         "boundaries while any lane has realtime-class work "
                         "(see epilog; bitwise-invisible)")
    ap.add_argument("--pool-budget-mb", type=float, default=None,
                    help="scheduler: LRU-evict cold compiled programs once "
                         "the pools exceed this many MB (XLA "
                         "memory_analysis accounting; recompile on reuse)")
    ap.add_argument("--async-front", action="store_true",
                    help="scheduler: drive the drain through the asyncio "
                         "ingestion front-end instead of a direct step loop")
    ap.add_argument("--solver", default="reversible_heun",
                    help="fresh-init (--smoke) solver; restored bundles "
                         "carry their own")
    ap.add_argument("--pallas", action="store_true",
                    help="fresh-init: request the fused hot loop (diagonal-"
                         "noise latent decode fuses; sde-gan warns + runs "
                         "unfused)")
    ap.add_argument("--sde-steps", type=int, default=None,
                    help="fresh-init solver steps (default 16)")
    ap.add_argument("--seed", type=int, default=0)
    # --workload lm (quarantined transformer decode loop)
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    if args.host_devices is not None:
        from ..distributed.compat import force_host_device_count

        force_host_device_count(args.host_devices)
    if args.workload == "lm":
        return serve_lm(args.arch, args.batch, args.prompt_len, args.gen,
                        args.smoke, args.seed)
    return serve_sde(args.workload, args.ckpt_dir, args.smoke,
                     args.max_batch, args.requests, args.request_max,
                     latent_mode=args.latent_mode, obs_len=args.obs_len,
                     stream_chunks=args.stream_chunks,
                     adaptive=args.adaptive, atol=args.atol,
                     seed=args.seed, scheduler=args.scheduler,
                     preempt=args.preempt,
                     pool_budget_mb=args.pool_budget_mb,
                     async_front=args.async_front, args=args)


if __name__ == "__main__":
    main()
