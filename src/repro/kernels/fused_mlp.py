"""Fused SDE vector-field MLP: Linear → LipSwish → Linear in one kernel.

The drift/diffusion networks of a Neural SDE are small MLPs evaluated once
per solver step (the paper's NFE unit).  At production batch sizes the two
GEMMs are tiny and *launch/memory-bound*: XLA emits two HLO dots with the
(batch, width) activation round-tripping through HBM.  This kernel keeps
both weight matrices and the intermediate activation in VMEM and tiles only
the batch dimension — one HBM read of ``x`` and one write of the output.

Weight shapes are the SDE-net sizes (width ≤ ~512), so both fit comfortably
in ~16 MB of VMEM: (Din·H + H·Dout)·4B ≤ 2 MB even at width 512.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lipswish(x):
    return 0.909 * x * jax.nn.sigmoid(x)


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = _lipswish(h)
    o = jnp.dot(h.astype(x.dtype), w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (o + b2_ref[...]).astype(o_ref.dtype)


def _tile(n: int, pref: int) -> int:
    for t in (pref, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if t <= n and n % t == 0:
            return t
    return 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_mlp(x, w1, b1, w2, b2, interpret: bool = True):
    """x: (..., Din) → (..., Dout) through Linear/LipSwish/Linear."""
    orig = x.shape
    din = orig[-1]
    h = w1.shape[1]
    dout = w2.shape[1]
    x2 = x.reshape(-1, din)
    rows = x2.shape[0]
    bm = _tile(rows, 256)
    out = pl.pallas_call(
        _kernel,
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, din), lambda i: (i, 0)),
            pl.BlockSpec((din, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, dout), lambda i: (0, 0)),
            pl.BlockSpec((dout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, dout), x.dtype),
        interpret=interpret,
    )(x2, w1, b1, w2, b2)
    return out.reshape(orig[:-1] + (dout,))
