"""Stepper-generic gradient backends for the SDE solve stack.

Importing this package registers the four built-in backends (in the
user-facing inventory order); :mod:`repro.core.solve` joins them against
the solver registry.  See :mod:`repro.core.gradients.base` for the
protocol and the precision policy.
"""

from .base import (
    GRADIENT_BACKENDS,
    PRECISION_POLICIES,
    GradientBackend,
    PrecisionPolicy,
    available_gradient_modes,
    get_backend,
    register_backend,
    resolve_precision,
)

# registration order == inventory order (keeps the classic three first so
# GRADIENT_MODES stays a superset-extension of its pre-refactor value)
from . import discretise as _discretise  # noqa: F401  (registers "discretise")
from .reversible import (
    reversible_heun_solve,
    reversible_heun_solve_adaptive,
    reversible_heun_solve_final,
)
from .continuous import continuous_adjoint_solve
from .checkpoint import (
    checkpoint_schedule,
    checkpoint_solve,
    checkpoint_solve_adaptive,
)

__all__ = [
    "GRADIENT_BACKENDS",
    "PRECISION_POLICIES",
    "GradientBackend",
    "PrecisionPolicy",
    "available_gradient_modes",
    "checkpoint_schedule",
    "checkpoint_solve",
    "checkpoint_solve_adaptive",
    "continuous_adjoint_solve",
    "get_backend",
    "register_backend",
    "resolve_precision",
    "reversible_heun_solve",
    "reversible_heun_solve_adaptive",
    "reversible_heun_solve_final",
]
