"""Gradient-backend registry tests (DESIGN.md §12).

Three claims pinned here:

1. **Porting was a move, not a rewrite** — dispatching through
   ``solve(gradient_mode=...)`` is BITWISE identical (f64, values and
   gradients) to calling the moved backend functions directly, on the
   fixed-grid, terminal-only, and adaptive paths.
2. **Checkpointing is exact for every registered solver** — recursive
   binomial checkpointing replays the same discrete steps, so its
   gradients match discretise-then-optimise to floating-point noise for
   every solver × noise type, on fixed and adaptive (accepted) grids, and
   its cost schedule follows the nested-scan model.
3. **Invalid combinations fail eagerly by name** — unknown backends,
   unknown precision policies, and illegal solver × mode × flag cells
   raise named ValueErrors at dispatch time, never from inside jit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.brownian import BrownianPath
from repro.core.gradients import (
    GRADIENT_BACKENDS,
    GradientBackend,
    checkpoint_schedule,
    continuous_adjoint_solve,
    register_backend,
    resolve_precision,
    reversible_heun_solve,
    reversible_heun_solve_adaptive,
    reversible_heun_solve_final,
)
from repro.core.solve import (
    GRADIENT_MODES,
    SOLVERS,
    get_solver,
    gradient_capabilities,
    solve,
)


@pytest.fixture(autouse=True)
def _x64_scope():
    """Bitwise-parity claims need f64; scope it to this module."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def _problem(key, batch=4, x_dim=4, w_dim=3, noise="general",
             dtype=jnp.float64, levy_area=None):
    from repro import nn

    k1, k2, kz, kw = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(k1, [x_dim, 8, x_dim], dtype=dtype),
              "g": nn.mlp_init(k2, [x_dim, 8, x_dim * w_dim], dtype=dtype)}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)

    if noise == "general":
        def diffusion(p, t, x):
            out = nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
            return 0.2 * out.reshape(x.shape[:-1] + (x_dim, w_dim))
        w_shape = (batch, w_dim)
    else:
        def diffusion(p, t, x):
            out = nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
            return 0.2 * out[..., :x_dim]
        w_shape = (batch, x_dim)

    z0 = jax.random.normal(kz, (batch, x_dim), dtype)
    bm = BrownianPath(kw, 0.0, 1.0, w_shape, dtype, levy_area=levy_area)
    return params, drift, diffusion, z0, bm


def _grads_equal(g1, g2):
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _max_grad_diff(g1, g2):
    return max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))


# =============================================================================
# Registry contents
# =============================================================================


def test_registry_modes_and_capabilities():
    assert GRADIENT_MODES == ("discretise", "reversible_adjoint",
                              "continuous_adjoint", "checkpoint")
    caps = gradient_capabilities()
    assert set(caps) == set(GRADIENT_MODES)
    # checkpoint and discretise serve EVERY solver; the exact adjoint only
    # the reversible pair; backsolve only the three with a backward
    # integrator
    assert set(caps["checkpoint"]) == set(SOLVERS)
    assert caps["checkpoint"] == caps["discretise"]
    assert caps["reversible_adjoint"] == ("reversible_heun",)
    assert set(caps["continuous_adjoint"]) == {
        "euler_maruyama", "midpoint", "heun"}


def test_backend_terminal_only_flags():
    assert not GRADIENT_BACKENDS["discretise"].terminal_only
    assert not GRADIENT_BACKENDS["reversible_adjoint"].terminal_only
    assert GRADIENT_BACKENDS["continuous_adjoint"].terminal_only
    assert GRADIENT_BACKENDS["checkpoint"].terminal_only


def test_register_backend_requires_adaptive_impl():
    with pytest.raises(ValueError, match="solve_adaptive"):
        register_backend(GradientBackend(
            name="broken", summary="", terminal_only=False,
            supports_adaptive=True, solve=lambda *a, **k: None,
            solve_adaptive=None, validate=lambda *a, **k: None))


# =============================================================================
# Bitwise parity: solve() dispatch vs the moved backend functions
# =============================================================================


def test_reversible_adjoint_dispatch_bitwise_trajectory(key):
    params, drift, diffusion, z0, bm = _problem(key)

    def via_solve(p):
        traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                     solver="reversible_heun",
                     gradient_mode="reversible_adjoint", noise="general")
        return jnp.sum(traj ** 2)

    def direct(p):
        traj = reversible_heun_solve(drift, diffusion, p, z0, bm, 0.0, 1.0,
                                     8, noise="general")
        return jnp.sum(traj ** 2)

    (l1, g1) = jax.value_and_grad(via_solve)(params)
    (l2, g2) = jax.value_and_grad(direct)(params)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _grads_equal(g1, g2)


def test_reversible_adjoint_dispatch_bitwise_final(key):
    params, drift, diffusion, z0, bm = _problem(key)

    def via_solve(p):
        zT = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                   solver="reversible_heun",
                   gradient_mode="reversible_adjoint", noise="general",
                   save_trajectory=False)
        return jnp.sum(zT ** 2)

    def direct(p):
        zT = reversible_heun_solve_final(drift, diffusion, p, z0, bm, 0.0,
                                         1.0, 8, noise="general")
        return jnp.sum(zT ** 2)

    (l1, g1) = jax.value_and_grad(via_solve)(params)
    (l2, g2) = jax.value_and_grad(direct)(params)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _grads_equal(g1, g2)


def test_reversible_adjoint_dispatch_bitwise_adaptive(key):
    params, drift, diffusion, z0, bm = _problem(key)
    kw = dict(rtol=1e-2, atol=1e-4, max_steps=64, dt0=1.0 / 8)

    def via_solve(p):
        zT = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                   solver="reversible_heun",
                   gradient_mode="reversible_adjoint", noise="general",
                   save_trajectory=False, adaptive=True, **kw)
        return jnp.sum(zT ** 2)

    def direct(p):
        zT, converged = reversible_heun_solve_adaptive(
            drift, diffusion, p, z0, bm, kw["rtol"], kw["atol"], 0.0, 1.0,
            kw["max_steps"], kw["dt0"], noise="general")
        # same NaN-poisoning solve() applies (identity when converged)
        zT = jnp.where(converged, zT, jnp.nan)
        return jnp.sum(zT ** 2)

    (l1, g1) = jax.value_and_grad(via_solve)(params)
    (l2, g2) = jax.value_and_grad(direct)(params)
    assert bool(jnp.isfinite(l1))  # the grid converged — parity is real
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _grads_equal(g1, g2)


def test_continuous_adjoint_dispatch_bitwise(key):
    params, drift, diffusion, z0, bm = _problem(key)

    def via_solve(p):
        zT = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                   solver="midpoint", gradient_mode="continuous_adjoint",
                   noise="general", save_trajectory=False)
        return jnp.sum(zT ** 2)

    def direct(p):
        zT = continuous_adjoint_solve(drift, diffusion, p, z0, bm, 0.0, 1.0,
                                      8, solver="midpoint", noise="general")
        return jnp.sum(zT ** 2)

    (l1, g1) = jax.value_and_grad(via_solve)(params)
    (l2, g2) = jax.value_and_grad(direct)(params)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _grads_equal(g1, g2)


# =============================================================================
# Checkpoint backend: exact for every solver x noise, fixed and adaptive
# =============================================================================


@pytest.mark.parametrize("solver,noise", [
    (s, n) for s in sorted(SOLVERS) for n in ("diagonal", "general")
    if n in SOLVERS[s].noise_types])  # capability-aware: srk is diagonal-only
def test_checkpoint_matches_discretise(key, solver, noise):
    params, drift, diffusion, z0, bm = _problem(
        key, noise=noise,
        levy_area="space-time" if SOLVERS[solver].needs_levy_area else None)

    def loss(mode, save_traj):
        def f(p):
            out = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                        solver=solver, gradient_mode=mode, noise=noise,
                        save_trajectory=save_traj)
            return jnp.sum((out[-1] if save_traj else out) ** 2)
        return f

    l1, g1 = jax.value_and_grad(loss("discretise", True))(params)
    l2, g2 = jax.value_and_grad(loss("checkpoint", False))(params)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert _max_grad_diff(g1, g2) <= 1e-10


def test_checkpoint_non_pow2_horizon(key):
    """Padding/masking for n != 2^k must not perturb the real steps."""
    params, drift, diffusion, z0, bm = _problem(key)

    def loss(mode, save_traj, n):
        def f(p):
            out = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, n,
                        solver="heun", gradient_mode=mode, noise="general",
                        save_trajectory=save_traj)
            return jnp.sum((out[-1] if save_traj else out) ** 2)
        return f

    for n in (1, 3, 13):
        l1, g1 = jax.value_and_grad(loss("discretise", True, n))(params)
        l2, g2 = jax.value_and_grad(loss("checkpoint", False, n))(params)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        assert _max_grad_diff(g1, g2) <= 1e-10


def test_checkpoint_adaptive_matches_reversible_adjoint(key):
    """On the controller's accepted grid, checkpoint's freeze-and-replay
    gradients must match the exact adjoint to floating-point noise."""
    params, drift, diffusion, z0, bm = _problem(key)
    kw = dict(adaptive=True, rtol=1e-2, atol=1e-4, max_steps=64,
              dt0=1.0 / 8, save_trajectory=False, noise="general")

    def loss(mode):
        def f(p):
            zT = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                       solver="reversible_heun", gradient_mode=mode, **kw)
            return jnp.sum(zT ** 2)
        return f

    l1, g1 = jax.value_and_grad(loss("reversible_adjoint"))(params)
    l2, g2 = jax.value_and_grad(loss("checkpoint"))(params)
    assert bool(jnp.isfinite(l1))  # converged — the comparison is real
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert _max_grad_diff(g1, g2) <= 1e-10


def test_checkpoint_adaptive_non_reversible_solver(key):
    """The capability the backend exists for: adaptive gradients for a
    solver with NO reversible pair (midpoint has an embedded estimate but
    no exact adjoint)."""
    params, drift, diffusion, z0, bm = _problem(key)

    def f(p):
        zT = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                   solver="midpoint", gradient_mode="checkpoint",
                   noise="general", save_trajectory=False, adaptive=True,
                   rtol=1e-2, atol=1e-4, max_steps=64, dt0=1.0 / 8)
        return jnp.sum(zT ** 2)

    l, g = jax.value_and_grad(f)(params)
    assert bool(jnp.isfinite(l))
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))


def test_checkpoint_schedule_model():
    """Pin the nested-scan cost recursion (the benchmark's memory gate)."""
    s1 = checkpoint_schedule(1)
    assert (s1["depth"], s1["peak_live_states"], s1["recompute_steps"]) == \
        (0, 1, 0)
    for n, depth in ((2, 1), (13, 4), (16, 4), (64, 6), (100, 7)):
        s = checkpoint_schedule(n)
        assert s["depth"] == depth
        assert s["padded_steps"] == 2 ** depth
        # L(k) = 2k + 1; R(2^k) = k 2^k — O(log n) memory, O(n log n) work
        assert s["peak_live_states"] == 2 * depth + 1
        assert s["recompute_steps"] == depth * 2 ** depth
    with pytest.raises(ValueError, match="num_steps"):
        checkpoint_schedule(0)


# =============================================================================
# Precision policy
# =============================================================================


def test_precision_policies_resolve():
    assert resolve_precision("highest").compute_dtype is None
    assert resolve_precision("bf16_compute").compute_dtype == jnp.bfloat16
    with pytest.raises(ValueError, match="unknown precision"):
        resolve_precision("f8_compute")
    with pytest.raises(ValueError, match="unknown precision"):
        solve(lambda p, t, z: z, lambda p, t, z: z, {}, jnp.ones(3),
              BrownianPath(jax.random.PRNGKey(0), 0.0, 1.0, (3,),
                           jnp.float64),
              0.0, 1.0, 4, precision="f8_compute")


def test_precision_highest_is_identity(key):
    params, drift, diffusion, z0, bm = _problem(key)

    def loss(**kw):
        def f(p):
            traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                         noise="general", **kw)
            return jnp.sum(traj[-1] ** 2)
        return f

    l1, g1 = jax.value_and_grad(loss())(params)
    l2, g2 = jax.value_and_grad(loss(precision="highest"))(params)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _grads_equal(g1, g2)


@pytest.mark.parametrize("mode,solver", [
    ("discretise", "heun"),
    ("reversible_adjoint", "reversible_heun"),
    ("checkpoint", "midpoint"),
])
def test_bf16_compute_composes_with_backends(key, mode, solver):
    """The policy wraps fields BEFORE the backend sees them, so every mode
    runs under it; gradients stay in the state dtype (accumulation is not
    degraded) and move by a small nonzero amount (the cast is real)."""
    params, drift, diffusion, z0, bm = _problem(key)
    save_traj = mode not in ("continuous_adjoint", "checkpoint")

    def loss(precision):
        def f(p):
            out = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 8,
                        solver=solver, gradient_mode=mode, noise="general",
                        save_trajectory=save_traj, precision=precision)
            return jnp.sum((out[-1] if save_traj else out) ** 2)
        return f

    g_hi = jax.grad(loss("highest"))(params)
    g_lo = jax.grad(loss("bf16_compute"))(params)
    for v in jax.tree.leaves(g_lo):
        assert v.dtype == jnp.float64
        assert bool(jnp.all(jnp.isfinite(v)))
    diff = _max_grad_diff(g_hi, g_lo)
    assert 0.0 < diff < 1.0


# =============================================================================
# Eager named errors
# =============================================================================


def test_unknown_gradient_mode_lists_registry(key):
    params, drift, diffusion, z0, bm = _problem(key)
    with pytest.raises(ValueError, match="unknown gradient_mode") as e:
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              gradient_mode="bogus", noise="general")
    for mode in GRADIENT_MODES:  # the error must name every backend
        assert mode in str(e.value)


def test_checkpoint_rejects_trajectory_and_pallas(key):
    params, drift, diffusion, z0, bm = _problem(key)
    with pytest.raises(ValueError, match="save_trajectory"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="heun", gradient_mode="checkpoint", noise="general",
              save_trajectory=True)
    with pytest.raises(ValueError, match="pallas"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="reversible_heun", gradient_mode="checkpoint",
              noise="diagonal", save_trajectory=False,
              use_pallas_kernels=True)


def test_mode_not_served_names_capable_solvers(key):
    """A solver x mode miss names the solver AND the solvers that do serve
    the mode — the error is the capability table, not a dead end."""
    params, drift, diffusion, z0, bm = _problem(key)
    with pytest.raises(ValueError) as e:
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="euler_maruyama", gradient_mode="reversible_adjoint",
              noise="general")
    msg = str(e.value)
    assert "euler_maruyama" in msg and "reversible_heun" in msg


def test_continuous_adjoint_adaptive_error_mentions_checkpoint(key):
    """The backsolve/adaptive rejection now points at the backend that CAN
    do adaptive terminal gradients."""
    params, drift, diffusion, z0, bm = _problem(key)
    with pytest.raises(ValueError, match="checkpoint"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="midpoint", gradient_mode="continuous_adjoint",
              noise="general", save_trajectory=False, adaptive=True,
              rtol=1e-2, atol=1e-4, max_steps=16)


def test_launch_step_adjoint_validation():
    from repro.core.sde import LatentSDEConfig
    from repro.launch.steps import make_latent_sde_step

    cfg = LatentSDEConfig(data_dim=2, num_steps=4, use_pallas_kernels=True,
                          exact_adjoint=False)
    with pytest.raises(ValueError, match="pallas"):
        make_latent_sde_step(cfg, lambda g, s, p: (g, s), 4, 5,
                             adjoint="checkpoint")
    with pytest.raises(ValueError, match="adjoint"):
        make_latent_sde_step(cfg, lambda g, s, p: (g, s), 4, 5,
                             adjoint="bogus")
