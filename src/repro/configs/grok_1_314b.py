"""grok-1-314b [moe] — 8 experts top-2.  [hf:xai-org/grok-1; unverified]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    moe=True,
    num_experts=8,
    top_k=2,
    ffn="gelu",
    norm="rmsnorm",
)
