"""Latent SDE on the air-quality-like dataset (paper Table 1 / F.4).

ELBO training (reconstruction + KL path penalty) with the reversible Heun
method and exact adjoint; Adam optimiser per the paper.  Prints ELBO and
signature-MMD of prior samples vs held-out data.

Run:  PYTHONPATH=src python examples/latent_sde_air_quality.py --steps 400
"""

import argparse
import time

import jax

from repro import optim
from repro.core import losses
from repro.core.sde import (LatentSDEConfig, latent_sde_init, latent_sde_loss,
                            latent_sde_sample)
from repro.data.synthetic import air_quality_like


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--solver", default="reversible_heun",
                    choices=("reversible_heun", "midpoint"))
    args = ap.parse_args(argv)

    cfg = LatentSDEConfig(data_dim=2, hidden_dim=16, context_dim=16, width=32,
                          num_steps=23, solver=args.solver,
                          exact_adjoint=args.solver == "reversible_heun",
                          kl_weight=0.1)
    key = jax.random.PRNGKey(0)
    params = latent_sde_init(key, cfg)
    oi, ou = optim.adam(1e-3)
    state = oi(params)

    @jax.jit
    def step_fn(p, s, k):
        ys, _ = air_quality_like(jax.random.fold_in(k, 0), args.batch, 24)
        (loss, parts), g = jax.value_and_grad(
            lambda p_: latent_sde_loss(p_, cfg, jax.random.fold_in(k, 1), ys),
            has_aux=True)(p)
        upd, s = ou(g, s, p)
        return optim.apply_updates(p, upd), s, loss, parts

    t0 = time.time()
    for step in range(args.steps):
        params, state, loss, parts = step_fn(params, state,
                                             jax.random.fold_in(key, 10 + step))
        if step % 50 == 0:
            print(f"step {step:4d}  -ELBO {float(loss):8.4f}  "
                  f"recon {float(parts['recon']):.4f}  "
                  f"kl_path {float(parts['kl_path']):.4f}  "
                  f"({time.time()-t0:.0f}s)", flush=True)

    ys, _ = air_quality_like(jax.random.fold_in(key, 999), 512, 24)
    samples = latent_sde_sample(params, cfg, jax.random.fold_in(key, 1000), 512)
    stride = cfg.num_steps // 23 if cfg.num_steps >= 23 else 1
    mmd = float(losses.signature_mmd(ys, samples[:: max(1, (samples.shape[0]-1)//23)][:24]))
    print(f"final ({args.solver}): sig-MMD(prior samples, held-out) {mmd:.4f}, "
          f"total {time.time()-t0:.0f}s")
    return mmd


if __name__ == "__main__":
    main()
