"""Latent-SDE (VAE) training subsystem tests (paper Appendix B; DESIGN.md §8).

The grid-misalignment regression (the eager ValueError replacing the old
broadcast TypeError / zero-stride crash), the one-``jax.vjp`` ELBO step,
fused-vs-unfused equivalence, the backsolve baseline, and the launch CLI on
1 and 2 (simulated) devices.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.sde import (LatentSDEConfig, latent_sde_init, latent_sde_loss,
                            latent_sde_loss_terminal, validate_latent_grid)
from repro.data.synthetic import air_quality_like
from repro.launch.steps import make_latent_sde_optimizer, make_latent_sde_step

BATCH, SEQ = 8, 9  # data grid: 9 observations => T = 8 intervals


def _tiny_setup(key, num_steps=8, adjoint="exact", **cfg_kw):
    cfg_kw.setdefault("solver",
                      "midpoint" if adjoint == "backsolve" else "reversible_heun")
    cfg_kw.setdefault("exact_adjoint", adjoint == "exact")
    cfg = LatentSDEConfig(data_dim=2, hidden_dim=8, context_dim=8, width=16,
                          num_steps=num_steps, kl_weight=0.1, **cfg_kw)
    params = latent_sde_init(key, cfg)
    oi, ou = make_latent_sde_optimizer(lr=1e-2)
    step = jax.jit(make_latent_sde_step(cfg, ou, BATCH, SEQ, adjoint=adjoint))
    return cfg, params, oi(params), step


# -----------------------------------------------------------------------------
# grid misalignment: the bugfix regression tests
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("num_steps", [30, 4])
def test_latent_sde_loss_rejects_misaligned_grid(key, num_steps):
    """num_steps=30, T=8 used to die in a broadcast TypeError; num_steps=4,
    T=8 in 'slice step cannot be zero'.  Both must now raise an eager
    ValueError naming cfg.num_steps and T."""
    cfg = LatentSDEConfig(data_dim=2, hidden_dim=8, context_dim=8, width=16,
                          num_steps=num_steps)
    params = latent_sde_init(key, cfg)
    ys, _ = air_quality_like(jax.random.fold_in(key, 1), BATCH, SEQ)
    with pytest.raises(ValueError, match=rf"num_steps \({num_steps}\).*T \(8"):
        latent_sde_loss(params, cfg, key, ys)
    with pytest.raises(ValueError, match=rf"num_steps \({num_steps}\).*T \(8"):
        latent_sde_loss_terminal(params, cfg, key, ys)


def test_validate_latent_grid_accepts_multiples():
    for T in (4, 8, 23):
        for k in (1, 2, 5):
            assert validate_latent_grid(k * T, T) == k
    with pytest.raises(ValueError, match=r"at least two observations"):
        validate_latent_grid(8, 0)


def test_misaligned_grid_raises_under_jit(key):
    """Shapes are static, so the named error surfaces at trace time even
    inside jit — not an opaque XLA failure."""
    cfg = LatentSDEConfig(data_dim=2, hidden_dim=8, context_dim=8, width=16,
                          num_steps=30)
    params = latent_sde_init(key, cfg)
    ys, _ = air_quality_like(jax.random.fold_in(key, 1), BATCH, SEQ)
    f = jax.jit(lambda p: latent_sde_loss(p, cfg, key, ys)[0])
    with pytest.raises(ValueError, match=r"num_steps \(30\)"):
        f(params)


# -----------------------------------------------------------------------------
# the step builder: eager config validation
# -----------------------------------------------------------------------------


def test_step_builder_validates_eagerly(key):
    cfg = LatentSDEConfig(data_dim=2, hidden_dim=8, context_dim=8, width=16,
                          num_steps=8)
    _, ou = make_latent_sde_optimizer()
    # misaligned grid at build time (before any data exists)
    with pytest.raises(ValueError, match=r"num_steps \(8\).*T \(6"):
        make_latent_sde_step(cfg, ou, BATCH, 7)
    # wrong data dimensionality for the air-quality workload
    bad = LatentSDEConfig(data_dim=3, num_steps=8)
    with pytest.raises(ValueError, match="data_dim"):
        make_latent_sde_step(bad, ou, BATCH, SEQ)
    # unknown adjoint name
    with pytest.raises(ValueError, match="adjoint"):
        make_latent_sde_step(cfg, ou, BATCH, SEQ, adjoint="magic")
    # backsolve needs a continuous-adjoint-capable solver
    with pytest.raises(ValueError, match="backsolve"):
        make_latent_sde_step(cfg, ou, BATCH, SEQ, adjoint="backsolve")
    # fusion is exact-adjoint-only
    fused_backsolve = LatentSDEConfig(data_dim=2, num_steps=8,
                                      solver="midpoint", exact_adjoint=False,
                                      use_pallas_kernels=True)
    with pytest.raises(ValueError, match="use_pallas_kernels"):
        make_latent_sde_step(fused_backsolve, ou, BATCH, SEQ,
                             adjoint="backsolve")
    with pytest.raises(ValueError, match="use_pallas_kernels"):
        make_latent_sde_step(fused_backsolve, ou, BATCH, SEQ)


# -----------------------------------------------------------------------------
# training behaviour
# -----------------------------------------------------------------------------


def test_elbo_step_decreases_loss_deterministically(key):
    """A few ELBO steps on a fixed batch decrease -ELBO, and the whole
    trajectory is a pure function of the seed (bitwise-identical re-run)."""

    def run():
        cfg, params, state, step = _tiny_setup(key)
        k = jax.random.fold_in(key, 2)
        out = []
        for _ in range(6):  # metrics are pre-update ⇒ 6 calls see 5 updates
            params, state, m = step(params, state, k)
            out.append(float(m["loss"]))
        return out

    a, b = run(), run()
    assert a == b, f"nondeterministic trajectory: {a} vs {b}"
    assert a[-1] < a[0], f"-ELBO not decreasing: {a}"


def test_fused_step_matches_unfused(key):
    """cfg.use_pallas_kernels routes the posterior solve through the fused
    path (jnp oracle on CPU, compiled kernels on TPU) — one optimiser step
    must agree with the unfused path to float tolerance."""
    outs = {}
    for fused in (False, True):
        cfg, params, state, step = _tiny_setup(key, use_pallas_kernels=fused)
        p1, _, m = step(params, state, jax.random.fold_in(key, 2))
        outs[fused] = (p1, float(m["loss"]))
    assert outs[True][1] == pytest.approx(outs[False][1], abs=1e-6)
    for a, b in zip(jax.tree.leaves(outs[True][0]),
                    jax.tree.leaves(outs[False][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_backsolve_step_runs_and_matches_metric_schema(key):
    """The continuous-adjoint baseline path of the shared step builder is
    runnable and reports the same metric schema as the exact path
    (benchmarks/latent_sde.py relies on both)."""
    for adjoint in ("exact", "backsolve"):
        cfg, params, state, step = _tiny_setup(key, adjoint=adjoint)
        params, _, m = step(params, state, jax.random.fold_in(key, 2))
        assert set(m) == {"loss", "recon", "kl_path", "kl_v"}
        assert all(np.isfinite(float(v)) for v in m.values())
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(params))


def test_terminal_and_trajectory_elbo_agree_roughly(key):
    """The terminal-form ELBO (recon as a state channel) is a quadrature of
    the same objective the trajectory form sums over observations — the two
    must agree to solver-truncation accuracy on an aligned grid."""
    cfg = LatentSDEConfig(data_dim=2, hidden_dim=8, context_dim=8, width=16,
                          num_steps=64, kl_weight=0.1)
    params = latent_sde_init(key, cfg)
    ys, _ = air_quality_like(jax.random.fold_in(key, 1), 16, SEQ)
    l_traj, _ = latent_sde_loss(params, cfg, jax.random.fold_in(key, 2), ys)
    l_term, _ = latent_sde_loss_terminal(params, cfg,
                                         jax.random.fold_in(key, 2), ys)
    assert float(l_term) == pytest.approx(float(l_traj), rel=0.25)


# -----------------------------------------------------------------------------
# the launch CLI, 1 and 2 (simulated) devices
# -----------------------------------------------------------------------------


def _run_train_cli(extra_env=None, extra_args=()):
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    env.update(extra_env or {})
    cmd = [sys.executable, "-m", "launch.train", "--workload", "latent-sde",
           "--steps", "2", "--batch", "8", "--sde-steps", "8",
           "--seq-len", "9", *extra_args]
    return subprocess.run(cmd, cwd=repo, env=env, capture_output=True,
                          text=True, timeout=600)


def test_train_cli_single_device():
    r = _run_train_cli()
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[latent-sde] done" in r.stdout


def test_train_cli_two_simulated_devices():
    r = _run_train_cli(
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "data-parallel over 2 devices" in r.stdout
    assert "[latent-sde] done" in r.stdout


def test_train_cli_rejects_misaligned_grid():
    """The CLI surfaces the named grid error, not a crash."""
    r = _run_train_cli(extra_args=("--sde-steps", "30"))
    assert r.returncode != 0
    assert "num_steps (30)" in r.stderr and "T (8" in r.stderr
