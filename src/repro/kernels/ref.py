"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written with no regard for
memory movement — tests sweep shapes/dtypes and assert the kernels match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..nn.core import lipswish
from . import prng


# -----------------------------------------------------------------------------
# reversible Heun fused state updates (diagonal noise)
# -----------------------------------------------------------------------------


def rev_heun_phase1(z, zh, mu, sigma, dw, dt, sign: float = 1.0):
    """ẑ_{n+1} = 2 z_n − ẑ_n + μ_n Δt + σ_n ΔW_n   (Algorithm 1, line 3).

    ``sign=-1.0`` is the algebraic inverse (Algorithm 2), matching the
    fused kernel's contract.  ``dt`` may be a Python float or a traced
    scalar (the adaptive driver's step size).
    """
    return 2.0 * z - zh + mu * (sign * dt) + (sign * sigma) * dw


def rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt, sign: float = 1.0):
    """z_{n+1} = z_n + ½(μ_n+μ_{n+1})Δt + ½(σ_n+σ_{n+1})ΔW_n."""
    return z + (sign * 0.5 * dt) * (mu + mu1) + (sign * 0.5) * (sigma + sigma1) * dw


# -----------------------------------------------------------------------------
# reversible Heun hand-derived backward (cotangent) phases
# -----------------------------------------------------------------------------
#
# The transpose of one Algorithm-1 step, factored around the single
# vector-field VJP exactly as DESIGN.md §3 derives it.  Op order is chosen
# so each output is BITWISE what ``jax.vjp`` of the unfused stepper
# produces (power-of-two scalings commute with IEEE rounding; two-term sums
# keep the transpose's grouping) — tests/test_adjoint.py pins fused ≡
# unfused gradients to 0.0 in f64 on the strength of this.


def rev_heun_bwd_phase1(g_z1, g_mu1, g_sig1, dw, dt):
    """Pre-field cotangents: seed the vector-field VJP.

    ``c_mu1 = ḡ_mu1 + ½Δt·ḡ_z1`` and ``c_sig1 = ḡ_sig1 + ½ΔW·ḡ_z1`` —
    the phase-2 (z₁) transpose contributions joined with the direct
    output cotangents of μ₁/σ₁.
    """
    c_mu1 = g_mu1 + 0.5 * (g_z1 * dt)
    c_sig1 = g_sig1 + 0.5 * (g_z1 * dw)
    return c_mu1, c_sig1


def rev_heun_bwd_phase2(g_z1, ghat, dw, dt):
    """Post-field cotangents: distribute ``ĝ`` (the total ẑ₁ cotangent,
    i.e. ``ḡ_zh1`` + the field VJP's ẑ₁ contribution) onto the step-``n``
    state.  Returns ``(d_z, d_zh, d_mu, d_sigma)``.
    """
    d_z = g_z1 + 2.0 * ghat
    d_zh = -ghat
    d_mu = 0.5 * (g_z1 * dt) + ghat * dt
    d_sigma = 0.5 * (g_z1 * dw) + ghat * dw
    return d_z, d_zh, d_mu, d_sigma


# -----------------------------------------------------------------------------
# counter-based Brownian generation (bitwise jax.random / BrownianPath)
# -----------------------------------------------------------------------------


def brownian_increment(k1, k2, n, shape, dtype, dt):
    """Step-``n`` increment of a ``num_steps`` uniform grid — bitwise
    ``BrownianPath.increment(n, num_steps)`` with ``dt = span/num_steps``.

    ``k1, k2``: the path key's raw uint32 scalars (``prng.key_data_pair``).
    """
    dtype = jnp.dtype(dtype)
    f1, f2 = prng.fold_in(k1, k2, n)
    z = prng.normal_like(f1, f2, tuple(shape), dtype)
    return z * jnp.sqrt(jnp.asarray(dt, dtype))


def brownian_value(k1, k2, t, t0, t1, shape, dtype, depth: int = 24):
    """``W(t) − W(t0)`` by Lévy-bridge descent — bitwise
    ``BrownianPath.value(t, depth)``.

    Identical conditioning to ``BrownianPath._w`` but with the descent
    *vectorised*: the interval sequence, per-level bridge stds and
    per-level midpoint keys depend only on ``t`` (scalar work), so all
    ``depth`` midpoint normals are drawn in ONE batched threefry+erf_inv
    call instead of ``depth`` sequential full-shape draws — the op
    sequence per element is unchanged, so every draw is bit-identical.
    """
    dtype = jnp.dtype(dtype)
    shape = tuple(shape)
    t = jnp.asarray(t, dtype)
    span = t1 - t0
    r1, r2 = prng.fold_in(k1, k2, jnp.uint32(0xB0B))
    w_t1 = prng.normal_like(r1, r2, shape, dtype) * jnp.sqrt(
        jnp.asarray(span, dtype))

    # -- scalar descent: intervals, stds, direction bits, midpoint keys
    def scal_body(i, c):
        a, b, c1, c2, stds, gos, km1s, km2s = c
        m = 0.5 * (a + b)
        std = jnp.sqrt(jnp.asarray((b - m) * (m - a) / (b - a), dtype))
        go_left = t <= m
        f1, f2 = prng.fold_in(c1, c2, jnp.uint32(1))
        n1, n2 = prng.fold_in(
            c1, c2, jnp.where(go_left, jnp.uint32(2), jnp.uint32(3)))
        stds = stds.at[i].set(std)
        gos = gos.at[i].set(go_left)
        km1s = km1s.at[i].set(f1)
        km2s = km2s.at[i].set(f2)
        a2 = jnp.where(go_left, a, m)
        b2 = jnp.where(go_left, m, b)
        return (a2, b2, n1, n2, stds, gos, km1s, km2s)

    a0 = jnp.asarray(t0, dtype)
    b0 = jnp.asarray(t1, dtype)
    u0 = jnp.zeros((depth,), jnp.uint32)
    a, b, _, _, stds, gos, km1s, km2s = lax.fori_loop(
        0, depth, scal_body,
        (a0, b0, r1, r2, jnp.zeros((depth,), dtype),
         jnp.zeros((depth,), bool), u0, u0))

    # -- ONE batched midpoint draw for all levels (the wall-clock win)
    zms = jax.vmap(lambda u, v: prng.normal_like(u, v, shape, dtype))(
        km1s, km2s)

    # -- cheap sequential combine (elementwise FMAs + selects only)
    def comb_body(i, c):
        wa, wb = c
        wm = 0.5 * (wa + wb) + stds[i] * zms[i]
        return (jnp.where(gos[i], wa, wm), jnp.where(gos[i], wm, wb))

    wa, wb = lax.fori_loop(0, depth, comb_body,
                           (jnp.zeros(shape, dtype), w_t1))
    frac = jnp.clip((t - a) / jnp.maximum(b - a, jnp.finfo(dtype).tiny),
                    0.0, 1.0)
    return wa + frac * (wb - wa)


# -----------------------------------------------------------------------------
# fused vector-field MLP (Linear → LipSwish → Linear)
# -----------------------------------------------------------------------------


def fused_mlp(x, w1, b1, w2, b2):
    h = lipswish(x @ w1 + b1)
    return h @ w2 + b2


# -----------------------------------------------------------------------------
# causal GQA flash attention
# -----------------------------------------------------------------------------


def flash_attention(q, k, v, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(D).astype(q.dtype)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv)


# -----------------------------------------------------------------------------
# Mamba2 SSD chunk scan
# -----------------------------------------------------------------------------


def ssd_scan(x, a, b, c):
    """Naive sequential SSD recurrence (the definition).

    x: (B, H, S, P) inputs, a: (B, H, S) log-decay (<= 0),
    b, c: (B, H, S, N) input/output projections.
    h_t = exp(a_t)·h_{t-1} + b_t ⊗ x_t ;  y_t = cᵀ_t h_t.  Returns (B,H,S,P).
    """
    Bb, H, S, P = x.shape
    N = b.shape[-1]

    def per_head(xh, ah, bh, ch):
        def step(h, inp):
            xt, at, bt, ct = inp
            h = jnp.exp(at) * h + bt[:, None] * xt[None, :]
            return h, ct @ h

        h0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xh.astype(jnp.float32), ah.astype(jnp.float32),
                                        bh.astype(jnp.float32), ch.astype(jnp.float32)))
        return ys.astype(x.dtype)

    f = jax.vmap(jax.vmap(per_head))
    return f(x, a, b, c)


# -----------------------------------------------------------------------------
# fused softmax cross entropy
# -----------------------------------------------------------------------------


def fused_xent(logits, labels):
    """Per-token next-token cross entropy; logsumexp in f32.
    logits: (..., V); labels: (...) int32 -> (...) f32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return lse - ll
