"""Front-end tests: registry dispatch, batched solving, Pallas-fused paths.

Covers the unified ``repro.solve()`` surface:

* every registered solver × gradient-mode combination accepts or rejects
  exactly as its :class:`repro.core.solve.SolverSpec` declares;
* vmapped multi-trajectory ``solve_batched`` matches a Python loop of
  single solves bitwise;
* the Pallas-fused reversible Heun (interpret mode on CPU) matches the
  unfused path on forward trajectories AND parameter gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.brownian import BrownianPath
from repro.core.solve import (GRADIENT_MODES, SOLVERS, SolverSpec,
                              get_solver, register_solver, solve,
                              solve_batched)
from repro.core.solvers import NFE_PER_STEP


def _ou():
    params = {"theta": jnp.float32(1.2), "mu": jnp.float32(0.5),
              "sigma": jnp.float32(0.3)}
    drift = lambda p, t, x: p["theta"] * (p["mu"] - x)
    diffusion = lambda p, t, x: p["sigma"] * jnp.ones_like(x)
    return params, drift, diffusion


def _neural(key, x_dim=6, dtype=jnp.float32):
    from repro import nn

    k1, k2 = jax.random.split(key)
    p = {"f": nn.mlp_init(k1, [x_dim, 16, x_dim], dtype=dtype),
         "g": nn.mlp_init(k2, [x_dim, 16, x_dim], dtype=dtype)}
    drift = lambda p_, t, x: nn.mlp(p_["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p_, t, x: 0.2 * nn.mlp(p_["g"], x, nn.lipswish, jnp.tanh)
    return p, drift, diffusion


# -----------------------------------------------------------------------------
# registry dispatch
# -----------------------------------------------------------------------------


def test_registry_contains_all_solvers():
    assert repro.available_solvers() == (
        "euler_maruyama", "heun", "midpoint", "reversible_heun", "srk")
    for spec in SOLVERS.values():
        assert spec.nfe_per_step == NFE_PER_STEP[spec.name]
        assert spec.gradient_modes  # never empty


@pytest.mark.parametrize("solver", sorted(SOLVERS))
@pytest.mark.parametrize("mode", GRADIENT_MODES)
def test_every_solver_mode_combination_dispatches_or_rejects(key, solver, mode):
    """Supported combos run and return the right shape; unsupported combos
    raise ValueError naming the solver — never silently fall back."""
    params, drift, diffusion = _ou()
    z0 = jnp.ones((4, 3))
    spec = get_solver(solver)
    bm = BrownianPath(key, 0.0, 1.0, (4, 3),
                      levy_area="space-time" if spec.needs_levy_area else None)
    save_traj = mode not in ("continuous_adjoint", "checkpoint")
    run = lambda: solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8,
                        solver=solver, gradient_mode=mode,
                        save_trajectory=save_traj)
    if mode in get_solver(solver).gradient_modes:
        out = run()
        assert out.shape == ((9, 4, 3) if save_traj else (4, 3))
        # and the gradient path is actually wired
        g = jax.grad(lambda p: jnp.sum(solve(
            drift, diffusion, p, z0, bm, 0.0, 1.0, 8, solver=solver,
            gradient_mode=mode, save_trajectory=save_traj)[-1]))(params)
        assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
    else:
        with pytest.raises(ValueError, match=solver):
            run()


def test_registry_embedded_pairs():
    """Adaptive capability is registry data: every solver except
    euler_maruyama carries an embedded error estimate (reversible Heun's
    z−ẑ gap increment; Heun/midpoint's Euler pair)."""
    for name, spec in SOLVERS.items():
        if name == "euler_maruyama":
            assert spec.embedded_stepper is None
        else:
            assert spec.embedded_stepper is not None, name


def test_unknown_solver_and_mode_rejected(key):
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 2))
    bm = BrownianPath(key, 0.0, 1.0, (2, 2))
    with pytest.raises(ValueError, match="unknown solver"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4, solver="rk45")
    with pytest.raises(ValueError, match="unknown gradient_mode"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              gradient_mode="magic")
    with pytest.raises(ValueError, match="unknown noise"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4, noise="weird")


def test_pallas_flag_validation(key):
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 4))
    bm = BrownianPath(key, 0.0, 1.0, (2, 4))
    # discretise + pallas: AD can't trace pallas_call -> eager rejection
    with pytest.raises(ValueError, match="discretise"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="reversible_heun", use_pallas_kernels=True)
    # non-reversible solver has no fused path
    with pytest.raises(ValueError, match="no fused Pallas path"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="midpoint", use_pallas_kernels=True)
    # general noise unsupported by the elementwise kernels
    with pytest.raises(ValueError, match="diagonal"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="reversible_heun", gradient_mode="reversible_adjoint",
              noise="general", use_pallas_kernels=True)


def test_pallas_flag_validation_is_mode_not_adaptivity(key):
    """The pallas rejection table is about gradient mode and noise, NOT
    adaptivity: adaptive × pallas × discretise is still rejected (plain AD
    cannot trace pallas_call), while the same flags under
    reversible_adjoint are legal — the fused kernels take the controller's
    dt as a traced scalar operand (covered end-to-end in
    tests/test_adaptive.py)."""
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 4))
    bm = BrownianPath(key, 0.0, 1.0, (2, 4))
    with pytest.raises(ValueError, match="discretise"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="reversible_heun", use_pallas_kernels=True,
              save_trajectory=False, adaptive=True)
    with pytest.raises(ValueError, match="diagonal"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="reversible_heun", gradient_mode="reversible_adjoint",
              noise="general", use_pallas_kernels=True,
              save_trajectory=False, adaptive=True)


def test_bridge_depth_validation(key):
    """bridge_depth is an adaptive-only BrownianPath-only option; every
    invalid use is rejected eagerly with an actionable message."""
    from repro.core.brownian import DenseBrownianPath

    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 4))
    bm = BrownianPath(key, 0.0, 1.0, (2, 4))
    # fixed-grid solve would silently ignore it
    with pytest.raises(ValueError, match="adaptive-mode options"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              bridge_depth=10)
    # a fixed-resolution path has no descent to cap
    dbm = DenseBrownianPath.sample(key, 0.0, 1.0, 16, (2, 4))
    with pytest.raises(ValueError, match="fixed resolution"):
        solve(drift, diffusion, params, z0, dbm, 0.0, 1.0, 4,
              adaptive=True, save_trajectory=False, bridge_depth=10)
    # nonsensical depths
    with pytest.raises(ValueError, match="positive int"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              adaptive=True, save_trajectory=False, bridge_depth=0)
    # and the valid case runs (depth caps the descent, still converges)
    out = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 16,
                adaptive=True, save_trajectory=False, bridge_depth=12)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_register_solver_validates_specs():
    with pytest.raises(ValueError, match="unknown gradient mode"):
        register_solver(SolverSpec(
            "bad", lambda *a: None, None, 1, 0.5, ("nope",)))
    with pytest.raises(ValueError, match="reverse_stepper"):
        register_solver(SolverSpec(
            "bad", lambda *a: None, None, 1, 0.5, ("reversible_adjoint",)))
    assert "bad" not in SOLVERS


def test_registered_custom_solver_dispatches(key):
    """A solver added via register_solver() is actually runnable through
    solve() — the registry's stepper is dispatched, not a hardcoded dict."""
    calls = {"n": 0}

    def drifted_euler(z, t, dt, dw, drift, diffusion, params, noise):
        calls["n"] += 1
        from repro.core.solvers import apply_diffusion
        return z + drift(params, t, z) * dt + apply_diffusion(
            diffusion(params, t, z), dw, noise)

    register_solver(SolverSpec(
        "custom_euler", drifted_euler, None, nfe_per_step=1, strong_order=0.5,
        gradient_modes=("discretise",), notes="test-only"))
    try:
        params, drift, diffusion = _ou()
        z0 = jnp.ones((2, 3))
        bm = BrownianPath(key, 0.0, 1.0, (2, 3))
        out = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8,
                    solver="custom_euler")
        ref = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8,
                    solver="euler_maruyama")
        assert calls["n"] > 0
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    finally:
        del SOLVERS["custom_euler"]


def test_custom_solver_rejected_for_unimplemented_adjoints(key):
    """Adjoint backends that only exist for the builtin steppers refuse
    custom solvers eagerly instead of silently integrating with the wrong
    numerics (backward-Euler fallback / reversible-Heun machinery)."""
    step = lambda z, t, dt, dw, dr, di, p, n: z
    register_solver(SolverSpec(
        "custom_ca", step, None, nfe_per_step=1, strong_order=0.5,
        gradient_modes=("discretise", "continuous_adjoint")))
    register_solver(SolverSpec(
        "custom_ra", step, step, nfe_per_step=1, strong_order=0.5,
        gradient_modes=("reversible_adjoint",)))
    try:
        params, drift, diffusion = _ou()
        z0 = jnp.ones((2, 2))
        bm = BrownianPath(key, 0.0, 1.0, (2, 2))
        with pytest.raises(ValueError, match="continuous-adjoint backward"):
            solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
                  solver="custom_ca", gradient_mode="continuous_adjoint",
                  save_trajectory=False)
        with pytest.raises(ValueError, match="reversible-Heun stepper pair"):
            solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
                  solver="custom_ra", gradient_mode="reversible_adjoint")
    finally:
        del SOLVERS["custom_ca"], SOLVERS["custom_ra"]


def test_continuous_adjoint_requires_terminal_only(key):
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 2))
    bm = BrownianPath(key, 0.0, 1.0, (2, 2))
    with pytest.raises(ValueError, match="save_trajectory"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 4,
              solver="midpoint", gradient_mode="continuous_adjoint")


# -----------------------------------------------------------------------------
# batched multi-trajectory solving
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("solver", ["euler_maruyama", "reversible_heun"])
def test_batched_matches_looped_single_solves(key, solver):
    """solve_batched == a Python loop of solves, per-trajectory."""
    params, drift, diffusion = _neural(key)
    B = 5
    z0 = jax.random.normal(jax.random.fold_in(key, 1), (B, 6))
    keys = jax.random.split(jax.random.fold_in(key, 2), B)

    batched = solve_batched(drift, diffusion, params, z0, keys, 0.0, 1.0, 16,
                            solver=solver)
    assert batched.shape == (B, 17, 6)
    for i in range(B):
        bm = BrownianPath(keys[i], 0.0, 1.0, (6,))
        single = solve(drift, diffusion, params, z0[i], bm, 0.0, 1.0, 16,
                       solver=solver)
        np.testing.assert_allclose(np.asarray(batched[i]), np.asarray(single),
                                   rtol=1e-6, atol=1e-6)


def test_batched_gradients_through_exact_adjoint(key):
    """grad of a vmapped exact-adjoint ensemble equals the sum of
    per-trajectory grads."""
    params, drift, diffusion = _neural(key)
    B = 3
    z0 = jax.random.normal(jax.random.fold_in(key, 1), (B, 6))
    keys = jax.random.split(jax.random.fold_in(key, 2), B)

    def batched_loss(p):
        traj = solve_batched(drift, diffusion, p, z0, keys, 0.0, 1.0, 8,
                             solver="reversible_heun",
                             gradient_mode="reversible_adjoint")
        return jnp.sum(traj[:, -1] ** 2)

    def looped_loss(p):
        tot = 0.0
        for i in range(B):
            bm = BrownianPath(keys[i], 0.0, 1.0, (6,))
            traj = solve(drift, diffusion, p, z0[i], bm, 0.0, 1.0, 8,
                         solver="reversible_heun",
                         gradient_mode="reversible_adjoint")
            tot = tot + jnp.sum(traj[-1] ** 2)
        return tot

    gb = jax.grad(batched_loss)(params)
    gl = jax.grad(looped_loss)(params)
    for a, b in zip(jax.tree.leaves(gb), jax.tree.leaves(gl)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_batched_shape_mismatch_rejected(key):
    params, drift, diffusion = _ou()
    with pytest.raises(ValueError, match="batch"):
        solve_batched(drift, diffusion, params, jnp.ones((4, 2)),
                      jax.random.split(key, 3), 0.0, 1.0, 4)


# -----------------------------------------------------------------------------
# Pallas-fused reversible Heun (interpret mode on CPU)
# -----------------------------------------------------------------------------


def test_pallas_fused_forward_matches_unfused(key):
    params, drift, diffusion = _neural(key)
    z0 = jax.random.normal(jax.random.fold_in(key, 1), (4, 6))
    bm = BrownianPath(jax.random.fold_in(key, 2), 0.0, 1.0, (4, 6))

    kw = dict(solver="reversible_heun", gradient_mode="reversible_adjoint")
    fused = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 32,
                  use_pallas_kernels=True, **kw)
    unfused = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 32, **kw)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)


def test_pallas_fused_gradients_match_unfused(key):
    """Acceptance bar: fused forward + fused backward reconstruction agree
    with the unfused exact adjoint on parameter gradients to <= 1e-5."""
    params, drift, diffusion = _neural(key)
    z0 = jax.random.normal(jax.random.fold_in(key, 1), (4, 6))
    bm = BrownianPath(jax.random.fold_in(key, 2), 0.0, 1.0, (4, 6))

    def loss(p, fused):
        traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 32,
                     solver="reversible_heun",
                     gradient_mode="reversible_adjoint",
                     use_pallas_kernels=fused)
        return jnp.mean(traj[-1] ** 2)

    gf = jax.grad(lambda p: loss(p, True))(params)
    gu = jax.grad(lambda p: loss(p, False))(params)
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_pallas_fused_under_jit_and_vmap(key):
    """The fused path composes with jit and with batched solving."""
    params, drift, diffusion = _ou()
    B = 3
    z0 = jnp.zeros((B, 4))
    keys = jax.random.split(key, B)
    f = jax.jit(lambda p: solve_batched(
        drift, diffusion, p, z0, keys, 0.0, 1.0, 8,
        solver="reversible_heun", gradient_mode="reversible_adjoint",
        use_pallas_kernels=True))
    out = f(params)
    ref = solve_batched(drift, diffusion, params, z0, keys, 0.0, 1.0, 8,
                        solver="reversible_heun",
                        gradient_mode="reversible_adjoint")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_fused_step_dispatch_interpret_matches_oracle(key):
    """The fused-step dispatcher (DESIGN.md §5): off-TPU the auto path runs
    the fused jnp oracle, and ``interpret=True`` forces the Pallas
    interpreter — both must agree with the plain unfused stepper, for the
    forward step AND the sign=-1 reverse reconstruction."""
    from repro.core.solvers import (RevHeunState, reversible_heun_reverse_step,
                                    reversible_heun_step)

    k1, k2 = jax.random.split(key)
    drift = lambda p, t, z: -p * z
    diffusion = lambda p, t, z: 0.3 * jnp.ones_like(z)
    p = jnp.float32(0.7)
    z = jax.random.normal(k1, (4, 8))
    state = RevHeunState(z, z, drift(p, 0.0, z), diffusion(p, 0.0, z))
    dw = 0.1 * jax.random.normal(k2, (4, 8))

    variants = {}
    for name, kw in (("unfused", dict(use_pallas=False)),
                     ("oracle", dict(use_pallas=True)),          # auto: off-TPU
                     ("interpret", dict(use_pallas=True, interpret=True))):
        fwd = reversible_heun_step(state, 0.0, 0.125, dw, drift, diffusion,
                                   p, "diagonal", **kw)
        rev = reversible_heun_reverse_step(fwd, 0.125, 0.125, dw, drift,
                                           diffusion, p, "diagonal", **kw)
        variants[name] = (fwd, rev)
    for name in ("oracle", "interpret"):
        for got, want in zip(jax.tree.leaves(variants[name]),
                             jax.tree.leaves(variants["unfused"])):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6, atol=1e-6)
    # the reverse step must reconstruct the pre-step state (Algorithm 2)
    for got, want in zip(jax.tree.leaves(variants["oracle"][1]),
                         jax.tree.leaves(tuple(state))):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
