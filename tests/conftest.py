import jax
import pytest


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
