"""Paper Tables 1/4/5 (speed axis): reversible Heun vs midpoint/Heun.

All timings go through the unified :func:`repro.solve` front-end.  Three
comparisons:

1. **Solver × gradient-mode** (the paper's headline): wall time + NFE of a
   full forward+backward through an SDE-GAN-scale Neural SDE.  Reversible
   Heun needs 1 NFE/step (vs 2) and the O(1)-memory exact adjoint — the
   up-to-1.98× training-speed win of Table 1.
2. **SRK vs reversible Heun** (diagonal noise, same step count): the
   wall-clock price of the order-1.5 SRK step — 5 NFE plus the (W, H)
   space-time Lévy-area draw per step vs 1 NFE plus a plain W draw.  The
   accuracy side of that trade (the error-vs-NFE crossing) is gated in
   ``benchmarks/convergence.py``.
3. **Fused vs unfused**: the reversible-Heun hot loop with and without the
   Pallas step kernels (``use_pallas_kernels``).  On TPU the fused kernels
   collapse ~6 HBM round-trips per step into one read+write per operand;
   off-TPU the fused flag dispatches to the fused jnp oracle (DESIGN.md
   §5), so the CPU number is a parity check, not a kernel speed claim.
4. **Batched vs looped**: ``repro.solve_batched`` (one vmapped XLA program
   over a batch of initial states × Brownian seeds) against a Python loop
   of single solves.
5. **Adaptive vs matched-error fixed grid**: wall clock of the embedded
   error-controlled solve against the uniform grid that reaches the same
   strong error, on a neural-perturbed stiffness burst with
   ``bridge_depth`` capping the Lévy-bridge descent.  Gated in-bench at
   2.25× (``adaptive_over_fixed_ratio``; true value ≈1.9 on a 1-core CPU
   runner — the margin is scheduler noise, the gate is for the ~4.3×
   regression mode).
6. **Backward cost model**: analytic HBM-byte ratio of the unfused
   elementwise backward chain vs the fused kernel pair, from the oracle
   jaxprs.  Gated in-bench at >= 1 (``bwd_hbm_bytes_ratio``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

try:
    from . import report
except ImportError:  # run as a loose script
    import report


def _timeit(fn, *args, reps: int = 5):
    """Best-of-``reps`` individually timed calls after a compile + warm run
    (EXPERIMENTS.md §Protocol: timing noise is one-sided, the min is the
    robust statistic on a shared runner — this suite once averaged, which
    left the ``adaptive_over_fixed_ratio`` gate flapping at its margin)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_solver(solver: str, exact_adjoint: bool, num_steps: int = 64,
                 batch: int = 128, reps: int = 5):
    from repro.core.brownian import BrownianPath
    from repro.core.solve import get_solver, solve
    from repro import nn

    key = jax.random.PRNGKey(0)
    x_dim, w_dim, width = 32, 16, 64
    kp1, kp2, kz, kw = jax.random.split(key, 4)
    params = {
        "f": nn.mlp_init(kp1, [1 + x_dim, width, x_dim]),
        "g": nn.mlp_init(kp2, [1 + x_dim, width, x_dim * w_dim]),
    }

    def tcat(t, x):
        tt = jnp.broadcast_to(jnp.asarray(t, x.dtype), x.shape[:-1] + (1,))
        return jnp.concatenate([tt, x], -1)

    def drift(p, t, x):
        return nn.mlp(p["f"], tcat(t, x), nn.lipswish, jnp.tanh)

    def diffusion(p, t, x):
        out = nn.mlp(p["g"], tcat(t, x), nn.lipswish, jnp.tanh)
        return out.reshape(x.shape[:-1] + (x_dim, w_dim))

    z0 = jax.random.normal(kz, (batch, x_dim))
    bm = BrownianPath(kw, 0.0, 1.0, (batch, w_dim))
    mode = "reversible_adjoint" if exact_adjoint else "discretise"

    def loss(p):
        traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                     solver=solver, gradient_mode=mode, noise="general")
        return jnp.mean(traj[-1] ** 2)

    dt = _timeit(jax.jit(jax.grad(loss)), params, reps=reps)
    return dt, get_solver(solver).nfe_per_step * num_steps


def bench_srk(num_steps: int = 64, batch: int = 128, x_dim: int = 32,
              reps: int = 5):
    """Diagonal-noise forward+backward: SRK vs reversible Heun at the same
    step count.

    The wall-clock price of the order-1.5 step (5 NFE + the (W, H)
    space-time Lévy-area draw per step, vs 1 NFE + a plain W draw) —
    complementing ``convergence_srk``'s accuracy-per-NFE crossing, which
    is where that price pays off.  Both run the ``discretise`` gradient
    mode (the modes SRK supports; reversible_heun's exact adjoint is
    timed in ``bench_solver``).
    """
    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve
    from repro import nn

    key = jax.random.PRNGKey(3)
    kp1, kp2, kz, kw = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(kp1, [x_dim, 64, x_dim]),
              "g": nn.mlp_init(kp2, [x_dim, 64, x_dim])}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p, t, x: 0.2 * nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
    z0 = jax.random.normal(kz, (batch, x_dim))
    paths = {
        "reversible_heun": BrownianPath(kw, 0.0, 1.0, (batch, x_dim)),
        "srk": BrownianPath(kw, 0.0, 1.0, (batch, x_dim),
                            levy_area="space-time"),
    }

    out = {}
    for solver, bm in paths.items():
        def loss(p, solver=solver, bm=bm):
            traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                         solver=solver, gradient_mode="discretise",
                         save_trajectory=False)
            return jnp.mean(traj ** 2)

        out[solver] = _timeit(jax.jit(jax.grad(loss)), params, reps=reps)
    return out


def bench_fused_vs_unfused(num_steps: int = 64, batch: int = 128,
                           x_dim: int = 128, reps: int = 5):
    """Reversible-Heun exact-adjoint training step, Pallas-fused vs not.

    Diagonal noise (the fused kernels' layout); same problem either way, so
    the ratio isolates the step-update fusion.
    """
    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve
    from repro import nn

    key = jax.random.PRNGKey(1)
    kp1, kp2, kz, kw = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(kp1, [x_dim, 64, x_dim]),
              "g": nn.mlp_init(kp2, [x_dim, 64, x_dim])}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p, t, x: 0.2 * nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
    z0 = jax.random.normal(kz, (batch, x_dim))
    bm = BrownianPath(kw, 0.0, 1.0, (batch, x_dim))

    def loss(p, fused):
        traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                     solver="reversible_heun",
                     gradient_mode="reversible_adjoint",
                     use_pallas_kernels=fused)
        return jnp.mean(traj[-1] ** 2)

    out = {}
    for fused in (False, True):
        g = jax.jit(jax.grad(lambda p: loss(p, fused)))
        out["fused" if fused else "unfused"] = _timeit(g, params, reps=reps)
    return out


def bench_batched_vs_looped(batch: int = 32, num_steps: int = 64,
                            x_dim: int = 32, reps: int = 3):
    """One vmapped multi-trajectory solve vs a Python loop of solves."""
    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve, solve_batched
    from repro import nn

    key = jax.random.PRNGKey(2)
    kp1, kp2, kz, kk = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(kp1, [x_dim, 64, x_dim]),
              "g": nn.mlp_init(kp2, [x_dim, 64, x_dim])}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p, t, x: 0.2 * nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
    z0 = jax.random.normal(kz, (batch, x_dim))
    keys = jax.random.split(kk, batch)

    batched = jax.jit(lambda z, k: solve_batched(
        drift, diffusion, params, z, k, 0.0, 1.0, num_steps,
        solver="reversible_heun"))

    single = jax.jit(lambda z, k: solve(
        drift, diffusion, params, z,
        BrownianPath(k, 0.0, 1.0, (x_dim,)), 0.0, 1.0, num_steps,
        solver="reversible_heun"))

    def looped(z, ks):
        return [single(z[i], ks[i]) for i in range(batch)]

    return {"batched": _timeit(batched, z0, keys, reps=reps),
            "looped": _timeit(looped, z0, keys, reps=reps)}


def bench_adaptive_vs_fixed(batch: int = 256, x_dim: int = 32,
                            fixed_steps: int = 200, reps: int = 3,
                            bridge_depth: int = 10):
    """Adaptive terminal solve vs the fixed grid of matching accuracy.

    The workload is the ``benchmarks/convergence.py`` stiffness burst with
    a small neural perturbation on the drift (``θ(t)(1−y) + 0.05·MLP(y)``)
    — representative of where adaptivity is deployed (a trained vector
    field with time-localised stiffness) while keeping the controller's
    step-size profile of the ``convergence_frontier`` gate: ~95 accepted
    steps / ~102 NFE vs the ~200-step matched-error uniform grid.
    "Matched error" is calibrated on a shared dense path at f64: adaptive
    at (rtol=2e-3, atol=1e-5) reaches strong error 2.5e-4 vs 2.7e-4 for
    the fixed 200-step grid.

    Two levers make the NFE saving show up on the wall clock (EXPERIMENTS
    §Frontier records the history — this row once sat at ~4.3×):

    * the adaptive driver carries ``W(t_left)`` so each attempt pays ONE
      single-point ``bm.value`` query instead of ``evaluate``'s two;
    * ``bridge_depth=10`` caps the per-query Lévy-bridge descent.  Each
      level is a conditional-normal draw over the full state, so on CPU
      the default 24-level descent dominates.  Depth 10 leaves a bridge
      residual of std ``0.5·2⁻⁵ ≈ 1.6e-2`` in units of ``sqrt(span)``,
      i.e. ~8e-4 of state through the σ=0.05 diffusion — well inside the
      2e-3 tolerance, and the calibration above was run at this depth.

    Emits the two ``_ms`` rows (regression-gated via ``--compare``) plus
    an ``adaptive_over_fixed_ratio`` row asserted ``<= 2.25`` in-bench —
    the paper's claim is that adaptivity does not cost multiples of a
    matched-accuracy fixed grid.
    """
    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve, solve_adaptive
    from repro import nn

    try:  # the SAME burst problem the convergence_frontier gate measures
        from .convergence import _burst_fields
    except ImportError:  # run as a loose script
        from convergence import _burst_fields

    burst_drift, diffusion = _burst_fields()
    kp, _ = jax.random.split(jax.random.PRNGKey(9))
    params = {"f": nn.mlp_init(kp, [x_dim, 64, x_dim])}

    def drift(p, t, y):
        return burst_drift(None, t, y) + 0.05 * nn.mlp(
            p["f"], y, nn.lipswish, jnp.tanh)

    key = jax.random.PRNGKey(5)
    z0 = jnp.zeros((batch, x_dim), jnp.float32)
    bm = BrownianPath(key, 0.0, 1.0, (batch, x_dim), jnp.float32)

    adaptive = jax.jit(lambda z: solve(
        drift, diffusion, params, z, bm, 0.0, 1.0, 16,
        solver="reversible_heun", save_trajectory=False,
        adaptive=True, rtol=2e-3, atol=1e-5, max_steps=2048,
        bridge_depth=bridge_depth))
    fixed = jax.jit(lambda z: solve(
        drift, diffusion, params, z, bm, 0.0, 1.0, fixed_steps,
        solver="reversible_heun", save_trajectory=False))
    _, stats = solve_adaptive(drift, diffusion, params, z0, bm, 0.0, 1.0,
                              solver="reversible_heun", rtol=2e-3, atol=1e-5,
                              max_steps=2048, dt0=1.0 / 16,
                              bridge_depth=bridge_depth)
    return {"adaptive": _timeit(adaptive, z0, reps=reps),
            "fixed_matched_error": _timeit(fixed, z0, reps=reps)}, \
        float(stats.nfe)


def bench_backward_cost_model(batch: int = 256, x_dim: int = 32):
    """Analytic HBM-traffic model of one fused-adjoint backward step.

    The fused backward kernels' claim is a memory-movement one, and CPU
    timings can't witness it (off-TPU the fused flag dispatches to the
    jnp oracle — parity, not speed).  So model it from the jaxprs of the
    pure-jnp oracles (``repro.kernels.ref``), which are the exact math the
    kernels fuse:

    * **unfused bytes**: every primitive in the jaxpr materialises its
      array operands and results through HBM — sum ``size·itemsize`` over
      each equation's inputs and outputs (scalars live in registers and
      are skipped).  This is the round-trip cost of running the same
      elementwise chain as individual XLA/HLO ops.
    * **fused bytes**: a Pallas kernel reads each distinct input array
      once and writes each output once — sum over the jaxpr's own
      invars/outvars only.

    Covers the four elementwise phases of one backward step (Algorithm-2
    reconstruction phases 1/2 with ``sign=-1`` + the hand-derived
    cotangent phases); the vector-field MLP evaluation between them is
    identical in both paths and excluded.  Emits the ratio as a
    ``solver_speed_fusion_costmodel`` row, asserted ``>= 1`` in-bench
    (the fused step can never move MORE memory than the unfused chain).
    """
    from repro.kernels import ref

    shape, dtype = (batch, x_dim), jnp.float32
    a = jnp.zeros(shape, dtype)
    dt = jnp.asarray(0.01, dtype)

    def _bytes(v):
        aval = v.aval
        return aval.size * aval.dtype.itemsize if aval.shape else 0

    def roundtrip_bytes(fn, *args):
        jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
        return sum(_bytes(v) for eqn in jaxpr.eqns
                   for v in (*eqn.invars, *eqn.outvars))

    def kernel_bytes(fn, *args):
        jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
        return sum(_bytes(v) for v in (*jaxpr.invars, *jaxpr.outvars))

    phases = [
        (lambda z, zh, mu, sig, dw, dt:
         ref.rev_heun_phase1(z, zh, mu, sig, dw, dt, sign=-1.0),
         (a, a, a, a, a, dt)),
        (lambda z, mu, mu1, sig, sig1, dw, dt:
         ref.rev_heun_phase2(z, mu, mu1, sig, sig1, dw, dt, sign=-1.0),
         (a, a, a, a, a, a, dt)),
        (ref.rev_heun_bwd_phase1, (a, a, a, a, dt)),
        (ref.rev_heun_bwd_phase2, (a, a, a, dt)),
    ]
    unfused = sum(roundtrip_bytes(fn, *args) for fn, args in phases)
    fused = sum(kernel_bytes(fn, *args) for fn, args in phases)
    ratio = unfused / fused
    assert ratio >= 1.0, (
        f"fused backward step models as moving MORE HBM bytes than the "
        f"unfused chain ({unfused} vs {fused}) — the fusion claim is broken")
    return ratio, unfused, fused


PRESET_SHAPES = {
    #          reps, solver num_steps/batch, fused num_steps/batch, looped batch/num_steps
    "tiny":  (2, 16, 32, 8, 16, 4, 8),
    "quick": (3, 64, 128, 16, 32, 8, 16),
    "full":  (10, 64, 128, 64, 128, 32, 64),
}


def main(preset: str = "full"):
    (reps, sv_steps, sv_batch, fu_steps, fu_batch,
     bl_batch, bl_steps) = PRESET_SHAPES[preset]
    rows = []
    base = None
    for solver, exact in (("midpoint", False), ("heun", False),
                          ("reversible_heun", False), ("reversible_heun", True)):
        label = solver + ("+exact_adjoint" if exact else "")
        dt, nfe = bench_solver(solver, exact, num_steps=sv_steps,
                               batch=sv_batch, reps=reps)
        if solver == "midpoint":
            base = dt
        speedup = base / dt if base else 1.0
        rows.append(("solver_speed", label, dt * 1e3))
        print(f"solver_speed,{label},{dt*1e3:.2f}ms,nfe={nfe},"
              f"speedup_vs_midpoint={speedup:.2f}x", flush=True)

    sk = bench_srk(num_steps=sv_steps, batch=sv_batch, reps=reps)
    for k, v in sk.items():
        rows.append(("solver_speed_srk", f"{k}_ms", v * 1e3))
        print(f"solver_speed_srk,{k},{v*1e3:.2f}ms", flush=True)
    print(f"solver_speed_srk,srk_over_revheun,"
          f"{sk['srk'] / sk['reversible_heun']:.2f}x (5 NFE/step + (W,H) "
          f"draw vs 1 NFE/step; accuracy payoff gated in convergence_srk)",
          flush=True)

    fu = bench_fused_vs_unfused(num_steps=fu_steps, batch=fu_batch, reps=reps)
    ratio = fu["unfused"] / fu["fused"]
    backend = jax.default_backend()
    for k, v in fu.items():
        rows.append(("solver_speed_fusion", k, v * 1e3))
        print(f"solver_speed_fusion,{k},{v*1e3:.2f}ms,backend={backend}",
              flush=True)
    print(f"solver_speed_fusion,fused_speedup,{ratio:.2f}x"
          f"{' (oracle dispatch - parity, not a kernel speed claim)' if backend != 'tpu' else ''}",
          flush=True)

    bl = bench_batched_vs_looped(batch=bl_batch, num_steps=bl_steps, reps=reps)
    for k, v in bl.items():
        rows.append(("solver_speed_batching", k, v * 1e3))
        print(f"solver_speed_batching,{k},{v*1e3:.2f}ms", flush=True)
    print(f"solver_speed_batching,batched_speedup,"
          f"{bl['looped'] / bl['batched']:.2f}x", flush=True)

    # The adaptive ratio's true value sits near 1.9 on a single-core CPU
    # runner (committed baseline 1.9953), so a 2.0 gate was a scheduler-
    # noise coin flip — extra reps tighten the min and 2.25 gives the
    # gate margin while still catching the ~4.3× regression mode it
    # exists for (EXPERIMENTS.md §Frontier history).
    ad, nfe = bench_adaptive_vs_fixed(reps=max(reps, 7))
    for k, v in ad.items():
        rows.append(("solver_speed_adaptive", f"{k}_ms", v * 1e3))
        print(f"solver_speed_adaptive,{k},{v*1e3:.2f}ms", flush=True)
    ad_ratio = ad["adaptive"] / ad["fixed_matched_error"]
    assert ad_ratio <= 2.25, (
        f"adaptive solve is {ad_ratio:.2f}x the matched-error fixed grid "
        f"(gate: 2.25x) — check bridge_depth plumbing and the W(t_left) "
        f"carry in the adaptive driver")
    rows.append(("solver_speed_adaptive", "adaptive_over_fixed_ratio",
                 ad_ratio))
    rows.append(("solver_speed_adaptive", "adaptive_nfe", nfe))
    print(f"solver_speed_adaptive,adaptive_over_fixed_ratio,{ad_ratio:.2f}x "
          f"(gate <= 2.25x, asserted in-bench)", flush=True)
    print(f"solver_speed_adaptive,adaptive_nfe,{nfe:.0f} "
          f"(vs ~200 fixed at matched error; accuracy gate lives in "
          f"convergence_frontier)", flush=True)

    cm_ratio, cm_unfused, cm_fused = bench_backward_cost_model()
    rows.append(("solver_speed_fusion_costmodel", "bwd_hbm_bytes_ratio",
                 cm_ratio))
    print(f"solver_speed_fusion_costmodel,bwd_hbm_bytes_ratio,"
          f"{cm_ratio:.2f}x ({cm_unfused} -> {cm_fused} modelled bytes per "
          f"backward step; analytic, asserted >= 1 in-bench)", flush=True)
    return rows


if __name__ == "__main__":
    report.standalone("solver_speed", main)
