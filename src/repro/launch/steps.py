"""Step functions: train_step / prefill_step / serve_step builders.

Pure functions of (state, batch) suitable for pjit with donated buffers.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .. import optim
from ..configs.base import ArchConfig

# NOTE: the transformer zoo (repro.models) is imported lazily inside the
# LM step builders below — launch/serve.py imports this module for the
# Neural-SDE samplers, and the SDE workloads must never touch the LM stack.


def make_optimizer(cfg: ArchConfig, peak_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000, weight_decay: float = 0.1):
    sched = optim.cosine_schedule(peak_lr, warmup, total)
    moment_dtype = None if cfg.adam_dtype == "param" else cfg.adam_dtype
    return optim.adamw(sched, weight_decay=weight_decay, moment_dtype=moment_dtype)


def make_train_step(cfg: ArchConfig, opt_update=None, grad_clip: float = 1.0):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    from ..models import transformer as T

    if opt_update is None:
        _, opt_update = make_optimizer(cfg)

    def train_step(params, opt_state, batch: Dict[str, Any]):
        (loss, parts), grads = jax.value_and_grad(T.lm_loss, has_aux=True)(
            params, cfg, batch)
        grads, gnorm = optim.clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, **parts}
        return params, opt_state, metrics

    return train_step


# -----------------------------------------------------------------------------
# SDE-GAN (paper §5; DESIGN.md §4)
# -----------------------------------------------------------------------------


def make_gan_optimizers(lr: float = 1.0, constraint: str = "clip"):
    """Paper Appendix F: Adadelta for both players.  Under ``"clip"`` the
    discriminator chain ends in the careful-clipping projection — clip
    applied *after* the optimiser update, as a composable transform rather
    than a hand-written post-step, so swapping the optimiser never silently
    drops the constraint.  ``"gp"`` (the baseline) leaves the discriminator
    unconstrained — the penalty lives in the loss instead.

    Returns ``((g_init, g_update), (d_init, d_update))``.
    """
    from ..core.clipping import clip_lipschitz

    if constraint not in ("clip", "gp"):
        raise ValueError(f"constraint must be 'clip' or 'gp', got {constraint!r}")
    gen_opt = optim.adadelta(lr)
    if constraint == "clip":
        disc_opt = optim.chain(
            optim.adadelta(lr),
            optim.lipschitz_projection(clip_lipschitz),
        )
    else:
        disc_opt = optim.adadelta(lr)
    return gen_opt, disc_opt


def make_sde_gan_step(cfg, g_update, d_update, batch: int, seq_len: int,
                      constraint: str = "clip", gp_weight: float = 10.0):
    """Build the WGAN step: ``(params, g_state, d_state, key) ->
    (params, g_state, d_state, metrics)``.

    ``constraint="clip"`` (the paper's recipe) runs the generator forward —
    generator solve + joint generator/discriminator solve + real-path CDE
    solve — exactly **once** per step via ``jax.vjp``, then pulls two
    cotangents (one per player) through the reversible-Heun exact adjoint.
    That halves the solve count versus ``jax.grad`` per player, and the
    Lipschitz constraint costs one elementwise projection inside
    ``d_update`` (no second backward anywhere).

    ``constraint="gp"`` is the WGAN-GP baseline the paper replaces: the
    penalty term is a gradient *of a gradient* through the CDE solve, so it
    cannot share the forward and must run discretise-then-optimise
    (``benchmarks/clipping.py`` measures the difference).

    Batch-parallel: path tensors are constrained to the time-major layout
    (batch on the mesh's data axes, time replicated) so GSPMD shards all
    solves by batch while parameters stay replicated.
    """
    from ..core.sde import gan_losses, gradient_penalty
    from ..data.synthetic import ou_process
    from ..distributed.sharding import shard_time_major

    def clip_step(params, g_state, d_state, k):
        y_real = shard_time_major(ou_process(jax.random.fold_in(k, 0),
                                             batch, seq_len, dtype=cfg.dtype))

        # One shared forward (generator solve + joint solve + CDE solve),
        # two cotangent pulls — instead of jax.grad per player re-running
        # the full SDE solves.
        def both_losses(gen, disc):
            p = {"gen": gen, "disc": disc}
            gl, dl, _ = gan_losses(p, cfg, jax.random.fold_in(k, 1), y_real, batch)
            return gl, dl

        (gl, dl), vjp = jax.vjp(both_losses, params["gen"], params["disc"])
        one, zero = jnp.ones_like(gl), jnp.zeros_like(gl)
        gg, _ = vjp((one, zero))
        _, dg = vjp((zero, one))

        upd, d_state = d_update(dg, d_state, params["disc"])
        disc = optim.apply_updates(params["disc"], upd)  # projection folded in
        upd, g_state = g_update(gg, g_state, params["gen"])
        gen = optim.apply_updates(params["gen"], upd)
        metrics = {"gen_loss": gl, "disc_loss": dl, "wasserstein": -dl}
        return {"gen": gen, "disc": disc}, g_state, d_state, metrics

    def gp_step(params, g_state, d_state, k):
        y_real = shard_time_major(ou_process(jax.random.fold_in(k, 0),
                                             batch, seq_len, dtype=cfg.dtype))

        def d_loss(disc):
            p = {"gen": params["gen"], "disc": disc}
            _, dl, fake = gan_losses(p, cfg, jax.random.fold_in(k, 1), y_real, batch)
            # reuse the fake paths the loss already solved for (no second
            # generator solve); GP interpolates are constants w.r.t. φ
            fake = jax.lax.stop_gradient(fake)
            return dl + gp_weight * gradient_penalty(
                disc, cfg, jax.random.fold_in(k, 3), y_real, fake), dl

        def g_loss(gen):
            p = {"gen": gen, "disc": params["disc"]}
            gl, _, _ = gan_losses(p, cfg, jax.random.fold_in(k, 1), y_real, batch)
            return gl

        (_, dl), dg = jax.value_and_grad(d_loss, has_aux=True)(params["disc"])
        upd, d_state = d_update(dg, d_state, params["disc"])
        disc = optim.apply_updates(params["disc"], upd)
        gl, gg = jax.value_and_grad(g_loss)(params["gen"])
        upd, g_state = g_update(gg, g_state, params["gen"])
        gen = optim.apply_updates(params["gen"], upd)
        metrics = {"gen_loss": gl, "disc_loss": dl, "wasserstein": -dl}
        return {"gen": gen, "disc": disc}, g_state, d_state, metrics

    if constraint not in ("clip", "gp"):
        raise ValueError(f"constraint must be 'clip' or 'gp', got {constraint!r}")
    if constraint == "gp" and seq_len != cfg.num_steps + 1:
        # the GP interpolates eps*y_real + (1-eps)*y_fake need both paths on
        # the same grid; fail eagerly instead of a broadcast error inside jit
        raise ValueError(
            f"gp constraint requires seq_len == num_steps + 1 so real and "
            f"fake paths share a grid; got seq_len={seq_len}, "
            f"num_steps={cfg.num_steps}")
    return clip_step if constraint == "clip" else gp_step


# -----------------------------------------------------------------------------
# Latent SDE / VAE (Li et al. [15]; paper Appendix B; DESIGN.md §8)
# -----------------------------------------------------------------------------


def make_latent_sde_optimizer(lr: float = 1e-2):
    """Adam, per the paper's Latent-SDE recipe (Appendix F).  Returns the
    ``(init, update)`` pair; no projection tail — the VAE has no Lipschitz
    constraint to maintain (that is the GAN discriminator's problem)."""
    return optim.adam(lr)


def make_latent_sde_step(cfg, opt_update, batch: int, seq_len: int,
                         adjoint: str = "exact"):
    """Build the ELBO step: ``(params, opt_state, key) ->
    (params, opt_state, metrics)``.

    One forward per step via ``jax.vjp`` — encoder GRU + posterior SDE
    solve, with the KL path integral riding as a state channel — and one
    cotangent pull through the solver's adjoint:

    * ``adjoint="exact"`` (the paper's recipe): the reversible-Heun exact
      O(1)-memory adjoint via :func:`repro.core.sde.latent_sde_loss`.  The
      reconstruction term reads the trajectory at the observation times —
      only the exact adjoint can backpropagate a whole-trajectory loss with
      O(1) memory.  This is the workload the fused diagonal-noise Pallas
      kernels were built for: set ``cfg.use_pallas_kernels=True`` and the
      posterior solve's forward scan and backward reconstruction run fused.
    * ``adjoint="backsolve"`` (the Li et al. baseline): the
      continuous-adjoint eq. (6), which only accepts terminal-value
      cotangents — so the step switches to
      :func:`repro.core.sde.latent_sde_loss_terminal`, where the recon
      integral rides as a second state channel.  Gradients carry the
      O(√h) truncation error the paper eliminates
      (``benchmarks/latent_sde.py`` measures it).
    * ``adjoint="checkpoint"``: recursive binomial checkpointing over the
      same terminal-form objective — gradients exact to floating point
      (unlike backsolve) at O(log n) memory (unlike discretise), and
      available for EVERY registered solver, not just the reversible pair.
      The frontier cell for non-reversible steppers; see DESIGN.md §12.

    All shape/config mismatches are validated **here, eagerly** — a
    misaligned solver grid or an illegal solver × adjoint × fusion cell
    raises a named ``ValueError`` at build time, not a broadcast error from
    inside jit.

    Batch-parallel: the observation paths are constrained to the
    time-major layout (``sharding.shard_time_major``) so GSPMD shards the
    encoder scan and the posterior solve by batch while the (tiny, shared)
    parameters stay replicated — identical layout to the SDE-GAN step.
    """
    from ..core.sde import (latent_sde_loss, latent_sde_loss_terminal,
                            validate_latent_grid)
    from ..core.solve import get_solver
    from ..data.synthetic import air_quality_like
    from ..distributed.sharding import shard_time_major

    if adjoint not in ("exact", "backsolve", "checkpoint"):
        raise ValueError(
            f"adjoint must be 'exact', 'backsolve', or 'checkpoint', "
            f"got {adjoint!r}")
    if seq_len < 2:
        raise ValueError(f"seq_len must be >= 2 observations, got {seq_len}")
    validate_latent_grid(cfg.num_steps, seq_len - 1)
    if cfg.data_dim != 2:
        raise ValueError(
            f"the latent-SDE workload trains on the bivariate air-quality "
            f"dataset (PM2.5-like, O₃-like); cfg.data_dim must be 2, got "
            f"{cfg.data_dim}")
    if adjoint == "backsolve":
        spec = get_solver(cfg.solver)
        if "continuous_adjoint" not in spec.gradient_modes:
            raise ValueError(
                f"adjoint='backsolve' needs a solver with a "
                f"continuous-adjoint backward integrator; {cfg.solver!r} "
                f"serves {spec.gradient_modes} — use midpoint/heun/"
                f"euler_maruyama (or adjoint='exact' for reversible_heun)")
        if cfg.use_pallas_kernels:
            raise ValueError(
                "use_pallas_kernels requires the exact reversible-Heun "
                "adjoint (the fused kernels have no VJP rule and the "
                "backsolve path is plain AD over eq. (6)); drop --pallas "
                "or use adjoint='exact'")
    elif adjoint == "checkpoint":
        if cfg.use_pallas_kernels:
            raise ValueError(
                "use_pallas_kernels requires the exact reversible-Heun "
                "adjoint (checkpointing differentiates the rematerialised "
                "segments by plain AD, which cannot trace a pallas_call); "
                "drop --pallas or use adjoint='exact'")
    elif cfg.use_pallas_kernels and not (
            cfg.solver == "reversible_heun" and cfg.exact_adjoint):
        raise ValueError(
            f"use_pallas_kernels requires solver='reversible_heun' with "
            f"exact_adjoint=True (got solver={cfg.solver!r}, "
            f"exact_adjoint={cfg.exact_adjoint}) — the fused kernels only "
            f"apply to the exact-adjoint hot loop")

    def step(params, opt_state, k):
        ys, _ = air_quality_like(jax.random.fold_in(k, 0), batch, seq_len,
                                 dtype=cfg.dtype)
        ys = shard_time_major(ys)

        def elbo(p):
            if adjoint == "exact":
                return latent_sde_loss(p, cfg, jax.random.fold_in(k, 1), ys)
            mode = ("continuous_adjoint" if adjoint == "backsolve"
                    else "checkpoint")
            return latent_sde_loss_terminal(
                p, cfg, jax.random.fold_in(k, 1), ys, gradient_mode=mode)

        loss, vjp, parts = jax.vjp(elbo, params, has_aux=True)
        (grads,) = vjp(jnp.ones_like(loss))
        upd, opt_state = opt_update(grads, opt_state, params)
        params = optim.apply_updates(params, upd)
        metrics = {"loss": loss, **parts}
        return params, opt_state, metrics

    return step


# -----------------------------------------------------------------------------
# Neural-SDE serving (DESIGN.md §9)
# -----------------------------------------------------------------------------

SERVE_WORKLOADS = ("sde-gan", "latent-sde")


def make_sample_step(workload: str, cfg, latent_mode: str = "prior",
                     obs_len: Optional[int] = None):
    """Build the batched trajectory sampler for one serving bucket:
    ``(params, keys) -> (num_steps+1, len(keys), data_dim)``.

    launch/serve.py AOT-compiles this once per bucket shape; an off-size
    coalesced request batch pads its key array up to the nearest bucket
    instead of triggering a recompile.  Padding is safe by construction:
    every row of the output is a pure function of ``(params, keys[i])``
    alone (see the serving entry points in repro.core.sde), which
    tests/test_serving.py pins bitwise.

    The trajectory tensor is constrained to the repo's time-major layout
    (``sharding.shard_time_major``), so under a data-parallel mesh GSPMD
    shards every per-row solve by batch while the (tiny) parameters stay
    replicated — the same layout as both training steps.

    ``workload="latent-sde"`` serves the prior decode by default;
    ``latent_mode="posterior"`` serves the encode→posterior-solve decode,
    synthesising the observation payload (``obs_len`` points) per row key —
    the smoke-shaped stand-in for a real observation channel, which would
    ride as a second AOT argument with the same bucket shape.

    All config/solver validation is eager: an illegal workload, latent
    mode, or observation grid raises a named ValueError here, at build
    time, never from inside the compiled sampler.
    """
    from ..core import sde as S
    from ..distributed.sharding import shard_time_major

    if workload not in SERVE_WORKLOADS:
        raise ValueError(
            f"workload must be one of {SERVE_WORKLOADS}, got {workload!r} "
            f"(the transformer-LM decode loop lives behind launch/serve.py "
            f"--workload lm, not this builder)")

    if workload == "sde-gan":
        def sample(params, keys):
            return shard_time_major(
                S.generator_sample_paths(params, cfg, keys))
        return sample

    if latent_mode not in ("prior", "posterior"):
        raise ValueError(
            f"latent_mode must be 'prior' or 'posterior', got {latent_mode!r}")
    if latent_mode == "prior":
        def sample(params, keys):
            return shard_time_major(
                S.latent_sde_sample_paths(params, cfg, keys))
        return sample

    if obs_len is None or obs_len < 2:
        raise ValueError(
            f"latent_mode='posterior' needs obs_len >= 2 observation points "
            f"per request, got {obs_len!r}")
    S.validate_latent_grid(cfg.num_steps, obs_len - 1)

    def sample(params, keys):
        from ..data.synthetic import air_quality_like

        def obs_row(k):  # -> (obs_len, data_dim), a pure function of k
            ys, _ = air_quality_like(jax.random.fold_in(k, 2), 1, obs_len,
                                     dtype=cfg.dtype)
            return ys[:, 0]

        y_obs = jax.vmap(obs_row, out_axes=1)(keys)
        return shard_time_major(
            S.latent_sde_posterior_decode(params, cfg, keys, y_obs))

    return sample


def make_adaptive_terminal_step(cfg, atol: float = 1e-6,
                                max_steps: int = 4096):
    """Build the adaptive terminal-distribution sampler for one serving
    bucket: ``(params, keys, rtol) -> ((len(keys), data_dim) samples,
    (len(keys),) converged)``.

    The per-request tolerance surface (DESIGN.md §10): ``rtol`` is a
    *traced* scalar, so launch/serve.py AOT-compiles ONE program per bucket
    and every tolerance a client asks for runs through it — the adaptive
    ``lax.while_loop`` simply takes more (or fewer) steps.  A coalesced
    batch runs at the tolerance :func:`repro.serving.route_rtol` picks —
    the loosest rtol the batch's tightest deadline allows, with explicit
    per-request asks as accuracy floors (the PR 7 SLO rule; the PR 5
    tightest-ask minimum is gone).  Rows whose
    controller exhausted its step budget come back ``converged=False`` —
    the serving loop reports them instead of passing them off as ``Y_T``.
    ``max_steps`` defaults to a production-sized 4096 (forward-only — no
    O(max_steps) adjoint buffers ride along here, and the while_loop only
    pays for iterations actually taken), so tight client tolerances don't
    starve at the library default budget.

    SDE-GAN generator only — it is the terminal-value workload; the
    trajectory-serving samplers keep their fixed grids (an adaptive solve
    has no fixed output grid to return).
    """
    from ..core import sde as S
    from ..core.solve import SOLVERS, get_solver

    spec = get_solver(cfg.solver)
    if spec.embedded_stepper is None:
        raise ValueError(
            f"--adaptive needs a solver with an embedded error estimate; "
            f"{cfg.solver!r} has none (embedded pairs: "
            f"{sorted(s.name for s in SOLVERS.values() if s.embedded_stepper)})")

    def sample(params, keys, rtol):
        return S.generator_sample_terminal(params, cfg, keys, rtol, atol,
                                           max_steps=max_steps)

    return sample


def make_stream_chunk_step(cfg, span: float, num_steps: int):
    """Build the streamed-rollout chunk step for long-horizon serving:
    ``(params, keys, x0, t_start) -> (ys_chunk, xT)``.

    ``t_start`` is a traced scalar — or a traced ``(B,)`` per-row vector,
    the continuous-batching form: rows admitted at different chunk
    boundaries sit at different horizon positions yet share ONE compiled
    program per bucket (``repro.serving.Scheduler``).  The stream loop
    passes a scalar (every row at the same chunk); either way the serving
    loop carries ``xT`` into the next chunk and emits each ``ys_chunk`` as
    it completes (first-chunk latency instead of full-horizon).  ``keys``
    must be pre-folded per chunk by the caller.  SDE-GAN generator only —
    the chunk carry is the generator hidden state.
    """
    from ..core import sde as S
    from ..distributed.sharding import shard_time_major

    def chunk_step(params, keys, x0, t_start):
        ys, xT = S.generator_rollout_chunk(params, cfg, keys, x0, t_start,
                                           span, num_steps)
        return shard_time_major(ys), xT

    return chunk_step


def make_prefill_step(cfg: ArchConfig, max_len: Optional[int] = None):
    """(params, batch) -> (last-token logits, populated caches)."""
    from ..models import transformer as T

    def prefill_step(params, batch: Dict[str, Any]):
        if cfg.family == "encdec":
            return T.encdec_prefill(params, cfg, batch["tokens"],
                                    batch["src_embeds"], max_len=max_len)
        return T.lm_prefill(params, cfg, batch["tokens"],
                            embeds=batch.get("embeds"), max_len=max_len)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """(params, caches, token, pos) -> (logits, new caches).  One new token
    against a KV/state cache — the ``decode_*`` / ``long_*`` dry-run target."""
    from ..models import transformer as T

    def serve_step(params, caches, token, pos):
        if cfg.family == "encdec":
            return T.encdec_decode(params, cfg, token, caches, pos)
        return T.lm_decode(params, cfg, token, caches, pos)

    return serve_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
