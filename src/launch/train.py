"""Alias for :mod:`repro.launch.train` — see that module for the driver.

Usage::

    PYTHONPATH=src python -m launch.train --workload sde-gan --steps 2
"""

from repro.launch.train import main, train, train_sde_gan  # noqa: F401

if __name__ == "__main__":
    main()
