"""Step functions: train_step / prefill_step / serve_step builders.

Pure functions of (state, batch) suitable for pjit with donated buffers.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .. import optim
from ..configs.base import ArchConfig
from ..models import transformer as T


def make_optimizer(cfg: ArchConfig, peak_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000, weight_decay: float = 0.1):
    sched = optim.cosine_schedule(peak_lr, warmup, total)
    moment_dtype = None if cfg.adam_dtype == "param" else cfg.adam_dtype
    return optim.adamw(sched, weight_decay=weight_decay, moment_dtype=moment_dtype)


def make_train_step(cfg: ArchConfig, opt_update=None, grad_clip: float = 1.0):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    if opt_update is None:
        _, opt_update = make_optimizer(cfg)

    def train_step(params, opt_state, batch: Dict[str, Any]):
        (loss, parts), grads = jax.value_and_grad(T.lm_loss, has_aux=True)(
            params, cfg, batch)
        grads, gnorm = optim.clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, **parts}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: Optional[int] = None):
    """(params, batch) -> (last-token logits, populated caches)."""

    def prefill_step(params, batch: Dict[str, Any]):
        if cfg.family == "encdec":
            return T.encdec_prefill(params, cfg, batch["tokens"],
                                    batch["src_embeds"], max_len=max_len)
        return T.lm_prefill(params, cfg, batch["tokens"],
                            embeds=batch.get("embeds"), max_len=max_len)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """(params, caches, token, pos) -> (logits, new caches).  One new token
    against a KV/state cache — the ``decode_*`` / ``long_*`` dry-run target."""

    def serve_step(params, caches, token, pos):
        if cfg.family == "encdec":
            return T.encdec_decode(params, cfg, token, caches, pos)
        return T.lm_decode(params, cfg, token, caches, pos)

    return serve_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
