"""Control paths for CDEs (the SDE-GAN discriminator consumes a path, eq. (2)).

Anything exposing ``increment(n, num_steps)`` can drive a solver — Brownian
motion (:class:`repro.core.brownian.BrownianPath`) or an observed/generated
data path interpolated piecewise-linearly (paper §2.3: "equation (2) may be
evaluated on an interpolation of the observed data").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LinearPathControl:
    """Piecewise-linear interpolation of a discrete series ``ys`` (T+1, ..., d).

    ``increment(n, N)`` with ``N == T`` returns ``ys[n+1] - ys[n]`` — the
    control increment ``dY`` a CDE solver consumes on step ``n``.
    """

    ys: jax.Array  # (T+1, ..., d), time leading

    def tree_flatten(self):
        return (self.ys,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(ys=children[0])

    def increment(self, n, num_steps: int):
        T = self.ys.shape[0] - 1
        if num_steps == T:
            return jax.lax.dynamic_index_in_dim(self.ys, n + 1, 0, keepdims=False) - \
                jax.lax.dynamic_index_in_dim(self.ys, n, 0, keepdims=False)
        # re-gridding: num_steps steps over the same [0, 1] span
        frac0 = n / num_steps * T
        frac1 = (n + 1) / num_steps * T
        return self._eval(frac1) - self._eval(frac0)

    def _eval(self, f):
        T = self.ys.shape[0] - 1
        f = jnp.clip(f, 0, T)
        i0 = jnp.clip(jnp.floor(f).astype(jnp.int32), 0, T - 1)
        w = f - i0
        y0 = jax.lax.dynamic_index_in_dim(self.ys, i0, 0, keepdims=False)
        y1 = jax.lax.dynamic_index_in_dim(self.ys, i0 + 1, 0, keepdims=False)
        return y0 * (1 - w) + y1 * w
