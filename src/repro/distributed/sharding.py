"""GSPMD sharding rules: 2D "FSDP × TP" with an optional pod axis.

Axis roles on the production mesh (see launch/mesh.py):

* ``pod``   — outermost data/FSDP axis across pods (DCN-connected).
* ``data``  — intra-pod data/FSDP axis.
* ``model`` — tensor/expert-parallel axis (ICI-connected).

Weights carry ``P(fsdp, 'model')`` on (in, out)-style matrices with the TP
axis on the head/ffn/vocab dimension (Megatron layout); the other dimension
is FSDP-sharded over (pod, data) so optimizer state and parameters scale
with the full device count.  Activations are batch-sharded over (pod, data).

Everything is *rule-driven off parameter names*, so new modules compose by
following the naming convention rather than hand-annotating every tensor.
All helpers degrade to no-ops when no mesh is active — CPU smoke tests and
the Neural-SDE path run unsharded through identical code.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .compat import ambient_mesh


def active_mesh_axes() -> Tuple[str, ...]:
    am = ambient_mesh()
    return tuple(am.axis_names) if am is not None else ()


def dp_axes(axes: Optional[Tuple[str, ...]] = None):
    axes = active_mesh_axes() if axes is None else axes
    got = tuple(a for a in ("pod", "data") if a in axes)
    return got if got else None


def tp_axis(axes: Optional[Tuple[str, ...]] = None) -> Optional[str]:
    axes = active_mesh_axes() if axes is None else axes
    return "model" if "model" in axes else None


def tp_size() -> int:
    am = ambient_mesh()
    if am is None:
        return 1
    return dict(am.shape).get("model", 1)


def hint(x: jax.Array, *dims) -> jax.Array:
    """with_sharding_constraint(x, P(*dims)) under the ambient mesh; no-op
    when unsharded.  ``dims`` entries: "dp", "tp", None."""
    axes = active_mesh_axes()
    if not axes:
        return x
    spec = []
    for d in dims:
        if d == "dp":
            spec.append(dp_axes(axes))
        elif d == "tp":
            spec.append(tp_axis(axes))
        else:
            spec.append(d)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def batch_pspec(batch_dim_first: bool = True) -> P:
    return P(dp_axes()) if batch_dim_first else P(None, dp_axes())


def time_major_pspec() -> P:
    """Spec for (T+1, batch, ...) path tensors — the SDE/CDE layout.

    Batch on the data axes, the time axis replicated: every solver step is a
    sequential dependency, so sharding time would serialise cross-device.
    The SDE-GAN step (DESIGN.md §4) shards *only* batch; parameters are tiny
    MLPs and stay replicated, so the per-step collective cost is one psum of
    scalar losses + parameter-sized gradient all-reduces.
    """
    return P(None, dp_axes())


def shard_time_major(x: jax.Array) -> jax.Array:
    """Constrain a (T+1, batch, ...) tensor to the time-major layout; no-op
    without a mesh.  Use inside jit (the GAN step) so GSPMD propagates the
    batch sharding through all three SDE/CDE solves."""
    axes = active_mesh_axes()
    if not axes or dp_axes(axes) is None:
        return x
    return jax.lax.with_sharding_constraint(x, time_major_pspec())


def data_parallel_mesh(batch: Optional[int] = None):
    """Pure data-parallel mesh over every visible device, or ``None`` when
    there is a single device (or ``batch`` is given and not divisible — a
    constraint GSPMD in_shardings cannot satisfy).

    Both Neural-SDE training steps and the serving sampler are pure batch
    parallelism (DESIGN.md §4/§8/§9): parameters are tiny and replicated,
    only the sample batch shards.  Callers activate the mesh with
    ``distributed.compat.set_mesh``.
    """
    from .compat import make_mesh

    n_dev = len(jax.devices())
    if n_dev <= 1:
        return None
    if batch is not None and batch % n_dev != 0:
        return None
    return make_mesh((n_dev,), ("data",))


# -----------------------------------------------------------------------------
# parameter sharding rules (by name, innermost path component)
# -----------------------------------------------------------------------------

# name -> spec over the *trailing* dims (leading stacked-layer dims get None)
_RULES = {
    # embeddings / head: vocab on TP, d_model on FSDP
    "embed": ("tp", "dp"),
    "head": ("dp", "tp"),
    "pos_embed": (None, "dp"),
    # attention
    "wq": ("dp", "tp"), "wk": ("dp", "tp"), "wv": ("dp", "tp"), "wo": ("tp", "dp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    # MLA
    "wq_a": ("dp", None), "wq_b": (None, "tp"),
    "wkv_a": ("dp", None), "wkv_b": (None, "tp"), "wo_mla": ("tp", "dp"),
    # dense ffn
    "gate": ("dp", "tp"), "up": ("dp", "tp"), "down": ("tp", "dp"),
    # moe (leading expert dim handled in param_pspecs).  The router is
    # deliberately ABSENT (=> replicated): it is tiny (d_model × E) and
    # sharding its contraction dim forces a f32 (B,S,D) partial-sum
    # all-reduce per MoE layer in the backward (§Perf iteration 3).
    "e_gate": ("ep", "dp", "tp_or_none"), "e_up": ("ep", "dp", "tp_or_none"),
    "e_down": ("ep", "tp_or_none", "dp"),
    # mamba2
    "in_proj": ("dp", "tp"), "out_proj": ("tp", "dp"),
    "conv_w": (None, "tp"), "conv_b": ("tp",),
    "A_log": ("tp",), "Dskip": ("tp",), "dt_bias": ("tp",), "norm_g": ("tp",),
}

_REPLICATED = {"g", "b", "ln1", "ln2", "ln3", "final_norm", "scale"}


def _axis_product(entry, sizes) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(entry, 1)


def _spec_for(name: str, shape, axes, sizes, ep_ok: bool):
    if name in _RULES:
        raw = _RULES[name]
        spec = []
        for r in raw:
            if r == "dp":
                spec.append(dp_axes(axes))
            elif r == "tp":
                spec.append(tp_axis(axes))
            elif r == "ep":
                spec.append(tp_axis(axes) if ep_ok else None)
            elif r == "tp_or_none":
                spec.append(None if ep_ok else tp_axis(axes))
            else:
                spec.append(None)
        # leading stacked-layer dims (scan over layers / blocks)
        pad = len(shape) - len(spec)
        spec = [None] * pad + spec
        # shape-aware fallback: jit in_shardings need exact divisibility.
        # Drop any entry whose mesh-axis product doesn't divide the dim
        # (e.g. vocab 73448 on a 16-way model axis) — production frameworks
        # pad such tables; we keep exact configs and replicate that dim.
        spec = [s if d % _axis_product(s, sizes) == 0 else None
                for s, d in zip(spec, shape)]
        return P(*spec)
    return P()  # replicate (norms, biases, small vectors)


def param_pspecs(params, num_experts: int = 0, serve_pure_tp: bool = False):
    """Tree of PartitionSpec matching ``params`` (a pytree of arrays or
    ShapeDtypeStructs), using the naming convention of repro.models.

    ``serve_pure_tp`` drops the FSDP (dp) factor — pure tensor parallelism.
    Decode moves one token against all weights, so ZeRO-3 weight gathers
    dominate its collective term (§Perf iteration D1); when params/TP fit
    HBM, serving replicates over dp and keeps only the model-axis shards.
    """
    axes = active_mesh_axes()
    am = ambient_mesh()
    sizes = dict(am.shape) if am is not None else {}
    tp_n = sizes.get("model", 1)
    ep_ok = num_experts > 0 and tp_n > 1 and num_experts % tp_n == 0

    def walk(tree, name=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, name) for v in tree]
            return type(tree)(t)
        spec = _spec_for(name, tree.shape, axes, sizes, ep_ok)
        if serve_pure_tp:
            dp = dp_axes(axes)
            spec = P(*[None if (s == dp or s in ("pod", "data")) else s
                       for s in spec])
        return spec

    return walk(params)


def named_shardings(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
