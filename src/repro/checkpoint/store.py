"""Sharded, atomic, step-granular checkpointing.

Layout::

    <dir>/step_<N>/
        shard_<host>.npz     # one file per host process (host 0 here)
        MANIFEST.json        # written LAST -> commit marker

A checkpoint is valid iff its MANIFEST exists; a crash mid-write leaves no
manifest and the directory is ignored (and garbage-collected on the next
save).  ``restore_checkpoint`` finds the newest valid step — the auto-resume
path of launch/train.py.  Leaves are addressed by their pytree key-path so a
restore is robust to dict-ordering changes.
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_names(tree) -> Tuple[list, Any]:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(path) for path, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return names, (leaves, treedef)


def save_checkpoint(ckpt_dir, step: int, tree, host_id: int = 0,
                    keep: int = 3) -> Path:
    """Atomically persist ``tree`` at ``step``; prunes to ``keep`` newest."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:012d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:012d}"
    if tmp_dir.exists():
        shutil.rmtree(tmp_dir)
    tmp_dir.mkdir(parents=True)

    names, (leaves, _) = _leaf_names(tree)
    arrays = {n: np.asarray(l) for n, l in zip(names, leaves)}
    np.savez(tmp_dir / f"shard_{host_id}.npz", **arrays)
    manifest = {
        "step": step,
        "num_hosts": 1,
        "leaves": {n: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for n, a in arrays.items()},
    }
    (tmp_dir / "MANIFEST.json").write_text(json.dumps(manifest, indent=2))
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp_dir.rename(step_dir)  # atomic commit

    # prune: keep the newest `keep` valid checkpoints + drop stale tmp dirs
    valid = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "MANIFEST.json").exists())
    for d in valid[:-keep]:
        shutil.rmtree(d)
    for d in ckpt_dir.glob(".tmp_step_*"):
        shutil.rmtree(d)
    return step_dir


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    valid = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "MANIFEST.json").exists())
    if not valid:
        return None
    return int(valid[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir, like_tree, step: Optional[int] = None,
                       host_id: int = 0):
    """Restore into the structure (and dtypes) of ``like_tree``.

    Returns (tree, step).  Raises FileNotFoundError when nothing valid exists.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:012d}"
    manifest = json.loads((step_dir / "MANIFEST.json").read_text())
    data = np.load(step_dir / f"shard_{host_id}.npz")

    names, (leaves, treedef) = _leaf_names(like_tree)
    restored = []
    for n, like in zip(names, leaves):
        arr = data[n]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"checkpoint leaf {n}: shape {arr.shape} != {like.shape}")
        restored.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["step"]
