"""ShapeDtypeStruct input stand-ins + PartitionSpecs for every (arch × shape).

``input_specs`` is the single source of truth for what each step function
consumes — weak-type-correct, shardable, zero device allocation.  The same
dict drives the dry-run lowers, the roofline costing, and (with real arrays
of identical shape) the runnable smoke paths.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..models import transformer as T

SDS = jax.ShapeDtypeStruct


def dp_axes_of(mesh) -> Optional[Tuple[str, ...]]:
    got = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return got if got else None


def _frontend_split(cfg: ArchConfig, seq_len: int) -> Tuple[int, int]:
    """(prefix_len, text_len) for archs with a stub modality frontend."""
    if cfg.frontend and cfg.family != "encdec":
        f = min(cfg.frontend_len, seq_len // 2)
        return f, seq_len - f
    return 0, seq_len


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the step kind of ``shape``.

    train   -> {tokens, labels[, embeds][, src_embeds]}
    prefill -> {tokens[, embeds][, src_embeds]}
    decode  -> {token, caches, pos}
    """
    B, S = shape.global_batch, shape.seq_len
    i32, dt = jnp.int32, cfg.dtype

    if cfg.family == "encdec":
        half = S // 2
        if shape.kind == "train":
            return {"src_embeds": SDS((B, half, cfg.d_model), dt),
                    "tokens": SDS((B, half), i32), "labels": SDS((B, half), i32)}
        if shape.kind == "prefill":
            return {"src_embeds": SDS((B, half, cfg.d_model), dt),
                    "tokens": SDS((B, half), i32)}
        caches = T.encdec_cache(cfg, B, max_len=half, src_len=half)
        return {"token": SDS((B, 1), i32), "caches": caches,
                "pos": SDS((), i32)}

    f, s_text = _frontend_split(cfg, S)
    if shape.kind == "train":
        out = {"tokens": SDS((B, s_text), i32), "labels": SDS((B, s_text), i32)}
        if f:
            out["embeds"] = SDS((B, f, cfg.d_model), dt)
        return out
    if shape.kind == "prefill":
        out = {"tokens": SDS((B, s_text), i32)}
        if f:
            out["embeds"] = SDS((B, f, cfg.d_model), dt)
        return out
    caches = T.init_cache(cfg, B, max_len=S)
    return {"token": SDS((B, 1), i32), "caches": caches, "pos": SDS((), i32)}


# -----------------------------------------------------------------------------
# PartitionSpecs
# -----------------------------------------------------------------------------


def _dp_size(mesh) -> int:
    sizes = dict(mesh.shape)  # works for Mesh and AbstractMesh alike
    return sizes.get("pod", 1) * sizes.get("data", 1)


def _model_size(mesh) -> int:
    return dict(mesh.shape).get("model", 1)


def _cache_leaf_pspec(name: str, shape, mesh, dp) -> P:
    """Decode-cache sharding.  Leaves are stacked (num_units leading).

    jit in_shardings require exact divisibility, so the model-axis placement
    is shape-aware: heads/channels when divisible, else the sequence axis
    (flash-decoding style), else replicated.  The batch axis drops its dp
    sharding when B < dp (e.g. long_500k with global_batch=1).
    """
    tp = _model_size(mesh)
    bdp = dp if (dp and shape[1] % _dp_size(mesh) == 0) else None
    if name in ("k", "v"):        # (U, B, S, Hkv, hd)
        if shape[3] % tp == 0:
            return P(None, bdp, None, "model", None)
        if shape[2] % tp == 0:
            return P(None, bdp, "model", None, None)
        return P(None, bdp, None, None, None)
    if name in ("ckv", "kpe"):    # (U, B, S, r): MLA latent — seq over model
        if shape[2] % tp == 0:
            return P(None, bdp, "model", None)
        return P(None, bdp, None, None)
    if name == "conv":            # (U, B, k-1, conv_dim): channels over model
        if shape[3] % tp == 0:
            return P(None, bdp, None, "model")
        return P(None, bdp, None, None)
    if name == "ssm":             # (U, B, H, N, P): heads over model
        if shape[2] % tp == 0:
            return P(None, bdp, "model", None, None)
        return P(None, bdp, None, None, None)
    return P(*([None] * len(shape)))


def cache_pspecs(caches, mesh):
    dp = dp_axes_of(mesh)

    def walk(tree):
        if isinstance(tree, dict):
            return {k: (_cache_leaf_pspec(k, v.shape, mesh, dp)
                        if hasattr(v, "shape") else walk(v))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return P()

    return walk(caches)


def batch_pspecs(specs: Dict[str, Any], mesh) -> Dict[str, Any]:
    """PartitionSpecs matching an ``input_specs`` dict."""
    dp = dp_axes_of(mesh)
    nd = _dp_size(mesh)
    out: Dict[str, Any] = {}
    for k, v in specs.items():
        if k == "caches":
            out[k] = cache_pspecs(v, mesh)
        elif k == "pos":
            out[k] = P()
        else:
            bdp = dp if (dp and v.shape[0] % nd == 0) else None
            if k in ("embeds", "src_embeds"):
                out[k] = P(bdp, None, None)
            else:  # tokens / labels / token
                out[k] = P(bdp, None)
    return out


def abstract_params(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs without allocation."""
    return jax.eval_shape(partial(T.init_lm, cfg=cfg), jax.random.PRNGKey(0))


def abstract_state(cfg: ArchConfig, opt_init):
    params = abstract_params(cfg)
    opt_state = jax.eval_shape(opt_init, params)
    return params, opt_state
