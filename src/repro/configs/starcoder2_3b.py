"""starcoder2-3b [dense] — GQA kv=2, RoPE, GELU MLP, tied embeddings.
[arXiv:2402.19173; hf]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab=49152,
    qkv_bias=True,
    ffn="gelu",
    norm="layernorm",
    tie_embeddings=True,
    rope_theta=100_000.0,
)
