"""Pallas TPU kernels for the compute hot-spots (+ ops.py dispatch wrappers,
ref.py pure-jnp oracles).  Validated in interpret mode on CPU."""

from . import ops, ref  # noqa: F401
