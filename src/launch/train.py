"""Alias for :mod:`repro.launch.train` — see that module for the driver.

Usage::

    PYTHONPATH=src python -m launch.train --workload sde-gan --steps 2
"""

from repro.launch.train import (  # noqa: F401
    main,
    train,
    train_latent_sde,
    train_sde_gan,
)

if __name__ == "__main__":
    main()
