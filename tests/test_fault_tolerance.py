"""Fault-tolerance drill: checkpoint/restart, determinism, elasticity.

The required posture for 1000+-node runs: a killed run resumed from its
last checkpoint must produce the SAME loss trajectory as an uninterrupted
run (deterministic data + optimizer state in the checkpoint)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data.synthetic import token_batches
from repro.distributed.elastic import plan_mesh, rebatch, surviving_devices
from repro.launch.train import StragglerMonitor, train


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"a": jax.random.normal(key, (4, 3)),
            "b": [jnp.arange(5), {"c": jnp.float32(2.5)}]}
    ckpt.save_checkpoint(tmp_path, 7, tree)
    restored, step = ckpt.restore_checkpoint(tmp_path, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_pruning(tmp_path, key):
    tree = {"w": jax.random.normal(key, (8,))}
    for s in (10, 20, 30, 40):
        ckpt.save_checkpoint(tmp_path, s, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 40
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.glob("step_*"))
    assert steps == [30, 40]  # pruned to keep=2
    # a directory without MANIFEST is invalid and ignored
    bad = tmp_path / "step_000000000099"
    bad.mkdir()
    assert ckpt.latest_step(tmp_path) == 40


def test_restore_rejects_shape_mismatch(tmp_path, key):
    ckpt.save_checkpoint(tmp_path, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(tmp_path, {"w": jnp.zeros((5,))})


def test_failure_restart_reproduces_trajectory(tmp_path):
    """Kill at step 30, resume, and match the uninterrupted run exactly."""
    kwargs = dict(arch="tinyllama-1.1b", steps=12, batch=2, seq=16,
                  ckpt_every=4, smoke=True, seed=0)
    _, losses_full = train(ckpt_dir=None, **kwargs)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        train(ckpt_dir=str(tmp_path), fail_at_step=6, **kwargs)
    _, losses_resumed = train(ckpt_dir=str(tmp_path), **kwargs)
    # resumed run covers steps 4..11 (last checkpoint at 4)
    np.testing.assert_allclose(losses_full[-len(losses_resumed):],
                               losses_resumed, rtol=1e-4)


def test_deterministic_data_pipeline(key):
    """batch(step) is a pure function of (key, step) — elastic replay."""
    b1 = token_batches(key, jnp.int32(17), 4, 32, 1000)
    b2 = token_batches(key, jnp.int32(17), 4, 32, 1000)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = token_batches(key, jnp.int32(18), 4, 32, 1000)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_elastic_mesh_planning():
    assert plan_mesh(512, 16) == (32, 16)
    assert plan_mesh(448, 16) == (28, 16)   # lost 4 hosts of 16
    assert plan_mesh(8, 16) == (1, 8)       # degrade TP when tiny
    assert rebatch(256, 28) == 10           # ceil(256/28)
    assert surviving_devices(512, 4, 8) == 480


def test_straggler_monitor():
    m = StragglerMonitor(factor=3.0)
    for _ in range(10):
        assert not m.observe(0.1)
    assert m.observe(1.0)       # 10x the mean -> flagged
    assert m.flagged == 1


def test_gradient_compression_error_feedback(key):
    """int8 EF compression: the quantisation error is carried, not lost."""
    from repro.optim.compression import decompress_int8, ef_compress_update

    g = {"w": jax.random.normal(key, (256,)) * 0.01}
    err0 = jax.tree.map(jnp.zeros_like, g)
    q, s, err1 = ef_compress_update(g, err0)
    deq = decompress_int8(q["w"], s["w"])
    np.testing.assert_allclose(np.asarray(deq + err1["w"]), np.asarray(g["w"]),
                               rtol=1e-6, atol=1e-7)
    # int8 payload is 4x smaller than f32
    assert q["w"].dtype == jnp.int8
