"""Brownian motion sampling — in-graph (XLA/TPU-native) implementations.

Three samplers, mirroring the paper's landscape (Section 4):

* :class:`BrownianPath` — the TPU-native adaptation of the paper's Brownian
  Interval.  JAX's counter-based splittable PRNG (Threefry; the paper's own
  reference [34] for splittable PRNGs) lets us derive the increment of *any*
  solver step from ``fold_in(key, step_index)``: exact, O(1) memory, O(1)
  time, and bit-identical on the forward and backward passes with **zero**
  storage.  Off-grid queries use Lévy-bridge bisection over a virtual dyadic
  tree, conditioning exactly as the paper's eq. (8).

* :class:`VirtualBrownianTree` — the Li et al. [15] baseline the paper beats:
  fixed-depth dyadic bisection to a tolerance ``eps``; approximate.

* :func:`brownian_increments` — dense pregenerated increments (the
  "store everything" O(T)-memory baseline).

The *faithful* host-side Brownian Interval (binary tree + LRU cache + search
hints, Algorithms 3/4) lives in :mod:`repro.core.brownian_interval`.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _normal_like(key: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return jax.random.normal(key, shape, dtype=dtype)


def brownian_increments(
    key: jax.Array,
    t0: float,
    t1: float,
    num_steps: int,
    shape: Tuple[int, ...],
    dtype=jnp.float32,
) -> jax.Array:
    """Dense iid increments ``W_{t_{n+1}} - W_{t_n}`` — O(T) memory baseline."""
    dt = (t1 - t0) / num_steps
    keys = jax.random.split(key, num_steps)
    out = jax.vmap(lambda k: _normal_like(k, shape, dtype))(keys)
    return out * jnp.sqrt(jnp.asarray(dt, dtype))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BrownianPath:
    """Exact, stateless, counter-based Brownian sample path on ``[t0, t1]``.

    The path is *defined* by ``key``: every query is a pure function of
    ``(key, query)``, so forward and backward passes of a solver see the same
    sample without storing anything (the paper's core requirement, §4).

    ``increment(n, num_steps)`` is the fast path used by fixed-step solvers:
    step ``n`` of an ``num_steps``-step grid.  Different grids over the same
    key are *different* refinements consistent in distribution but not
    pathwise; solvers must use one grid per solve (as torchsde's fixed-step
    solvers do).  ``evaluate(s, t)`` offers pathwise-consistent arbitrary
    queries via dyadic Lévy-bridge descent (exact at dyadic points, depth-
    limited elsewhere like the Virtual Brownian Tree but reusing the same
    conditioning as the paper's eq. (8)).
    """

    key: jax.Array
    t0: float
    t1: float
    shape: Tuple[int, ...]
    dtype: object = jnp.float32

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.key,), (self.t0, self.t1, self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, t1, shape, dtype = aux
        return cls(key=key, t0=t0, t1=t1, shape=shape, dtype=dtype)

    # -- fixed-grid exact increments ----------------------------------------
    def increment(self, n: jax.Array, num_steps: int) -> jax.Array:
        """Exact increment of step ``n`` on the ``num_steps`` uniform grid.

        Dispatches through :mod:`repro.kernels.ops`: on TPU the draw runs
        *inside* a Pallas kernel (counter-based Threefry keyed on ``n``,
        bit-identical to the ``jax.random`` scheme — see
        :mod:`repro.kernels.prng`); elsewhere the pure-jnp oracle runs.
        """
        from ..kernels import ops

        dt = (self.t1 - self.t0) / num_steps
        return ops.brownian_increment(self.key, n, self.shape, self.dtype, dt)

    def increments(self, num_steps: int) -> jax.Array:
        """All increments on the grid, stacked (for dense baselines/tests)."""
        return jax.vmap(lambda n: self.increment(n, num_steps))(
            jnp.arange(num_steps)
        )

    # -- arbitrary-interval queries (Lévy bridge descent) --------------------
    def evaluate(self, s, t, depth: int = 24) -> jax.Array:
        """``W_t - W_s`` via ``W(t) - W(s)`` with dyadic bridge descent."""
        return self._w(t, depth) - self._w(s, depth)

    def value(self, t, depth: int = 24) -> jax.Array:
        """``W(t) - W(t0)`` — one bridge descent.  Contract (relied on by
        the adaptive driver, which carries the left-endpoint value):
        ``evaluate(s, t) == value(t) - value(s)`` bitwise."""
        return self._w(t, depth)

    def _w(self, t, depth: int) -> jax.Array:
        """Sample W(t) by descending the virtual dyadic tree to ``depth``.

        Invariant per level: the current interval ``[a, b]`` has endpoint
        values ``(wa, wb)``; the midpoint value is bridge-sampled from the
        interval's splittable seed (the Lévy bridge of the paper's eq. (8):
        mean = linear interpolant, std = sqrt((b-m)(m-a)/(b-a))), then we
        recurse into the half containing ``t``.  At dyadic ``t`` this
        terminates exactly; otherwise the depth bound gives a
        2^-depth * (t1-t0) resolution (the VBT trade-off, but sharing seeds
        with ``increment`` queries is not required — a BrownianPath used
        with bridge queries should use ``evaluate`` only).

        Dispatches through :mod:`repro.kernels.ops`: on TPU the whole
        descent runs as ONE Pallas kernel (in-kernel Threefry + a single
        batched midpoint draw); elsewhere the vectorised jnp oracle
        (:func:`repro.kernels.ref.brownian_value`) runs — same per-element
        op sequence, so both produce identical bits.
        """
        from ..kernels import ops

        return ops.brownian_value(self.key, t, self.t0, self.t1, self.shape,
                                  self.dtype, depth=depth)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseBrownianPath:
    """Pregenerated fine-grid increments with *pathwise-consistent*
    coarsening: ``increment(n, N)`` sums the fine increments inside coarse
    step ``n``.  This is the O(T)-memory baseline — and the right tool for
    strong-convergence measurements, where coarse and fine solves must see
    the SAME sample path (the counter-based :class:`BrownianPath` gives
    per-grid refinements that agree in law but not pathwise)."""

    w: jax.Array  # (fine_steps, *shape) increments on the finest grid
    t0: float = 0.0
    t1: float = 1.0

    def tree_flatten(self):
        return (self.w,), (self.t0, self.t1)

    @classmethod
    def tree_unflatten(cls, aux, children):
        t0, t1 = aux
        return cls(w=children[0], t0=t0, t1=t1)

    @classmethod
    def sample(cls, key, t0: float, t1: float, fine_steps: int, shape,
               dtype=jnp.float32):
        return cls(brownian_increments(key, t0, t1, fine_steps, shape, dtype),
                   t0=t0, t1=t1)

    @property
    def fine_steps(self) -> int:
        return self.w.shape[0]

    def increment(self, n: jax.Array, num_steps: int) -> jax.Array:
        r = self.fine_steps // num_steps
        assert r * num_steps == self.fine_steps, \
            f"{num_steps} must divide fine_steps={self.fine_steps}"
        if r == 1:
            return lax.dynamic_index_in_dim(self.w, n, 0, keepdims=False)
        return jnp.sum(lax.dynamic_slice_in_dim(self.w, n * r, r, 0), axis=0)

    # -- arbitrary-interval queries (adaptive solvers) -----------------------
    def _w_at(self, t) -> jax.Array:
        """W(t) from the stored fine increments: exact at fine-grid nodes
        (prefix sums of ``w``), linearly interpolated inside a fine cell.
        The interpolation is the bridge *mean* — deterministic, so
        ``evaluate`` stays exactly additive — but it under-resolves
        variation below the fine grid; size ``fine_steps`` well above the
        expected adaptive step count.

        The prefix sum is recomputed per query rather than cached on the
        pytree: under jit it is a loop constant (XLA hoists it out of the
        adaptive while_loop), and the eager payers are tests/benchmarks —
        a second ``cum`` leaf would complicate every vmap-constructed
        ``DenseBrownianPath(w_i, ...)`` for an O(fine_steps) win nothing
        on the hot path needs."""
        dtype = self.w.dtype
        t = jnp.asarray(t, dtype)
        pos = (t - self.t0) / (self.t1 - self.t0) * self.fine_steps
        pos = jnp.clip(pos, 0.0, float(self.fine_steps))
        i = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, self.fine_steps - 1)
        frac = pos - i.astype(dtype)
        cum = jnp.cumsum(self.w, axis=0)  # cum[k] = W(node k+1) − W(t0)
        w_lo = jnp.where(i > 0, lax.dynamic_index_in_dim(
            cum, jnp.maximum(i - 1, 0), 0, keepdims=False), jnp.zeros_like(self.w[0]))
        inc = lax.dynamic_index_in_dim(self.w, i, 0, keepdims=False)
        return w_lo + frac * inc

    def evaluate(self, s, t) -> jax.Array:
        """``W_t − W_s``; pathwise-consistent with :meth:`increment` (sums of
        the same fine increments) and exactly additive over adjacent
        intervals, because every query is a difference of ``W(·)``."""
        return self._w_at(t) - self._w_at(s)

    def value(self, t) -> jax.Array:
        """``W(t) − W(t0)`` (see :meth:`BrownianPath.value` for the
        ``evaluate(s,t) == value(t) − value(s)`` contract)."""
        return self._w_at(t)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class VirtualBrownianTree:
    """Li et al. [15] baseline: approximate dyadic bisection to tolerance.

    Every query pays the *full* ``O(log(1/eps))`` descent from the root —
    exactly the cost profile the Brownian Interval removes (paper Table 2).
    """

    key: jax.Array
    t0: float
    t1: float
    shape: Tuple[int, ...]
    tol: float = 1e-5
    dtype: object = jnp.float32

    def tree_flatten(self):
        return (self.key,), (self.t0, self.t1, self.shape, self.tol, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, t1, shape, tol, dtype = aux
        return cls(key=key, t0=t0, t1=t1, shape=shape, tol=tol, dtype=dtype)

    @property
    def _depth(self) -> int:
        import math

        span = self.t1 - self.t0
        return max(1, int(math.ceil(math.log2(max(span / self.tol, 2.0)))))

    def _w(self, t) -> jax.Array:
        path = BrownianPath(self.key, self.t0, self.t1, self.shape, self.dtype)
        return path._w(t, depth=self._depth)

    def evaluate(self, s, t) -> jax.Array:
        return self._w(t) - self._w(s)

    def value(self, t) -> jax.Array:
        return self._w(t)

    def increment(self, n: jax.Array, num_steps: int) -> jax.Array:
        dt = (self.t1 - self.t0) / num_steps
        s = self.t0 + n * dt
        return self.evaluate(s, s + dt)


def space_time_levy_area(key: jax.Array, dt, shape, dtype=jnp.float32):
    """Sample ``(W, H)`` on an interval: increment + space-time Lévy area.

    ``H`` (Foster et al. [54]) is N(0, dt/12) independent of W — used by the
    higher-order / additive-noise paths and by the log-ODE style solvers the
    paper's Appendix E discusses.  Included as a building block for the
    ``W̃`` Lévy-area approximation of Davie/Foster (Appendix E, eq. for W̃).
    """
    kw, kh = jax.random.split(key)
    dt = jnp.asarray(dt, dtype)
    w = jax.random.normal(kw, shape, dtype) * jnp.sqrt(dt)
    h = jax.random.normal(kh, shape, dtype) * jnp.sqrt(dt / 12.0)
    return w, h


def davie_levy_area(key: jax.Array, w: jax.Array, h: jax.Array, dt) -> jax.Array:
    """Davie/Foster approximation of the second iterated integral W̃ (App. E).

    ``W̃ = 0.5 W⊗W + H⊗W − W⊗H + λ`` with antisymmetric λ, λ_ij ~ N(0, dt²/12).
    ``w, h`` have shape (..., d); returns (..., d, d).
    """
    d = w.shape[-1]
    dtype = w.dtype
    lam_flat = jax.random.normal(key, w.shape[:-1] + (d, d), dtype)
    lam = (jnp.tril(lam_flat, -1) - jnp.swapaxes(jnp.tril(lam_flat, -1), -1, -2)) * jnp.sqrt(
        jnp.asarray(dt, dtype) ** 2 / 12.0
    )
    outer = lambda a, b: a[..., :, None] * b[..., None, :]
    return 0.5 * outer(w, w) + outer(h, w) - outer(w, h) + lam
