"""Lipschitz-constrained Neural-CDE discriminator stack (paper §5 / eq. (2)).

The SDE-GAN discriminator is the Neural CDE

    H_0 = ξ_φ(t_0, Y_0),   dH_t = f_φ(t, H_t) dt + g_φ(t, H_t) d(t, Y_t),
    F_φ(Y) = m_φ · H_T

driven by the generator's (time-augmented) sample path.  Its recurrent
structure amplifies any vector-field Lipschitz constant λ > 1 to O(λ^T), so
the whole stack is built to live inside the Lipschitz-1 constraint set:

* **LipSwish** activations throughout (Lipschitz 1, C² — ReLU is ruled out
  by the solver's smoothness requirements, paper Appendix D);
* every Linear is initialised with entries drawn from
  ``[-1/fan_in, 1/fan_in]`` — the *same* box the careful-clipping projection
  (:mod:`repro.core.clipping`) enforces after each optimiser update, so the
  discriminator starts inside the constraint set rather than being slammed
  onto its boundary by the first clip;
* the readout ``m`` is deliberately unconstrained (it is applied once at
  ``t = T``, not recurrently — clipping it would only shrink the score
  scale, paper §5).

This module owns the parameters and vector fields; solving the CDE against
a control path is composed one layer up (``repro.core.sde``) so that ``nn``
stays free of solver dependencies.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .core import linear, linear_init, lipswish, mlp
from .core import tcat as _tcat


@dataclasses.dataclass(frozen=True)
class CDEDiscriminatorSpec:
    """Shapes of the discriminator stack (decoupled from the generator's)."""

    data_dim: int = 1      # y — dimension of the observed/generated path
    hidden_dim: int = 16   # h — CDE state
    width: int = 32
    depth: int = 1
    dtype: object = jnp.float32


def _box_mlp_init(key, sizes, dtype) -> dict:
    """MLP init *drawn inside* the careful-clipping box: each layer's
    entries uniform in [-1/fan_in, 1/fan_in] (not a wider law clipped down,
    which would pile most mass onto the boundary)."""
    keys = jax.random.split(key, len(sizes) - 1)
    return {"layers": [linear_init(k, a, b, scale=1.0 / a, dtype=dtype)
                       for k, a, b in zip(keys, sizes[:-1], sizes[1:])]}


def cde_discriminator_init(key, spec: CDEDiscriminatorSpec) -> dict:
    """Init the full stack: ``xi`` (initial condition), ``f`` (drift field),
    ``g`` (control field), ``m`` (readout).  ``xi``/``f``/``g`` start
    strictly inside the Lipschitz constraint set that training-time careful
    clipping enforces; ``m`` is unconstrained (see module docstring)."""
    kx, kf, kg, km = jax.random.split(key, 4)
    hid = [spec.width] * spec.depth
    h, y, d = spec.hidden_dim, spec.data_dim, spec.dtype
    return {
        "xi": _box_mlp_init(kx, [1 + y] + hid + [h], dtype=d),
        "f": _box_mlp_init(kf, [1 + h] + hid + [h], dtype=d),
        "g": _box_mlp_init(kg, [1 + h] + hid + [h * (1 + y)], dtype=d),
        "m": linear_init(km, h, 1, dtype=d),
    }


def cde_initial(params: dict, t0, y0) -> jax.Array:
    """H_0 = ξ_φ(t_0, Y_0)."""
    return mlp(params["xi"], _tcat(t0, y0), lipswish)


def cde_drift(spec: CDEDiscriminatorSpec):
    """f_φ: (t, h) -> dh/dt drift component."""

    def f(params, t, h):
        return mlp(params["f"], _tcat(t, h), lipswish, jnp.tanh)

    return f


def cde_control_field(spec: CDEDiscriminatorSpec):
    """g_φ: (t, h) -> (h, 1+y) matrix field against the time-augmented
    control (t, Y_t), so the vector field sees dt through the control too."""

    def g(params, t, h):
        out = mlp(params["g"], _tcat(t, h), lipswish, jnp.tanh)
        return out.reshape(h.shape[:-1] + (spec.hidden_dim, 1 + spec.data_dim))

    return g


def cde_readout(params: dict, h_final: jax.Array) -> jax.Array:
    """F_φ = m · H_T, scalar score per batch element."""
    return linear(params["m"], h_final)[..., 0]
