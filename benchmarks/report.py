"""Render EXPERIMENTS.md tables from experiments/{dryrun,roofline}/*.json."""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1] / "experiments"


def _gib(n):
    return n / 2**30


def dryrun_table() -> str:
    rows = []
    header = ("| arch | shape | mesh | step | peak GiB/dev | args GiB/dev | "
              "HLO flops/dev | HLO bytes/dev | coll bytes/dev | compile s |")
    rows.append(header)
    rows.append("|" + "---|" * 10)
    for p in sorted((ROOT / "dryrun").glob("*.json")):
        r = json.loads(p.read_text())
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                        f"skip | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                        f"**FAILED** | — | — | — | — | — |")
            continue
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step_kind']} | "
            f"{_gib(m['peak_bytes']):.2f} | {_gib(m['argument_bytes']):.2f} | "
            f"{r['flops']:.2e} | {r['bytes_accessed']:.2e} | "
            f"{r['collective_bytes']['total']:.2e} | {r['compile_seconds']} |")
    return "\n".join(rows)


def roofline_table(variant: str = "baseline") -> str:
    rows = []
    rows.append("| arch | shape | compute s | memory s | collective s | "
                "dominant | MODEL/HLO flops | roofline frac |")
    rows.append("|" + "---|" * 8)
    for p in sorted((ROOT / "roofline").glob(f"*__{variant}.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            tag = "skip" if r.get("status") == "skipped" else "**FAILED**"
            rows.append(f"| {r['arch']} | {r['shape']} | {tag} | | | | | |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f}ms | "
            f"{r['memory_s']*1e3:.2f}ms | {r['collective_s']*1e3:.2f}ms | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def variant_comparison(arch: str, shape: str) -> str:
    rows = ["| variant | compute s | memory s | collective s | dominant | roofline frac |",
            "|" + "---|" * 6]
    for p in sorted((ROOT / "roofline").glob(f"{arch}__{shape}__*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        rows.append(f"| {r['variant']} | {r['compute_s']*1e3:.2f}ms | "
                    f"{r['memory_s']*1e3:.2f}ms | {r['collective_s']*1e3:.2f}ms | "
                    f"{r['dominant']} | {r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys

    what = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    if what == "dryrun":
        print(dryrun_table())
    elif what == "roofline":
        print(roofline_table(sys.argv[2] if len(sys.argv) > 2 else "baseline"))
    else:
        print(variant_comparison(sys.argv[2], sys.argv[3]))
