"""Serving subsystem tests (DESIGN.md §9).

The bucket-padding invariant (padding a request batch up to a compiled
bucket must not change the rows a client asked for), the train→serve
checkpoint handshake, the quarantined LM path, the streamed rollout's
chunk continuity, and the launch CLI on 1 and 2 (simulated) devices.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.sde import (LatentSDEConfig, NeuralSDEConfig, generator_init,
                            generator_initial_state, latent_sde_init)
from repro.launch.steps import (make_sample_step, make_stream_chunk_step)

GAN_CFG = dict(data_dim=1, hidden_dim=8, noise_dim=4, width=16, num_steps=8)
LATENT_CFG = dict(data_dim=2, hidden_dim=8, context_dim=8, width=16,
                  num_steps=16)


def _sampler(workload, key, **kw):
    if workload == "sde-gan":
        cfg = NeuralSDEConfig(**GAN_CFG)
        params = generator_init(key, cfg)
    else:
        cfg = LatentSDEConfig(**LATENT_CFG)
        params = latent_sde_init(key, cfg)
    return cfg, params, jax.jit(make_sample_step(workload, cfg, **kw))


# -----------------------------------------------------------------------------
# bucket padding: the determinism invariant the AOT cache relies on
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("workload,kw", [
    ("sde-gan", {}),
    ("latent-sde", {}),
    ("latent-sde", dict(latent_mode="posterior", obs_len=9)),
])
def test_bucket_padding_preserves_unpadded_rows(key, workload, kw):
    """The same 3 request keys inside a 4-bucket and an 8-bucket produce
    bitwise-identical trajectories — every row is a pure function of its
    own key, so off-size batches pad up without perturbing real rows."""
    _, params, step = _sampler(workload, key, **kw)
    real = jax.random.split(jax.random.fold_in(key, 1), 3)
    out = {}
    for bucket in (4, 8):
        pad = jax.random.split(jax.random.fold_in(key, 2), bucket - 3)
        ys = step(params, jnp.concatenate([real, pad]))
        assert ys.shape[1] == bucket
        assert np.isfinite(np.asarray(ys)).all()
        out[bucket] = np.asarray(ys[:, :3])
    np.testing.assert_array_equal(out[4], out[8])


def test_sampler_rejects_bad_workload_and_grid(key):
    cfg = NeuralSDEConfig(**GAN_CFG)
    with pytest.raises(ValueError, match="workload"):
        make_sample_step("lm", cfg)
    lcfg = LatentSDEConfig(**LATENT_CFG)
    with pytest.raises(ValueError, match="latent_mode"):
        make_sample_step("latent-sde", lcfg, latent_mode="magic")
    with pytest.raises(ValueError, match="obs_len"):
        make_sample_step("latent-sde", lcfg, latent_mode="posterior")
    # posterior observation grid must align with the solver grid
    with pytest.raises(ValueError, match=r"num_steps \(16\).*T \(6"):
        make_sample_step("latent-sde", lcfg, latent_mode="posterior",
                         obs_len=7)


# -----------------------------------------------------------------------------
# streamed rollout: chunk continuity
# -----------------------------------------------------------------------------


def test_stream_chunks_are_continuous(key):
    """Chunk c's first emitted row equals chunk c-1's last — the carried
    hidden state stitches the stream into one trajectory.  One compiled
    program serves every chunk (t_start is traced)."""
    cfg = NeuralSDEConfig(**GAN_CFG)
    params = generator_init(key, cfg)
    chunks, steps_per = 4, cfg.num_steps // 4
    span = cfg.t1 / chunks
    chunk_fn = jax.jit(make_stream_chunk_step(cfg, span, steps_per))
    keys = jax.random.split(jax.random.fold_in(key, 1), 3)
    x = generator_initial_state(params, cfg, keys)
    prev_last = None
    for c in range(chunks):
        ckeys = jax.vmap(lambda k, c=c: jax.random.fold_in(k, 1000 + c))(keys)
        ys, x = chunk_fn(params, ckeys, x, jnp.asarray(c * span, cfg.dtype))
        assert ys.shape == (steps_per + 1, 3, cfg.data_dim)
        if prev_last is not None:
            np.testing.assert_allclose(np.asarray(ys[0]), prev_last,
                                       rtol=1e-6, atol=1e-6)
        prev_last = np.asarray(ys[-1])


# -----------------------------------------------------------------------------
# checkpoint handshake: train -> serve round trip, named failure modes
# -----------------------------------------------------------------------------


def test_checkpoint_roundtrip_train_to_serve(key, tmp_path):
    """train_sde_gan writes the serving bundle alongside its checkpoints;
    restore_for_serving rebuilds the config and restores bitwise-equal
    generator params, and the restored model samples finite trajectories."""
    from repro.launch.serve import restore_for_serving
    from repro.launch.train import train_sde_gan

    trained, _ = train_sde_gan(steps=2, batch=8, ckpt_dir=str(tmp_path),
                               ckpt_every=1, num_steps=8, seq_len=9,
                               log_every=100)
    params, cfg, step = restore_for_serving("sde-gan", str(tmp_path))
    assert step == 2
    assert cfg.num_steps == 8
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(trained["gen"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ys = jax.jit(make_sample_step("sde-gan", cfg))(
        params, jax.random.split(key, 2))
    assert np.isfinite(np.asarray(ys)).all()


def test_serving_handshake_named_errors(key, tmp_path):
    from repro.launch.serve import restore_for_serving

    # no bundle at all -> a named pointer at train.py / --smoke
    with pytest.raises(FileNotFoundError, match="serving bundle"):
        ckpt.load_serving_meta(tmp_path)
    # bundle for the other workload -> named mismatch, not a pytree error
    cfg = LatentSDEConfig(**LATENT_CFG)
    ckpt.save_serving_bundle(tmp_path, 3, latent_sde_init(key, cfg),
                             "latent-sde", cfg)
    with pytest.raises(ValueError, match="workload"):
        restore_for_serving("sde-gan", str(tmp_path))
    # the happy path restores the config dataclass, dtype included
    params, cfg2, step = restore_for_serving("latent-sde", str(tmp_path))
    assert step == 3 and cfg2.num_steps == cfg.num_steps
    assert jnp.dtype(cfg2.dtype) == jnp.dtype(cfg.dtype)


# -----------------------------------------------------------------------------
# the quarantined LM path
# -----------------------------------------------------------------------------


def test_sde_serving_never_imports_transformer_stack():
    """`--workload sde-gan` must not touch repro.models (the seed scaffold's
    LM decode loop lives behind --workload lm only)."""
    from repro.launch import serve

    for m in [m for m in sys.modules if m.startswith("repro.models")]:
        del sys.modules[m]
    serve.main(["--workload", "sde-gan", "--smoke", "--requests", "2",
                "--max-batch", "2", "--sde-steps", "8"])
    assert not any(m.startswith("repro.models") for m in sys.modules)


# -----------------------------------------------------------------------------
# the launch CLI, 1 and 2 (simulated) devices
# -----------------------------------------------------------------------------


def _run_serve_cli(extra_args=(), extra_env=None):
    repo = Path(__file__).resolve().parents[1]
    # pin XLA_FLAGS: importing repro.launch.dryrun anywhere in the pytest
    # process (test_analysis does) exports a 512-device flag that these
    # subprocesses would otherwise inherit
    env = dict(os.environ, PYTHONPATH=str(repo / "src"), XLA_FLAGS="")
    env.update(extra_env or {})
    cmd = [sys.executable, "-m", "repro.launch.serve", "--smoke",
           "--requests", "6", "--max-batch", "4", "--sde-steps", "8",
           *extra_args]
    return subprocess.run(cmd, cwd=repo, env=env, capture_output=True,
                          text=True, timeout=600)


def test_serve_cli_single_device():
    r = _run_serve_cli(["--workload", "sde-gan"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "traj/s" in r.stdout
    assert "latency p50" in r.stdout


def test_serve_cli_two_simulated_devices():
    r = _run_serve_cli(["--workload", "sde-gan", "--host-devices", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "data-parallel over 2 devices" in r.stdout
    assert "traj/s" in r.stdout


def test_serve_cli_latent_and_stream():
    r = _run_serve_cli(["--workload", "latent-sde", "--sde-steps", "16"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "traj/s" in r.stdout
    r = _run_serve_cli(["--workload", "sde-gan", "--stream-chunks", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "first-chunk latency" in r.stdout


def test_serve_cli_scheduler_modes():
    """--scheduler runs the continuous-batching path (and its fifo
    baseline) through the same CLI; both report the usual latency lines
    plus the scheduler's pool summary."""
    for mode in ("continuous", "fifo"):
        r = _run_serve_cli(["--workload", "sde-gan", "--scheduler", mode])
        assert r.returncode == 0, r.stderr[-2000:]
        assert f"scheduler-{mode}" in r.stdout
        assert "traj/s" in r.stdout
        assert "latency p50" in r.stdout
        assert "admission at chunk boundaries" in r.stdout


def test_serve_cli_scheduler_two_simulated_devices():
    """The scheduler's re-stacked batch operands must agree with the AOT
    input shardings under a data-parallel mesh (Scheduler._put pins both
    sides)."""
    r = _run_serve_cli(["--workload", "sde-gan", "--scheduler", "continuous",
                        "--host-devices", "2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "data-parallel over 2 devices" in r.stdout
    assert "scheduler-continuous" in r.stdout
    assert "traj/s" in r.stdout


def test_serve_cli_async_preempt_pool_budget():
    """The PR 10 scheduler extras through the CLI: --async-front drives
    the asyncio ingestion path, --preempt and --pool-budget-mb thread to
    the scheduler/registry (a generous budget evicts nothing but prints
    its accounting), and all three are rejected by name without
    --scheduler."""
    r = _run_serve_cli(["--workload", "sde-gan", "--scheduler", "continuous",
                        "--async-front", "--preempt",
                        "--pool-budget-mb", "4096"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "scheduler-continuous" in r.stdout
    assert "pool budget 4096 MB" in r.stdout
    assert "0 evictions" in r.stdout
    assert "latency p50" in r.stdout
    r = _run_serve_cli(["--workload", "sde-gan", "--async-front"])
    assert r.returncode != 0
    assert "--scheduler" in r.stderr


def test_serve_cli_adaptive_per_request_tolerance():
    """--adaptive terminal sampling: several distinct request tolerances
    must be served by exactly one compiled program per bucket (rtol is
    traced, never a cache key), and the latent workload is rejected by
    name (no fixed output grid to serve)."""
    r = _run_serve_cli(["--workload", "sde-gan", "--adaptive"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "traj/s" in r.stdout
    assert "distinct tolerances" in r.stdout
    assert "no recompiles" in r.stdout
    r = _run_serve_cli(["--workload", "latent-sde", "--adaptive"])
    assert r.returncode != 0
    assert "terminal samples" in r.stderr
