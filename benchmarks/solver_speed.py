"""Paper Tables 1/4/5 (speed axis): reversible Heun vs midpoint/Heun.

All timings go through the unified :func:`repro.solve` front-end.  Three
comparisons:

1. **Solver × gradient-mode** (the paper's headline): wall time + NFE of a
   full forward+backward through an SDE-GAN-scale Neural SDE.  Reversible
   Heun needs 1 NFE/step (vs 2) and the O(1)-memory exact adjoint — the
   up-to-1.98× training-speed win of Table 1.
2. **Fused vs unfused**: the reversible-Heun hot loop with and without the
   Pallas step kernels (``use_pallas_kernels``).  On TPU the fused kernels
   collapse ~6 HBM round-trips per step into one read+write per operand;
   off-TPU the fused flag dispatches to the fused jnp oracle (DESIGN.md
   §5), so the CPU number is a parity check, not a kernel speed claim.
3. **Batched vs looped**: ``repro.solve_batched`` (one vmapped XLA program
   over a batch of initial states × Brownian seeds) against a Python loop
   of single solves.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

try:
    from . import report
except ImportError:  # run as a loose script
    import report


def _timeit(fn, *args, reps: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_solver(solver: str, exact_adjoint: bool, num_steps: int = 64,
                 batch: int = 128, reps: int = 5):
    from repro.core.brownian import BrownianPath
    from repro.core.solve import get_solver, solve
    from repro import nn

    key = jax.random.PRNGKey(0)
    x_dim, w_dim, width = 32, 16, 64
    kp1, kp2, kz, kw = jax.random.split(key, 4)
    params = {
        "f": nn.mlp_init(kp1, [1 + x_dim, width, x_dim]),
        "g": nn.mlp_init(kp2, [1 + x_dim, width, x_dim * w_dim]),
    }

    def tcat(t, x):
        tt = jnp.broadcast_to(jnp.asarray(t, x.dtype), x.shape[:-1] + (1,))
        return jnp.concatenate([tt, x], -1)

    def drift(p, t, x):
        return nn.mlp(p["f"], tcat(t, x), nn.lipswish, jnp.tanh)

    def diffusion(p, t, x):
        out = nn.mlp(p["g"], tcat(t, x), nn.lipswish, jnp.tanh)
        return out.reshape(x.shape[:-1] + (x_dim, w_dim))

    z0 = jax.random.normal(kz, (batch, x_dim))
    bm = BrownianPath(kw, 0.0, 1.0, (batch, w_dim))
    mode = "reversible_adjoint" if exact_adjoint else "discretise"

    def loss(p):
        traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                     solver=solver, gradient_mode=mode, noise="general")
        return jnp.mean(traj[-1] ** 2)

    dt = _timeit(jax.jit(jax.grad(loss)), params, reps=reps)
    return dt, get_solver(solver).nfe_per_step * num_steps


def bench_fused_vs_unfused(num_steps: int = 64, batch: int = 128,
                           x_dim: int = 128, reps: int = 5):
    """Reversible-Heun exact-adjoint training step, Pallas-fused vs not.

    Diagonal noise (the fused kernels' layout); same problem either way, so
    the ratio isolates the step-update fusion.
    """
    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve
    from repro import nn

    key = jax.random.PRNGKey(1)
    kp1, kp2, kz, kw = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(kp1, [x_dim, 64, x_dim]),
              "g": nn.mlp_init(kp2, [x_dim, 64, x_dim])}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p, t, x: 0.2 * nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
    z0 = jax.random.normal(kz, (batch, x_dim))
    bm = BrownianPath(kw, 0.0, 1.0, (batch, x_dim))

    def loss(p, fused):
        traj = solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                     solver="reversible_heun",
                     gradient_mode="reversible_adjoint",
                     use_pallas_kernels=fused)
        return jnp.mean(traj[-1] ** 2)

    out = {}
    for fused in (False, True):
        g = jax.jit(jax.grad(lambda p: loss(p, fused)))
        out["fused" if fused else "unfused"] = _timeit(g, params, reps=reps)
    return out


def bench_batched_vs_looped(batch: int = 32, num_steps: int = 64,
                            x_dim: int = 32, reps: int = 3):
    """One vmapped multi-trajectory solve vs a Python loop of solves."""
    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve, solve_batched
    from repro import nn

    key = jax.random.PRNGKey(2)
    kp1, kp2, kz, kk = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(kp1, [x_dim, 64, x_dim]),
              "g": nn.mlp_init(kp2, [x_dim, 64, x_dim])}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p, t, x: 0.2 * nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
    z0 = jax.random.normal(kz, (batch, x_dim))
    keys = jax.random.split(kk, batch)

    batched = jax.jit(lambda z, k: solve_batched(
        drift, diffusion, params, z, k, 0.0, 1.0, num_steps,
        solver="reversible_heun"))

    single = jax.jit(lambda z, k: solve(
        drift, diffusion, params, z,
        BrownianPath(k, 0.0, 1.0, (x_dim,)), 0.0, 1.0, num_steps,
        solver="reversible_heun"))

    def looped(z, ks):
        return [single(z[i], ks[i]) for i in range(batch)]

    return {"batched": _timeit(batched, z0, keys, reps=reps),
            "looped": _timeit(looped, z0, keys, reps=reps)}


def bench_adaptive_vs_fixed(batch: int = 256, x_dim: int = 32,
                            fixed_steps: int = 200, reps: int = 3):
    """Adaptive terminal solve vs the fixed grid of matching accuracy.

    The same time-localised stiffness burst ``benchmarks/convergence.py``
    measures: there the adaptive controller reaches its strong error with
    ~117 evaluations while a uniform grid needs ~200 (the
    ``convergence_frontier`` gate) — so ``fixed_steps`` defaults to that
    matched-error grid.  These rows track the wall-clock *realisation* of
    the NFE saving, regression-gated like every other ``_ms`` row.  Note
    the CPU caveat (EXPERIMENTS.md §Frontier): with a trivial scalar field
    each adaptive attempt is dominated by the 24-level Lévy-bridge descent
    (one ``bm.value`` per attempt), so off-accelerator wall clock favours
    the fixed grid even though the adaptive solve does ~40% fewer
    vector-field evaluations — the lever pays when the field itself (a
    neural network on an accelerator) dwarfs the Brownian query.  The
    batch/x_dim defaults are sized so both rows are compute-bound
    (hundreds of ms): dispatch-noise-scale timings would make the 2× CI
    regression gate a coin flip.
    """
    from repro.core.brownian import BrownianPath
    from repro.core.solve import solve, solve_adaptive

    try:  # the SAME burst problem the convergence_frontier gate measures
        from .convergence import _burst_fields
    except ImportError:  # run as a loose script
        from convergence import _burst_fields

    drift, diffusion = _burst_fields()
    key = jax.random.PRNGKey(5)
    z0 = jnp.zeros((batch, x_dim), jnp.float32)
    bm = BrownianPath(key, 0.0, 1.0, (batch, x_dim), jnp.float32)

    adaptive = jax.jit(lambda z: solve(
        drift, diffusion, None, z, bm, 0.0, 1.0, 16,
        solver="reversible_heun", save_trajectory=False,
        adaptive=True, rtol=2e-3, atol=1e-5, max_steps=2048))
    fixed = jax.jit(lambda z: solve(
        drift, diffusion, None, z, bm, 0.0, 1.0, fixed_steps,
        solver="reversible_heun", save_trajectory=False))
    _, stats = solve_adaptive(drift, diffusion, None, z0, bm, 0.0, 1.0,
                              solver="reversible_heun", rtol=2e-3, atol=1e-5,
                              max_steps=2048, dt0=1.0 / 16)
    return {"adaptive": _timeit(adaptive, z0, reps=reps),
            "fixed_matched_error": _timeit(fixed, z0, reps=reps)}, \
        float(stats.nfe)


PRESET_SHAPES = {
    #          reps, solver num_steps/batch, fused num_steps/batch, looped batch/num_steps
    "tiny":  (2, 16, 32, 8, 16, 4, 8),
    "quick": (3, 64, 128, 16, 32, 8, 16),
    "full":  (10, 64, 128, 64, 128, 32, 64),
}


def main(preset: str = "full"):
    (reps, sv_steps, sv_batch, fu_steps, fu_batch,
     bl_batch, bl_steps) = PRESET_SHAPES[preset]
    rows = []
    base = None
    for solver, exact in (("midpoint", False), ("heun", False),
                          ("reversible_heun", False), ("reversible_heun", True)):
        label = solver + ("+exact_adjoint" if exact else "")
        dt, nfe = bench_solver(solver, exact, num_steps=sv_steps,
                               batch=sv_batch, reps=reps)
        if solver == "midpoint":
            base = dt
        speedup = base / dt if base else 1.0
        rows.append(("solver_speed", label, dt * 1e3))
        print(f"solver_speed,{label},{dt*1e3:.2f}ms,nfe={nfe},"
              f"speedup_vs_midpoint={speedup:.2f}x", flush=True)

    fu = bench_fused_vs_unfused(num_steps=fu_steps, batch=fu_batch, reps=reps)
    ratio = fu["unfused"] / fu["fused"]
    backend = jax.default_backend()
    for k, v in fu.items():
        rows.append(("solver_speed_fusion", k, v * 1e3))
        print(f"solver_speed_fusion,{k},{v*1e3:.2f}ms,backend={backend}",
              flush=True)
    print(f"solver_speed_fusion,fused_speedup,{ratio:.2f}x"
          f"{' (oracle dispatch - parity, not a kernel speed claim)' if backend != 'tpu' else ''}",
          flush=True)

    bl = bench_batched_vs_looped(batch=bl_batch, num_steps=bl_steps, reps=reps)
    for k, v in bl.items():
        rows.append(("solver_speed_batching", k, v * 1e3))
        print(f"solver_speed_batching,{k},{v*1e3:.2f}ms", flush=True)
    print(f"solver_speed_batching,batched_speedup,"
          f"{bl['looped'] / bl['batched']:.2f}x", flush=True)

    ad, nfe = bench_adaptive_vs_fixed(reps=reps)
    for k, v in ad.items():
        rows.append(("solver_speed_adaptive", f"{k}_ms", v * 1e3))
        print(f"solver_speed_adaptive,{k},{v*1e3:.2f}ms", flush=True)
    rows.append(("solver_speed_adaptive", "adaptive_nfe", nfe))
    print(f"solver_speed_adaptive,adaptive_nfe,{nfe:.0f} "
          f"(vs ~200 fixed at matched error; accuracy gate lives in "
          f"convergence_frontier)", flush=True)
    return rows


if __name__ == "__main__":
    report.standalone("solver_speed", main)
