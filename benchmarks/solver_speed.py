"""Paper Tables 1/4/5 (speed axis): reversible Heun vs midpoint/Heun.

Measures wall time + function evaluations (NFE) of a full
forward+backward through an SDE-GAN-scale Neural SDE per solver.  The
paper's headline: reversible Heun needs 1 NFE/step (vs 2) and computes the
backward with the O(1)-memory exact adjoint — observed as the up-to-1.98×
training-speed win in Table 1.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def bench_solver(solver: str, exact_adjoint: bool, num_steps: int = 64,
                 batch: int = 128, reps: int = 5):
    from repro.core.adjoint import reversible_heun_solve
    from repro.core.brownian import BrownianPath
    from repro.core.solvers import NFE_PER_STEP, sde_solve
    from repro import nn

    key = jax.random.PRNGKey(0)
    x_dim, w_dim, width = 32, 16, 64
    kp1, kp2, kz, kw = jax.random.split(key, 4)
    params = {
        "f": nn.mlp_init(kp1, [1 + x_dim, width, x_dim]),
        "g": nn.mlp_init(kp2, [1 + x_dim, width, x_dim * w_dim]),
    }

    def tcat(t, x):
        tt = jnp.broadcast_to(jnp.asarray(t, x.dtype), x.shape[:-1] + (1,))
        return jnp.concatenate([tt, x], -1)

    def drift(p, t, x):
        return nn.mlp(p["f"], tcat(t, x), nn.lipswish, jnp.tanh)

    def diffusion(p, t, x):
        out = nn.mlp(p["g"], tcat(t, x), nn.lipswish, jnp.tanh)
        return out.reshape(x.shape[:-1] + (x_dim, w_dim))

    z0 = jax.random.normal(kz, (batch, x_dim))
    bm = BrownianPath(kw, 0.0, 1.0, (batch, w_dim))

    if exact_adjoint:
        def loss(p):
            traj = reversible_heun_solve(drift, diffusion, p, z0, bm, 0.0, 1.0,
                                         num_steps, "general")
            return jnp.mean(traj[-1] ** 2)
    else:
        def loss(p):
            traj = sde_solve(drift, diffusion, p, z0, bm, 0.0, 1.0, num_steps,
                             solver=solver, noise="general")
            return jnp.mean(traj[-1] ** 2)

    g = jax.jit(jax.grad(loss))
    out = g(params)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = g(params)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / reps
    return dt, NFE_PER_STEP[solver] * num_steps


def main(quick: bool = False):
    reps = 3 if quick else 10
    rows = []
    base = None
    for solver, exact in (("midpoint", False), ("heun", False),
                          ("reversible_heun", False), ("reversible_heun", True)):
        label = solver + ("+exact_adjoint" if exact else "")
        dt, nfe = bench_solver(solver, exact, reps=reps)
        if solver == "midpoint":
            base = dt
        speedup = base / dt if base else 1.0
        rows.append(("solver_speed", label, dt * 1e3))
        print(f"solver_speed,{label},{dt*1e3:.2f}ms,nfe={nfe},"
              f"speedup_vs_midpoint={speedup:.2f}x", flush=True)
    return rows


if __name__ == "__main__":
    main()
