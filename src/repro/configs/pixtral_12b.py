"""pixtral-12b [vlm] — Pixtral ViT frontend (stub) + Mistral-Nemo-style decoder.

[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
The vision frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed patch embeddings for the image prefix.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    ffn="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    frontend="patch",
    frontend_len=1024,
)
