"""Neural SDE models (paper §2): generator, SDE-GAN, Latent SDE.

Follows the paper's "certain minimal amount of structure" (eq. (1)):

    X_0 = ζ_θ(V),   dX_t = μ_θ(t, X_t) dt + σ_θ(t, X_t) ∘ dW_t,   Y_t = ℓ_θ(X_t)

with ζ_θ, μ_θ, σ_θ MLPs and ℓ_θ affine.  The SDE-GAN discriminator is the
Neural CDE of eq. (2); generator+discriminator are solved as a *single* joint
SDE so the Wasserstein loss is a function of the terminal state and the
reversible-Heun exact adjoint applies end-to-end (paper §2.4: "the loss is an
integral ... computed as part of Z in a single SDE solve").
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .. import nn
from ..nn.core import tcat as _tcat  # the shared time-augmentation convention
from .brownian import BrownianPath
from .paths import LinearPathControl
from .solve import get_solver, solve


@dataclasses.dataclass(frozen=True)
class NeuralSDEConfig:
    data_dim: int = 1          # y
    hidden_dim: int = 16       # x
    noise_dim: int = 4         # w
    initial_noise_dim: int = 4  # v
    width: int = 32
    depth: int = 1
    disc_hidden_dim: int = 16  # h (discriminator CDE state)
    disc_width: int = 32
    disc_depth: int = 1
    num_steps: int = 32
    t1: float = 1.0
    solver: str = "reversible_heun"
    exact_adjoint: bool = True
    gradient_mode: Optional[str] = None  # explicit backend; None = derive
    precision: str = "highest"  # field-eval compute policy (solve stack)
    use_pallas_kernels: bool = False  # fused reversible-Heun hot loop
    dtype: object = jnp.float32


def _cfg_solve(cfg, drift, diffusion, params, z0, bm, num_steps, noise,
               gradient_mode=None, solver=None, save_trajectory=True):
    """All SDE-GAN / Latent-SDE solves go through the unified front-end.

    ``gradient_mode``/``solver`` default to the config's derivation
    (``cfg.gradient_mode`` when set, else exact reversible adjoint when
    configured, discretise otherwise); explicit values let the Latent-SDE
    backsolve baseline request ``"continuous_adjoint"`` without a second
    dispatch path.  Terminal-only backends (``"continuous_adjoint"``,
    ``"checkpoint"``) configured via ``cfg.gradient_mode`` pair with the
    terminal-form objectives (:func:`latent_sde_loss_terminal`);
    trajectory-consuming entry points surface the registry's eager named
    error rather than silently falling back.

    ``cfg.precision`` rides along unconditionally — the policy wraps the
    vector fields inside :func:`repro.core.solve.solve`, so it composes
    with every backend.

    ``use_pallas_kernels`` only applies where the fused kernels are legal:
    diagonal noise under the exact adjoint (see the registry validation in
    repro.core.solve) — e.g. the Latent SDE's posterior solve.  General
    (matrix) noise falls back to the unfused path with a warning.
    """
    solver = cfg.solver if solver is None else solver
    # (W, H)-consuming solvers (srk): rebuild the path in space-time mode so
    # cfg.solver="srk" works on every diagonal-noise config path without each
    # call site knowing about Lévy areas.  General-noise solves fall through
    # to the registry's eager noise_types error.
    if (get_solver(solver).needs_levy_area and isinstance(bm, BrownianPath)
            and bm.levy_area is None):
        bm = dataclasses.replace(bm, levy_area="space-time")
    if gradient_mode is None:
        gradient_mode = getattr(cfg, "gradient_mode", None)
    if gradient_mode is None:
        exact = cfg.exact_adjoint and solver == "reversible_heun"
        gradient_mode = "reversible_adjoint" if exact else "discretise"
    wants_fuse = getattr(cfg, "use_pallas_kernels", False)
    fuse = (wants_fuse and noise == "diagonal"
            and gradient_mode == "reversible_adjoint")
    if wants_fuse and not fuse:
        import warnings

        warnings.warn(
            f"use_pallas_kernels requested but this solve cannot fuse "
            f"(noise={noise!r}, gradient_mode={gradient_mode!r}) — running "
            f"unfused",
            stacklevel=3)
    return solve(drift, diffusion, params, z0, bm, 0.0, cfg.t1, num_steps,
                 solver=solver, gradient_mode=gradient_mode, noise=noise,
                 save_trajectory=save_trajectory, use_pallas_kernels=fuse,
                 precision=getattr(cfg, "precision", "highest"))


# =============================================================================
# Generator
# =============================================================================


def generator_init(key, cfg: NeuralSDEConfig):
    kz, km, ks, kl = jax.random.split(key, 4)
    hid = [cfg.width] * cfg.depth
    d = cfg.dtype
    return {
        "zeta": nn.mlp_init(kz, [cfg.initial_noise_dim] + hid + [cfg.hidden_dim], dtype=d),
        "mu": nn.mlp_init(km, [1 + cfg.hidden_dim] + hid + [cfg.hidden_dim], dtype=d),
        "sigma": nn.mlp_init(ks, [1 + cfg.hidden_dim] + hid + [cfg.hidden_dim * cfg.noise_dim], dtype=d),
        "ell": nn.linear_init(kl, cfg.hidden_dim, cfg.data_dim, dtype=d),
    }


def gen_drift(cfg):
    def mu(params, t, x):
        return nn.mlp(params["mu"], _tcat(t, x), nn.lipswish, jnp.tanh)
    return mu


def gen_diffusion(cfg):
    def sigma(params, t, x):
        out = nn.mlp(params["sigma"], _tcat(t, x), nn.lipswish, jnp.tanh)
        return out.reshape(x.shape[:-1] + (cfg.hidden_dim, cfg.noise_dim))
    return sigma


def generator_sample(params, cfg: NeuralSDEConfig, key, batch: int):
    """Sample ``Y`` paths: returns (num_steps+1, batch, data_dim)."""
    kv, kw = jax.random.split(key)
    v = jax.random.normal(kv, (batch, cfg.initial_noise_dim), cfg.dtype)
    x0 = nn.mlp(params["zeta"], v, nn.lipswish)
    bm = BrownianPath(kw, 0.0, cfg.t1, (batch, cfg.noise_dim), cfg.dtype)
    traj = _cfg_solve(cfg, gen_drift(cfg), gen_diffusion(cfg), params, x0, bm,
                      cfg.num_steps, "general")
    return nn.linear(params["ell"], traj)


# =============================================================================
# Discriminator (Neural CDE, eq. (2))
# =============================================================================


def _disc_spec(cfg: NeuralSDEConfig) -> nn.CDEDiscriminatorSpec:
    return nn.CDEDiscriminatorSpec(
        data_dim=cfg.data_dim, hidden_dim=cfg.disc_hidden_dim,
        width=cfg.disc_width, depth=cfg.disc_depth, dtype=cfg.dtype)


def discriminator_init(key, cfg: NeuralSDEConfig):
    """Init the Lipschitz-constrained CDE stack (repro.nn.cde): xi/f/g start
    inside the careful-clipping box, the readout m is unconstrained."""
    return nn.cde_discriminator_init(key, _disc_spec(cfg))


def disc_f(cfg):
    return nn.cde_drift(_disc_spec(cfg))


def disc_g(cfg):
    """g_φ maps h -> (h, 1+y): the CDE is driven by the time-augmented path
    (t, Y_t) so the vector field sees dt through the control as well."""
    return nn.cde_control_field(_disc_spec(cfg))


def discriminate_path(params, cfg: NeuralSDEConfig, ys, exact_adjoint: Optional[bool] = None):
    """Score an observed path ``ys`` (T+1, batch, y): F_φ(Y) = m·H_T.

    Drives the CDE with the piecewise-linear time-augmented control (t, Y).
    """
    T = ys.shape[0] - 1
    ts = jnp.linspace(0.0, cfg.t1, T + 1, dtype=ys.dtype)
    tt = jnp.broadcast_to(ts[:, None, None], ys.shape[:-1] + (1,))
    control = LinearPathControl(jnp.concatenate([tt, ys], -1))
    h0 = nn.cde_initial(params, ts[0], ys[0])
    exact = cfg.exact_adjoint if exact_adjoint is None else exact_adjoint
    mode = "reversible_adjoint" if exact else "discretise"
    solver = "reversible_heun" if exact else cfg.solver
    traj = solve(disc_f(cfg), disc_g(cfg), params, h0, control, 0.0, cfg.t1, T,
                 solver=solver, gradient_mode=mode, noise="general")
    return nn.cde_readout(params, traj[-1])


# =============================================================================
# Joint generator+discriminator SDE (fake-sample scoring, end-to-end)
# =============================================================================


def joint_drift(cfg):
    mu_f, f_f, g_f = gen_drift(cfg), disc_f(cfg), disc_g(cfg)

    def drift(params, t, u):
        x, h = jnp.split(u, [cfg.hidden_dim], axis=-1)
        mu = mu_f(params["gen"], t, x)
        f = f_f(params["disc"], t, h)
        g = g_f(params["disc"], t, h)           # (..., h, 1+y)
        wl = params["gen"]["ell"]["w"]          # (x, y)
        dy_dt = jnp.concatenate(
            [jnp.ones(mu.shape[:-1] + (1,), mu.dtype), mu @ wl], -1)  # (…, 1+y)
        dh = f + jnp.einsum("...hy,...y->...h", g, dy_dt)
        return jnp.concatenate([mu, dh], -1)

    return drift


def joint_diffusion(cfg):
    sig_f, g_f = gen_diffusion(cfg), disc_g(cfg)

    def diffusion(params, t, u):
        x, h = jnp.split(u, [cfg.hidden_dim], axis=-1)
        sig = sig_f(params["gen"], t, x)        # (..., x, w)
        g = g_f(params["disc"], t, h)           # (..., h, 1+y)
        wl = params["gen"]["ell"]["w"]          # (x, y)
        #   dY = ℓ'(X) dX  ⇒  noise into h is g[:, 1:]·(Wᵀσ)
        gh = jnp.einsum("...hy,xy,...xw->...hw", g[..., 1:], wl, sig)
        return jnp.concatenate([sig, gh], -2)   # (..., x+h, w)

    return diffusion


def gan_score_fake(params, cfg: NeuralSDEConfig, key, batch: int):
    """F_φ(Y) for generated Y, via a single joint SDE solve (exact adjoint)."""
    kv, kw = jax.random.split(key)
    v = jax.random.normal(kv, (batch, cfg.initial_noise_dim), cfg.dtype)
    x0 = nn.mlp(params["gen"]["zeta"], v, nn.lipswish)
    y0 = nn.linear(params["gen"]["ell"], x0)
    h0 = nn.cde_initial(params["disc"], 0.0, y0)
    u0 = jnp.concatenate([x0, h0], -1)
    bm = BrownianPath(kw, 0.0, cfg.t1, (batch, cfg.noise_dim), cfg.dtype)
    traj = _cfg_solve(cfg, joint_drift(cfg), joint_diffusion(cfg), params, u0, bm,
                      cfg.num_steps, "general")
    hT = traj[-1][..., cfg.hidden_dim:]
    score = nn.cde_readout(params["disc"], hT)
    ys = nn.linear(params["gen"]["ell"], traj[..., : cfg.hidden_dim])
    return score, ys


def gan_losses(params, cfg: NeuralSDEConfig, key, y_real, batch: int):
    """Wasserstein losses (eq. (3)): returns (gen_loss, disc_loss, fake_ys)."""
    fake_score, fake_ys = gan_score_fake(params, cfg, key, batch)
    real_score = discriminate_path(params["disc"], cfg, y_real)
    gen_loss = -jnp.mean(fake_score)
    disc_loss = jnp.mean(fake_score) - jnp.mean(real_score)
    return gen_loss, disc_loss, fake_ys


def gradient_penalty(params_disc, cfg: NeuralSDEConfig, key, y_real, y_fake):
    """WGAN-GP baseline (Gulrajani et al. [36]) — the double-backward the
    paper's clipping removes.  Differentiates the CDE solve w.r.t. the input
    path (discretise-then-optimise; continuous double-adjoint is exactly the
    error source §5 describes)."""
    eps = jax.random.uniform(key, (1, y_real.shape[1], 1), y_real.dtype)
    y_mix = eps * y_real + (1 - eps) * y_fake

    def score_of_path(y):
        return jnp.sum(discriminate_path(params_disc, cfg, y, exact_adjoint=False))

    g = jax.grad(score_of_path)(y_mix)
    gnorm = jnp.sqrt(jnp.sum(g * g, axis=(0, 2)) + 1e-12)
    return jnp.mean((gnorm - 1.0) ** 2)


# =============================================================================
# Latent SDE (Li et al. [15]; paper Appendix B)
# =============================================================================


@dataclasses.dataclass(frozen=True)
class LatentSDEConfig:
    data_dim: int = 1
    hidden_dim: int = 16
    context_dim: int = 16
    initial_noise_dim: int = 8
    width: int = 32
    depth: int = 1
    num_steps: int = 32
    t1: float = 1.0
    solver: str = "reversible_heun"
    exact_adjoint: bool = True
    gradient_mode: Optional[str] = None  # explicit backend; None = derive
    precision: str = "highest"  # field-eval compute policy (solve stack)
    kl_weight: float = 1.0
    use_pallas_kernels: bool = False  # fused diagonal-noise hot loop
    dtype: object = jnp.float32


def validate_latent_grid(num_steps: int, T: int) -> int:
    """Check the solver grid aligns with the observation grid; return stride.

    The reconstruction term reads the solver trajectory at the ``T + 1``
    observation times, so ``num_steps`` must be a positive multiple of ``T``
    (the number of observation intervals) for every observation to land
    exactly on a solver step.  Validated eagerly — shapes are static — so
    callers get a named error instead of an opaque broadcast ``TypeError``
    (``num_steps=30, T=8``) or ``slice step cannot be zero``
    (``num_steps < T``) from deep inside the solve.
    """
    if T < 1:
        raise ValueError(
            f"latent-SDE data must contain at least two observations; got "
            f"T = {T} observation intervals")
    if num_steps < T or num_steps % T != 0:
        reason = (f"num_steps < T" if num_steps < T
                  else f"num_steps % T == {num_steps % T} != 0")
        raise ValueError(
            f"latent-SDE solver grid is misaligned with the observation "
            f"grid: cfg.num_steps ({num_steps}) must be a positive multiple "
            f"of the data grid T ({T}, the number of observation intervals "
            f"= len(y) - 1) so every observation lands on a solver step "
            f"(valid: {T}, {2 * T}, {3 * T}, ...); got {reason}")
    return num_steps // T


def latent_sde_init(key, cfg: LatentSDEConfig):
    kz, km, ks, kl, ke, kn, kq = jax.random.split(key, 7)
    hid = [cfg.width] * cfg.depth
    d = cfg.dtype
    return {
        "zeta": nn.mlp_init(kz, [cfg.initial_noise_dim] + hid + [cfg.hidden_dim], dtype=d),
        "mu": nn.mlp_init(km, [1 + cfg.hidden_dim] + hid + [cfg.hidden_dim], dtype=d),        # prior drift
        "sigma": nn.mlp_init(ks, [1 + cfg.hidden_dim] + hid + [cfg.hidden_dim], dtype=d),     # diagonal
        "ell": nn.linear_init(kl, cfg.hidden_dim, cfg.data_dim, dtype=d),
        "enc": nn.gru_init(ke, cfg.data_dim, cfg.context_dim, dtype=d),                        # ν_φ² (bwd GRU)
        "nu": nn.mlp_init(kn, [1 + cfg.hidden_dim + cfg.context_dim] + hid + [cfg.hidden_dim], dtype=d),
        "qz0": nn.mlp_init(kq, [cfg.context_dim] + hid + [2 * cfg.initial_noise_dim], dtype=d),  # ξ_φ
    }


def _lsde_sigma(params, t, x):
    raw = nn.mlp(params["sigma"], _tcat(t, x), nn.lipswish)
    return jax.nn.sigmoid(raw) * 0.5 + 0.05  # bounded positive diagonal


def _latent_encode(params, cfg: LatentSDEConfig, key, y_true):
    """Backward-GRU context + initial-latent sample.

    Returns ``(ctx, x0, kl_v)``: the (T+1, B, c) context path ν_φ², the
    initial hidden state ζ_θ(V̂) with V̂ ~ N(m, s) from ξ_φ(ctx_0), and the
    per-sample KL(N(m, s) ‖ N(0, 1)) of the initial latent.
    """
    ctx = nn.gru_scan(params["enc"], y_true, reverse=True)  # (T+1, B, c)
    ms = nn.mlp(params["qz0"], ctx[0], nn.lipswish)
    m, log_s = jnp.split(ms, 2, -1)
    s = jnp.exp(jnp.clip(log_s, -8, 4))
    v = m + s * jax.random.normal(key, m.shape, cfg.dtype)
    kl_v = 0.5 * jnp.sum(m**2 + s**2 - 2.0 * jnp.log(s) - 1.0, -1)
    x0 = nn.mlp(params["zeta"], v, nn.lipswish)
    return ctx, x0, kl_v


def _step_index_lookup(t1: float, T: int):
    """``(path, t) -> path[round(t / t1 * T)]`` — index a (T+1, ...) tensor
    (encoder context, observations) by solver time.  Shared by the training
    posterior fields and the serving posterior decode."""

    def at(p, t):
        idx = jnp.clip(jnp.asarray(t / t1 * T).astype(jnp.int32), 0, T)
        return jax.lax.dynamic_index_in_dim(p, idx, 0, keepdims=False)

    return at


def _latent_posterior_fields(cfg: LatentSDEConfig, T: int, n_aux: int,
                             with_recon: bool = False):
    """Posterior drift/diffusion over the augmented state ``[x, kl(, recon)]``.

    The KL path integrand ½‖(μ−ν)/σ‖² always rides as a state channel
    (paper eq. (4) / Appendix B).  ``with_recon`` adds a second channel
    integrating the squared reconstruction error against the (step-indexed)
    observations — the form the terminal-only ELBO needs.  Aux channels
    carry zero diffusion rows.
    """

    _ctx_at = _step_index_lookup(cfg.t1, T)

    def post_drift(p, t, u):
        x = u[..., : cfg.hidden_dim]
        nets = p["nets"]
        c = _ctx_at(p["ctx"], t)
        nu = nn.mlp(nets["nu"], jnp.concatenate([_tcat(t, x), c], -1),
                    nn.lipswish, jnp.tanh)
        mu = nn.mlp(nets["mu"], _tcat(t, x), nn.lipswish, jnp.tanh)
        sig = _lsde_sigma(nets, t, x)
        u_ratio = (mu - nu) / sig
        dkl = 0.5 * jnp.sum(u_ratio * u_ratio, -1, keepdims=True)
        chans = [nu, dkl]
        if with_recon:
            y_hat = nn.linear(nets["ell"], x)
            y_t = _ctx_at(p["y"], t)
            chans.append(jnp.mean((y_hat - y_t) ** 2, -1, keepdims=True))
        return jnp.concatenate(chans, -1)

    def post_diffusion(p, t, u):
        x = u[..., : cfg.hidden_dim]
        sig = _lsde_sigma(p["nets"], t, x)
        return jnp.concatenate(
            [sig, jnp.zeros(sig.shape[:-1] + (n_aux,), sig.dtype)], -1)

    return post_drift, post_diffusion


def latent_sde_loss(params, cfg: LatentSDEConfig, key, y_true):
    """Negative ELBO (paper eq. (4) / Appendix B).  ``y_true``: (T+1, B, y).

    The KL path integral rides along as an extra state channel so the whole
    objective is a function of one SDE solve's trajectory; the
    reconstruction term reads that trajectory at the observation times,
    which is why ``cfg.num_steps`` must be a positive multiple of the data
    grid ``T`` (checked eagerly by :func:`validate_latent_grid`).
    """
    T = y_true.shape[0] - 1
    B = y_true.shape[1]
    stride = validate_latent_grid(cfg.num_steps, T)
    dt_data = cfg.t1 / T
    kz0, kw = jax.random.split(key)

    ctx, x0, kl_v = _latent_encode(params, cfg, kz0, y_true)
    aug_params = {"nets": params, "ctx": ctx}
    post_drift, post_diffusion = _latent_posterior_fields(cfg, T, n_aux=1)

    u0 = jnp.concatenate([x0, jnp.zeros((B, 1), cfg.dtype)], -1)
    bm = BrownianPath(kw, 0.0, cfg.t1, (B, cfg.hidden_dim + 1), cfg.dtype)
    traj = _cfg_solve(cfg, post_drift, post_diffusion, aug_params, u0, bm,
                      cfg.num_steps, "diagonal")

    xs = traj[..., : cfg.hidden_dim]                       # (N+1, B, x)
    kl_path = traj[-1][..., -1]                            # (B,)
    y_hat = nn.linear(params["ell"], xs)                   # (N+1, B, y)
    y_hat_obs = y_hat[::stride]                            # (T+1, B, y)
    recon = jnp.sum(jnp.mean((y_hat_obs - y_true) ** 2, axis=(1, 2))) * dt_data
    recon0 = jnp.mean(jnp.sum((y_hat_obs[0] - y_true[0]) ** 2, -1))
    loss = recon + recon0 + cfg.kl_weight * jnp.mean(kl_path + kl_v)
    return loss, {"recon": recon, "kl_path": jnp.mean(kl_path), "kl_v": jnp.mean(kl_v)}


def latent_sde_loss_terminal(params, cfg: LatentSDEConfig, key, y_true,
                             gradient_mode=None, solver=None):
    """Negative ELBO as a function of the *terminal* augmented state only.

    Both the KL path integral and the reconstruction error ride as state
    channels, so the whole objective is ``f(u_T)`` — the form the
    continuous-adjoint ("backsolve") baseline requires: eq. (6)
    backpropagates a terminal-value cotangent only, so it cannot consume a
    trajectory the way :func:`latent_sde_loss` does.  (The exact reversible
    adjoint has no such restriction — that asymmetry is the point of the
    paper; see DESIGN.md §8.)  The recon channel integrates the squared
    error against the step-indexed observations, so the grid-alignment rule
    is the same as the trajectory form's.

    ``gradient_mode``/``solver`` override the config's derivation — e.g.
    ``("continuous_adjoint", "midpoint")`` for the backsolve baseline,
    ``None`` for the config default (exact adjoint when configured).
    """
    T = y_true.shape[0] - 1
    B = y_true.shape[1]
    validate_latent_grid(cfg.num_steps, T)
    kz0, kw = jax.random.split(key)

    ctx, x0, kl_v = _latent_encode(params, cfg, kz0, y_true)
    aug_params = {"nets": params, "ctx": ctx, "y": y_true}
    post_drift, post_diffusion = _latent_posterior_fields(
        cfg, T, n_aux=2, with_recon=True)

    u0 = jnp.concatenate([x0, jnp.zeros((B, 2), cfg.dtype)], -1)
    bm = BrownianPath(kw, 0.0, cfg.t1, (B, cfg.hidden_dim + 2), cfg.dtype)
    uT = _cfg_solve(cfg, post_drift, post_diffusion, aug_params, u0, bm,
                    cfg.num_steps, "diagonal", gradient_mode=gradient_mode,
                    solver=solver, save_trajectory=False)

    kl_path = uT[..., cfg.hidden_dim]                      # (B,)
    recon = jnp.mean(uT[..., cfg.hidden_dim + 1])          # ∫‖ŷ−y‖² dt, mean B
    y_hat0 = nn.linear(params["ell"], x0)
    recon0 = jnp.mean(jnp.sum((y_hat0 - y_true[0]) ** 2, -1))
    loss = recon + recon0 + cfg.kl_weight * jnp.mean(kl_path + kl_v)
    return loss, {"recon": recon, "kl_path": jnp.mean(kl_path), "kl_v": jnp.mean(kl_v)}


def latent_prior_drift(p, t, x):
    """Prior drift μ_θ — shared by training-time prior sampling and serving."""
    return nn.mlp(p["mu"], _tcat(t, x), nn.lipswish, jnp.tanh)


def latent_prior_diffusion(p, t, x):
    """Diagonal prior diffusion (bounded positive, shared with the posterior)."""
    return _lsde_sigma(p, t, x)


def latent_sde_sample(params, cfg: LatentSDEConfig, key, batch: int):
    """Sample from the prior: returns (num_steps+1, batch, y)."""
    kv, kw = jax.random.split(key)
    v = jax.random.normal(kv, (batch, cfg.initial_noise_dim), cfg.dtype)
    x0 = nn.mlp(params["zeta"], v, nn.lipswish)

    bm = BrownianPath(kw, 0.0, cfg.t1, (batch, cfg.hidden_dim), cfg.dtype)
    traj = solve(latent_prior_drift, latent_prior_diffusion, params, x0, bm,
                 0.0, cfg.t1, cfg.num_steps,
                 solver=cfg.solver, gradient_mode="discretise", noise="diagonal")
    return nn.linear(params["ell"], traj)


# =============================================================================
# Inference-only sampling entry points (serving; DESIGN.md §9)
# =============================================================================
#
# No loss plumbing: these produce trajectories, nothing else.  The serving
# contract is that **every trajectory row is a pure function of its own PRNG
# key** (plus params), so the bucket-padding in launch/serve.py — padding an
# off-size request batch up to the nearest compiled bucket — can never
# perturb the rows a client actually asked for.  All solves dispatch through
# :func:`_cfg_solve`, i.e. the unified ``repro.solve`` front-end: any
# registered solver × noise type is servable.


def generator_sample_paths(params, cfg: NeuralSDEConfig, keys):
    """SDE-GAN generator rollout for serving, one trajectory per key.

    ``keys``: (B,) PRNG keys.  Returns (num_steps+1, B, data_dim),
    time-major like every path tensor in the repo.
    """

    def one(k):
        kv, kw = jax.random.split(k)
        v = jax.random.normal(kv, (cfg.initial_noise_dim,), cfg.dtype)
        x0 = nn.mlp(params["zeta"], v, nn.lipswish)
        bm = BrownianPath(kw, 0.0, cfg.t1, (cfg.noise_dim,), cfg.dtype)
        traj = _cfg_solve(cfg, gen_drift(cfg), gen_diffusion(cfg), params,
                          x0, bm, cfg.num_steps, "general")
        return nn.linear(params["ell"], traj)

    return jax.vmap(one, out_axes=1)(keys)


def generator_sample_terminal(params, cfg: NeuralSDEConfig, keys, rtol, atol,
                              max_steps: Optional[int] = None):
    """Adaptive terminal-distribution sampling for serving: one terminal
    sample ``Y_T`` per key, solved to a *requested accuracy* instead of a
    fixed grid (DESIGN.md §10).

    ``rtol``/``atol`` may be **traced scalars** — one compiled sampler
    serves every tolerance, which is how launch/serve.py offers per-request
    tolerance without a recompile per tolerance.  The same bucket-padding
    invariant as the other serving entry points holds: each row is a pure
    function of ``(params, keys[i], rtol, atol)``.

    Returns ``(samples, converged)``: ``(B, data_dim)`` terminal samples
    plus a ``(B,)`` bool marking rows whose controller reached ``t1``
    within the step budget — a row with ``converged[i] == False`` is the
    state at ``t_final < t1``, and the serving loop must surface it rather
    than hand it to a client as ``Y_T`` (solver × adaptive validation
    itself happens inside :func:`repro.core.solve.solve_adaptive`).
    """
    if max_steps is None:
        max_steps = max(4 * cfg.num_steps, 256)

    def one(k):
        kv, kw = jax.random.split(k)
        v = jax.random.normal(kv, (cfg.initial_noise_dim,), cfg.dtype)
        x0 = nn.mlp(params["zeta"], v, nn.lipswish)
        bm = BrownianPath(kw, 0.0, cfg.t1, (cfg.noise_dim,), cfg.dtype)
        from .solve import solve_adaptive

        xT, stats = solve_adaptive(
            gen_drift(cfg), gen_diffusion(cfg), params, x0, bm, 0.0, cfg.t1,
            solver=cfg.solver, rtol=rtol, atol=atol, max_steps=max_steps,
            dt0=cfg.t1 / cfg.num_steps, noise="general")
        return nn.linear(params["ell"], xT), stats.converged

    return jax.vmap(one)(keys)


def generator_initial_state(params, cfg: NeuralSDEConfig, keys):
    """x₀ = ζ_θ(V) per key — the entry state for the streamed (time-chunked)
    rollout in launch/serve.py.  Returns (B, hidden_dim)."""

    def one(k):
        kv, _ = jax.random.split(k)
        v = jax.random.normal(kv, (cfg.initial_noise_dim,), cfg.dtype)
        return nn.mlp(params["zeta"], v, nn.lipswish)

    return jax.vmap(one)(keys)


def generator_rollout_chunk(params, cfg: NeuralSDEConfig, keys, x0, t_start,
                            span: float, num_steps: int):
    """Continue generator trajectories over one time chunk
    ``[t_start, t_start + span]`` of a streamed horizon.

    ``t_start`` may be a *traced* scalar — or, since PR 7, a traced
    ``(B,)`` **per-row vector**: the drift/diffusion consume it
    arithmetically only, so one compiled program serves every chunk of the
    stream AND every mix of horizon positions inside one batch — the
    property the continuous-batching scheduler (``repro.serving``) builds
    on, where rows admitted at different chunk boundaries share a compiled
    batch.  ``keys`` must be pre-folded per chunk by the caller — the
    Brownian sample is keyed per (row, chunk), keeping the stream
    deterministic, rows independent, and a mid-flight join bitwise
    identical to the same request run solo.  Runs
    ``gradient_mode="discretise"`` (plain scan): serving takes no
    gradients, and the traced ``t_start`` rules out the fused path's
    static-``dt`` contract.

    Returns ``(ys, xT)``: ys (num_steps+1, B, data_dim) with row 0 the
    chunk-entry state (== previous chunk's final row, for continuity
    checks), and xT (B, hidden_dim) to carry into the next chunk.
    """
    t_start = jnp.asarray(t_start, cfg.dtype)
    t_axis = 0 if t_start.ndim == 1 else None
    if t_start.ndim > 1:
        raise ValueError(
            f"t_start must be a scalar or a (B,) per-row vector, got shape "
            f"{t_start.shape}")

    def one(k, x0_i, t0_i):
        bm = BrownianPath(k, 0.0, span, (cfg.noise_dim,), cfg.dtype)
        traj = solve(gen_drift(cfg), gen_diffusion(cfg), params, x0_i, bm,
                     t0_i, t0_i + span, num_steps,
                     solver=cfg.solver, gradient_mode="discretise",
                     noise="general")
        return nn.linear(params["ell"], traj), traj[-1]

    return jax.vmap(one, in_axes=(0, 0, t_axis),
                    out_axes=(1, 0))(keys, x0, t_start)


def latent_sde_sample_paths(params, cfg: LatentSDEConfig, keys):
    """Latent-SDE prior decode for serving, one trajectory per key.

    Diagonal noise, so with ``cfg.use_pallas_kernels`` the solve runs the
    fused reversible-Heun forward scan.  Returns (num_steps+1, B, data_dim).
    """

    def one(k):
        kv, kw = jax.random.split(k)
        v = jax.random.normal(kv, (cfg.initial_noise_dim,), cfg.dtype)
        x0 = nn.mlp(params["zeta"], v, nn.lipswish)
        bm = BrownianPath(kw, 0.0, cfg.t1, (cfg.hidden_dim,), cfg.dtype)
        traj = _cfg_solve(cfg, latent_prior_drift, latent_prior_diffusion,
                          params, x0, bm, cfg.num_steps, "diagonal")
        return nn.linear(params["ell"], traj)

    return jax.vmap(one, out_axes=1)(keys)


def latent_sde_posterior_decode(params, cfg: LatentSDEConfig, keys, y_obs):
    """Latent-SDE posterior decode for serving: encode observed paths, solve
    the posterior SDE (no KL/recon channels), return ŷ on the solver grid.

    ``keys``: (B,); ``y_obs``: (T+1, B, data_dim) observations.  Row ``i``
    depends only on ``(params, keys[i], y_obs[:, i])`` — the same
    bucket-padding invariant as the other serving entry points.  Returns
    (num_steps+1, B, data_dim).
    """
    T = y_obs.shape[0] - 1
    validate_latent_grid(cfg.num_steps, T)
    ctx_at = _step_index_lookup(cfg.t1, T)

    def drift(p, t, x):
        c = ctx_at(p["ctx"], t)
        return nn.mlp(p["nets"]["nu"],
                      jnp.concatenate([_tcat(t, x), c], -1),
                      nn.lipswish, jnp.tanh)

    def diffusion(p, t, x):
        return _lsde_sigma(p["nets"], t, x)

    def one(k, y):  # y: (T+1, data_dim)
        ctx, x0, _ = _latent_encode(params, cfg, jax.random.fold_in(k, 0), y)
        bm = BrownianPath(jax.random.fold_in(k, 1), 0.0, cfg.t1,
                          (cfg.hidden_dim,), cfg.dtype)
        traj = _cfg_solve(cfg, drift, diffusion, {"nets": params, "ctx": ctx},
                          x0, bm, cfg.num_steps, "diagonal")
        return nn.linear(params["ell"], traj)

    return jax.vmap(one, in_axes=(0, 1), out_axes=1)(keys, y_obs)
