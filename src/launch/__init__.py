"""Top-level launcher alias: ``PYTHONPATH=src python -m launch.train``.

Thin re-export of :mod:`repro.launch` so launch commands don't need the
package prefix.  All real code lives under ``repro/``.
"""
