"""Kernel-parity differential suite: every Pallas kernel in the fused
reversible-Heun adjoint pipeline, interpret mode vs the jnp oracle
(:mod:`repro.kernels.ref`), asserted BITWISE.

This is the gate the fused exact adjoint rests on (DESIGN.md §3): the
backward kernels are registered as *the* derivative of the forward step
through ``custom_vjp``, so "fused gradient == unfused gradient" reduces to
per-kernel bit-equality, which is what these tests pin.

Methodology (the three rules that make bitwise comparison meaningful —
each was found the hard way, see the module docstring of
:mod:`repro.kernels.reversible_heun_step`):

1. **jit both sides.** An un-jitted pallas interpret call executes with
   different FMA-contraction choices than a jit'd jnp graph; the public
   kernel wrappers are jit'd, so the oracle side must be too.
2. **Trace every scalar.** A constant-folded ``dt`` contracts differently
   than a traced one — ``dt`` (and ``t``) are passed as jit *arguments* on
   both sides, never closed over as Python floats.
3. **Whole-array blocks under interpret.** Multi-cell interpreter grids
   compile each block as a separate subcomputation with different
   contraction at block boundaries; ``_call_elementwise`` runs interpret
   mode as one block, and these tests would catch a regression of that.

Fuzzing is seeded-sweep based: ``hypothesis`` is an optional extra this
environment does not ship, so the same case matrix is generated from a
fixed PRNG seed — deterministic, and wide enough (shapes × dtypes × signs
× dt scales) to have caught every contraction bug found while deriving
the kernels.  If ``hypothesis`` is available the sweep still runs as-is
(no skip): the seeded matrix IS the contract.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.brownian import BrownianPath
from repro.kernels import brownian as bk
from repro.kernels import ops, prng, ref
from repro.kernels import reversible_heun_step as rh


@pytest.fixture(autouse=True)
def _x64_scope():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


# Fuzzed case matrix: shapes exercise 1-D states, non-divisible dims, >2-D
# batching, and a VPU-aligned tile; dt scales exercise sub-ulp and O(1)
# magnitudes against state values of O(1).
SHAPES = [(4, 4), (8, 128), (4, 3), (5, 7), (1, 17), (2, 3, 8), (16,)]
DTYPES = [jnp.float32, jnp.float64]
SIGNS = [1.0, -1.0]
DTS = [0.01, 0.3]


def _fuzz(seed, shape, dtype, n_arrays):
    """Deterministic operand draw — the seeded stand-in for hypothesis."""
    ks = jax.random.split(jax.random.PRNGKey(seed), n_arrays)
    return [0.5 * jax.random.normal(k, shape, dtype) for k in ks]


def _assert_bitwise(a, b, label):
    a = a if isinstance(a, tuple) else (a,)
    b = b if isinstance(b, tuple) else (b,)
    for i, (x, y) in enumerate(zip(a, b)):
        ulps = 0 if bool(jnp.all(x == y)) else "nonzero"
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{label} output {i} not bitwise (ulp drift: {ulps})")


def _both(kernel_fn, ref_fn, arrays, dt, dtype):
    """jit-to-jit comparison with dt traced on BOTH sides (rules 1+2)."""
    dt = jnp.asarray(dt, dtype)
    got = jax.jit(lambda d: kernel_fn(*arrays, d))(dt)
    want = jax.jit(lambda d: ref_fn(*arrays, d))(dt)
    return got, want


# -----------------------------------------------------------------------------
# forward phases
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_phase1_bitwise(shape, dtype):
    z, zh, mu, sig, dw = _fuzz(11, shape, dtype, 5)
    for sign in SIGNS:
        for dt in DTS:
            got, want = _both(
                lambda *a: rh.rev_heun_phase1(*a, sign=sign, interpret=True),
                lambda *a: ref.rev_heun_phase1(*a, sign),
                (z, zh, mu, sig, dw), dt, dtype)
            _assert_bitwise(got, want, f"phase1 {shape} {dtype} {sign} {dt}")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_phase2_bitwise(shape, dtype):
    z, mu, mu1, sig, sig1, dw = _fuzz(13, shape, dtype, 6)
    for sign in SIGNS:
        for dt in DTS:
            got, want = _both(
                lambda *a: rh.rev_heun_phase2(*a, sign=sign, interpret=True),
                lambda *a: ref.rev_heun_phase2(*a, sign),
                (z, mu, mu1, sig, sig1, dw), dt, dtype)
            _assert_bitwise(got, want, f"phase2 {shape} {dtype} {sign} {dt}")


# -----------------------------------------------------------------------------
# backward (cotangent) phases — the hand-derived adjoint transpose
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_bwd_phase1_bitwise(shape, dtype):
    g_z1, g_mu1, g_sig1, dw = _fuzz(17, shape, dtype, 4)
    for dt in DTS:
        got, want = _both(
            lambda *a: rh.rev_heun_bwd_phase1(*a, interpret=True),
            ref.rev_heun_bwd_phase1,
            (g_z1, g_mu1, g_sig1, dw), dt, dtype)
        _assert_bitwise(got, want, f"bwd_phase1 {shape} {dtype} {dt}")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_bwd_phase2_bitwise(shape, dtype):
    g_z1, ghat, dw = _fuzz(19, shape, dtype, 3)
    for dt in DTS:
        got, want = _both(
            lambda *a: rh.rev_heun_bwd_phase2(*a, interpret=True),
            ref.rev_heun_bwd_phase2,
            (g_z1, ghat, dw), dt, dtype)
        _assert_bitwise(got, want, f"bwd_phase2 {shape} {dtype} {dt}")


def test_bwd_phases_are_the_vjp_transpose(key):
    """The backward kernels ARE jax.vjp of the reference step — bitwise.

    This is the identity the fused adjoint substitutes kernels into plain
    AD on: seed the unfused phase-1/phase-2 composition with cotangents and
    check the kernel pipeline reproduces ``jax.vjp``'s outputs exactly.
    """
    dtype = jnp.float64
    shape = (4, 8)
    z, zh, mu, sig, dw, g_z1 = _fuzz(23, shape, dtype, 6)
    dt = jnp.asarray(0.07, dtype)

    def phase2(z_, mu_, mu1, sig_, sig1, dw_, dt_):
        return ref.rev_heun_phase2(z_, mu_, mu1, sig_, sig1, dw_, dt_, 1.0)

    # unfused: AD transpose of phase 2 w.r.t. (z, mu1, sig1) — the pieces
    # _fused_local_vjp routes through the field VJP
    mu1, sig1 = _fuzz(29, shape, dtype, 2)
    _, vjp = jax.vjp(lambda z_, mu1_, sig1_: phase2(z_, mu, mu1_, sig, sig1_,
                                                    dw, dt), z, mu1, sig1)
    d_z_ad, c_mu1_ad, c_sig1_ad = vjp(g_z1)

    c_mu1_k, c_sig1_k = jax.jit(
        lambda d: rh.rev_heun_bwd_phase1(g_z1, jnp.zeros_like(mu),
                                         jnp.zeros_like(sig), dw, d,
                                         interpret=True))(dt)
    c_mu1_ref, c_sig1_ref = jax.jit(
        lambda d: ref.rev_heun_bwd_phase1(g_z1, jnp.zeros_like(mu),
                                          jnp.zeros_like(sig), dw, d))(dt)
    _assert_bitwise((c_mu1_k, c_sig1_k), (c_mu1_ref, c_sig1_ref),
                    "bwd_phase1 vs ref under vjp seeds")
    np.testing.assert_allclose(np.asarray(c_mu1_k), np.asarray(c_mu1_ad),
                               rtol=0, atol=1e-15)
    np.testing.assert_allclose(np.asarray(c_sig1_k), np.asarray(c_sig1_ad),
                               rtol=0, atol=1e-15)


# -----------------------------------------------------------------------------
# in-kernel Brownian generation
# -----------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(4, 4), (5, 7), (2, 3, 8), (16,)])
def test_brownian_increment_kernel_bitwise(shape, dtype):
    k1, k2 = prng.key_data_pair(jax.random.PRNGKey(42))
    for n in (0, 5, 63):
        for dt in DTS:
            dt = jnp.asarray(dt, dtype)
            got = jax.jit(lambda d: bk.brownian_increment(
                k1, k2, n, shape, dtype, d, interpret=True))(dt)
            want = jax.jit(lambda d: ref.brownian_increment(
                k1, k2, n, shape, dtype, d))(dt)
            _assert_bitwise(got, want, f"brownian_increment {shape} {dtype} {n}")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(4, 4), (2, 3, 8), (16,)])
def test_brownian_value_kernel_bitwise(shape, dtype):
    k1, k2 = prng.key_data_pair(jax.random.PRNGKey(43))
    for t in (0.125, 0.3, 0.77):
        t = jnp.asarray(t, dtype)
        got = jax.jit(lambda t_: bk.brownian_value(
            k1, k2, t_, 0.0, 1.0, shape, dtype, interpret=True))(t)
        want = jax.jit(lambda t_: ref.brownian_value(
            k1, k2, t_, 0.0, 1.0, shape, dtype))(t)
        _assert_bitwise(got, want, f"brownian_value {shape} {dtype} {float(t)}")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(4, 4), (5, 7), (2, 3, 8), (16,)])
def test_phase1_gen_kernel_bitwise(shape, dtype):
    k1, k2 = prng.key_data_pair(jax.random.PRNGKey(44))
    z, zh, mu, sig = _fuzz(31, shape, dtype, 4)
    dt_grid = jnp.asarray(1.0 / 64, dtype)
    for sign in SIGNS:
        for dt in DTS:
            dt = jnp.asarray(dt, dtype)
            got = jax.jit(lambda dg, d: bk.rev_heun_phase1_gen(
                z, zh, mu, sig, k1, k2, 5, dg, d, sign=sign,
                interpret=True))(dt_grid, dt)

            def want_fn(dg, d):
                dw = ref.brownian_increment(k1, k2, 5, shape, dtype, dg)
                return ref.rev_heun_phase1(z, zh, mu, sig, dw, d, sign), dw

            want = jax.jit(want_fn)(dt_grid, dt)
            _assert_bitwise(got, want, f"phase1_gen {shape} {dtype} {sign}")


# -----------------------------------------------------------------------------
# PRNG primitives: the in-kernel Threefry port vs jax.random itself
# -----------------------------------------------------------------------------


def test_threefry_port_matches_jax_random():
    """The hand-ported counter-based PRNG reproduces jax.random draws
    bitwise — the foundation of the in-kernel generation contract."""
    key = jax.random.PRNGKey(123)
    folded = jax.random.fold_in(key, 7)
    k1, k2 = prng.key_data_pair(key)
    for shape in [(4, 4), (5, 7), (33,)]:
        for dtype in DTYPES:
            want = jax.random.normal(folded, shape, dtype)
            fk1, fk2 = prng.fold_in(k1, k2, 7)
            got = prng.normal_like(fk1, fk2, shape, dtype)
            _assert_bitwise(got, want, f"normal {shape} {dtype}")


@pytest.mark.parametrize("dtype", DTYPES)
def test_increment_matches_brownianpath_contract(dtype):
    """PRNG contract, grid half: the in-kernel increment is bitwise the
    ``BrownianPath.increment`` draw for the same ``(key, n, grid)`` — the
    noise a fused fixed-step solve generates in-kernel is the noise the
    unfused solve reads off the path object."""
    key = jax.random.PRNGKey(9)
    shape = (3, 5)
    num_steps = 16
    bm = BrownianPath(key, 0.0, 1.0, shape, dtype)
    dt = jnp.asarray((bm.t1 - bm.t0) / num_steps, dtype)
    k1, k2 = prng.key_data_pair(key)
    for n in (0, 3, 15):
        path_inc = bm.increment(n, num_steps)
        kern_inc = jax.jit(lambda d: bk.brownian_increment(
            k1, k2, n, shape, dtype, d, interpret=True))(dt)
        _assert_bitwise(kern_inc, path_inc, f"increment n={n} {dtype}")


@pytest.mark.parametrize("dtype", DTYPES)
def test_value_kernel_matches_evaluate_contract(dtype):
    """PRNG contract, bridge half: in-kernel ``brownian_value`` differences
    are bitwise ``BrownianPath.evaluate(s, t)`` — the noise the fused
    adaptive driver consumes per attempt is exactly what the unfused
    driver (and the backward replay) query through the bridge API.  (Grid
    increments and bridge queries are different refinements of the path by
    design — this test deliberately compares bridge-to-bridge.)"""
    key = jax.random.PRNGKey(9)
    shape = (3, 5)
    bm = BrownianPath(key, 0.0, 1.0, shape, dtype)
    k1, k2 = prng.key_data_pair(key)
    for s, t in [(0.0, 0.25), (0.125, 0.3), (0.5, 0.77)]:
        ev = bm.evaluate(s, t)
        vs = jax.jit(lambda x: bk.brownian_value(
            k1, k2, x, 0.0, 1.0, shape, dtype, interpret=True))
        kern = vs(jnp.asarray(t, dtype)) - vs(jnp.asarray(s, dtype))
        _assert_bitwise(kern, ev, f"value-diff vs evaluate ({s},{t}) {dtype}")


def test_increment_contract_under_vmap():
    """The contract holds lane-wise under vmap over keys (batched
    multi-trajectory solving draws per-lane paths this way)."""
    dtype = jnp.float64
    shape = (4,)
    keys = jax.random.split(jax.random.PRNGKey(77), 5)
    num_steps = 8
    dt = jnp.asarray(1.0 / num_steps, dtype)

    def kern(key, d):
        k1, k2 = prng.key_data_pair(key)
        return bk.brownian_increment(k1, k2, 3, shape, dtype, d,
                                     interpret=True)

    def oracle(key, d):
        k1, k2 = prng.key_data_pair(key)
        return ref.brownian_increment(k1, k2, 3, shape, dtype, d)

    got = jax.jit(jax.vmap(kern, in_axes=(0, None)))(keys, dt)
    want = jax.jit(jax.vmap(oracle, in_axes=(0, None)))(keys, dt)
    _assert_bitwise(got, want, "vmapped increment")
    # and lane-wise against the path object's own draw.  bm.increment runs
    # the oracle EAGERLY on CPU, where XLA's contraction choices can drift
    # 1 ulp from the jit'd kernel (methodology rule 1) — so this linkage
    # assert is 1-ulp-tolerant; the bitwise gates above are jit-to-jit.
    lane = jax.jit(functools.partial(kern, keys[2]))(dt)
    path = BrownianPath(keys[2], 0.0, 1.0, shape, dtype)
    np.testing.assert_allclose(np.asarray(lane),
                               np.asarray(path.increment(3, num_steps)),
                               rtol=0, atol=5e-16)


# -----------------------------------------------------------------------------
# dispatch-layer equivalence: ops routes both paths to the same bits
# -----------------------------------------------------------------------------


def test_ops_forced_kernel_equals_oracle_path(key):
    """ops.* with use_kernel=True (interpret off-TPU) is bitwise the
    use_kernel=False oracle under jit — callers cannot observe the
    dispatch choice.  (The solver hot loops always run these inside
    compiled scans/whiles, so jit is the operative context.)"""
    dtype = jnp.float64
    shape = (4, 8)
    z, zh, mu, sig, dw = _fuzz(37, shape, dtype, 5)
    dt = jnp.asarray(0.05, dtype)

    def pipeline(uk, d):
        return (
            ops.rev_heun_phase1(z, zh, mu, sig, dw, d, use_kernel=uk),
            ops.rev_heun_phase2(z, mu, zh, sig, mu, dw, d, use_kernel=uk),
            ops.rev_heun_bwd_phase1(z, zh, mu, dw, d, use_kernel=uk),
            ops.rev_heun_bwd_phase2(z, zh, dw, d, use_kernel=uk),
            ops.brownian_increment(key, 2, shape, dtype, d, use_kernel=uk),
        )

    kernel_out = jax.jit(functools.partial(pipeline, True))(dt)
    oracle_out = jax.jit(functools.partial(pipeline, False))(dt)
    for i, (a, b) in enumerate(zip(jax.tree.leaves(kernel_out),
                                   jax.tree.leaves(oracle_out))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"ops dispatch leaf {i}")
