"""Unified SDE-solve front-end: one entry point, a solver registry, and
first-class batched multi-trajectory solving.

This is the `sdeint`-style surface the paper's pieces plug into
(cf. Li et al. 2020's ``sdeint(..., method=, adjoint=)``): callers pick a
``solver`` × ``gradient_mode`` × ``noise`` combination and
:func:`solve` dispatches to

* plain ``lax.scan`` + JAX AD (``gradient_mode="discretise"``,
  discretise-then-optimise, O(N) activation memory),
* the paper's algebraically-reversible exact adjoint
  (``"reversible_adjoint"``, O(1) memory, FP-exact gradients — §3/App. C),
* the optimise-then-discretise continuous adjoint baseline
  (``"continuous_adjoint"``, eq. (6), O(√h) gradient error).

Every solver is described by a :class:`SolverSpec` in :data:`SOLVERS`; the
spec carries the stepper, its algebraic inverse (when one exists), the NFE
accounting the paper's Tables 1/4/5 report, the strong order, and which
gradient modes / fused-kernel paths are legal.  Validation therefore
happens *once, by data* — adding a **discretise-mode** solver means
registering a spec, not editing dispatch chains (the spec's stepper is
dispatched into the scan).  The two adjoint backends are not (yet)
stepper-generic: "reversible_adjoint" is implemented for the
reversible-Heun pair and "continuous_adjoint" for the builtin
midpoint/heun/euler backward integrators — :func:`solve` validates this
eagerly rather than producing another solver's numerics silently.

``use_pallas_kernels=True`` routes the reversible-Heun hot loop through the
fused Pallas kernels (:mod:`repro.kernels.reversible_heun_step`): the
forward scan and the backward's closed-form state reconstruction run
fused; local per-step VJPs stay unfused (the kernels have no VJP rule).
On non-TPU backends the kernels run in interpret mode automatically.

Batched multi-trajectory solving (:func:`solve_batched`) vmaps a batch of
initial states against a batch of Brownian seeds — one fused XLA program
for the whole ensemble instead of a Python loop of solves.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax

from .adjoint import (
    continuous_adjoint_solve,
    reversible_heun_solve,
    reversible_heun_solve_final,
)
from .brownian import BrownianPath
from .solvers import (
    _euler_maruyama_step,
    _heun_step,
    _midpoint_step,
    reversible_heun_reverse_step,
    reversible_heun_step,
    sde_solve,
)

__all__ = [
    "GRADIENT_MODES",
    "SOLVERS",
    "SolverSpec",
    "available_solvers",
    "get_solver",
    "register_solver",
    "solve",
    "solve_batched",
]

#: The three gradient paths of the paper's landscape (§2.3/§2.4).
GRADIENT_MODES = ("discretise", "reversible_adjoint", "continuous_adjoint")


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Registry entry describing one solver's capabilities.

    Attributes:
        name: registry key (the ``solver=`` string).
        stepper: ``(z_or_state, t, dt, dw, drift, diffusion, params, noise)``
            single-step function.
        reverse_stepper: algebraic inverse of ``stepper`` or ``None`` for
            non-reversible solvers.
        nfe_per_step: drift+diffusion evaluations per step (paper §3).
        strong_order: strong convergence order (multiplicative noise).
        gradient_modes: subset of :data:`GRADIENT_MODES` this solver serves.
        supports_pallas: whether the fused Pallas step kernels apply.
        sde_type: "ito" or "stratonovich".
        notes: one-line description (surfaced in README's inventory table).
    """

    name: str
    stepper: Callable
    reverse_stepper: Optional[Callable]
    nfe_per_step: int
    strong_order: float
    gradient_modes: Tuple[str, ...]
    supports_pallas: bool = False
    sde_type: str = "stratonovich"
    notes: str = ""

    @property
    def reversible(self) -> bool:
        return self.reverse_stepper is not None


SOLVERS: dict = {}


def register_solver(spec: SolverSpec) -> SolverSpec:
    """Add (or replace) a solver spec in the registry."""
    for m in spec.gradient_modes:
        if m not in GRADIENT_MODES:
            raise ValueError(f"{spec.name}: unknown gradient mode {m!r}")
    if "reversible_adjoint" in spec.gradient_modes and not spec.reversible:
        raise ValueError(
            f"{spec.name}: reversible_adjoint requires a reverse_stepper")
    SOLVERS[spec.name] = spec
    return spec


def get_solver(name: str) -> SolverSpec:
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered: {sorted(SOLVERS)}") from None


def available_solvers() -> Tuple[str, ...]:
    return tuple(sorted(SOLVERS))


register_solver(SolverSpec(
    "euler_maruyama", _euler_maruyama_step, None,
    nfe_per_step=1, strong_order=0.5,
    gradient_modes=("discretise", "continuous_adjoint"),
    sde_type="ito", notes="order-0.5 Itô baseline"))

register_solver(SolverSpec(
    "midpoint", _midpoint_step, None,
    nfe_per_step=2, strong_order=0.5,
    gradient_modes=("discretise", "continuous_adjoint"),
    notes="paper's main baseline"))

register_solver(SolverSpec(
    "heun", _heun_step, None,
    nfe_per_step=2, strong_order=0.5,
    gradient_modes=("discretise", "continuous_adjoint"),
    notes="trapezoidal"))

register_solver(SolverSpec(
    "reversible_heun", reversible_heun_step, reversible_heun_reverse_step,
    nfe_per_step=1, strong_order=0.5,
    gradient_modes=("discretise", "reversible_adjoint"),
    supports_pallas=True,
    notes="algebraically reversible; O(1)-memory exact adjoint (paper §3)"))


#: Solvers the continuous-adjoint backward integrator (adjoint.py) actually
#: implements a time-reversed stepper for.  A registered solver outside this
#: set would silently fall back to backward Euler — reject instead.
_CONTINUOUS_ADJOINT_BACKWARDS = ("euler_maruyama", "midpoint", "heun")


def _validate(spec: SolverSpec, gradient_mode: str, noise: str,
              use_pallas_kernels: bool, save_trajectory: bool) -> None:
    if gradient_mode not in GRADIENT_MODES:
        raise ValueError(
            f"unknown gradient_mode {gradient_mode!r}; one of {GRADIENT_MODES}")
    if gradient_mode not in spec.gradient_modes:
        raise ValueError(
            f"solver {spec.name!r} does not support gradient_mode="
            f"{gradient_mode!r} (supported: {spec.gradient_modes})")
    if (gradient_mode == "continuous_adjoint"
            and spec.name not in _CONTINUOUS_ADJOINT_BACKWARDS):
        raise ValueError(
            f"solver {spec.name!r} declares continuous_adjoint but the "
            f"continuous-adjoint backward integrator only implements "
            f"{_CONTINUOUS_ADJOINT_BACKWARDS} (repro.core.adjoint); extend "
            f"continuous_adjoint_solve before registering this combination")
    if (gradient_mode == "reversible_adjoint"
            and (spec.stepper is not reversible_heun_step
                 or spec.reverse_stepper is not reversible_heun_reverse_step)):
        raise ValueError(
            f"solver {spec.name!r} declares reversible_adjoint but the exact "
            f"adjoint is implemented for the reversible-Heun stepper pair "
            f"(repro.core.adjoint); a custom reversible solver needs its own "
            f"custom_vjp there")
    if noise not in ("diagonal", "general"):
        raise ValueError(f"unknown noise type {noise!r}")
    if use_pallas_kernels:
        if not spec.supports_pallas:
            raise ValueError(
                f"solver {spec.name!r} has no fused Pallas path "
                f"(only: {[s.name for s in SOLVERS.values() if s.supports_pallas]})")
        if noise != "diagonal":
            raise ValueError(
                "use_pallas_kernels requires diagonal noise (the fused "
                "kernels are elementwise; general noise needs an einsum)")
        if gradient_mode == "discretise":
            raise ValueError(
                "use_pallas_kernels is incompatible with gradient_mode="
                "'discretise': pallas_call has no VJP rule, so plain AD "
                "cannot trace through the fused step.  Use gradient_mode="
                "'reversible_adjoint' instead — its forward pass is the "
                "identical fused scan (so this also covers pure forward "
                "simulation), and differentiating it gives the exact "
                "adjoint with fused backward reconstruction")
    if gradient_mode == "continuous_adjoint" and save_trajectory:
        raise ValueError(
            "continuous_adjoint backpropagates a terminal-value cotangent "
            "only — call solve(..., save_trajectory=False)")


def solve(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    t0: float,
    t1: float,
    num_steps: int,
    *,
    solver: str = "reversible_heun",
    gradient_mode: str = "discretise",
    noise: str = "diagonal",
    save_trajectory: bool = True,
    use_pallas_kernels: bool = False,
):
    """Solve ``dZ = μ_θ dt + σ_θ ∘ dW`` on ``[t0, t1]`` in ``num_steps`` steps.

    The single front door to the solver subsystem::

        traj = repro.solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 64,
                           solver="reversible_heun",
                           gradient_mode="reversible_adjoint")

    Args:
        drift: ``(params, t, z) -> dz/dt`` (shape of ``z``).
        diffusion: ``(params, t, z) -> σ`` — shape of ``z`` for diagonal
            noise, ``(*z.shape, w)`` for general noise.
        params: pytree of parameters passed to both vector fields.
        z0: initial state.
        bm: Brownian sample path (:class:`repro.core.brownian.BrownianPath`
            or anything exposing ``increment(n, num_steps)``).
        t0, t1, num_steps: uniform time grid.
        solver: registry key — see :func:`available_solvers`.
        gradient_mode: "discretise" (AD through the scan, O(N) memory),
            "reversible_adjoint" (paper's exact O(1)-memory adjoint), or
            "continuous_adjoint" (optimise-then-discretise baseline).
        noise: "diagonal" or "general".
        save_trajectory: return the full ``(num_steps+1, *z0.shape)``
            trajectory (index 0 is ``z0``) instead of the terminal value.
            Must be ``False`` for "continuous_adjoint".
        use_pallas_kernels: fuse the reversible-Heun state updates through
            the Pallas kernels (diagonal noise; forbidden with
            "discretise" — the fused ops are not AD-traceable).

    Returns:
        Trajectory or terminal value, differentiable w.r.t. ``params`` and
        ``z0`` according to ``gradient_mode``.
    """
    spec = get_solver(solver)
    _validate(spec, gradient_mode, noise, use_pallas_kernels, save_trajectory)

    if gradient_mode == "reversible_adjoint":
        if save_trajectory:
            return reversible_heun_solve(
                drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
                use_pallas_kernels)
        return reversible_heun_solve_final(
            drift, diffusion, params, z0, bm, t0, t1, num_steps, noise,
            use_pallas_kernels)

    if gradient_mode == "continuous_adjoint":
        return continuous_adjoint_solve(
            drift, diffusion, params, z0, bm, t0, t1, num_steps,
            solver=solver, noise=noise)

    return sde_solve(
        drift, diffusion, params, z0, bm, t0, t1, num_steps,
        solver=solver, noise=noise, save_trajectory=save_trajectory,
        use_pallas_kernels=use_pallas_kernels,
        # registry-registered steppers (z-carried) dispatch through here;
        # "reversible_heun" keeps sde_solve's carried-state fast path.
        step_fn=None if solver == "reversible_heun" else spec.stepper)


def solve_batched(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    keys: jax.Array,
    t0: float,
    t1: float,
    num_steps: int,
    *,
    w_dim: Optional[int] = None,
    **kwargs,
):
    """Vmapped multi-trajectory :func:`solve`: batch of initial states ×
    batch of Brownian seeds, as one XLA program.

    Args:
        z0: ``(B, *state_shape)`` initial states.
        keys: ``(B,)`` PRNG keys — one independent Brownian path per
            trajectory (pass ``jax.random.split(key, B)``).
        w_dim: Brownian dimension for general noise (defaults to the
            trailing state dim, i.e. diagonal layout).
        **kwargs: forwarded to :func:`solve` (solver / gradient_mode /
            noise / save_trajectory / use_pallas_kernels); validated once
            before vmapping so errors surface eagerly.

    Returns:
        ``(B, num_steps+1, *state_shape)`` trajectories (or ``(B, *state)``
        terminal values with ``save_trajectory=False``).
    """
    if z0.ndim < 1 or keys.shape[0] != z0.shape[0]:
        raise ValueError(
            f"leading (batch) dims must agree: z0 {z0.shape} vs keys "
            f"{keys.shape}")
    spec = get_solver(kwargs.get("solver", "reversible_heun"))
    _validate(spec,
              kwargs.get("gradient_mode", "discretise"),
              kwargs.get("noise", "diagonal"),
              kwargs.get("use_pallas_kernels", False),
              kwargs.get("save_trajectory", True))

    state_shape = z0.shape[1:]
    if kwargs.get("noise", "diagonal") == "general":
        if w_dim is None:
            raise ValueError("general noise needs w_dim= for the Brownian shape")
        bm_shape = state_shape[:-1] + (w_dim,)
    else:
        bm_shape = state_shape

    def single(z0_i, key_i):
        bm = BrownianPath(key_i, t0, t1, bm_shape, z0.dtype)
        return solve(drift, diffusion, params, z0_i, bm, t0, t1, num_steps,
                     **kwargs)

    return jax.vmap(single)(z0, keys)
