"""Batched-request serving driver: prefill + greedy decode loop.

The inference-side end-to-end example (the paper's kind is training, so
train.py is the headline driver; this exercises the ``prefill_*``/``decode_*``
step functions with real batched requests on a smoke config).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_config
from .steps import greedy_sample, make_prefill_step, make_serve_step


def serve(arch: str, batch: int, prompt_len: int, gen: int, smoke: bool = True,
          seed: int = 0):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    if cfg.family == "encdec":
        raise SystemExit("use --arch with a decoder-only config for serve.py")
    from ..models import transformer as T

    key = jax.random.PRNGKey(seed)
    params = T.init_lm(key, cfg)
    max_len = prompt_len + gen + (cfg.frontend_len if cfg.frontend else 0)

    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab)
    batch_in = {"tokens": prompts}
    pos0 = prompt_len
    if cfg.frontend:
        batch_in["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.frontend_len, cfg.d_model), cfg.dtype)
        pos0 += cfg.frontend_len

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, caches = prefill(params, batch_in)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    token = greedy_sample(logits)
    out_tokens = [token]
    t0 = time.time()
    for i in range(gen - 1):
        logits, caches = decode(params, caches, token, jnp.asarray(pos0 + i, jnp.int32))
        token = greedy_sample(logits)
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0

    gen_tokens = jnp.concatenate(out_tokens, axis=1)
    tps = batch * (gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] {arch}: batch={batch} prefill({prompt_len} tok) "
          f"{t_prefill*1e3:.1f}ms; decode {gen-1} steps @ {tps:.1f} tok/s")
    print(f"[serve] sample generation (row 0): {gen_tokens[0].tolist()}")
    return gen_tokens


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    serve(args.arch, args.batch, args.prompt_len, args.gen, args.smoke, args.seed)


if __name__ == "__main__":
    main()
