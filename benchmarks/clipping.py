"""Paper Table 3 / 11 (speed axis): clipping vs gradient penalty.

Measures one discriminator update under (a) the paper's hard clipping +
LipSwish recipe (single backward) and (b) WGAN-GP (double backward through
the CDE solve).  The removal of the double backward is the 1.41× speedup of
Table 11; reversible Heun adds the rest (1.87× total).
Also verifies the clipped vector fields have Lipschitz bound ≤ 1.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def main(quick: bool = False):
    from repro.core.clipping import clip_lipschitz, lipschitz_bound_mlp
    from repro.core.sde import (NeuralSDEConfig, discriminator_init,
                                discriminate_path, gradient_penalty)
    from repro.data.synthetic import ou_process

    reps = 3 if quick else 10
    cfg = NeuralSDEConfig(num_steps=31, exact_adjoint=False, solver="midpoint")
    key = jax.random.PRNGKey(0)
    disc = discriminator_init(key, cfg)
    y_real = ou_process(jax.random.fold_in(key, 1), 128, 32)
    y_fake = ou_process(jax.random.fold_in(key, 2), 128, 32)

    def disc_loss_plain(p):
        return (jnp.mean(discriminate_path(p, cfg, y_fake))
                - jnp.mean(discriminate_path(p, cfg, y_real)))

    def disc_loss_gp(p):
        gp = gradient_penalty(p, cfg, jax.random.fold_in(key, 3), y_real, y_fake)
        return disc_loss_plain(p) + 10.0 * gp

    # One full discriminator update per regime, all device work jitted:
    #   clipping     : grad(plain loss) -> apply -> hard clip  (single bwd)
    #   grad penalty : grad(plain + 10*GP)                     (double bwd)
    def update_clip(p):
        g = jax.grad(disc_loss_plain)(p)
        p = jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)
        return clip_lipschitz(p)

    def update_gp(p):
        g = jax.grad(disc_loss_gp)(p)
        return jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)

    rows = []
    timings = {}
    for name, fn in (("clipping", update_clip), ("grad_penalty", update_gp)):
        step = jax.jit(fn)
        out = step(disc)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = step(disc)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        timings[name] = dt
        rows.append(("clipping", name, dt * 1e3))
        print(f"clipping,{name},{dt*1e3:.2f}ms", flush=True)
    sp = timings["grad_penalty"] / timings["clipping"]
    print(f"clipping,speedup,{sp:.2f}x", flush=True)
    rows.append(("clipping", "speedup", sp))

    # Lipschitz bound after clipping (must be <= 1 for f, g, xi)
    clipped = clip_lipschitz(jax.tree.map(lambda x: x * 10.0, disc))
    for name in ("f", "g", "xi"):
        b = float(lipschitz_bound_mlp(clipped[name]))
        rows.append(("clipping", f"lipschitz_bound_{name}", b))
        print(f"clipping,lipschitz_bound_{name},{b:.3f}", flush=True)
        assert b <= 1.0 + 1e-6, f"clipping failed to bound {name}"
    return rows


if __name__ == "__main__":
    main()
