"""End-to-end Neural SDE tests: SDE-GAN + Latent SDE training behaviour
(the paper's system), clipping/LipSwish, signature MMD."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.core.clipping import clip_lipschitz, lipschitz_bound_mlp
from repro.core.sde import (LatentSDEConfig, NeuralSDEConfig, discriminator_init,
                            gan_losses, generator_init,
                            generator_sample, latent_sde_init, latent_sde_loss,
                            latent_sde_sample)
from repro.data.synthetic import air_quality_like, ou_process


def test_generator_sample_shapes(key):
    cfg = NeuralSDEConfig(num_steps=8)
    params = generator_init(key, cfg)
    ys = generator_sample(params, cfg, key, batch=4)
    assert ys.shape == (9, 4, cfg.data_dim)
    assert np.isfinite(np.asarray(ys)).all()


def test_gan_losses_and_grads(key):
    cfg = NeuralSDEConfig(num_steps=8)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    y_real = ou_process(jax.random.fold_in(key, 2), 16, 9)

    def gen_loss(p):
        g, d, _ = gan_losses(p, cfg, jax.random.fold_in(key, 3), y_real, 16)
        return g

    def disc_loss(p):
        g, d, _ = gan_losses(p, cfg, jax.random.fold_in(key, 3), y_real, 16)
        return d

    gg = jax.grad(gen_loss)(params)
    gd = jax.grad(disc_loss)(params)
    for t in (gg, gd):
        assert all(np.isfinite(np.asarray(x, np.float32)).all()
                   for x in jax.tree.leaves(t))
    # adversarial signs: gen loss decreases what disc loss increases
    g, d, fake = gan_losses(params, cfg, jax.random.fold_in(key, 3), y_real, 16)
    assert np.isfinite(float(g)) and np.isfinite(float(d))


def test_clipping_enforces_lipschitz(key):
    cfg = NeuralSDEConfig()
    disc = discriminator_init(key, cfg)
    blown = jax.tree.map(lambda x: x * 50.0, disc)
    clipped = clip_lipschitz(blown)
    for name in ("f", "g", "xi"):
        assert float(lipschitz_bound_mlp(clipped[name])) <= 1.0 + 1e-6
    # m (the readout) is untouched
    np.testing.assert_allclose(np.asarray(clipped["m"]["w"]),
                               np.asarray(blown["m"]["w"]))


def test_lipswish_properties():
    from repro.nn import lipswish

    x = jnp.linspace(-20, 20, 10_001)
    g = jax.vmap(jax.grad(lambda t: lipswish(t)))(x)
    assert float(jnp.max(jnp.abs(g))) <= 1.0 + 1e-4  # Lipschitz constant 1
    # smooth (C²): second derivative exists and is finite
    h = jax.vmap(jax.grad(jax.grad(lambda t: lipswish(t))))(x)
    assert np.isfinite(np.asarray(h)).all()


def test_latent_sde_elbo_and_training(key):
    # data has 24 observations => T = 23 intervals; num_steps must be a
    # multiple of T so the solver grid aligns with the data grid.
    cfg = LatentSDEConfig(data_dim=2, num_steps=23, hidden_dim=8, context_dim=8,
                          width=16)
    params = latent_sde_init(key, cfg)
    ys, _ = air_quality_like(jax.random.fold_in(key, 1), 32, 24)

    def loss_fn(p, k):
        loss, parts = latent_sde_loss(p, cfg, k, ys)
        return loss

    loss0 = float(loss_fn(params, jax.random.fold_in(key, 2)))
    assert np.isfinite(loss0)
    # a few Adam steps reduce the ELBO loss
    from repro import optim

    oi, ou = optim.adam(1e-2)
    state = oi(params)
    p = params
    step = jax.jit(lambda p_, s_, k_: _adam_step(p_, s_, k_, loss_fn, ou))
    for i in range(20):
        p, state = step(p, state, jax.random.fold_in(key, 100 + i))
    loss1 = float(loss_fn(p, jax.random.fold_in(key, 999)))
    assert loss1 < loss0, (loss0, loss1)


def _adam_step(p, s, k, loss_fn, ou):
    g = jax.grad(loss_fn)(p, k)
    upd, s = ou(g, s, p)
    from repro import optim

    return optim.apply_updates(p, upd), s


def test_latent_sde_sampling(key):
    cfg = LatentSDEConfig(num_steps=8)
    params = latent_sde_init(key, cfg)
    ys = latent_sde_sample(params, cfg, key, 8)
    assert ys.shape == (9, 8, cfg.data_dim)
    assert np.isfinite(np.asarray(ys)).all()


def test_signature_mmd_separates_distributions(key):
    """MMD(P, P') small for same law; large for different laws."""
    y1 = ou_process(jax.random.fold_in(key, 1), 256, 16)
    y2 = ou_process(jax.random.fold_in(key, 2), 256, 16)
    y3 = jnp.cumsum(jax.random.normal(jax.random.fold_in(key, 3), (16, 256, 1)), 0)
    same = float(losses.signature_mmd(y1, y2, depth=3))
    diff = float(losses.signature_mmd(y1, y3, depth=3))
    assert diff > 3 * same, (same, diff)


def test_signature_chen_identity(key):
    """Signature of a concatenated path == tensor product of signatures
    (Chen's relation) — checked at depth 2 via the additivity of level 1
    and the level-2 cross term."""
    path = jnp.cumsum(jax.random.normal(key, (9, 1, 2)), 0)
    full = losses.signature(path, depth=2)
    a = losses.signature(path[:5], depth=2)
    b = losses.signature(path[4:], depth=2)
    d = 2
    lvl1 = lambda s: s[..., :d]
    lvl2 = lambda s: s[..., d:].reshape(s.shape[:-1] + (d, d))
    np.testing.assert_allclose(np.asarray(lvl1(full)),
                               np.asarray(lvl1(a) + lvl1(b)), rtol=1e-4, atol=1e-5)
    want2 = lvl2(a) + lvl2(b) + lvl1(a)[..., :, None] * lvl1(b)[..., None, :]
    np.testing.assert_allclose(np.asarray(lvl2(full)), np.asarray(want2),
                               rtol=1e-4, atol=1e-5)


def test_gradient_penalty_runs(key):
    """The WGAN-GP baseline (double backward) the paper replaces."""
    from repro.core.sde import gradient_penalty

    cfg = NeuralSDEConfig(num_steps=8, exact_adjoint=False, solver="midpoint")
    disc = discriminator_init(key, cfg)
    y_real = ou_process(jax.random.fold_in(key, 1), 8, 9)
    y_fake = ou_process(jax.random.fold_in(key, 2), 8, 9)
    gp = gradient_penalty(disc, cfg, jax.random.fold_in(key, 3), y_real, y_fake)
    assert np.isfinite(float(gp))
    g = jax.grad(lambda p: gradient_penalty(p, cfg, jax.random.fold_in(key, 3),
                                            y_real, y_fake))(disc)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))
