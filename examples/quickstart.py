"""Quickstart: the paper's three contributions in ~60 lines.

1. Solve a Stratonovich SDE with the **reversible Heun** method.
2. Backprop through it with the **O(1)-memory exact adjoint** and check the
   gradients equal discretise-then-optimise to float precision.
3. Sample Brownian increments with the **Brownian Interval** — exact,
   cache-backed, reconstructible on the backward pass.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.adjoint import reversible_heun_solve
from repro.core.brownian import BrownianPath
from repro.core.brownian_interval import BrownianInterval
from repro.core.solvers import sde_solve

jax.config.update("jax_enable_x64", True)


def main():
    key = jax.random.PRNGKey(0)
    k1, k2, kz, kw = jax.random.split(key, 4)

    # --- a small Neural SDE: dX = μ_θ(X) dt + σ_θ(X) ∘ dW -------------------
    params = {"mu": nn.mlp_init(k1, [4, 32, 4], dtype=jnp.float64),
              "sigma": nn.mlp_init(k2, [4, 32, 4], dtype=jnp.float64)}
    drift = lambda p, t, x: nn.mlp(p["mu"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p, t, x: 0.2 * nn.mlp(p["sigma"], x, nn.lipswish, jnp.tanh)

    x0 = jax.random.normal(kz, (8, 4), jnp.float64)
    bm = BrownianPath(kw, 0.0, 1.0, (8, 4), jnp.float64)   # counter-based, exact

    # --- 1. solve ------------------------------------------------------------
    traj = reversible_heun_solve(drift, diffusion, params, x0, bm, 0.0, 1.0,
                                 64, "diagonal")
    print(f"solved: trajectory {traj.shape}, X_T mean {float(traj[-1].mean()):+.4f}")

    # --- 2. exact gradients ----------------------------------------------------
    def loss_exact(p):
        t = reversible_heun_solve(drift, diffusion, p, x0, bm, 0.0, 1.0, 64, "diagonal")
        return jnp.mean(t[-1] ** 2)

    def loss_dto(p):  # autodiff through the solver internals (O(N) memory)
        t = sde_solve(drift, diffusion, p, x0, bm, 0.0, 1.0, 64,
                      solver="reversible_heun")
        return jnp.mean(t[-1] ** 2)

    g1 = jax.grad(loss_exact)(params)
    g2 = jax.grad(loss_dto)(params)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    print(f"exact adjoint vs discretise-then-optimise: max |Δgrad| = {err:.2e}"
          f"  (float64 roundoff — the paper's Fig. 2)")

    # --- 3. Brownian Interval -------------------------------------------------
    bi = BrownianInterval(0.0, 1.0, shape=(3,), seed=42)
    w_ab = bi(0.2, 0.7)
    w_half = bi(0.2, 0.45) + bi(0.45, 0.7)   # consistency under refinement
    print(f"Brownian Interval: W(0.2,0.7) = {w_ab.round(4)}; "
          f"additivity error {np.abs(w_ab - w_half).max():.2e}")
    hits, misses = bi.cache_stats
    print(f"LRU cache: {hits} hits / {misses} misses")


if __name__ == "__main__":
    main()
