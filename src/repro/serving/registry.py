"""Hot-loadable multi-model registry (DESIGN.md §11).

N named checkpoints live in ONE serving process: each
:class:`LoadedModel` is a params-only restore of one ``repro-serving/v2``
bundle entry (v1 bundles upgrade transparently to a single ``"default"``
entry — :func:`repro.checkpoint.load_serving_manifest`), and every
AOT-compiled program the schedulers build is cached here keyed by
``(model_id, kind, bucket)`` — unloading a model drops its params AND its
compile pool, loading a new checkpoint under a fresh id never touches the
programs already serving traffic.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt


def _build_cfg(workload: str, config: dict):
    """Rebuild the model config dataclass from the bundle's JSON dict."""
    from ..core.sde import LatentSDEConfig, NeuralSDEConfig

    cls = NeuralSDEConfig if workload == "sde-gan" else LatentSDEConfig
    d = dict(config)
    d["dtype"] = jnp.dtype(d.get("dtype", "float32"))
    try:
        return cls(**d)
    except TypeError as e:
        raise ValueError(
            f"serving bundle config does not match {cls.__name__} — written "
            f"by an incompatible code version ({e})") from e


def _init_params(workload: str, cfg, seed: int):
    """Parameter template (and fresh-init values) for a workload's bundle."""
    from ..core.sde import generator_init, latent_sde_init

    key = jax.random.PRNGKey(seed)
    if workload == "sde-gan":
        return generator_init(key, cfg)  # serving needs the generator only
    return latent_sde_init(key, cfg)


@dataclasses.dataclass
class LoadedModel:
    """One registry entry: a named, servable checkpoint."""

    model_id: str
    workload: str
    cfg: object
    params: object
    step: int = 0


def load_model(ckpt_dir, model_id: Optional[str] = None,
               step: Optional[int] = None) -> LoadedModel:
    """Restore ONE named model from a serving bundle -> :class:`LoadedModel`.

    ``model_id=None`` picks the bundle's sole entry (erroring by name on a
    multi-entry bundle).  This is the public single-model loader —
    :meth:`ModelRegistry.load` restores every entry of a bundle at once.
    """
    meta, _ = ckpt.load_serving_manifest(ckpt_dir)
    entries = {m["model_id"]: m for m in meta["models"]}
    if model_id is None:
        if len(entries) != 1:
            raise ValueError(
                f"serving bundle under {ckpt_dir} carries "
                f"{len(entries)} model entries ({sorted(entries)}); pass "
                f"model_id= to pick one")
        model_id = next(iter(entries))
    if model_id not in entries:
        raise ValueError(
            f"serving bundle under {ckpt_dir} has no model {model_id!r} "
            f"(entries: {sorted(entries)})")
    entry = entries[model_id]
    cfg = _build_cfg(entry["workload"], entry["config"])
    params, got = ckpt.restore_serving_model(
        ckpt_dir, _init_params(entry["workload"], cfg, 0), model_id,
        step=step)
    return LoadedModel(model_id, entry["workload"], cfg, params, got)


def restore_for_serving(workload: str, ckpt_dir: str):
    """PR 4-compatible handshake + restore: ``(params, cfg, step)``.

    Single-model bundles only; the restored workload must match the asked
    one (named mismatch, never a pytree shape error)."""
    model = load_model(ckpt_dir)
    if model.workload != workload:
        raise ValueError(
            f"serving bundle under {ckpt_dir} was trained for workload "
            f"{model.workload!r}, not {workload!r} — point --ckpt-dir "
            f"at a matching run or change --workload")
    return model.params, model.cfg, model.step


class ModelRegistry:
    """The in-process model table: ``model_id -> LoadedModel`` plus the
    per-model AOT compile pools.

    Hot-loading contract: :meth:`load`/:meth:`register` may be called
    while other models are serving — compiled programs are cached lazily
    per ``(model_id, kind, bucket)``, so a new model's first batch pays
    its compiles and nobody else's cache is invalidated.  :meth:`unload`
    drops a model's params and every pool entry keyed to it.
    """

    def __init__(self):
        self._models: dict = {}
        self._pools: dict = {}  # (model_id, kind, bucket) -> compiled

    # -- the model table ----------------------------------------------------

    def register(self, model: LoadedModel, replace: bool = False) -> str:
        """Add a model under its id (``replace=True`` to hot-swap — the
        stale compile pool is dropped with the old params)."""
        if model.model_id in self._models and not replace:
            raise ValueError(
                f"model {model.model_id!r} is already registered "
                f"(ids: {sorted(self._models)}); unload it or pass "
                f"replace=True to hot-swap")
        if model.model_id in self._models:
            self.unload(model.model_id)
        self._models[model.model_id] = model
        return model.model_id

    def load(self, ckpt_dir, step: Optional[int] = None,
             replace: bool = False) -> tuple:
        """Restore EVERY entry of a serving bundle into the registry.

        Returns the tuple of loaded model ids.  A v1 bundle contributes
        its single upgraded ``"default"`` entry."""
        meta, _ = ckpt.load_serving_manifest(ckpt_dir)
        ids = []
        for entry in meta["models"]:
            ids.append(self.register(
                load_model(ckpt_dir, entry["model_id"], step=step),
                replace=replace))
        return tuple(ids)

    def unload(self, model_id: str) -> None:
        if model_id not in self._models:
            raise ValueError(f"model {model_id!r} is not registered "
                             f"(ids: {sorted(self._models)})")
        del self._models[model_id]
        for key in [k for k in self._pools if k[0] == model_id]:
            del self._pools[key]

    def get(self, model_id: str) -> LoadedModel:
        try:
            return self._models[model_id]
        except KeyError:
            raise ValueError(
                f"no model {model_id!r} in the registry (ids: "
                f"{sorted(self._models)}); load a bundle or register a "
                f"model first") from None

    def ids(self) -> tuple:
        return tuple(sorted(self._models))

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._models

    # -- the compile pools --------------------------------------------------

    def compiled(self, model_id: str, kind: str, bucket: int,
                 builder: Callable, verbose: bool = True):
        """Memoised AOT compile keyed ``(model_id, kind, bucket)``.

        ``builder()`` must return the compiled program (the caller owns
        ``jit(...).lower(...).compile()`` — the registry only owns the
        cache and its keying).  ``kind`` names the program family
        (``"sample"``, ``"init"``, ``"chunk"``, ``"terminal"``) so one
        model's families never collide on a bucket size."""
        self.get(model_id)  # unknown ids fail by name, not a silent pool
        key = (model_id, kind, bucket)
        if key not in self._pools:
            t0 = time.perf_counter()
            self._pools[key] = builder()
            if verbose:
                print(f"[serve] compiled {model_id}/{kind} bucket {bucket} "
                      f"in {time.perf_counter() - t0:.2f}s", flush=True)
        return self._pools[key]

    def pool_keys(self, model_id: Optional[str] = None) -> tuple:
        """The compile-pool keys currently cached (a model's on request)."""
        keys = self._pools if model_id is None else [
            k for k in self._pools if k[0] == model_id]
        return tuple(sorted(keys))
