"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written with no regard for
memory movement — tests sweep shapes/dtypes and assert the kernels match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..nn.core import lipswish


# -----------------------------------------------------------------------------
# reversible Heun fused state updates (diagonal noise)
# -----------------------------------------------------------------------------


def rev_heun_phase1(z, zh, mu, sigma, dw, dt: float, sign: float = 1.0):
    """ẑ_{n+1} = 2 z_n − ẑ_n + μ_n Δt + σ_n ΔW_n   (Algorithm 1, line 3).

    ``sign=-1.0`` is the algebraic inverse (Algorithm 2), matching the
    fused kernel's contract.
    """
    return 2.0 * z - zh + mu * (sign * dt) + (sign * sigma) * dw


def rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt: float, sign: float = 1.0):
    """z_{n+1} = z_n + ½(μ_n+μ_{n+1})Δt + ½(σ_n+σ_{n+1})ΔW_n."""
    return z + (sign * 0.5 * dt) * (mu + mu1) + (sign * 0.5) * (sigma + sigma1) * dw


# -----------------------------------------------------------------------------
# fused vector-field MLP (Linear → LipSwish → Linear)
# -----------------------------------------------------------------------------


def fused_mlp(x, w1, b1, w2, b2):
    h = lipswish(x @ w1 + b1)
    return h @ w2 + b2


# -----------------------------------------------------------------------------
# causal GQA flash attention
# -----------------------------------------------------------------------------


def flash_attention(q, k, v, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(D).astype(q.dtype)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv)


# -----------------------------------------------------------------------------
# Mamba2 SSD chunk scan
# -----------------------------------------------------------------------------


def ssd_scan(x, a, b, c):
    """Naive sequential SSD recurrence (the definition).

    x: (B, H, S, P) inputs, a: (B, H, S) log-decay (<= 0),
    b, c: (B, H, S, N) input/output projections.
    h_t = exp(a_t)·h_{t-1} + b_t ⊗ x_t ;  y_t = cᵀ_t h_t.  Returns (B,H,S,P).
    """
    Bb, H, S, P = x.shape
    N = b.shape[-1]

    def per_head(xh, ah, bh, ch):
        def step(h, inp):
            xt, at, bt, ct = inp
            h = jnp.exp(at) * h + bt[:, None] * xt[None, :]
            return h, ct @ h

        h0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xh.astype(jnp.float32), ah.astype(jnp.float32),
                                        bh.astype(jnp.float32), ch.astype(jnp.float32)))
        return ys.astype(x.dtype)

    f = jax.vmap(jax.vmap(per_head))
    return f(x, a, b, c)


# -----------------------------------------------------------------------------
# fused softmax cross entropy
# -----------------------------------------------------------------------------


def fused_xent(logits, labels):
    """Per-token next-token cross entropy; logsumexp in f32.
    logits: (..., V); labels: (...) int32 -> (...) f32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return lse - ll
