"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 1000+-node scale the cross-pod (DCN) all-reduce of gradients is the
scarcest bandwidth.  We compress per-tensor to int8 with a float32 scale
(≈4× traffic reduction) and keep the quantisation residual in an
error-feedback buffer added back next step (Seide et al.-style EF-SGD), which
preserves convergence to first order.

``ef_compress_update`` is pure and shard_map-friendly: the caller all-reduces
the *compressed* payload over the pod axis.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale.astype(dtype)


def ef_compress_update(grads, error_buf):
    """Returns (quantised tree, scales tree, new error buffer).

    new_error = (g + e) - dequant(quant(g + e))
    """
    corrected = jax.tree.map(jnp.add, grads, error_buf)
    qs = jax.tree.map(compress_int8, corrected)
    q_tree = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree.map(lambda q, s, g: decompress_int8(q, s, g.dtype), q_tree, s_tree, corrected)
    new_err = jax.tree.map(jnp.subtract, corrected, deq)
    return q_tree, s_tree, new_err


def allreduce_compressed(grads, error_buf, axis_name: str):
    """Compressed cross-pod mean all-reduce (use inside shard_map over the
    pod axis).  Intra-pod reduction should happen first (full precision)."""
    q, s, new_err = ef_compress_update(grads, error_buf)
    deq = jax.tree.map(lambda qq, ss, g: decompress_int8(qq, ss, g.dtype), q, s, grads)
    summed = jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), deq)
    return summed, new_err
