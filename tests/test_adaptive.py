"""Adaptive stepping tests (DESIGN.md §10).

Four pillars:

* **eager validation** — every solver × adaptive × gradient-mode /
  trajectory / fusion combination that cannot work raises a named
  ValueError before any tracing;
* **strong-error regression** — on a *shared* ``DenseBrownianPath``,
  adaptive at tight tolerance beats the uniform grid of equal cost
  (same NFE budget) on the burst problem;
* **replay** — the accepted-step sequence replays bitwise (a plain scan
  over the stored ``(ts, dts)`` reproduces the adaptive terminal state
  exactly), the run is deterministic, and the exact adjoint's gradient
  matches plain AD through the frozen-grid replay to float64 round-off;
* **pathwise consistency** — ``BrownianPath.evaluate`` across a
  rejected-then-halved step: the increment of the full step equals the sum
  of the two half-step increments (the rejected attempt and its retry see
  the SAME underlying path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core.brownian import BrownianPath, DenseBrownianPath
from repro.core.solve import solve, solve_adaptive
from repro.core.solvers import RevHeunState, reversible_heun_step, sde_solve

# the time-localised stiffness burst (benchmarks/convergence.py §Frontier)
_A, _AMP, _C, _W, _SIGMA = 0.5, 30.0, 0.5, 0.05, 0.05


def _burst(p, t, y):
    theta = _A + _AMP * jnp.exp(-(((t - _C) / _W) ** 2))
    return theta * (1.0 - y) + (0.0 if p is None else p["shift"])


def _burst_diffusion(p, t, y):
    return _SIGMA * jnp.ones_like(y)


def _ou():
    params = {"theta": jnp.float32(1.2), "mu": jnp.float32(0.5),
              "sigma": jnp.float32(0.3)}
    drift = lambda p, t, x: p["theta"] * (p["mu"] - x)
    diffusion = lambda p, t, x: p["sigma"] * jnp.ones_like(x)
    return params, drift, diffusion


# -----------------------------------------------------------------------------
# eager validation
# -----------------------------------------------------------------------------


def test_adaptive_rejects_solver_without_embedded_pair(key):
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 3))
    bm = BrownianPath(key, 0.0, 1.0, (2, 3))
    with pytest.raises(ValueError, match="embedded error estimate"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8,
              solver="euler_maruyama", save_trajectory=False, adaptive=True)


def test_adaptive_rejects_save_trajectory(key):
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 3))
    bm = BrownianPath(key, 0.0, 1.0, (2, 3))
    with pytest.raises(ValueError, match="save_trajectory"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8, adaptive=True)


def test_adaptive_rejects_continuous_adjoint(key):
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 3))
    bm = BrownianPath(key, 0.0, 1.0, (2, 3))
    with pytest.raises(ValueError, match="continuous_adjoint"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8,
              solver="midpoint", gradient_mode="continuous_adjoint",
              save_trajectory=False, adaptive=True)


def test_adaptive_accepts_pallas_fusion(key):
    """adaptive × use_pallas_kernels is legal (dt is a traced kernel
    operand) and agrees with the unfused adaptive solve."""
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 3))
    bm = BrownianPath(key, 0.0, 1.0, (2, 3))
    kw = dict(solver="reversible_heun", gradient_mode="reversible_adjoint",
              save_trajectory=False, adaptive=True)
    z_fused = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8,
                    use_pallas_kernels=True, **kw)
    z_plain = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8, **kw)
    assert jnp.all(jnp.isfinite(z_fused))
    assert jnp.allclose(z_fused, z_plain, atol=1e-6)


def test_tolerance_options_require_adaptive(key):
    """rtol/atol/max_steps/dt0 without adaptive=True would be silently
    ignored by a fixed-grid solve — rejected eagerly instead."""
    params, drift, diffusion = _ou()
    z0 = jnp.ones((2, 3))
    bm = BrownianPath(key, 0.0, 1.0, (2, 3))
    with pytest.raises(ValueError, match="adaptive=True"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8, rtol=1e-6)
    with pytest.raises(ValueError, match="adaptive=True"):
        solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 8, max_steps=64)


def test_adaptive_rejects_bm_without_evaluate(key):
    """A fixed-grid-only path (DenseBrownianPath predates evaluate; use a
    stub) is rejected by name, not by an AttributeError mid-trace."""

    class GridOnly:
        def increment(self, n, num_steps):
            return jnp.zeros(())

    params, drift, diffusion = _ou()
    with pytest.raises(ValueError, match="evaluate"):
        solve(drift, diffusion, params, jnp.ones((2,)), GridOnly(),
              0.0, 1.0, 8, save_trajectory=False, adaptive=True)


# -----------------------------------------------------------------------------
# correctness: strong error at equal cost, on a SHARED dense path
# -----------------------------------------------------------------------------


def test_adaptive_beats_equal_cost_uniform_grid(key):
    """On the burst problem, adaptive at tight tolerance reaches a lower
    strong error than the uniform grid spending the SAME number of
    vector-field evaluations — pathwise (shared DenseBrownianPath)."""
    jax.config.update("jax_enable_x64", True)
    try:
        n_paths, fine = 32, 2048
        y0 = jnp.zeros((n_paths, 1), jnp.float64)
        bm = DenseBrownianPath.sample(key, 0.0, 1.0, fine, (n_paths, 1),
                                      jnp.float64)
        ref = sde_solve(_burst, _burst_diffusion, None, y0, bm, 0.0, 1.0,
                        fine, solver="heun", save_trajectory=False)

        def one(wi, y0i):
            bmi = DenseBrownianPath(wi, 0.0, 1.0)
            z, st = solve_adaptive(_burst, _burst_diffusion, None, y0i, bmi,
                                   0.0, 1.0, solver="reversible_heun",
                                   rtol=2e-3, atol=1e-5, max_steps=1024,
                                   dt0=1.0 / 16)
            return z, st.nfe, st.converged

        zT, nfe, conv = jax.vmap(one)(jnp.moveaxis(bm.w, 1, 0), y0)
        assert bool(jnp.all(conv))
        adaptive_err = float(jnp.mean(jnp.abs(zT - ref)))
        # uniform grid with AT LEAST equal cost: round the adaptive NFE up
        # to the next power of two (a divisor of the fine grid), so the
        # fixed baseline spends >= the adaptive budget — a strictly harder
        # bar than exactly-equal cost
        mean_nfe = float(jnp.mean(nfe))
        equal_steps = 1
        while equal_steps < mean_nfe - 1:
            equal_steps *= 2
        zT_fix = sde_solve(_burst, _burst_diffusion, None, y0, bm, 0.0, 1.0,
                           equal_steps, solver="reversible_heun",
                           save_trajectory=False)
        uniform_err = float(jnp.mean(jnp.abs(zT_fix - ref)))
        assert adaptive_err < uniform_err, (
            f"adaptive ({adaptive_err:.2e}, ~{mean_nfe:.0f} NFE) must beat "
            f"the >= equal-cost uniform grid ({uniform_err:.2e}, "
            f"{equal_steps + 1} NFE)")
    finally:
        jax.config.update("jax_enable_x64", False)


# -----------------------------------------------------------------------------
# replay: bitwise accepted-step sequence, exact adjoint == frozen-grid AD
# -----------------------------------------------------------------------------


def _adaptive_setup(key):
    p0 = {"shift": jnp.float64(0.0)}
    z0 = jnp.zeros((3,), jnp.float64)
    bm = BrownianPath(key, 0.0, 1.0, (3,), jnp.float64)
    kw = dict(rtol=1e-3, atol=1e-6, max_steps=512, dt0=1.0 / 16)
    return p0, z0, bm, kw


def test_accepted_sequence_replays_bitwise_and_deterministically(key):
    jax.config.update("jax_enable_x64", True)
    try:
        p0, z0, bm, kw = _adaptive_setup(key)
        zT, st = solve_adaptive(_burst, _burst_diffusion, p0, z0, bm,
                                0.0, 1.0, solver="reversible_heun", **kw)
        zT2, st2 = solve_adaptive(_burst, _burst_diffusion, p0, z0, bm,
                                  0.0, 1.0, solver="reversible_heun", **kw)
        # determinism: two runs agree bitwise, grid included
        np.testing.assert_array_equal(np.asarray(zT), np.asarray(zT2))
        np.testing.assert_array_equal(np.asarray(st.ts), np.asarray(st2.ts))
        np.testing.assert_array_equal(np.asarray(st.dts), np.asarray(st2.dts))
        assert int(st.num_accepted) == int(st2.num_accepted)
        assert bool(st.converged) and int(st.num_rejected) >= 0

        # a plain scan over the stored grid reproduces z_T bitwise — the
        # replay contract the exact adjoint's backward pass relies on
        n = int(st.num_accepted)
        s0 = RevHeunState(z0, z0, _burst(p0, 0.0, z0),
                          _burst_diffusion(p0, 0.0, z0))

        def body(s, i):
            dw = bm.evaluate(st.ts[i], st.ts[i] + st.dts[i]).astype(z0.dtype)
            return reversible_heun_step(s, st.ts[i], st.dts[i], dw, _burst,
                                        _burst_diffusion, p0, "diagonal"), None

        fin, _ = lax.scan(body, s0, jnp.arange(n))
        np.testing.assert_array_equal(np.asarray(fin.z), np.asarray(zT))
    finally:
        jax.config.update("jax_enable_x64", False)


def test_adaptive_exact_adjoint_matches_frozen_grid_ad(key):
    """Gradient of the adaptive solve (exact adjoint, O(max_steps)-scalar
    residuals) == plain AD through a scan over the frozen accepted grid,
    to float64 round-off."""
    jax.config.update("jax_enable_x64", True)
    try:
        p0, z0, bm, kw = _adaptive_setup(key)
        _, st = solve_adaptive(_burst, _burst_diffusion, p0, z0, bm,
                               0.0, 1.0, solver="reversible_heun", **kw)
        n = int(st.num_accepted)

        g_adj = jax.grad(lambda p: jnp.sum(solve(
            _burst, _burst_diffusion, p, z0, bm, 0.0, 1.0, 16,
            solver="reversible_heun", gradient_mode="reversible_adjoint",
            save_trajectory=False, adaptive=True, **kw) ** 2))(p0)

        def frozen(p):
            s0 = RevHeunState(z0, z0, _burst(p, 0.0, z0),
                              _burst_diffusion(p, 0.0, z0))

            def body(s, i):
                dw = bm.evaluate(st.ts[i],
                                 st.ts[i] + st.dts[i]).astype(z0.dtype)
                return reversible_heun_step(
                    s, st.ts[i], st.dts[i], dw, _burst, _burst_diffusion,
                    p, "diagonal"), None

            fin, _ = lax.scan(body, s0, jnp.arange(n))
            return jnp.sum(fin.z ** 2)

        g_frozen = jax.grad(frozen)(p0)
        np.testing.assert_allclose(float(g_adj["shift"]),
                                   float(g_frozen["shift"]),
                                   rtol=1e-10, atol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_adaptive_gradient_under_jit_and_traced_tolerance(key):
    """The adjoint composes with jit, and rtol may be a *traced* scalar
    (the per-request-tolerance serving surface) — one compiled program,
    many tolerances, tighter tolerance => more accepted steps."""
    params, drift, diffusion = _ou()
    z0 = jnp.ones((4,), jnp.float32)
    bm = BrownianPath(key, 0.0, 1.0, (4,), jnp.float32)

    @jax.jit
    def g(p, rtol):
        return jax.grad(lambda q: jnp.sum(solve(
            drift, diffusion, q, z0, bm, 0.0, 1.0, 16,
            solver="reversible_heun", gradient_mode="reversible_adjoint",
            save_trajectory=False, adaptive=True, rtol=rtol, atol=1e-6,
            max_steps=1024) ** 2))(p)

    for rtol in (1e-2, 1e-3):
        out = g(params, jnp.float32(rtol))
        assert all(bool(jnp.all(jnp.isfinite(v)))
                   for v in jax.tree.leaves(out))

    @jax.jit
    def steps_at(rtol):
        _, st = solve_adaptive(drift, diffusion, params, z0, bm, 0.0, 1.0,
                               solver="reversible_heun", rtol=rtol,
                               atol=1e-7, max_steps=2048)
        return st.num_accepted

    assert int(steps_at(jnp.float32(1e-4))) > int(steps_at(jnp.float32(1e-2)))


@pytest.mark.parametrize("solver", ["heun", "midpoint"])
def test_heun_midpoint_adaptive_forward(key, solver):
    """The Heun/Euler and midpoint/Euler embedded pairs qualify both
    two-evaluation solvers for adaptive forward solving; their terminal
    values agree with a fine fixed-grid reference at tolerance level."""
    params, drift, diffusion = _ou()
    z0 = jnp.ones((4,), jnp.float32)
    bm = BrownianPath(key, 0.0, 1.0, (4,), jnp.float32)
    zT, st = solve_adaptive(drift, diffusion, params, z0, bm, 0.0, 1.0,
                            solver=solver, rtol=1e-4, atol=1e-6,
                            max_steps=4096)
    assert bool(st.converged)
    zT_ref, st_ref = solve_adaptive(drift, diffusion, params, z0, bm,
                                    0.0, 1.0, solver=solver, rtol=1e-5,
                                    atol=1e-7, max_steps=4096)
    assert bool(st_ref.converged)
    np.testing.assert_allclose(np.asarray(zT), np.asarray(zT_ref),
                               rtol=2e-3, atol=2e-3)


def test_budget_exhaustion_is_loud(key):
    """A budget-exhausted adaptive solve sits at t_final < t1: solve()
    NaN-poisons it (both gradient modes) instead of passing it off as
    z_T; solve_adaptive reports it gracefully via stats.converged."""
    params, drift, diffusion = _ou()
    z0 = jnp.ones((4,), jnp.float32)
    bm = BrownianPath(key, 0.0, 1.0, (4,), jnp.float32)
    tight = dict(rtol=1e-6, atol=1e-8, max_steps=8)

    zT, st = solve_adaptive(drift, diffusion, params, z0, bm, 0.0, 1.0,
                            solver="reversible_heun", **tight)
    assert not bool(st.converged) and float(st.t_final) < 1.0
    assert bool(jnp.all(jnp.isfinite(zT)))  # graceful: raw state + stats

    for mode in ("discretise", "reversible_adjoint"):
        out = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 16,
                    solver="reversible_heun", gradient_mode=mode,
                    save_trajectory=False, adaptive=True, **tight)
        assert bool(jnp.all(jnp.isnan(out))), mode  # loud

    # and a CONVERGED solve is untouched by the poisoning select
    ok = solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 16,
               solver="reversible_heun", gradient_mode="reversible_adjoint",
               save_trajectory=False, adaptive=True, rtol=1e-2, atol=1e-4,
               max_steps=1024)
    assert bool(jnp.all(jnp.isfinite(ok)))


# -----------------------------------------------------------------------------
# pathwise consistency across rejection
# -----------------------------------------------------------------------------


def test_evaluate_consistent_across_rejected_then_halved_step(key):
    """The rejection contract: when the controller rejects ``[t, t+dt)``
    and retries ``[t, t+dt/2)`` + ``[t+dt/2, t+dt)``, all three queries
    come from the SAME underlying path — the full-step increment equals
    the sum of the halves (and value/evaluate agree bitwise)."""
    jax.config.update("jax_enable_x64", True)
    try:
        bm = BrownianPath(key, 0.0, 1.0, (5,), jnp.float64)
        # controller-shaped points: non-dyadic t, then a halved retry
        for t, dt in ((0.137, 0.25), (0.5, 0.113), (0.93, 0.07)):
            full = np.asarray(bm.evaluate(t, t + dt))
            half1 = np.asarray(bm.evaluate(t, t + dt / 2))
            half2 = np.asarray(bm.evaluate(t + dt / 2, t + dt))
            np.testing.assert_allclose(half1 + half2, full, atol=1e-12)
            # the driver's value-carry form is bitwise the evaluate form
            np.testing.assert_array_equal(
                np.asarray(bm.value(t + dt) - bm.value(t)), full)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_dense_path_evaluate_matches_increment_sums(key):
    """DenseBrownianPath.evaluate is pathwise-consistent with the fixed
    grids: at fine-node times it telescopes the SAME fine increments the
    uniform solves consume, and it is exactly additive in between."""
    jax.config.update("jax_enable_x64", True)
    try:
        fine = 64
        bm = DenseBrownianPath.sample(key, 0.0, 1.0, fine, (3,), jnp.float64)
        # fine-node queries == increment sums
        for i, j in ((0, 8), (8, 24), (17, 61)):
            via_eval = np.asarray(bm.evaluate(i / fine, j / fine))
            via_inc = sum(np.asarray(bm.increment(jnp.int32(k), fine))
                          for k in range(i, j))
            np.testing.assert_allclose(via_eval, via_inc, atol=1e-12)
        # additivity at non-node points (the linear-interp region)
        s, m, t = 0.1234, 0.37, 0.7921
        np.testing.assert_allclose(
            np.asarray(bm.evaluate(s, m) + bm.evaluate(m, t)),
            np.asarray(bm.evaluate(s, t)), atol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_adaptive_on_dense_path_converges_to_reference(key):
    """solve() adaptive mode over a DenseBrownianPath lands on the fine
    reference as the tolerance tightens (same sample path)."""
    jax.config.update("jax_enable_x64", True)
    try:
        fine = 2048
        z0 = jnp.zeros((1,), jnp.float64)
        bm = DenseBrownianPath.sample(key, 0.0, 1.0, fine, (1,), jnp.float64)
        ref = sde_solve(_burst, _burst_diffusion, None, z0, bm, 0.0, 1.0,
                        fine, solver="heun", save_trajectory=False)
        errs = []
        for rtol in (1e-2, 1e-4):
            zT, st = solve_adaptive(_burst, _burst_diffusion, None, z0, bm,
                                    0.0, 1.0, solver="reversible_heun",
                                    rtol=rtol, atol=rtol * 1e-2,
                                    max_steps=2048, dt0=1.0 / 16)
            assert bool(st.converged)
            errs.append(float(jnp.max(jnp.abs(zT - ref))))
        assert errs[1] < errs[0], errs
    finally:
        jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize("bridge_depth", [None, 10])
def test_fused_adaptive_adjoint_bitwise_matches_unfused(key, bridge_depth):
    """Accepted-grid variant of the fused gradient-exactness regression:
    adaptive solve with use_pallas_kernels=True produces the SAME bits as
    the unfused adaptive adjoint in float64 — the fused backward replay
    (kernel reconstruction + hand-derived cotangent phases) is the jax.vjp
    transpose, and the controller's accepted grid is identical because the
    fused forward is bitwise too.  A capped bridge_depth must preserve all
    of this: the backward replay descends to the SAME depth as the
    forward, so the replayed dw stays bit-identical at any setting."""
    jax.config.update("jax_enable_x64", True)
    try:
        p0 = {"shift": jnp.float64(0.1)}
        z0 = jnp.full((4,), 0.2, jnp.float64)
        bm = BrownianPath(key, 0.0, 1.0, (4,), jnp.float64)
        kw = dict(solver="reversible_heun",
                  gradient_mode="reversible_adjoint",
                  save_trajectory=False, adaptive=True,
                  rtol=1e-4, atol=1e-7, max_steps=2048,
                  bridge_depth=bridge_depth)

        def loss(p, z, fused):
            zT = solve(_burst, _burst_diffusion, p, z, bm, 0.0, 1.0, 16,
                       use_pallas_kernels=fused, **kw)
            return jnp.sum(zT ** 2)

        v_f, g_f = jax.value_and_grad(loss, argnums=(0, 1))(p0, z0, True)
        v_u, g_u = jax.value_and_grad(loss, argnums=(0, 1))(p0, z0, False)
        assert jnp.isfinite(v_f), "adaptive solve did not converge"
        np.testing.assert_array_equal(np.asarray(v_f), np.asarray(v_u))
        for a, b in zip(jax.tree.leaves(g_f), jax.tree.leaves(g_u)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg="fused adaptive gradient != unfused")
    finally:
        jax.config.update("jax_enable_x64", False)
