"""Unified SDE-solve front-end: one entry point, a solver registry, and
first-class batched multi-trajectory solving.

This is the `sdeint`-style surface the paper's pieces plug into
(cf. Li et al. 2020's ``sdeint(..., method=, adjoint=)``): callers pick a
``solver`` × ``gradient_mode`` × ``noise`` × ``precision`` combination and
:func:`solve` dispatches to the matching gradient backend
(:mod:`repro.core.gradients`):

* plain ``lax.scan`` + JAX AD (``gradient_mode="discretise"``,
  discretise-then-optimise, O(N) activation memory),
* the paper's algebraically-reversible exact adjoint
  (``"reversible_adjoint"``, O(1) memory, FP-exact gradients — §3/App. C),
* the optimise-then-discretise continuous adjoint baseline
  (``"continuous_adjoint"``, eq. (6), O(√h) gradient error),
* recursive binomial checkpointing (``"checkpoint"``, FP-exact gradients
  at O(log n) memory / O(n log n) recompute — works for every registered
  stepper, including the non-reversible ones and adaptive accepted grids).

Both sides of the dispatch are data.  Every solver is described by a
:class:`SolverSpec` in :data:`SOLVERS`: the stepper, its algebraic inverse
(when one exists), the NFE accounting the paper's Tables 1/4/5 report, the
strong order, and which gradient modes / fused-kernel paths are legal.
Every gradient mode is a :class:`~repro.core.gradients.GradientBackend` in
its own registry: a forward residual policy plus a backward rule, with
backend-specific constraints validated eagerly (``spec.gradient_modes``
names backends, so "which solver serves which mode" is a join over the two
tables — see :func:`gradient_capabilities`).  Adding a solver or a
gradient path means registering a spec or a backend, not editing dispatch
chains; an unsupported pairing raises a named error rather than producing
another solver's numerics silently.

``precision="bf16_compute"`` applies the solve-stack precision policy
(:func:`repro.core.gradients.resolve_precision`): vector-field evaluation
is cast to bf16 while solver state, Brownian increments, and adjoint
accumulators stay in the state dtype.  The wrap happens before any
backend sees the fields, so every gradient mode is mixed-precision-capable
by construction; benchmarks/gradient_error.py gates the induced gradient
error against a pinned tolerance.  The default ``"highest"`` is the
identity — bitwise the pre-policy behaviour.

``use_pallas_kernels=True`` routes the reversible-Heun hot loop through the
fused Pallas kernels (:mod:`repro.kernels.reversible_heun_step`): the
forward scan (with in-kernel Brownian generation where the path allows),
the backward's closed-form state reconstruction, AND the per-step local
VJP all run fused — the hand-derived backward kernel pair is the
derivative, registered through the reversible-adjoint ``custom_vjp``.
Because the kernels take ``dt`` as a traced scalar operand this composes
with ``adaptive=True``.  On non-TPU backends the kernels run in interpret
mode automatically.

Batched multi-trajectory solving (:func:`solve_batched`) vmaps a batch of
initial states against a batch of Brownian seeds — one fused XLA program
for the whole ensemble instead of a Python loop of solves.
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .brownian import BrownianPath, stlevy_difference
from .gradients import (
    GRADIENT_BACKENDS,
    PRECISION_POLICIES,
    available_gradient_modes,
    get_backend,
    resolve_precision,
)
from .solvers import (
    RevHeunState,
    _euler_maruyama_step,
    _heun_embedded_step,
    _heun_step,
    _midpoint_embedded_step,
    _midpoint_step,
    _srk_embedded_step,
    _srk_step,
    _tree_cast,
    reversible_heun_embedded_step,
    reversible_heun_reverse_step,
    reversible_heun_step,
)

__all__ = [
    "GRADIENT_MODES",
    "PRECISION_POLICIES",
    "SOLVERS",
    "AdaptiveStats",
    "SolverSpec",
    "available_solvers",
    "get_solver",
    "gradient_capabilities",
    "register_solver",
    "solve",
    "solve_adaptive",
    "solve_batched",
]

#: The registered gradient paths, in inventory order: the paper landscape's
#: three (§2.3/§2.4) plus recursive checkpointing.  Derived from the
#: backend registry — registering a new backend extends this tuple.
GRADIENT_MODES = available_gradient_modes()


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Registry entry describing one solver's capabilities.

    Attributes:
        name: registry key (the ``solver=`` string).
        stepper: ``(z_or_state, t, dt, dw, drift, diffusion, params, noise)``
            single-step function.
        reverse_stepper: algebraic inverse of ``stepper`` or ``None`` for
            non-reversible solvers.
        nfe_per_step: drift+diffusion evaluations per step (paper §3).
        strong_order: strong convergence order (multiplicative noise).
        gradient_modes: subset of :data:`GRADIENT_MODES` this solver serves.
        supports_pallas: whether the fused Pallas step kernels apply.
        sde_type: "ito" or "stratonovich".
        notes: one-line description (surfaced in README's inventory table).
        embedded_stepper: ``(carry, t, dt, dw, drift, diffusion, params,
            noise) -> (carry_new, err)`` embedded-pair step for adaptive
            error control, or ``None`` for solvers with no free embedded
            estimate (``adaptive=True`` is rejected for those).
        needs_levy_area: the stepper consumes ``(ΔW, ΔH)`` space–time
            Lévy-area pairs instead of plain ``ΔW`` increments; the
            Brownian path must be constructed with
            ``levy_area="space-time"`` (checked eagerly both ways).
        noise_types: noise layouts the stepper accepts; ``noise=`` values
            outside this tuple are rejected eagerly.
    """

    name: str
    stepper: Callable
    reverse_stepper: Optional[Callable]
    nfe_per_step: int
    strong_order: float
    gradient_modes: Tuple[str, ...]
    supports_pallas: bool = False
    sde_type: str = "stratonovich"
    notes: str = ""
    embedded_stepper: Optional[Callable] = None
    needs_levy_area: bool = False
    noise_types: Tuple[str, ...] = ("diagonal", "general")

    @property
    def reversible(self) -> bool:
        return self.reverse_stepper is not None


SOLVERS: dict = {}


def register_solver(spec: SolverSpec) -> SolverSpec:
    """Add (or replace) a solver spec in the registry.

    ``spec.gradient_modes`` must name registered gradient backends — the
    join the capability table (:func:`gradient_capabilities`) is built on.
    """
    for m in spec.gradient_modes:
        if m not in GRADIENT_BACKENDS:
            raise ValueError(
                f"{spec.name}: unknown gradient mode {m!r}; registered "
                f"backends: {available_gradient_modes()}")
    if "reversible_adjoint" in spec.gradient_modes and not spec.reversible:
        raise ValueError(
            f"{spec.name}: reversible_adjoint requires a reverse_stepper")
    SOLVERS[spec.name] = spec
    return spec


def get_solver(name: str) -> SolverSpec:
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered: {sorted(SOLVERS)}") from None


def available_solvers() -> Tuple[str, ...]:
    return tuple(sorted(SOLVERS))


register_solver(SolverSpec(
    "euler_maruyama", _euler_maruyama_step, None,
    nfe_per_step=1, strong_order=0.5,
    gradient_modes=("discretise", "continuous_adjoint", "checkpoint"),
    sde_type="ito", notes="order-0.5 Itô baseline"))

register_solver(SolverSpec(
    "midpoint", _midpoint_step, None,
    nfe_per_step=2, strong_order=0.5,
    gradient_modes=("discretise", "continuous_adjoint", "checkpoint"),
    notes="paper's main baseline",
    embedded_stepper=_midpoint_embedded_step))

register_solver(SolverSpec(
    "heun", _heun_step, None,
    nfe_per_step=2, strong_order=0.5,
    gradient_modes=("discretise", "continuous_adjoint", "checkpoint"),
    notes="trapezoidal",
    embedded_stepper=_heun_embedded_step))

register_solver(SolverSpec(
    "reversible_heun", reversible_heun_step, reversible_heun_reverse_step,
    nfe_per_step=1, strong_order=0.5,
    gradient_modes=("discretise", "reversible_adjoint", "checkpoint"),
    supports_pallas=True,
    notes="algebraically reversible; O(1)-memory exact adjoint (paper §3)",
    embedded_stepper=reversible_heun_embedded_step))

register_solver(SolverSpec(
    "srk", _srk_step, None,
    nfe_per_step=5, strong_order=1.5,
    gradient_modes=("discretise", "checkpoint"),
    sde_type="ito",
    notes="strong-order-1.5 SRK (Kloeden–Platen) on (W, H) space–time "
          "Lévy-area pairs; diagonal noise",
    embedded_stepper=_srk_embedded_step,
    needs_levy_area=True,
    noise_types=("diagonal",)))


def gradient_capabilities() -> dict:
    """The capability table: ``gradient_mode -> tuple of solver names``.

    The join of the two registries, in backend-inventory order — this is
    what gradient-mode error messages and the README inventory are built
    from, so both always reflect what is actually registered.
    """
    return {
        mode: tuple(s.name for s in SOLVERS.values()
                    if mode in s.gradient_modes)
        for mode in available_gradient_modes()
    }


def _validate(spec: SolverSpec, gradient_mode: str, noise: str,
              use_pallas_kernels: bool, save_trajectory: bool,
              adaptive: bool = False) -> None:
    backend = get_backend(gradient_mode)  # unknown mode: lists the registry
    if gradient_mode not in spec.gradient_modes:
        raise ValueError(
            f"solver {spec.name!r} does not support gradient_mode="
            f"{gradient_mode!r} (supported: {spec.gradient_modes}; solvers "
            f"serving {gradient_mode!r}: "
            f"{gradient_capabilities()[gradient_mode]})")
    if noise not in ("diagonal", "general"):
        raise ValueError(f"unknown noise type {noise!r}")
    if noise not in spec.noise_types:
        raise ValueError(
            f"solver {spec.name!r} supports noise={spec.noise_types}, got "
            f"{noise!r} (the order-1.5 scheme needs full Lévy areas for "
            f"general noise, which space-time H does not provide)")
    if use_pallas_kernels:
        if not spec.supports_pallas:
            raise ValueError(
                f"solver {spec.name!r} has no fused Pallas path "
                f"(only: {[s.name for s in SOLVERS.values() if s.supports_pallas]})")
        if noise != "diagonal":
            raise ValueError(
                "use_pallas_kernels requires diagonal noise (the fused "
                "kernels are elementwise; general noise needs an einsum)")
    if adaptive:
        if spec.embedded_stepper is None:
            raise ValueError(
                f"solver {spec.name!r} has no embedded error estimate, so "
                f"adaptive=True has nothing to control the step size with "
                f"(embedded pairs: "
                f"{[s.name for s in SOLVERS.values() if s.embedded_stepper is not None]}"
                f"); use a fixed grid or switch solver")
        if save_trajectory:
            raise ValueError(
                "adaptive=True accepts steps on a solver-chosen non-uniform "
                "grid, which save_trajectory's fixed (num_steps+1)-point "
                "output grid cannot represent — call solve(..., "
                "save_trajectory=False) for the terminal value (or "
                "solve_adaptive for the accepted-grid stats)")
    # backend-specific constraints (terminal-only outputs, pallas
    # compatibility, backward-integrator coverage, ...) live with the
    # backend — adaptive × use_pallas_kernels in general is legal: the
    # fused step kernels take dt as a traced scalar operand, so the
    # controller's per-attempt dt flows straight into the kernels.
    if backend.validate is not None:
        backend.validate(spec, noise=noise, save_trajectory=save_trajectory,
                         use_pallas=use_pallas_kernels, adaptive=adaptive)


# =============================================================================
# Adaptive stepping: PI-controlled accept/reject driver (DESIGN.md §10)
# =============================================================================

#: PI step-size controller gains (Gustafsson; DESIGN.md §10).  With the
#: normalised error ratio r_n (accept iff r_n <= 1) the next step is
#:   dt' = dt * clip(SAFETY * r_n^-BETA1 * r_prev^BETA2, FMIN, FMAX)
#: where r_prev is the ratio of the last *accepted* step.  BETA1 = kI + kP
#: and BETA2 = kP with kI = 0.3/k, kP = 0.4/k for embedded-pair order k = 2.
_PI_SAFETY = 0.9
_PI_BETA1 = 0.35
_PI_BETA2 = 0.2
_PI_FACTOR_MIN = 0.2
_PI_FACTOR_MAX = 5.0
_MIN_ERR_RATIO = 1e-10  # a zero error estimate must not produce dt = inf


class AdaptiveStats(NamedTuple):
    """Controller diagnostics of one adaptive solve (all in-graph arrays).

    ``dts``/``ts`` are ``(max_steps,)`` scalar buffers: entry ``i <
    num_accepted`` holds accepted step ``i``'s size and left endpoint; the
    tail is zero-padding.  ``nfe`` counts drift+diffusion evaluation pairs
    including rejected attempts (the cost the paper's tables report).
    ``converged`` is False when the step budget ran out before ``t1`` —
    the terminal value then sits at ``t_final``, not ``t1``.
    """

    num_accepted: jax.Array
    num_rejected: jax.Array
    nfe: jax.Array
    t_final: jax.Array
    converged: jax.Array
    dts: jax.Array
    ts: jax.Array


def _adaptive_loop(spec, drift, diffusion, params, z0, bm, t0, t1,
                   rtol, atol, max_steps: int, dt0, noise,
                   use_pallas: bool = False,
                   bridge_depth: Optional[int] = None):
    """Bounded ``lax.while_loop`` accept/reject driver.

    Brownian increments come from ``bm.evaluate(t, t + dt)`` — arbitrary-
    interval queries on ONE underlying sample path, so a rejected step and
    its halved retry see pathwise-consistent noise (the Lévy-bridge
    conditioning of the paper's eq. (8)).  ``bridge_depth`` caps the dyadic
    descent of those queries (paths that take a ``depth`` argument only);
    ``None`` keeps each path's own default.  The loop runs at most
    ``2 * max_steps`` iterations (``max_steps`` accepts + ``max_steps``
    rejects); if the budget is exhausted the solve stops early and
    ``stats.converged`` is False.

    Returns ``(final_carry, AdaptiveStats)``.  The accepted ``(ts, dts)``
    scalars are the replay contract consumed by the exact adjoint
    (repro.core.adjoint): the backward pass re-derives every accepted
    step's ``(t, dt, dw)`` bit-identically from them.
    """
    dtype = z0.dtype
    step = spec.embedded_stepper
    rev = spec.stepper is reversible_heun_step
    if use_pallas and rev:
        # fused state updates; legal because dt rides into the kernels as a
        # traced scalar operand (see repro.kernels.reversible_heun_step)
        step = functools.partial(step, use_pallas=True)
    if rev:
        carry0 = RevHeunState(z0, z0, drift(params, t0, z0),
                              diffusion(params, t0, z0))
        get_z = lambda c: c.z
    else:
        carry0 = z0
        get_z = lambda c: c
    rtol = jnp.asarray(rtol, dtype)
    atol = jnp.asarray(atol, dtype)
    t1a = jnp.asarray(t1, dtype)
    zeros = jnp.zeros((max_steps,), dtype)
    # Carrying W(t_left) halves the per-attempt Brownian cost when the path
    # offers single-point queries: one bridge descent (the right endpoint)
    # instead of evaluate's two.  Relies on the documented contract
    # ``evaluate(s, t) == value(t) - value(s)`` bitwise, which keeps the
    # backward replay (via evaluate) bit-identical to the forward.
    has_value = hasattr(bm, "value")
    # space-time mode: single-point queries return (W(t), H_{t0,t}) pairs;
    # the interval pair is recovered through the SAME op graph evaluate()
    # uses (stlevy_difference), so the backward replay stays bit-identical.
    levy = getattr(bm, "levy_area", None) == "space-time"
    dkw = {} if bridge_depth is None else {"depth": bridge_depth}
    w_left0 = (_tree_cast(bm.value(t0, **dkw), dtype) if has_value
               else jnp.zeros((), dtype))
    state0 = (carry0, jnp.asarray(t0, dtype), jnp.asarray(dt0, dtype),
              jnp.asarray(1.0, dtype), jnp.asarray(0, jnp.int32),
              jnp.asarray(0, jnp.int32), zeros, zeros, w_left0,
              jnp.asarray(False))

    def cond(s):
        _, _, _, _, n_acc, n_rej, _, _, _, done = s
        return (~done) & (n_acc < max_steps) & (n_rej < max_steps)

    def body(s):
        carry, t, dt, prev_ratio, n_acc, n_rej, dts, ts, w_left, done = s
        # ``done`` lanes only arise under vmap (the batched while_loop keeps
        # stepping finished lanes until every lane finishes) — guard them.
        active = ~done
        remaining = t1a - t
        is_last = dt >= remaining
        dt_eff = jnp.minimum(dt, remaining)
        if has_value:
            w_right = _tree_cast(bm.value(t + dt_eff, **dkw), dtype)
            if levy:
                dw = stlevy_difference(w_left, w_right, t, t + dt_eff, bm.t0)
            else:
                dw = w_right - w_left
        else:
            w_right = w_left
            dw = _tree_cast(bm.evaluate(t, t + dt_eff, **dkw), dtype)
        cand, err = step(carry, t, dt_eff, dw, drift, diffusion, params, noise)
        scale = atol + rtol * jnp.maximum(jnp.abs(get_z(carry)),
                                          jnp.abs(get_z(cand)))
        ratio = jnp.sqrt(jnp.mean(jnp.square(err / scale)))
        ratio = jnp.maximum(ratio, _MIN_ERR_RATIO)
        accept = (ratio <= 1.0) & active
        # PI controller; a rejected step must shrink (safety < 1 and both
        # ratio powers <= 1 there), an accepted one may grow up to FMAX.
        factor = _PI_SAFETY * ratio ** (-_PI_BETA1) * prev_ratio ** _PI_BETA2
        factor = jnp.clip(factor, _PI_FACTOR_MIN, _PI_FACTOR_MAX)
        factor = jnp.where(accept, factor, jnp.minimum(factor, 1.0))
        carry_new = jax.tree.map(lambda a, b: jnp.where(accept, a, b),
                                 cand, carry)
        dts = dts.at[n_acc].set(jnp.where(accept, dt_eff, dts[n_acc]))
        ts = ts.at[n_acc].set(jnp.where(accept, t, ts[n_acc]))
        return (carry_new,
                jnp.where(accept, jnp.where(is_last, t1a, t + dt_eff), t),
                jnp.where(active, dt_eff * factor, dt),
                jnp.where(accept, ratio, prev_ratio),
                n_acc + accept.astype(jnp.int32),
                n_rej + (active & ~accept).astype(jnp.int32),
                dts, ts,
                jax.tree.map(lambda a, b: jnp.where(accept, a, b),
                             w_right, w_left),
                done | (accept & is_last))

    carry, t, _, _, n_acc, n_rej, dts, ts, _, done = lax.while_loop(
        cond, body, state0)
    nfe = (n_acc + n_rej) * spec.nfe_per_step + (1 if rev else 0)
    stats = AdaptiveStats(n_acc, n_rej, nfe, t, done, dts, ts)
    return carry, stats


def _check_levy_area(spec: SolverSpec, bm) -> None:
    """(W, H)-pair solvers need a space-time path, and vice versa — eagerly.

    A mismatch either way would fail deep inside a scan (tuple vs array
    ``dw``) or, worse for the None-mode direction, silently feed a ``(W,
    H)`` tuple into steppers written for bare ``ΔW``.
    """
    mode = getattr(bm, "levy_area", None)
    if spec.needs_levy_area and mode != "space-time":
        raise ValueError(
            f"solver {spec.name!r} consumes (W, H) space-time Lévy-area "
            f"pairs — construct the Brownian path with "
            f"levy_area='space-time' (got levy_area={mode!r} on "
            f"{type(bm).__name__})")
    if not spec.needs_levy_area and mode == "space-time":
        raise ValueError(
            f"solver {spec.name!r} consumes plain ΔW increments but the "
            f"Brownian path was built with levy_area='space-time' — drop "
            f"the flag (solvers consuming (W, H) pairs: "
            f"{[s.name for s in SOLVERS.values() if s.needs_levy_area]})")


def _check_adaptive_bm(bm) -> None:
    if not hasattr(bm, "evaluate"):
        raise ValueError(
            f"adaptive=True queries Brownian increments over solver-chosen "
            f"intervals via bm.evaluate(s, t); {type(bm).__name__} has no "
            f"evaluate method — use BrownianPath, VirtualBrownianTree or "
            f"DenseBrownianPath")


def _check_bridge_depth(bm, bridge_depth) -> None:
    if bridge_depth is None:
        return
    if not (isinstance(bridge_depth, int) and bridge_depth >= 1):
        raise ValueError(
            f"bridge_depth must be a positive int (dyadic descent levels), "
            f"got {bridge_depth!r}")
    probe = bm.value if hasattr(bm, "value") else bm.evaluate
    if "depth" not in inspect.signature(probe).parameters:
        raise ValueError(
            f"bridge_depth requires a Brownian path whose point queries "
            f"take a depth argument (BrownianPath); {type(bm).__name__} "
            f"has a fixed resolution — drop bridge_depth")


def solve_adaptive(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    t0: float,
    t1: float,
    *,
    solver: str = "reversible_heun",
    rtol: float = 1e-3,
    atol: float = 1e-6,
    max_steps: int = 4096,
    dt0: Optional[float] = None,
    noise: str = "diagonal",
    bridge_depth: Optional[int] = None,
    precision: str = "highest",
):
    """Adaptive solve returning ``(z_T, AdaptiveStats)``.

    The diagnostics-bearing sibling of ``solve(..., adaptive=True)``:
    benchmarks read NFE and the accepted grid off the stats.  Forward
    simulation only — for gradients call :func:`solve` with
    ``gradient_mode="reversible_adjoint"`` or ``"checkpoint"`` (the stats
    buffers live inside the backend's residuals there).
    """
    spec = get_solver(solver)
    _validate(spec, "discretise", noise, False, False, adaptive=True)
    _check_levy_area(spec, bm)
    _check_adaptive_bm(bm)
    _check_bridge_depth(bm, bridge_depth)
    drift, diffusion = resolve_precision(precision).wrap_fields(
        drift, diffusion)
    if dt0 is None:
        dt0 = (t1 - t0) / 16
    carry, stats = _adaptive_loop(spec, drift, diffusion, params, z0, bm,
                                  t0, t1, rtol, atol, max_steps, dt0, noise,
                                  bridge_depth=bridge_depth)
    z = carry.z if spec.stepper is reversible_heun_step else carry
    return z, stats


def solve(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    bm: BrownianPath,
    t0: float,
    t1: float,
    num_steps: int,
    *,
    solver: str = "reversible_heun",
    gradient_mode: str = "discretise",
    noise: str = "diagonal",
    save_trajectory: bool = True,
    use_pallas_kernels: bool = False,
    adaptive: bool = False,
    rtol: Optional[float] = None,
    atol: Optional[float] = None,
    max_steps: Optional[int] = None,
    dt0: Optional[float] = None,
    bridge_depth: Optional[int] = None,
    precision: str = "highest",
):
    """Solve ``dZ = μ_θ dt + σ_θ ∘ dW`` on ``[t0, t1]`` in ``num_steps`` steps.

    The single front door to the solver subsystem::

        traj = repro.solve(drift, diffusion, params, z0, bm, 0.0, 1.0, 64,
                           solver="reversible_heun",
                           gradient_mode="reversible_adjoint")

    Args:
        drift: ``(params, t, z) -> dz/dt`` (shape of ``z``).
        diffusion: ``(params, t, z) -> σ`` — shape of ``z`` for diagonal
            noise, ``(*z.shape, w)`` for general noise.
        params: pytree of parameters passed to both vector fields.
        z0: initial state.
        bm: Brownian sample path (:class:`repro.core.brownian.BrownianPath`
            or anything exposing ``increment(n, num_steps)``).
        t0, t1, num_steps: uniform time grid.
        solver: registry key — see :func:`available_solvers`.
        gradient_mode: "discretise" (AD through the scan, O(N) memory),
            "reversible_adjoint" (paper's exact O(1)-memory adjoint),
            "continuous_adjoint" (optimise-then-discretise baseline), or
            "checkpoint" (recursive binomial checkpointing: exact
            gradients for every registered solver at O(log n) memory).
        noise: "diagonal" or "general".
        save_trajectory: return the full ``(num_steps+1, *z0.shape)``
            trajectory (index 0 is ``z0``) instead of the terminal value.
            Must be ``False`` for the terminal-only gradient modes
            ("continuous_adjoint", "checkpoint") and for adaptive mode
            (the accepted grid is non-uniform).
        use_pallas_kernels: fuse the reversible-Heun per-step pipeline
            through the Pallas kernels — state updates, in-kernel Brownian
            generation (fixed-grid ``BrownianPath``), and the hand-derived
            backward cotangent phases (diagonal noise; forbidden with
            "discretise", whose plain AD cannot trace ``pallas_call`` —
            the fused derivative lives in the reversible-adjoint
            ``custom_vjp``).  Composes with ``adaptive=True``: dt is a
            traced kernel operand.
        adaptive: embedded-error-controlled stepping (DESIGN.md §10)
            instead of the fixed ``num_steps`` grid.  ``num_steps`` then
            only seeds the initial step ``dt0 = (t1-t0)/num_steps`` and the
            default budget ``max_steps``.  Requires a solver with an
            embedded pair (every registered solver except euler_maruyama)
            and a ``bm`` with arbitrary-interval ``evaluate``.  Gradients:
            ``"reversible_adjoint"`` replays the accepted grid exactly;
            ``"checkpoint"`` freezes the accepted grid under
            ``stop_gradient`` and differentiates a rematerialised replay;
            ``"discretise"`` is forward-only (``lax.while_loop`` has no
            reverse-mode rule); ``"continuous_adjoint"`` is rejected.
        rtol, atol: accept tolerance (defaults 1e-3 / 1e-6) — a step is
            accepted when the RMS of ``err / (atol + rtol * max(|z|,
            |z'|))`` is <= 1.  May be traced scalars (e.g. a per-request
            tolerance in serving).  Passing either without
            ``adaptive=True`` is an error — a fixed-grid solve would
            silently ignore the requested tolerance.
        max_steps: accepted-step budget (also bounds rejections); the
            backward replay buffers are ``(max_steps,)`` scalars.
            Defaults to ``max(4 * num_steps, 256)``.  A budget-exhausted
            solve returns **NaN** (its state sits at ``t_final < t1``,
            which must not pass silently as ``z_T``) — raise ``max_steps``
            or loosen the tolerance, or use :func:`solve_adaptive` to
            observe ``stats.converged`` gracefully.
        dt0: initial step size; defaults to ``(t1 - t0) / num_steps``.
        bridge_depth: cap on the dyadic Lévy-bridge descent of each
            adaptive Brownian query (``BrownianPath`` only; adaptive mode
            only).  The default (``None``) keeps the path's own depth-24
            resolution.  Each level costs one conditional-normal draw per
            attempted step, so on CPU the descent dominates adaptive wall
            clock; a solve run to tolerance ``rtol`` only needs the bridge
            residual — std ``<= 0.5 * 2^(-depth/2)`` in units of
            ``sqrt(t1-t0)`` — to sit well below ``rtol``, e.g. depth 10
            gives 1.6e-2, which scaled by a diffusion of 0.05 is ~8e-4 of
            state per unit time, comfortably inside a 2e-3 tolerance.  The
            SAME depth is used by the exact adjoint's backward replay, so
            replay stays bit-identical to the forward at any setting.
            Truncating the descent is a controlled approximation of the
            sample path — convergence-order studies should keep the
            default.
        precision: "highest" (default — fields run in the state dtype,
            bitwise the pre-policy behaviour) or "bf16_compute" (the
            mixed-precision policy: vector-field evaluation in bf16,
            solver state / Brownian increments / adjoint accumulators in
            the state dtype).  Applied before the gradient backend sees
            the fields, so it composes with every ``gradient_mode``.

    Returns:
        Trajectory or terminal value, differentiable w.r.t. ``params`` and
        ``z0`` according to ``gradient_mode``.

    The serving sampler contract: every adaptive *batch* sampler built on
    this subsystem (``repro.core.sde.generator_sample_terminal``, exposed
    per-bucket via ``repro.launch.steps.make_adaptive_terminal_step``)
    returns a ``(samples, converged)`` pair — ``samples`` of shape
    ``(batch, data_dim)`` and ``converged`` a ``(batch,)`` bool marking
    rows whose controller reached ``t1`` within ``max_steps``.
    Non-converged rows carry the state at ``t_final < t1`` (NOT NaN — the
    serving tier must return *something* to the client) and the flag rides
    back structurally on ``repro.serving.ServeResult.converged``.  For
    single-solve diagnostics (NFE, acceptance counts, the accepted grid)
    use :func:`solve_adaptive`, which returns the richer
    ``(z_T, repro.AdaptiveStats)`` instead.
    """
    spec = get_solver(solver)
    _validate(spec, gradient_mode, noise, use_pallas_kernels, save_trajectory,
              adaptive)
    _check_levy_area(spec, bm)
    if not adaptive and any(
            v is not None for v in (rtol, atol, max_steps, dt0,
                                    bridge_depth)):
        raise ValueError(
            "rtol/atol/max_steps/dt0/bridge_depth are adaptive-mode options "
            "but adaptive=False — pass adaptive=True (a fixed-grid solve "
            "would silently ignore the requested tolerance)")

    backend = get_backend(gradient_mode)
    # the precision policy wraps the fields BEFORE the backend sees them,
    # so adjoint replays/backsolves evaluate the same (wrapped) fields as
    # the forward; "highest" is the identity wrap
    drift, diffusion = resolve_precision(precision).wrap_fields(
        drift, diffusion)

    if adaptive:
        _check_adaptive_bm(bm)
        _check_bridge_depth(bm, bridge_depth)
        rtol = 1e-3 if rtol is None else rtol
        atol = 1e-6 if atol is None else atol
        if max_steps is None:
            max_steps = max(4 * num_steps, 256)
        if dt0 is None:
            dt0 = (t1 - t0) / num_steps
        z, converged = backend.solve_adaptive(
            spec, drift, diffusion, params, z0, bm, rtol, atol, t0, t1,
            max_steps, dt0, noise=noise, use_pallas=use_pallas_kernels,
            bridge_depth=bridge_depth)
        # a budget-exhausted solve sits at t_final < t1 — poison it rather
        # than hand back a truncated-horizon state as z_T (select-based, so
        # converged solves keep their gradient untouched); callers wanting
        # graceful access go through solve_adaptive's stats
        return jnp.where(converged, z, jnp.asarray(jnp.nan, z.dtype))

    return backend.solve(
        spec, drift, diffusion, params, z0, bm, t0, t1, num_steps,
        noise=noise, save_trajectory=save_trajectory,
        use_pallas=use_pallas_kernels)


def solve_batched(
    drift: Callable,
    diffusion: Callable,
    params,
    z0: jax.Array,
    keys: jax.Array,
    t0: float,
    t1: float,
    num_steps: int,
    *,
    w_dim: Optional[int] = None,
    **kwargs,
):
    """Vmapped multi-trajectory :func:`solve`: batch of initial states ×
    batch of Brownian seeds, as one XLA program.

    Args:
        z0: ``(B, *state_shape)`` initial states.
        keys: ``(B,)`` PRNG keys — one independent Brownian path per
            trajectory (pass ``jax.random.split(key, B)``).
        w_dim: Brownian dimension for general noise (defaults to the
            trailing state dim, i.e. diagonal layout).
        **kwargs: forwarded to :func:`solve` (solver / gradient_mode /
            noise / save_trajectory / use_pallas_kernels / adaptive /
            rtol / atol / max_steps / dt0); validated once before vmapping
            so errors surface eagerly.  With ``adaptive=True`` every
            trajectory runs its own controller (per-trajectory accepted
            grids — the batched while_loop runs until the slowest lane
            finishes).

    Returns:
        ``(B, num_steps+1, *state_shape)`` trajectories (or ``(B, *state)``
        terminal values with ``save_trajectory=False``).
    """
    if z0.ndim < 1 or keys.shape[0] != z0.shape[0]:
        raise ValueError(
            f"leading (batch) dims must agree: z0 {z0.shape} vs keys "
            f"{keys.shape}")
    spec = get_solver(kwargs.get("solver", "reversible_heun"))
    _validate(spec,
              kwargs.get("gradient_mode", "discretise"),
              kwargs.get("noise", "diagonal"),
              kwargs.get("use_pallas_kernels", False),
              kwargs.get("save_trajectory", True),
              kwargs.get("adaptive", False))
    resolve_precision(kwargs.get("precision", "highest"))

    state_shape = z0.shape[1:]
    if kwargs.get("noise", "diagonal") == "general":
        if w_dim is None:
            raise ValueError("general noise needs w_dim= for the Brownian shape")
        bm_shape = state_shape[:-1] + (w_dim,)
    else:
        bm_shape = state_shape

    def single(z0_i, key_i):
        bm = BrownianPath(key_i, t0, t1, bm_shape, z0.dtype,
                          levy_area="space-time" if spec.needs_levy_area
                          else None)
        return solve(drift, diffusion, params, z0_i, bm, t0, t1, num_steps,
                     **kwargs)

    return jax.vmap(single)(z0, keys)
