"""Solver unit tests: reversibility, convergence order, ODE stability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.brownian import BrownianPath
from repro.core.solvers import (RevHeunState, reversible_heun_reverse_step,
                                reversible_heun_step, sde_solve)


@pytest.fixture(autouse=True)
def _x64_scope():
    """These tests need f64 (FP-exactness claims); scope it to this module
    so x64 never leaks into the bf16 model tests that run later."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)



def _nets(key, x_dim=6, dtype=jnp.float64):
    from repro import nn

    k1, k2 = jax.random.split(key)
    p = {"f": nn.mlp_init(k1, [x_dim, 16, x_dim], dtype=dtype),
         "g": nn.mlp_init(k2, [x_dim, 16, x_dim], dtype=dtype)}
    drift = lambda p_, t, x: nn.mlp(p_["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p_, t, x: 0.2 * nn.mlp(p_["g"], x, nn.lipswish, jnp.tanh)
    return p, drift, diffusion


def test_algebraic_reversibility(key):
    """Forward then reverse step reconstructs the state to float precision —
    the paper's core property (Algorithm 2 'Reverse step').  The carried
    (μ, σ) must satisfy the solver invariant μ_n = μ(t_n, ẑ_n)."""
    p, drift, diffusion = _nets(key)
    z = jax.random.normal(jax.random.fold_in(key, 1), (4, 6), jnp.float64)
    zh = z + 0.01
    state = RevHeunState(z, zh, drift(p, 0.0, zh), diffusion(p, 0.0, zh))
    dt, dw = 0.05, 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (4, 6), jnp.float64)
    fwd = reversible_heun_step(state, 0.0, dt, dw, drift, diffusion, p, "diagonal")
    back = reversible_heun_reverse_step(fwd, dt, dt, dw, drift, diffusion, p, "diagonal")
    for a, b in zip(state, back):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12, atol=1e-12)


def test_reversibility_many_steps(key):
    """Reverse the whole trajectory of a 64-step solve."""
    p, drift, diffusion = _nets(key)
    z0 = jax.random.normal(jax.random.fold_in(key, 1), (3, 6), jnp.float64)
    bm = BrownianPath(jax.random.fold_in(key, 2), 0.0, 1.0, (3, 6), jnp.float64)
    n = 64
    dt = 1.0 / n
    state = RevHeunState(z0, z0, drift(p, 0.0, z0), diffusion(p, 0.0, z0))
    states = [state]
    for i in range(n):
        state = reversible_heun_step(state, i * dt, dt, bm.increment(i, n),
                                     drift, diffusion, p, "diagonal")
        states.append(state)
    for i in range(n, 0, -1):
        state = reversible_heun_reverse_step(state, i * dt, dt, bm.increment(i - 1, n),
                                             drift, diffusion, p, "diagonal")
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(state, states[i - 1]))
        assert err < 1e-9, f"reverse diverged at step {i}: {err}"


@pytest.mark.parametrize("solver", ["midpoint", "heun", "reversible_heun"])
def test_strong_convergence_order(key, solver):
    """Strong order ~0.5 on a multiplicative-noise scalar SDE (Theorem D.12).

    Uses DenseBrownianPath so coarse and fine solves see the SAME path."""
    from repro.core.brownian import DenseBrownianPath

    drift = lambda p, t, y: -0.5 * y
    diffusion = lambda p, t, y: 0.5 * y
    n_paths = 2000
    y0 = jnp.ones((n_paths, 1), jnp.float64)
    bm = DenseBrownianPath.sample(key, 0.0, 1.0, 512, (n_paths, 1), jnp.float64)
    errs, hs = [], []
    fine = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, 512,
                     solver="heun", save_trajectory=False)
    for n in (8, 16, 32, 64):
        c = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, n,
                      solver=solver, save_trajectory=False)
        errs.append(float(jnp.mean(jnp.abs(c - fine))))
        hs.append(1.0 / n)
    order = np.polyfit(np.log(hs), np.log(errs), 1)[0]
    assert 0.3 < order < 1.6, f"{solver}: empirical strong order {order}"


def test_additive_noise_first_order(key):
    """Additive noise upgrades reversible Heun to strong order ~1 (Thm D.17)."""
    from repro.core.brownian import DenseBrownianPath

    drift = lambda p, t, y: jnp.sin(y)
    diffusion = lambda p, t, y: jnp.ones_like(y)
    n_paths = 2000
    y0 = jnp.ones((n_paths, 1), jnp.float64)
    bm = DenseBrownianPath.sample(key, 0.0, 1.0, 512, (n_paths, 1), jnp.float64)
    fine = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, 512,
                     solver="heun", save_trajectory=False)
    errs, hs = [], []
    for n in (8, 16, 32, 64):
        c = sde_solve(drift, diffusion, None, y0, bm, 0.0, 1.0, n,
                      solver="reversible_heun", save_trajectory=False)
        errs.append(float(jnp.mean(jnp.abs(c - fine))))
        hs.append(1.0 / n)
    order = np.polyfit(np.log(hs), np.log(errs), 1)[0]
    assert order > 0.8, f"additive-noise order {order} (expected ~1)"


def test_stability_region(key):
    """App. D.5: for y' = λy the iterates stay bounded iff λh ∈ [-i, i]."""
    from repro.core.solvers import ode_solve

    # λ = i (on the boundary, stable): λh with h=1/64 well inside [-i, i].
    lam_stable = 1j
    lam_unstable = -4.0  # real negative λ is OUTSIDE the interval [-i, i]
    for lam, should_be_bounded in ((lam_stable, True), (lam_unstable, False)):
        # complex arithmetic via 2D real system [[re, -im], [im, re]]
        A = jnp.array([[lam.real if isinstance(lam, complex) else lam,
                        -(lam.imag if isinstance(lam, complex) else 0.0)],
                       [lam.imag if isinstance(lam, complex) else 0.0,
                        lam.real if isinstance(lam, complex) else lam]], jnp.float64)
        f = lambda p, t, y: y @ A.T
        y0 = jnp.array([[1.0, 0.0]], jnp.float64)
        traj = ode_solve(f, None, y0, 0.0, 40.0, 2560, solver="reversible_heun")
        mx = float(jnp.max(jnp.abs(traj)))
        if should_be_bounded:
            assert mx < 10.0, f"λ={lam}: should be bounded, got {mx}"
        else:
            assert mx > 1e3, f"λ={lam}: should blow up, got {mx}"


def test_nfe_accounting():
    """Reversible Heun costs 1 drift+diffusion eval per step; midpoint/Heun
    cost 2 (the paper's 'computational efficiency' claim, §3)."""
    from repro.core.solvers import (NFE_PER_STEP, _heun_step, _midpoint_step)

    counts = {"n": 0}

    def drift(p, t, y):
        counts["n"] += 1
        return -y

    diffusion = lambda p, t, y: jnp.ones_like(y) * 0.1
    y = jnp.ones((1, 1))
    dw = jnp.full((1, 1), 0.1)

    counts["n"] = 0
    st = RevHeunState(y, y, drift(None, 0.0, y), diffusion(None, 0.0, y))
    counts["n"] = 0  # don't count the one-off init
    reversible_heun_step(st, 0.0, 0.1, dw, drift, diffusion, None, "diagonal")
    assert counts["n"] == NFE_PER_STEP["reversible_heun"] == 1

    counts["n"] = 0
    _midpoint_step(y, 0.0, 0.1, dw, drift, diffusion, None, "diagonal")
    assert counts["n"] == NFE_PER_STEP["midpoint"] == 2

    counts["n"] = 0
    _heun_step(y, 0.0, 0.1, dw, drift, diffusion, None, "diagonal")
    assert counts["n"] == NFE_PER_STEP["heun"] == 2
