"""Gradient-backend protocol + registry, and the solve-stack precision policy.

A :class:`GradientBackend` packages one *gradient path* through an SDE
solve as data: how the forward pass stores (or avoids storing) residuals,
and which backward rule consumes them.  ``SolverSpec.gradient_modes`` names
backends from this registry, so "which solver serves which gradient mode"
is a join over two tables — the front-end (:mod:`repro.core.solve`)
validates the pair eagerly and then dispatches to the backend, never to a
mode-string ``if``-chain.

The four built-in backends (registered by :mod:`repro.core.gradients`'s
submodules, in this order):

==================== ======================= ==========================
mode                 residual policy          backward rule
==================== ======================= ==========================
discretise           O(n) activations (scan)  JAX AD through the scan
reversible_adjoint   O(1): terminal state     algebraic reversal (Alg. 2)
continuous_adjoint   O(1): terminal value     adjoint SDE backsolve (eq. 6)
checkpoint           O(log n): segment roots  recursive rematerialisation
==================== ======================= ==========================

The precision policy rides the same layer: :func:`resolve_precision` maps
``precision="highest" | "bf16_compute"`` to a :class:`PrecisionPolicy`
whose ``wrap_fields`` casts vector-field *evaluation* to the compute dtype
while keeping solver state and adjoint accumulators in the state dtype
(the casts are linear, so cotangents come back up-cast — accumulation
never happens in bf16).  Because the wrap happens before any backend sees
the fields, every backend is mixed-precision-capable by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax.numpy as jnp

__all__ = [
    "GRADIENT_BACKENDS",
    "PRECISION_POLICIES",
    "GradientBackend",
    "PrecisionPolicy",
    "available_gradient_modes",
    "get_backend",
    "register_backend",
    "resolve_precision",
]


@dataclasses.dataclass(frozen=True)
class GradientBackend:
    """Registry entry describing one gradient path through a solve.

    Attributes:
        name: registry key (the ``gradient_mode=`` string).
        summary: one-line description (surfaced in error messages and the
            README inventory).
        terminal_only: the backward rule consumes a terminal-value
            cotangent only (``save_trajectory=True`` is rejected).
        supports_adaptive: the backend can differentiate (or at least run)
            an adaptive accepted-grid solve.
        solve: ``(spec, drift, diffusion, params, z0, bm, t0, t1,
            num_steps, *, noise, save_trajectory, use_pallas)`` fixed-grid
            entry point; returns the trajectory or terminal value.
        solve_adaptive: ``(spec, drift, diffusion, params, z0, bm, rtol,
            atol, t0, t1, max_steps, dt0, *, noise, use_pallas,
            bridge_depth) -> (z_T, converged)`` adaptive entry point, or
            ``None`` when ``supports_adaptive`` is False.
        validate: backend-specific eager checks, called by the front-end
            after its generic ones; raises ``ValueError`` with a named
            reason.  ``None`` means no extra constraints.
    """

    name: str
    summary: str
    terminal_only: bool
    supports_adaptive: bool
    solve: Callable
    solve_adaptive: Optional[Callable] = None
    validate: Optional[Callable] = None


#: gradient_mode -> GradientBackend, in registration order (the order is
#: the user-facing inventory order, so keep the classic three first).
GRADIENT_BACKENDS: dict = {}


def register_backend(backend: GradientBackend) -> GradientBackend:
    """Add (or replace) a gradient backend in the registry."""
    if backend.supports_adaptive and backend.solve_adaptive is None:
        raise ValueError(
            f"{backend.name}: supports_adaptive=True needs a solve_adaptive")
    GRADIENT_BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> GradientBackend:
    try:
        return GRADIENT_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown gradient_mode {name!r}; registered backends: "
            f"{available_gradient_modes()}") from None


def available_gradient_modes() -> Tuple[str, ...]:
    return tuple(GRADIENT_BACKENDS)


# =============================================================================
# Precision policy (bf16 compute / f32 state)
# =============================================================================

PRECISION_POLICIES = ("highest", "bf16_compute")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """How vector-field evaluation relates to the solver-state dtype.

    ``compute_dtype=None`` ("highest") evaluates the fields in the state
    dtype untouched — the wrap is the identity, so the default path is
    bitwise unchanged.  A concrete ``compute_dtype`` (bf16) down-casts
    parameters and state *for the field evaluation only*; the output is
    cast back to the state dtype, so the solver state, the Brownian path,
    and every adjoint accumulator stay full-precision.
    """

    name: str
    compute_dtype: Optional[jnp.dtype] = None

    def wrap_fields(self, drift: Callable, diffusion: Callable):
        if self.compute_dtype is None:
            return drift, diffusion
        from ...kernels import ops

        return (ops.wrap_vector_field(drift, self.compute_dtype),
                ops.wrap_vector_field(diffusion, self.compute_dtype))


def resolve_precision(precision) -> PrecisionPolicy:
    """``precision=`` string (or ready policy) -> :class:`PrecisionPolicy`."""
    if isinstance(precision, PrecisionPolicy):
        return precision
    if precision == "highest":
        return PrecisionPolicy("highest", None)
    if precision == "bf16_compute":
        return PrecisionPolicy("bf16_compute", jnp.bfloat16)
    raise ValueError(
        f"unknown precision {precision!r}; one of {PRECISION_POLICIES}")
