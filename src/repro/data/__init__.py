from .synthetic import (  # noqa: F401
    air_quality_like,
    ou_process,
    sgd_weights_like,
    token_batches,
)
