"""Hot-loadable multi-model registry with elastic AOT pools
(DESIGN.md §11/§14).

N named checkpoints live in ONE serving process: each
:class:`LoadedModel` is a params-only restore of one ``repro-serving/v2``
bundle entry (v1 bundles upgrade transparently to a single ``"default"``
entry — :func:`repro.checkpoint.load_serving_manifest`), and every
AOT-compiled program the schedulers build is cached here keyed by
``(model_id, kind, bucket)`` — unloading a model drops its params AND its
compile pool, loading a new checkpoint under a fresh id never touches the
programs already serving traffic.

The pools are **elastic** (PR 10): each cached program's footprint is
read from XLA's ``memory_analysis()`` at compile time, and under a
``pool_budget_bytes`` cap (CLI ``--pool-budget-mb``) the registry evicts
cold ``(model_id, kind, bucket)`` entries least-recently-used until the
pool fits.  Eviction is transparent: the next request for an evicted
program re-compiles it through the same memoised :meth:`compiled` path,
and because compilation is deterministic for a fixed (program, shapes),
an evicted-then-recompiled rollout is bitwise the uncached one
(tests/test_serving_async.py pins this).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt


def _program_bytes(compiled) -> int:
    """A compiled program's resident footprint: generated code + argument
    + output + temp bytes from XLA's ``memory_analysis()``.  Returns 0
    when the backend cannot report (then the budget can never trip —
    eviction fails open rather than guessing)."""
    try:
        mem = compiled.memory_analysis()
    except Exception:  # noqa: BLE001 — backend-dependent, absence is fine
        return 0
    total = 0
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes"):
        try:
            total += int(getattr(mem, field, 0) or 0)
        except TypeError:
            pass
    return total


def _build_cfg(workload: str, config: dict):
    """Rebuild the model config dataclass from the bundle's JSON dict."""
    from ..core.sde import LatentSDEConfig, NeuralSDEConfig

    cls = NeuralSDEConfig if workload == "sde-gan" else LatentSDEConfig
    d = dict(config)
    d["dtype"] = jnp.dtype(d.get("dtype", "float32"))
    try:
        return cls(**d)
    except TypeError as e:
        raise ValueError(
            f"serving bundle config does not match {cls.__name__} — written "
            f"by an incompatible code version ({e})") from e


def _init_params(workload: str, cfg, seed: int):
    """Parameter template (and fresh-init values) for a workload's bundle."""
    from ..core.sde import generator_init, latent_sde_init

    key = jax.random.PRNGKey(seed)
    if workload == "sde-gan":
        return generator_init(key, cfg)  # serving needs the generator only
    return latent_sde_init(key, cfg)


@dataclasses.dataclass
class LoadedModel:
    """One registry entry: a named, servable checkpoint.

    ``hints`` carries the bundle's optional per-model ``"serving"`` dict
    (e.g. ``{"quota": 4}`` — see ``save_serving_registry``); schedulers
    read it as a default for per-model admission quotas, and an explicit
    ``Scheduler(quota=...)`` always wins over it.
    """

    model_id: str
    workload: str
    cfg: object
    params: object
    step: int = 0
    hints: dict = dataclasses.field(default_factory=dict)


def load_model(ckpt_dir, model_id: Optional[str] = None,
               step: Optional[int] = None) -> LoadedModel:
    """Restore ONE named model from a serving bundle -> :class:`LoadedModel`.

    ``model_id=None`` picks the bundle's sole entry (erroring by name on a
    multi-entry bundle).  This is the public single-model loader —
    :meth:`ModelRegistry.load` restores every entry of a bundle at once.
    """
    meta, _ = ckpt.load_serving_manifest(ckpt_dir)
    entries = {m["model_id"]: m for m in meta["models"]}
    if model_id is None:
        if len(entries) != 1:
            raise ValueError(
                f"serving bundle under {ckpt_dir} carries "
                f"{len(entries)} model entries ({sorted(entries)}); pass "
                f"model_id= to pick one")
        model_id = next(iter(entries))
    if model_id not in entries:
        raise ValueError(
            f"serving bundle under {ckpt_dir} has no model {model_id!r} "
            f"(entries: {sorted(entries)})")
    entry = entries[model_id]
    cfg = _build_cfg(entry["workload"], entry["config"])
    params, got = ckpt.restore_serving_model(
        ckpt_dir, _init_params(entry["workload"], cfg, 0), model_id,
        step=step)
    return LoadedModel(model_id, entry["workload"], cfg, params, got,
                       hints=dict(entry.get("serving") or {}))


def restore_for_serving(workload: str, ckpt_dir: str):
    """PR 4-compatible handshake + restore: ``(params, cfg, step)``.

    Single-model bundles only; the restored workload must match the asked
    one (named mismatch, never a pytree shape error)."""
    model = load_model(ckpt_dir)
    if model.workload != workload:
        raise ValueError(
            f"serving bundle under {ckpt_dir} was trained for workload "
            f"{model.workload!r}, not {workload!r} — point --ckpt-dir "
            f"at a matching run or change --workload")
    return model.params, model.cfg, model.step


class ModelRegistry:
    """The in-process model table: ``model_id -> LoadedModel`` plus the
    per-model AOT compile pools.

    Hot-loading contract: :meth:`load`/:meth:`register` may be called
    while other models are serving — compiled programs are cached lazily
    per ``(model_id, kind, bucket)``, so a new model's first batch pays
    its compiles and nobody else's cache is invalidated.  :meth:`unload`
    drops a model's params and every pool entry keyed to it.

    Elastic-pool contract: with ``pool_budget_bytes`` set, the pool is an
    LRU — every :meth:`compiled` hit refreshes its entry, and inserting a
    program that pushes :meth:`pool_bytes` past the budget evicts the
    coldest entries first (the entry just inserted is never evicted, so a
    program too big for the budget still serves).  Eviction never changes
    results: the recompiled program is bitwise the evicted one.
    ``evictions`` / ``compiles`` counters are public for tests and
    benchmarks to assert the cache actually cycled.
    """

    def __init__(self, pool_budget_bytes: Optional[int] = None):
        if pool_budget_bytes is not None and pool_budget_bytes <= 0:
            raise ValueError(
                f"pool_budget_bytes must be positive (got "
                f"{pool_budget_bytes}); pass None for an unbounded pool")
        self._models: dict = {}
        # (model_id, kind, bucket) -> (compiled, nbytes); ordered cold->hot.
        self._pools: "collections.OrderedDict" = collections.OrderedDict()
        self.pool_budget_bytes = pool_budget_bytes
        #: Programs dropped under the budget / total builder() calls.
        self.evictions = 0
        self.compiles = 0

    # -- the model table ----------------------------------------------------

    def register(self, model: LoadedModel, replace: bool = False) -> str:
        """Add a model under its id (``replace=True`` to hot-swap — the
        stale compile pool is dropped with the old params)."""
        if model.model_id in self._models and not replace:
            raise ValueError(
                f"model {model.model_id!r} is already registered "
                f"(ids: {sorted(self._models)}); unload it or pass "
                f"replace=True to hot-swap")
        if model.model_id in self._models:
            self.unload(model.model_id)
        self._models[model.model_id] = model
        return model.model_id

    def load(self, ckpt_dir, step: Optional[int] = None,
             replace: bool = False) -> tuple:
        """Restore EVERY entry of a serving bundle into the registry.

        Returns the tuple of loaded model ids.  A v1 bundle contributes
        its single upgraded ``"default"`` entry."""
        meta, _ = ckpt.load_serving_manifest(ckpt_dir)
        ids = []
        for entry in meta["models"]:
            ids.append(self.register(
                load_model(ckpt_dir, entry["model_id"], step=step),
                replace=replace))
        return tuple(ids)

    def unload(self, model_id: str) -> None:
        """Drop a model's params AND every compile-pool entry keyed to it
        (errors by name on unknown ids)."""
        if model_id not in self._models:
            raise ValueError(f"model {model_id!r} is not registered "
                             f"(ids: {sorted(self._models)})")
        del self._models[model_id]
        for key in [k for k in self._pools if k[0] == model_id]:
            del self._pools[key]

    def get(self, model_id: str) -> LoadedModel:
        """Look up a registered model by id, erroring by name (listing the
        registered ids) rather than raising a bare ``KeyError``."""
        try:
            return self._models[model_id]
        except KeyError:
            raise ValueError(
                f"no model {model_id!r} in the registry (ids: "
                f"{sorted(self._models)}); load a bundle or register a "
                f"model first") from None

    def ids(self) -> tuple:
        """The registered model ids, sorted (stable across runs)."""
        return tuple(sorted(self._models))

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._models

    # -- the compile pools --------------------------------------------------

    def compiled(self, model_id: str, kind: str, bucket: int,
                 builder: Callable, verbose: bool = True):
        """Memoised AOT compile keyed ``(model_id, kind, bucket)``.

        ``builder()`` must return the compiled program (the caller owns
        ``jit(...).lower(...).compile()`` — the registry only owns the
        cache and its keying).  ``kind`` names the program family
        (``"sample"``, ``"init"``, ``"chunk"``, ``"terminal"``) so one
        model's families never collide on a bucket size.

        Under a pool budget this is also the LRU touch point: a hit
        refreshes the entry, a miss compiles, records the program's
        ``memory_analysis()`` bytes, and evicts cold entries until the
        pool fits (see the class docstring; an evicted key just lands
        back here as a miss)."""
        self.get(model_id)  # unknown ids fail by name, not a silent pool
        key = (model_id, kind, bucket)
        if key not in self._pools:
            t0 = time.perf_counter()
            compiled = builder()
            self.compiles += 1
            self._pools[key] = (compiled, _program_bytes(compiled))
            if verbose:
                print(f"[serve] compiled {model_id}/{kind} bucket {bucket} "
                      f"in {time.perf_counter() - t0:.2f}s", flush=True)
            self._evict(protect=key, verbose=verbose)
        self._pools.move_to_end(key)  # LRU touch: hottest at the end
        return self._pools[key][0]

    def _evict(self, protect, verbose: bool = True) -> None:
        """Drop coldest pool entries until the pool fits the budget.

        ``protect`` (the key just inserted) is never evicted — a single
        program larger than the whole budget must still serve."""
        if self.pool_budget_bytes is None:
            return
        while (self.pool_bytes() > self.pool_budget_bytes
               and len(self._pools) > 1):
            cold = next(iter(self._pools))
            if cold == protect:
                break
            _, nbytes = self._pools.pop(cold)
            self.evictions += 1
            if verbose:
                print(f"[serve] evicted {cold[0]}/{cold[1]} bucket "
                      f"{cold[2]} ({nbytes} B) under pool budget "
                      f"{self.pool_budget_bytes} B", flush=True)

    def pool_keys(self, model_id: Optional[str] = None) -> tuple:
        """The compile-pool keys currently cached (a model's on request)."""
        keys = self._pools if model_id is None else [
            k for k in self._pools if k[0] == model_id]
        return tuple(sorted(keys))

    def pool_bytes(self, model_id: Optional[str] = None) -> int:
        """Total ``memory_analysis()`` bytes resident in the compile pool
        (one model's share on request).  0 on backends that cannot report
        program footprints — then no budget can ever trip."""
        return sum(nbytes for k, (_, nbytes) in self._pools.items()
                   if model_id is None or k[0] == model_id)
