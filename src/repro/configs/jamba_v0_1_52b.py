"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every second layer.  [arXiv:2403.19887; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    moe=True,
    num_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,      # 1 attention : 7 mamba
    ssm_state=16,      # jamba uses mamba-1-style d_state=16
    ssm_headdim=64,
    ffn="swiglu",
    norm="rmsnorm",
)
