"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.
[arXiv:2308.11596; hf]

12L(enc)+12L(dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
The audio frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed frame embeddings to the encoder.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,           # decoder depth
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    ffn="gelu",
    norm="layernorm",
    frontend="frame",
    frontend_len=1024,
)
