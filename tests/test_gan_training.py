"""SDE-GAN training subsystem tests (paper §5; DESIGN.md §4).

Careful clipping as an optimiser-chain transform, the Lipschitz-constrained
CDE discriminator stack, the shared WGAN step, and the launch CLI on 1 and
2 (simulated) devices.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn, optim
from repro.core.clipping import (clip_lipschitz, clip_pytree,
                                 lipschitz_bound_mlp, max_lipschitz_bound,
                                 per_layer_violation)
from repro.core.sde import (NeuralSDEConfig, discriminator_init,
                            generator_init)
from repro.launch.steps import make_gan_optimizers, make_sde_gan_step

TINY = dict(num_steps=8)          # 8 solver steps per solve
BATCH, SEQ = 16, 9                # data paths: (9, 16, 1)


def _tiny_setup(key, constraint="clip"):
    cfg = NeuralSDEConfig(**TINY)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    (gi, gu), (di, du) = make_gan_optimizers(lr=1.0, constraint=constraint)
    step = jax.jit(make_sde_gan_step(cfg, gu, du, BATCH, SEQ,
                                     constraint=constraint))
    return cfg, params, gi(params["gen"]), di(params["disc"]), step


# -----------------------------------------------------------------------------
# the constraint set: init, projection, per-layer bound after a real update
# -----------------------------------------------------------------------------


def test_lipswish_is_lipschitz_one_at_init(key):
    """LipSwish + the clipped init: the discriminator's vector fields start
    with Lipschitz bound ≤ 1 — no first-step clip slam needed."""
    x = jnp.linspace(-20, 20, 4_001)
    g = jax.vmap(jax.grad(nn.lipswish))(x)
    assert float(jnp.max(jnp.abs(g))) <= 1.0 + 1e-4
    disc = discriminator_init(key, NeuralSDEConfig(**TINY))
    assert float(max_lipschitz_bound(disc)) <= 1.0 + 1e-6
    for name in ("f", "g", "xi"):
        assert float(lipschitz_bound_mlp(disc[name])) <= 1.0 + 1e-6
        assert float(per_layer_violation(disc[name])) <= 1.0 + 1e-6


def test_clipped_disc_satisfies_per_layer_bound_after_update(key):
    """One *real* optimiser update (Adadelta → projection) from far outside
    the constraint set must land every layer of f/g/xi back inside its
    [-1/fan_in, 1/fan_in] box; the readout m stays unconstrained."""
    cfg, params, g_state, d_state, step = _tiny_setup(key)
    params["disc"] = jax.tree.map(lambda x: x * 10.0, params["disc"])
    m_before = np.asarray(params["disc"]["m"]["w"])
    params, _, _, _ = step(params, g_state, d_state, jax.random.fold_in(key, 2))
    for name in ("f", "g", "xi"):
        assert float(per_layer_violation(params["disc"][name])) <= 1.0 + 1e-6
        assert float(lipschitz_bound_mlp(params["disc"][name])) <= 1.0 + 1e-6
    # m moved by the optimiser but was not projected to the tiny clip box
    m_after = np.asarray(params["disc"]["m"]["w"])
    assert not np.array_equal(m_before, m_after)
    assert float(np.max(np.abs(m_after))) > 1.0 / m_after.shape[0]


def test_projection_transform_equals_manual_clip(key):
    """chain(adadelta, lipschitz_projection) ≡ clip(params + adadelta-update):
    the transform is exactly clip-after-update, rearranged to compose."""
    disc = discriminator_init(key, NeuralSDEConfig(**TINY))
    grads = jax.tree.map(
        lambda x: jax.random.normal(key, x.shape, x.dtype), disc)

    ai, au = optim.adadelta(lr=1.0)
    ci, cu = optim.chain(optim.adadelta(lr=1.0),
                         optim.lipschitz_projection(clip_lipschitz))

    upd, _ = au(grads, ai(disc), disc)
    want = clip_lipschitz(optim.apply_updates(disc, upd))
    upd2, _ = cu(grads, ci(disc), disc)
    got = optim.apply_updates(disc, upd2)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_clip_pytree_structural(key):
    """The structural projection clips every MLP in an arbitrary tree and
    leaves bare Linears / non-MLP leaves alone."""
    tree = {
        "vf": {"layers": [{"w": jnp.full((8, 4), 3.0), "b": jnp.ones((4,))}]},
        "nested": [{"layers": [{"w": jnp.full((2, 2), -5.0)}]}],
        "readout": {"w": jnp.full((4, 1), 7.0)},
        "scalar": jnp.float32(2.0),
    }
    out = clip_pytree(tree)
    assert float(jnp.max(jnp.abs(out["vf"]["layers"][0]["w"]))) <= 1 / 8
    np.testing.assert_array_equal(np.asarray(out["vf"]["layers"][0]["b"]),
                                  np.ones(4))
    assert float(jnp.max(jnp.abs(out["nested"][0]["layers"][0]["w"]))) <= 1 / 2
    np.testing.assert_array_equal(np.asarray(out["readout"]["w"]),
                                  np.full((4, 1), 7.0))
    assert float(out["scalar"]) == 2.0


# -----------------------------------------------------------------------------
# training behaviour
# -----------------------------------------------------------------------------


def test_two_step_loop_decreases_wasserstein_deterministically(key):
    """Two WGAN steps on a fixed batch decrease the Wasserstein estimate
    (disc_loss = E[fake] − E[real]), and the whole trajectory is a pure
    function of the seed (bitwise-identical on re-run)."""

    def run():
        cfg, params, g_state, d_state, step = _tiny_setup(key)
        k = jax.random.fold_in(key, 2)
        out = []
        for _ in range(3):  # metrics are pre-update ⇒ 3 calls see 2 updates
            params, g_state, d_state, m = step(params, g_state, d_state, k)
            out.append(float(m["disc_loss"]))
        return out

    a, b = run(), run()
    assert a == b, f"nondeterministic trajectory: {a} vs {b}"
    assert a[1] < a[0] and a[2] < a[1], f"W estimate not decreasing: {a}"


def test_gp_step_runs_and_matches_metric_keys(key):
    """The WGAN-GP baseline path of the shared step builder is runnable and
    reports the same metric schema (benchmarks/clipping.py relies on it)."""
    cfg = NeuralSDEConfig(num_steps=4, solver="midpoint", exact_adjoint=False)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    (gi, gu), (di, du) = make_gan_optimizers(lr=1.0, constraint="gp")
    step = jax.jit(make_sde_gan_step(cfg, gu, du, 8, 5, constraint="gp"))
    params, _, _, m = step(params, gi(params["gen"]), di(params["disc"]),
                           jax.random.fold_in(key, 2))
    assert set(m) == {"gen_loss", "disc_loss", "wasserstein"}
    assert all(np.isfinite(float(v)) for v in m.values())
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(params))


# -----------------------------------------------------------------------------
# the launch CLI, 1 and 2 (simulated) devices
# -----------------------------------------------------------------------------


def _run_train_cli(extra_env=None, extra_args=()):
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    env.update(extra_env or {})
    cmd = [sys.executable, "-m", "launch.train", "--workload", "sde-gan",
           "--steps", "2", "--batch", "8", "--sde-steps", "8",
           "--seq-len", "9", *extra_args]
    return subprocess.run(cmd, cwd=repo, env=env, capture_output=True,
                          text=True, timeout=600)


def test_train_cli_single_device():
    r = _run_train_cli()
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[sde-gan] done" in r.stdout


def test_train_cli_two_simulated_devices():
    r = _run_train_cli(
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "data-parallel over 2 devices" in r.stdout
    assert "[sde-gan] done" in r.stdout
