"""Documentation cross-reference guards.

The repo's convention is that code comments cite docs by file + section
("DESIGN.md §4", "EXPERIMENTS.md §Perf").  These tests keep those
references live: every markdown file a source file points at must exist,
every cited section must resolve, every relative markdown link must land
on a real file, and every public serving-API symbol must carry a
docstring — a rename or deletion fails tier-1 instead of leaving
dangling pointers (the seed shipped nine references to a nonexistent
EXPERIMENTS.md).
"""

import inspect
import re
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _md_files():
    """Every markdown file the guards cover: repo root + docs/."""
    files = sorted(REPO.glob("*.md"))
    docs = REPO / "docs"
    if docs.is_dir():
        files += sorted(docs.glob("*.md"))
    return files


def _source_blob() -> str:
    parts = []
    for sub in ("src", "benchmarks", "examples", "tests"):
        for p in (REPO / sub).rglob("*.py"):
            parts.append(p.read_text(encoding="utf-8"))
    for p in _md_files():
        parts.append(p.read_text(encoding="utf-8"))
    return "\n".join(parts)


def test_referenced_markdown_files_exist():
    blob = _source_blob()
    # uppercase markdown references resolve at the repo root or under docs/
    missing = {name for name in set(re.findall(r"\b[A-Z][A-Z_]*\.md\b", blob))
               if not ((REPO / name).exists()
                       or (REPO / "docs" / name).exists())}
    assert not missing, f"dangling doc references: {sorted(missing)}"


def test_design_section_references_resolve():
    design = (REPO / "DESIGN.md").read_text(encoding="utf-8")
    cited = set(re.findall(r"DESIGN\.md §(\d+)", _source_blob()))
    assert cited, "expected at least one DESIGN.md section citation"
    missing = {n for n in cited if f"## §{n} " not in design}
    assert not missing, f"DESIGN.md sections cited but absent: {sorted(missing)}"


def test_experiments_section_references_resolve():
    exp = (REPO / "EXPERIMENTS.md").read_text(encoding="utf-8")
    cited = set(re.findall(r"EXPERIMENTS\.md §(\w+)", _source_blob()))
    assert cited, "expected at least one EXPERIMENTS.md section citation"
    missing = {s for s in cited if f"§{s}" not in exp}
    assert not missing, (
        f"EXPERIMENTS.md sections cited but absent: {sorted(missing)}")


def test_markdown_links_resolve():
    """Every relative [text](target) link in root + docs/ markdown lands
    on an existing file (anchors are stripped; http/mailto links and
    in-page anchors are out of scope)."""
    broken = []
    for md in _md_files():
        text = md.read_text(encoding="utf-8")
        for target in re.findall(r"\[[^\]]*\]\(([^)\s]+)\)", text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                broken.append(f"{md.relative_to(REPO)} -> {target}")
    assert not broken, f"broken markdown links: {broken}"


def test_serving_public_api_docstrings():
    """Every symbol in repro.serving.__all__ carries a docstring, and so
    does every public method/property those classes define — the serving
    API documents its bitwise/ordering contracts at the symbol."""
    import repro.serving as serving

    undocumented = []
    for name in serving.__all__:
        obj = getattr(serving, name)
        if not inspect.isroutine(obj) and not inspect.isclass(obj):
            continue  # data tables (DEADLINE_CLASSES) document in-module
        if not (getattr(obj, "__doc__", None) or "").strip():
            undocumented.append(name)
        if inspect.isclass(obj):
            for attr, member in vars(obj).items():
                if attr.startswith("_"):
                    continue
                target = member.fget if isinstance(member, property) \
                    else member
                if not callable(target):
                    continue
                if not (getattr(target, "__doc__", None) or "").strip():
                    undocumented.append(f"{name}.{attr}")
    assert not undocumented, (
        f"public serving API without docstrings: {sorted(undocumented)}")
