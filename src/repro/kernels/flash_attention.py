"""Causal GQA flash attention (TPU Pallas) — the LM prefill hot spot.

Standard online-softmax blocking adapted to the TPU memory hierarchy:
Q/K/V tiles live in VMEM, running (m, l, acc) statistics in VMEM scratch,
the KV axis is the innermost (sequential) grid dimension so the MXU sees
back-to-back (bq × d)·(d × bk) and (bq × bk)·(bk × d) matmuls without HBM
materialisation of the (S × S) score matrix.  GQA is expressed through the
K/V BlockSpec index maps (q-head → kv-head), so no ``repeat`` copy is made.

Block sizes default to 128 — MXU-aligned (128×128 systolic array) and a
multiple of the f32 (8, 128) VMEM tile.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(causal, scale, bq, bk, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # with a causal mask, KV blocks strictly above the diagonal contribute
    # nothing — skip their compute entirely (2x flops saving on prefill)
    needed = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0]  # (bq, d)
        k = k_ref[0, 0]  # (bk, d)
        v = v_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,           # (B, Hq, S, D)
    k: jax.Array,           # (B, Hkv, S, D)
    v: jax.Array,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq = min(block_q, S)
    bk = min(block_k, S)
    while S % bq:
        bq //= 2
    while S % bk:
        bk //= 2

    grid = (B * Hq, S // bq, S // bk)
    kernel = functools.partial(_kernel, causal, scale, bq, bk)

    def qmap(bh, iq, ik):
        return (bh // Hq, bh % Hq, iq, 0)

    def kvmap(bh, iq, ik):
        return (bh // Hq, (bh % Hq) // group, ik, 0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), qmap),
            pl.BlockSpec((1, 1, bk, D), kvmap),
            pl.BlockSpec((1, 1, bk, D), kvmap),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), qmap),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
