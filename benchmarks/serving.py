"""Serving benchmark suite: batched trajectory-sampling throughput,
plus the open-loop load generator gating the continuous-batching
scheduler (suite ``serving_load``).

Two axes (DESIGN.md §9; the serving architecture under test is
``repro.launch.steps.make_sample_step`` — the exact program
launch/serve.py AOT-compiles per bucket):

1. **Throughput vs batch size** (SDE-GAN generator rollout): best-of-reps
   wall clock and trajectories/sec per bucket size.  Larger buckets must
   amortise per-dispatch overhead — the whole point of request coalescing —
   so the gate asserts trajectories/sec is strictly higher at the largest
   bucket than at batch 1.

2. **Fused vs unfused latent prior decode** — the diagonal-noise sampler
   with and without ``use_pallas_kernels``.  As in benchmarks/latent_sde.py,
   wall-clock rows are reported for existence and the **gated** comparison
   is the XLA cost-model bytes-accessed ratio (deterministic where shared
   CI runners are not): fusion never *adds* traffic, so the ratio is ≥ 1
   by construction (exactly 1.0 off-TPU, where the fused path dispatches
   to the identical jnp oracle — DESIGN.md §5).

The ``*_ms`` rows feed CI's bench-regression gate
(``benchmarks/report.py --compare``): a >2× best-of-reps wall-clock
regression against the committed BENCH_serving.json fails bench-smoke.

The **serving_load** suite (``main_load``; BENCH_serving_load.json) is
the scheduler gate (DESIGN.md §11): a synthetic *open-loop* generator
offers Poisson arrivals with mixed deadline classes at a fixed fraction
of the measured service capacity — offered load is set by the arrival
process, not by completions, so queueing delay is accounted rather than
hidden — and the identical request trace is replayed against the FIFO
drain-then-coalesce baseline and the continuous-batching scheduler
(same compiled programs; only admission differs).  Latency bookkeeping
runs on the scheduler's injectable clock in *virtual time* with the
measured per-iteration service cost (see :func:`_virtual_open_loop`),
so the in-bench gate — ``continuous_p99_ms <= fifo_p99_ms``, admitting
at chunk boundaries must beat waiting for the batch to drain on the
tail — is deterministic per machine calibration, while the millisecond
scale still tracks real hardware for the CI regression trajectory.

The **serving_async** suite (``main_async``; BENCH_serving_async.json)
gates the PR 10 surfaces (DESIGN.md §14): open-loop Poisson arrivals over
the asyncio ingestion frontend with per-deadline-class p50/p99 and a
bitwise frontend-vs-solo oracle; the cross-lane preemption gate — one
seeded trace of relaxed bulk rollouts + realtime terminal requests
replayed with ``preempt`` off/on on a virtual clock charged per
*executed batch* at fixed synthetic costs (machine-independent:
realtime misses with preemption must be <= without, and the no-preempt
run must actually miss at utilisation rho >= 0.3); and the elastic-pool
gate — LRU eviction under a byte budget must engage and the
evicted-then-recompiled rollout must be bitwise the unbounded
registry's.

Run:  PYTHONPATH=src python benchmarks/serving.py --preset tiny
Emits BENCH_serving.json (schema in benchmarks/report.py).
"""

from __future__ import annotations

import time

import jax

try:
    from . import report
    from .latent_sde import _bytes_accessed
except ImportError:  # run as a loose script: python benchmarks/serving.py
    import report
    from latent_sde import _bytes_accessed

# num_steps: solver horizon; batches: bucket sizes (throughput axis);
# fused_batch: bucket for the fused-vs-unfused comparison; reps: timing reps
PRESET_SHAPES = {
    "tiny":  dict(num_steps=16, batches=(1, 4, 16), fused_batch=16,
                  hidden=8, width=16, reps=5),
    "quick": dict(num_steps=32, batches=(1, 8, 32, 128), fused_batch=64,
                  hidden=16, width=32, reps=8),
    "full":  dict(num_steps=64, batches=(1, 16, 128, 1024), fused_batch=256,
                  hidden=16, width=32, reps=15),
}


def _best_of(reps: int, compiled, *args) -> float:
    jax.block_until_ready(compiled(*args))  # warm (AOT: compile already done)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_throughput(num_steps: int, batches, hidden: int, width: int,
                     reps: int):
    """trajectories/sec per bucket size for the SDE-GAN sampler."""
    from repro.core.sde import NeuralSDEConfig, generator_init
    from repro.launch.steps import make_sample_step

    cfg = NeuralSDEConfig(data_dim=1, hidden_dim=hidden, noise_dim=4,
                          width=width, num_steps=num_steps)
    key = jax.random.PRNGKey(0)
    params = generator_init(key, cfg)
    jitted = jax.jit(make_sample_step("sde-gan", cfg))

    rows, tps = [], {}
    for b in batches:
        keys = jax.random.split(jax.random.fold_in(key, b), b)
        compiled = jitted.lower(params, keys).compile()
        best = _best_of(reps, compiled, params, keys)
        tps[b] = b / best
        rows.append(("serving", f"sde_gan_batch{b}_ms", best * 1e3))
        rows.append(("serving", f"sde_gan_traj_per_s,batch={b}", tps[b]))
        print(f"serving,sde_gan,batch={b},{best*1e3:.2f}ms,"
              f"{tps[b]:.1f}traj/s", flush=True)
    big, small = max(batches), min(batches)
    # coalescing must pay: the big bucket amortises dispatch overhead
    assert tps[big] > tps[small], (
        f"batching did not improve throughput: batch={big} served "
        f"{tps[big]:.1f} traj/s vs {tps[small]:.1f} at batch={small}")
    return rows


def bench_fused_prior(num_steps: int, fused_batch: int, hidden: int,
                      width: int, reps: int):
    """Fused vs unfused latent prior decode: interleaved best-of-reps wall
    clock + the deterministic cost-model bytes gate."""
    from repro.core.sde import LatentSDEConfig, latent_sde_init
    from repro.launch.steps import make_sample_step

    key = jax.random.PRNGKey(1)
    keys = jax.random.split(key, fused_batch)
    built = {}
    for fused in (False, True):
        cfg = LatentSDEConfig(data_dim=2, hidden_dim=hidden,
                              context_dim=hidden, width=width,
                              num_steps=num_steps, use_pallas_kernels=fused)
        params = latent_sde_init(key, cfg)
        jitted = jax.jit(make_sample_step("latent-sde", cfg))
        built[fused] = (jitted.lower(params, keys).compile(), jitted, params)
        jax.block_until_ready(built[fused][0](params, keys))  # warm

    best = {False: float("inf"), True: float("inf")}
    for _ in range(reps):  # interleave: same machine conditions for both
        for fused, (compiled, _, params) in built.items():
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(params, keys))
            best[fused] = min(best[fused], time.perf_counter() - t0)
    bytes_ = {fused: _bytes_accessed(jitted, params, keys)
              for fused, (_, jitted, params) in built.items()}

    rows = []
    for fused in (False, True):
        label = "fused" if fused else "unfused"
        rows.append(("serving", f"latent_prior_{label}_ms", best[fused] * 1e3))
        rows.append(("serving", f"latent_prior_{label}_bytes_accessed",
                     bytes_[fused]))
        print(f"serving,latent_prior_{label},{best[fused]*1e3:.2f}ms,"
              f"bytes={bytes_[fused]:.3e}", flush=True)
    speedup = bytes_[False] / bytes_[True]
    rows.append(("serving", "latent_prior_fused_speedup", speedup))
    print(f"serving,latent_prior_fused_speedup,{speedup:.3f}x "
          f"(cost-model bytes)", flush=True)
    assert speedup >= 1.0 - 1e-9, (
        f"fused prior decode accessed MORE bytes than unfused "
        f"({bytes_[True]:.3e} vs {bytes_[False]:.3e})")
    return rows


def main(preset: str = "full"):
    shape = PRESET_SHAPES[preset]
    rows = bench_throughput(shape["num_steps"], shape["batches"],
                            shape["hidden"], shape["width"], shape["reps"])
    rows += bench_fused_prior(shape["num_steps"], shape["fused_batch"],
                              shape["hidden"], shape["width"], shape["reps"])
    return rows


# -----------------------------------------------------------------------------
# serving_load: the open-loop continuous-batching gate
# -----------------------------------------------------------------------------

# rho: offered load as a fraction of the *measured* chunk-service capacity
# (calibrated per machine so the queueing regime — not absolute speed — is
# what the suite pins down).  The shapes deliberately put the system in the
# regime continuous batching targets: max_batch >> request size, so an
# in-flight batch usually has free slots (admission blocking — the
# mode-dependent penalty — dominates the tail), and rho low enough that
# capacity queueing (mode-INdependent) doesn't drown it.
LOAD_SHAPES = {
    "tiny":  dict(num_steps=16, max_batch=8, chunks=8, n_requests=100,
                  request_max=2, rho=0.3, hidden=8, width=16),
    "quick": dict(num_steps=16, max_batch=16, chunks=8, n_requests=150,
                  request_max=4, rho=0.4, hidden=16, width=32),
    "full":  dict(num_steps=32, max_batch=32, chunks=8, n_requests=256,
                  request_max=4, rho=0.4, hidden=16, width=32),
}


def _load_trace(n_requests, request_max, mean_interarrival_s, seed=0):
    """The synthetic request trace: sizes and seeds on the synthetic_requests
    grid, deadline classes cycled (realtime/interactive/standard/relaxed),
    Poisson arrivals (seeded exponential interarrivals)."""
    import numpy as np

    from repro.serving import DEADLINE_CLASSES, Request

    rng = np.random.RandomState(seed)
    requests = [
        Request(rid=i, size=1 + (i * 7 + seed) % request_max,
                seed=seed * 100_003 + i,
                deadline_ms=DEADLINE_CLASSES[
                    i % len(DEADLINE_CLASSES)].max_deadline_ms)
        for i in range(n_requests)
    ]
    arrivals = np.cumsum(
        rng.exponential(mean_interarrival_s, n_requests)).tolist()
    return requests, arrivals


def _virtual_open_loop(sched, requests, arrivals, vt, t_iter):
    """Open-loop driver on the scheduler's *virtual* clock: arrivals land at
    their synthetic offsets, every iteration advances virtual time by the
    calibrated ``t_iter``, and idle gaps jump to the next arrival.  The
    compiled chunk programs really execute — only the latency bookkeeping
    is in virtual time, so the policy comparison is deterministic (host
    jitter — GC pauses, CPU contention — would otherwise swamp the
    ~one-drain-time structural gap this suite exists to measure)."""
    feed = sorted(zip(arrivals, range(len(requests))))
    results, i = [], 0
    while i < len(feed) or sched.busy:
        while i < len(feed) and feed[i][0] <= vt[0]:
            arrival, idx = feed[i]
            sched.submit(requests[idx], arrival_s=arrival)
            i += 1
        if sched.busy:
            results += sched.step()
            vt[0] += t_iter
        else:
            vt[0] = feed[i][0]
    return results


def bench_open_loop(num_steps, max_batch, chunks, n_requests, request_max,
                    rho, hidden, width, seed=0):
    """Open-loop p50/p99 + throughput: FIFO baseline vs continuous batching
    on one Poisson trace, through the SAME compiled chunk programs."""
    from repro.core.sde import NeuralSDEConfig
    from repro.serving import (LoadedModel, ModelRegistry, Request,
                               Scheduler, latency_summary)
    from repro.serving.registry import _init_params

    cfg = NeuralSDEConfig(data_dim=1, hidden_dim=hidden, noise_dim=4,
                          width=width, num_steps=num_steps)
    params = _init_params("sde-gan", cfg, seed)
    registry = ModelRegistry()
    registry.register(LoadedModel("default", "sde-gan", cfg, params))

    # calibrate: compile every pool once (registry-cached for both runs),
    # then time a full-bucket closed-loop drain — the per-iteration wall
    # clock INCLUDES the host-side scheduling overhead the compiled chunk
    # time alone would hide, so the offered load really lands at
    # utilisation ~rho on THIS machine
    warmup = Scheduler(registry, max_batch=max_batch, chunks=chunks)
    warmup.warm("default")
    t_iter = float("inf")
    for rep in range(3):
        for i in range(max_batch):
            warmup.submit(Request(rid=-1 - i, size=1,
                                  seed=seed + 10_000 * (rep + 1) + i))
        t0 = time.perf_counter()
        warmup.run()
        t_iter = min(t_iter, (time.perf_counter() - t0) / chunks)
    avg_size = sum(1 + (i * 7 + seed) % request_max
                   for i in range(n_requests)) / n_requests
    # capacity: max_batch row-chunks per iteration; a size-s request costs
    # s * chunks row-chunks
    lam_max = max_batch / (t_iter * avg_size * chunks)
    mean_interarrival = 1.0 / (rho * lam_max)
    print(f"serving_load,calibrated: iteration {t_iter * 1e3:.2f}ms, "
          f"offered {rho * lam_max:.1f} req/s "
          f"(rho={rho}, interarrival {mean_interarrival * 1e3:.2f}ms)",
          flush=True)

    rows = [("serving_load", "offered_req_per_s", rho * lam_max)]
    p99 = {}
    for mode in ("fifo", "continuous"):
        requests, arrivals = _load_trace(n_requests, request_max,
                                         mean_interarrival, seed)
        vt = [0.0]
        sched = Scheduler(registry, max_batch=max_batch, chunks=chunks,
                          mode=mode, clock=lambda: vt[0])
        sched.warm("default")  # cached — keeps compiles off the clock
        results = _virtual_open_loop(sched, requests, arrivals, vt, t_iter)
        summary = latency_summary(results)
        tps = summary["rows"] / max(vt[0], 1e-9)
        p99[mode] = summary["p99_s"] * 1e3
        rows += [
            ("serving_load", f"{mode}_p50_ms", summary["p50_s"] * 1e3),
            ("serving_load", f"{mode}_p99_ms", p99[mode]),
            ("serving_load", f"{mode}_traj_per_s", tps),
            ("serving_load", f"{mode}_deadline_misses",
             summary["deadline_misses"]),
        ]
        print(f"serving_load,{mode},p50={summary['p50_s'] * 1e3:.1f}ms,"
              f"p99={p99[mode]:.1f}ms,{tps:.1f}traj/s,"
              f"misses={summary['deadline_misses']}", flush=True)
    # the gate: iteration-level admission must beat drain-then-coalesce on
    # the tail (identical compiled programs and trace; deterministic in
    # virtual time, so a failure is a policy regression, never jitter)
    assert p99["continuous"] <= p99["fifo"], (
        f"continuous batching lost to the FIFO baseline on p99: "
        f"{p99['continuous']:.1f}ms vs {p99['fifo']:.1f}ms")
    return rows


def main_load(preset: str = "full"):
    return bench_open_loop(**LOAD_SHAPES[preset])


# -----------------------------------------------------------------------------
# serving_async: asyncio ingestion + preemption + elastic pools (DESIGN.md §14)
# -----------------------------------------------------------------------------

#: Fixed synthetic batch costs for the preemption gate's virtual clock.
#: Charged per *executed batch* (scheduler counter deltas), not per
#: iteration — preemption's whole effect is running FEWER/cheaper batches
#: while realtime work is outstanding, which a flat per-iteration charge
#: would erase.  Fixed costs (not measured) make the gate bit-identical
#: across machines: a bulk chunk batch is a long device dispatch, a
#: terminal batch a short one, and the 50ms realtime deadline sits between
#: one terminal batch and one chunk batch.
T_CHUNK_S = 0.060
T_TERM_S = 0.010

ASYNC_SHAPES = {
    "tiny":  dict(num_steps=16, max_batch=8, chunks=8, hidden=8, width=16,
                  n_front=24, n_bulk=12, n_rt=80,
                  bulk_interarrival_s=0.12, rt_interarrival_s=0.025),
    "quick": dict(num_steps=16, max_batch=16, chunks=8, hidden=16, width=32,
                  n_front=48, n_bulk=20, n_rt=140,
                  bulk_interarrival_s=0.10, rt_interarrival_s=0.020),
    "full":  dict(num_steps=32, max_batch=32, chunks=8, hidden=16, width=32,
                  n_front=96, n_bulk=32, n_rt=240,
                  bulk_interarrival_s=0.08, rt_interarrival_s=0.015),
}


def _make_registry(num_steps, hidden, width, model_ids=("default",),
                   pool_budget_bytes=None, seed=0):
    from repro.core.sde import NeuralSDEConfig
    from repro.serving import LoadedModel, ModelRegistry
    from repro.serving.registry import _init_params

    cfg = NeuralSDEConfig(data_dim=1, hidden_dim=hidden, noise_dim=4,
                          width=width, num_steps=num_steps)
    registry = ModelRegistry(pool_budget_bytes=pool_budget_bytes)
    for i, mid in enumerate(model_ids):
        registry.register(LoadedModel(
            mid, "sde-gan", cfg, _init_params("sde-gan", cfg, seed + i)))
    return registry, cfg


def bench_async_ingestion(num_steps, max_batch, chunks, hidden, width,
                          n_front, seed=0, **_):
    """Open-loop Poisson arrivals over the asyncio frontend (real time,
    mixed deadline classes), per-class p50/p99 — plus the bitwise oracle:
    a request served through the frontend equals its solo direct-step
    trajectories exactly."""
    import asyncio

    import numpy as np

    from repro.serving import (AsyncFrontend, Request, Scheduler,
                               class_latency_summary)

    registry, cfg = _make_registry(num_steps, hidden, width, seed=seed)
    sched = Scheduler(registry, max_batch=max_batch, chunks=chunks)
    sched.warm("default", kinds=("init", "chunk", "terminal"))

    rng = np.random.RandomState(seed)
    requests, kinds = [], ("rollout", "terminal")
    from repro.serving import DEADLINE_CLASSES
    for i in range(n_front):
        cls = DEADLINE_CLASSES[i % len(DEADLINE_CLASSES)]
        kind = kinds[i % 2]
        requests.append(Request(
            rid=i, size=1 + i % 2, seed=seed * 7919 + i, kind=kind,
            deadline_ms=cls.max_deadline_ms if kind == "terminal"
            else float("inf")))
    # modest offered rate: the suite measures the ingestion path's
    # latency accounting, not saturation (bench_preemption owns that)
    arrivals = np.cumsum(rng.exponential(0.005, n_front))

    async def drive():
        front = AsyncFrontend(sched)
        await front.start()

        async def client(req, at):
            await asyncio.sleep(float(at))
            return await front.submit(req)

        try:
            return await asyncio.gather(
                *(client(r, a) for r, a in zip(requests, arrivals)))
        finally:
            await front.close()

    results = asyncio.run(drive())
    assert len(results) == n_front
    summary = class_latency_summary(results)
    rows = []
    for cls_name, s in sorted(summary.items()):
        rows += [("serving_async", f"front_{cls_name}_p50_ms",
                  s["p50_s"] * 1e3),
                 ("serving_async", f"front_{cls_name}_p99_ms",
                  s["p99_s"] * 1e3)]
        print(f"serving_async,front,{cls_name},p50={s['p50_s']*1e3:.1f}ms,"
              f"p99={s['p99_s']*1e3:.1f}ms,n={s['requests']}", flush=True)

    # bitwise oracle: frontend-served == solo direct-step, exactly
    probe = Request(rid=0, size=2, seed=seed + 12345)

    def solo():
        s = Scheduler(registry, max_batch=max_batch, chunks=chunks,
                      collect=True)
        s.submit(Request(rid=1, size=2, seed=seed + 12345))
        (res,) = s.run()
        return res.samples

    async def through_front():
        s = Scheduler(registry, max_batch=max_batch, chunks=chunks,
                      collect=True)
        front = AsyncFrontend(s)
        await front.start()
        try:
            # a second in-flight request makes the oracle non-trivial:
            # the probe shares its batches
            other = asyncio.ensure_future(front.submit(
                Request(rid=9, size=1, seed=seed + 999)))
            res = await front.submit(probe)
            await other
            return res.samples
        finally:
            await front.close()

    np.testing.assert_array_equal(asyncio.run(through_front()), solo())
    rows.append(("serving_async", "front_bitwise_vs_solo_ok", 1.0))
    print("serving_async,front_bitwise_vs_solo_ok", flush=True)
    return rows


def _virtual_batch_loop(sched, requests, arrivals, vt):
    """Open-loop driver charging virtual time per *executed batch*
    (counter deltas x the fixed T_CHUNK_S/T_TERM_S costs).  Unlike
    serving_load's flat per-iteration charge, this makes preemption
    visible to the clock: a preempting iteration skips the bulk chunk
    batch and costs only the terminal batch it actually ran."""
    feed = sorted(zip(arrivals, range(len(requests))))
    results, i = [], 0
    while i < len(feed) or sched.busy:
        while i < len(feed) and feed[i][0] <= vt[0]:
            arrival, idx = feed[i]
            sched.submit(requests[idx], arrival_s=arrival)
            i += 1
        if sched.busy:
            c0 = sched.counters["chunk_batches"]
            t0 = sched.counters["terminal_batches"]
            results += sched.step()
            dt = ((sched.counters["chunk_batches"] - c0) * T_CHUNK_S
                  + (sched.counters["terminal_batches"] - t0) * T_TERM_S)
            # an iteration that executed nothing (everything paused or
            # deferred) still ticks, else the loop would freeze the clock
            vt[0] += dt if dt > 0 else T_TERM_S
        else:
            vt[0] = feed[i][0]
    return results


def bench_preemption(num_steps, max_batch, chunks, hidden, width, n_bulk,
                     n_rt, bulk_interarrival_s, rt_interarrival_s, seed=0,
                     **_):
    """The preemption gate: one seeded trace — relaxed-class bulk rollouts
    on lane "bulk", realtime-class terminal requests on lane "rt" —
    replayed with preempt off and on.  Virtual time per executed batch
    (see :data:`T_CHUNK_S`), so the comparison is machine-independent.
    Gates: realtime misses with preemption <= without (and the scenario is
    non-vacuous: misses occur without preemption, rows really paused)."""
    import numpy as np

    from repro.serving import Request, Scheduler, class_latency_summary

    rng = np.random.RandomState(seed)
    bulk = [Request(rid=i, size=1 + i % 2, seed=seed + i, model_id="bulk")
            for i in range(n_bulk)]
    rt = [Request(rid=1000 + i, size=1, seed=seed + 5000 + i, model_id="rt",
                  kind="terminal", deadline_ms=40.0) for i in range(n_rt)]
    arrivals = (np.cumsum(rng.exponential(bulk_interarrival_s,
                                          n_bulk)).tolist()
                + np.cumsum(rng.exponential(rt_interarrival_s, n_rt)).tolist())
    requests = bulk + rt

    registry, _ = _make_registry(num_steps, hidden, width, ("bulk", "rt"),
                                 seed=seed)
    # compile both lanes' pools once (registry-cached across both runs)
    warm = Scheduler(registry, max_batch=max_batch, chunks=chunks)
    warm.warm("bulk", kinds=("init", "chunk"))
    warm.warm("rt", kinds=("terminal",))

    rows, misses, rho = [], {}, {}
    for preempt in (False, True):
        vt = [0.0]
        sched = Scheduler(registry, max_batch=max_batch, chunks=chunks,
                          clock=lambda: vt[0], preempt=preempt)
        results = _virtual_batch_loop(sched, requests, arrivals, vt)
        assert len(results) == len(requests)
        busy_s = (sched.counters["chunk_batches"] * T_CHUNK_S
                  + sched.counters["terminal_batches"] * T_TERM_S)
        rho[preempt] = busy_s / max(vt[0], 1e-9)
        mode = "preempt" if preempt else "nopreempt"
        summary = class_latency_summary(results)
        rt_s = summary["realtime"]
        misses[preempt] = rt_s["deadline_misses"]
        rows += [
            ("serving_async", f"{mode}_rt_p50_ms", rt_s["p50_s"] * 1e3),
            ("serving_async", f"{mode}_rt_p99_ms", rt_s["p99_s"] * 1e3),
            ("serving_async", f"{mode}_rt_misses", float(misses[preempt])),
            ("serving_async", f"{mode}_relaxed_p99_ms",
             summary["relaxed"]["p99_s"] * 1e3),
            ("serving_async", f"{mode}_rho", rho[preempt]),
        ]
        if preempt:
            rows.append(("serving_async", "preempted_rows",
                         float(sched.counters["preempted_rows"])))
            assert sched.counters["preempted_rows"] > 0, (
                "preemption never engaged — the gate would be vacuous")
            assert (sched.counters["resumed_rows"]
                    == sched.counters["preempted_rows"]), (
                "paused rows leaked: "
                f"{sched.counters['preempted_rows']} paused vs "
                f"{sched.counters['resumed_rows']} resumed")
        print(f"serving_async,{mode},rt_p99={rt_s['p99_s']*1e3:.1f}ms,"
              f"rt_misses={misses[preempt]}/{n_rt},rho={rho[preempt]:.2f}",
              flush=True)

    assert rho[False] >= 0.3, (
        f"offered load rho={rho[False]:.2f} < 0.3 — the no-preempt run is "
        f"not in the contended regime the gate is about")
    assert misses[False] > 0, (
        "no realtime misses even WITHOUT preemption — the trace is too "
        "easy for the gate to mean anything")
    # THE gate: preemption may never cost realtime misses, and on this
    # trace it must cut them (deterministic: virtual clock, seeded trace)
    assert misses[True] <= misses[False], (
        f"preemption INCREASED realtime misses: {misses[True]} vs "
        f"{misses[False]}")
    return rows


def bench_eviction(num_steps, max_batch, chunks, hidden, width, seed=0,
                   **_):
    """Elastic-pool gate: under a budget sized below the working set the
    registry must evict (LRU) and transparently recompile — and the
    recompiled rollout must be bitwise the unbounded registry's."""
    import numpy as np

    from repro.serving import ModelRegistry, Request, Scheduler

    def run(registry, rid):
        sched = Scheduler(registry, max_batch=max_batch, chunks=chunks,
                          collect=True)
        sched.submit(Request(rid=rid, size=1, seed=seed + 424242))
        (res,) = sched.run()
        return res.samples

    free, cfg = _make_registry(num_steps, hidden, width, seed=seed)
    expect = run(free, 0)
    unbounded_bytes = free.pool_bytes()
    rows = [("serving_async", "pool_unbounded_bytes",
             float(unbounded_bytes))]
    if unbounded_bytes == 0:
        # documented fail-open: no memory_analysis on this backend
        rows.append(("serving_async", "pool_evictions", 0.0))
        print("serving_async,eviction,SKIP (no memory_analysis sizes)",
              flush=True)
        return rows

    budget = max(1, int(unbounded_bytes * 0.75))
    reg = ModelRegistry(pool_budget_bytes=budget)
    from repro.serving import LoadedModel
    reg.register(LoadedModel("default", "sde-gan", cfg,
                             free.get("default").params))
    got = run(reg, 1)
    compiles_first = reg.compiles
    np.testing.assert_array_equal(got, expect)
    assert reg.evictions >= 1, (
        f"budget {budget} B under a {unbounded_bytes} B working set "
        f"never evicted")
    # the evicted program recompiles transparently — and stays bitwise
    got2 = run(reg, 2)
    np.testing.assert_array_equal(got2, expect)
    assert reg.compiles > compiles_first, (
        "second pass recompiled nothing — eviction did not actually drop "
        "a program the workload needs")
    rows += [
        ("serving_async", "pool_budget_bytes", float(budget)),
        ("serving_async", "pool_evictions", float(reg.evictions)),
        ("serving_async", "pool_recompiles",
         float(reg.compiles - compiles_first)),
        ("serving_async", "eviction_bitwise_ok", 1.0),
    ]
    print(f"serving_async,eviction,budget={budget}B,"
          f"evictions={reg.evictions},recompiles="
          f"{reg.compiles - compiles_first},bitwise_ok", flush=True)
    return rows


def main_async(preset: str = "full"):
    shape = ASYNC_SHAPES[preset]
    rows = bench_async_ingestion(**shape)
    rows += bench_preemption(**shape)
    rows += bench_eviction(**shape)
    return rows


if __name__ == "__main__":
    report.standalone("serving", main)
