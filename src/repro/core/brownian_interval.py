"""The Brownian Interval — faithful host-side implementation (paper §4, App. E).

A lazily grown binary tree of ``(interval, seed)`` nodes.  Queries return the
exact increment ``W_{s,t}``; the tree aligns itself with query points, so no
discretisation error is ever introduced (unlike the Virtual Brownian Tree).
Three of the paper's engineering points are reproduced:

* **splittable PRNG** — each child's seed is derived deterministically from
  its parent's (Salmon et al. [34] / Claessen & Pałka [35]); we use numpy's
  Philox counter-based generator keyed by the node seed.
* **LRU cache on computed increments** — queries adjacent to recent queries
  (the SDE-solver access pattern) hit the cache and cost amortised O(1).
* **search hints** — ``traverse`` starts from the most recent node, not the
  root (App. E "Search hints"), and an optional **pre-planted dyadic tree**
  (App. E "Backward pass") bounds recomputation on right-to-left sweeps.

This module is intentionally host-side Python: it is the *reference /
benchmark* implementation used to reproduce Table 2.  The in-graph TPU path
(:class:`repro.core.brownian.BrownianPath`) achieves the same
exactness-without-storage via JAX's own counter-based splittable PRNG; see
DESIGN.md §2 for why the LRU cache dissolves on TPU.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["BrownianInterval", "HostVirtualBrownianTree"]


class _Node:
    __slots__ = ("a", "b", "seed", "parent", "left", "right")

    def __init__(self, a: float, b: float, seed: int, parent: Optional["_Node"]):
        self.a = a
        self.b = b
        self.seed = seed
        self.parent = parent
        self.left: Optional[_Node] = None
        self.right: Optional[_Node] = None

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Node([{self.a}, {self.b}])"


def _split_seed(seed: int) -> Tuple[int, int]:
    """Deterministic splittable seed derivation (counter-based hash)."""
    rng = np.random.Philox(key=seed & ((1 << 64) - 1))
    child = np.random.Generator(rng).integers(0, 2**63 - 1, size=2)
    return int(child[0]), int(child[1])


class _LRU:
    """Fixed-size LRU cache: node-id -> increment array."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: OrderedDict[int, np.ndarray] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, k: int):
        v = self._d.get(k)
        if v is not None:
            self.hits += 1
            self._d.move_to_end(k)
        else:
            self.misses += 1
        return v

    def put(self, k: int, v: np.ndarray):
        self._d[k] = v
        self._d.move_to_end(k)
        if len(self._d) > self.maxsize:
            self._d.popitem(last=False)


class BrownianInterval:
    """Exact sampling/reconstruction of Brownian increments ``W_{s,t}``.

    Parameters
    ----------
    t0, t1 : global interval.
    shape  : shape of each increment (e.g. ``(batch, w_dim)``).
    seed   : global seed (root of the splittable-PRNG tree).
    cache_size : LRU cache entries (the paper's "fixed and constant" GPU cost).
    preplant_dt : if given, pre-plant a dyadic tree whose leaves are no larger
        than ``4/5 * preplant_dt * cache_size`` (App. E backward-pass remedy),
        making right-to-left sweeps O(n log n) instead of O(n^2).
    levy_area : ``None`` (plain ``W_{s,t}`` — bitwise the historical draws)
        or ``"space-time"``: queries return ``(W_{s,t}, H_{s,t})`` pairs,
        the paper's §4 design point.  Internally each node carries the raw
        time-area ``A_{a,b} = ∫_a^b (W_r - W_a) dr`` alongside ``W_{a,b}``;
        bisection samples the left child's ``(w, A)`` jointly conditional on
        the parent pair (exact Gaussian conditioning at an arbitrary split
        fraction — off the midpoint the conditional cross-covariance is
        non-zero, so ``a₁`` is drawn conditionally on the realised ``w₁``),
        and the right child is the algebraic complement.  Combining a query's
        node list left to right uses the chen relation
        ``A_{s,t} = Σᵢ (aᵢ + dtᵢ · W_acc)``; ``H = A/(t-s) - W/2``.
    """

    def __init__(
        self,
        t0: float,
        t1: float,
        shape: Tuple[int, ...],
        seed: int = 0,
        cache_size: int = 128,
        preplant_dt: Optional[float] = None,
        dtype=np.float64,
        levy_area: Optional[str] = None,
    ):
        assert t1 > t0
        if levy_area not in (None, "space-time"):
            raise ValueError(
                f"unknown levy_area mode {levy_area!r}; supported: "
                f"(None, 'space-time')")
        self.t0, self.t1 = float(t0), float(t1)
        self.shape = tuple(shape)
        self.dtype = dtype
        self.levy_area = levy_area
        self._root = _Node(self.t0, self.t1, seed, None)
        self._cache = _LRU(cache_size)
        self._hint: _Node = self._root
        if preplant_dt is not None:
            leaf = max(preplant_dt * cache_size * 0.8, 1e-12)
            self._preplant(self._root, leaf)

    # -- public API ----------------------------------------------------------
    def __call__(self, s: float, t: float):
        """Exact ``W_t - W_s`` — or the ``(W, H)`` pair in space-time mode."""
        if not (self.t0 <= s < t <= self.t1):
            raise ValueError(f"query [{s}, {t}] outside [{self.t0}, {self.t1}]")
        nodes = self._traverse(self._hint, s, t)
        self._hint = nodes[-1]
        if self.levy_area == "space-time":
            w_acc = np.zeros(self.shape, self.dtype)
            a_acc = np.zeros(self.shape, self.dtype)
            for n in nodes:
                w_i, a_i = self._sample(n)
                a_acc += a_i + (n.b - n.a) * w_acc
                w_acc += w_i
            return w_acc, a_acc / (t - s) - 0.5 * w_acc
        out = np.zeros(self.shape, self.dtype)
        for n in nodes:
            out += self._sample(n)
        return out

    @property
    def cache_stats(self) -> Tuple[int, int]:
        return self._cache.hits, self._cache.misses

    # -- Algorithm 3: sample -------------------------------------------------
    def _base_normal(self, seed: int, scale: float) -> np.ndarray:
        g = np.random.Generator(np.random.Philox(key=seed & ((1 << 64) - 1)))
        return g.normal(0.0, scale, size=self.shape).astype(self.dtype, copy=False)

    def _bridge(self, a: float, b: float, x: float, w_parent: np.ndarray, seed: int) -> np.ndarray:
        """Lévy bridge (paper eq. (8)): sample W_{a,x} | W_{a,b} = w_parent."""
        mean = (x - a) / (b - a) * w_parent
        std = np.sqrt((b - x) * (x - a) / (b - a))
        g = np.random.Generator(np.random.Philox(key=seed & ((1 << 64) - 1)))
        return mean + std * g.standard_normal(self.shape).astype(self.dtype, copy=False)

    def _root_pair(self, seed: int) -> Tuple[np.ndarray, np.ndarray]:
        """Unconditional ``(W, A)`` over the whole interval: ``W ~ N(0, h)``,
        ``H ~ N(0, h/12)`` independent, ``A = h(H + W/2)``."""
        h = self.t1 - self.t0
        g = np.random.Generator(np.random.Philox(key=seed & ((1 << 64) - 1)))
        w = g.normal(0.0, np.sqrt(h), size=self.shape).astype(self.dtype, copy=False)
        hh = g.normal(0.0, np.sqrt(h / 12.0), size=self.shape).astype(self.dtype, copy=False)
        return w, h * (hh + 0.5 * w)

    def _bridge_pair(self, a: float, b: float, x: float,
                     parent: Tuple[np.ndarray, np.ndarray],
                     seed: int) -> Tuple[np.ndarray, np.ndarray]:
        """Left-child ``(w₁, A₁)`` over ``[a, x]`` conditional on the parent
        pair over ``[a, b]`` — exact Gaussian conditioning at split fraction
        ``θ = (x-a)/(b-a)`` (the (W, A) generalisation of eq. (8)):

            E[w₁]   = (3θ² - 2θ)·w + 6θ(1-θ)·A/h        Var = hθ(1-4θ+6θ²-3θ³)
            E[A₁]   = -hθ²(1-θ)·w + (3θ² - 2θ³)·A       Var = (h³/3)θ³(1-θ)³
            Cov(w₁, A₁ | w, A) = h²θ²(1-θ)²(1-2θ)/2

        The conditional cross-covariance vanishes only at the midpoint, so
        ``A₁`` is sampled conditionally on the realised ``w₁``.
        """
        w, area = parent
        h = b - a
        th = (x - a) / h
        g = np.random.Generator(np.random.Philox(key=seed & ((1 << 64) - 1)))
        xi0 = g.standard_normal(self.shape).astype(self.dtype, copy=False)
        xi1 = g.standard_normal(self.shape).astype(self.dtype, copy=False)
        mean_w = (3.0 * th * th - 2.0 * th) * w + 6.0 * th * (1.0 - th) * area / h
        var_w = h * th * (1.0 - 4.0 * th + 6.0 * th * th - 3.0 * th ** 3)
        var_w = max(var_w, 0.0)
        w1 = mean_w + np.sqrt(var_w) * xi0
        mean_a = -h * th * th * (1.0 - th) * w + (3.0 * th * th - 2.0 * th ** 3) * area
        var_a = (h ** 3 / 3.0) * th ** 3 * (1.0 - th) ** 3
        cov = 0.5 * h * h * th * th * (1.0 - th) ** 2 * (1.0 - 2.0 * th)
        if var_w > 0.0:
            mean_a = mean_a + (cov / var_w) * (w1 - mean_w)
            var_a = var_a - cov * cov / var_w
        a1 = mean_a + np.sqrt(max(var_a, 0.0)) * xi1
        return w1, a1

    def _sample(self, node: _Node):
        cached = self._cache.get(id(node))
        if cached is not None:
            return cached
        pairs = self.levy_area == "space-time"
        if node is self._root:
            out = (self._root_pair(node.seed) if pairs else
                   self._base_normal(node.seed, np.sqrt(self.t1 - self.t0)))
        else:
            parent = node.parent
            w_parent = self._sample(parent)
            left = parent.left
            if pairs:
                w1, a1 = self._bridge_pair(parent.a, parent.b, left.b,
                                           w_parent, left.seed)
                if node is parent.right:
                    # complement: W₂ = W - w₁; A₂ = A - A₁ - (b - x)·w₁
                    wp, ap = w_parent
                    out = (wp - w1, ap - a1 - (parent.b - left.b) * w1)
                else:
                    out = (w1, a1)
            elif node is parent.right:
                # W_{mid, b} = W_{a, b} - W_{a, mid}
                w_left = self._bridge(parent.a, parent.b, left.b, w_parent, left.seed)
                out = w_parent - w_left
            else:
                out = self._bridge(parent.a, parent.b, node.b, w_parent, node.seed)
        self._cache.put(id(node), out)
        return out

    # -- Algorithm 4: traverse -------------------------------------------------
    def _bisect(self, node: _Node, x: float) -> None:
        s_left, s_right = _split_seed(node.seed)
        node.left = _Node(node.a, x, s_left, node)
        node.right = _Node(x, node.b, s_right, node)

    def _traverse(self, start: _Node, c: float, d: float) -> List[_Node]:
        nodes: List[_Node] = []
        # Iterative (trampolined) version of Algorithm 4 — the paper notes
        # recursion depth errors otherwise ("Recursion errors", App. E).
        stack: List[Tuple[_Node, float, float]] = [(start, c, d)]
        while stack:
            node, lo, hi = stack.pop()
            # outside our jurisdiction — pass to parent
            while lo < node.a or hi > node.b:
                node = node.parent
            if lo == node.a and hi == node.b:
                nodes.append(node)
                continue
            if node.left is None:  # leaf
                if node.a == lo:
                    self._bisect(node, hi)
                    nodes.append(node.left)
                else:
                    self._bisect(node, lo)
                    stack.append((node.right, lo, hi))
                continue
            m = node.left.b
            if hi <= m:
                stack.append((node.left, lo, hi))
            elif lo >= m:
                stack.append((node.right, lo, hi))
            else:
                # split across both children; keep left-to-right output order
                stack.append((node.right, m, hi))
                stack.append((node.left, lo, m))
        return nodes

    def _preplant(self, node: _Node, leaf_size: float) -> None:
        stack = [node]
        while stack:
            n = stack.pop()
            if (n.b - n.a) <= leaf_size:
                continue
            self._bisect(n, 0.5 * (n.a + n.b))
            stack.extend((n.left, n.right))


class HostVirtualBrownianTree:
    """Host-side Virtual Brownian Tree baseline (Li et al. [15]).

    Every query runs the full ``O(log(1/eps))`` dyadic descent from the root —
    no cache, no tree growth, approximate at resolution ``eps``.
    """

    def __init__(self, t0: float, t1: float, shape, seed: int = 0, eps: float = 1e-5, dtype=np.float64):
        self.t0, self.t1 = float(t0), float(t1)
        self.shape = tuple(shape)
        self.eps = eps
        self.seed = seed
        self.dtype = dtype
        import math

        self._depth = max(1, int(math.ceil(math.log2((t1 - t0) / eps))))

    def _w(self, t: float) -> np.ndarray:
        g = np.random.Generator(np.random.Philox(key=self.seed))
        w_a = np.zeros(self.shape, self.dtype)
        w_b = g.standard_normal(self.shape).astype(self.dtype) * np.sqrt(self.t1 - self.t0)
        a, b = self.t0, self.t1
        seed = self.seed
        for _ in range(self._depth):
            m = 0.5 * (a + b)
            s_left, s_right = _split_seed(seed)
            gm = np.random.Generator(np.random.Philox(key=s_left))
            std = np.sqrt((b - m) * (m - a) / (b - a))
            w_m = 0.5 * (w_a + w_b) + std * gm.standard_normal(self.shape).astype(self.dtype)
            if t <= m:
                b, w_b, seed = m, w_m, s_left
            else:
                a, w_a, seed = m, w_m, s_right
            if (b - a) <= self.eps:
                break
        return w_a

    def __call__(self, s: float, t: float) -> np.ndarray:
        return self._w(t) - self._w(s)
