"""Recursive binomial checkpointing: memory-bounded exact gradients for
EVERY registered solver (``gradient_mode="checkpoint"``).

The capability gap this closes: ``reversible_adjoint`` is exact and O(1)
memory but exists only for the algebraically reversible Heun pair, while
euler-maruyama/midpoint/heun had to choose between O(n) activations
(``discretise``) and O(√h) gradient *error* (``continuous_adjoint``).
Recursive checkpointing (McCallum & Foster, arXiv:2410.11648) is the
frontier between those: gradients are **exact to floating point** (they
are discretise-then-optimise gradients, just rematerialised) at O(log n)
live residuals and O(n log n) recompute.

The schedule is recursive halving, built as ``ceil(log2 n)`` nested
levels of two-iteration ``lax.scan`` whose bodies run under
:func:`jax.checkpoint`: a level-``k`` runner advances ``2^k`` steps by
scanning its rematerialised level-``k-1`` runner twice.  A checkpointed
body saves only its entry carry, so the forward stores two carries per
level and the backward re-runs one half at a time — at any moment at most
one root-to-leaf path of segment carries is live: ``O(log2 n)`` solver
states, each step recomputed once per level above it
(:func:`checkpoint_schedule` derives the exact counts; the benchmark
gates against them).  Nesting scans instead of unrolling the recursion
keeps the *program* O(log n) too — compile time does not grow with the
horizon.  Brownian increments are drawn *inside* the checkpointed regions
from the counter-based path, so noise is regenerated, never stored — the
same principle as the exact adjoint's replay (paper §4).  Horizons that
are not a power of two pad the step index up and mask the surplus steps
to the identity (their field evaluations get zero cotangent, so gradients
see exactly the ``n`` real steps).

Adaptive solves compose via a freeze-and-replay split: the accept/reject
controller runs once under ``stop_gradient`` (``lax.while_loop`` has no
reverse rule, and gradients must not flow through the controller's
discrete accept decisions anyway), fixing the accepted ``(ts, dts,
num_accepted)`` scalars; the differentiable path then *replays* the
accepted grid over the padded ``max_steps`` buffer under the same
recursive schedule, masking padding slots with ``jnp.where``.  Each
replayed step re-derives its increment with the driver's own
value-difference expression, so the replayed terminal state is
bit-identical to the controller's.  Cost: one extra (gradient-free)
forward pass.

Terminal-value cotangents only: a trajectory output is itself O(n)
memory, which is exactly what this backend exists to avoid —
``save_trajectory=True`` is rejected eagerly.
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
from jax import lax

from ..brownian import stlevy_difference
from ..solvers import RevHeunState, _tree_cast, reversible_heun_step
from .base import GradientBackend, register_backend

__all__ = [
    "checkpoint_schedule",
    "checkpoint_solve",
    "checkpoint_solve_adaptive",
]


def _carry_init(spec, drift, diffusion, params, z0, t0):
    """Solver carry at ``t0`` — stepper-generic, like the adaptive driver."""
    if spec.stepper is reversible_heun_step:
        return RevHeunState(z0, z0, drift(params, t0, z0),
                            diffusion(params, t0, z0))
    return z0


def _carry_z(spec, carry):
    return carry.z if spec.stepper is reversible_heun_step else carry


def _chain(step, num_steps):
    """Compose ``num_steps`` steps under the recursive-halving schedule.

    Returns ``(carry, params) -> carry``.  ``step`` is ``(carry, params,
    i) -> carry`` with ``i`` a traced int32 step index.  ``params`` is
    threaded as an explicit argument so ``jax.checkpoint`` treats it as an
    input (always available to the backward pass) rather than a
    per-segment residual.  Non-power-of-two horizons are the caller's
    problem: ``num_steps`` is padded up and ``step`` must mask ``i >=
    num_steps`` to the identity.
    """
    depth = max(0, math.ceil(math.log2(num_steps))) if num_steps > 1 else 0

    def runner(k):
        """``(carry, params, base) -> carry`` advancing steps
        ``[base, base + 2^k)``."""
        if k == 0:
            return lambda carry, params, base: step(carry, params, base)
        half = 2 ** (k - 1)
        inner = jax.checkpoint(runner(k - 1))

        def run(carry, params, base):
            def body(c, j):
                return inner(c, params, base + j * half), None

            out, _ = lax.scan(body, carry, jnp.arange(2, dtype=jnp.int32))
            return out

        return run

    top = runner(depth)
    return lambda carry, params: top(
        carry, params, jnp.asarray(0, jnp.int32))


def checkpoint_solve(spec, drift, diffusion, params, z0, bm, t0, t1,
                     num_steps, noise):
    """Terminal value ``z_T``; AD through it follows the halving schedule.

    The per-step math is ``spec.stepper`` verbatim on the uniform grid —
    the same ops, in the same order, as the discretise-mode scan — so the
    gradients agree with discretise-then-optimise to floating-point error
    while peak residual memory follows :func:`checkpoint_schedule`.
    """
    dt = (t1 - t0) / num_steps
    dtype = z0.dtype

    def step(carry, params_, i):
        j = jnp.minimum(i, num_steps - 1)  # pad-to-pow2 slots clamp in-range
        t = t0 + j * dt
        # drawn inside the checkpointed region: regenerated on remat, not
        # stored (counter-based threefry — cheap relative to a field eval)
        dw = _tree_cast(bm.increment(j, num_steps), dtype)
        new = spec.stepper(carry, t, dt, dw, drift, diffusion, params_,
                           noise)
        return jax.tree.map(
            lambda a, b: jnp.where(i < num_steps, a, b), new, carry)

    carry0 = _carry_init(spec, drift, diffusion, params, z0, t0)
    return _carry_z(spec, _chain(step, num_steps)(carry0, params))


def checkpoint_solve_adaptive(spec, drift, diffusion, params, z0, bm,
                              rtol, atol, t0, t1, max_steps, dt0, noise,
                              bridge_depth=None):
    """``(z_T, converged)`` over the controller's accepted grid.

    Freeze-and-replay: the PI-controlled driver fixes the accepted
    ``(ts, dts)`` under ``stop_gradient``; the checkpointed replay over
    the padded buffer is the differentiable path.  ``dw`` uses the same
    value-difference (astype order AND bridge depth) as the forward
    driver, so each replayed step is bit-identical to the accepted one.
    """
    from ..solve import _adaptive_loop

    _, stats = _adaptive_loop(
        spec, drift, diffusion, lax.stop_gradient(params),
        lax.stop_gradient(z0), bm, t0, t1, lax.stop_gradient(rtol),
        lax.stop_gradient(atol), max_steps, dt0, noise,
        bridge_depth=bridge_depth)
    ts = lax.stop_gradient(stats.ts)
    dts = lax.stop_gradient(stats.dts)
    n_acc = lax.stop_gradient(stats.num_accepted)

    dtype = z0.dtype
    has_value = hasattr(bm, "value")
    levy = getattr(bm, "levy_area", None) == "space-time"
    dkw = {} if bridge_depth is None else {"depth": bridge_depth}

    def step(carry, params_, i):
        j = jnp.minimum(i, max_steps - 1)  # pad-to-pow2 slots clamp in-range
        t_left = ts[j]
        dt = dts[j]
        if has_value:
            val_l = _tree_cast(bm.value(t_left, **dkw), dtype)
            val_r = _tree_cast(bm.value(t_left + dt, **dkw), dtype)
            if levy:
                dw = stlevy_difference(val_l, val_r, t_left, t_left + dt,
                                       bm.t0)
            else:
                dw = val_r - val_l
        else:
            dw = _tree_cast(bm.evaluate(t_left, t_left + dt, **dkw), dtype)
        new = spec.stepper(carry, t_left, dt, dw, drift, diffusion,
                           params_, noise)
        # padding slots (dt = 0, dw = 0) still evaluate the fields — at
        # the carried state, so they stay finite — and are masked out here
        return jax.tree.map(
            lambda a, b: jnp.where(i < n_acc, a, b), new, carry)

    carry0 = _carry_init(spec, drift, diffusion, params, z0, t0)
    z = _carry_z(spec, _chain(step, max_steps)(carry0, params))
    return z, stats.converged


# =============================================================================
# Schedule cost model (the benchmark's memory gate)
# =============================================================================


@lru_cache(maxsize=None)
def _peak_live(depth: int) -> int:
    """Max simultaneously-live solver carries while differentiating a
    level-``depth`` runner (the leaf's own step residuals count as 1).

    A scan over a checkpointed body stores exactly the per-iteration
    entry carries (2 of them); the backward holds those while recursing
    into one half at a time: ``L(k) = 2 + L(k-1)``, ``L(0) = 1``.
    """
    if depth <= 0:
        return 1
    return 2 + _peak_live(depth - 1)


@lru_cache(maxsize=None)
def _recompute(depth: int) -> int:
    """Extra forward step evaluations the backward over a level-``depth``
    runner performs: each of the scan's 2 iterations re-runs its remat'd
    inner forward (``2^(k-1)`` steps) before differentiating it —
    ``R(k) = 2 * (2^(k-1) + R(k-1))``, ``R(0) = 0``, i.e. ``k * 2^k``.
    """
    if depth <= 0:
        return 0
    return 2 * (2 ** (depth - 1) + _recompute(depth - 1))


def checkpoint_schedule(num_steps: int) -> dict:
    """Exact cost model of the nested-scan halving schedule.

    Non-power-of-two horizons run padded to ``padded = 2^depth`` with the
    surplus steps masked to identity (they still cost recompute — the
    schedule is shape-static).  Returns ``depth`` (= ceil(log2 n)),
    ``peak_live_states`` (solver carries simultaneously resident during
    the backward sweep — the O(log n) bound: ``2 * depth + 1``), and
    ``recompute_steps`` (extra step evaluations beyond the forward's
    ``padded`` — the O(n log n) bound: ``depth * padded``).
    benchmarks/gradient_error.py multiplies ``peak_live_states`` by the
    carry byte-size and gates the product against the log-model; tests
    pin the recursion itself.
    """
    if num_steps < 1:
        raise ValueError(f"num_steps must be >= 1, got {num_steps}")
    depth = max(0, math.ceil(math.log2(num_steps))) if num_steps > 1 else 0
    return {
        "num_steps": num_steps,
        "padded_steps": 2 ** depth,
        "depth": depth,
        "peak_live_states": _peak_live(depth),
        "recompute_steps": _recompute(depth),
    }


# =============================================================================
# Backend registration
# =============================================================================


def _validate(spec, *, noise, save_trajectory, use_pallas, adaptive):
    if save_trajectory:
        raise ValueError(
            "gradient_mode='checkpoint' backpropagates a terminal-value "
            "cotangent only (a trajectory output is itself the O(n) "
            "memory this backend exists to avoid) — call solve(..., "
            "save_trajectory=False)")
    if use_pallas:
        raise ValueError(
            "use_pallas_kernels is incompatible with gradient_mode="
            "'checkpoint': the rematerialised segments are differentiated "
            "by plain AD, which cannot trace a pallas_call (the fused "
            "derivative lives in the reversible-adjoint custom_vjp).  Use "
            "gradient_mode='reversible_adjoint' for the fused path")


def _solve(spec, drift, diffusion, params, z0, bm, t0, t1, num_steps, *,
           noise, save_trajectory, use_pallas):
    return checkpoint_solve(spec, drift, diffusion, params, z0, bm, t0, t1,
                            num_steps, noise)


def _solve_adaptive(spec, drift, diffusion, params, z0, bm, rtol, atol,
                    t0, t1, max_steps, dt0, *, noise, use_pallas,
                    bridge_depth):
    return checkpoint_solve_adaptive(
        spec, drift, diffusion, params, z0, bm, rtol, atol, t0, t1,
        max_steps, dt0, noise, bridge_depth=bridge_depth)


register_backend(GradientBackend(
    name="checkpoint",
    summary="recursive binomial checkpointing: exact gradients, "
            "O(log n) memory, O(n log n) recompute",
    terminal_only=True,
    supports_adaptive=True,
    solve=_solve,
    solve_adaptive=_solve_adaptive,
    validate=_validate,
))
