"""Version-portable mesh APIs.

The mesh surface moved between JAX releases: ``jax.sharding.get_abstract_mesh``
/ ``jax.set_mesh`` / ``jax.sharding.AxisType`` only exist on newer versions,
while older releases activate a mesh with ``with mesh:`` and track it in
``jax._src.mesh.thread_resources``.  Everything in repro that needs the
*ambient* mesh (sharding rules, launch plumbing, tests) goes through this
module so the rest of the codebase is written against one API.

Four helpers:

* :func:`ambient_mesh` — the currently active (abstract or concrete) mesh,
  or ``None`` when unsharded.
* :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` passed through
  only where supported.
* :func:`set_mesh` — context manager activating a mesh (``jax.set_mesh`` on
  new JAX, the mesh's own context manager on old).
* :func:`abstract_mesh` — construct an ``AbstractMesh`` across both
  constructor signatures (shape-tuple vs axis_shapes/axis_names).
"""

from __future__ import annotations

import contextlib
from typing import Sequence

import jax


def _auto_axis_types(n: int):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def ambient_mesh():
    """Return the active mesh (``Mesh`` or ``AbstractMesh``) or ``None``.

    Checks the new-style ambient abstract mesh first (``jax.set_mesh``),
    then the legacy ``with mesh:`` thread-resources slot.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        try:
            am = get()
        except Exception:  # pragma: no cover - defensive
            am = None
        if am is not None and hasattr(am, "axis_names") and not am.empty:
            return am
    try:
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:  # pragma: no cover - internal layout moved
        pass
    return None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` that only requests Auto axis types where they exist."""
    types = _auto_axis_types(len(tuple(axis_names)))
    if types is not None:
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=types)
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


@contextlib.contextmanager
def set_mesh(mesh):
    """Activate ``mesh`` for the dynamic extent of the block."""
    setter = getattr(jax, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def force_host_device_count(n: int) -> None:
    """Simulate ``n`` CPU devices (the ``--host-devices`` flag of
    launch/train.py and launch/serve.py) by appending
    ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``.

    Must run before the XLA backend initialises — once it is up the flag
    would be silently ignored, so this raises instead."""
    import os

    try:  # backend already up ⇒ the flag would be silently ignored
        initialised = bool(jax._src.xla_bridge._backends)
    except AttributeError:  # internal layout moved; trust the caller
        initialised = False
    if initialised:
        raise RuntimeError("--host-devices must be processed before jax "
                           "initialises; set XLA_FLAGS instead")
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}")


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Construct ``jax.sharding.AbstractMesh`` on either constructor API."""
    shapes = tuple(axis_shapes)
    names = tuple(axis_names)
    types = _auto_axis_types(len(names))
    if types is not None:
        try:
            return jax.sharding.AbstractMesh(shapes, names, axis_types=types)
        except TypeError:
            pass
    try:
        return jax.sharding.AbstractMesh(shapes, names)
    except TypeError:
        # oldest signature: a single tuple of (name, size) pairs
        return jax.sharding.AbstractMesh(tuple(zip(names, shapes)))
