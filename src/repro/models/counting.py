"""Analytic parameter counts per architecture (roofline MODEL_FLOPS = 6·N·D).

Counts mirror exactly what :mod:`repro.models.transformer` initialises — any
drift between the two is caught by ``tests/test_models.py::test_param_count``
which compares against the real pytree leaf sizes on the smoke configs.
"""

from __future__ import annotations

from ..configs.base import ArchConfig


def _attn_params(cfg: ArchConfig) -> int:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.attention == "mla":
        rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        n = d * rq + rq            # wq_a + q_ln
        n += rq * cfg.num_heads * (dn + dr)            # wq_b
        n += d * (rkv + dr) + rkv                      # wkv_a + kv_ln
        n += rkv * cfg.num_heads * (dn + dv)           # wkv_b
        n += cfg.num_heads * dv * d                    # wo
        return n
    n = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
    if cfg.qkv_bias:
        n += hq * hd + 2 * hkv * hd
    return n


def _ffn_params(cfg: ArchConfig, d_ff: int | None = None) -> int:
    d, f = cfg.d_model, cfg.d_ff if d_ff is None else d_ff
    n = 2 * d * f                       # up + down
    if cfg.ffn == "swiglu":
        n += d * f                      # gate
    return n


def _moe_params(cfg: ArchConfig, active_only: bool = False) -> int:
    e = cfg.top_k if active_only else cfg.num_experts
    return cfg.d_model * cfg.num_experts + e * _ffn_params(cfg)  # router + experts


def _mamba_params(cfg: ArchConfig) -> int:
    d, di, n, h, k = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    conv_dim = di + 2 * n
    total = d * (2 * di + 2 * n + h)    # in_proj
    total += k * conv_dim + conv_dim    # conv
    total += 3 * h                      # A_log, dt_bias, Dskip
    total += di                         # norm_g
    total += di * d                     # out_proj
    return total


def _norm_params(cfg: ArchConfig) -> int:
    return 2 * cfg.d_model if cfg.norm == "layernorm" else cfg.d_model


def _layer_params(cfg: ArchConfig, layer_idx: int, active_only: bool) -> int:
    """One block of the stack at global index ``layer_idx``."""
    n = 0
    if cfg.ssm:                                              # pure SSM stack
        return _mamba_params(cfg) + _norm_params(cfg)
    if cfg.family == "hybrid":
        is_attn = (layer_idx % cfg.attn_every) == 0
        mixer = _attn_params(cfg) if is_attn else _mamba_params(cfg)
        is_moe = cfg.moe and (layer_idx % cfg.moe_every) == 1
        ffn = _moe_params(cfg, active_only) if is_moe else _ffn_params(cfg)
        return mixer + ffn + 2 * _norm_params(cfg)
    # homogeneous transformer block
    n += _attn_params(cfg)
    n += _moe_params(cfg, active_only) if cfg.moe else _ffn_params(cfg)
    n += 2 * _norm_params(cfg)
    return n


def param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    """Total (or routing-active) parameter count of the full model."""
    n = cfg.vocab * cfg.d_model                              # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab * cfg.d_model                         # head
    n += _norm_params(cfg)                                   # final norm
    for i in range(cfg.num_layers):
        n += _layer_params(cfg, i, active_only)
    if cfg.encoder_layers:
        # encoder blocks: self-attn + ffn; decoder adds cross-attn per block
        enc = cfg.encoder_layers * (_attn_params(cfg) + _ffn_params(cfg) + 2 * _norm_params(cfg))
        cross = cfg.num_layers * (_attn_params(cfg) + _norm_params(cfg))
        n += enc + cross + _norm_params(cfg)                 # + encoder final norm
    return n


def model_flops_per_token(cfg: ArchConfig) -> int:
    """6·N_active — the standard training-FLOPs-per-token estimate."""
    return 6 * param_count(cfg, active_only=True)
