"""Mamba2 SSD (state-space duality) chunk scan as a Pallas TPU kernel.

The SSD recurrence  h_t = e^{a_t} h_{t-1} + b_t ⊗ x_t,  y_t = c_tᵀ h_t  is
sequential, but the chunked dual form turns it into MXU matmuls:

  per chunk (length L):  cum_t = Σ_{u≤t} a_u
    intra:  Y += [(C Bᵀ) ⊙ e^{cum_t - cum_s} ⊙ 1(s≤t)] X         (L×L)·(L×P)
    inter:  Y += e^{cum} ⊙ (C H_prev)                            (L×N)·(N×P)
    state:  H ← e^{cum_L} H_prev + (B ⊙ e^{cum_L - cum})ᵀ X      (N×L)·(L×P)

TPU mapping: the grid is (batch·heads, num_chunks) with the chunk axis
innermost — TPU grids execute sequentially, so the inter-chunk state lives
in VMEM scratch and never touches HBM.  All three products are MXU shapes
(L, N, P ∈ {64, 128}).  This is the layer that makes `long_500k` linear-time
for the mamba2/jamba architectures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(L, x_ref, a_ref, b_ref, c_ref, y_ref, h_ref):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)   # (L, P)
    a = a_ref[0].astype(jnp.float32)   # (L,)
    b = b_ref[0].astype(jnp.float32)   # (L, N)
    c = c_ref[0].astype(jnp.float32)   # (L, N)

    cum = jnp.cumsum(a)                # inclusive (L,)
    # intra-chunk: decay(t, s) = exp(cum_t - cum_s) for s <= t
    s_mat = jnp.dot(c, b.T, preferred_element_type=jnp.float32)      # (L, L)
    ti = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    s_mat = jnp.where(ti >= si, s_mat * decay, 0.0)
    y = jnp.dot(s_mat, x, preferred_element_type=jnp.float32)        # (L, P)
    # inter-chunk: contribution of the carried state
    y = y + jnp.exp(cum)[:, None] * jnp.dot(
        c, h_ref[...], preferred_element_type=jnp.float32)           # (L, P)
    # state update
    b_scaled = b * jnp.exp(cum[-1] - cum)[:, None]                   # (L, N)
    h_ref[...] = jnp.exp(cum[-1]) * h_ref[...] + jnp.dot(
        b_scaled.T, x, preferred_element_type=jnp.float32)           # (N, P)
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk(
    x: jax.Array,   # (B, H, S, P)
    a: jax.Array,   # (B, H, S)   log-decay (<= 0)
    b: jax.Array,   # (B, H, S, N)
    c: jax.Array,   # (B, H, S, N)
    chunk: int = 64,
    interpret: bool = True,
):
    B, H, S, P = x.shape
    N = b.shape[-1]
    L = min(chunk, S)
    while S % L:
        L //= 2
    BH = B * H
    xf = x.reshape(BH, S, P)
    af = a.reshape(BH, S)
    bf = b.reshape(BH, S, N)
    cf = c.reshape(BH, S, N)
    grid = (BH, S // L)
    out = pl.pallas_call(
        functools.partial(_kernel, L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, L), lambda i, j: (i, j)),
            pl.BlockSpec((1, L, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, L, N), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, P), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xf, af, bf, cf)
    return out.reshape(B, H, S, P)
