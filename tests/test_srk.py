"""Strong-order-1.5 SRK solver tests (DESIGN.md §13).

The scheme is the Kloeden–Platen explicit order-1.5 method for Itô
diagonal noise, consuming (ΔW, ΔH) pairs from a ``levy_area="space-time"``
Brownian path.  Tested here: registry capabilities and eager rejections,
gradient-backend agreement (checkpoint == discretise to roundoff),
adaptive composition, exactness properties the tableau implies, and the
dt=0 padding-slot NaN guard the checkpoint replay relies on.  The
empirical order-1.5 slope is gated in benchmarks/convergence.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.brownian import BrownianPath, DenseBrownianPath
from repro.core.solve import get_solver, solve, solve_adaptive, solve_batched
from repro.core.solvers import NFE_PER_STEP, _srk_embedded_step


def _gbm():
    drift = lambda p, t, z: p * z
    diffusion = lambda p, t, z: 0.4 * z
    return drift, diffusion


def _levy_bm(seed=5, shape=(), dtype=jnp.float32):
    return BrownianPath(jax.random.PRNGKey(seed), 0.0, 1.0, shape, dtype,
                        levy_area="space-time")


# -----------------------------------------------------------------------------
# registry + eager validation
# -----------------------------------------------------------------------------


def test_srk_spec_registered():
    spec = get_solver("srk")
    assert spec.strong_order == 1.5
    assert spec.needs_levy_area
    assert spec.noise_types == ("diagonal",)
    assert spec.sde_type == "ito"
    assert spec.embedded_stepper is not None
    assert not spec.reversible
    assert NFE_PER_STEP["srk"] == spec.nfe_per_step == 5


def test_srk_eager_rejections():
    drift, diffusion = _gbm()
    bm = _levy_bm()
    z0 = jnp.asarray(1.0)
    with pytest.raises(ValueError, match="reversible_adjoint"):
        solve(drift, diffusion, 0.7, z0, bm, 0.0, 1.0, 8, solver="srk",
              gradient_mode="reversible_adjoint", save_trajectory=False)
    with pytest.raises(ValueError, match="Pallas"):
        solve(drift, diffusion, 0.7, z0, bm, 0.0, 1.0, 8, solver="srk",
              use_pallas_kernels=True, save_trajectory=False)
    with pytest.raises(ValueError, match="noise"):
        solve(drift, diffusion, 0.7, z0, bm, 0.0, 1.0, 8, solver="srk",
              noise="general", save_trajectory=False)
    # path-mode mismatches, both directions
    plain = BrownianPath(jax.random.PRNGKey(5), 0.0, 1.0, ())
    with pytest.raises(ValueError, match="space-time"):
        solve(drift, diffusion, 0.7, z0, plain, 0.0, 1.0, 8, solver="srk",
              save_trajectory=False)
    with pytest.raises(ValueError, match="space-time"):
        solve(drift, diffusion, 0.7, z0, bm, 0.0, 1.0, 8, solver="heun",
              save_trajectory=False)


def test_srk_stepper_rejects_bare_dw():
    drift, diffusion = _gbm()
    with pytest.raises(TypeError, match="space-time"):
        _srk_embedded_step(jnp.asarray(1.0), 0.0, 0.125, jnp.asarray(0.1),
                           drift, diffusion, 0.7, "diagonal")


# -----------------------------------------------------------------------------
# solve paths
# -----------------------------------------------------------------------------


def test_srk_fixed_grid_runs_and_saves_trajectory():
    drift, diffusion = _gbm()
    traj = solve(drift, diffusion, 0.7, jnp.asarray(1.0), _levy_bm(),
                 0.0, 1.0, 16, solver="srk")
    assert traj.shape == (17,)
    assert bool(jnp.all(jnp.isfinite(traj)))
    assert float(traj[0]) == 1.0


def test_srk_checkpoint_matches_discretise_gradients():
    """Checkpointing is a rematerialisation of the same discrete scheme —
    gradients agree to f64 roundoff."""
    jax.config.update("jax_enable_x64", True)
    try:
        drift, diffusion = _gbm()
        bm = _levy_bm(dtype=jnp.float64)

        def loss(p, mode):
            return solve(drift, diffusion, p, jnp.asarray(1.0, jnp.float64),
                         bm, 0.0, 1.0, 16, solver="srk", gradient_mode=mode,
                         save_trajectory=False)

        g_disc = jax.grad(loss)(jnp.asarray(0.7, jnp.float64), "discretise")
        g_ckpt = jax.grad(loss)(jnp.asarray(0.7, jnp.float64), "checkpoint")
        np.testing.assert_allclose(np.asarray(g_disc), np.asarray(g_ckpt),
                                   rtol=1e-12, atol=1e-14)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_srk_additive_noise_interpolates_exactly_in_w():
    """Additive noise, zero drift: the scheme reduces to z + σΔW exactly
    (every supporting-stage difference vanishes except the b₀ΔW term)."""
    jax.config.update("jax_enable_x64", True)
    try:
        drift = lambda p, t, z: jnp.zeros_like(z)
        diffusion = lambda p, t, z: jnp.full_like(z, 0.3)
        # Dense path: grid increments telescope to value(t1) pathwise
        # (BrownianPath.increment is iid-per-grid by design)
        bm = DenseBrownianPath.sample(jax.random.PRNGKey(9), 0.0, 1.0, 64,
                                      (4,), jnp.float64,
                                      levy_area="space-time")
        z = solve(drift, diffusion, None, jnp.zeros(4, jnp.float64), bm,
                  0.0, 1.0, 8, solver="srk", save_trajectory=False)
        w1, _ = bm.value(1.0)
        np.testing.assert_allclose(np.asarray(z), 0.3 * np.asarray(w1),
                                   rtol=1e-12, atol=1e-14)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_srk_adaptive_composes_and_checkpoint_grad_finite():
    jax.config.update("jax_enable_x64", True)
    try:
        drift, diffusion = _gbm()
        bm = _levy_bm(dtype=jnp.float64)
        z, stats = solve_adaptive(drift, diffusion, jnp.asarray(0.7),
                                  jnp.asarray(1.0, jnp.float64), bm,
                                  0.0, 1.0, solver="srk", rtol=2e-3,
                                  atol=1e-6)
        assert bool(stats.converged)
        assert int(stats.num_accepted) > 0
        assert int(stats.nfe) == 5 * (int(stats.num_accepted)
                                      + int(stats.num_rejected))

        def loss(p):
            return solve(drift, diffusion, p,
                         jnp.asarray(1.0, jnp.float64), bm, 0.0, 1.0, 16,
                         solver="srk", gradient_mode="checkpoint",
                         save_trajectory=False, adaptive=True, rtol=2e-3,
                         atol=1e-6)

        # freeze-and-replay: the replayed primal agrees with the
        # controller's to roundoff (the richer SRK expression graph may
        # fuse differently between the while-loop and nested-scan
        # programs, so this is allclose-tight, not bitwise like the
        # simpler steppers)
        np.testing.assert_allclose(float(loss(jnp.asarray(0.7))), float(z),
                                   rtol=1e-13)
        g = jax.grad(loss)(jnp.asarray(0.7))
        assert bool(jnp.isfinite(g)) and float(g) != 0.0
    finally:
        jax.config.update("jax_enable_x64", False)


def test_srk_dt_zero_padding_step_is_identity_with_clean_gradient():
    """The checkpoint replay's padding slots run the stepper at dt=0 with
    (ΔW, ΔH) = (0, 0); the dt_safe guard must make that an exact identity
    AND keep NaN out of the backward (inf·0 in a mul VJP poisons the
    cotangent even when masked downstream)."""
    drift, diffusion = _gbm()

    z0 = jnp.asarray(1.3)

    def step_terminal(p):
        pair = (jnp.zeros(()), jnp.zeros(()))
        out, err = _srk_embedded_step(z0, 0.0, jnp.asarray(0.0), pair,
                                      drift, diffusion, p, "diagonal")
        return out, err

    out, err = step_terminal(jnp.asarray(0.7))
    assert float(out) == float(z0) and float(err) == 0.0
    g = jax.grad(lambda p: step_terminal(p)[0])(jnp.asarray(0.7))
    assert bool(jnp.isfinite(g))


def test_srk_batched_constructs_levy_paths():
    drift, diffusion = _gbm()
    z = solve_batched(drift, diffusion, 0.7, jnp.ones((4,)),
                      jax.random.split(jax.random.PRNGKey(0), 4),
                      0.0, 1.0, 8, solver="srk", save_trajectory=False)
    assert z.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(z)))


def test_srk_via_config_path():
    """cfg.solver='srk' flows through the sde-module front-end: the
    diagonal-noise Brownian path is rebuilt in space-time mode
    transparently (the serving/train eager-validation path)."""
    from repro.core.sde import NeuralSDEConfig, _cfg_solve

    cfg = NeuralSDEConfig(solver="srk", exact_adjoint=False, num_steps=8)
    drift, diffusion = _gbm()
    bm = BrownianPath(jax.random.PRNGKey(2), 0.0, cfg.t1, (3,), cfg.dtype)
    traj = _cfg_solve(cfg, drift, diffusion, 0.7,
                      jnp.ones(3, cfg.dtype), bm, cfg.num_steps, "diagonal")
    assert traj.shape == (9, 3)
    assert bool(jnp.all(jnp.isfinite(traj)))


def test_srk_strong_error_beats_heun_on_shared_path():
    """On one shared Brownian path, SRK at n=32 beats reversible-Heun-family
    baselines at the same n on GBM terminal error (the order-1.5 claim in
    miniature; the full slope fit is gated in benchmarks/convergence.py)."""
    jax.config.update("jax_enable_x64", True)
    try:
        mu, sig = 0.7, 0.5
        drift = lambda p, t, z: mu * z
        diffusion = lambda p, t, z: sig * z
        paths = 256

        def err_one(solver, levy):
            def one(k):
                dp = DenseBrownianPath.sample(
                    k, 0.0, 1.0, 256, (), jnp.float64,
                    levy_area="space-time" if levy else None)
                z = solve(drift, diffusion, None, jnp.asarray(1.0), dp,
                          0.0, 1.0, 32, solver=solver,
                          save_trajectory=False)
                wT = dp.value(1.0)[0] if levy else dp.value(1.0)
                # Itô GBM pathwise-exact terminal value
                exact = jnp.exp((mu - 0.5 * sig ** 2) + sig * wT)
                return (z - exact) ** 2
            ks = jax.random.split(jax.random.PRNGKey(0), paths)
            return float(jnp.sqrt(jnp.mean(jax.vmap(one)(ks))))

        e_srk = err_one("srk", True)
        e_em = err_one("euler_maruyama", False)
        assert e_srk < 0.2 * e_em, (e_srk, e_em)
    finally:
        jax.config.update("jax_enable_x64", False)
