"""Benchmark suite entrypoint: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--preset tiny|quick|full] [--only NAME]

Each suite prints ``table,label,value`` CSV lines and, on success, emits a
schema-checked ``BENCH_<name>.json`` in the repo root (see
benchmarks/report.py) — the machine-readable perf trajectory that CI's
``bench-smoke`` job gates on.  The roofline harness
(benchmarks/roofline.py) is run separately — it needs the 512-device XLA
flag and hour-scale compiles; see EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (brownian, clipping, convergence, gradient_error, latent_sde,
               report, serving, solver_speed)

SUITES = {
    "gradient_error": gradient_error.main,   # paper Fig. 2 / Table 6
    "solver_speed": solver_speed.main,       # paper Tables 1/4/5 (speed)
    "brownian": brownian.main,               # paper Table 2 / Tables 7-10
    "clipping": clipping.main,               # paper Tables 3/11 (speed)
    "convergence": convergence.main,         # paper Figs. 5/6 (App. D.4)
    "latent_sde": latent_sde.main,           # paper Fig. 2 / App. B on the ELBO
    "serving": serving.main,                 # trajectory-sampling throughput
    "serving_load": serving.main_load,       # open-loop continuous-batching gate
    "serving_async": serving.main_async,     # async front + preemption + pools
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=report.PRESETS, default="full",
                    help="tiny = CI smoke; quick = laptop scale; full = "
                         "paper scale")
    ap.add_argument("--quick", action="store_true",
                    help="alias for --preset quick (back-compat)")
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--list-suites", action="store_true",
                    help="print the suite names, comma-joined, and exit — "
                         "the single source of truth CI's expect-list "
                         "consumes (report.py --validate)")
    args = ap.parse_args(argv)
    if args.list_suites:
        print(",".join(SUITES))
        return 0
    preset = "quick" if args.quick and args.preset == "full" else args.preset

    names = [args.only] if args.only else list(SUITES)
    failures = 0
    for name in names:
        print(f"=== {name} ({preset}) ===", flush=True)
        t0 = time.time()
        try:
            rows = SUITES[name](preset=preset)
            report.write_bench(name, rows, preset)
            print(f"=== {name} done in {time.time()-t0:.1f}s ===", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            import traceback

            traceback.print_exc()
            print(f"=== {name} FAILED: {e} ===", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
