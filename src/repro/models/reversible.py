"""Beyond-paper: the reversible Heun update as a *residual-stack* wrapper.

The paper (Appendix A) notes that residual networks are discretised ODEs.
We close the loop: treat the transformer layer stack as an ODE with a
layer-indexed vector field F(θ_n, ·) = unit_n(x) − x and integrate it with
the paper's OWN reversible Heun scheme (σ = 0, Δt = 1):

    ẑ_{n+1} = 2 z_n − ẑ_n + F(θ_n, ẑ_n)
    z_{n+1} = z_n + ½ (F(θ_n, ẑ_n) + F(θ_{n+1}, ẑ_{n+1}))

Because the update is algebraically reversible, the backward pass
reconstructs every intermediate activation in closed form — training stores
O(1) activations in depth (vs O(L) carried residual-streams under
scan+remat), at the cost of one extra F evaluation per unit on the backward
(same extra count as remat).  Gradients are exact (same custom_vjp as the
SDE adjoint — ``reversible_heun_solve_final``).

Enabled per-arch with ``cfg.reversible_residual=True``; the two-track
scheme is a (slightly) different architecture than the vanilla stack, so it
is a model choice, not a pure execution knob.  Used as the memory-term
hillclimb lever in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.adjoint import reversible_heun_solve_final


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ZeroPath:
    """A Brownian-path stand-in whose increments are identically zero —
    turns the SDE machinery into the deterministic (ODE/resnet) case."""

    dtype: object = jnp.float32

    def tree_flatten(self):
        return (), (self.dtype,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(dtype=aux[0])

    def increment(self, n, num_steps: int):
        return jnp.zeros((), self.dtype)


def reversible_stack(cfg: ArchConfig, stacked_units, x, unit_residual) -> jax.Array:
    """Run the unit stack reversibly.  ``unit_residual(uparams, cfg, x) -> F``
    must return the residual delta of one unit.  Returns the final hidden
    state (terminal value only — nothing O(depth) is materialised)."""
    from .transformer import num_units

    n = num_units(cfg)

    def drift(p, t, z):
        idx = jnp.clip(jnp.asarray(t, jnp.float32).astype(jnp.int32), 0, n - 1)
        uparams = jax.tree.map(lambda a: a[idx], p)
        return unit_residual(uparams, cfg, z)

    def diffusion(p, t, z):
        return jnp.zeros((), z.dtype)   # σ = 0: deterministic stack

    bm = ZeroPath(x.dtype)
    return reversible_heun_solve_final(
        drift, diffusion, stacked_units, x, bm, 0.0, float(n), n, "diagonal")
