"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]

48L d_model=2048 vocab=50280, ssm_state=128, headdim=64, expand=2.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=32,            # unused by the mixer; kept for schema uniformity
    num_kv_heads=32,
    d_ff=0,
    vocab=50280,
    ssm=True,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
    norm="rmsnorm",
)
