"""Architecture-zoo assembly: init / train-forward / prefill / decode.

One code path serves all ten assigned architectures.  The stack is described
as a repeating *unit* of layers (``unit_pattern``): homogeneous archs have a
1-layer unit; jamba's unit is the 8-layer ``lcm(attn_every, moe_every)``
pattern (1 attention + 7 mamba, MoE on odd layers).  Units have identical
pytree structure, so the whole stack is a stacked pytree scanned with
``lax.scan`` (O(1) HLO size at any depth) or unrolled (exact
``cost_analysis`` for the roofline harness) per ``cfg.scan_layers``.

Encoder–decoder (seamless) keeps its own assembly: a bidirectional encoder
stack over stub frame embeddings + a decoder stack with causal self- and
cross-attention.

Modes:
  * train   — full-sequence forward, returns logits (+ MoE aux loss).
  * prefill — forward that also returns the populated cache pytree.
  * decode  — one new token against the cache (``serve_step``).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import nn
from ..configs.base import ArchConfig
from ..distributed.sharding import hint
from . import layers as L

Params = Dict[str, Any]


# =============================================================================
# unit pattern
# =============================================================================


def unit_pattern(cfg: ArchConfig) -> List[Tuple[str, str]]:
    """(mixer, ffn) per layer in the smallest repeating unit of the stack."""
    if cfg.ssm:
        return [("mamba", "none")]
    if cfg.family == "hybrid":
        size = math.lcm(cfg.attn_every, cfg.moe_every if cfg.moe else 1)
        pat = []
        for l in range(size):
            mixer = "attn" if l % cfg.attn_every == 0 else "mamba"
            ffn = "moe" if (cfg.moe and l % cfg.moe_every == 1) else "dense"
            pat.append((mixer, ffn))
        return pat
    if cfg.moe:
        return [("attn", "moe")]
    return [("attn", "dense")]


def num_units(cfg: ArchConfig) -> int:
    size = len(unit_pattern(cfg))
    assert cfg.num_layers % size == 0, (cfg.name, cfg.num_layers, size)
    return cfg.num_layers // size


# =============================================================================
# norms
# =============================================================================


def _norm_init(cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return nn.layernorm_init(cfg.d_model, cfg.dtype)
    return nn.rmsnorm_init(cfg.d_model, cfg.dtype)


def _norm(cfg: ArchConfig, p, x):
    return nn.layernorm(p, x) if cfg.norm == "layernorm" else nn.rmsnorm(p, x)


def _res_hint(cfg: ArchConfig, x):
    """Residual-stream sharding: batch over DP; optionally sequence over the
    model axis (Megatron-SP — the memory-term hillclimb lever)."""
    return hint(x, "dp", "tp" if cfg.sequence_parallel else None, None)


# =============================================================================
# blocks
# =============================================================================


def _mixer_init(key, cfg: ArchConfig, mixer: str):
    if mixer == "attn":
        return L.mla_init(key, cfg) if cfg.attention == "mla" else L.gqa_init(key, cfg)
    return L.mamba2_init(key, cfg)


def _ffn_init(key, cfg: ArchConfig, ffn: str):
    return L.moe_init(key, cfg) if ffn == "moe" else L.ffn_init(key, cfg)


def block_init(key, cfg: ArchConfig, mixer: str, ffn: str) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": _norm_init(cfg), "mixer": _mixer_init(k1, cfg, mixer)}
    if ffn != "none":
        p["ln2"] = _norm_init(cfg)
        p["ffn"] = _ffn_init(k2, cfg, ffn)
    return p


def block_apply(p: Params, cfg: ArchConfig, mixer: str, ffn: str, x,
                causal: bool = True):
    """Full-sequence block.  Returns (x, cache_entry, aux_loss)."""
    h = _norm(cfg, p["ln1"], x)
    if mixer == "attn":
        if cfg.attention == "mla":
            o, (ckv, kpe) = L.mla_attend(p["mixer"], cfg, h, causal=causal)
            cache = {"ckv": ckv, "kpe": kpe}
        else:
            o, (k, v) = L.gqa_attend(p["mixer"], cfg, h, causal=causal)
            cache = {"k": k, "v": v}
    else:
        o, cache = L.mamba2_apply(p["mixer"], cfg, h)
    x = _res_hint(cfg, x + o)
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h2 = _norm(cfg, p["ln2"], x)
        if ffn == "moe":
            f, router_logits = L.moe_apply(p["ffn"], cfg, h2)
            aux = L.moe_aux_loss(router_logits)
        else:
            f = L.ffn_apply(p["ffn"], cfg, h2)
        x = _res_hint(cfg, x + f)
    return x, cache, aux


def block_decode(p: Params, cfg: ArchConfig, mixer: str, ffn: str, x, cache,
                 pos):
    """Single-token block step against ``cache``.  x: (B, 1, D)."""
    h = _norm(cfg, p["ln1"], x)
    if mixer == "attn":
        if cfg.attention == "mla":
            o, cache = L.mla_decode(p["mixer"], cfg, h, cache, pos)
        else:
            o, cache = L.gqa_decode(p["mixer"], cfg, h, cache, pos)
    else:
        o, cache = L.mamba2_decode(p["mixer"], cfg, h, cache, pos)
    x = x + o
    if ffn != "none":
        h2 = _norm(cfg, p["ln2"], x)
        if ffn == "moe":
            f, _ = L.moe_apply(p["ffn"], cfg, h2)
        else:
            f = L.ffn_apply(p["ffn"], cfg, h2)
        x = x + f
    return x, cache


def block_cache_spec(cfg: ArchConfig, mixer: str, batch: int, max_len: int):
    """Abstract (ShapeDtypeStruct) cache entry for one block."""
    dt = cfg.dtype
    if mixer == "attn":
        if cfg.attention == "mla":
            return {
                "ckv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dt),
                "kpe": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_head_dim), dt),
            }
        return {
            "k": jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
            "v": jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
        }
    conv_dim = cfg.ssm_inner + 2 * cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_dim), dt),
        "ssm": jax.ShapeDtypeStruct((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
                                    jnp.float32),
    }


# =============================================================================
# decoder-only LM (dense / moe / ssm / hybrid / vlm frontends)
# =============================================================================


def init_lm(key, cfg: ArchConfig) -> Params:
    pat = unit_pattern(cfg)
    n_units = num_units(cfg)
    ke, kh, ku = jax.random.split(key, 3)
    params: Params = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), cfg.dtype) * 0.02,
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(kh, (cfg.d_model, cfg.vocab), cfg.dtype) \
            / math.sqrt(cfg.d_model)

    def one_unit(k):
        ks = jax.random.split(k, len(pat))
        return [block_init(kk, cfg, m, f) for kk, (m, f) in zip(ks, pat)]

    unit_keys = jax.random.split(ku, n_units)
    units = [one_unit(k) for k in unit_keys]
    params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    if cfg.family == "encdec":
        params.update(_init_encoder(jax.random.fold_in(key, 7), cfg))
    return params


def _unit_apply(uparams, cfg: ArchConfig, x, causal: bool, want_cache: bool):
    pat = unit_pattern(cfg)
    caches, aux = [], jnp.zeros((), jnp.float32)
    for bp, (m, f) in zip(uparams, pat):
        x, c, a = block_apply(bp, cfg, m, f, x, causal=causal)
        aux = aux + a
        if want_cache:
            caches.append(c)
    return x, caches, aux


def _unit_residual(uparams, cfg: ArchConfig, x):
    """Residual delta of one unit: F(θ, x) = unit(x) − x.  Used by the
    reversible stack (σ=0 reversible-Heun over depth)."""
    out, _, _ = _unit_apply(uparams, cfg, x, causal=True, want_cache=False)
    return out - x


def _stack_forward(params_units, cfg: ArchConfig, x, causal: bool = True,
                   want_cache: bool = False, n_units_override: Optional[int] = None):
    """Run the unit stack.  Returns (x, stacked_caches | None, aux)."""
    n = n_units_override or num_units(cfg)

    if cfg.reversible_residual and not want_cache and causal:
        # beyond-paper O(1)-activation-memory path (models/reversible.py);
        # MoE aux-loss accumulation is not threaded through — dense archs.
        from .reversible import reversible_stack

        x = reversible_stack(cfg, params_units, x, _unit_residual)
        return x, None, jnp.zeros((), jnp.float32)

    def _remat(fn):
        if not cfg.remat:
            return fn
        if cfg.remat_policy == "collectives":
            policy = jax.checkpoint_policies.save_only_these_names("post_ar")
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)

    if cfg.scan_layers:
        def body(carry, uparams):
            xc, auxc = carry
            fn = _remat(partial(_unit_apply, cfg=cfg, causal=causal,
                                want_cache=want_cache))
            xc, caches, a = fn(uparams, x=xc)
            return (xc, auxc + a), (caches if want_cache else None)

        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params_units)
        return x, caches, aux

    # unrolled path (roofline costing; exact HLO FLOPs)
    aux = jnp.zeros((), jnp.float32)
    all_caches = []
    for i in range(n):
        uparams = jax.tree.map(lambda a: a[i], params_units)
        fn = _remat(partial(_unit_apply, cfg=cfg, causal=causal,
                            want_cache=want_cache))
        x, caches, a = fn(uparams, x=x)
        aux = aux + a
        if want_cache:
            all_caches.append(caches)
    if want_cache:
        all_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *all_caches)
    return x, (all_caches if want_cache else None), aux


def _embed(params, cfg: ArchConfig, tokens, embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return _res_hint(cfg, x)


def _lm_head(params, cfg: ArchConfig, x):
    from ..distributed.sharding import tp_size

    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ w
    # TP-shard the vocab dim ONLY when it divides the model axis — otherwise
    # the head weight is replicated on V (shape-aware param rule) and a
    # sharded-logits hint makes GSPMD all-gather the full-vocab f32 cotangent
    # in the backward (§Perf iteration E1: 18 GiB/step on minicpm3).
    t = tp_size()
    vocab_tp = "tp" if (t > 1 and cfg.vocab % t == 0) else None
    return hint(logits, "dp", None, vocab_tp)


def lm_forward(params, cfg: ArchConfig, tokens, embeds=None,
               n_units_override: Optional[int] = None):
    """Train-mode forward: logits over the full sequence + MoE aux loss."""
    x = _embed(params, cfg, tokens, embeds)
    x, _, aux = _stack_forward(params["units"], cfg, x,
                               n_units_override=n_units_override)
    x = _norm(cfg, params["final_norm"], x)
    return _lm_head(params, cfg, x), aux


def lm_prefill(params, cfg: ArchConfig, tokens, embeds=None, max_len: Optional[int] = None):
    """Prefill: last-position logits + populated cache.

    The cache is sized to the prompt; serving pads to ``max_len`` slots.
    """
    x = _embed(params, cfg, tokens, embeds)
    x, caches, _ = _stack_forward(params["units"], cfg, x, want_cache=True)
    x = _norm(cfg, params["final_norm"], x)
    logits = _lm_head(params, cfg, x[:, -1:, :])
    if max_len is not None:
        caches = _pad_caches(caches, max_len)
    return logits, caches


def _pad_caches(caches, max_len: int):
    def pad(leaf):
        # attention caches carry a sequence axis at position 2 of (U, B, S, ...)
        if leaf.ndim >= 3 and leaf.shape[2] < max_len:
            cfgpad = [(0, 0)] * leaf.ndim
            cfgpad[2] = (0, max_len - leaf.shape[2])
            return jnp.pad(leaf, cfgpad)
        return leaf

    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k in ("k", "v", "ckv", "kpe"):
                    out[k] = pad(v)
                else:
                    out[k] = walk(v) if isinstance(v, (dict, list)) else v
            return out
        if isinstance(tree, list):
            return [walk(v) for v in tree]
        return tree

    return walk(caches)


def lm_decode(params, cfg: ArchConfig, token, caches, pos):
    """One decode step.  token: (B, 1) int32; pos: scalar int32 position.

    ``caches``: stacked (num_units leading dim) cache pytree.
    Returns (logits (B, 1, vocab), new caches).
    """
    x = _embed(params, cfg, token)
    pat = unit_pattern(cfg)

    def unit_decode(uparams, ucache, xc):
        new_caches = []
        for bp, c, (m, f) in zip(uparams, ucache, pat):
            xc, c2 = block_decode(bp, cfg, m, f, xc, c, pos)
            new_caches.append(c2)
        return xc, new_caches

    if cfg.scan_layers:
        def body(xc, inp):
            uparams, ucache = inp
            xc, nc = unit_decode(uparams, ucache, xc)
            return xc, nc

        x, new_caches = jax.lax.scan(body, x, (params["units"], caches))
    else:
        n = num_units(cfg)
        outs = []
        for i in range(n):
            uparams = jax.tree.map(lambda a: a[i], params["units"])
            ucache = jax.tree.map(lambda a: a[i], caches)
            x, nc = unit_decode(uparams, ucache, x)
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = _norm(cfg, params["final_norm"], x)
    return _lm_head(params, cfg, x), new_caches


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Abstract stacked cache pytree (ShapeDtypeStructs; zeros via init_cache_zeros)."""
    pat = unit_pattern(cfg)
    unit = [block_cache_spec(cfg, m, batch, max_len) for (m, _) in pat]
    n = num_units(cfg)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), unit)


def init_cache_zeros(cfg: ArchConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache(cfg, batch, max_len))


# =============================================================================
# encoder–decoder (seamless-m4t)
# =============================================================================


def _init_encoder(key, cfg: ArchConfig) -> Params:
    ku, kx = jax.random.split(key)

    def one_enc(k):
        return [block_init(k, cfg, "attn", "dense")]

    unit_keys = jax.random.split(ku, cfg.encoder_layers)
    enc_units = [one_enc(k) for k in unit_keys]
    # decoder cross-attention: one gqa block per decoder layer
    kc = jax.random.split(kx, cfg.num_layers)
    cross = [{"ln": _norm_init(cfg), "attn": L.gqa_init(k, cfg)} for k in kc]
    return {
        "enc_units": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_units),
        "enc_final_norm": _norm_init(cfg),
        "cross": jax.tree.map(lambda *xs: jnp.stack(xs), *cross),
    }


def encode(params, cfg: ArchConfig, src_embeds):
    """Bidirectional encoder over stub frame embeddings (B, Ss, D)."""
    x = _res_hint(cfg, src_embeds.astype(cfg.dtype))
    x, _, _ = _stack_forward(params["enc_units"], cfg, x, causal=False,
                             n_units_override=cfg.encoder_layers)
    return _norm(cfg, params["enc_final_norm"], x)


def encdec_forward(params, cfg: ArchConfig, tokens, src_embeds):
    """Full enc-dec training forward: returns (logits, aux=0)."""
    enc_out = encode(params, cfg, src_embeds)
    x = _embed(params, cfg, tokens)
    pat = unit_pattern(cfg)
    n = num_units(cfg)

    def dec_unit(uparams, cross_p, xc):
        for bp, (m, f) in zip(uparams, pat):
            # causal self-attention + ffn
            h = _norm(cfg, bp["ln1"], xc)
            o, _ = L.gqa_attend(bp["mixer"], cfg, h, causal=True)
            xc = _res_hint(cfg, xc + o)
            # cross-attention over the encoder output
            hc = _norm(cfg, cross_p["ln"], xc)
            oc, _ = L.gqa_attend(cross_p["attn"], cfg, hc, causal=False,
                                 kv_source=enc_out)
            xc = _res_hint(cfg, xc + oc)
            h2 = _norm(cfg, bp["ln2"], xc)
            xc = _res_hint(cfg, xc + L.ffn_apply(bp["ffn"], cfg, h2))
        return xc

    if cfg.scan_layers:
        def body(xc, inp):
            uparams, cross_p = inp
            fn = dec_unit
            if cfg.remat:
                fn = jax.checkpoint(fn)
            return fn(uparams, cross_p, xc), None

        x, _ = jax.lax.scan(body, x, (params["units"], params["cross"]))
    else:
        for i in range(n):
            uparams = jax.tree.map(lambda a: a[i], params["units"])
            cross_p = jax.tree.map(lambda a: a[i], params["cross"])
            x = dec_unit(uparams, cross_p, x)

    x = _norm(cfg, params["final_norm"], x)
    return _lm_head(params, cfg, x), jnp.zeros((), jnp.float32)


def encdec_prefill(params, cfg: ArchConfig, tokens, src_embeds,
                   max_len: Optional[int] = None):
    """Encode source + prefill the decoder self/cross caches."""
    enc_out = encode(params, cfg, src_embeds)
    x = _embed(params, cfg, tokens)
    pat = unit_pattern(cfg)

    def dec_unit(uparams, cross_p, xc):
        caches = []
        for bp, (m, f) in zip(uparams, pat):
            h = _norm(cfg, bp["ln1"], xc)
            o, (k, v) = L.gqa_attend(bp["mixer"], cfg, h, causal=True)
            xc = _res_hint(cfg, xc + o)
            hc = _norm(cfg, cross_p["ln"], xc)
            oc, (ck, cv) = L.gqa_attend(cross_p["attn"], cfg, hc, causal=False,
                                        kv_source=enc_out)
            xc = _res_hint(cfg, xc + oc)
            h2 = _norm(cfg, bp["ln2"], xc)
            xc = _res_hint(cfg, xc + L.ffn_apply(bp["ffn"], cfg, h2))
            caches.append({"self": {"k": k, "v": v}, "cross": {"k": ck, "v": cv}})
        return xc, caches

    if cfg.scan_layers:
        def body(xc, inp):
            uparams, cross_p = inp
            xc, caches = dec_unit(uparams, cross_p, xc)
            return xc, caches

        x, caches = jax.lax.scan(body, x, (params["units"], params["cross"]))
    else:
        outs = []
        for i in range(num_units(cfg)):
            uparams = jax.tree.map(lambda a: a[i], params["units"])
            cross_p = jax.tree.map(lambda a: a[i], params["cross"])
            x, c = dec_unit(uparams, cross_p, x)
            outs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = _norm(cfg, params["final_norm"], x)
    logits = _lm_head(params, cfg, x[:, -1:, :])
    if max_len is not None:
        # pad ONLY the self-attention cache: the cross cache length is the
        # (fixed) source length and cross-attention is unmasked, so padding
        # it would corrupt the softmax.
        def pad_self(tree):
            if isinstance(tree, dict):
                if "self" in tree:
                    return {"self": _pad_caches(tree["self"], max_len),
                            "cross": tree["cross"]}
                return {k: pad_self(v) for k, v in tree.items()}
            if isinstance(tree, list):
                return [pad_self(v) for v in tree]
            return tree

        caches = pad_self(caches)
    return logits, caches


def encdec_decode(params, cfg: ArchConfig, token, caches, pos):
    """One decoder step: causal self-attn against the self cache + cross-attn
    against the (fixed) encoder cache."""
    x = _embed(params, cfg, token)

    def unit_decode(uparams, cross_p, ucache, xc):
        new_caches = []
        for bp, c in zip(uparams, ucache):
            h = _norm(cfg, bp["ln1"], xc)
            o, self_c = L.gqa_decode(bp["mixer"], cfg, h, c["self"], pos)
            xc = xc + o
            hc = _norm(cfg, cross_p["ln"], xc)
            oc = L.gqa_cross_decode(cross_p["attn"], cfg, hc, c["cross"]["k"],
                                    c["cross"]["v"])
            xc = xc + oc
            h2 = _norm(cfg, bp["ln2"], xc)
            xc = xc + L.ffn_apply(bp["ffn"], cfg, h2)
            new_caches.append({"self": self_c, "cross": c["cross"]})
        return xc, new_caches

    if cfg.scan_layers:
        def body(xc, inp):
            uparams, cross_p, ucache = inp
            return unit_decode(uparams, cross_p, ucache, xc)

        x, new_caches = jax.lax.scan(
            body, x, (params["units"], params["cross"], caches))
    else:
        outs = []
        for i in range(num_units(cfg)):
            uparams = jax.tree.map(lambda a: a[i], params["units"])
            cross_p = jax.tree.map(lambda a: a[i], params["cross"])
            ucache = jax.tree.map(lambda a: a[i], caches)
            x, nc = unit_decode(uparams, cross_p, ucache, x)
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = _norm(cfg, params["final_norm"], x)
    return _lm_head(params, cfg, x), new_caches


def encdec_cache(cfg: ArchConfig, batch: int, max_len: int, src_len: int):
    pat = unit_pattern(cfg)
    unit = [{
        "self": block_cache_spec(cfg, "attn", batch, max_len),
        "cross": block_cache_spec(cfg, "attn", batch, src_len),
    } for _ in pat]
    n = num_units(cfg)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), unit)


# =============================================================================
# loss
# =============================================================================


def softmax_xent(logits, labels):
    """Mean next-token cross entropy; logsumexp in f32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def lm_loss(params, cfg: ArchConfig, batch, aux_weight: float = 0.01):
    """Unified training loss for every family.  ``batch`` keys:
    tokens/labels (+ embeds for vlm/audio prefix, + src_embeds for encdec)."""
    if cfg.family == "encdec":
        logits, aux = encdec_forward(params, cfg, batch["tokens"], batch["src_embeds"])
    else:
        logits, aux = lm_forward(params, cfg, batch["tokens"],
                                 embeds=batch.get("embeds"))
        if "embeds" in batch:                      # loss on the text region only
            logits = logits[:, batch["embeds"].shape[1]:, :]
    loss = softmax_xent(logits, batch["labels"])
    return loss + aux_weight * aux, {"xent": loss, "moe_aux": aux}
