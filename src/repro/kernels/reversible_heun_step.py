"""Fused reversible-Heun state updates (Algorithm 1/2) as Pallas TPU kernels.

The solver's per-step arithmetic is pure elementwise VPU work: without
fusion, XLA materialises each intermediate (2z, −ẑ, μΔt, σΔW, …) through
HBM.  One VMEM-resident kernel per phase turns ~6 HBM round-trips into one
read + one write per operand — the solver loop is memory-bound, so this is
the hot spot the paper's 1-NFE-per-step advantage exposes.

Phase 1 computes ẑ_{n+1} (before the vector-field evaluation); phase 2
computes z_{n+1} (after).  Both take a static ``sign``: ``+1.0`` is the
forward step (Algorithm 1) and ``-1.0`` the algebraic inverse (Algorithm 2,
used by the O(1)-memory backward reconstruction in
:mod:`repro.core.adjoint`), which negates the Δt and ΔW terms in-kernel so
no extra negated operand ever touches HBM.

The backward (cotangent) phases are the hand-derived transpose of one
Algorithm-1 step, factored around the single vector-field VJP exactly as
DESIGN.md §3 derives it: :func:`rev_heun_bwd_phase1` builds the seeds of
the field VJP, :func:`rev_heun_bwd_phase2` distributes its result onto the
step-``n`` state cotangents.  Their op order is chosen so every output is
BITWISE what ``jax.vjp`` of the unfused stepper produces — the fused exact
adjoint in :mod:`repro.core.adjoint` rests on that identity, and
tests/test_kernel_parity.py pins it.

Kernel contract
===============

* **Noise layout**: diagonal noise only — ``z, ẑ, μ, σ, ΔW`` all share the
  state shape.  General (matrix) noise needs an ``einsum`` per step and is
  served by the unfused path in :mod:`repro.core.solvers`.
* **Shapes/tiling**: operands are flattened to ``(rows, cols)`` with
  ``cols = shape[-1]`` (1-D states become ``(1, n)``).  Block sizes are the
  largest divisor of each dim from the preference ladder
  ``(256|512, 256, 128, 64, …, 1)``, so *any* shape is legal, but
  performance wants ``cols`` a multiple of the 128-lane VPU width and
  ``rows`` a multiple of 8 (f32) / 16 (bf16) sublanes.
* **dt is a traced scalar operand**: ``dt`` rides in as a ``(1, 1)`` block
  broadcast to every grid cell, so one compiled kernel serves every step
  size — this is what lets the *adaptive* driver (traced, per-attempt
  ``dt``) use the fused path.  ``sign`` stays static (±1.0 is a branch of
  the algorithm, not data).
* **Interpret mode**: ``interpret=True`` runs the kernel body under the
  Pallas interpreter — required on CPU, and how CI validates the kernels
  without a TPU (see tests/test_kernel_parity.py and tests/test_solve.py).
  The solver hot loop does NOT pay this off-TPU: ``repro.core.solvers``
  dispatches per the kernels/ops.py policy (compiled kernel on TPU, the
  fused jnp oracle in :mod:`repro.kernels.ref` elsewhere) and only forces
  the interpreter when a caller passes ``interpret=True`` explicitly.
* **Differentiability**: ``pallas_call`` still has no *automatic* VJP rule
  — but it no longer needs one: the backward phases above ARE the
  derivative, registered through the solver-level ``custom_vjp`` in
  :mod:`repro.core.adjoint`.  AD never traces through a kernel; the
  adjoint rules call the backward kernels directly.  ``jax.vmap``
  (batched multi-trajectory solving) IS supported.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _phase1_kernel(sign, z_ref, zh_ref, mu_ref, sig_ref, dw_ref, dt_ref, o_ref):
    dt = dt_ref[0, 0]
    o_ref[...] = (
        2.0 * z_ref[...]
        - zh_ref[...]
        + mu_ref[...] * (sign * dt)
        + (sign * sig_ref[...]) * dw_ref[...]
    )


def _phase2_kernel(sign, z_ref, mu_ref, mu1_ref, sig_ref, sig1_ref, dw_ref,
                   dt_ref, o_ref):
    dt = dt_ref[0, 0]
    o_ref[...] = (
        z_ref[...]
        + (sign * 0.5 * dt) * (mu_ref[...] + mu1_ref[...])
        + (sign * 0.5) * (sig_ref[...] + sig1_ref[...]) * dw_ref[...]
    )


def _bwd_phase1_kernel(gz1_ref, gmu1_ref, gsig1_ref, dw_ref, dt_ref,
                       cmu1_ref, csig1_ref):
    dt = dt_ref[0, 0]
    g_z1 = gz1_ref[...]
    cmu1_ref[...] = gmu1_ref[...] + 0.5 * (g_z1 * dt)
    csig1_ref[...] = gsig1_ref[...] + 0.5 * (g_z1 * dw_ref[...])


def _bwd_phase2_kernel(gz1_ref, ghat_ref, dw_ref, dt_ref,
                       dz_ref, dzh_ref, dmu_ref, dsig_ref):
    dt = dt_ref[0, 0]
    g_z1 = gz1_ref[...]
    ghat = ghat_ref[...]
    dw = dw_ref[...]
    dz_ref[...] = g_z1 + 2.0 * ghat
    dzh_ref[...] = -ghat
    dmu_ref[...] = 0.5 * (g_z1 * dt) + ghat * dt
    dsig_ref[...] = 0.5 * (g_z1 * dw) + ghat * dw


def _tile(n: int, pref: int) -> int:
    for t in (pref, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if t <= n and n % t == 0:
            return t
    return 1


def _call_elementwise(kernel, args, scalars, interpret: bool, n_out: int = 1):
    """Tiled elementwise pallas_call: tensor ``args`` share one block grid,
    ``scalars`` ride along as (1, 1) blocks mapped to every grid cell.

    Interpret mode runs the whole array as ONE block: the interpreter's
    per-cell grid loop compiles each block as a separate XLA subcomputation,
    and LLVM's FMA-contraction choices differ between that loop body and the
    plain jnp oracle graph — observable as ±1-ulp drift at block boundaries.
    A single block keeps interpret mode bit-identical to the oracle (the
    parity contract tests/test_kernel_parity.py pins); compiled TPU mode
    keeps the tile ladder for VMEM residency.
    """
    x = args[0]
    orig_shape = x.shape
    flat = [a.reshape(-1, orig_shape[-1]) if a.ndim > 1 else a.reshape(1, -1)
            for a in args]
    rows, cols = flat[0].shape
    if interpret:
        br, bc = rows, cols
    else:
        br, bc = _tile(rows, 256), _tile(cols, 512)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    sspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    svals = [jnp.asarray(s, x.dtype).reshape(1, 1) for s in scalars]
    shape = jax.ShapeDtypeStruct((rows, cols), x.dtype)
    out = pl.pallas_call(
        kernel,
        grid=(rows // br, cols // bc),
        in_specs=[spec] * len(flat) + [sspec] * len(svals),
        out_specs=spec if n_out == 1 else (spec,) * n_out,
        out_shape=shape if n_out == 1 else (shape,) * n_out,
        interpret=interpret,
    )(*flat, *svals)
    if n_out == 1:
        return out.reshape(orig_shape)
    return tuple(o.reshape(orig_shape) for o in out)


@functools.partial(jax.jit, static_argnames=("sign", "interpret"))
def rev_heun_phase1(z, zh, mu, sigma, dw, dt, sign: float = 1.0,
                    interpret: bool = True):
    """ẑ_{n+1} = 2z − ẑ + sign·(μΔt + σΔW) — fused, one HBM pass."""
    return _call_elementwise(
        functools.partial(_phase1_kernel, sign), (z, zh, mu, sigma, dw), (dt,),
        interpret)


@functools.partial(jax.jit, static_argnames=("sign", "interpret"))
def rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt, sign: float = 1.0,
                    interpret: bool = True):
    """z_{n+1} = z + sign·(½(μ+μ′)Δt + ½(σ+σ′)ΔW) — fused, one HBM pass."""
    return _call_elementwise(
        functools.partial(_phase2_kernel, sign), (z, mu, mu1, sigma, sigma1, dw),
        (dt,), interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rev_heun_bwd_phase1(g_z1, g_mu1, g_sig1, dw, dt, interpret: bool = True):
    """Backward pre-field phase: ``(c_mu1, c_sig1)`` seeds for the single
    vector-field VJP — ``c_mu1 = ḡ_mu1 + ½Δt·ḡ_z1``,
    ``c_sig1 = ḡ_sig1 + ½ΔW·ḡ_z1``."""
    return _call_elementwise(
        _bwd_phase1_kernel, (g_z1, g_mu1, g_sig1, dw), (dt,), interpret,
        n_out=2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rev_heun_bwd_phase2(g_z1, ghat, dw, dt, interpret: bool = True):
    """Backward post-field phase: distribute the total ẑ₁ cotangent ``ĝ``
    onto the step-``n`` state — ``(d_z, d_zh, d_mu, d_sigma)``."""
    return _call_elementwise(
        _bwd_phase2_kernel, (g_z1, ghat, dw), (dt,), interpret, n_out=4)
