"""Space-time Lévy area tests: the (W, H) path contract, the bitwise
``levy_area=None`` freeze, and the moment structure of the samplers.

Three layers of guarantee (DESIGN.md §13):

* **None-mode freeze** — adding the ``levy_area`` mode must not move a
  single bit of the existing draws; pinned with ``assert_array_equal``
  against literals captured from the pre-change implementation.
* **(W, H) contract** — the W component keeps the bitwise
  ``evaluate(s, t) == value(t) - value(s)`` identity (under ``jit(vmap)``,
  at non-dyadic points, under ``bridge_depth`` caps), and H satisfies the
  chen-combine rule over adjacent intervals.
* **Moments** — H ~ N(0, dt/12) independent of W at the path level, and
  λ-antisymmetry in :func:`davie_levy_area`.

Float64 assertions pin x64 for their scope (the x64-truncation trap:
without it the requested dtype silently truncates to float32).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.brownian import (
    BrownianPath,
    DenseBrownianPath,
    VirtualBrownianTree,
    davie_levy_area,
    space_time_levy_area,
    stlevy_difference,
)
from repro.core.brownian_interval import BrownianInterval


def _chen_h(w_st, h_st, w_tu, h_tu, h1, h2):
    """Chen-combine rule for space-time Lévy area over adjacent intervals."""
    h = h1 + h2
    return (h1 * h_st + h2 * h_tu) / h + (h2 * w_st - h1 * w_tu) / (2.0 * h)


# -----------------------------------------------------------------------------
# None-mode bitwise freeze (oracles captured from the pre-change code)
# -----------------------------------------------------------------------------


def test_levy_none_mode_bitwise_unchanged():
    """``levy_area=None`` draws are bit-identical to the pre-Lévy-area
    implementation — pinned against literals captured before the H plumbing
    landed.  A changed key-derivation chain or draw order fails here."""
    jax.config.update("jax_enable_x64", True)
    try:
        bm = BrownianPath(jax.random.PRNGKey(1234), 0.0, 1.0, (3,),
                          jnp.float64)
        np.testing.assert_array_equal(
            np.asarray(bm.increment(jnp.int32(5), 16)),
            [-0.375534278014852, 0.21138405638582938, -0.2041279297322032])
        np.testing.assert_array_equal(
            np.asarray(bm.value(0.37)),
            [-0.05096384495686117, 0.6007916360445986, -0.3669449112653378])
        np.testing.assert_array_equal(
            np.asarray(bm.evaluate(0.2, 0.9)),
            [-0.4398328553843184, 0.8588984436938387, -0.30782485110202823])

        dp = DenseBrownianPath.sample(jax.random.PRNGKey(7), 0.0, 1.0, 32,
                                      (2,), jnp.float64)
        np.testing.assert_array_equal(
            np.asarray(dp.w[0]),
            [-0.23657609026237209, -0.04391988045123099])
        np.testing.assert_array_equal(
            np.asarray(dp.increment(jnp.int32(3), 8)),
            [-0.007367515643208873, -0.016263428119183826])
        np.testing.assert_array_equal(
            np.asarray(dp.value(0.55)),
            [-0.6935191655375951, 0.4143443384798501])

        vb = VirtualBrownianTree(jax.random.PRNGKey(99), 0.0, 1.0, (2,),
                                 tol=1e-3, dtype=jnp.float64)
        np.testing.assert_array_equal(
            np.asarray(vb.evaluate(0.25, 0.8)),
            [0.015947176913055826, -1.4200387079056345])
    finally:
        jax.config.update("jax_enable_x64", False)


def test_levy_mode_rejected_eagerly():
    with pytest.raises(ValueError, match="levy_area"):
        BrownianPath(jax.random.PRNGKey(0), 0.0, 1.0, (2,),
                     levy_area="space-time-time")
    with pytest.raises(ValueError, match="levy_area"):
        BrownianInterval(0.0, 1.0, (2,), levy_area="full")
    # Dense: hh and the mode must travel together
    with pytest.raises(ValueError, match="hh"):
        DenseBrownianPath(jnp.zeros((4, 2)), t0=0.0, t1=1.0,
                          levy_area="space-time")


# -----------------------------------------------------------------------------
# (W, H) contract
# -----------------------------------------------------------------------------


def test_wh_value_evaluate_contract_bitwise_w():
    """W component of ``evaluate(s, t)`` == ``value(t) - value(s)`` bitwise,
    including non-dyadic query points; ``value(t0) == (0, 0)``."""
    jax.config.update("jax_enable_x64", True)
    try:
        for path in (
            BrownianPath(jax.random.PRNGKey(3), 0.0, 1.0, (4,), jnp.float64,
                         levy_area="space-time"),
            DenseBrownianPath.sample(jax.random.PRNGKey(4), 0.0, 1.0, 64,
                                     (4,), jnp.float64,
                                     levy_area="space-time"),
            VirtualBrownianTree(jax.random.PRNGKey(5), 0.0, 1.0, (4,),
                                tol=1e-4, dtype=jnp.float64,
                                levy_area="space-time"),
        ):
            w0, h0 = path.value(0.0)
            np.testing.assert_array_equal(np.asarray(w0), np.zeros(4))
            np.testing.assert_array_equal(np.asarray(h0), np.zeros(4))
            for s, t in ((0.0, 0.3), (0.21, 0.77), (0.5, 1.0),
                         (0.137, 0.1371)):
                dw, dh = path.evaluate(s, t)
                vs, vt = path.value(s), path.value(t)
                np.testing.assert_array_equal(np.asarray(dw),
                                              np.asarray(vt[0] - vs[0]))
                assert np.all(np.isfinite(np.asarray(dh)))
    finally:
        jax.config.update("jax_enable_x64", False)


def test_wh_contract_under_jit_vmap():
    """The bitwise W contract survives ``jit(vmap(...))`` — the form the
    adaptive driver's left-endpoint carry actually runs in."""
    jax.config.update("jax_enable_x64", True)
    try:
        bm = BrownianPath(jax.random.PRNGKey(11), 0.0, 1.0, (3,),
                          jnp.float64, levy_area="space-time")
        ss = jnp.asarray([0.1, 0.23, 0.4], jnp.float64)
        ts = jnp.asarray([0.35, 0.81, 0.93], jnp.float64)

        ev = jax.jit(jax.vmap(lambda s, t: bm.evaluate(s, t)))
        vd = jax.jit(jax.vmap(
            lambda s, t: stlevy_difference(bm.value(s), bm.value(t),
                                           s, t, bm.t0)))
        dw_e, dh_e = ev(ss, ts)
        dw_v, dh_v = vd(ss, ts)
        np.testing.assert_array_equal(np.asarray(dw_e), np.asarray(dw_v))
        np.testing.assert_array_equal(np.asarray(dh_e), np.asarray(dh_v))
    finally:
        jax.config.update("jax_enable_x64", False)


def test_wh_contract_under_bridge_depth_cap():
    """``bridge_depth`` caps keep both components' value/evaluate identity
    (the capped descent is a consistent path approximation, not a skew)."""
    jax.config.update("jax_enable_x64", True)
    try:
        bm = BrownianPath(jax.random.PRNGKey(13), 0.0, 1.0, (3,),
                          jnp.float64, levy_area="space-time")
        for depth in (6, 10):
            for s, t in ((0.2, 0.9), (0.31, 0.57)):
                dw, dh = bm.evaluate(s, t, depth=depth)
                ref = stlevy_difference(bm.value(s, depth=depth),
                                        bm.value(t, depth=depth),
                                        s, t, bm.t0)
                np.testing.assert_array_equal(np.asarray(dw),
                                              np.asarray(ref[0]))
                np.testing.assert_array_equal(np.asarray(dh),
                                              np.asarray(ref[1]))
    finally:
        jax.config.update("jax_enable_x64", False)


def test_wh_chen_combine_over_adjacent_intervals():
    """H combines over adjacent intervals by the chen rule
    ``H_{s,u} = (h₁H_{s,t} + h₂H_{t,u})/h + (h₂W_{s,t} - h₁W_{t,u})/(2h)``
    — exact by construction (H is derived from the additive running
    integral), so the tolerance is f64-roundoff-tight."""
    jax.config.update("jax_enable_x64", True)
    try:
        for path in (
            BrownianPath(jax.random.PRNGKey(17), 0.0, 1.0, (4,),
                         jnp.float64, levy_area="space-time"),
            DenseBrownianPath.sample(jax.random.PRNGKey(18), 0.0, 1.0, 64,
                                     (4,), jnp.float64,
                                     levy_area="space-time"),
        ):
            for s, t, u in ((0.1, 0.456, 0.83), (0.0, 0.25, 1.0),
                            (0.3, 0.31, 0.42)):
                w_st, h_st = (np.asarray(x) for x in path.evaluate(s, t))
                w_tu, h_tu = (np.asarray(x) for x in path.evaluate(t, u))
                w_su, h_su = (np.asarray(x) for x in path.evaluate(s, u))
                np.testing.assert_allclose(w_st + w_tu, w_su,
                                           rtol=1e-12, atol=1e-12)
                np.testing.assert_allclose(
                    _chen_h(w_st, h_st, w_tu, h_tu, t - s, u - t), h_su,
                    rtol=1e-9, atol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", False)


def test_dense_wh_shares_w_bitwise_with_none_mode():
    """Dense H-mode draws W from the same stream as None-mode — shared-path
    solver comparisons (the convergence frontier) rely on it."""
    k = jax.random.PRNGKey(21)
    plain = DenseBrownianPath.sample(k, 0.0, 1.0, 32, (3,))
    levy = DenseBrownianPath.sample(k, 0.0, 1.0, 32, (3,),
                                    levy_area="space-time")
    np.testing.assert_array_equal(np.asarray(plain.w), np.asarray(levy.w))
    for n, num in ((0, 8), (5, 16), (31, 32)):
        np.testing.assert_array_equal(
            np.asarray(plain.increment(jnp.int32(n), num)),
            np.asarray(levy.increment(jnp.int32(n), num)[0]))


# -----------------------------------------------------------------------------
# Moments (the dead-helpers satellite: the samplers behind the path API)
# -----------------------------------------------------------------------------


def test_path_level_h_moments():
    """Path-level increments: H ~ N(0, dt/12), independent of W."""
    bm = BrownianPath(jax.random.PRNGKey(0), 0.0, 1.0, (100_000,),
                      levy_area="space-time")
    w, h = bm.increment(jnp.int32(2), 8)
    dt = 1.0 / 8.0
    assert abs(float(jnp.var(h)) / (dt / 12.0) - 1.0) < 0.05
    assert abs(float(jnp.var(w)) / dt - 1.0) < 0.05
    assert abs(float(jnp.mean(w * h))) < 3.0 * dt / jnp.sqrt(12.0 * 100_000)


def test_bridged_h_moments():
    """After the Lévy-bridge descent (non-dyadic interval) the conditional
    pieces still recombine to the unconditional law: H ~ N(0, dt/12),
    uncorrelated with W."""
    bm = BrownianPath(jax.random.PRNGKey(1), 0.0, 1.0, (60_000,),
                      levy_area="space-time")
    w, h = bm.evaluate(0.21, 0.74)
    w, h = np.asarray(w), np.asarray(h)
    dt = 0.74 - 0.21
    assert abs(np.var(w) / dt - 1.0) < 0.05
    assert abs(np.var(h) / (dt / 12.0) - 1.0) < 0.05
    assert abs(np.corrcoef(w, h)[0, 1]) < 0.02


def test_space_time_levy_area_moments():
    w, h = space_time_levy_area(jax.random.PRNGKey(2), 0.25, (120_000,))
    assert abs(float(jnp.var(w)) / 0.25 - 1.0) < 0.05
    assert abs(float(jnp.var(h)) / (0.25 / 12.0) - 1.0) < 0.05


def test_davie_levy_area_lambda_antisymmetry():
    """``W̃ + W̃ᵀ == w⊗w`` exactly: the 0.5·w⊗w symmetric part doubles, the
    (H⊗W - W⊗H) part and antisymmetric λ cancel against their transposes.
    Also ``diag(W̃) = w²/2`` (λ has a zero diagonal)."""
    key = jax.random.PRNGKey(3)
    dt = 0.3
    w, h = space_time_levy_area(jax.random.fold_in(key, 0), dt, (64, 5))
    wt = davie_levy_area(jax.random.fold_in(key, 1), w, h, dt)
    assert wt.shape == (64, 5, 5)
    sym = np.asarray(wt + jnp.swapaxes(wt, -1, -2))
    outer = np.asarray(w)[..., :, None] * np.asarray(w)[..., None, :]
    np.testing.assert_allclose(sym, outer, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(jnp.diagonal(wt, axis1=-2, axis2=-1)),
        0.5 * np.asarray(w) ** 2, rtol=1e-5, atol=1e-6)
    # λ scale: off-diagonal variance is dt²/12 above the structured part
    lam = np.asarray(wt) - (0.5 * outer
                            + np.asarray(h)[..., :, None] * np.asarray(w)[..., None, :]
                            - np.asarray(w)[..., :, None] * np.asarray(h)[..., None, :])
    off = lam[..., ~np.eye(5, dtype=bool)]
    assert abs(np.var(off) / (dt ** 2 / 12.0) - 1.0) < 0.1


# -----------------------------------------------------------------------------
# Host-side Brownian Interval pairs
# -----------------------------------------------------------------------------


def test_interval_wh_chen_and_determinism():
    bi = BrownianInterval(0.0, 1.0, (4,), seed=7, levy_area="space-time")
    w_su, h_su = bi(0.1, 0.9)
    w_st, h_st = bi(0.1, 0.4)
    w_tu, h_tu = bi(0.4, 0.9)
    np.testing.assert_allclose(w_st + w_tu, w_su, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(_chen_h(w_st, h_st, w_tu, h_tu, 0.3, 0.5),
                               h_su, rtol=1e-9, atol=1e-12)
    w2, h2 = bi(0.1, 0.9)  # replay through the grown tree
    np.testing.assert_array_equal(w_su, w2)
    np.testing.assert_array_equal(h_su, h2)


def test_interval_wh_moments_after_conditioning():
    """Sub-interval queries on a grown tree go through the general-split
    conditional (w, A) sampler; the recombined law must stay N(0, dt) ×
    N(0, dt/12) uncorrelated."""
    n = 40_000
    bi = BrownianInterval(0.0, 1.0, (n,), seed=3, levy_area="space-time",
                          cache_size=512)
    bi(0.13, 0.61)  # grow a non-dyadic tree first
    w, h = bi(0.25, 0.37)
    dt = 0.37 - 0.25
    assert abs(np.var(w) / dt - 1.0) < 0.06
    assert abs(np.var(h) / (dt / 12.0) - 1.0) < 0.06
    assert abs(np.corrcoef(w, h)[0, 1]) < 0.03
