from .counting import model_flops_per_token, param_count  # noqa: F401
from .transformer import (  # noqa: F401
    encdec_cache,
    encdec_decode,
    encdec_forward,
    encdec_prefill,
    init_cache,
    init_cache_zeros,
    init_lm,
    lm_decode,
    lm_forward,
    lm_loss,
    lm_prefill,
    num_units,
    softmax_xent,
    unit_pattern,
)
