"""In-kernel Brownian generation (counter-based Threefry) as Pallas kernels.

Moving increment generation on-device removes the per-step host round-trip
the solver loop otherwise pays: a fixed-grid step's ``ΔW`` and an adaptive
attempt's bridge descent each become ONE kernel launch whose body runs the
bit-exact ``jax.random`` op sequence (:mod:`repro.kernels.prng`).

Three kernels:

* :func:`brownian_increment` — ``fold_in(key, n)`` + shaped normal draw
  scaled by ``sqrt(dt)``; bitwise ``BrownianPath.increment(n, num_steps)``.
* :func:`brownian_value` — the full Lévy-bridge descent of
  ``BrownianPath.value(t)`` fused into one grid: in-kernel key chaining,
  one batched midpoint draw, elementwise combine.  This is what lets the
  adaptive driver pay a single launch per attempted step instead of
  ``depth`` sequential draws.
* :func:`rev_heun_phase1_gen` — Algorithm 1's first state update with the
  step's ``ΔW`` generated *inside the same kernel* (returns ``(ẑ_{n+1},
  ΔW)`` so phase 2 reuses the increment without re-deriving it).

Kernel contract
===============

* The kernel bodies call the :mod:`repro.kernels.ref` oracles on loaded
  values — kernel and oracle are the SAME traced op sequence, so bitwise
  parity (tests/test_kernel_parity.py) holds by construction and the tests
  pin that the Pallas lowering/interpreter preserves it.
* Whole-array blocks: Brownian states here are small ``(batch, w_dim)``
  tensors; each kernel runs as a single VMEM-resident block with scalar
  operands (key halves, counter, times) in SMEM.  Shapes that overflow
  VMEM should use the unfused oracle path (``use_kernel=False``).
* ``interpret=True`` runs the body under the Pallas interpreter — the
  CPU/CI validation path (DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

try:  # pltpu.SMEM exists only with the TPU plugin's pallas build
    from jax.experimental.pallas import tpu as pltpu

    _SMEM = pltpu.SMEM
except Exception:  # pragma: no cover - CPU-only wheels
    _SMEM = None


def _smem_spec():
    if _SMEM is None:
        return pl.BlockSpec(memory_space=None)
    return pl.BlockSpec(memory_space=_SMEM)


def _scalar_specs(n: int):
    return [_smem_spec() for _ in range(n)]


def _increment_kernel(shape, dtype, k1_ref, k2_ref, n_ref, dt_ref, o_ref):
    dw = ref.brownian_increment(k1_ref[0], k2_ref[0], n_ref[0], shape, dtype,
                                dt_ref[0])
    o_ref[...] = dw.reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "interpret"))
def brownian_increment(k1, k2, n, shape, dtype, dt, interpret: bool = True):
    """Step-``n`` grid increment, generated in-kernel.

    ``k1, k2``: raw uint32 key halves; ``n``: step counter; ``dt``: the
    grid spacing (scalar, may be traced).
    """
    dtype = jnp.dtype(dtype)
    shape = tuple(shape)
    out = pl.pallas_call(
        functools.partial(_increment_kernel, shape, dtype),
        in_specs=_scalar_specs(4),
        out_specs=pl.BlockSpec(shape, lambda: (0,) * len(shape)),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        grid=(),
        interpret=interpret,
    )(jnp.asarray(k1, jnp.uint32).reshape(1),
      jnp.asarray(k2, jnp.uint32).reshape(1),
      jnp.asarray(n).reshape(1),
      jnp.asarray(dt, dtype).reshape(1))
    return out


def _value_kernel(t0, t1, shape, dtype, depth, k1_ref, k2_ref, t_ref, o_ref):
    w = ref.brownian_value(k1_ref[0], k2_ref[0], t_ref[0], t0, t1, shape,
                           dtype, depth)
    o_ref[...] = w.reshape(o_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("t0", "t1", "shape", "dtype", "depth", "interpret"))
def brownian_value(k1, k2, t, t0, t1, shape, dtype, depth: int = 24,
                   interpret: bool = True):
    """``W(t) − W(t0)`` with the whole bridge descent fused into one kernel."""
    dtype = jnp.dtype(dtype)
    shape = tuple(shape)
    out = pl.pallas_call(
        functools.partial(_value_kernel, t0, t1, shape, dtype, depth),
        in_specs=_scalar_specs(3),
        out_specs=pl.BlockSpec(shape, lambda: (0,) * len(shape)),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        grid=(),
        interpret=interpret,
    )(jnp.asarray(k1, jnp.uint32).reshape(1),
      jnp.asarray(k2, jnp.uint32).reshape(1),
      jnp.asarray(t, dtype).reshape(1))
    return out


def _phase1_gen_kernel(shape, dtype, z_ref, zh_ref, mu_ref, sig_ref,
                       k1_ref, k2_ref, n_ref, dt_grid_ref, dt_ref, sign_ref,
                       zh1_ref, dw_ref):
    dw = ref.brownian_increment(k1_ref[0], k2_ref[0], n_ref[0], shape, dtype,
                                dt_grid_ref[0])
    dw = dw.reshape(dw_ref.shape)
    sign = sign_ref[0]
    zh1_ref[...] = ref.rev_heun_phase1(z_ref[...], zh_ref[...], mu_ref[...],
                                       sig_ref[...], dw, dt_ref[0], sign)
    dw_ref[...] = dw


@functools.partial(jax.jit, static_argnames=("interpret",))
def rev_heun_phase1_gen(z, zh, mu, sigma, k1, k2, n, dt_grid, dt,
                        sign=1.0, interpret: bool = True):
    """Fused Algorithm-1 phase 1 + in-kernel ΔW generation.

    Returns ``(ẑ_{n+1}, ΔW_n)`` from one kernel launch: the increment is
    drawn inside the grid (``fold_in(key, n)`` Threefry, scaled by
    ``sqrt(dt_grid)``) and immediately consumed by the state update, so the
    solver's time loop never leaves the kernel between noise generation and
    state propagation.  ``dt_grid`` is the Brownian grid spacing (the
    ``sqrt``-scaling), ``dt`` the integration step — identical for the
    uniform fixed-step solvers that use this kernel.
    """
    dtype = z.dtype
    shape = tuple(z.shape)
    spec = pl.BlockSpec(shape, lambda: (0,) * len(shape))
    zh1, dw = pl.pallas_call(
        functools.partial(_phase1_gen_kernel, shape, dtype),
        in_specs=[spec] * 4 + _scalar_specs(6),
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct(shape, dtype),
                   jax.ShapeDtypeStruct(shape, dtype)),
        grid=(),
        interpret=interpret,
    )(z, zh, mu, sigma,
      jnp.asarray(k1, jnp.uint32).reshape(1),
      jnp.asarray(k2, jnp.uint32).reshape(1),
      jnp.asarray(n).reshape(1),
      jnp.asarray(dt_grid, dtype).reshape(1),
      jnp.asarray(dt, dtype).reshape(1),
      jnp.asarray(sign, dtype).reshape(1))
    return zh1, dw
