from .cde import (  # noqa: F401
    CDEDiscriminatorSpec,
    cde_control_field,
    cde_discriminator_init,
    cde_drift,
    cde_initial,
    cde_readout,
)
from .core import (  # noqa: F401
    Embedding,
    gru_cell,
    gru_init,
    gru_scan,
    layernorm,
    layernorm_init,
    linear,
    linear_init,
    lipswish,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    silu,
)
