"""Collective/traffic diagnostics for one costing cell.

Prints the top collective ops (type, per-device bytes, source op_name) of a
1-unit unrolled lower — the measurement step of each §Perf iteration.

    PYTHONPATH=src python benchmarks/diagnose.py dbrx-132b train_4k [k]
    PYTHONPATH=src python benchmarks/diagnose.py dbrx-132b train_4k 1 sequence_parallel=true
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses
import re
import sys
from collections import defaultdict

_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_META = re.compile(r'op_name="([^"]*)"')

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8}


def _nbytes(dt, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dt, 4)


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    k = int(sys.argv[3]) if len(sys.argv) > 3 else 1
    overrides = {}
    for kv in sys.argv[4:]:
        key, v = kv.split("=")
        overrides[key] = {"true": True, "false": False}.get(v.lower(), v)

    from benchmarks.roofline import _costing_cfg
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import analyze, lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cfg = _costing_cfg(cfg, k)
    mesh = make_production_mesh(multi_pod=False)
    lowered, kind = lower_cell(cfg, SHAPES[shape_name], mesh)
    compiled = lowered.compile()
    text = compiled.as_text()

    per_op = []
    by_source = defaultdict(int)
    for line in text.splitlines():
        s = line.strip()
        for coll in _COLLECTIVES:
            if f" {coll}(" in s:
                head = s.split(f" {coll}(")[0]
                nb = sum(_nbytes(dt, dims) for dt, dims in _SHAPE.findall(head))
                m = _META.search(s)
                src = m.group(1) if m else "?"
                # strip the jit(...)/jvp noise, keep the tail of the op path
                src_tail = "/".join(src.split("/")[-3:])
                shapes = _SHAPE.findall(head)
                shape_str = (f"{shapes[0][0]}[{shapes[0][1]}]" if shapes else "?")
                per_op.append((nb, coll, src_tail))
                by_source[(coll, src_tail, shape_str)] += nb
                break

    total = sum(nb for nb, _, _ in per_op)
    print(f"{arch} × {shape_name} (k={k}, overrides={overrides}): "
          f"{len(per_op)} collectives, {total/2**30:.3f} GiB/dev total")
    print("\ntop sources:")
    for (coll, src, shp), nb in sorted(by_source.items(), key=lambda x: -x[1])[:18]:
        print(f"  {nb/2**30:8.3f} GiB  {coll:20s} {shp:28s} {src}")

    a = analyze(lowered)
    print(f"\nflops {a['flops']:.3e}  macro_bytes {a['macro_bytes']:.3e}  "
          f"raw_bytes {a['bytes_accessed']:.3e}")
    print("collectives by type:", {k: f"{v:.2e}" for k, v in
                                   a["collective_bytes"].items()})


if __name__ == "__main__":
    main()
