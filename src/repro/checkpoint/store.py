"""Sharded, atomic, step-granular checkpointing.

Layout::

    <dir>/step_<N>/
        shard_<host>.npz     # one file per host process (host 0 here)
        MANIFEST.json        # written LAST -> commit marker

A checkpoint is valid iff its MANIFEST exists; a crash mid-write leaves no
manifest and the directory is ignored (and garbage-collected on the next
save).  ``restore_checkpoint`` finds the newest valid step — the auto-resume
path of launch/train.py.  Leaves are addressed by their pytree key-path so a
restore is robust to dict-ordering changes.

**Serving bundles** (DESIGN.md §9/§11): training additionally persists a
params-only checkpoint under ``<dir>/serving/`` whose manifest carries the
``repro-serving/v2`` handshake — a **list of named model entries**
(``model_id`` + workload + the model config needed to rebuild each
parameter template), so one bundle can carry a whole model registry.
PR 4-era ``repro-serving/v1`` bundles (one anonymous workload) are
transparently upgraded at read time to a single-entry registry under
``model_id="default"``; an unknown schema version raises
:class:`UnknownServingSchemaError`.  ``repro.serving`` restores *only*
from a bundle, so a training checkpoint saved under different flags or an
older code version dies with a named error instead of a silent shape
mismatch.
"""

from __future__ import annotations

import dataclasses
import json
import shutil
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

SERVING_SCHEMA_V1 = "repro-serving/v1"
SERVING_SCHEMA_V2 = "repro-serving/v2"
#: The schema new bundles are written with.
SERVING_SCHEMA = SERVING_SCHEMA_V2
#: The model id a v1 bundle's single anonymous workload is upgraded to.
DEFAULT_MODEL_ID = "default"
_SERVING_SUBDIR = "serving"


class UnknownServingSchemaError(ValueError):
    """A serving bundle carries a schema this code version cannot read."""


def _leaf_names(tree) -> Tuple[list, Any]:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(path) for path, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return names, (leaves, treedef)


def save_checkpoint(ckpt_dir, step: int, tree, host_id: int = 0,
                    keep: int = 3, meta: Optional[dict] = None) -> Path:
    """Atomically persist ``tree`` at ``step``; prunes to ``keep`` newest.

    ``meta``: optional JSON-safe dict stored in the manifest (the serving
    handshake rides here)."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:012d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:012d}"
    if tmp_dir.exists():
        shutil.rmtree(tmp_dir)
    tmp_dir.mkdir(parents=True)

    names, (leaves, _) = _leaf_names(tree)
    arrays = {n: np.asarray(l) for n, l in zip(names, leaves)}
    np.savez(tmp_dir / f"shard_{host_id}.npz", **arrays)
    manifest = {
        "step": step,
        "num_hosts": 1,
        "leaves": {n: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for n, a in arrays.items()},
    }
    if meta is not None:
        manifest["meta"] = meta
    (tmp_dir / "MANIFEST.json").write_text(json.dumps(manifest, indent=2))
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp_dir.rename(step_dir)  # atomic commit

    # prune: keep the newest `keep` valid checkpoints + drop stale tmp dirs
    valid = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "MANIFEST.json").exists())
    for d in valid[:-keep]:
        shutil.rmtree(d)
    for d in ckpt_dir.glob(".tmp_step_*"):
        shutil.rmtree(d)
    return step_dir


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    valid = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "MANIFEST.json").exists())
    if not valid:
        return None
    return int(valid[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir, like_tree, step: Optional[int] = None,
                       host_id: int = 0):
    """Restore into the structure (and dtypes) of ``like_tree``.

    Returns (tree, step).  Raises FileNotFoundError when nothing valid exists.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:012d}"
    manifest = json.loads((step_dir / "MANIFEST.json").read_text())
    data = np.load(step_dir / f"shard_{host_id}.npz")

    names, (leaves, treedef) = _leaf_names(like_tree)
    restored = []
    for n, like in zip(names, leaves):
        arr = data[n]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"checkpoint leaf {n}: shape {arr.shape} != {like.shape}")
        restored.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["step"]


# -----------------------------------------------------------------------------
# serving bundles (the train -> serve checkpoint handshake; DESIGN.md §9)
# -----------------------------------------------------------------------------


def _json_safe(v):
    """JSON-encode dataclass config values; dtype-likes become their name."""
    try:
        json.dumps(v)
        return v
    except TypeError:
        return np.dtype(v).name  # jnp.float32 & friends


def config_to_meta(cfg) -> dict:
    """Dataclass model config -> the JSON-safe dict stored in the bundle."""
    d = dataclasses.asdict(cfg) if dataclasses.is_dataclass(cfg) else dict(cfg)
    return {k: _json_safe(v) for k, v in d.items()}


def save_serving_bundle(ckpt_dir, step: int, params, workload: str,
                        cfg, model_id: str = DEFAULT_MODEL_ID) -> Path:
    """Persist a params-only serving checkpoint under ``<ckpt_dir>/serving``.

    Writes the ``repro-serving/v2`` handshake: a single named model entry
    (``model_id`` + workload + config) so ``repro.serving`` can rebuild the
    parameter template and the sampler without the training flags.  For a
    multi-model bundle use :func:`save_serving_registry`."""
    return save_serving_registry(ckpt_dir, step,
                                 {model_id: (params, workload, cfg)})


def save_serving_registry(ckpt_dir, step: int, models: dict,
                          serving_hints: Optional[dict] = None) -> Path:
    """Persist N named models as ONE v2 serving bundle.

    ``models``: ``{model_id: (params, workload, cfg)}``.  The params trees
    are stored under their model id (leaf paths are prefixed), and the
    manifest's ``models`` list carries one entry per id — the registry
    handshake ``repro.serving.ModelRegistry.load`` restores from.

    ``serving_hints``: optional ``{model_id: dict}`` of JSON-safe serving
    hints written as each entry's ``"serving"`` key (e.g.
    ``{"quota": 4}``) — the loader surfaces them as
    ``LoadedModel.hints`` and the scheduler reads ``quota`` as a
    per-model admission default.  Hints are advisory: readers ignore keys
    they don't know, and bundles without the key load exactly as before
    (the v2 schema is unchanged — the key is additive)."""
    if not models:
        raise ValueError("a serving bundle needs at least one model entry")
    hints = serving_hints or {}
    unknown = sorted(set(hints) - set(models))
    if unknown:
        raise ValueError(f"serving_hints name model ids {unknown} that are "
                         f"not in the bundle ({sorted(models)})")
    meta = {"schema": SERVING_SCHEMA,
            "models": [{"model_id": mid, "workload": workload,
                        "config": config_to_meta(cfg),
                        **({"serving": hints[mid]} if mid in hints else {})}
                       for mid, (_, workload, cfg) in models.items()]}
    tree = {mid: params for mid, (params, _, _) in models.items()}
    return save_checkpoint(Path(ckpt_dir) / _SERVING_SUBDIR, step, tree,
                           meta=meta)


def save_serving_bundle_v1(ckpt_dir, step: int, params, workload: str,
                           cfg) -> Path:
    """Write the PR 4-era single-workload v1 bundle (flat params tree).

    Kept as the fixture writer for the v1→v2 upgrade path — production
    code writes v2 via :func:`save_serving_bundle`."""
    meta = {"schema": SERVING_SCHEMA_V1, "workload": workload,
            "config": config_to_meta(cfg)}
    return save_checkpoint(Path(ckpt_dir) / _SERVING_SUBDIR, step, params,
                           meta=meta)


def _raw_serving_manifest(ckpt_dir) -> Tuple[dict, int]:
    sdir = Path(ckpt_dir) / _SERVING_SUBDIR
    step = latest_step(sdir)
    if step is None:
        raise FileNotFoundError(
            f"no serving bundle under {ckpt_dir} — launch/train.py writes "
            f"<ckpt-dir>/{_SERVING_SUBDIR}/ alongside training checkpoints "
            f"(this checkpoint predates the serving subsystem, or the path "
            f"is wrong); re-run training, or use launch/serve.py --smoke "
            f"for a fresh-init service")
    manifest = json.loads(
        (sdir / f"step_{step:012d}" / "MANIFEST.json").read_text())
    return manifest.get("meta") or {}, step


def load_serving_manifest(ckpt_dir) -> Tuple[dict, int]:
    """Read the newest serving bundle's handshake as **v2** -> ``(meta, step)``.

    ``meta["models"]`` is always a list of ``{model_id, workload, config}``
    entries: a v1 bundle is transparently upgraded to a single-entry
    registry under ``model_id="default"`` (``meta["upgraded_from"]`` marks
    it, and :func:`restore_serving_model` reads its flat leaf layout).  An
    unknown schema raises :class:`UnknownServingSchemaError`; an absent
    bundle raises ``FileNotFoundError`` — named errors ``repro.serving``
    surfaces verbatim instead of a pytree-leaf mismatch deep inside
    restore."""
    meta, step = _raw_serving_manifest(ckpt_dir)
    schema = meta.get("schema")
    if schema == SERVING_SCHEMA_V1:
        meta = {"schema": SERVING_SCHEMA,
                "upgraded_from": SERVING_SCHEMA_V1,
                "models": [{"model_id": DEFAULT_MODEL_ID,
                            "workload": meta.get("workload"),
                            "config": meta.get("config", {})}]}
    elif schema != SERVING_SCHEMA_V2:
        raise UnknownServingSchemaError(
            f"serving bundle under {ckpt_dir} has schema {schema!r}; this "
            f"code reads {SERVING_SCHEMA_V2!r} (and upgrades "
            f"{SERVING_SCHEMA_V1!r}) — written by an incompatible code "
            f"version; re-run training or upgrade the reader")
    if not meta.get("models"):
        raise ValueError(
            f"serving bundle under {ckpt_dir} carries no model entries — "
            f"corrupt manifest; re-run training")
    return meta, step


def load_serving_meta(ckpt_dir) -> Tuple[dict, int]:
    """Back-compat single-model view of the handshake -> ``(meta, step)``.

    ``meta`` carries flat ``workload``/``config`` keys like the v1 reader
    did.  Multi-entry bundles are rejected by name — callers wanting the
    registry go through :func:`load_serving_manifest`."""
    meta, step = load_serving_manifest(ckpt_dir)
    models = meta["models"]
    if len(models) != 1:
        raise ValueError(
            f"serving bundle under {ckpt_dir} carries {len(models)} model "
            f"entries ({[m['model_id'] for m in models]}); the single-model "
            f"reader cannot pick one — use "
            f"repro.checkpoint.load_serving_manifest / "
            f"repro.serving.ModelRegistry.load")
    entry = models[0]
    return {"schema": meta["schema"], "model_id": entry["model_id"],
            "workload": entry["workload"], "config": entry["config"]}, step


def restore_serving_model(ckpt_dir, like_tree, model_id: str,
                          step: Optional[int] = None):
    """Restore ONE named model's params from a serving bundle.

    v2 bundles store each model's leaves under its id; an upgraded v1
    bundle stores the single ``"default"`` model flat — both layouts
    restore bitwise into ``like_tree``'s structure."""
    meta, newest = load_serving_manifest(ckpt_dir)
    ids = [m["model_id"] for m in meta["models"]]
    if model_id not in ids:
        raise ValueError(
            f"serving bundle under {ckpt_dir} has no model {model_id!r} "
            f"(entries: {ids})")
    sdir = Path(ckpt_dir) / _SERVING_SUBDIR
    if meta.get("upgraded_from") == SERVING_SCHEMA_V1:
        return restore_checkpoint(sdir, like_tree, step=step)
    tree, got = restore_checkpoint(sdir, {model_id: like_tree}, step=step)
    return tree[model_id], got


def restore_serving_bundle(ckpt_dir, like_tree, step: Optional[int] = None):
    """Back-compat: restore the params of a bundle's sole model entry."""
    meta, _ = load_serving_meta(ckpt_dir)  # rejects multi-entry by name
    return restore_serving_model(ckpt_dir, like_tree, meta["model_id"],
                                 step=step)
