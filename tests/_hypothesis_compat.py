"""Import-or-stub shim for hypothesis: plain tests always run.

The property-test modules used to ``pytest.importorskip("hypothesis")`` at
module scope, which skipped their PLAIN tests too whenever hypothesis was
absent (e.g. a minimal local environment).  Importing ``given``/
``settings``/``st`` from here instead keeps the granularity per-test:

* hypothesis installed (CI installs ``requirements-dev.txt``): the real
  decorators, property tests run and are enforced;
* hypothesis absent: each ``@given`` test is individually skip-marked with
  a named reason, and every non-property test in the module still runs.

``HAVE_HYPOTHESIS`` lets CI assert the real path was taken (the
property-test enforcement step greps for unexpected skips).
"""

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="property test needs hypothesis "
                       "(pip install -r requirements-dev.txt)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """``st.<anything>(...)`` placeholder; never executed — the
        ``@given`` wrapper above skips the test before drawing."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _StrategyStub()
