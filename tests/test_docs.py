"""Documentation cross-reference guards.

The repo's convention is that code comments cite docs by file + section
("DESIGN.md §4", "EXPERIMENTS.md §Perf").  These tests keep those
references live: every markdown file a source file points at must exist,
and every cited section must resolve — a rename or deletion fails tier-1
instead of leaving dangling pointers (the seed shipped nine references to a
nonexistent EXPERIMENTS.md).
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _source_blob() -> str:
    parts = []
    for sub in ("src", "benchmarks", "examples", "tests"):
        for p in (REPO / sub).rglob("*.py"):
            parts.append(p.read_text(encoding="utf-8"))
    for p in REPO.glob("*.md"):
        parts.append(p.read_text(encoding="utf-8"))
    return "\n".join(parts)


def test_referenced_markdown_files_exist():
    blob = _source_blob()
    missing = {name for name in set(re.findall(r"\b[A-Z][A-Z_]*\.md\b", blob))
               if not (REPO / name).exists()}
    assert not missing, f"dangling doc references: {sorted(missing)}"


def test_design_section_references_resolve():
    design = (REPO / "DESIGN.md").read_text(encoding="utf-8")
    cited = set(re.findall(r"DESIGN\.md §(\d+)", _source_blob()))
    assert cited, "expected at least one DESIGN.md section citation"
    missing = {n for n in cited if f"## §{n} " not in design}
    assert not missing, f"DESIGN.md sections cited but absent: {sorted(missing)}"


def test_experiments_section_references_resolve():
    exp = (REPO / "EXPERIMENTS.md").read_text(encoding="utf-8")
    cited = set(re.findall(r"EXPERIMENTS\.md §(\w+)", _source_blob()))
    assert cited, "expected at least one EXPERIMENTS.md section citation"
    missing = {s for s in cited if f"§{s}" not in exp}
    assert not missing, (
        f"EXPERIMENTS.md sections cited but absent: {sorted(missing)}")
