"""Fault-tolerant training driver.

Three workloads behind one driver (``--workload``):

* ``lm`` (default) — the transformer zoo (repro.models) train loop below;
* ``sde-gan`` — the paper's Neural SDE-GAN (repro.core.sde), every solve
  dispatched through the unified :func:`repro.solve` front-end
  (reversible Heun + exact O(1)-memory adjoint);
* ``latent-sde`` — the paper's Latent SDE / VAE (Li et al., Appendix B):
  one-``jax.vjp`` ELBO steps through the exact adjoint (or the
  ``--backsolve`` continuous-adjoint baseline), diagonal noise — the
  workload the Pallas-fused hot loop (``--pallas``) was built for.

Runs for real on whatever devices exist (CPU smoke configs here; the same
loop pjit-scales to the production mesh).  Demonstrates the full
large-scale-runnability posture:

* **step-granular atomic checkpoints** with auto-resume from the newest
  valid manifest (repro.checkpoint);
* **deterministic data** — the batch for step *n* is a pure function of
  (data_key, n), so restart/elastic replays identical samples;
* **simulated failure drill** (``--fail-at-step``): the process raises at a
  chosen step; re-running the same command resumes from the last checkpoint
  and reaches the same final step (tests/test_fault_tolerance.py asserts
  loss-trajectory equality);
* **elastic re-planning** (``--lose-devices``): on restart the mesh is
  re-planned from the surviving device count (distributed/elastic.py) and
  the global batch is re-sharded;
* **straggler monitor**: an EWMA per-step deadline; steps breaching it are
  logged (on a real fleet this triggers re-scheduling — here it exercises
  the control path).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt
from ..configs import get_config, smoke_config
from ..data.synthetic import token_batches
from ..distributed.elastic import plan_mesh, surviving_devices
from ..models import transformer as T
from .steps import make_optimizer, make_train_step


class StragglerMonitor:
    """EWMA step-time deadline: flags steps slower than ``factor``× the mean."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.mean: Optional[float] = None
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        straggle = self.mean is not None and dt > self.factor * self.mean
        self.mean = dt if self.mean is None else (1 - self.alpha) * self.mean + self.alpha * dt
        if straggle:
            self.flagged += 1
        return straggle


def train(arch: str, steps: int, batch: int, seq: int, ckpt_dir: Optional[str],
          ckpt_every: int = 20, smoke: bool = True, seed: int = 0,
          fail_at_step: Optional[int] = None, lose_devices: int = 0,
          log_every: int = 10, peak_lr: float = 3e-4):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    data_key = jax.random.fold_in(key, 1)

    # --- elastic planning: size the (data, model) grid to surviving devices
    n_dev = surviving_devices(len(jax.devices()), 0) - lose_devices
    data_deg, model_deg = plan_mesh(max(n_dev, 1), model_parallel=1)
    print(f"[train] mesh plan: data={data_deg} model={model_deg} "
          f"({n_dev} devices)", flush=True)

    params = T.init_lm(key, cfg)
    opt_init, opt_update = make_optimizer(cfg, peak_lr=peak_lr, total=steps)
    opt_state = opt_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_update), donate_argnums=(0, 1))

    start = 0
    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            (params, opt_state), start = ckpt.restore_checkpoint(
                ckpt_dir, (params, opt_state))
            print(f"[train] resumed from step {start}", flush=True)

    monitor = StragglerMonitor()
    losses = []
    for step in range(start, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"simulated node failure at step {step}")
        t0 = time.time()
        batch_data = token_batches(data_key, jnp.int32(step), batch, seq, cfg.vocab)
        if cfg.frontend and cfg.family != "encdec":
            f = cfg.frontend_len
            batch_data = {
                "embeds": jax.random.normal(
                    jax.random.fold_in(data_key, step + 10_000),
                    (batch, f, cfg.d_model), cfg.dtype),
                "tokens": batch_data["tokens"][:, f:],
                "labels": batch_data["labels"][:, f:],
            }
        elif cfg.family == "encdec":
            batch_data = {
                "src_embeds": jax.random.normal(
                    jax.random.fold_in(data_key, step + 10_000),
                    (batch, seq, cfg.d_model), cfg.dtype),
                "tokens": batch_data["tokens"],
                "labels": batch_data["labels"],
            }
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if monitor.observe(dt):
            print(f"[train] straggler: step {step} took {dt:.2f}s "
                  f"(mean {monitor.mean:.2f}s)", flush=True)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)
        if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
            ckpt.save_checkpoint(ckpt_dir, step + 1, (params, opt_state))
    if ckpt_dir is not None:
        ckpt.save_checkpoint(ckpt_dir, steps, (params, opt_state))
    return params, losses


def _data_parallel_mesh(batch: int, tag: str):
    """Data-parallel mesh over every visible device (1-device ⇒ no mesh).

    Both Neural-SDE workloads are pure batch parallelism (DESIGN.md §4/§8):
    parameters are tiny and replicated; only the sample batch shards.
    Simulate a multi-device host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
    ``--host-devices`` flag below does this for you).
    """
    from ..distributed.sharding import data_parallel_mesh

    mesh = data_parallel_mesh(batch)
    if mesh is None and len(jax.devices()) > 1:
        print(f"[{tag}] batch {batch} not divisible by "
              f"{len(jax.devices())} devices — running unsharded", flush=True)
    return mesh


def _restore_or_fresh(ckpt_dir: Optional[str], template, tag: str):
    """Resume from the newest checkpoint into ``template`` (fresh state,
    start step 0, when there is none).  A layout mismatch — a checkpoint
    saved under different flags or an older code version — dies here with
    a named error instead of deep inside pytree leaf lookup."""
    if ckpt_dir is None or ckpt.latest_step(ckpt_dir) is None:
        return template, 0
    try:
        state, start = ckpt.restore_checkpoint(ckpt_dir, template)
    except (KeyError, ValueError) as e:
        raise ValueError(
            f"checkpoint in {ckpt_dir} does not match the current "
            f"parameter/optimiser-state layout — it was saved under "
            f"different flags (e.g. --constraint) or an older code version; "
            f"use a fresh --ckpt-dir or rerun with matching flags") from e
    print(f"[{tag}] resumed from step {start}", flush=True)
    return state, start


def _sde_training_loop(tag: str, start: int, steps: int, batch: int, state,
                       step_fn, data_key, ckpt_dir: Optional[str],
                       ckpt_every: int, on_step, serving=None):
    """Shared step-loop scaffold for the Neural-SDE workloads (DESIGN.md
    §4/§8): data-parallel mesh over visible devices, straggler monitoring,
    periodic logging, step-granular atomic checkpoints.

    ``step_fn``: ``(state, key) -> (state, metrics)`` with ``state`` the
    checkpointed pytree.  ``on_step(step, state, metrics, dt)`` handles
    logging and returns a scalar to record in the returned history (or
    ``None`` to record nothing for this step).

    ``serving``: optional ``(workload, cfg, extract_params)`` handshake —
    every checkpoint save also writes the params-only serving bundle
    (``<ckpt_dir>/serving/``) that launch/serve.py restores from
    (DESIGN.md §9).  ``extract_params(state)`` picks the servable subtree
    (the generator for the GAN, the full VAE params for the latent SDE).
    """
    import contextlib

    from ..distributed.compat import set_mesh

    mesh = _data_parallel_mesh(batch, tag)
    if mesh is not None:
        print(f"[{tag}] data-parallel over {len(jax.devices())} devices",
              flush=True)
    mesh_ctx = set_mesh(mesh) if mesh is not None else contextlib.nullcontext()

    def save(step, state):
        ckpt.save_checkpoint(ckpt_dir, step, state)
        if serving is not None:
            workload, cfg, extract_params = serving
            ckpt.save_serving_bundle(ckpt_dir, step, extract_params(state),
                                     workload, cfg)

    monitor = StragglerMonitor()
    history = []
    with mesh_ctx:
        for step in range(start, steps):
            t0 = time.time()
            state, metrics = step_fn(state, jax.random.fold_in(data_key, step))
            dt = time.time() - t0
            if monitor.observe(dt):
                print(f"[{tag}] straggler: step {step} took {dt:.2f}s",
                      flush=True)
            rec = on_step(step, state, metrics, dt)
            if rec is not None:
                history.append(rec)
            if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
                save(step + 1, state)
    if ckpt_dir is not None:
        save(steps, state)
    return state, history


def train_sde_gan(steps: int, batch: int, ckpt_dir: Optional[str] = None,
                  ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
                  solver: str = "reversible_heun", use_pallas: bool = False,
                  num_steps: int = 31, seq_len: int = 32,
                  constraint: str = "clip", precision: str = "highest"):
    """SDE-GAN training (paper §5) through the :func:`repro.solve` front-end.

    The generator sample, joint generator+discriminator solve, and CDE
    discriminator all dispatch through the solver registry — reversible
    Heun with the exact adjoint by default (``gradient_mode`` is derived
    from the config inside repro.core.sde).  The step itself comes from
    :func:`repro.launch.steps.make_sde_gan_step`: one shared forward per
    step via ``jax.vjp``, careful clipping as the tail of the discriminator
    optimiser chain, batch sharded over the data-parallel mesh.
    """
    from ..core.losses import signature_mmd
    from ..core.sde import (NeuralSDEConfig, discriminator_init,
                            generator_init, generator_sample)
    from ..data.synthetic import ou_process
    from .steps import make_gan_optimizers, make_sde_gan_step

    cfg = NeuralSDEConfig(
        data_dim=1, hidden_dim=16, noise_dim=4, width=32, num_steps=num_steps,
        solver=solver, exact_adjoint=solver == "reversible_heun",
        use_pallas_kernels=use_pallas, precision=precision)
    key = jax.random.PRNGKey(seed)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    data_key = jax.random.fold_in(key, 2)

    (gi, gu), (di, du) = make_gan_optimizers(lr=1.0, constraint=constraint)
    g_state, d_state = gi(params["gen"]), di(params["disc"])
    step_fn = jax.jit(make_sde_gan_step(cfg, gu, du, batch, seq_len,
                                        constraint=constraint))

    state, start = _restore_or_fresh(ckpt_dir, (params, g_state, d_state),
                                     "sde-gan")

    def gan_step(state, k):
        params, g_state, d_state = state
        params, g_state, d_state, metrics = step_fn(params, g_state,
                                                    d_state, k)
        return (params, g_state, d_state), metrics

    def on_step(step, state, metrics, dt):
        if step % log_every != 0:
            return None
        y_real = ou_process(jax.random.fold_in(key, 777), 256, seq_len)
        fake = generator_sample(state[0]["gen"], cfg,
                                jax.random.fold_in(key, 778), 256)
        mmd = float(signature_mmd(y_real, fake))
        print(f"[sde-gan] step {step:5d} sig-MMD {mmd:.4f} "
              f"W {float(metrics['wasserstein']):.4f} {dt*1e3:.0f}ms",
              flush=True)
        return mmd

    (params, _, _), mmds = _sde_training_loop(
        "sde-gan", start, steps, batch, state, gan_step, data_key,
        ckpt_dir, ckpt_every, on_step,
        serving=("sde-gan", cfg, lambda s: s[0]["gen"]))
    return params, mmds


def train_latent_sde(steps: int, batch: int, ckpt_dir: Optional[str] = None,
                     ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
                     solver: str = "reversible_heun", use_pallas: bool = False,
                     num_steps: int = 23, seq_len: int = 24,
                     adjoint: str = "exact", kl_weight: float = 0.1,
                     lr: float = 1e-2, precision: str = "highest"):
    """Latent-SDE (VAE) training (paper Appendix B) at parity with the
    SDE-GAN path: same data-parallel mesh machinery, checkpointing,
    straggler monitoring — and the first workload whose training hot loop
    actually runs the Pallas-fused diagonal-noise kernels (``--pallas``).

    The step comes from :func:`repro.launch.steps.make_latent_sde_step`:
    one ``jax.vjp`` ELBO forward (encoder GRU + posterior solve with KL as
    a state channel), one cotangent pull through the reversible-Heun exact
    adjoint (or the continuous-adjoint "backsolve" baseline).
    """
    from ..core.sde import LatentSDEConfig, latent_sde_init
    from .steps import make_latent_sde_optimizer, make_latent_sde_step

    cfg = LatentSDEConfig(
        data_dim=2, hidden_dim=16, context_dim=16, width=32,
        num_steps=num_steps, solver=solver, kl_weight=kl_weight,
        exact_adjoint=adjoint == "exact" and solver == "reversible_heun",
        use_pallas_kernels=use_pallas, precision=precision)
    key = jax.random.PRNGKey(seed)
    params = latent_sde_init(key, cfg)
    data_key = jax.random.fold_in(key, 2)

    oi, ou = make_latent_sde_optimizer(lr)
    opt_state = oi(params)
    # eager validation (grid alignment, solver × adjoint × fusion) happens
    # here, before jit — see make_latent_sde_step
    step_fn = jax.jit(make_latent_sde_step(cfg, ou, batch, seq_len,
                                           adjoint=adjoint))

    state, start = _restore_or_fresh(ckpt_dir, (params, opt_state),
                                     "latent-sde")

    def vae_step(state, k):
        params, opt_state = state
        params, opt_state, metrics = step_fn(params, opt_state, k)
        return (params, opt_state), metrics

    def on_step(step, state, metrics, dt):
        loss = float(metrics["loss"])
        if step % log_every == 0:
            print(f"[latent-sde] step {step:5d} -ELBO {loss:.4f} "
                  f"recon {float(metrics['recon']):.4f} "
                  f"kl_path {float(metrics['kl_path']):.4f} "
                  f"{dt*1e3:.0f}ms", flush=True)
        return loss

    (params, _), losses = _sde_training_loop(
        "latent-sde", start, steps, batch, state, vae_step, data_key,
        ckpt_dir, ckpt_every, on_step,
        serving=("latent-sde", cfg, lambda s: s[0]))
    return params, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "sde-gan", "latent-sde"),
                    default="lm")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="lm: shrink the arch to a CPU-runnable smoke "
                         "config (default).  The sde-gan/latent-sde "
                         "defaults are already smoke-scale, so the flag is "
                         "a no-op there")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--lose-devices", type=int, default=0)
    ap.add_argument("--solver", default="reversible_heun",
                    help="sde-gan/latent-sde: any solver registered with "
                         "repro.solve")
    ap.add_argument("--pallas", action="store_true",
                    help="request the fused reversible-Heun hot loop.  The "
                         "latent-sde workload is diagonal-noise, so its "
                         "posterior solve runs genuinely fused (forward "
                         "scan + backward reconstruction); the sde-gan "
                         "workload's general-noise solves warn and run "
                         "unfused")
    ap.add_argument("--constraint", choices=("clip", "gp"), default="clip",
                    help="sde-gan Lipschitz control: 'clip' = the paper's "
                         "careful clipping, 'gp' = WGAN-GP baseline")
    ap.add_argument("--backsolve", action="store_true",
                    help="latent-sde: use the continuous-adjoint backsolve "
                         "baseline (Li et al. eq. (6), O(√h) gradient "
                         "error) instead of the exact reversible adjoint; "
                         "pairs with --solver midpoint (auto-selected if "
                         "the solver is left at reversible_heun)")
    ap.add_argument("--adjoint", choices=("exact", "backsolve", "checkpoint"),
                    default=None,
                    help="latent-sde gradient derivation: 'exact' (the "
                         "paper's reversible adjoint), 'backsolve' (same as "
                         "--backsolve), or 'checkpoint' (recursive binomial "
                         "checkpointing — exact gradients at O(log n) "
                         "memory, any solver).  Default: exact, or "
                         "backsolve when --backsolve is given")
    ap.add_argument("--precision", choices=("highest", "bf16_compute"),
                    default="highest",
                    help="sde-gan/latent-sde field-eval compute policy: "
                         "'bf16_compute' casts drift/diffusion evaluation "
                         "to bfloat16 while gradient accumulation stays in "
                         "the state dtype; 'highest' (default) is bitwise "
                         "unchanged")
    ap.add_argument("--kl-weight", type=float, default=0.1,
                    help="latent-sde: ELBO KL term weight")
    ap.add_argument("--lr", type=float, default=1e-2,
                    help="latent-sde: Adam learning rate")
    ap.add_argument("--sde-steps", type=int, default=None,
                    help="solver steps per solve (default: 31 for sde-gan; "
                         "23 for latent-sde, which must be a positive "
                         "multiple of seq_len - 1)")
    ap.add_argument("--seq-len", type=int, default=None,
                    help="observed path length (default: 32 for sde-gan, "
                         "24 for latent-sde)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="simulate N CPU devices (sets "
                         "--xla_force_host_platform_device_count before the "
                         "backend initialises; must come before any jax use)")
    args = ap.parse_args(argv)
    if args.host_devices is not None:
        from ..distributed.compat import force_host_device_count

        force_host_device_count(args.host_devices)
    if args.workload == "sde-gan":
        _, mmds = train_sde_gan(
            args.steps, args.batch, args.ckpt_dir, args.ckpt_every, args.seed,
            solver=args.solver, use_pallas=args.pallas,
            num_steps=31 if args.sde_steps is None else args.sde_steps,
            seq_len=32 if args.seq_len is None else args.seq_len,
            constraint=args.constraint, precision=args.precision)
        if mmds:
            print(f"[sde-gan] done: first sig-MMD {mmds[0]:.4f} -> "
                  f"last {mmds[-1]:.4f}")
        else:  # e.g. resumed a finished run: no steps executed
            print("[sde-gan] done: no steps run")
        return
    if args.workload == "latent-sde":
        adjoint = args.adjoint
        if adjoint is None:
            adjoint = "backsolve" if args.backsolve else "exact"
        elif args.backsolve and adjoint != "backsolve":
            ap.error(f"--backsolve conflicts with --adjoint {adjoint}")
        solver = args.solver
        if adjoint == "backsolve" and solver == "reversible_heun":
            solver = "midpoint"  # the backsolve baseline's solver (paper's)
            print("[latent-sde] --backsolve: using midpoint (reversible_heun "
                  "has no continuous-adjoint backward)", flush=True)
        seq_len = 24 if args.seq_len is None else args.seq_len
        num_steps = seq_len - 1 if args.sde_steps is None else args.sde_steps
        _, losses = train_latent_sde(
            args.steps, args.batch, args.ckpt_dir, args.ckpt_every, args.seed,
            solver=solver, use_pallas=args.pallas,
            num_steps=num_steps, seq_len=seq_len, adjoint=adjoint,
            kl_weight=args.kl_weight, lr=args.lr,
            precision=args.precision)
        if losses:
            print(f"[latent-sde] done: first -ELBO {losses[0]:.4f} -> "
                  f"last {losses[-1]:.4f}")
        else:
            print("[latent-sde] done: no steps run")
        return
    _, losses = train(args.arch, args.steps, args.batch, args.seq,
                      args.ckpt_dir, args.ckpt_every, args.smoke, args.seed,
                      args.fail_at_step, args.lose_devices)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
