"""Bench-regression gate tests (benchmarks/report.py --compare).

CI's bench-smoke job snapshots the committed BENCH_*.json trajectory, reruns
the tiny preset, and fails on a >2× wall-clock regression of any gated
(``*_ms``) metric.  These tests pin the gate's decision table: regression
detected, within-factor pass, absent-from-baseline skip, preset/backend
mismatch skip.
"""

import copy
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from benchmarks.report import _is_gated, compare_bench  # noqa: E402

DOC = {
    "schema": "repro-bench/v1",
    "name": "serving",
    "preset": "tiny",
    "backend": "cpu",
    "jax_version": "0.0.test",
    "rows": [
        {"suite": "serving", "label": "sde_gan_batch4_ms", "value": 10.0},
        {"suite": "serving", "label": "sde_gan_traj_per_s,batch=4", "value": 400.0},
        {"suite": "serving", "label": "latent_prior_fused_speedup", "value": 1.0},
    ],
}


def _write(d, doc):
    d.mkdir(exist_ok=True)
    (d / "BENCH_serving.json").write_text(json.dumps(doc))


def test_gated_labels_are_wall_clock_only():
    assert _is_gated("serving", "sde_gan_batch4_ms")
    assert _is_gated("clipping", "clipping_ms_per_step")
    # solver_speed's bare labels predate the _ms convention but are all ms
    assert _is_gated("solver_speed", "reversible_heun")
    assert _is_gated("solver_speed_batching", "batched")
    # higher-is-better / ratio / bytes rows are each suite's own gates
    assert not _is_gated("serving", "sde_gan_traj_per_s,batch=4")
    assert not _is_gated("latent_sde", "fused_speedup")
    assert not _is_gated("latent_sde", "unfused_bytes_accessed")
    assert not _is_gated("brownian", "sequential,size=1")  # VBT/BI ratio


def test_compare_passes_within_factor(tmp_path):
    fresh = copy.deepcopy(DOC)
    fresh["rows"][0]["value"] = 19.0  # 1.9x < 2x: noisy but tolerated
    _write(tmp_path / "base", DOC)
    _write(tmp_path / "fresh", fresh)
    assert compare_bench(tmp_path / "base", tmp_path / "fresh") == 0


def test_compare_fails_on_2x_regression(tmp_path):
    fresh = copy.deepcopy(DOC)
    fresh["rows"][0]["value"] = 25.0  # 2.5x > 2x
    _write(tmp_path / "base", DOC)
    _write(tmp_path / "fresh", fresh)
    assert compare_bench(tmp_path / "base", tmp_path / "fresh") == 1
    # a looser explicit factor tolerates the same value
    assert compare_bench(tmp_path / "base", tmp_path / "fresh", factor=3.0) == 0


def test_compare_skips_metrics_absent_from_baseline(tmp_path):
    """A new row (or suite) cannot fail the PR that introduces it."""
    fresh = copy.deepcopy(DOC)
    fresh["rows"].append(
        {"suite": "serving", "label": "brand_new_ms", "value": 1e9})
    _write(tmp_path / "base", DOC)
    _write(tmp_path / "fresh", fresh)
    assert compare_bench(tmp_path / "base", tmp_path / "fresh") == 0
    # ...and a baseline-less file is skipped wholesale
    (tmp_path / "fresh" / "BENCH_new_suite.json").write_text(
        json.dumps({**copy.deepcopy(DOC), "name": "new_suite"}))
    assert compare_bench(tmp_path / "base", tmp_path / "fresh") == 0


def test_compare_skips_sub_noise_floor_baselines(tmp_path):
    """Sub-half-ms baselines are dispatch-noise-dominated; the ratio gate
    skips them instead of flipping coins."""
    base = copy.deepcopy(DOC)
    base["rows"][0]["value"] = 0.3  # < COMPARE_NOISE_FLOOR_MS
    fresh = copy.deepcopy(base)
    fresh["rows"][0]["value"] = 3.0  # 10x, but unjudgeable
    _write(tmp_path / "base", base)
    _write(tmp_path / "fresh", fresh)
    assert compare_bench(tmp_path / "base", tmp_path / "fresh") == 0


def test_compare_skips_preset_or_backend_mismatch(tmp_path):
    """A tiny-CPU baseline says nothing about a full-TPU run."""
    fresh = copy.deepcopy(DOC)
    fresh["rows"][0]["value"] = 1000.0  # would be a 100x "regression"
    fresh["preset"] = "full"
    _write(tmp_path / "base", DOC)
    _write(tmp_path / "fresh", fresh)
    assert compare_bench(tmp_path / "base", tmp_path / "fresh") == 0
