"""Fault-tolerant training driver.

Two workloads behind one driver (``--workload``):

* ``lm`` (default) — the transformer zoo (repro.models) train loop below;
* ``sde-gan`` — the paper's Neural SDE-GAN (repro.core.sde), every solve
  dispatched through the unified :func:`repro.solve` front-end
  (reversible Heun + exact O(1)-memory adjoint, optional Pallas-fused hot
  loop via ``--pallas``).

Runs for real on whatever devices exist (CPU smoke configs here; the same
loop pjit-scales to the production mesh).  Demonstrates the full
large-scale-runnability posture:

* **step-granular atomic checkpoints** with auto-resume from the newest
  valid manifest (repro.checkpoint);
* **deterministic data** — the batch for step *n* is a pure function of
  (data_key, n), so restart/elastic replays identical samples;
* **simulated failure drill** (``--fail-at-step``): the process raises at a
  chosen step; re-running the same command resumes from the last checkpoint
  and reaches the same final step (tests/test_fault_tolerance.py asserts
  loss-trajectory equality);
* **elastic re-planning** (``--lose-devices``): on restart the mesh is
  re-planned from the surviving device count (distributed/elastic.py) and
  the global batch is re-sharded;
* **straggler monitor**: an EWMA per-step deadline; steps breaching it are
  logged (on a real fleet this triggers re-scheduling — here it exercises
  the control path).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt
from ..configs import get_config, smoke_config
from ..data.synthetic import token_batches
from ..distributed.elastic import plan_mesh, surviving_devices
from ..models import transformer as T
from .steps import make_optimizer, make_train_step


class StragglerMonitor:
    """EWMA step-time deadline: flags steps slower than ``factor``× the mean."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.mean: Optional[float] = None
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        straggle = self.mean is not None and dt > self.factor * self.mean
        self.mean = dt if self.mean is None else (1 - self.alpha) * self.mean + self.alpha * dt
        if straggle:
            self.flagged += 1
        return straggle


def train(arch: str, steps: int, batch: int, seq: int, ckpt_dir: Optional[str],
          ckpt_every: int = 20, smoke: bool = True, seed: int = 0,
          fail_at_step: Optional[int] = None, lose_devices: int = 0,
          log_every: int = 10, peak_lr: float = 3e-4):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    data_key = jax.random.fold_in(key, 1)

    # --- elastic planning: size the (data, model) grid to surviving devices
    n_dev = surviving_devices(len(jax.devices()), 0) - lose_devices
    data_deg, model_deg = plan_mesh(max(n_dev, 1), model_parallel=1)
    print(f"[train] mesh plan: data={data_deg} model={model_deg} "
          f"({n_dev} devices)", flush=True)

    params = T.init_lm(key, cfg)
    opt_init, opt_update = make_optimizer(cfg, peak_lr=peak_lr, total=steps)
    opt_state = opt_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_update), donate_argnums=(0, 1))

    start = 0
    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            (params, opt_state), start = ckpt.restore_checkpoint(
                ckpt_dir, (params, opt_state))
            print(f"[train] resumed from step {start}", flush=True)

    monitor = StragglerMonitor()
    losses = []
    for step in range(start, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"simulated node failure at step {step}")
        t0 = time.time()
        batch_data = token_batches(data_key, jnp.int32(step), batch, seq, cfg.vocab)
        if cfg.frontend and cfg.family != "encdec":
            f = cfg.frontend_len
            batch_data = {
                "embeds": jax.random.normal(
                    jax.random.fold_in(data_key, step + 10_000),
                    (batch, f, cfg.d_model), cfg.dtype),
                "tokens": batch_data["tokens"][:, f:],
                "labels": batch_data["labels"][:, f:],
            }
        elif cfg.family == "encdec":
            batch_data = {
                "src_embeds": jax.random.normal(
                    jax.random.fold_in(data_key, step + 10_000),
                    (batch, seq, cfg.d_model), cfg.dtype),
                "tokens": batch_data["tokens"],
                "labels": batch_data["labels"],
            }
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if monitor.observe(dt):
            print(f"[train] straggler: step {step} took {dt:.2f}s "
                  f"(mean {monitor.mean:.2f}s)", flush=True)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)
        if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
            ckpt.save_checkpoint(ckpt_dir, step + 1, (params, opt_state))
    if ckpt_dir is not None:
        ckpt.save_checkpoint(ckpt_dir, steps, (params, opt_state))
    return params, losses


def _gan_mesh(batch: int):
    """Data-parallel mesh over every visible device (1-device ⇒ no mesh).

    The GAN step is pure batch parallelism (DESIGN.md §4): parameters are
    tiny and replicated; only the sample batch shards.  Simulate a multi-
    device host with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (the ``--host-devices`` flag below does this for you).
    """
    from ..distributed.compat import make_mesh

    n_dev = len(jax.devices())
    if n_dev <= 1:
        return None
    if batch % n_dev != 0:
        print(f"[sde-gan] batch {batch} not divisible by {n_dev} devices — "
              f"running unsharded", flush=True)
        return None
    return make_mesh((n_dev,), ("data",))


def train_sde_gan(steps: int, batch: int, ckpt_dir: Optional[str] = None,
                  ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
                  solver: str = "reversible_heun", use_pallas: bool = False,
                  num_steps: int = 31, seq_len: int = 32,
                  constraint: str = "clip"):
    """SDE-GAN training (paper §5) through the :func:`repro.solve` front-end.

    The generator sample, joint generator+discriminator solve, and CDE
    discriminator all dispatch through the solver registry — reversible
    Heun with the exact adjoint by default (``gradient_mode`` is derived
    from the config inside repro.core.sde).  The step itself comes from
    :func:`repro.launch.steps.make_sde_gan_step`: one shared forward per
    step via ``jax.vjp``, careful clipping as the tail of the discriminator
    optimiser chain, batch sharded over the data-parallel mesh.
    """
    import contextlib

    from ..core.losses import signature_mmd
    from ..core.sde import (NeuralSDEConfig, discriminator_init,
                            generator_init, generator_sample)
    from ..data.synthetic import ou_process
    from ..distributed.compat import set_mesh
    from .steps import make_gan_optimizers, make_sde_gan_step

    cfg = NeuralSDEConfig(
        data_dim=1, hidden_dim=16, noise_dim=4, width=32, num_steps=num_steps,
        solver=solver, exact_adjoint=solver == "reversible_heun",
        use_pallas_kernels=use_pallas)
    key = jax.random.PRNGKey(seed)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    data_key = jax.random.fold_in(key, 2)

    (gi, gu), (di, du) = make_gan_optimizers(lr=1.0, constraint=constraint)
    g_state, d_state = gi(params["gen"]), di(params["disc"])
    step_fn = jax.jit(make_sde_gan_step(cfg, gu, du, batch, seq_len,
                                        constraint=constraint))

    start = 0
    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            try:
                (params, g_state, d_state), start = ckpt.restore_checkpoint(
                    ckpt_dir, (params, g_state, d_state))
            except (KeyError, ValueError) as e:
                # the optimiser-state pytree depends on --constraint (the
                # clip chain carries an extra projection slot); a mismatched
                # checkpoint otherwise dies deep in leaf lookup
                raise ValueError(
                    f"checkpoint in {ckpt_dir} does not match the current "
                    f"optimiser-state layout — it was saved under a "
                    f"different --constraint or an older code version; use "
                    f"a fresh --ckpt-dir or rerun with matching flags") from e
            print(f"[sde-gan] resumed from step {start}", flush=True)

    mesh = _gan_mesh(batch)
    if mesh is not None:
        print(f"[sde-gan] data-parallel over {len(jax.devices())} devices",
              flush=True)
    mesh_ctx = set_mesh(mesh) if mesh is not None else contextlib.nullcontext()

    monitor = StragglerMonitor()
    mmds = []
    with mesh_ctx:
        for step in range(start, steps):
            t0 = time.time()
            params, g_state, d_state, metrics = step_fn(
                params, g_state, d_state, jax.random.fold_in(data_key, step))
            dt = time.time() - t0
            if monitor.observe(dt):
                print(f"[sde-gan] straggler: step {step} took {dt:.2f}s",
                      flush=True)
            if step % log_every == 0:
                y_real = ou_process(jax.random.fold_in(key, 777), 256, seq_len)
                fake = generator_sample(params["gen"], cfg,
                                        jax.random.fold_in(key, 778), 256)
                mmd = float(signature_mmd(y_real, fake))
                mmds.append(mmd)
                print(f"[sde-gan] step {step:5d} sig-MMD {mmd:.4f} "
                      f"W {float(metrics['wasserstein']):.4f} {dt*1e3:.0f}ms",
                      flush=True)
            if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
                ckpt.save_checkpoint(ckpt_dir, step + 1,
                                     (params, g_state, d_state))
    if ckpt_dir is not None:
        ckpt.save_checkpoint(ckpt_dir, steps, (params, g_state, d_state))
    return params, mmds


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "sde-gan"), default="lm")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--lose-devices", type=int, default=0)
    ap.add_argument("--solver", default="reversible_heun",
                    help="sde-gan: any solver registered with repro.solve")
    ap.add_argument("--pallas", action="store_true",
                    help="sde-gan: request the fused reversible-Heun hot "
                         "loop; the GAN's general-noise solves warn and run "
                         "unfused (fusion applies to diagonal-noise solves, "
                         "e.g. Latent SDE)")
    ap.add_argument("--constraint", choices=("clip", "gp"), default="clip",
                    help="sde-gan Lipschitz control: 'clip' = the paper's "
                         "careful clipping, 'gp' = WGAN-GP baseline")
    ap.add_argument("--sde-steps", type=int, default=31,
                    help="sde-gan: solver steps per solve")
    ap.add_argument("--seq-len", type=int, default=32,
                    help="sde-gan: observed path length")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="simulate N CPU devices (sets "
                         "--xla_force_host_platform_device_count before the "
                         "backend initialises; must come before any jax use)")
    args = ap.parse_args(argv)
    if args.host_devices is not None:
        import os

        try:  # backend already up ⇒ the flag would be silently ignored
            initialised = bool(jax._src.xla_bridge._backends)
        except AttributeError:  # internal layout moved; trust the caller
            initialised = False
        if initialised:
            raise RuntimeError("--host-devices must be processed before jax "
                               "initialises; set XLA_FLAGS instead")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")
    if args.workload == "sde-gan":
        _, mmds = train_sde_gan(args.steps, args.batch, args.ckpt_dir,
                                args.ckpt_every, args.seed,
                                solver=args.solver, use_pallas=args.pallas,
                                num_steps=args.sde_steps, seq_len=args.seq_len,
                                constraint=args.constraint)
        if mmds:
            print(f"[sde-gan] done: first sig-MMD {mmds[0]:.4f} -> "
                  f"last {mmds[-1]:.4f}")
        else:  # e.g. resumed a finished run: no steps executed
            print("[sde-gan] done: no steps run")
        return
    _, losses = train(args.arch, args.steps, args.batch, args.seq,
                      args.ckpt_dir, args.ckpt_every, args.smoke, args.seed,
                      args.fail_at_step, args.lose_devices)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
