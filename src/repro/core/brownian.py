"""Brownian motion sampling — in-graph (XLA/TPU-native) implementations.

Three samplers, mirroring the paper's landscape (Section 4):

* :class:`BrownianPath` — the TPU-native adaptation of the paper's Brownian
  Interval.  JAX's counter-based splittable PRNG (Threefry; the paper's own
  reference [34] for splittable PRNGs) lets us derive the increment of *any*
  solver step from ``fold_in(key, step_index)``: exact, O(1) memory, O(1)
  time, and bit-identical on the forward and backward passes with **zero**
  storage.  Off-grid queries use Lévy-bridge bisection over a virtual dyadic
  tree, conditioning exactly as the paper's eq. (8).

* :class:`VirtualBrownianTree` — the Li et al. [15] baseline the paper beats:
  fixed-depth dyadic bisection to a tolerance ``eps``; approximate.

* :func:`brownian_increments` — dense pregenerated increments (the
  "store everything" O(T)-memory baseline).

The *faithful* host-side Brownian Interval (binary tree + LRU cache + search
hints, Algorithms 3/4) lives in :mod:`repro.core.brownian_interval`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _normal_like(key: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return jax.random.normal(key, shape, dtype=dtype)


#: Valid values of the paths' ``levy_area`` mode.  ``None`` keeps the
#: original scalar-increment behaviour (bit-identical to before the mode
#: existed); ``"space-time"`` makes ``increment``/``evaluate``/``value``
#: return ``(W, H)`` pairs, where ``H`` is the space-time Lévy area of the
#: queried interval (Foster et al. [54]; paper App. E) — the extra
#: integral the strong-order-1.5 SRK solver consumes.
LEVY_AREAS = (None, "space-time")


def _check_levy_mode(levy_area) -> None:
    if levy_area not in LEVY_AREAS:
        raise ValueError(
            f"unknown levy_area mode {levy_area!r}; supported: {LEVY_AREAS}")


def stlevy_difference(val_s, val_t, s, t, t0):
    """``(W, H)`` over ``[s, t]`` from two space-time path *values*.

    ``val_s``/``val_t`` are ``(W, H)`` pairs as returned by a path's
    ``value`` in ``levy_area="space-time"`` mode — both components
    relative to ``t0``.  The W component is the literal difference
    ``val_t[0] - val_s[0]`` (so ``evaluate(s,t)[0] == value(t)[0] -
    value(s)[0]`` stays bitwise).  The H component inverts Chen's
    relation exactly: with the running time-integral ``I(u) =
    (u - t0)·(H_u + W_u/2) = ∫_{t0}^u (W_r - W_{t0}) dr``, the interval's
    raw time-area is ``A_{s,t} = I(t) - I(s) - (t-s)·W_s`` and
    ``H_{s,t} = A_{s,t}/(t-s) - W_{s,t}/2``.  Because every query is this
    difference of per-point values, H additivity (the chen-combine rule)
    holds over adjacent intervals by construction.

    The same op graph serves the adaptive driver, the checkpoint
    backend's freeze-and-replay, and ``evaluate`` itself — the bitwise-
    replay requirement (DESIGN.md §10).  A zero-length query (padding
    slots in the checkpoint replay) returns exact zeros instead of 0/0.
    """
    w_s, h_s = val_s
    w_t, h_t = val_t
    dtype = jnp.result_type(w_t)
    s = jnp.asarray(s, dtype)
    t = jnp.asarray(t, dtype)
    t0 = jnp.asarray(t0, dtype)
    dw = w_t - w_s
    i_s = (s - t0) * (h_s + 0.5 * w_s)
    i_t = (t - t0) * (h_t + 0.5 * w_t)
    span = t - s
    area = i_t - i_s - span * w_s
    safe = jnp.where(span == 0, jnp.ones_like(span), span)
    dh = jnp.where(span == 0, jnp.zeros_like(dw), area / safe - 0.5 * dw)
    return dw, dh


def _h_from_wi(w, i, span, dtype):
    """``H = I/span - W/2`` with the zero-length query guarded to 0."""
    span = jnp.asarray(span, dtype)
    safe = jnp.where(span == 0, jnp.ones_like(span), span)
    return jnp.where(span == 0, jnp.zeros_like(w), i / safe - 0.5 * w)


def brownian_increments(
    key: jax.Array,
    t0: float,
    t1: float,
    num_steps: int,
    shape: Tuple[int, ...],
    dtype=jnp.float32,
) -> jax.Array:
    """Dense iid increments ``W_{t_{n+1}} - W_{t_n}`` — O(T) memory baseline."""
    dt = (t1 - t0) / num_steps
    keys = jax.random.split(key, num_steps)
    out = jax.vmap(lambda k: _normal_like(k, shape, dtype))(keys)
    return out * jnp.sqrt(jnp.asarray(dt, dtype))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BrownianPath:
    """Exact, stateless, counter-based Brownian sample path on ``[t0, t1]``.

    The path is *defined* by ``key``: every query is a pure function of
    ``(key, query)``, so forward and backward passes of a solver see the same
    sample without storing anything (the paper's core requirement, §4).

    ``increment(n, num_steps)`` is the fast path used by fixed-step solvers:
    step ``n`` of an ``num_steps``-step grid.  Different grids over the same
    key are *different* refinements consistent in distribution but not
    pathwise; solvers must use one grid per solve (as torchsde's fixed-step
    solvers do).  ``evaluate(s, t)`` offers pathwise-consistent arbitrary
    queries via dyadic Lévy-bridge descent (exact at dyadic points, depth-
    limited elsewhere like the Virtual Brownian Tree but reusing the same
    conditioning as the paper's eq. (8)).

    ``levy_area="space-time"`` switches every query to ``(W, H)`` pairs
    (paper App. E; DESIGN.md §13): ``increment`` draws iid pairs per grid
    step, and ``evaluate``/``value`` run a joint ``(W, ∫W)`` Lévy-bridge
    descent whose per-level conditioning extends eq. (8) with the interval
    time-integral, so H combines exactly over adjacent intervals (Chen's
    relation) while the W component keeps the bitwise
    ``evaluate(s,t) == value(t) - value(s)`` contract.  ``levy_area=None``
    paths are bit-identical to the pre-mode implementation — the H-mode
    descent is a separate key stream and code path.
    """

    key: jax.Array
    t0: float
    t1: float
    shape: Tuple[int, ...]
    dtype: object = jnp.float32
    levy_area: Optional[str] = None

    def __post_init__(self):
        _check_levy_mode(self.levy_area)

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.key,), (self.t0, self.t1, self.shape, self.dtype,
                             self.levy_area)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, t1, shape, dtype, levy_area = aux
        return cls(key=key, t0=t0, t1=t1, shape=shape, dtype=dtype,
                   levy_area=levy_area)

    # -- fixed-grid exact increments ----------------------------------------
    def increment(self, n: jax.Array, num_steps: int) -> jax.Array:
        """Exact increment of step ``n`` on the ``num_steps`` uniform grid.

        Dispatches through :mod:`repro.kernels.ops`: on TPU the draw runs
        *inside* a Pallas kernel (counter-based Threefry keyed on ``n``,
        bit-identical to the ``jax.random`` scheme — see
        :mod:`repro.kernels.prng`); elsewhere the pure-jnp oracle runs.
        """
        from ..kernels import ops

        dt = (self.t1 - self.t0) / num_steps
        if self.levy_area == "space-time":
            # iid (W, H) pair for this grid cell — the fold_in(key, n)
            # schedule mirrors the scalar stream but is a distinct draw
            # (the H-mode key is consumed by space_time_levy_area's split)
            return space_time_levy_area(jax.random.fold_in(self.key, n),
                                        dt, self.shape, self.dtype)
        return ops.brownian_increment(self.key, n, self.shape, self.dtype, dt)

    def increments(self, num_steps: int) -> jax.Array:
        """All increments on the grid, stacked (for dense baselines/tests)."""
        return jax.vmap(lambda n: self.increment(n, num_steps))(
            jnp.arange(num_steps)
        )

    # -- arbitrary-interval queries (Lévy bridge descent) --------------------
    def evaluate(self, s, t, depth: int = 24):
        """``W_t - W_s`` via ``W(t) - W(s)`` with dyadic bridge descent.

        In ``levy_area="space-time"`` mode: the ``(W, H)`` pair of
        ``[s, t]`` via :func:`stlevy_difference` over the two point
        values — W stays the literal value difference (bitwise), H obeys
        chen-combine additivity by construction."""
        if self.levy_area == "space-time":
            return stlevy_difference(self.value(s, depth),
                                     self.value(t, depth),
                                     s, t, self.t0)
        return self._w(t, depth) - self._w(s, depth)

    def value(self, t, depth: int = 24):
        """``W(t) - W(t0)`` — one bridge descent.  Contract (relied on by
        the adaptive driver, which carries the left-endpoint value):
        ``evaluate(s, t) == value(t) - value(s)`` bitwise.  In
        ``levy_area="space-time"`` mode returns the pair
        ``(W(t) - W(t0), H_{t0,t})``."""
        if self.levy_area == "space-time":
            dtype = jnp.dtype(self.dtype)
            w, i = self._wh(t, depth)
            span = jnp.asarray(t, dtype) - jnp.asarray(self.t0, dtype)
            return w, _h_from_wi(w, i, span, dtype)
        return self._w(t, depth)

    def _w(self, t, depth: int) -> jax.Array:
        """Sample W(t) by descending the virtual dyadic tree to ``depth``.

        Invariant per level: the current interval ``[a, b]`` has endpoint
        values ``(wa, wb)``; the midpoint value is bridge-sampled from the
        interval's splittable seed (the Lévy bridge of the paper's eq. (8):
        mean = linear interpolant, std = sqrt((b-m)(m-a)/(b-a))), then we
        recurse into the half containing ``t``.  At dyadic ``t`` this
        terminates exactly; otherwise the depth bound gives a
        2^-depth * (t1-t0) resolution (the VBT trade-off, but sharing seeds
        with ``increment`` queries is not required — a BrownianPath used
        with bridge queries should use ``evaluate`` only).

        Dispatches through :mod:`repro.kernels.ops`: on TPU the whole
        descent runs as ONE Pallas kernel (in-kernel Threefry + a single
        batched midpoint draw); elsewhere the vectorised jnp oracle
        (:func:`repro.kernels.ref.brownian_value`) runs — same per-element
        op sequence, so both produce identical bits.
        """
        from ..kernels import ops

        return ops.brownian_value(self.key, t, self.t0, self.t1, self.shape,
                                  self.dtype, depth=depth)

    def _wh(self, t, depth: int):
        """Joint ``(W(t) - W(t0), I(t))`` descent, where ``I(t) =
        ∫_{t0}^t (W_r - W_{t0}) dr`` is the running time-integral.

        Each level of the dyadic descent carries the current interval's
        ``(w, A)`` — increment and *raw time-area* ``A = ∫ (W_r - W_a) dr``
        — plus the prefix ``(W(a) - W(t0), I(a))`` accumulated on
        right-descents.  The midpoint conditional (joint Gaussian
        conditioning of ``(W_m, ∫_a^m W)`` on ``(w, A)``; the H extension
        of the paper's eq. (8)) is, with ``h = b - a`` and ``l = h/2``::

            w_left = (3/2)·A/h - w/4 + sqrt(l/8)  · ξ0
            a_left = -l·w/4 + A/2   + sqrt(l³/24) · ξ1

        with ``w_left ⊥ a_left`` given ``(w, A)`` (the conditional
        cross-covariance vanishes exactly at the midpoint), and::

            w_right = w - w_left
            a_right = A - a_left - l·w_left

        At the depth bound the cell tail is closed with the conditional
        *mean* given the cell's ``(w, A)`` (θ = in-cell fraction)::

            W += (3θ² - 2θ)·w + 6θ(1-θ)·A/h
            I += θh·prefix_W + h(θ³ - θ²)·w + (3θ² - 2θ³)·A

        — deterministic, so queries stay exactly additive (the same
        truncation trade-off as the scalar descent's linear tail).

        A fresh key stream (root tag 0xB0BA, midpoints ``fold_in(·, 1)``
        then a split for the two conditional normals) keeps the
        ``levy_area=None`` draws untouched.
        """
        dtype = jnp.dtype(self.dtype)
        shape = self.shape
        t = jnp.asarray(t, dtype)
        span = self.t1 - self.t0
        root_key = jax.random.fold_in(self.key, 0xB0BA)
        w_root, h_root = space_time_levy_area(root_key, span, shape, dtype)
        a_root = jnp.asarray(span, dtype) * (h_root + 0.5 * w_root)

        def body(_, c):
            a, b, w, area, pw, pi, key = c
            h = b - a
            half = 0.5 * h
            m = a + half
            k0, k1 = jax.random.split(jax.random.fold_in(key, 1))
            xi0 = _normal_like(k0, shape, dtype)
            xi1 = _normal_like(k1, shape, dtype)
            w_l = 1.5 * area / h - 0.25 * w + jnp.sqrt(half / 8.0) * xi0
            a_l = -0.25 * half * w + 0.5 * area + jnp.sqrt(
                half ** 3 / 24.0) * xi1
            w_r = w - w_l
            a_r = area - a_l - half * w_l
            go_left = t <= m
            key_next = jax.random.fold_in(
                key, jnp.where(go_left, jnp.uint32(2), jnp.uint32(3)))
            sel = lambda x, y: jnp.where(go_left, x, y)
            return (sel(a, m), sel(m, b), sel(w_l, w_r), sel(a_l, a_r),
                    sel(pw, pw + w_l), sel(pi, pi + half * pw + a_l),
                    key_next)

        zeros = jnp.zeros(shape, dtype)
        a, b, w, area, pw, pi, _ = lax.fori_loop(
            0, depth, body,
            (jnp.asarray(self.t0, dtype), jnp.asarray(self.t1, dtype),
             w_root, a_root, zeros, zeros, root_key))
        h = b - a
        theta = jnp.clip((t - a) / jnp.maximum(h, jnp.finfo(dtype).tiny),
                         0.0, 1.0)
        w_t = pw + (3.0 * theta ** 2 - 2.0 * theta) * w \
            + 6.0 * theta * (1.0 - theta) * area / h
        i_t = pi + theta * h * pw + h * (theta ** 3 - theta ** 2) * w \
            + (3.0 * theta ** 2 - 2.0 * theta ** 3) * area
        return w_t, i_t


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseBrownianPath:
    """Pregenerated fine-grid increments with *pathwise-consistent*
    coarsening: ``increment(n, N)`` sums the fine increments inside coarse
    step ``n``.  This is the O(T)-memory baseline — and the right tool for
    strong-convergence measurements, where coarse and fine solves must see
    the SAME sample path (the counter-based :class:`BrownianPath` gives
    per-grid refinements that agree in law but not pathwise)."""

    w: jax.Array  # (fine_steps, *shape) increments on the finest grid
    t0: float = 0.0
    t1: float = 1.0
    #: (fine_steps, *shape) per-cell space-time Lévy areas (H-mode only) —
    #: a leaf so vmap-constructed paths slice it alongside ``w``
    hh: Optional[jax.Array] = None
    levy_area: Optional[str] = None

    def __post_init__(self):
        _check_levy_mode(self.levy_area)
        if (self.levy_area == "space-time") != (self.hh is not None):
            raise ValueError(
                "DenseBrownianPath: levy_area='space-time' requires the "
                "per-cell areas hh (use sample(..., "
                "levy_area='space-time')); hh without the mode is a bug")

    def tree_flatten(self):
        return (self.w, self.hh), (self.t0, self.t1, self.levy_area)

    @classmethod
    def tree_unflatten(cls, aux, children):
        t0, t1, levy_area = aux
        return cls(w=children[0], hh=children[1], t0=t0, t1=t1,
                   levy_area=levy_area)

    @classmethod
    def sample(cls, key, t0: float, t1: float, fine_steps: int, shape,
               dtype=jnp.float32, levy_area: Optional[str] = None):
        # ``w`` is drawn from ``key`` exactly as in scalar mode, so the
        # H-mode path shares its W component bitwise with the
        # ``levy_area=None`` path of the same key — strong-convergence
        # studies can compare (W)-solvers and (W, H)-solvers on the SAME
        # sample path.  The per-cell areas come from a fold_in-tagged key.
        _check_levy_mode(levy_area)
        w = brownian_increments(key, t0, t1, fine_steps, shape, dtype)
        hh = None
        if levy_area == "space-time":
            dt = (t1 - t0) / fine_steps
            hh = jax.random.normal(
                jax.random.fold_in(key, 0xB0BA),
                (fine_steps,) + tuple(shape), dtype,
            ) * jnp.sqrt(jnp.asarray(dt, dtype) / 12.0)
        return cls(w, t0=t0, t1=t1, hh=hh, levy_area=levy_area)

    @property
    def fine_steps(self) -> int:
        return self.w.shape[0]

    @property
    def _dt_fine(self):
        return (self.t1 - self.t0) / self.fine_steps

    def increment(self, n: jax.Array, num_steps: int):
        r = self.fine_steps // num_steps
        assert r * num_steps == self.fine_steps, \
            f"{num_steps} must divide fine_steps={self.fine_steps}"
        if self.levy_area == "space-time":
            return self._increment_wh(n, r)
        if r == 1:
            return lax.dynamic_index_in_dim(self.w, n, 0, keepdims=False)
        return jnp.sum(lax.dynamic_slice_in_dim(self.w, n * r, r, 0), axis=0)

    def _increment_wh(self, n: jax.Array, r: int):
        """Coarse ``(W, H)`` by chen-combining the ``r`` fine cells of
        coarse step ``n``: raw areas add after shifting each cell's to the
        coarse left endpoint, ``A = Σ_i (A_i + dt_f · W_{prefix,i})``."""
        dtype = self.w.dtype
        dt_f = jnp.asarray(self._dt_fine, dtype)
        if r == 1:
            return (lax.dynamic_index_in_dim(self.w, n, 0, keepdims=False),
                    lax.dynamic_index_in_dim(self.hh, n, 0, keepdims=False))
        ws = lax.dynamic_slice_in_dim(self.w, n * r, r, 0)
        hs = lax.dynamic_slice_in_dim(self.hh, n * r, r, 0)
        w = jnp.sum(ws, axis=0)
        cells = dt_f * (hs + 0.5 * ws)                    # per-cell raw areas
        prefix = jnp.cumsum(ws, axis=0) - ws              # exclusive W prefix
        area = jnp.sum(cells + dt_f * prefix, axis=0)
        return w, area / (r * dt_f) - 0.5 * w

    # -- arbitrary-interval queries (adaptive solvers) -----------------------
    def _w_at(self, t) -> jax.Array:
        """W(t) from the stored fine increments: exact at fine-grid nodes
        (prefix sums of ``w``), linearly interpolated inside a fine cell.
        The interpolation is the bridge *mean* — deterministic, so
        ``evaluate`` stays exactly additive — but it under-resolves
        variation below the fine grid; size ``fine_steps`` well above the
        expected adaptive step count.

        The prefix sum is recomputed per query rather than cached on the
        pytree: under jit it is a loop constant (XLA hoists it out of the
        adaptive while_loop), and the eager payers are tests/benchmarks —
        a second ``cum`` leaf would complicate every vmap-constructed
        ``DenseBrownianPath(w_i, ...)`` for an O(fine_steps) win nothing
        on the hot path needs."""
        dtype = self.w.dtype
        t = jnp.asarray(t, dtype)
        pos = (t - self.t0) / (self.t1 - self.t0) * self.fine_steps
        pos = jnp.clip(pos, 0.0, float(self.fine_steps))
        i = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, self.fine_steps - 1)
        frac = pos - i.astype(dtype)
        cum = jnp.cumsum(self.w, axis=0)  # cum[k] = W(node k+1) − W(t0)
        w_lo = jnp.where(i > 0, lax.dynamic_index_in_dim(
            cum, jnp.maximum(i - 1, 0), 0, keepdims=False), jnp.zeros_like(self.w[0]))
        inc = lax.dynamic_index_in_dim(self.w, i, 0, keepdims=False)
        return w_lo + frac * inc

    def _wi_at(self, t):
        """H-mode point query: ``(W(t) - W(t0), I(t))`` with ``I`` the
        running time-integral.  Exact at fine-grid nodes (prefix sums of
        the per-cell increments and raw areas); inside a cell both
        components close with the conditional mean given the cell's
        ``(w, H)`` — the same deterministic-tail policy as the scalar
        linear interpolation, but H-aware (``θw + 6θ(1-θ)H`` instead of
        ``θw``), so W and I stay mutually consistent."""
        dtype = self.w.dtype
        t = jnp.asarray(t, dtype)
        dt_f = jnp.asarray(self._dt_fine, dtype)
        pos = (t - self.t0) / (self.t1 - self.t0) * self.fine_steps
        pos = jnp.clip(pos, 0.0, float(self.fine_steps))
        i = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, self.fine_steps - 1)
        theta = pos - i.astype(dtype)
        zero = jnp.zeros_like(self.w[0])
        cum_w = jnp.cumsum(self.w, axis=0)
        cells = dt_f * (self.hh + 0.5 * self.w)           # per-cell raw areas
        # I at node k = Σ_{j<k} (A_j + dt_f · (W(node j) − W(t0)))
        cum_i = jnp.cumsum(cells + dt_f * (cum_w - self.w), axis=0)
        at = lambda arr, k: lax.dynamic_index_in_dim(arr, k, 0, keepdims=False)
        w_lo = jnp.where(i > 0, at(cum_w, jnp.maximum(i - 1, 0)), zero)
        i_lo = jnp.where(i > 0, at(cum_i, jnp.maximum(i - 1, 0)), zero)
        w_c = at(self.w, i)
        a_c = at(cells, i)
        w_t = w_lo + (3.0 * theta ** 2 - 2.0 * theta) * w_c \
            + 6.0 * theta * (1.0 - theta) * a_c / dt_f
        i_t = i_lo + theta * dt_f * w_lo \
            + dt_f * (theta ** 3 - theta ** 2) * w_c \
            + (3.0 * theta ** 2 - 2.0 * theta ** 3) * a_c
        return w_t, i_t

    def evaluate(self, s, t):
        """``W_t − W_s``; pathwise-consistent with :meth:`increment` (sums of
        the same fine increments) and exactly additive over adjacent
        intervals, because every query is a difference of ``W(·)``.  In
        ``levy_area="space-time"`` mode: the ``(W, H)`` pair via
        :func:`stlevy_difference` over the two point values."""
        if self.levy_area == "space-time":
            return stlevy_difference(self.value(s), self.value(t),
                                     s, t, self.t0)
        return self._w_at(t) - self._w_at(s)

    def value(self, t):
        """``W(t) − W(t0)`` (see :meth:`BrownianPath.value` for the
        ``evaluate(s,t) == value(t) − value(s)`` contract); the
        ``(W, H_{t0,t})`` pair in ``levy_area="space-time"`` mode."""
        if self.levy_area == "space-time":
            dtype = self.w.dtype
            w, i = self._wi_at(t)
            span = jnp.asarray(t, dtype) - jnp.asarray(self.t0, dtype)
            return w, _h_from_wi(w, i, span, dtype)
        return self._w_at(t)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class VirtualBrownianTree:
    """Li et al. [15] baseline: approximate dyadic bisection to tolerance.

    Every query pays the *full* ``O(log(1/eps))`` descent from the root —
    exactly the cost profile the Brownian Interval removes (paper Table 2).
    """

    key: jax.Array
    t0: float
    t1: float
    shape: Tuple[int, ...]
    tol: float = 1e-5
    dtype: object = jnp.float32
    levy_area: Optional[str] = None

    def __post_init__(self):
        _check_levy_mode(self.levy_area)

    def tree_flatten(self):
        return (self.key,), (self.t0, self.t1, self.shape, self.tol,
                             self.dtype, self.levy_area)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (key,) = children
        t0, t1, shape, tol, dtype, levy_area = aux
        return cls(key=key, t0=t0, t1=t1, shape=shape, tol=tol, dtype=dtype,
                   levy_area=levy_area)

    @property
    def _depth(self) -> int:
        import math

        span = self.t1 - self.t0
        return max(1, int(math.ceil(math.log2(max(span / self.tol, 2.0)))))

    def _path(self) -> BrownianPath:
        return BrownianPath(self.key, self.t0, self.t1, self.shape,
                            self.dtype, levy_area=self.levy_area)

    def _w(self, t) -> jax.Array:
        return self._path()._w(t, depth=self._depth)

    def evaluate(self, s, t):
        if self.levy_area == "space-time":
            return stlevy_difference(self.value(s), self.value(t),
                                     s, t, self.t0)
        return self._w(t) - self._w(s)

    def value(self, t):
        if self.levy_area == "space-time":
            return self._path().value(t, depth=self._depth)
        return self._w(t)

    def increment(self, n: jax.Array, num_steps: int):
        dt = (self.t1 - self.t0) / num_steps
        s = self.t0 + n * dt
        return self.evaluate(s, s + dt)


def space_time_levy_area(key: jax.Array, dt, shape, dtype=jnp.float32):
    """Sample ``(W, H)`` on an interval: increment + space-time Lévy area.

    ``H`` (Foster et al. [54]) is N(0, dt/12) independent of W — the pair
    the strong-order-1.5 SRK solver consumes (paper App. E; DESIGN.md §13).
    This is the primitive draw behind the paths' ``levy_area="space-time"``
    mode (:meth:`BrownianPath.increment`, :meth:`DenseBrownianPath.sample`)
    and a building block for the ``W̃`` Lévy-area approximation of
    Davie/Foster (Appendix E, eq. for W̃; :func:`davie_levy_area`).
    """
    kw, kh = jax.random.split(key)
    dt = jnp.asarray(dt, dtype)
    w = jax.random.normal(kw, shape, dtype) * jnp.sqrt(dt)
    h = jax.random.normal(kh, shape, dtype) * jnp.sqrt(dt / 12.0)
    return w, h


def davie_levy_area(key: jax.Array, w: jax.Array, h: jax.Array, dt) -> jax.Array:
    """Davie/Foster approximation of the second iterated integral W̃ (App. E).

    ``W̃ = 0.5 W⊗W + H⊗W − W⊗H + λ`` with antisymmetric λ, λ_ij ~ N(0, dt²/12).
    ``w, h`` have shape (..., d); returns (..., d, d).
    """
    d = w.shape[-1]
    dtype = w.dtype
    lam_flat = jax.random.normal(key, w.shape[:-1] + (d, d), dtype)
    lam = (jnp.tril(lam_flat, -1) - jnp.swapaxes(jnp.tril(lam_flat, -1), -1, -2)) * jnp.sqrt(
        jnp.asarray(dt, dtype) ** 2 / 12.0
    )
    outer = lambda a, b: a[..., :, None] * b[..., None, :]
    return 0.5 * outer(w, w) + outer(h, w) - outer(w, h) + lam
