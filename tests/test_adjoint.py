"""Gradient tests — the paper's headline claim (Fig. 2 / Table 6).

The reversible-Heun exact adjoint must match discretise-then-optimise to
floating-point error; the continuous adjoint for midpoint/Heun must show
truncation error that DECREASES with step size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adjoint import continuous_adjoint_solve, reversible_heun_solve
from repro.core.brownian import BrownianPath
from repro.core.solvers import sde_solve


@pytest.fixture(autouse=True)
def _x64_scope():
    """These tests need f64 (FP-exactness claims); scope it to this module
    so x64 never leaks into the bf16 model tests that run later."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)



def _problem(key, batch=8, x_dim=8, w_dim=4, dtype=jnp.float64):
    from repro import nn

    k1, k2, kz, kw = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(k1, [x_dim, 8, x_dim], dtype=dtype),
              "g": nn.mlp_init(k2, [x_dim, 8, x_dim * w_dim], dtype=dtype)}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)

    def diffusion(p, t, x):
        out = nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
        return 0.2 * out.reshape(x.shape[:-1] + (x_dim, w_dim))

    z0 = jax.random.normal(kz, (batch, x_dim), dtype)
    bm = BrownianPath(kw, 0.0, 1.0, (batch, w_dim), dtype)
    return params, drift, diffusion, z0, bm


def _rel_err(g1, g2):
    n = sum(float(jnp.sum(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    d = max(sum(float(jnp.sum(jnp.abs(a))) for a in jax.tree.leaves(g1)), 1e-300)
    return n / d


def test_exact_adjoint_matches_dto(key):
    """reversible_heun_solve gradients == autodiff-through-the-solver."""
    params, drift, diffusion, z0, bm = _problem(key)
    n = 64

    def loss_exact(p, z):
        traj = reversible_heun_solve(drift, diffusion, p, z, bm, 0.0, 1.0, n, "general")
        return jnp.sum(traj[-1] ** 2) + jnp.sum(jnp.abs(traj[n // 2]))

    def loss_dto(p, z):
        traj = sde_solve(drift, diffusion, p, z, bm, 0.0, 1.0, n,
                         solver="reversible_heun", noise="general")
        return jnp.sum(traj[-1] ** 2) + jnp.sum(jnp.abs(traj[n // 2]))

    g1 = jax.grad(loss_exact, argnums=(0, 1))(params, z0)
    g2 = jax.grad(loss_dto, argnums=(0, 1))(params, z0)
    assert _rel_err(g1, g2) < 1e-12  # float64 roundoff — 'accurate to FP error'


def test_exact_adjoint_under_jit_and_vmap(key):
    params, drift, diffusion, z0, bm = _problem(key)

    @jax.jit
    def g(p):
        traj = reversible_heun_solve(drift, diffusion, p, z0, bm, 0.0, 1.0, 16, "general")
        return jnp.sum(traj[-1] ** 2)

    out = jax.jit(jax.grad(g))(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(out))


@pytest.mark.parametrize("solver", ["midpoint", "heun"])
def test_continuous_adjoint_error_decreases(key, solver):
    """Standard continuous adjoints: O(h^p) gradient error, shrinking in h."""
    params, drift, diffusion, z0, bm = _problem(key)
    errs = []
    for n in (4, 64):
        def loss_otd(p):
            zT = continuous_adjoint_solve(drift, diffusion, p, z0, bm, 0.0, 1.0, n,
                                          solver=solver, noise="general")
            return jnp.sum(zT ** 2)

        def loss_dto(p):
            traj = sde_solve(drift, diffusion, p, z0, bm, 0.0, 1.0, n,
                             solver=solver, noise="general")
            return jnp.sum(traj[-1] ** 2)

        g1 = jax.grad(loss_otd)(params)
        g2 = jax.grad(loss_dto)(params)
        errs.append(_rel_err(g1, g2))
    assert errs[1] < errs[0], f"{solver} adjoint error did not decrease: {errs}"
    assert errs[0] > 1e-10, "standard adjoint should NOT be exact"


def test_exact_adjoint_memory_scaling(key):
    """The custom-vjp backward stores O(1) residuals in depth: the saved
    residual pytree must not grow with num_steps."""
    params, drift, diffusion, z0, bm = _problem(key)

    def residual_count(n):
        def loss(p):
            traj = reversible_heun_solve(drift, diffusion, p, z0, bm, 0.0, 1.0, n, "general")
            return jnp.sum(traj[-1] ** 2)

        # residuals = everything saved between fwd and bwd; measure via the
        # linearized jaxpr of the fwd rule
        _, f_vjp = jax.vjp(loss, params)
        leaves = jax.tree.leaves(f_vjp)
        return sum(x.size for x in leaves if hasattr(x, "size"))

    # trajectory output itself is O(n); residuals beyond it must stay flat.
    r16 = residual_count(16)
    r256 = residual_count(256)
    traj_bytes_16 = 17 * z0.size
    traj_bytes_256 = 257 * z0.size
    # subtract the cotangent-trajectory contribution before comparing
    assert (r256 - traj_bytes_256) <= (r16 - traj_bytes_16) * 1.5 + 1024, \
        f"residuals grew with steps: {r16} -> {r256}"


# -----------------------------------------------------------------------------
# fused (Pallas) exact adjoint: gradient-exactness regressions
# -----------------------------------------------------------------------------


def _diag_problem(key, batch=4, x_dim=8, dtype=jnp.float64):
    """Diagonal-noise problem — the fused kernels' supported layout."""
    from repro import nn

    k1, k2, kz, kw = jax.random.split(key, 4)
    params = {"f": nn.mlp_init(k1, [x_dim, 8, x_dim], dtype=dtype),
              "g": nn.mlp_init(k2, [x_dim, 8, x_dim], dtype=dtype)}
    drift = lambda p, t, x: nn.mlp(p["f"], x, nn.lipswish, jnp.tanh)
    diffusion = lambda p, t, x: 0.2 * nn.mlp(p["g"], x, nn.lipswish, jnp.tanh)
    z0 = jax.random.normal(kz, (batch, x_dim), dtype)
    bm = BrownianPath(kw, 0.0, 1.0, (batch, x_dim), dtype)
    return params, drift, diffusion, z0, bm


def _assert_tree_equal(g1, g2, msg):
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=msg)


@pytest.mark.parametrize("save_trajectory", [True, False],
                         ids=["trajectory", "final"])
def test_fused_adjoint_bitwise_matches_unfused(key, save_trajectory):
    """use_pallas_kernels=True must not change the gradient AT ALL: the
    hand-derived backward kernels are bitwise the jax.vjp transpose of the
    unfused step, so fused and unfused exact adjoints agree to 0.0 in
    float64 — not merely to round-off."""
    from repro.core.solve import solve

    params, drift, diffusion, z0, bm = _diag_problem(key)
    n = 32

    def loss(p, z, fused):
        out = solve(drift, diffusion, p, z, bm, 0.0, 1.0, n,
                    gradient_mode="reversible_adjoint",
                    save_trajectory=save_trajectory,
                    use_pallas_kernels=fused)
        return jnp.sum(out ** 2)

    v_f, g_f = jax.value_and_grad(loss, argnums=(0, 1))(params, z0, True)
    v_u, g_u = jax.value_and_grad(loss, argnums=(0, 1))(params, z0, False)
    np.testing.assert_array_equal(np.asarray(v_f), np.asarray(v_u),
                                  err_msg="fused forward value drifted")
    _assert_tree_equal(g_f, g_u, "fused gradient != unfused gradient")


def test_fused_adjoint_matches_plain_ad(key):
    """Fused exact adjoint vs plain AD through the unfused frozen-grid scan
    — float64 round-off, same bar the unfused adjoint meets."""
    from repro.core.solve import solve

    params, drift, diffusion, z0, bm = _diag_problem(key)
    n = 64

    def loss_fused(p, z):
        traj = solve(drift, diffusion, p, z, bm, 0.0, 1.0, n,
                     gradient_mode="reversible_adjoint",
                     use_pallas_kernels=True)
        return jnp.sum(traj[-1] ** 2)

    def loss_dto(p, z):
        traj = sde_solve(drift, diffusion, p, z, bm, 0.0, 1.0, n,
                         solver="reversible_heun", noise="diagonal")
        return jnp.sum(traj[-1] ** 2)

    g1 = jax.grad(loss_fused, argnums=(0, 1))(params, z0)
    g2 = jax.grad(loss_dto, argnums=(0, 1))(params, z0)
    assert _rel_err(g1, g2) < 1e-12
