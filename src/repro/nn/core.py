"""Functional NN building blocks (params are plain pytrees of arrays).

Everything is a pair ``(X_init(key, ...) -> params, X(params, inputs) -> out)``
so that models compose as pure functions — the form pjit/shard_map and the
custom SDE adjoints require.
"""

from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

# -----------------------------------------------------------------------------
# activations
# -----------------------------------------------------------------------------


def silu(x):
    return x * jax.nn.sigmoid(x)


def lipswish(x):
    """LipSwish (Chen et al. [38]): 0.909·x·sigmoid(x), Lipschitz constant 1.

    The paper's required discriminator activation (§5): Lipschitz ≤ 1 and
    twice continuously differentiable (ReLU is ruled out).
    """
    return 0.909 * silu(x)


ACTIVATIONS = {
    "lipswish": lipswish,
    "silu": silu,
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "relu": jax.nn.relu,
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda x: x,
}


def tcat(t, z):
    """Concatenate a broadcast time channel onto ``z``: (..., d) -> (..., 1+d).

    The one definition of the time-augmentation convention shared by the
    generator fields (core/sde.py) and the discriminator fields (nn/cde.py).
    """
    tt = jnp.broadcast_to(jnp.asarray(t, z.dtype), z.shape[:-1] + (1,))
    return jnp.concatenate([tt, z], -1)


# -----------------------------------------------------------------------------
# linear / mlp
# -----------------------------------------------------------------------------


def linear_init(key, in_dim: int, out_dim: int, bias: bool = True, scale: Optional[float] = None,
                dtype=jnp.float32):
    kw, _ = jax.random.split(key)
    s = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": jax.random.uniform(kw, (in_dim, out_dim), dtype, -s, s)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def mlp_init(key, sizes: Sequence[int], bias: bool = True, dtype=jnp.float32):
    keys = jax.random.split(key, len(sizes) - 1)
    return {"layers": [linear_init(k, a, b, bias, dtype=dtype)
                       for k, a, b in zip(keys, sizes[:-1], sizes[1:])]}


def mlp(params, x, activation: Callable = lipswish, final_activation: Optional[Callable] = None):
    layers = params["layers"]
    for p in layers[:-1]:
        x = activation(linear(p, x))
    x = linear(layers[-1], x)
    if final_activation is not None:
        x = final_activation(x)
    return x


# -----------------------------------------------------------------------------
# norms
# -----------------------------------------------------------------------------


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"g": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    m = jnp.mean(x, -1, keepdims=True)
    v = jnp.var(x, -1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + eps) * params["g"] + params["b"]


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"g": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    # compute the variance in f32 for bf16 stability
    xf = x.astype(jnp.float32)
    v = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(v + eps)).astype(x.dtype) * params["g"]


# -----------------------------------------------------------------------------
# embedding
# -----------------------------------------------------------------------------


class Embedding:
    @staticmethod
    def init(key, vocab: int, dim: int, dtype=jnp.float32):
        return {"table": jax.random.normal(key, (vocab, dim), dtype) * 0.02}

    @staticmethod
    def lookup(params, ids):
        return jnp.take(params["table"], ids, axis=0)

    @staticmethod
    def attend(params, x):
        """Tied-readout logits."""
        return x @ params["table"].T


# -----------------------------------------------------------------------------
# GRU (latent-SDE encoder ν_φ², paper Appendix B / F)
# -----------------------------------------------------------------------------


def gru_init(key, in_dim: int, hidden: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": linear_init(k1, in_dim, 3 * hidden, dtype=dtype),
        "wh": linear_init(k2, hidden, 3 * hidden, bias=False, dtype=dtype),
        "h0": jnp.zeros((hidden,), dtype),
    }


def gru_cell(params, h, x):
    gi = linear(params["wi"], x)
    gh = linear(params["wh"], h)
    i_r, i_z, i_n = jnp.split(gi, 3, -1)
    h_r, h_z, h_n = jnp.split(gh, 3, -1)
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    return (1 - z) * n + z * h


def gru_scan(params, xs, reverse: bool = False):
    """Run a GRU over time axis 0 of ``xs`` (T, ..., in_dim) -> (T, ..., H)."""
    h0 = jnp.broadcast_to(params["h0"], xs.shape[1:-1] + params["h0"].shape)

    def body(h, x):
        h = gru_cell(params, h, x)
        return h, h

    _, hs = jax.lax.scan(body, h0, xs, reverse=reverse)
    return hs
