"""Per-arch smoke tests (deliverable f): reduced configs, one forward/train
step on CPU, output shapes + no NaNs; prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.launch.steps import (greedy_sample, make_optimizer, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import transformer as T
from repro.models.counting import param_count


def _batch(cfg, key, B=2, S=16):
    tok = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        return {"src_embeds": jax.random.normal(key, (B, S, cfg.d_model), cfg.dtype),
                "tokens": tok, "labels": tok}
    if cfg.frontend:
        f = cfg.frontend_len
        return {"embeds": jax.random.normal(key, (B, f, cfg.d_model), cfg.dtype),
                "tokens": tok[:, : S - f], "labels": tok[:, : S - f]}
    return {"tokens": tok, "labels": tok}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(key, arch):
    cfg = smoke_config(arch)
    params = T.init_lm(key, cfg)
    batch = _batch(cfg, key)
    # forward: shapes + finiteness
    if cfg.family == "encdec":
        logits, _ = T.encdec_forward(params, cfg, batch["tokens"], batch["src_embeds"])
        want_len = batch["tokens"].shape[1]
    else:
        logits, _ = T.lm_forward(params, cfg, batch["tokens"],
                                 embeds=batch.get("embeds"))
        want_len = batch["tokens"].shape[1] + (cfg.frontend_len if cfg.frontend else 0)
    assert logits.shape == (2, want_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one train step
    opt_init, opt_update = make_optimizer(cfg, total=10)
    step = jax.jit(make_train_step(cfg, opt_update))
    params2, _, metrics = step(params, opt_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    changed = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed, "train step did not update parameters"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_decode_consistency(key, arch):
    """decode(token | prefill cache) == forward over the extended sequence."""
    cfg = smoke_config(arch)
    if cfg.moe:  # ample capacity: avoid train-route token dropping in the test
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_lm(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    tok = batch["tokens"]
    nxt = jax.random.randint(jax.random.fold_in(key, 9), (B, 1), 0, cfg.vocab)

    prefill = make_prefill_step(cfg, max_len=S + 4)
    serve = make_serve_step(cfg)
    if cfg.family == "encdec":
        _, caches = prefill(params, {"src_embeds": batch["src_embeds"], "tokens": tok})
        pos = jnp.asarray(tok.shape[1], jnp.int32)
        logits_d, _ = serve(params, caches, nxt, pos)
        ext, _ = T.encdec_forward(params, cfg, jnp.concatenate([tok, nxt], 1),
                                  batch["src_embeds"])
    elif cfg.frontend:
        _, caches = prefill(params, {"embeds": batch["embeds"], "tokens": tok})
        pos = jnp.asarray(cfg.frontend_len + tok.shape[1], jnp.int32)
        logits_d, _ = serve(params, caches, nxt, pos)
        ext, _ = T.lm_forward(params, cfg, jnp.concatenate([tok, nxt], 1),
                              embeds=batch["embeds"])
    else:
        _, caches = prefill(params, {"tokens": tok})
        pos = jnp.asarray(S, jnp.int32)
        logits_d, _ = serve(params, caches, nxt, pos)
        ext, _ = T.lm_forward(params, cfg, jnp.concatenate([tok, nxt], 1))
    np.testing.assert_allclose(np.asarray(logits_d[:, 0, :], np.float32),
                               np.asarray(ext[:, -1, :], np.float32),
                               rtol=2e-2, atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_count_matches_analytic(key, arch):
    """counting.py must agree exactly with the real pytree (on smoke cfgs)."""
    cfg = smoke_config(arch)
    params = T.init_lm(key, cfg)
    real = sum(x.size for x in jax.tree.leaves(params))
    assert real == param_count(cfg), (real, param_count(cfg))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_unit_pattern(arch):
    """The FULL config's stack must divide into units (dry-run requirement)."""
    cfg = get_config(arch)
    n = T.num_units(cfg)
    assert n * len(T.unit_pattern(cfg)) == cfg.num_layers


def test_moe_capacity_drops_tokens(key):
    """Capacity routing must drop overflow (and combine must not NaN)."""
    from repro.models.layers import moe_apply

    cfg = dataclasses.replace(smoke_config("dbrx-132b"), capacity_factor=0.25)
    from repro.models.layers import moe_init

    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), cfg.dtype)
    y, logits = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_scan_vs_unrolled_identical(key):
    """cfg.scan_layers is a pure execution knob — bitwise same math."""
    cfg_s = dataclasses.replace(smoke_config("tinyllama-1.1b"),
                                num_layers=4, scan_layers=True)
    cfg_u = dataclasses.replace(cfg_s, scan_layers=False)
    params = T.init_lm(key, cfg_s)
    tok = jax.random.randint(key, (2, 8), 0, cfg_s.vocab)
    a, _ = T.lm_forward(params, cfg_s, tok)
    b, _ = T.lm_forward(params, cfg_u, tok)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-5, atol=1e-5)


def test_greedy_generation_runs(key):
    cfg = smoke_config("tinyllama-1.1b")
    params = T.init_lm(key, cfg)
    tok = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    prefill = make_prefill_step(cfg, max_len=16)
    serve = make_serve_step(cfg)
    logits, caches = prefill(params, {"tokens": tok})
    t = greedy_sample(logits)
    outs = [int(t[0, 0])]
    for i in range(4):
        logits, caches = serve(params, caches, t, jnp.asarray(8 + i, jnp.int32))
        t = greedy_sample(logits)
        outs.append(int(t[0, 0]))
    assert all(0 <= o < cfg.vocab for o in outs)


def test_reversible_residual_stack(key):
    """Beyond-paper reversible-Heun layer stack: finite grads, O(1)-memory
    custom-vjp path engaged, and gradients matching plain autodiff of the
    identical two-track recursion."""
    import dataclasses as dc

    from repro.models.reversible import reversible_stack
    from repro.models.transformer import _unit_residual

    cfg = dc.replace(smoke_config("tinyllama-1.1b"), num_layers=4,
                     reversible_residual=True)
    params = T.init_lm(key, cfg)
    x0 = jax.random.normal(jax.random.fold_in(key, 5), (2, 8, cfg.d_model), cfg.dtype)
    n = T.num_units(cfg)

    def ref_two_track(p, x):
        z = zh = x
        mu = _unit_residual(jax.tree.map(lambda a: a[0], p), cfg, zh)
        for i in range(n):
            zh1 = 2 * z - zh + mu
            mu1 = _unit_residual(
                jax.tree.map(lambda a: a[min(i + 1, n - 1)], p), cfg, zh1)
            z, zh, mu = z + 0.5 * (mu + mu1), zh1, mu1
        return z

    f_rev = lambda p: jnp.sum(reversible_stack(cfg, p["units"], x0, _unit_residual) ** 2)
    f_ref = lambda p: jnp.sum(ref_two_track(p["units"], x0) ** 2)
    np.testing.assert_allclose(float(f_rev(params)), float(f_ref(params)), rtol=1e-3)
    g1, g2 = jax.grad(f_rev)(params), jax.grad(f_ref)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-2, atol=2e-3)

    # end-to-end: train-mode forward + loss runs under the flag
    tok = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    loss, _ = T.lm_loss(params, cfg, {"tokens": tok, "labels": tok})
    assert np.isfinite(float(loss))
