"""Asyncio ingestion front-end for the continuous-batching scheduler
(DESIGN.md §14).

The :class:`Scheduler` is deliberately synchronous — one thread owns the
compiled-rollout hot loop and calls :meth:`~Scheduler.step` in a tight
iteration.  :class:`AsyncFrontend` puts an asyncio surface in front of it
without ever blocking that loop:

* Clients ``await submit(request)`` (or connect to the TCP loopback
  started by :meth:`serve_tcp`); submissions land on an
  ``asyncio.Queue``.
* One engine task drains the queue into ``Scheduler.submit`` **between**
  scheduler iterations — which is exactly a chunk boundary, so async
  arrivals join in-flight batches under the same bitwise mid-flight-
  admission contract the synchronous path has (a request submitted over
  the frontend produces trajectories bitwise-equal to a solo scheduler
  run; tests/test_serving_async.py pins this).
* Each ``Scheduler.step`` runs on a single-worker thread pool via
  ``run_in_executor``, so the event loop keeps accepting submissions
  while a compiled batch executes on device.  One worker — the scheduler
  is not thread-safe and never needs to be: all scheduler calls are
  serialised (submit on the loop thread strictly between the executor
  steps).

Ordering contract: submissions are handed to the scheduler in queue
(arrival) order, matching the scheduler's own arrival-order admission.
Results resolve per-request futures keyed by ``rid``; each future
resolves exactly once, in scheduler completion order.
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import json
import math
from typing import Dict, Optional, Tuple

from .scheduler import Scheduler
from .types import Request, ServeResult

#: Engine wakeup cadence while idle (seconds).  Only paid when the
#: scheduler has no work at all; any queued submission wakes it at once.
_IDLE_POLL_S = 0.002


def result_summary(result: ServeResult) -> dict:
    """The JSON-safe wire form of a :class:`ServeResult` — everything but
    the sample payload (trajectories never cross the TCP loopback; batch
    clients that want payloads use :class:`AsyncFrontend` in-process with
    a collecting scheduler)."""
    return {
        "rid": result.rid,
        "model_id": result.model_id,
        "size": result.size,
        "num_converged": result.num_converged,
        "latency_s": result.latency_s,
        "deadline_ms": (result.deadline_ms
                        if math.isfinite(result.deadline_ms) else None),
        "deadline_met": bool(result.deadline_met),
        "rtol": result.rtol,
    }


def request_from_wire(obj: dict) -> Request:
    """Build a :class:`Request` from a decoded JSON object (the TCP
    protocol's request form).  Unknown fields error by name — a typo'd
    field silently ignored would serve the wrong ask."""
    if not isinstance(obj, dict):
        raise ValueError(f"request must be a JSON object, got "
                         f"{type(obj).__name__}")
    allowed = {"rid", "size", "seed", "rtol", "deadline_ms", "model_id",
               "kind"}
    unknown = sorted(set(obj) - allowed)
    if unknown:
        raise ValueError(f"unknown request fields {unknown} "
                         f"(allowed: {sorted(allowed)})")
    kw = dict(obj)
    if kw.get("deadline_ms") is None:
        kw["deadline_ms"] = math.inf
    return Request(**kw)


class AsyncFrontend:
    """Async ingestion in front of one :class:`Scheduler` (see the module
    docstring for the threading and bitwise contracts).

    Usage::

        front = AsyncFrontend(scheduler)
        await front.start()
        result = await front.submit(Request(rid=0, size=2, seed=7))
        await front.close()

    ``submit`` returns when the scheduler completes the request; N
    concurrent ``submit`` coroutines form an open-loop client population.
    """

    def __init__(self, scheduler: Scheduler):
        self.scheduler = scheduler
        self._queue: Optional[asyncio.Queue] = None
        self._futures: Dict[int, asyncio.Future] = {}
        self._engine: Optional[asyncio.Task] = None
        self._executor: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._server: Optional[asyncio.AbstractServer] = None
        #: Scheduler iterations the engine has run (tests observe progress).
        self.steps = 0

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        """Start the engine task.  Idempotent; must run inside the event
        loop that will carry the submissions."""
        if self._engine is not None:
            return
        self._queue = asyncio.Queue()
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-step")
        self._engine = asyncio.get_running_loop().create_task(
            self._run_engine())

    async def close(self) -> None:
        """Stop the engine after the queue drains and every outstanding
        request resolves; shuts the TCP server down first if one is up."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        if self._engine is None:
            return
        while self._futures or not self._queue.empty():
            await asyncio.sleep(_IDLE_POLL_S)
        engine, self._engine = self._engine, None
        engine.cancel()
        try:
            await engine
        except asyncio.CancelledError:
            pass
        self._executor.shutdown(wait=True)
        self._executor = None

    # -- submission ---------------------------------------------------------

    async def submit(self, request: Request,
                     arrival_s: Optional[float] = None) -> ServeResult:
        """Enqueue one request and await its :class:`ServeResult`.

        ``arrival_s`` (scheduler-clock seconds) is forwarded to
        ``Scheduler.submit`` so open-loop drivers can stamp synthetic
        arrival times; by default the scheduler stamps hand-off time, so
        reported latency includes time spent queued in the frontend.
        ``rid`` values must be unique among in-flight requests — the rid
        keys the result future."""
        if self._engine is None:
            raise RuntimeError("AsyncFrontend.start() has not run — "
                               "submissions have no engine to serve them")
        if request.rid in self._futures:
            raise ValueError(
                f"request rid {request.rid} is already in flight — rids "
                f"key result delivery and must be unique")
        future: asyncio.Future = asyncio.get_running_loop().create_future()
        self._futures[request.rid] = future
        self._queue.put_nowait((request, arrival_s))
        return await future

    # -- the engine ---------------------------------------------------------

    def _drain_queue(self) -> None:
        # runs on the loop thread between executor steps — the only place
        # submissions enter the scheduler, so arrivals join at chunk
        # boundaries by construction
        while not self._queue.empty():
            request, arrival_s = self._queue.get_nowait()
            try:
                self.scheduler.submit(request, arrival_s=arrival_s)
            except Exception as e:  # noqa: BLE001 — deliver, don't kill loop
                self._futures.pop(request.rid).set_exception(e)

    async def _run_engine(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            if self._queue.empty() and not self.scheduler.busy:
                await asyncio.sleep(_IDLE_POLL_S)
                continue
            self._drain_queue()
            if not self.scheduler.busy:
                continue
            results = await loop.run_in_executor(
                self._executor, self.scheduler.step)
            self.steps += 1
            for result in results:
                future = self._futures.pop(result.rid, None)
                if future is not None and not future.done():
                    future.set_result(result)
            # yield so submit() callers queued behind the step get in
            # before the next iteration
            await asyncio.sleep(0)

    # -- TCP loopback -------------------------------------------------------

    async def serve_tcp(self, host: str = "127.0.0.1",
                        port: int = 0) -> Tuple[str, int]:
        """Expose the frontend on a TCP loopback socket; returns the bound
        ``(host, port)``.

        Wire protocol: JSON lines.  Each client line is one request object
        (fields of :class:`Request`; ``deadline_ms: null`` means no SLO),
        answered — in completion order, not necessarily request order — by
        one :func:`result_summary` line, or ``{"rid": ..., "error": msg}``
        for a rejected submission.  Payloads never cross the socket."""
        if self._engine is None:
            await self.start()

        async def handle(reader, writer):
            pending = set()

            async def roundtrip(line):
                try:
                    result = await self.submit(request_from_wire(
                        json.loads(line)))
                    out = result_summary(result)
                except Exception as e:  # noqa: BLE001 — report to client
                    try:
                        rid = json.loads(line).get("rid")
                    except Exception:  # noqa: BLE001
                        rid = None
                    out = {"rid": rid, "error": str(e)}
                writer.write(json.dumps(out).encode() + b"\n")
                await writer.drain()

            try:
                while True:
                    line = await reader.readline()
                    if not line:
                        break
                    pending.add(asyncio.get_running_loop().create_task(
                        roundtrip(line.decode())))
                    pending = {t for t in pending if not t.done()}
                if pending:
                    await asyncio.gather(*pending)
            finally:
                writer.close()
                await writer.wait_closed()

        self._server = await asyncio.start_server(handle, host, port)
        sock = self._server.sockets[0].getsockname()
        return sock[0], sock[1]
