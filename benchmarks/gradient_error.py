"""Paper Fig. 2 / Table 6: relative gradient error of continuous adjoints.

Fixes the paper's test problem (differentiate a small Neural SDE) and
compares optimise-then-discretise gradients against discretise-then-optimise
per solver and step size.  The reversible Heun method must be exact to
floating-point error; midpoint/Heun carry O(h^p) truncation error.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:
    from . import report
except ImportError:  # run as a loose script
    import report


def build_problem(key, batch=32, x_dim=32, w_dim=16, width=8, dtype=jnp.float64):
    from repro import nn
    from repro.core.brownian import BrownianPath

    kp1, kp2, kz, kw = jax.random.split(key, 4)
    params = {
        "f": nn.mlp_init(kp1, [x_dim, width, x_dim], dtype=dtype),
        "g": nn.mlp_init(kp2, [x_dim, width, x_dim * w_dim], dtype=dtype),
    }

    def drift(p, t, x):
        return jax.nn.sigmoid(nn.mlp(p["f"], x, nn.lipswish))

    def diffusion(p, t, x):
        out = jax.nn.sigmoid(nn.mlp(p["g"], x, nn.lipswish))
        return out.reshape(x.shape[:-1] + (x_dim, w_dim)) * 0.2

    z0 = jax.random.normal(kz, (batch, x_dim), dtype)
    bm = BrownianPath(kw, 0.0, 1.0, (batch, w_dim), dtype)
    return params, drift, diffusion, z0, bm


def relative_l1(g1, g2):
    l1, l2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    num = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(l1, l2))
    den = max(sum(float(jnp.sum(jnp.abs(a))) for a in l1),
              sum(float(jnp.sum(jnp.abs(b))) for b in l2), 1e-300)
    return num / den


def gradient_error(solver: str, num_steps: int, key=None, dtype=jnp.float64):
    """Relative L1 error of adjoint-computed vs autodiff gradients.

    Both paths dispatch through :func:`repro.solve`: the reference is
    ``gradient_mode="discretise"`` (AD through the scan), the adjoint under
    test is the registry's native adjoint for the solver —
    ``"reversible_adjoint"`` (exact) for reversible Heun,
    ``"continuous_adjoint"`` (eq. (6), O(√h) error) for midpoint/Heun.
    """
    from repro.core.solve import get_solver, solve

    key = jax.random.PRNGKey(0) if key is None else key
    params, drift, diffusion, z0, bm = build_problem(key, dtype=dtype)

    def loss_dto(p, z):
        traj = solve(drift, diffusion, p, z, bm, 0.0, 1.0, num_steps,
                     solver=solver, gradient_mode="discretise", noise="general")
        return jnp.sum(traj[-1] ** 2)

    g_dto = jax.grad(loss_dto, argnums=(0, 1))(params, z0)

    adjoint_mode = ("reversible_adjoint"
                    if "reversible_adjoint" in get_solver(solver).gradient_modes
                    else "continuous_adjoint")

    def loss_otd(p, z):
        zT = solve(drift, diffusion, p, z, bm, 0.0, 1.0, num_steps,
                   solver=solver, gradient_mode=adjoint_mode, noise="general",
                   save_trajectory=False)
        return jnp.sum(zT ** 2)

    g_otd = jax.grad(loss_otd, argnums=(0, 1))(params, z0)
    return relative_l1(g_otd, g_dto)


PRESET_STEPS = {
    "tiny": [1, 4, 16],
    "quick": [1, 4, 16, 64],
    "full": [1, 4, 16, 64, 256, 1024],
}


def main(preset: str = "full"):
    jax.config.update("jax_enable_x64", True)
    steps_list = PRESET_STEPS[preset]
    rows = []
    for solver in ("midpoint", "heun", "reversible_heun"):
        for n in steps_list:
            err = gradient_error(solver, n)
            rows.append(("gradient_error", f"{solver},steps={n}", err))
            print(f"gradient_error,{solver},steps={n},{err:.3e}", flush=True)
    jax.config.update("jax_enable_x64", False)
    return rows


if __name__ == "__main__":
    report.standalone("gradient_error", main)
