"""Test metrics and losses (paper Appendix F.1).

The headline evaluation metric is the signature-feature MMD: the feature map
ψ is the depth-``m`` truncated path signature of the time-augmented path
(Király & Oberhauser [69]); MMD = ‖E ψ(P) − E ψ(Q)‖ (paper F.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _segment_exp(dy, depth: int):
    """Truncated signature of a linear segment: exp⊗(dy) levels 1..depth.

    Level k is ``dy^⊗k / k!`` with shape ``batch + (d,)*k``.
    """
    d = dy.shape[-1]
    batch = dy.shape[:-1]
    levels = [dy]
    for k in range(2, depth + 1):
        prev = levels[-1]  # batch + (d,)*(k-1)
        nxt = prev[..., None] * dy.reshape(batch + (1,) * (k - 1) + (d,)) / k
        levels.append(nxt)
    return levels


def signature(path: jax.Array, depth: int = 3) -> jax.Array:
    """Depth-``depth`` truncated signature of ``path`` (T+1, ..., d).

    Chen's relation over segments: S ← S ⊗ exp(Δy).  Returns the flattened
    concatenation of levels 1..depth, shape (..., d + d² + … + d^depth).
    """
    d = path.shape[-1]
    dys = path[1:] - path[:-1]  # (T, ..., d)
    batch_shape = path.shape[1:-1]

    def init_levels():
        return [jnp.zeros(batch_shape + (d,) * k, path.dtype) for k in range(1, depth + 1)]

    def body(S, dy):
        E = _segment_exp(dy, depth)
        out = []
        for k in range(1, depth + 1):
            # level k of S ⊗ E:  E_k + S_k + Σ_{i=1..k-1} S_i ⊗ E_{k-i}
            acc = E[k - 1] + S[k - 1]
            for i in range(1, k):
                a = S[i - 1].reshape(batch_shape + (d,) * i + (1,) * (k - i))
                b = E[k - i - 1].reshape(batch_shape + (1,) * i + (d,) * (k - i))
                acc = acc + a * b
            out.append(acc)
        return out, None

    S, _ = lax.scan(body, init_levels(), dys)
    flat = [s.reshape(batch_shape + (-1,)) for s in S]
    return jnp.concatenate(flat, -1)


def time_augment(ys: jax.Array, t1: float = 1.0) -> jax.Array:
    """Prepend a time channel: (T+1, ..., y) -> (T+1, ..., 1+y)."""
    T = ys.shape[0] - 1
    ts = jnp.linspace(0.0, t1, T + 1, dtype=ys.dtype)
    tt = jnp.broadcast_to(ts[(slice(None),) + (None,) * (ys.ndim - 1)], ys.shape[:-1] + (1,))
    return jnp.concatenate([tt, ys], -1)


def signature_mmd(y_p: jax.Array, y_q: jax.Array, depth: int = 3) -> jax.Array:
    """MMD between two path samples (T+1, batch, y) with signature features."""
    fp = signature(time_augment(y_p), depth)
    fq = signature(time_augment(y_q), depth)
    diff = jnp.mean(fp, axis=0) - jnp.mean(fq, axis=0)
    return jnp.sqrt(jnp.sum(diff * diff) + 1e-12)


def wasserstein_losses(fake_score, real_score):
    gen_loss = -jnp.mean(fake_score)
    disc_loss = jnp.mean(fake_score) - jnp.mean(real_score)
    return gen_loss, disc_loss
