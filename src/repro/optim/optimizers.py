"""Optimisers as (init, update) pairs over parameter pytrees.

Paper Appendix F: Adam [81] for Latent SDEs, Adadelta [82] for SDE-GANs,
stochastic weight averaging (Cesàro mean over the last 50% of steps) [83, 84]
for GAN generators.  AdamW + cosine schedule serve the LM training path.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def _zeros_like_tree(t):
    return jax.tree.map(jnp.zeros_like, t)


class OptState(NamedTuple):
    step: jax.Array
    m: object
    v: object


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, moment_dtype=None):
    """``moment_dtype`` ("bfloat16" halves optimizer HBM at 100B+ scale; see
    EXPERIMENTS.md §Perf) defaults to the parameter dtype."""

    def _moments(params):
        if moment_dtype is None:
            return _zeros_like_tree(params)
        dt = jnp.dtype(moment_dtype)
        return jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _moments(params), _moments(params))

    def update(grads, state, params=None):
        step = state.step + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                         state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * (g * g).astype(v_.dtype),
                         state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr(step) if callable(lr) else lr
        upd = jax.tree.map(
            lambda m_, v_, g: (-lr_t * (m_.astype(jnp.float32) / bc1)
                               / (jnp.sqrt(v_.astype(jnp.float32) / bc2) + eps)
                               ).astype(g.dtype),
            m, v, grads)
        return upd, OptState(step, m, v)

    return init, update


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, moment_dtype=None):
    ai, au = adam(lr, b1, b2, eps, moment_dtype=moment_dtype)

    def update(grads, state, params):
        upd, state = au(grads, state, params)
        lr_t = lr(state.step) if callable(lr) else lr
        upd = jax.tree.map(lambda u, p: u - lr_t * weight_decay * p, upd, params)
        return upd, state

    return ai, update


def adadelta(lr=1.0, rho=0.9, eps=1e-6):
    """Adadelta [82] — the paper's SDE-GAN optimiser."""

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_tree(params), _zeros_like_tree(params))

    def update(grads, state, params=None):
        acc_g = jax.tree.map(lambda a, g: rho * a + (1 - rho) * g * g, state.m, grads)
        upd = jax.tree.map(
            lambda g, ag, ad: -lr * g * jnp.sqrt(ad + eps) / jnp.sqrt(ag + eps),
            grads, acc_g, state.v)
        acc_d = jax.tree.map(lambda a, u: rho * a + (1 - rho) * u * u, state.v, upd)
        return upd, OptState(state.step + 1, acc_g, acc_d)

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(jnp.add, params, updates)


# -----------------------------------------------------------------------------
# optax-style composition
# -----------------------------------------------------------------------------
#
# Every optimiser here is an ``(init, update)`` pair with
# ``update(updates, state, params) -> (updates, state)`` — the optax
# GradientTransformation protocol minus the NamedTuple wrapper.  ``chain``
# composes them left-to-right, so real optax transforms interoperate:
# ``chain(optax.clip(1.0), adadelta(1.0), lipschitz_projection())`` is legal
# (optax's extra-args update signature matches).


def chain(*transforms):
    """Compose ``(init, update)`` transforms; states are carried as a tuple."""
    inits, updates = zip(*transforms)

    def init(params):
        return tuple(i(params) for i in inits)

    def update(grads, state, params=None):
        new_state = []
        for u, s in zip(updates, state):
            grads, s = u(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return init, update


def lipschitz_projection(clip_fn=None):
    """Careful clipping (paper §5) as a pytree transform in the update chain.

    The paper applies the clip to the *parameters after* the optimiser
    update.  Expressed on updates — so it composes with any optax-style
    chain — that is ``upd ← clip(params + upd) − params``: applying the
    returned update lands exactly on the projected parameters, with no
    second backward pass anywhere (DESIGN.md §4).

    Place it *last* in the chain (it must see the final update).  Stateless.
    ``clip_fn`` defaults to the structural :func:`repro.core.clipping.clip_pytree`;
    pass e.g. ``clip_lipschitz`` to restrict to named discriminator MLPs.
    """
    from ..core.clipping import clip_pytree

    project = clip_fn if clip_fn is not None else clip_pytree

    def init(params):
        return ()

    def update(upd, state, params):
        if params is None:
            raise ValueError("lipschitz_projection needs params: the clip is "
                             "a projection of params + update, not of the "
                             "update alone")
        stepped = apply_updates(params, upd)
        clipped = project(stepped)
        new_upd = jax.tree.map(jnp.subtract, clipped, params)
        return new_upd, state

    return init, update


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def swa_update(avg_params, params, num_avged):
    """Cesàro/Polyak averaging (paper: mean over latter 50% of GAN steps)."""
    w = 1.0 / (num_avged + 1)
    return jax.tree.map(lambda a, p: a + w * (p - a), avg_params, params)
