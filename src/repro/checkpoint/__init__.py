from .store import (  # noqa: F401
    SERVING_SCHEMA,
    config_to_meta,
    latest_step,
    load_serving_meta,
    restore_checkpoint,
    restore_serving_bundle,
    save_checkpoint,
    save_serving_bundle,
)
