"""Fused reversible-Heun state updates (Algorithm 1) as Pallas TPU kernels.

The solver's per-step arithmetic is pure elementwise VPU work: without
fusion, XLA materialises each intermediate (2z, −ẑ, μΔt, σΔW, …) through
HBM.  One VMEM-resident kernel per phase turns ~6 HBM round-trips into one
read + one write per operand — the solver loop is memory-bound, so this is
the hot spot the paper's 1-NFE-per-step advantage exposes.

Phase 1 computes ẑ_{n+1} (before the vector-field evaluation); phase 2
computes z_{n+1} (after).  Diagonal-noise layout: all operands share the
state shape, flattened to (rows, cols) with cols a multiple of the 128-lane
VPU width where possible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _phase1_kernel(dt, z_ref, zh_ref, mu_ref, sig_ref, dw_ref, o_ref):
    o_ref[...] = (
        2.0 * z_ref[...]
        - zh_ref[...]
        + mu_ref[...] * dt
        + sig_ref[...] * dw_ref[...]
    )


def _phase2_kernel(dt, z_ref, mu_ref, mu1_ref, sig_ref, sig1_ref, dw_ref, o_ref):
    o_ref[...] = (
        z_ref[...]
        + (0.5 * dt) * (mu_ref[...] + mu1_ref[...])
        + 0.5 * (sig_ref[...] + sig1_ref[...]) * dw_ref[...]
    )


def _tile(n: int, pref: int) -> int:
    for t in (pref, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if t <= n and n % t == 0:
            return t
    return 1


def _call_elementwise(kernel, args, interpret: bool):
    x = args[0]
    orig_shape = x.shape
    flat = [a.reshape(-1, orig_shape[-1]) if a.ndim > 1 else a.reshape(1, -1) for a in args]
    rows, cols = flat[0].shape
    br, bc = _tile(rows, 256), _tile(cols, 512)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    out = pl.pallas_call(
        kernel,
        grid=(rows // br, cols // bc),
        in_specs=[spec] * len(flat),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(*flat)
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("dt", "interpret"))
def rev_heun_phase1(z, zh, mu, sigma, dw, dt: float, interpret: bool = True):
    return _call_elementwise(
        functools.partial(_phase1_kernel, dt), (z, zh, mu, sigma, dw), interpret)


@functools.partial(jax.jit, static_argnames=("dt", "interpret"))
def rev_heun_phase2(z, mu, mu1, sigma, sigma1, dw, dt: float, interpret: bool = True):
    return _call_elementwise(
        functools.partial(_phase2_kernel, dt), (z, mu, mu1, sigma, sigma1, dw), interpret)
