"""dbrx-132b [moe] — 16 experts top-4 (fine-grained MoE).
[hf:databricks/dbrx-base; unverified]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    moe=True,
    num_experts=16,
    top_k=4,
    ffn="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
)
