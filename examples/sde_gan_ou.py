"""SDE-GAN on the time-dependent Ornstein-Uhlenbeck dataset (paper §5/F.7).

Trains the generator/discriminator pair with the paper's recipe:
Stratonovich reversible Heun + exact adjoint, Adadelta, hard Lipschitz
clipping + LipSwish (NO gradient penalty), stochastic weight averaging.
Reports signature-MMD against held-out data.

Run:  PYTHONPATH=src python examples/sde_gan_ou.py --steps 300
"""

import argparse
import time

import jax

from repro import optim
from repro.core import losses
from repro.core.sde import (NeuralSDEConfig, discriminator_init,
                            generator_init, generator_sample)
from repro.data.synthetic import ou_process
from repro.launch.steps import make_gan_optimizers, make_sde_gan_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--constraint", choices=("clip", "gp"), default="clip",
                    help="'clip' = paper §5; 'gp' = WGAN-GP baseline")
    ap.add_argument("--solver", default="reversible_heun",
                    choices=("reversible_heun", "midpoint"))
    ap.add_argument("--pallas", action="store_true",
                    help="request the fused reversible-Heun hot loop "
                         "(repro.solve use_pallas_kernels). NOTE: the fused "
                         "kernels are diagonal-noise only, and every SDE-GAN "
                         "solve uses general (matrix) noise — each solve "
                         "warns and runs unfused. Kept as the config knob "
                         "for diagonal-noise workloads (e.g. Latent SDE).")
    args = ap.parse_args(argv)

    cfg = NeuralSDEConfig(
        data_dim=1, hidden_dim=16, noise_dim=4, width=32, num_steps=31,
        solver=args.solver, exact_adjoint=args.solver == "reversible_heun",
        use_pallas_kernels=args.pallas)
    key = jax.random.PRNGKey(0)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    data_key = jax.random.fold_in(key, 2)

    # The shared WGAN step (repro.launch.steps): under "clip" one jax.vjp
    # forward + careful clipping as the tail of the discriminator optimiser
    # chain; under "gp" the double-backward WGAN-GP baseline.
    (gi, gu), (di, du) = make_gan_optimizers(lr=1.0, constraint=args.constraint)
    g_state, d_state = gi(params["gen"]), di(params["disc"])
    train_step = jax.jit(make_sde_gan_step(cfg, gu, du, args.batch, 32,
                                           constraint=args.constraint))

    swa, n_avg = None, 0
    t0 = time.time()
    for step in range(args.steps):
        params, g_state, d_state, _ = train_step(params, g_state, d_state,
                                                 jax.random.fold_in(data_key, step))
        if step >= args.steps // 2:               # SWA over the latter 50%
            swa = params["gen"] if swa is None else optim.swa_update(swa, params["gen"], n_avg)
            n_avg += 1
        if step % 50 == 0:
            y_real = ou_process(jax.random.fold_in(key, 777), 256, 32)
            fake = generator_sample(params["gen"], cfg, jax.random.fold_in(key, 778), 256)
            mmd = float(losses.signature_mmd(y_real, fake))
            print(f"step {step:4d}  sig-MMD {mmd:.4f}  ({time.time()-t0:.0f}s)",
                  flush=True)

    gen_final = swa if swa is not None else params["gen"]
    y_real = ou_process(jax.random.fold_in(key, 888), 512, 32)
    fake = generator_sample(gen_final, cfg, jax.random.fold_in(key, 889), 512)
    mmd = float(losses.signature_mmd(y_real, fake))
    print(f"final ({args.constraint}, {args.solver}): sig-MMD {mmd:.4f}, "
          f"total {time.time()-t0:.0f}s")
    return mmd


if __name__ == "__main__":
    main()
