"""Paper Table 3 / 11 (speed axis): careful clipping vs gradient penalty.

Times one full WGAN training step of the SDE-GAN subsystem
(``repro.launch.steps.make_sde_gan_step``) under both Lipschitz regimes:

* **clipping** — the paper's recipe: reversible Heun + exact adjoint, one
  shared ``jax.vjp`` forward for both players, hard clipping as the tail of
  the discriminator optimiser chain (single backward);
* **grad_penalty** — the WGAN-GP baseline it replaces: midpoint +
  discretise-then-optimise, double backward through the CDE solve plus an
  extra generator solve for the interpolates.

The removal of the double backward is the 1.41× speedup of Table 11;
reversible Heun adds the rest (1.87× total).  Also verifies the clipped
vector fields keep Lipschitz bound ≤ 1 after a real optimiser update.

Run:  PYTHONPATH=src python benchmarks/clipping.py --preset tiny
Emits BENCH_clipping.json (schema in benchmarks/report.py).
"""

from __future__ import annotations

import time

import jax

try:
    from . import report
except ImportError:  # run as a loose script: python benchmarks/clipping.py
    import report

# Shapes: solver steps must be high enough that the GP step's structural
# extra work (double backward + interpolate CDE solve) dominates per-step
# dispatch overhead, or the CI gate gets noisy — 8-step problems measure
# the Python/XLA launch path, not the algorithms.
PRESET_SHAPES = {
    #          num_steps, seq_len, batch, reps
    "tiny":  (16, 17, 32, 8),
    "quick": (24, 25, 64, 8),
    "full":  (31, 32, 128, 15),
}


def _time_step(step, params, g_state, d_state, key, reps: int) -> float:
    """Best of ``reps`` individually-timed steps — the paper's protocol
    ("errors in speed benchmarks are one-sided"): the min is robust to GC
    pauses and scheduler noise on shared CI runners, which a mean is not."""
    for _ in range(2):  # compile, then one warm run (caches, allocator)
        out = step(params, g_state, d_state, key)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = step(params, g_state, d_state, key)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_constraint(constraint: str, num_steps: int, seq_len: int,
                     batch: int, reps: int) -> float:
    """Seconds per full WGAN step under the given Lipschitz regime."""
    from repro.core.sde import NeuralSDEConfig, discriminator_init, generator_init
    from repro.launch.steps import make_gan_optimizers, make_sde_gan_step

    # The paper's pairing: clipping gets reversible Heun + exact adjoint;
    # GP is stuck with discretise-then-optimise (no double-backward rule
    # for the O(1)-memory adjoint) on the midpoint baseline.
    clip = constraint == "clip"
    cfg = NeuralSDEConfig(
        num_steps=num_steps,
        solver="reversible_heun" if clip else "midpoint",
        exact_adjoint=clip)
    key = jax.random.PRNGKey(0)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    (gi, gu), (di, du) = make_gan_optimizers(lr=1.0, constraint=constraint)
    g_state, d_state = gi(params["gen"]), di(params["disc"])
    step = jax.jit(make_sde_gan_step(cfg, gu, du, batch, seq_len,
                                     constraint=constraint))
    return _time_step(step, params, g_state, d_state,
                      jax.random.fold_in(key, 2), reps)


def lipschitz_rows(num_steps: int, seq_len: int, batch: int):
    """Bound ≤ 1 for f/g/xi after a *real* update step (not just a raw clip)."""
    from repro.core.clipping import lipschitz_bound_mlp
    from repro.core.sde import NeuralSDEConfig, discriminator_init, generator_init
    from repro.launch.steps import make_gan_optimizers, make_sde_gan_step

    cfg = NeuralSDEConfig(num_steps=num_steps)
    key = jax.random.PRNGKey(7)
    params = {"gen": generator_init(key, cfg),
              "disc": discriminator_init(jax.random.fold_in(key, 1), cfg)}
    # blow the discriminator out of the constraint set, then take one step:
    # the projection in the optimiser chain must land it back inside
    params["disc"] = jax.tree.map(lambda x: x * 10.0, params["disc"])
    (gi, gu), (di, du) = make_gan_optimizers(lr=1.0, constraint="clip")
    step = jax.jit(make_sde_gan_step(cfg, gu, du, batch, seq_len))
    params, _, _, _ = step(params, gi(params["gen"]), di(params["disc"]),
                           jax.random.fold_in(key, 2))
    rows = []
    for name in ("f", "g", "xi"):
        b = float(lipschitz_bound_mlp(params["disc"][name]))
        rows.append(("clipping", f"lipschitz_bound_{name}", b))
        print(f"clipping,lipschitz_bound_{name},{b:.3f}", flush=True)
        assert b <= 1.0 + 1e-6, f"clipping failed to bound {name}: {b}"
    return rows


def main(preset: str = "full"):
    num_steps, seq_len, batch, reps = PRESET_SHAPES[preset]
    rows = []
    timings = {}
    for constraint, label in (("clip", "clipping"), ("gp", "grad_penalty")):
        dt = bench_constraint(constraint, num_steps, seq_len, batch, reps)
        timings[label] = dt
        rows.append(("clipping", f"{label}_ms_per_step", dt * 1e3))
        print(f"clipping,{label},{dt*1e3:.2f}ms", flush=True)
    sp = timings["grad_penalty"] / timings["clipping"]
    rows.append(("clipping", "speedup", sp))
    print(f"clipping,speedup,{sp:.2f}x", flush=True)
    # the paper's claim, and the CI gate: clipping is never slower than GP
    assert timings["clipping"] <= timings["grad_penalty"], (
        f"clipping ({timings['clipping']*1e3:.2f}ms) slower than gradient "
        f"penalty ({timings['grad_penalty']*1e3:.2f}ms)")

    rows.extend(lipschitz_rows(num_steps, seq_len, batch))
    return rows


if __name__ == "__main__":
    report.standalone("clipping", main)
